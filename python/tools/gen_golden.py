"""Golden-vector generator for the rust native backend parity suite.

Replicates the rust `util::rng::Rng` (splitmix64-seeded xoshiro256**,
Box-Muller normals with spare caching) bit-exactly, regenerates the same
inputs `rust/tests/native_parity.rs` builds, evaluates the reference math
(float64 numpy transliteration of python/compile/{model,besa}.py — the
same formulas validated against jax), and writes summary vectors to
`rust/tests/golden/native_test_vectors.json`.

Run from the repo root:  python3 python/tools/gen_golden.py

The rust test regenerates identical inputs via its own Rng and compares
native-backend outputs against these values within float32 tolerances.
No jax/torch required — this is plain numpy.
"""

import json
import math
import os

import numpy as np

MASK64 = (1 << 64) - 1


class Rng:
    """Bit-exact mirror of rust util::rng::Rng."""

    def __init__(self, seed: int):
        sm = seed & MASK64
        s = []
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & MASK64
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
            s.append(z ^ (z >> 31))
        self.s = s
        self.spare = None

    def next_u64(self) -> int:
        s = self.s
        x = (s[1] * 5) & MASK64
        result = (((x << 7) | (x >> 57)) & MASK64) * 9 & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = ((s[3] << 45) | (s[3] >> 19)) & MASK64
        return result

    def f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n: int) -> int:
        return self.next_u64() % n

    def normal(self) -> float:
        if self.spare is not None:
            v = self.spare
            self.spare = None
            return v
        while True:
            u1 = self.f64()
            u2 = self.f64()
            if u1 <= 2.2250738585072014e-308:
                continue
            r = math.sqrt(-2.0 * math.log(u1))
            ang = 2.0 * math.pi * u2
            self.spare = r * math.sin(ang)
            return r * math.cos(ang)

    def normal_f32(self) -> float:
        return np.float32(self.normal())

    def shuffle(self, xs):
        for i in range(len(xs) - 1, 0, -1):
            j = self.below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]

    def permutation(self, n: int):
        v = list(range(n))
        self.shuffle(v)
        return v


# --------------------------- config ("test") --------------------------------
class Cfg:
    vocab = 256
    d_model = 32
    n_heads = 2
    n_blocks = 2
    d_ffn = 88
    seq_len = 32
    batch = 4
    n_rates = 16
    rope_base = 10000.0
    norm_eps = 1e-5
    d_head = 16


cfg = Cfg()
LAYER_NAMES = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"]


def layer_shape(w):
    d, f = cfg.d_model, cfg.d_ffn
    return {"wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
            "wg": (f, d), "wu": (f, d), "wd": (d, f)}[w]


def param_order():
    names = ["embed"]
    for l in range(cfg.n_blocks):
        names += [f"blocks.{l}.{w}" for w in LAYER_NAMES]
        names += [f"blocks.{l}.norm1", f"blocks.{l}.norm2"]
    names.append("norm_f")
    return names


def param_shape(name):
    if name == "embed":
        return (cfg.vocab, cfg.d_model)
    if name == "norm_f" or name.endswith(("norm1", "norm2")):
        return (cfg.d_model,)
    return layer_shape(name.rsplit(".", 1)[-1])


def param_store_init(seed):
    """Mirror of rust ParamStore::init."""
    rng = Rng(seed)
    params = {}
    for name in param_order():
        shape = param_shape(name)
        n = int(np.prod(shape))
        if len(shape) == 1:
            t = np.ones(shape)
        elif name == "embed":
            t = np.array([rng.normal_f32() * np.float32(0.02) for _ in range(n)],
                         dtype=np.float64).reshape(shape)
        else:
            std = np.float32(1.0) / np.float32(np.sqrt(np.float32(shape[1])))
            t = np.array([rng.normal_f32() * std for _ in range(n)],
                         dtype=np.float64).reshape(shape)
        params[name] = t
    return params


# --------------------------- reference math (float64) -----------------------
def rmsnorm(x, gain):
    var = np.mean(x * x, axis=-1, keepdims=True)
    return x / np.sqrt(var + cfg.norm_eps) * gain


def rmsnorm_bwd(x, gain, gy):
    d = x.shape[-1]
    var = np.mean(x * x, axis=-1, keepdims=True)
    r = 1.0 / np.sqrt(var + cfg.norm_eps)
    ggain = np.sum(gy * x * r, axis=tuple(range(x.ndim - 1)))
    s = np.sum(gy * gain * x, axis=-1, keepdims=True)
    gx = gy * gain * r - (r ** 3 / d) * x * s
    return gx, ggain


def rope_tables():
    dh = cfg.d_head
    inv = 1.0 / (cfg.rope_base ** (np.arange(0, dh, 2) / dh))
    ang = np.arange(cfg.seq_len)[:, None] * inv[None, :]
    return np.cos(ang), np.sin(ang)


def apply_rope(q, cos, sin):
    q1, q2 = q[..., 0::2], q[..., 1::2]
    out = np.empty_like(q)
    out[..., 0::2] = q1 * cos - q2 * sin
    out[..., 1::2] = q1 * sin + q2 * cos
    return out


def rope_bwd(go, cos, sin):
    g1, g2 = go[..., 0::2], go[..., 1::2]
    gq = np.empty_like(go)
    gq[..., 0::2] = g1 * cos + g2 * sin
    gq[..., 1::2] = -g1 * sin + g2 * cos
    return gq


def split_heads(x):
    b, s, d = x.shape
    return x.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)


def merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def attention_fwd(q, k, v, save=False):
    qh, kh, vh = split_heads(q), split_heads(k), split_heads(v)
    cos, sin = rope_tables()
    qr, kr = apply_rope(qh, cos, sin), apply_rope(kh, cos, sin)
    att = np.einsum("bhqd,bhkd->bhqk", qr, kr) / np.sqrt(cfg.d_head)
    s = att.shape[-1]
    causal = np.tril(np.ones((s, s), bool))
    att = np.where(causal[None, None], att, -np.inf)
    att = att - att.max(axis=-1, keepdims=True)
    e = np.exp(att)
    p = e / e.sum(axis=-1, keepdims=True)
    out = merge_heads(np.einsum("bhqk,bhkd->bhqd", p, vh))
    if save:
        return out, (qr, kr, vh, p)
    return out


def attention_bwd(saved, gy):
    qr, kr, vh, p = saved
    cos, sin = rope_tables()
    scale = 1.0 / np.sqrt(cfg.d_head)
    go = split_heads(gy)
    gp = np.einsum("bhqd,bhkd->bhqk", go, vh)
    gv = np.einsum("bhqk,bhqd->bhkd", p, go)
    ga = p * (gp - np.sum(gp * p, axis=-1, keepdims=True))
    gq = merge_heads(rope_bwd(np.einsum("bhqk,bhkd->bhqd", ga, kr) * scale, cos, sin))
    gk = merge_heads(rope_bwd(np.einsum("bhqk,bhqd->bhkd", ga, qr) * scale, cos, sin))
    return gq, gk, merge_heads(gv)


def silu(x):
    return x / (1.0 + np.exp(-x))


def silu_grad(x):
    s = 1.0 / (1.0 + np.exp(-x))
    return s * (1.0 + x * (1.0 - s))


def block_fwd(x, eff, norms, save=False):
    g1, g2 = norms
    h1 = rmsnorm(x, g1)
    q, k, v = h1 @ eff["wq"].T, h1 @ eff["wk"].T, h1 @ eff["wv"].T
    attout, att_saved = attention_fwd(q, k, v, save=True)
    o = attout @ eff["wo"].T
    x2 = x + o
    h2 = rmsnorm(x2, g2)
    gate, up = h2 @ eff["wg"].T, h2 @ eff["wu"].T
    act = silu(gate) * up
    y = x2 + act @ eff["wd"].T
    saved = dict(x=x, h1=h1, attout=attout, x2=x2, h2=h2, gate=gate, up=up,
                 act=act, att=att_saved, eff=eff, norms=norms)
    return (y, saved) if save else (y, None)


def block_bwd(sv, gy):
    eff, (g1, g2) = sv["eff"], sv["norms"]
    gw = {}
    gw["wd"] = np.einsum("bsn,bsk->nk", gy, sv["act"])
    g_act = gy @ eff["wd"]
    g_gate = g_act * sv["up"] * silu_grad(sv["gate"])
    g_up = g_act * silu(sv["gate"])
    gw["wg"] = np.einsum("bsn,bsk->nk", g_gate, sv["h2"])
    gw["wu"] = np.einsum("bsn,bsk->nk", g_up, sv["h2"])
    g_h2 = g_gate @ eff["wg"] + g_up @ eff["wu"]
    gx2_rms, gnorm2 = rmsnorm_bwd(sv["x2"], g2, g_h2)
    g_x2 = gy + gx2_rms
    gw["wo"] = np.einsum("bsn,bsk->nk", g_x2, sv["attout"])
    g_attout = g_x2 @ eff["wo"]
    gq, gk, gv = attention_bwd(sv["att"], g_attout)
    gw["wq"] = np.einsum("bsn,bsk->nk", gq, sv["h1"])
    gw["wk"] = np.einsum("bsn,bsk->nk", gk, sv["h1"])
    gw["wv"] = np.einsum("bsn,bsk->nk", gv, sv["h1"])
    g_h1 = gq @ eff["wq"] + gk @ eff["wk"] + gv @ eff["wv"]
    gx1_rms, gnorm1 = rmsnorm_bwd(sv["x"], g1, g_h1)
    return g_x2 + gx1_rms, gw, gnorm1, gnorm2


def theta_chain(theta, rows):
    D = cfg.n_rates
    e = np.exp(theta - theta.max(axis=-1, keepdims=True))
    b = e / e.sum(axis=-1, keepdims=True)
    beta = np.concatenate([b, np.zeros((b.shape[0], 1))], axis=-1)
    beta = np.broadcast_to(beta, (rows, D))
    cumb = np.concatenate([np.zeros((rows, 1)), np.cumsum(beta, axis=-1)[:, :-1]], axis=-1)
    alpha = np.sum(beta * (np.arange(1, D + 1) / D)[None, :], axis=-1)
    return beta, cumb, alpha


def theta_chain_bwd(theta, rows, gcumb, galpha):
    D = cfg.n_rates
    e = np.exp(theta - theta.max(axis=-1, keepdims=True))
    b = e / e.sum(axis=-1, keepdims=True)
    gbeta = np.zeros((rows, D))
    suf = np.cumsum(gcumb[:, ::-1], axis=-1)[:, ::-1]
    gbeta[:, :-1] = suf[:, 1:]
    gbeta += galpha[:, None] * (np.arange(1, D + 1) / D)[None, :]
    if theta.shape[0] == 1:
        gbeta = gbeta.sum(axis=0, keepdims=True)
    gb = gbeta[:, : D - 1]
    return b * (gb - np.sum(gb * b, axis=-1, keepdims=True))


def bucket(rank, C):
    return np.minimum((rank * cfg.n_rates) // C, cfg.n_rates - 1)


def hard_mask(cumb, alpha, rank):
    k = bucket(rank, rank.shape[1])
    keep = np.take_along_axis(cumb, k, axis=1)
    return ((1.0 - keep) < alpha[:, None]).astype(float)


def mask_bwd_to_cumb(rank, g):
    D = cfg.n_rates
    k = bucket(rank, rank.shape[1])
    out = np.zeros((rank.shape[0], D))
    for d in range(D):
        out[:, d] = np.sum(g * (k == d), axis=1)
    return out


def fake_quant(w, g0, g1, bits=4):
    qmax = 2.0 ** bits - 1.0
    wmin = g0 * w.min()
    wmax = g1 * w.max()
    h = max((wmax - wmin) / qmax, 1e-8)
    z = np.round(-wmin / h)
    return (np.clip(np.round(w / h) + z, 0.0, qmax) - z) * h


def fake_quant_gamma_bwd(w, g0, g1, gout, bits=4):
    qmax = 2.0 ** bits - 1.0
    mw, Mw = w.min(), w.max()
    a0, a1 = g0 * mw, g1 * Mw
    raw_h = (a1 - a0) / qmax
    floored = raw_h <= 1e-8
    h = max(raw_h, 1e-8)
    z = -a0 / h
    dh = [0.0, 0.0] if floored else [-1.0 / qmax, 1.0 / qmax]
    dz = [-1.0 / h + a0 / (h * h) * dh[0], a0 / (h * h) * dh[1]]
    u = w / h + z
    inside = (u >= 0.0) & (u <= qmax)
    c = np.clip(u, 0.0, qmax)
    out = []
    for i in range(2):
        du = -w / (h * h) * dh[i] + dz[i]
        dout = (inside * du - dz[i]) * h + (c - z) * dh[i]
        out.append(float(np.sum(gout * dout)))
    return out[0] * mw, out[1] * Mw


def besa_step(thetas, x, y_dense, weights, norms, ranks, lam, ah,
              grouping="block", gammas=None):
    chains, masks = {}, {}
    qw = {}
    for n in LAYER_NAMES:
        r = layer_shape(n)[0]
        beta, cumb, alpha = theta_chain(thetas[n], r)
        chains[n] = (beta, cumb, alpha)
        masks[n] = hard_mask(cumb, alpha, ranks[n])
        w = weights[n]
        if gammas is not None:
            w = fake_quant(w, gammas[n][0], gammas[n][1])
        qw[n] = w
    eff = {n: qw[n] * masks[n] for n in LAYER_NAMES}
    y, sv = block_fwd(x, eff, norms, save=True)
    denom = max(np.sum(y_dense ** 2), 1e-9)
    recon = np.sum((y - y_dense) ** 2) / denom
    groups = {"block": [LAYER_NAMES],
              "attn_mlp": [["wq", "wk", "wv", "wo"], ["wg", "wu", "wd"]]}[grouping]

    def group_term(g):
        num = sum(chains[n][2].sum() * layer_shape(n)[1] for n in g)
        den = sum(layer_shape(n)[0] * layer_shape(n)[1] for n in g)
        return num / den - ah, den

    sparse = sum(gt ** 2 for gt, _ in map(group_term, groups))
    ma_num = sum(chains[n][2].sum() * layer_shape(n)[1] for n in LAYER_NAMES)
    ma_den = sum(layer_shape(n)[0] * layer_shape(n)[1] for n in LAYER_NAMES)
    mean_alpha = ma_num / ma_den
    loss = recon + lam * sparse

    gy = 2.0 * (y - y_dense) / denom
    _, gw_eff, _, _ = block_bwd(sv, gy)
    coef = {}
    for g in groups:
        dev, den = group_term(g)
        for n in g:
            coef[n] = 2.0 * lam * dev * layer_shape(n)[1] / den
    dthetas, dgammas = {}, {}
    for n in LAYER_NAMES:
        r = layer_shape(n)[0]
        gM = gw_eff[n] * qw[n]
        gcumb = mask_bwd_to_cumb(ranks[n], gM)
        galpha = np.full(r, coef[n])
        dthetas[n] = theta_chain_bwd(thetas[n], r, gcumb, galpha)
        if gammas is not None:
            gqw = gw_eff[n] * masks[n]
            dgammas[n] = fake_quant_gamma_bwd(weights[n], gammas[n][0], gammas[n][1], gqw)
    return loss, recon, mean_alpha, dthetas, dgammas


def head_and_loss(params, tokens, x):
    emb, norm_f = params["embed"], params["norm_f"]
    h = rmsnorm(x, norm_f)
    logits = np.einsum("bsd,vd->bsv", h, emb)
    m = logits.max(axis=-1, keepdims=True)
    logp = logits - (m + np.log(np.sum(np.exp(logits - m), axis=-1, keepdims=True)))
    tgt = np.roll(tokens, -1, axis=1)
    nll = -np.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    nll[:, -1] = 0.0
    return nll, logp, h, tgt


def lm_train_step(params, tokens):
    emb = params["embed"]
    x = emb[tokens]
    saves = []
    for l in range(cfg.n_blocks):
        eff = {n: params[f"blocks.{l}.{n}"] for n in LAYER_NAMES}
        norms = (params[f"blocks.{l}.norm1"], params[f"blocks.{l}.norm2"])
        x, sv = block_fwd(x, eff, norms, save=True)
        saves.append(sv)
    nll, logp, h, tgt = head_and_loss(params, tokens, x)
    count = int(np.sum(nll != 0.0))
    loss = nll.sum() / count
    grads = {}
    gnll = (nll != 0.0).astype(float) / count
    sm = np.exp(logp)
    onehot = np.zeros_like(sm)
    np.put_along_axis(onehot, tgt[..., None], 1.0, axis=-1)
    glogits = gnll[..., None] * (sm - onehot)
    gh = np.einsum("bsv,vd->bsd", glogits, emb)
    gemb = np.einsum("bsv,bsd->vd", glogits, h)
    gx, grads["norm_f"] = rmsnorm_bwd(x, params["norm_f"], gh)
    for l in reversed(range(cfg.n_blocks)):
        gx, gw, gn1, gn2 = block_bwd(saves[l], gx)
        for n in LAYER_NAMES:
            grads[f"blocks.{l}.{n}"] = gw[n]
        grads[f"blocks.{l}.norm1"] = gn1
        grads[f"blocks.{l}.norm2"] = gn2
    np.add.at(gemb, tokens.reshape(-1), gx.reshape(-1, cfg.d_model))
    grads["embed"] = gemb
    return loss, grads


# --------------------------- input generation (mirrors rust test) -----------
def gen_x(seed, scale=0.5):
    rng = Rng(seed)
    n = cfg.batch * cfg.seq_len * cfg.d_model
    return np.array([rng.normal_f32() * np.float32(scale) for _ in range(n)],
                    dtype=np.float64).reshape(cfg.batch, cfg.seq_len, cfg.d_model)


def gen_tokens(seed):
    rng = Rng(seed)
    n = cfg.batch * cfg.seq_len
    return np.array([rng.below(256) for _ in range(n)]).reshape(cfg.batch, cfg.seq_len)


def gen_thetas(seed):
    rng = Rng(seed)
    out = {}
    for n in LAYER_NAMES:
        r = layer_shape(n)[0]
        vals = [rng.normal_f32() * np.float32(0.5) for _ in range(r * (cfg.n_rates - 1))]
        out[n] = np.array(vals, dtype=np.float64).reshape(r, cfg.n_rates - 1)
    return out


def gen_ranks(seed):
    rng = Rng(seed)
    out = {}
    for n in LAYER_NAMES:
        r, c = layer_shape(n)
        rows = [rng.permutation(c) for _ in range(r)]
        out[n] = np.array(rows, dtype=np.int64)
    return out


def stats(a):
    a = np.asarray(a, dtype=np.float64).reshape(-1)
    return {"sum": float(a.sum()), "abs_sum": float(np.abs(a).sum()),
            "first": [float(v) for v in a[:6]]}


def main():
    params = param_store_init(123)
    tokens = gen_tokens(7)
    x = gen_x(11)
    thetas = gen_thetas(13)
    ranks = gen_ranks(17)
    b0 = {n: params[f"blocks.0.{n}"] for n in LAYER_NAMES}
    norms0 = (params["blocks.0.norm1"], params["blocks.0.norm2"])

    golden = {"config": "test", "seed_doc":
              "params=ParamStore::init(123); tokens=Rng(7).below(256); "
              "x=Rng(11).normal_f32*0.5; thetas=Rng(13).normal_f32*0.5; "
              "ranks=Rng(17).permutation rows"}

    # block_fwd / capture
    y, sv = block_fwd(x, b0, norms0, save=True)
    golden["block_fwd_y"] = stats(y)
    golden["capture_h1"] = stats(sv["h1"])
    golden["capture_att"] = stats(sv["attout"])
    golden["capture_h2"] = stats(sv["h2"])
    golden["capture_act"] = stats(sv["act"])

    # embed + head_nll
    xemb = params["embed"][tokens]
    golden["embed_x"] = stats(xemb)
    nll, _, _, _ = head_and_loss(params, tokens, x)
    golden["head_nll"] = stats(nll)

    # mask_decode on the 32x32 shape (wq)
    _, cumb, alpha = theta_chain(thetas["wq"], 32)
    md_mask = hard_mask(cumb, alpha, ranks["wq"])
    golden["mask_decode_mask_sum"] = float(md_mask.sum())
    golden["mask_decode_alpha"] = stats(alpha)

    # quant_apply on wq with gamma (0.9, 0.85)
    golden["quant_apply_wq"] = stats(fake_quant(b0["wq"], 0.9, 0.85))

    # besa_step_row (lam=2, ah=0.6) against y_dense = dense block output
    loss, recon, ma, dth, _ = besa_step(thetas, x, y, b0, norms0, ranks, 2.0, 0.6)
    golden["besa_step_row"] = {
        "loss": loss, "recon": recon, "mean_alpha": ma,
        "dtheta": {n: stats(dth[n]) for n in LAYER_NAMES},
    }

    # besa_step_attnmlp
    loss_a, recon_a, ma_a, dth_a, _ = besa_step(
        thetas, x, y, b0, norms0, ranks, 2.0, 0.6, grouping="attn_mlp")
    golden["besa_step_attnmlp"] = {
        "loss": loss_a, "recon": recon_a, "mean_alpha": ma_a,
        "dtheta_wq": stats(dth_a["wq"]), "dtheta_wd": stats(dth_a["wd"]),
    }

    # besa_step_layer: theta rows = 1 (first row of each row-wise theta)
    thetas1 = {n: thetas[n][:1].copy() for n in LAYER_NAMES}
    loss_l, recon_l, ma_l, dth_l, _ = besa_step(
        thetas1, x, y, b0, norms0, ranks, 2.0, 0.6)
    golden["besa_step_layer"] = {
        "loss": loss_l, "recon": recon_l, "mean_alpha": ma_l,
        "dtheta_wq": stats(dth_l["wq"]), "dtheta_wd": stats(dth_l["wd"]),
    }

    # besa_quant_step_row with gammas 0.95/0.9 everywhere
    gammas = {n: (0.95, 0.9) for n in LAYER_NAMES}
    loss_q, recon_q, ma_q, dth_q, dgm = besa_step(
        thetas, x, y, b0, norms0, ranks, 2.0, 0.6, gammas=gammas)
    golden["besa_quant_step_row"] = {
        "loss": loss_q, "recon": recon_q, "mean_alpha": ma_q,
        "dtheta_wq": stats(dth_q["wq"]),
        "dgamma": {n: [dgm[n][0], dgm[n][1]] for n in LAYER_NAMES},
    }

    # lm_train_step
    loss_t, grads = lm_train_step(params, tokens)
    golden["lm_train_step"] = {
        "loss": loss_t,
        "d_embed": stats(grads["embed"]),
        "d_blocks.0.wq": stats(grads["blocks.0.wq"]),
        "d_blocks.1.wd": stats(grads["blocks.1.wd"]),
        "d_blocks.0.norm1": stats(grads["blocks.0.norm1"]),
        "d_norm_f": stats(grads["norm_f"]),
    }

    out_path = os.path.join(os.path.dirname(__file__), "..", "..",
                            "rust", "tests", "golden", "native_test_vectors.json")
    out_path = os.path.normpath(out_path)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(golden, f, indent=1)
    print(f"wrote {out_path}")
    print(f"  lm loss {loss_t:.6f} (ln V = {math.log(cfg.vocab):.6f})")
    print(f"  besa_step_row loss {loss:.6f} recon {recon:.6f} mean_alpha {ma:.6f}")


if __name__ == "__main__":
    main()
