"""wanda_importance kernel + rank computation properties."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, wanda


@settings(max_examples=20, deadline=None)
@given(
    r=st.sampled_from([4, 32, 88]),
    c=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_wanda_matches_ref(r, c, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    n = jnp.asarray(np.abs(rng.normal(size=(c,))), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(wanda.wanda_importance(w, n)),
        np.asarray(ref.wanda_importance_ref(w, n)),
        rtol=1e-6,
    )


def test_ranks_are_permutations():
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.normal(size=(8, 33)), jnp.float32)
    rk = np.asarray(wanda.ranks_from_scores(s))
    for row in rk:
        assert sorted(row.tolist()) == list(range(33))


def test_ranks_order_matches_scores():
    rng = np.random.default_rng(1)
    s = np.abs(rng.normal(size=(4, 16))).astype(np.float32)
    rk = np.asarray(wanda.ranks_from_scores(jnp.asarray(s)))
    for i in range(4):
        order = np.argsort(s[i])
        # element with the smallest score gets rank 0
        assert rk[i][order[0]] == 0
        assert rk[i][order[-1]] == 15
