"""L1 (masked) matmul kernels vs jnp oracle, forward and VJP."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import masked_matmul as mm
from compile.kernels import ref


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([1, 4, 8, 32, 100]),
    k=st.sampled_from([8, 32, 88]),
    n=st.sampled_from([4, 32, 88]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(mm.matmul_t(x, w)), np.asarray(ref.matmul_ref(x, w)), rtol=2e-5, atol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([2, 8, 32]),
    k=st.sampled_from([8, 32, 88]),
    n=st.sampled_from([4, 32]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_matmul_matches_ref(m, k, n, density, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    msk = jnp.asarray((rng.random((n, k)) < density).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(mm.masked_matmul(x, w, msk)),
        np.asarray(ref.masked_matmul_ref(x, w, msk)),
        rtol=2e-5,
        atol=1e-5,
    )


def test_masked_matmul_vjp_exact():
    rng = np.random.default_rng(3)
    m, k, n = 8, 16, 12
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    msk = jnp.asarray((rng.random((n, k)) < 0.5).astype(np.float32))

    def f_kernel(x, w, msk):
        return jnp.sum(jnp.sin(mm.masked_matmul(x, w, msk)))

    def f_ref(x, w, msk):
        return jnp.sum(jnp.sin(ref.masked_matmul_ref(x, w, msk)))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, msk)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, msk)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-5)


def test_dense_matmul_vjp_exact():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(12, 16)), jnp.float32)
    gk = jax.grad(lambda x, w: jnp.sum(jnp.tanh(mm.dense_matmul(x, w))), (0, 1))(x, w)
    gr = jax.grad(lambda x, w: jnp.sum(jnp.tanh(ref.matmul_ref(x, w))), (0, 1))(x, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-5)


def test_linear_3d_shapes():
    rng = np.random.default_rng(5)
    x3 = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, 16)), jnp.float32)
    y = mm.linear(x3, w)
    assert y.shape == (2, 8, 24)
    np.testing.assert_allclose(
        np.asarray(y).reshape(16, 24),
        np.asarray(ref.matmul_ref(x3.reshape(16, 16), w)),
        rtol=2e-5,
        atol=1e-5,
    )
