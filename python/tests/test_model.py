"""L2 model graph shape/semantic tests on the `test` config."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import CONFIGS, LAYER_NAMES

CFG = CONFIGS["test"]


def make_params(rng, cfg=CFG, scale=0.05):
    flat = []
    for name in model.param_order(cfg):
        if name == "embed":
            s = (cfg.vocab, cfg.d_model)
        elif name.endswith(("norm1", "norm2")) or name == "norm_f":
            s = (cfg.d_model,)
        else:
            s = cfg.layer_shapes()[name.split(".")[-1]]
        if len(s) == 1:
            flat.append(jnp.ones(s, jnp.float32))
        else:
            flat.append(jnp.asarray(rng.normal(size=s) * scale, jnp.float32))
    return flat


def make_block(rng, cfg=CFG, scale=0.05):
    w = {
        n: jnp.asarray(rng.normal(size=s) * scale, jnp.float32)
        for n, s in cfg.layer_shapes().items()
    }
    norms = (jnp.ones(cfg.d_model), jnp.ones(cfg.d_model))
    return w, norms


def test_block_forward_shape(rng):
    w, norms = make_block(rng)
    x = jnp.asarray(rng.normal(size=(CFG.batch, CFG.seq_len, CFG.d_model)), jnp.float32)
    y = model.block_forward(x, w, norms, CFG)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))


def test_block_masked_all_ones_equals_dense(rng):
    w, norms = make_block(rng)
    x = jnp.asarray(rng.normal(size=(CFG.batch, CFG.seq_len, CFG.d_model)), jnp.float32)
    ones = {n: jnp.ones(s, jnp.float32) for n, s in CFG.layer_shapes().items()}
    yd = model.block_forward(x, w, norms, CFG)
    ym = model.block_forward(x, w, norms, CFG, masks=ones)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(ym), rtol=1e-5, atol=1e-6)


def test_block_masked_zero_mask_is_residual_only(rng):
    w, norms = make_block(rng)
    x = jnp.asarray(rng.normal(size=(CFG.batch, CFG.seq_len, CFG.d_model)), jnp.float32)
    zeros = {n: jnp.zeros(s, jnp.float32) for n, s in CFG.layer_shapes().items()}
    y = model.block_forward(x, w, norms, CFG, masks=zeros)
    # all projections zeroed -> block reduces to the residual stream
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-5, atol=1e-6)


def test_capture_matches_forward(rng):
    w, norms = make_block(rng)
    x = jnp.asarray(rng.normal(size=(CFG.batch, CFG.seq_len, CFG.d_model)), jnp.float32)
    y = model.block_forward(x, w, norms, CFG)
    y2, caps = model.block_forward(x, w, norms, CFG, capture=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-6)
    h1, att, h2, act = caps
    assert h1.shape == x.shape and att.shape == x.shape and h2.shape == x.shape
    assert act.shape == (CFG.batch, CFG.seq_len, CFG.d_ffn)


def test_causality(rng):
    """Changing a future token must not affect past NLL positions."""
    flat = make_params(rng)
    toks = np.asarray(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)), np.int32)
    toks2 = toks.copy()
    toks2[:, -1] = (toks2[:, -1] + 7) % CFG.vocab

    def nll_of(t):
        emb, blocks, norm_f = model.unflatten_params(CFG, flat)
        x = model.embed(jnp.asarray(t), emb)
        for w, norms in blocks:
            x = model.block_forward(x, w, norms, CFG)
        return np.asarray(model.head_nll(x, norm_f, emb, jnp.asarray(t), CFG))

    a, b = nll_of(toks), nll_of(toks2)
    # positions strictly before S-2 predict unchanged targets from unchanged
    # context -> identical NLL
    np.testing.assert_allclose(a[:, : CFG.seq_len - 2], b[:, : CFG.seq_len - 2], atol=1e-5)


def test_lm_loss_near_uniform_at_init(rng):
    """Random small weights -> loss ~ log(vocab)."""
    flat = make_params(rng, scale=0.01)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)), jnp.int32)
    loss = float(model.lm_loss(flat, toks, CFG))
    assert abs(loss - np.log(CFG.vocab)) < 0.5, loss


def test_train_step_grads_finite_and_complete(rng):
    flat = make_params(rng)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)), jnp.int32)
    out = model.lm_train_step(flat, toks, CFG)
    loss, grads = out[0], out[1:]
    assert len(grads) == len(flat)
    assert np.isfinite(float(loss))
    nonzero = sum(float(jnp.linalg.norm(g)) > 0 for g in grads)
    assert nonzero == len(grads), f"only {nonzero}/{len(grads)} grads nonzero"


def test_gradient_descends(rng):
    flat = make_params(rng)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)), jnp.int32)
    out = model.lm_train_step(flat, toks, CFG)
    loss0, grads = float(out[0]), out[1:]
    stepped = [p - 0.5 * g for p, g in zip(flat, grads)]
    loss1 = float(model.lm_loss(stepped, toks, CFG))
    assert loss1 < loss0
