"""fake_quant kernel vs oracle + STE gradient sanity."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fake_quant as fq
from compile.kernels import ref


@settings(max_examples=20, deadline=None)
@given(
    r=st.sampled_from([4, 32, 88]),
    c=st.sampled_from([8, 32, 128]),
    bits=st.sampled_from([2, 3, 4, 8]),
    g0=st.floats(0.5, 1.0),
    g1=st.floats(0.5, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_fake_quant_matches_ref(r, c, bits, g0, g1, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    got = fq.fake_quant(w, jnp.float32(g0), jnp.float32(g1), bits)
    want = ref.fake_quant_ref(w, g0, g1, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_quant_levels_bounded():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    for bits in (2, 4):
        q = np.asarray(fq.fake_quant(w, jnp.float32(1.0), jnp.float32(1.0), bits))
        assert len(np.unique(q)) <= 2**bits


def test_ste_gradients_nonzero():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)

    def loss(g0, g1, w):
        return jnp.sum(fq.fake_quant(w, g0, g1, 4) ** 2)

    g = jax.grad(loss, argnums=(0, 1, 2))(jnp.float32(0.9), jnp.float32(0.9), w)
    assert float(jnp.abs(g[0])) > 0
    assert float(jnp.abs(g[1])) > 0
    assert float(jnp.linalg.norm(g[2])) > 0


def test_identity_when_bits_large():
    """16-bit quantization of a small-range tensor is near-lossless."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    q = fq.fake_quant(w, jnp.float32(1.0), jnp.float32(1.0), 16)
    np.testing.assert_allclose(np.asarray(q), np.asarray(w), rtol=1e-3, atol=1e-3)
