"""End-to-end BESA step graph: optimizing theta actually allocates sparsity.

This is the python-side replica of what the rust coordinator does with the
AOT artifact — a miniature Algorithm 1 inner loop on the `test` config.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import besa, model
from compile.configs import CONFIGS, LAYER_NAMES
from compile.kernels import wanda

CFG = CONFIGS["test"]


@pytest.fixture
def block(rng):
    w = {
        n: jnp.asarray(rng.normal(size=s) * 0.08, jnp.float32)
        for n, s in CFG.layer_shapes().items()
    }
    norms = (jnp.ones(CFG.d_model), jnp.ones(CFG.d_model))
    x = jnp.asarray(rng.normal(size=(CFG.batch, CFG.seq_len, CFG.d_model)), jnp.float32)
    y = model.block_forward(x, w, norms, CFG)
    ranks = {
        n: wanda.ranks_from_scores(jnp.abs(w[n]))  # unit colnorm importance
        for n in LAYER_NAMES
    }
    return w, norms, x, y, ranks


def zero_thetas(rowwise=True):
    return {
        n: jnp.zeros((s[0] if rowwise else 1, CFG.n_rates - 1), jnp.float32)
        for n, s in CFG.layer_shapes().items()
    }


def test_step_outputs_shapes(block):
    w, norms, x, y, ranks = block
    th = zero_thetas()
    out = besa.besa_step(th, x, y, w, norms, ranks, jnp.float32(5.0), jnp.float32(0.5), CFG)
    loss, recon, ma = out[:3]
    dth = out[3:]
    assert len(dth) == 7
    for n, g in zip(LAYER_NAMES, dth):
        assert g.shape == th[n].shape
    assert np.isfinite(float(loss)) and np.isfinite(float(recon))
    assert 0.0 <= float(ma) <= 1.0


def test_sparsity_penalty_pulls_alpha_to_target(block):
    """A few Adam-free SGD steps must move mean sparsity toward alpha_hat."""
    w, norms, x, y, ranks = block
    th = zero_thetas()
    lam, ah = jnp.float32(20.0), jnp.float32(0.7)
    ma0 = None
    lr = 50.0  # gradients through softmax+STE are tiny; Adam handles this in rust
    for it in range(30):
        out = besa.besa_step(th, x, y, w, norms, ranks, lam, ah, CFG)
        ma = float(out[2])
        if ma0 is None:
            ma0 = ma
        for n, g in zip(LAYER_NAMES, out[3:]):
            th[n] = th[n] - lr * g
    assert abs(ma - 0.7) < abs(ma0 - 0.7), (ma0, ma)


def test_layerwise_theta_broadcasts(block):
    w, norms, x, y, ranks = block
    th = zero_thetas(rowwise=False)
    out = besa.besa_step(th, x, y, w, norms, ranks, jnp.float32(5.0), jnp.float32(0.5), CFG)
    assert out[3].shape == (1, CFG.n_rates - 1)


def test_quant_step_returns_gamma_grads(block):
    w, norms, x, y, ranks = block
    th = zero_thetas()
    gm = {n: jnp.asarray([1.0, 1.0], jnp.float32) for n in LAYER_NAMES}
    out = besa.besa_step(
        th, x, y, w, norms, ranks, jnp.float32(5.0), jnp.float32(0.5), CFG, gammas=gm
    )
    assert len(out) == 3 + 14
    dgm = out[10:]
    assert all(g.shape == (2,) for g in dgm)


def test_attn_mlp_granularity_runs(block):
    w, norms, x, y, ranks = block
    th = zero_thetas()
    out = besa.besa_step(
        th, x, y, w, norms, ranks, jnp.float32(5.0), jnp.float32(0.5), CFG, "attn_mlp"
    )
    assert np.isfinite(float(out[0]))


def test_two_block_step_runs(rng, block):
    w, norms, x, y, ranks = block
    w2 = {
        n: jnp.asarray(rng.normal(size=s) * 0.08, jnp.float32)
        for n, s in CFG.layer_shapes().items()
    }
    y2 = model.block_forward(y, w2, norms, CFG)
    ranks2 = {n: wanda.ranks_from_scores(jnp.abs(w2[n])) for n in LAYER_NAMES}
    th = [zero_thetas(), zero_thetas()]
    out = besa.two_block_step(
        th, x, y2, [w, w2], [norms, norms], [ranks, ranks2],
        jnp.float32(5.0), jnp.float32(0.5), CFG,
    )
    assert len(out) == 3 + 14
    assert np.isfinite(float(out[0]))


def test_recon_zero_at_zero_sparsity(block):
    """Theta concentrated on the lowest rate -> alpha ~ 1/D, near-dense mask,
    reconstruction error ~ 0."""
    w, norms, x, y, ranks = block
    th = {}
    for n, s in CFG.layer_shapes().items():
        t = np.full((s[0], CFG.n_rates - 1), -30.0, np.float32)
        t[:, 0] = 30.0
        th[n] = jnp.asarray(t)
    out = besa.besa_step(th, x, y, w, norms, ranks, jnp.float32(5.0), jnp.float32(0.0), CFG)
    assert float(out[1]) < 0.05, float(out[1])
    assert abs(float(out[2]) - 1.0 / CFG.n_rates) < 1e-5
