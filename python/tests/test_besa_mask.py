"""L1 besa_mask kernel vs pure-jnp oracle, hypothesis shape/value sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.configs import CONFIGS
from compile import besa
from compile.kernels import besa_mask, ref


def random_ranks(rng, r, c):
    return np.stack([rng.permutation(c) for _ in range(r)]).astype(np.int32)


def random_theta(rng, r, d):
    return jnp.asarray(rng.normal(size=(r, d - 1)), jnp.float32)


def excl_cumsum(beta):
    """Keep-probability per bucket: c[k] = sum_{d<=k} beta_d (see besa.theta_to_mask)."""
    return jnp.concatenate(
        [jnp.zeros_like(beta[..., :1]), jnp.cumsum(beta, axis=-1)[..., :-1]], axis=-1
    )


def mask_inputs(rng, r, c, d):
    rank = jnp.asarray(random_ranks(rng, r, c))
    theta = random_theta(rng, r, d)
    beta = besa.beta_from_theta(theta)
    cumb = excl_cumsum(beta)
    p = jnp.arange(1, d + 1, dtype=jnp.float32) / d
    alpha = jnp.sum(beta * p[None], axis=-1)
    return rank, cumb, alpha


@settings(max_examples=25, deadline=None)
@given(
    r=st.sampled_from([1, 2, 4, 8, 16, 24]),
    c=st.sampled_from([8, 16, 32, 88, 100]),
    d=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mask_kernel_matches_ref(r, c, d, seed):
    rng = np.random.default_rng(seed)
    rank, cumb, alpha = mask_inputs(rng, r, c, d)
    m_k, keep_k = besa_mask.besa_mask_kernel(rank, cumb, alpha)
    m_r, keep_r = ref.besa_mask_ref(rank, cumb, alpha)
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))
    np.testing.assert_allclose(np.asarray(keep_k), np.asarray(keep_r), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    r=st.sampled_from([2, 4, 8]),
    c=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mask_bwd_kernel_matches_ref(r, c, d, seed):
    rng = np.random.default_rng(seed)
    rank = jnp.asarray(random_ranks(rng, r, c))
    g = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)
    gk = besa_mask.besa_mask_grad_kernel(rank, g, d)
    gr = ref.besa_mask_bwd_ref(rank, g, d)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-5, atol=1e-6)


def test_mask_monotone_in_importance(rng):
    """Pruning probability must be non-increasing in rank: the kept set is
    always the top-importance suffix (paper: most important always retained)."""
    for seed in range(5):
        r = np.random.default_rng(seed)
        rank, cumb, alpha = mask_inputs(r, 8, 64, 16)
        m, _ = besa_mask.besa_mask_kernel(rank, cumb, alpha)
        m = np.asarray(m)
        rk = np.asarray(rank)
        for i in range(m.shape[0]):
            by_rank = m[i][np.argsort(rk[i])]
            # once kept (1), stays kept for all higher ranks
            assert np.all(np.diff(by_rank) >= 0), by_rank


def test_most_important_never_pruned(rng):
    rank, cumb, alpha = mask_inputs(rng, 16, 64, 16)
    m, _ = besa_mask.besa_mask_kernel(rank, cumb, alpha)
    m = np.asarray(m)
    rk = np.asarray(rank)
    top = np.take_along_axis(m, np.argmax(rk, axis=1)[:, None], axis=1)
    assert np.all(top == 1.0)


def test_concentrated_beta_gives_exact_rate():
    """If beta is a point mass at rate p_d, exactly d/D of each row is pruned."""
    c, d = 64, 16
    rng = np.random.default_rng(0)
    rank = jnp.asarray(random_ranks(rng, 4, c))
    for dstar in [1, 4, 8, 12]:
        theta = np.full((4, d - 1), -30.0, np.float32)
        theta[:, dstar - 1] = 30.0
        beta = besa.beta_from_theta(jnp.asarray(theta))
        cumb = excl_cumsum(beta)
        p = jnp.arange(1, d + 1, dtype=jnp.float32) / d
        alpha = jnp.sum(beta * p[None], -1)
        m, _ = besa_mask.besa_mask_kernel(rank, cumb, alpha)
        sparsity = 1.0 - np.asarray(m).mean(axis=1)
        np.testing.assert_allclose(sparsity, dstar / d, atol=1e-6)


def test_ste_gradient_matches_bucket_map():
    """dL/dtheta via the STE must equal the analytic bucket-binned gradient."""
    rng = np.random.default_rng(7)
    r, c, d = 4, 32, 8
    rank = jnp.asarray(random_ranks(rng, r, c))
    theta = random_theta(rng, r, d)
    gout = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)

    def loss(th):
        beta = besa.beta_from_theta(th)
        cumb = excl_cumsum(beta)
        p = jnp.arange(1, d + 1, dtype=jnp.float32) / d
        alpha = jnp.sum(beta * p[None], -1)
        m = besa_mask.besa_mask_ste(rank, cumb, alpha)
        return jnp.sum(m * gout)

    g_kernel = jax.grad(loss)(theta)

    def loss_ref(th):
        beta = besa.beta_from_theta(th)
        cumb = excl_cumsum(beta)
        k = ref.bucket_of_rank(rank, c, d)
        keep = jnp.take_along_axis(cumb, k, axis=1)  # differentiable surrogate
        return jnp.sum(keep * gout)

    g_ref = jax.grad(loss_ref)(theta)
    np.testing.assert_allclose(np.asarray(g_kernel), np.asarray(g_ref), rtol=1e-5, atol=1e-7)
