"""The emitted artifacts + manifest honor the rust-side contract."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

REQUIRED = [
    "embed",
    "head_nll",
    "block_fwd",
    "block_fwd_masked",
    "block_capture",
    "besa_step_row",
    "besa_step_layer",
    "besa_step_attnmlp",
    "besa_quant_step_row",
    "two_block_step",
    "lm_train_step",
]


def manifest(cfg):
    path = os.path.join(ART, cfg, "manifest.json")
    if not os.path.exists(path):
        pytest.skip(f"artifacts for '{cfg}' not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("cfg", ["test", "sm", "md"])
def test_required_artifacts_present(cfg):
    m = manifest(cfg)
    for name in REQUIRED:
        assert name in m["artifacts"], name
        f = os.path.join(ART, cfg, m["artifacts"][name]["file"])
        assert os.path.exists(f), f
        assert os.path.getsize(f) > 100


@pytest.mark.parametrize("cfg", ["test"])
def test_hlo_text_parses_as_hlo_module(cfg):
    m = manifest(cfg)
    f = os.path.join(ART, cfg, m["artifacts"]["block_fwd"]["file"])
    head = open(f).read(200)
    assert head.startswith("HloModule"), head[:50]


def test_besa_step_interface_counts():
    m = manifest("test")
    a = m["artifacts"]["besa_step_row"]
    # 7 theta + x + y + 7 w + 2 norms + 7 ranks + lam + alpha_hat = 27
    assert len(a["inputs"]) == 27
    assert len(a["outputs"]) == 10
    q = m["artifacts"]["besa_quant_step_row"]
    assert len(q["inputs"]) == 34
    assert len(q["outputs"]) == 17


def test_param_order_matches_train_step():
    m = manifest("test")
    porder = m["config"]["param_order"]
    tr = m["artifacts"]["lm_train_step"]
    assert [i["name"] for i in tr["inputs"][:-1]] == porder
    assert tr["inputs"][-1]["name"] == "tokens"
    assert [o["name"] for o in tr["outputs"]] == ["loss"] + ["d_" + n for n in porder]


def test_theta_shapes_rowwise_vs_layerwise():
    m = manifest("test")
    row = m["artifacts"]["besa_step_row"]["inputs"][0]
    lay = m["artifacts"]["besa_step_layer"]["inputs"][0]
    d = m["config"]["n_rates"]
    assert row["shape"][1] == d - 1
    assert lay["shape"][0] == 1
