"""L2: the BESA training-step graphs (paper Eqn. 1-6, Algorithm 1 inner loop).

One `besa_step` executes: theta -> beta -> cumbeta/alpha -> STE masks
(L1 kernel) -> masked block forward (L1 kernels) -> blockwise
reconstruction + sparsity loss -> gradients w.r.t. theta (and gamma for
the joint-quantization variant). The rust coordinator owns the Adam loop
and calls this artifact once per calibration minibatch.

Granularities (paper Table 6): "block" (default) constrains the mean
sparsity of all 7 layers of one block; "attn-mlp" constrains the attention
(wq..wo) and MLP (wg,wu,wd) groups separately; "two-block" spans 14 layers
of two consecutive blocks. "layer" granularity is exactly Wanda and lives
in rust (prune/wanda.rs).
"""

import functools

import jax
import jax.numpy as jnp

from .configs import LAYER_NAMES, ModelConfig
from .kernels.besa_mask import besa_mask_ste
from .kernels.fake_quant import fake_quant
from .model import block_forward


def rates(cfg: ModelConfig):
    """Candidate pruning rates p_d = d/D for d = 1..D (p_0 = 0 implicit)."""
    d = cfg.n_rates
    return jnp.arange(1, d + 1, dtype=jnp.float32) / d


def beta_from_theta(theta):
    """beta = softmax(theta) over D-1 learnable logits, beta_D = 0.

    theta: [R, D-1] (row-wise) or [1, D-1] (layer-wise, broadcast).
    Returns beta [R, D] with the last rate's probability pinned to zero so
    the most important bucket is never pruned (paper boundary condition).
    """
    b = jax.nn.softmax(theta, axis=-1)
    return jnp.concatenate([b, jnp.zeros_like(b[..., :1])], axis=-1)


def theta_to_mask(theta, rank, cfg: ModelConfig):
    """theta [R|1, D-1], rank int32 [R, C] -> (mask [R, C], alpha [R])."""
    r = rank.shape[0]
    beta = beta_from_theta(theta)
    beta = jnp.broadcast_to(beta, (r, cfg.n_rates))
    # Exclusive cumsum: keep-probability of bucket k is c[k] = sum_{d<=k} beta_d
    # (paper Eqn. 4: P = sum_{d>k} beta_d = 1 - c[k]; bucket 0 covers ranks
    # [0, C*p_1) and must have P = 1 when beta is a point mass at p_1).
    cumb = jnp.concatenate(
        [jnp.zeros_like(beta[..., :1]), jnp.cumsum(beta, axis=-1)[..., :-1]], axis=-1
    )
    alpha = jnp.sum(beta * rates(cfg)[None, :], axis=-1)  # [R]
    mask = besa_mask_ste(rank, cumb, alpha)
    return mask, alpha


GROUPS = {
    "block": [LAYER_NAMES],
    "attn_mlp": [["wq", "wk", "wv", "wo"], ["wg", "wu", "wd"]],
}


def besa_block_loss(
    thetas,
    x_pruned,
    y_dense,
    weights,
    norms,
    ranks,
    lam,
    alpha_hat,
    cfg: ModelConfig,
    granularity: str = "block",
    gammas=None,
    bits: int = 4,
):
    """L^block = L^recon / ||y_dense||^2 + lam * sum_groups (alpha_g - alpha_hat)^2.

    thetas: dict name -> [R|1, D-1] logits.
    gammas: optional dict name -> [2] clipping strengths (joint quant).
    Returns (loss, (recon, mean_alpha)).
    """
    masks, alphas = {}, {}
    qweights = {}
    for n in LAYER_NAMES:
        w = weights[n]
        if gammas is not None:
            w = fake_quant(w, gammas[n][0], gammas[n][1], bits)
        qweights[n] = w
        masks[n], alphas[n] = theta_to_mask(thetas[n], ranks[n], cfg)
    y = block_forward(x_pruned, qweights, norms, cfg, masks=masks)
    recon = jnp.sum((y - y_dense) ** 2) / jnp.maximum(jnp.sum(y_dense**2), 1e-9)
    sparse = 0.0
    for group in GROUPS[granularity]:
        num = sum(jnp.sum(alphas[n]) * ranks[n].shape[1] for n in group)
        den = sum(alphas[n].shape[0] * ranks[n].shape[1] for n in group)
        sparse = sparse + (num / den - alpha_hat) ** 2
    mean_num = sum(jnp.sum(alphas[n]) * ranks[n].shape[1] for n in LAYER_NAMES)
    mean_den = sum(alphas[n].shape[0] * ranks[n].shape[1] for n in LAYER_NAMES)
    loss = recon + lam * sparse
    return loss, (recon, mean_num / mean_den)


def besa_step(
    thetas,
    x_pruned,
    y_dense,
    weights,
    norms,
    ranks,
    lam,
    alpha_hat,
    cfg: ModelConfig,
    granularity: str = "block",
    gammas=None,
    bits: int = 4,
):
    """One optimization step's forward+backward.

    Returns (loss, recon, mean_alpha, dtheta[7], [dgamma[7]]).
    """

    def f(th, gm):
        return besa_block_loss(
            {n: th[i] for i, n in enumerate(LAYER_NAMES)},
            x_pruned,
            y_dense,
            weights,
            norms,
            ranks,
            lam,
            alpha_hat,
            cfg,
            granularity,
            gammas=None if gm is None else {n: gm[i] for i, n in enumerate(LAYER_NAMES)},
            bits=bits,
        )

    th = [thetas[n] for n in LAYER_NAMES]
    if gammas is None:
        (loss, (recon, ma)), dth = jax.value_and_grad(lambda t: f(t, None), has_aux=True)(th)
        return (loss, recon, ma, *dth)
    gm = [gammas[n] for n in LAYER_NAMES]
    (loss, (recon, ma)), (dth, dgm) = jax.value_and_grad(
        f, argnums=(0, 1), has_aux=True
    )(th, gm)
    return (loss, recon, ma, *dth, *dgm)


def two_block_step(
    thetas2, x_pruned, y_dense, weights2, norms2, ranks2, lam, alpha_hat, cfg
):
    """Two-block granularity (paper Table 6 "Two Blocks").

    All *2 args are pairs (block l, block l+1); the reconstruction target is
    the dense output after both blocks and a single sparsity constraint
    covers all 14 layers.
    """

    def f(th_pair):
        x = x_pruned
        alphas_all, sizes_all = [], []
        for b in range(2):
            th = {n: th_pair[b * 7 + i] for i, n in enumerate(LAYER_NAMES)}
            masks, alphas = {}, {}
            for n in LAYER_NAMES:
                masks[n], alphas[n] = theta_to_mask(th[n], ranks2[b][n], cfg)
                alphas_all.append(jnp.sum(alphas[n]) * ranks2[b][n].shape[1])
                sizes_all.append(alphas[n].shape[0] * ranks2[b][n].shape[1])
            x = block_forward(x, weights2[b], norms2[b], cfg, masks=masks)
        recon = jnp.sum((x - y_dense) ** 2) / jnp.maximum(jnp.sum(y_dense**2), 1e-9)
        ma = sum(alphas_all) / sum(sizes_all)
        loss = recon + lam * (ma - alpha_hat) ** 2
        return loss, (recon, ma)

    th = [thetas2[b][n] for b in range(2) for n in LAYER_NAMES]
    (loss, (recon, ma)), dth = jax.value_and_grad(f, has_aux=True)(th)
    return (loss, recon, ma, *dth)
