"""L2: LLaMA-architecture transformer in JAX, built on the L1 kernels.

Every linear projection goes through the Pallas (masked) matmul so the
same graph serves dense forward (mask=None), pruned forward (hard masks),
and the BESA training step (STE masks). RoPE, RMSNorm and attention are
jnp — XLA fuses them; the matmuls are the MXU hot path.

Weight convention: W[out, in] (Wanda rows = output channels), applied as
x @ W.T via kernels.masked_matmul.linear.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import LAYER_NAMES, ModelConfig
from .kernels.masked_matmul import linear


def rmsnorm(x, gain, eps):
    var = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (x * (1.0 / jnp.sqrt(var + eps)) * gain).astype(x.dtype)


def rope_angles(cfg: ModelConfig):
    dh = cfg.d_head
    inv = 1.0 / (cfg.rope_base ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    pos = jnp.arange(cfg.seq_len, dtype=jnp.float32)
    ang = pos[:, None] * inv[None, :]  # [S, dh/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(q, cos, sin):
    # q: [B, H, S, dh]
    q1, q2 = q[..., 0::2], q[..., 1::2]
    out1 = q1 * cos - q2 * sin
    out2 = q1 * sin + q2 * cos
    return jnp.stack([out1, out2], axis=-1).reshape(q.shape)


def attention(q, k, v, cfg: ModelConfig):
    b, s, d = q.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    cos, sin = rope_angles(cfg)
    cos, sin = cos[None, None, :s], sin[None, None, :s]
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
    causal = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(causal[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    return out.transpose(0, 2, 1, 3).reshape(b, s, d)


def block_forward(x, weights, norms, cfg: ModelConfig, masks=None, capture=False):
    """One transformer block.

    weights: dict name -> W[out, in] for the seven prunable projections.
    norms:   (g1, g2) RMSNorm gains.
    masks:   optional dict name -> 0/1 (or STE) mask, same shape as W.
    capture: additionally return the inputs seen by each linear layer
             (for Wanda column norms and SparseGPT Hessians).
    """
    m = (lambda n: masks[n]) if masks is not None else (lambda n: None)
    g1, g2 = norms
    h1 = rmsnorm(x, g1, cfg.norm_eps)
    q = linear(h1, weights["wq"], m("wq"))
    k = linear(h1, weights["wk"], m("wk"))
    v = linear(h1, weights["wv"], m("wv"))
    att = attention(q, k, v, cfg)
    o = linear(att, weights["wo"], m("wo"))
    x2 = x + o
    h2 = rmsnorm(x2, g2, cfg.norm_eps)
    gate = linear(h2, weights["wg"], m("wg"))
    up = linear(h2, weights["wu"], m("wu"))
    act = jax.nn.silu(gate) * up
    down = linear(act, weights["wd"], m("wd"))
    y = x2 + down
    if capture:
        # inputs to {q,k,v}, {o}, {gate,up}, {down} respectively
        return y, (h1, att, h2, act)
    return y


def embed(tokens, emb):
    return emb[tokens]


def head_nll(x, gain_f, emb, tokens, cfg: ModelConfig):
    """Per-position next-token NLL [B, S] (last position zeroed).

    Head is tied to the embedding: logits = rmsnorm(x) @ emb.T.
    """
    h = rmsnorm(x, gain_f, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", h, emb)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = jnp.roll(tokens, -1, axis=1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    valid = jnp.ones_like(nll).at[:, -1].set(0.0)
    return nll * valid


# ---------------------------------------------------------------------------
# Whole-model graphs (pretraining + eval), parameterized by a flat list in a
# fixed order so the rust side can feed literals positionally.
# ---------------------------------------------------------------------------


def param_order(cfg: ModelConfig):
    """Canonical parameter name order shared with rust (model/params.rs)."""
    names = ["embed"]
    for l in range(cfg.n_blocks):
        for w in LAYER_NAMES:
            names.append(f"blocks.{l}.{w}")
        names.append(f"blocks.{l}.norm1")
        names.append(f"blocks.{l}.norm2")
    names.append("norm_f")
    return names


def unflatten_params(cfg: ModelConfig, flat):
    names = param_order(cfg)
    assert len(flat) == len(names), (len(flat), len(names))
    p = dict(zip(names, flat))
    blocks = []
    for l in range(cfg.n_blocks):
        w = {n: p[f"blocks.{l}.{n}"] for n in LAYER_NAMES}
        norms = (p[f"blocks.{l}.norm1"], p[f"blocks.{l}.norm2"])
        blocks.append((w, norms))
    return p["embed"], blocks, p["norm_f"]


def lm_loss(flat_params, tokens, cfg: ModelConfig):
    emb, blocks, norm_f = unflatten_params(cfg, flat_params)
    x = embed(tokens, emb)
    for w, norms in blocks:
        x = block_forward(x, w, norms, cfg)
    nll = head_nll(x, norm_f, emb, tokens, cfg)
    return jnp.sum(nll) / jnp.sum(nll != 0.0).astype(jnp.float32)


def lm_train_step(flat_params, tokens, cfg: ModelConfig):
    """Returns (loss, grads...) — optimizer (Adam) lives in rust."""
    loss, grads = jax.value_and_grad(lambda fp: lm_loss(fp, tokens, cfg))(
        list(flat_params)
    )
    return (loss, *grads)
