"""AOT lowering: every L2 graph -> HLO *text* + a JSON manifest.

Run once by `make artifacts` (python never touches the request path):

    python -m compile.aot --config md --out ../artifacts

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the rust `xla` 0.1.6 crate binds) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

The manifest records, for every artifact, the positional input/output
specs (name, dtype, shape) so the rust runtime can validate literals
before execution and tests can assert the contract.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import besa, model
from .configs import CONFIGS, LAYER_NAMES, ModelConfig

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _named(specs):
    """specs: list of (name, ShapeDtypeStruct) -> manifest fragment."""
    return [
        {"name": n, "dtype": str(s.dtype), "shape": list(s.shape)} for n, s in specs
    ]


class Emitter:
    def __init__(self, cfg: ModelConfig, outdir: str):
        self.cfg = cfg
        self.dir = os.path.join(outdir, cfg.name)
        os.makedirs(self.dir, exist_ok=True)
        self.manifest = {
            "config": {
                "name": cfg.name,
                "vocab": cfg.vocab,
                "d_model": cfg.d_model,
                "n_heads": cfg.n_heads,
                "n_blocks": cfg.n_blocks,
                "d_ffn": cfg.d_ffn,
                "seq_len": cfg.seq_len,
                "batch": cfg.batch,
                "n_rates": cfg.n_rates,
                "norm_eps": cfg.norm_eps,
                "rope_base": cfg.rope_base,
                "layer_shapes": {n: list(s) for n, s in cfg.layer_shapes().items()},
                "param_order": model.param_order(cfg),
            },
            "artifacts": {},
        }

    def emit(self, name, fn, in_specs, out_names):
        """Lower fn at the given positional specs and write <name>.hlo.txt."""
        lowered = jax.jit(fn).lower(*[s for _, s in in_specs])
        text = to_hlo_text(lowered)
        path = os.path.join(self.dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *[s for _, s in in_specs])
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        self.manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": _named(in_specs),
            "outputs": _named(list(zip(out_names, out_avals))),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"  {self.cfg.name}/{name}: {len(text)/1e6:.2f} MB HLO text")

    def finish(self):
        with open(os.path.join(self.dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)


def weight_specs(cfg, prefix=""):
    return [(prefix + n, spec(s)) for n, s in cfg.layer_shapes().items()]


def norm_specs(cfg, prefix=""):
    d = cfg.d_model
    return [(prefix + "norm1", spec((d,))), (prefix + "norm2", spec((d,)))]


def rank_specs(cfg, prefix=""):
    return [(prefix + "rank_" + n, spec(s, I32)) for n, s in cfg.layer_shapes().items()]


def theta_specs(cfg, rowwise: bool, prefix=""):
    dd = cfg.n_rates - 1
    return [
        (prefix + "theta_" + n, spec((s[0] if rowwise else 1, dd)))
        for n, s in cfg.layer_shapes().items()
    ]


def gamma_specs(cfg, prefix=""):
    return [(prefix + "gamma_" + n, spec((2,))) for n in cfg.layer_shapes()]


def emit_config(cfg: ModelConfig, outdir: str):
    em = Emitter(cfg, outdir)
    B, S, d, V = cfg.batch, cfg.seq_len, cfg.d_model, cfg.vocab
    x3 = spec((B, S, d))
    toks = spec((B, S), I32)
    names7 = LAYER_NAMES

    # --- embedding / head -------------------------------------------------
    em.emit(
        "embed",
        lambda tokens, emb: (model.embed(tokens, emb),),
        [("tokens", toks), ("emb", spec((V, d)))],
        ["x"],
    )
    em.emit(
        "head_nll",
        lambda x, nf, emb, tokens: (model.head_nll(x, nf, emb, tokens, cfg),),
        [("x", x3), ("norm_f", spec((d,))), ("emb", spec((V, d))), ("tokens", toks)],
        ["nll"],
    )

    # --- block forward (dense / masked / capture) -------------------------
    def mk_block(masked, capture):
        def f(*args):
            x = args[0]
            w = dict(zip(names7, args[1:8]))
            norms = (args[8], args[9])
            masks = dict(zip(names7, args[10:17])) if masked else None
            out = model.block_forward(x, w, norms, cfg, masks=masks, capture=capture)
            return out if not capture else (out[0], *out[1])

        return f

    base_in = [("x", x3)] + weight_specs(cfg) + norm_specs(cfg)
    mask_in = [("mask_" + n, spec(s)) for n, s in cfg.layer_shapes().items()]
    em.emit("block_fwd", mk_block(False, False), base_in, ["y"])
    em.emit("block_fwd_masked", mk_block(True, False), base_in + mask_in, ["y"])
    em.emit(
        "block_capture",
        mk_block(False, True),
        base_in,
        ["y", "h1", "att", "h2", "act"],
    )

    # --- BESA steps --------------------------------------------------------
    def mk_besa(rowwise, granularity, quant):
        def f(*args):
            i = 0

            def take(k):
                nonlocal i
                out = args[i : i + k]
                i += k
                return out

            th = dict(zip(names7, take(7)))
            xp, yd = take(2)
            w = dict(zip(names7, take(7)))
            norms = tuple(take(2))
            rk = dict(zip(names7, take(7)))
            lam, ah = take(2)
            gm = dict(zip(names7, take(7))) if quant else None
            return besa.besa_step(
                th, xp, yd, w, norms, rk, lam, ah, cfg, granularity, gammas=gm
            )

        return f

    def besa_inputs(rowwise, quant):
        ins = (
            theta_specs(cfg, rowwise)
            + [("x_pruned", x3), ("y_dense", x3)]
            + weight_specs(cfg)
            + norm_specs(cfg)
            + rank_specs(cfg)
            + [("lam", spec(())), ("alpha_hat", spec(()))]
        )
        if quant:
            ins += gamma_specs(cfg)
        return ins

    besa_outs = ["loss", "recon", "mean_alpha"] + ["dtheta_" + n for n in names7]
    em.emit(
        "besa_step_row", mk_besa(True, "block", False), besa_inputs(True, False), besa_outs
    )

    # Table 5 "sparsity step" ablation: same step graph at other D values
    import dataclasses

    for alt_d in cfg.alt_rates:
        alt_cfg = dataclasses.replace(cfg, n_rates=alt_d, alt_rates=())
        alt_em_cfg = em.cfg  # emit into the same dir/manifest
        del alt_em_cfg

        def mk_besa_alt(acfg):
            def f(*args):
                i = 0

                def take(k):
                    nonlocal i
                    out = args[i : i + k]
                    i += k
                    return out

                th = dict(zip(names7, take(7)))
                xp, yd = take(2)
                w = dict(zip(names7, take(7)))
                norms = tuple(take(2))
                rk = dict(zip(names7, take(7)))
                lam, ah = take(2)
                return besa.besa_step(th, xp, yd, w, norms, rk, lam, ah, acfg, "block")

            return f

        alt_theta = [
            ("theta_" + n, spec((s[0], alt_d - 1)))
            for n, s in cfg.layer_shapes().items()
        ]
        alt_in = (
            alt_theta
            + [("x_pruned", x3), ("y_dense", x3)]
            + weight_specs(cfg)
            + norm_specs(cfg)
            + rank_specs(cfg)
            + [("lam", spec(())), ("alpha_hat", spec(()))]
        )
        em.emit(f"besa_step_row_d{alt_d}", mk_besa_alt(alt_cfg), alt_in, besa_outs)
    em.emit(
        "besa_step_layer",
        mk_besa(False, "block", False),
        besa_inputs(False, False),
        besa_outs,
    )
    em.emit(
        "besa_step_attnmlp",
        mk_besa(True, "attn_mlp", False),
        besa_inputs(True, False),
        besa_outs,
    )
    em.emit(
        "besa_quant_step_row",
        mk_besa(True, "block", True),
        besa_inputs(True, True),
        besa_outs + ["dgamma_" + n for n in names7],
    )

    # --- two-block granularity (Table 6) -----------------------------------
    def two_block(*args):
        i = 0

        def take(k):
            nonlocal i
            out = args[i : i + k]
            i += k
            return out

        th = [dict(zip(names7, take(7))) for _ in range(2)]
        xp, yd = take(2)
        w = [dict(zip(names7, take(7))) for _ in range(2)]
        norms = [tuple(take(2)) for _ in range(2)]
        rk = [dict(zip(names7, take(7))) for _ in range(2)]
        lam, ah = take(2)
        return besa.two_block_step(th, xp, yd, w, norms, rk, lam, ah, cfg)

    tb_in = (
        theta_specs(cfg, True, "b0_")
        + theta_specs(cfg, True, "b1_")
        + [("x_pruned", x3), ("y_dense", x3)]
        + weight_specs(cfg, "b0_")
        + weight_specs(cfg, "b1_")
        + norm_specs(cfg, "b0_")
        + norm_specs(cfg, "b1_")
        + rank_specs(cfg, "b0_")
        + rank_specs(cfg, "b1_")
        + [("lam", spec(())), ("alpha_hat", spec(()))]
    )
    tb_out = (
        ["loss", "recon", "mean_alpha"]
        + ["b0_dtheta_" + n for n in names7]
        + ["b1_dtheta_" + n for n in names7]
    )
    em.emit("two_block_step", two_block, tb_in, tb_out)

    # --- mask decode + quant apply per distinct layer shape -----------------
    distinct = {}
    for n, s in cfg.layer_shapes().items():
        distinct.setdefault(s, n)
    for shape, _n in distinct.items():
        r, c = shape
        tag = f"{r}x{c}"

        def mk_decode(sh):
            def f(theta, rank):
                m, a = besa.theta_to_mask(theta, rank, cfg)
                return m, a

            return f

        em.emit(
            f"mask_decode_{tag}",
            mk_decode(shape),
            [("theta", spec((r, cfg.n_rates - 1))), ("rank", spec((r, c), I32))],
            ["mask", "alpha"],
        )

        def mk_quant(sh):
            from .kernels.fake_quant import fake_quant

            def f(w, gamma):
                return (fake_quant(w, gamma[0], gamma[1], 4),)

            return f

        em.emit(
            f"quant_apply_{tag}",
            mk_quant(shape),
            [("w", spec((r, c))), ("gamma", spec((2,)))],
            ["wq"],
        )

    # --- whole-model pretraining step --------------------------------------
    porder = model.param_order(cfg)

    def pshape(name):
        if name == "embed":
            return (V, d)
        if name.endswith(("norm1", "norm2")) or name == "norm_f":
            return (d,)
        return cfg.layer_shapes()[name.split(".")[-1]]

    train_in = [(n, spec(pshape(n))) for n in porder] + [("tokens", toks)]

    def train(*args):
        return model.lm_train_step(args[:-1], args[-1], cfg)

    em.emit(
        "lm_train_step", train, train_in, ["loss"] + ["d_" + n for n in porder]
    )

    em.finish()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", action="append", default=None, help="config name(s)")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    names = args.config or list(CONFIGS)
    for name in names:
        print(f"[aot] lowering config '{name}'")
        emit_config(CONFIGS[name], args.out)
    print("[aot] done")


if __name__ == "__main__":
    main()
