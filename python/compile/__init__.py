"""Build-time compile package: L2 jax graphs + L1 kernels + AOT lowering."""
