"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function here is the mathematical definition of the corresponding
kernel in this package; pytest sweeps shapes/dtypes with hypothesis and
asserts allclose between the kernel (interpret=True) and these references.
"""

import jax.numpy as jnp


def bucket_of_rank(rank, n_cols: int, n_rates: int):
    """Bucket index k(r) = floor(r * D / C) for rank r in [0, C).

    Candidate pruning rates are p_d = d / D for d = 0..D; an element whose
    ascending-importance rank falls in [C*p_k, C*p_{k+1}) belongs to bucket
    k and has pruning probability P = sum_{d>k} beta_d = 1 - cumbeta[k].
    """
    return jnp.minimum((rank * n_rates) // n_cols, n_rates - 1).astype(jnp.int32)


def besa_mask_ref(rank, cumbeta, alpha):
    """Hard BESA mask (Eqn. 4-5 of the paper).

    rank:    int32 [R, C]  ascending per-row importance rank (0 = least)
    cumbeta: f32  [R, D]   cumsum of beta over candidate rates (beta_D = 0)
    alpha:   f32  [R]      per-row expected sparsity  sum_d beta_d * p_d
    returns (mask [R, C], keepprob [R, C]) where keepprob = cumbeta[k(rank)]
    and mask = 1[1 - keepprob < alpha]  (P < alpha  =>  keep).
    """
    r, c = rank.shape
    d = cumbeta.shape[-1]
    k = bucket_of_rank(rank, c, d)
    keep = jnp.take_along_axis(cumbeta, k, axis=1)
    prune_prob = 1.0 - keep
    mask = (prune_prob < alpha[:, None]).astype(cumbeta.dtype)
    return mask, keep


def besa_mask_bwd_ref(rank, g, n_rates: int):
    """Backward of the STE mask w.r.t. cumbeta: bin g by bucket.

    grad_cumbeta[i, d] = sum_j g[i, j] * 1[k(rank[i,j]) == d]
    """
    r, c = rank.shape
    k = bucket_of_rank(rank, c, n_rates)
    onehot = (k[:, :, None] == jnp.arange(n_rates)[None, None, :]).astype(g.dtype)
    return jnp.einsum("rc,rcd->rd", g, onehot)


def matmul_ref(x, w):
    """y = x @ w.T with f32 accumulation."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32).T).astype(x.dtype)


def masked_matmul_ref(x, w, m):
    """y = x @ (w * m).T — the pruned linear layer."""
    return matmul_ref(x, w * m)


def wanda_importance_ref(w, colnorm):
    """delta_ij = |W_ij| * ||X_:,j||_2 (Wanda metric, Eqn. 2)."""
    return jnp.abs(w) * colnorm[None, :]


def fake_quant_ref(w, gamma0, gamma1, bits: int):
    """Min-max fake quantization with learnable clipping (Eqn. 7)."""
    qmax = 2.0**bits - 1.0
    wmin = gamma0 * jnp.min(w)
    wmax = gamma1 * jnp.max(w)
    h = jnp.maximum((wmax - wmin) / qmax, 1e-8)
    z = jnp.round(-wmin / h)
    q = jnp.clip(jnp.round(w / h) + z, 0.0, qmax)
    return (q - z) * h


def rmsnorm_ref(x, gain, eps: float = 1e-5):
    var = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (x / jnp.sqrt(var + eps) * gain).astype(x.dtype)
