"""L1 Pallas kernel: differentiable BESA mask generation (paper Eqn. 4-6).

This is the paper's "customized CUDA operator" rethought for TPU
(DESIGN.md §Hardware-Adaptation): instead of warp-parallel row scans, the
bucket index k(r) = floor(r*D/C) is pure vector math on the VPU, the
per-element keep-probability is a take_along_axis gather from a [TR, D]
cumbeta tile resident in VMEM, and the whole thing fuses with the masked
matmul downstream. Sorting is *not* in this kernel — ranks are computed
once per block (Algorithm 1, line 4) outside the optimization loop.

The straight-through estimator is expressed as a jax.custom_vjp around the
forward/backward kernel pair, so the same primitive serves the besa_step
training graph and the mask_decode artifact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
# custom-calls; real-TPU perf is estimated analytically (DESIGN.md §Perf).
INTERPRET = True


def _row_tile(n_rows: int) -> int:
    for t in (64, 32, 16, 8, 4, 2, 1):
        if n_rows % t == 0:
            return t
    return 1


def _mask_fwd_kernel(rank_ref, cumb_ref, alpha_ref, mask_ref, keep_ref, *, n_rates):
    rank = rank_ref[...]  # [TR, C] int32
    cumb = cumb_ref[...]  # [TR, D]
    alpha = alpha_ref[...]  # [TR, 1]
    c = rank.shape[-1]
    k = jnp.minimum((rank * n_rates) // c, n_rates - 1)
    keep = jnp.take_along_axis(cumb, k, axis=1)
    mask = ((1.0 - keep) < alpha).astype(cumb.dtype)
    mask_ref[...] = mask
    keep_ref[...] = keep


def _mask_bwd_kernel(rank_ref, g_ref, out_ref, *, n_rates):
    rank = rank_ref[...]  # [TR, C]
    g = g_ref[...]  # [TR, C]
    c = rank.shape[-1]
    k = jnp.minimum((rank * n_rates) // c, n_rates - 1)
    onehot = (k[:, :, None] == jnp.arange(n_rates)[None, None, :]).astype(g.dtype)
    out_ref[...] = jnp.einsum("rc,rcd->rd", g, onehot)


def besa_mask_kernel(rank, cumbeta, alpha):
    """Raw forward kernel: (mask, keepprob), no autodiff semantics."""
    r, c = rank.shape
    d = cumbeta.shape[-1]
    tr = _row_tile(r)
    grid = (r // tr,)
    return pl.pallas_call(
        functools.partial(_mask_fwd_kernel, n_rates=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, c), lambda i: (i, 0)),
            pl.BlockSpec((tr, d), lambda i: (i, 0)),
            pl.BlockSpec((tr, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tr, c), lambda i: (i, 0)),
            pl.BlockSpec((tr, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), cumbeta.dtype),
            jax.ShapeDtypeStruct((r, c), cumbeta.dtype),
        ],
        interpret=INTERPRET,
    )(rank, cumbeta, alpha.reshape(r, 1))


def besa_mask_grad_kernel(rank, g, n_rates):
    """Raw backward kernel: bucket-binned segment sum of g -> [R, D]."""
    r, c = rank.shape
    tr = _row_tile(r)
    grid = (r // tr,)
    return pl.pallas_call(
        functools.partial(_mask_bwd_kernel, n_rates=n_rates),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, c), lambda i: (i, 0)),
            pl.BlockSpec((tr, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tr, n_rates), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n_rates), g.dtype),
        interpret=INTERPRET,
    )(rank, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def besa_mask_ste(rank, cumbeta, alpha):
    """STE mask: forward = hard 0/1 mask, backward routes dL/dM into cumbeta
    via the bucket map (paper Eqn. 6: dM/d(beta_d) = 1[d <= k])."""
    mask, _ = besa_mask_kernel(rank, cumbeta, alpha)
    return mask


def _ste_fwd(rank, cumbeta, alpha):
    mask, _ = besa_mask_kernel(rank, cumbeta, alpha)
    return mask, (rank, cumbeta.shape[-1])


def _ste_bwd(res, g):
    rank, n_rates = res
    gcum = besa_mask_grad_kernel(rank, g, n_rates)
    # alpha enters the loss only through the (differentiable) sparsity
    # penalty, not through the hard mask: no gradient here (Eqn. 6).
    return (None, gcum, jnp.zeros(rank.shape[0], gcum.dtype))


besa_mask_ste.defvjp(_ste_fwd, _ste_bwd)
