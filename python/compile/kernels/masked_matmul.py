"""L1 Pallas kernels: tiled (masked) matmul — the pruned linear layer.

y = x @ (w * m).T with x [M, K], w/m [N, K] (Wanda row convention).

TPU mapping (DESIGN.md §Hardware-Adaptation): the mask multiply happens on
the weight tile *in VMEM* right before it is fed to the MXU, so the sparse
weight never round-trips to HBM densified. Tiles are MXU-shaped
(up to 128x128); the K dimension is kept whole per tile (our model dims,
<= 516, fit VMEM comfortably: 128*516*4B = 258 KiB/tile).

A jax.custom_vjp provides the exact backward as three more tiled matmuls:
  dx = g @ (w*m);  dw = (g.T @ x) * m;  dm = (g.T @ x) * w
so gradients flow to the mask (and through the STE into the BESA betas).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _tile(n: int, pref: int = 128) -> int:
    for t in (pref, 64, 32, 16, 8, 4, 2, 1):
        if n % t == 0 and t <= n:
            return t
    return 1


def _mm_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(x, w.T).astype(o_ref.dtype)


def _mmm_kernel(x_ref, w_ref, m_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    w = (w_ref[...] * m_ref[...]).astype(jnp.float32)
    o_ref[...] = jnp.dot(x, w.T).astype(o_ref.dtype)


def matmul_t(x, w):
    """y[M,N] = x[M,K] @ w[N,K].T as a tiled Pallas kernel."""
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2, (x.shape, w.shape)
    tm, tn = _tile(m), _tile(n)
    return pl.pallas_call(
        _mm_kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=INTERPRET,
    )(x, w)


def _masked_matmul_raw(x, w, m):
    mm, k = x.shape
    n, k2 = w.shape
    assert k == k2
    tm, tn = _tile(mm), _tile(n)
    return pl.pallas_call(
        _mmm_kernel,
        grid=(mm // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((tn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, n), x.dtype),
        interpret=INTERPRET,
    )(x, w, m)


@jax.custom_vjp
def dense_matmul(x, w):
    """y = x @ w.T, differentiable (used by the dense forward / pretraining)."""
    return matmul_t(x, w)


def _dmm_fwd(x, w):
    return matmul_t(x, w), (x, w)


def _dmm_bwd(res, g):
    x, w = res
    dx = matmul_t(g, jnp.swapaxes(w, 0, 1))
    dw = matmul_t(jnp.swapaxes(g, 0, 1), jnp.swapaxes(x, 0, 1))
    return dx, dw


dense_matmul.defvjp(_dmm_fwd, _dmm_bwd)


@jax.custom_vjp
def masked_matmul(x, w, m):
    """y = x @ (w*m).T, differentiable in x, w and m."""
    return _masked_matmul_raw(x, w, m)


def _mmm_fwd(x, w, m):
    return _masked_matmul_raw(x, w, m), (x, w, m)


def _mmm_bwd(res, g):
    x, w, m = res
    wm = w * m
    # dx[M,K] = g[M,N] @ wm[N,K]  (matmul_t computes a @ b.T)
    dx = matmul_t(g, jnp.swapaxes(wm, 0, 1))
    # gtx[N,K] = g.T[N,M] @ x[M,K]
    gtx = matmul_t(jnp.swapaxes(g, 0, 1), jnp.swapaxes(x, 0, 1))
    return dx, gtx * m, gtx * w


masked_matmul.defvjp(_mmm_fwd, _mmm_bwd)


def linear(x3, w, m=None):
    """Apply (masked) linear to a [B, S, K] activation, returns [B, S, N]."""
    b, s, k = x3.shape
    x2 = x3.reshape(b * s, k)
    y2 = masked_matmul(x2, w, m) if m is not None else dense_matmul(x2, w)
    return y2.reshape(b, s, w.shape[0])
