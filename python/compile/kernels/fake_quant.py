"""L1 Pallas kernel: min-max fake quantization with learnable clipping.

Implements paper Eqn. 7 (OmniQuant-style): the weight tensor is quantized
to N-bit integers with scale/zero-point derived from *learnable* clipping
strengths gamma0/gamma1 in [0, 1], then dequantized. STE on the round op
makes the graph differentiable w.r.t. both w and the gammas, so joint
pruning+quantization (paper §3.3, Table 3) trains both the BESA betas and
the clipping strengths in one besa_quant_step artifact.

The elementwise quant runs as a Pallas kernel over weight tiles; the
global min/max reduction (a scalar) stays in jnp where XLA fuses it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _tile(n: int, pref: int = 128) -> int:
    for t in (pref, 64, 32, 16, 8, 4, 2, 1):
        if n % t == 0 and t <= n:
            return t
    return 1


def _quant_kernel(w_ref, h_ref, z_ref, o_ref, *, qmax):
    w = w_ref[...]
    h = h_ref[0, 0]
    z = z_ref[0, 0]
    q = jnp.clip(jnp.round(w / h) + z, 0.0, qmax)
    o_ref[...] = (q - z) * h


def _quant_elementwise(w, h, z, bits):
    r, c = w.shape
    tr, tc = _tile(r), _tile(c, pref=512)
    qmax = 2.0**bits - 1.0
    return pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=(r // tr, c // tc),
        in_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), w.dtype),
        interpret=INTERPRET,
    )(w, h.reshape(1, 1), z.reshape(1, 1))


def _ste_round(x):
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def _soft_fake_quant(w, gamma0, gamma1, bits: int):
    """STE surrogate: identical forward values, fully differentiable."""
    qmax = 2.0**bits - 1.0
    wmin = gamma0 * jnp.min(w)
    wmax = gamma1 * jnp.max(w)
    h = jnp.maximum((wmax - wmin) / qmax, 1e-8)
    z = _ste_round(-wmin / h)
    return (jnp.clip(_ste_round(w / h) + z, 0.0, qmax) - z) * h


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fake_quant(w, gamma0, gamma1, bits: int):
    """Differentiable fake quantization (forward = ref.fake_quant_ref).

    Forward runs the Pallas elementwise kernel; backward differentiates the
    STE surrogate (round treated as identity), so gradients reach both w and
    the clipping strengths gamma0/gamma1 through h and z.
    """
    qmax = 2.0**bits - 1.0
    wmin = gamma0 * jnp.min(w)
    wmax = gamma1 * jnp.max(w)
    h = jnp.maximum((wmax - wmin) / qmax, 1e-8)
    z = jnp.round(-wmin / h)
    return _quant_elementwise(w, h, z, bits)


def _fq_fwd(w, gamma0, gamma1, bits):
    return fake_quant(w, gamma0, gamma1, bits), (w, gamma0, gamma1)


def _fq_bwd(bits, res, g):
    w, gamma0, gamma1 = res
    _, vjp = jax.vjp(lambda w_, g0, g1: _soft_fake_quant(w_, g0, g1, bits), w, gamma0, gamma1)
    return vjp(g)


fake_quant.defvjp(_fq_fwd, _fq_bwd)
