"""L1 Pallas kernel: Wanda importance scoring (paper Eqn. 2).

delta_ij = |W_ij| * ||X_:,j||_2 — elementwise magnitude times the
broadcast column norm of the calibration activations. The norm vector is
accumulated streaming over calibration batches (rust side / L2 capture
graph); this kernel only does the broadcast-multiply over weight tiles so
it can fuse with the sort-free rank consumers.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _tile(n: int, pref: int = 128) -> int:
    for t in (pref, 64, 32, 16, 8, 4, 2, 1):
        if n % t == 0 and t <= n:
            return t
    return 1


def _wanda_kernel(w_ref, n_ref, o_ref):
    o_ref[...] = jnp.abs(w_ref[...]) * n_ref[...]


def wanda_importance(w, colnorm):
    """scores [R, C] = |w| * colnorm[None, :]."""
    r, c = w.shape
    tr, tc = _tile(r), _tile(c, pref=512)
    return pl.pallas_call(
        _wanda_kernel,
        grid=(r // tr, c // tc),
        in_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((1, tc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), w.dtype),
        interpret=INTERPRET,
    )(w, colnorm.reshape(1, c))


def ranks_from_scores(scores):
    """Ascending per-row rank of each element (0 = least important).

    rank = argsort(argsort(scores)) — computed ONCE per block (Algorithm 1
    line 4), outside the beta-optimization loop; stays in jnp/XLA because
    sort is the one op that does not map to the TPU VPU/MXU.
    """
    order = jnp.argsort(scores, axis=-1)
    return jnp.argsort(order, axis=-1).astype(jnp.int32)
