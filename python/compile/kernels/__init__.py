"""L1 Pallas kernels (interpret=True on CPU PJRT) + pure-jnp oracles."""

from . import besa_mask, fake_quant, masked_matmul, ref, wanda  # noqa: F401
