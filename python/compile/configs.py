"""Model / pruning configurations shared by the AOT compiler and tests.

These are the "LLaMA family" stand-ins of the reproduction (see DESIGN.md
§Substitutions): same architecture (pre-norm, RoPE, SwiGLU, 7 linear weights
per block, tied embedding head), scaled to sizes that pretrain in minutes on
a CPU PJRT client. The rust side reads the same values from the manifest
emitted by aot.py — python is never imported at run time.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int  # byte-level tokenizer
    d_model: int
    n_heads: int
    n_blocks: int
    d_ffn: int
    seq_len: int  # fixed AOT sequence length
    batch: int  # fixed AOT batch (calibration minibatch & eval batch)
    # BESA hyperparameters baked into artifact shapes
    n_rates: int = 100  # D: number of candidate pruning rates (sparsity step 1/D)
    # extra candidate-rate counts to lower besa_step variants for
    # (Table 5 "sparsity step" ablation); empty for most configs
    alt_rates: tuple = ()
    rope_base: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    # The seven prunable linear weights of one block, in pipeline order.
    # Shapes follow the Wanda convention: W[out, in], importance is sorted
    # per *row* (output channel) over the input dimension.
    def layer_shapes(self):
        d, f = self.d_model, self.d_ffn
        return {
            "wq": (d, d),
            "wk": (d, d),
            "wv": (d, d),
            "wo": (d, d),
            "wg": (f, d),
            "wu": (f, d),
            "wd": (d, f),
        }

    def block_param_count(self) -> int:
        return sum(r * c for r, c in self.layer_shapes().values())


CONFIGS = {
    # unit-test scale: exercised by pytest and cargo test
    "test": ModelConfig("test", 256, 32, 2, 2, 88, 32, 4, n_rates=16),
    # the "model family" standing in for LLaMA-7B/13B/30B (DESIGN.md)
    "sm": ModelConfig("sm", 256, 64, 4, 4, 172, 64, 8, n_rates=32, alt_rates=(8, 64)),
    "md": ModelConfig("md", 256, 128, 4, 8, 344, 128, 8, n_rates=100),
    "lg": ModelConfig("lg", 256, 192, 8, 8, 516, 128, 8, n_rates=100),
}

LAYER_NAMES = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"]
