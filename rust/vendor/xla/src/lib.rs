//! Offline stub of the `xla` PJRT binding crate (v0.1.6 API surface used
//! by this repo). The container build has no network and no XLA shared
//! library, so the `pjrt` cargo feature links this stub instead: the
//! `runtime::pjrt` backend compiles and typechecks, and every runtime
//! entry point fails with a clear error telling the operator to swap in
//! the real bindings (replace the `vendor/xla` path dependency).

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn stub_err<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: built against the vendored xla *stub* — point the `xla` \
         path dependency in rust/Cargo.toml at the real PJRT bindings \
         (or run with --backend native)"
    )))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
}

#[derive(Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Debug)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

pub struct Literal(());

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        stub_err("Literal::create_from_shape_and_untyped_data")
    }

    pub fn shape(&self) -> Result<Shape> {
        stub_err("Literal::shape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        stub_err("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        stub_err("Literal::to_tuple")
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        stub_err("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err("PjRtBuffer::to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err("PjRtClient::compile")
    }
}
