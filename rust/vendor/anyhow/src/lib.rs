//! Minimal, dependency-free subset of the `anyhow` crate, vendored so the
//! workspace builds fully offline (no crates.io access at compile time).
//!
//! Implements the surface this repo actually uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] macros and the [`Context`] extension trait
//! for `Result` and `Option`. Context frames accumulate outermost-first;
//! `{:#}` (and `{:?}`) formatting prints the whole chain separated by
//! `": "`, matching upstream anyhow's rendering closely enough for logs
//! and test assertions.

use std::fmt;

/// An error type holding a message plus a chain of context frames.
pub struct Error {
    /// Outermost message first; the root cause is the last entry.
    frames: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { frames: vec![m.to_string()] }
    }

    /// Wrap with an outer context frame (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.frames.insert(0, c.to_string());
        self
    }

    /// Iterate the chain outermost-first (upstream: `Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow: any std error converts into `Error`. (No overlap
// with the reflexive `From<Error> for Error` because `Error` itself does
// not implement `std::error::Error` — same trick as upstream.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an error if a condition is false (upstream `ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<String> {
        let r = std::fs::read_to_string("/definitely/not/a/real/path/xyz");
        r.with_context(|| "reading config".to_string())
    }

    #[test]
    fn context_chain_formats() {
        let e = fails_io().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").starts_with("reading config: "));
        assert!(e.chain().count() >= 2);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn bail_and_anyhow() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        let e: Error = anyhow!("ad-hoc {}", 7);
        assert_eq!(e.root_cause(), "ad-hoc 7");
    }
}
