//! Property-based tests on coordinator/pruning invariants (offline
//! proptest replacement: besa::util::proptest).

use besa::prune::importance::{decode_mask, magnitude_scores, ranks, wanda_scores};
use besa::prune::topk_row_mask;
use besa::sim::{dense_cycles, simulate_spmm, Csr, SimConfig};
use besa::tensor::Tensor;
use besa::util::proptest::{check, F32Vec, Strategy, UsizeIn, Zip};
use besa::util::rng::Rng;

struct MatrixStrat {
    rows: std::ops::RangeInclusive<usize>,
    cols: std::ops::RangeInclusive<usize>,
}

impl Strategy for MatrixStrat {
    type Value = (usize, usize, Vec<f32>, u64);
    fn sample(&self, rng: &mut Rng) -> Self::Value {
        let r = UsizeIn(self.rows.clone()).sample(rng);
        let c = UsizeIn(self.cols.clone()).sample(rng);
        let seed = rng.next_u64();
        let mut g = Rng::seed(seed);
        let data = (0..r * c).map(|_| g.normal_f32()).collect();
        (r, c, data, seed)
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let (r, c, _, seed) = v;
        let mut out = Vec::new();
        for (nr, nc) in [(r / 2, *c), (*r, c / 2), (1, *c), (*r, *self.cols.start())] {
            if nr >= *self.rows.start() && nc >= *self.cols.start() && (nr, nc) != (*r, *c) {
                let mut g = Rng::seed(*seed);
                out.push((nr, nc, (0..nr * nc).map(|_| g.normal_f32()).collect(), *seed));
            }
        }
        out
    }
}

#[test]
fn prop_topk_mask_sparsity_exact() {
    let strat = MatrixStrat { rows: 1..=16, cols: 4..=64 };
    check("topk mask hits requested rate per row", 60, &strat, |(r, c, data, _)| {
        let t = Tensor::from_f32(&[*r, *c], data.clone());
        for sparsity in [0.25, 0.5, 0.75] {
            let m = topk_row_mask(&t, sparsity);
            let expect = ((*c as f64) * sparsity).round() / *c as f64;
            for row in 0..*r {
                let z = m.f32s()[row * c..(row + 1) * c].iter().filter(|v| **v == 0.0).count();
                let got = z as f64 / *c as f64;
                if (got - expect).abs() > 1e-9 {
                    return Err(format!("row {row}: sparsity {got} != {expect}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ranks_are_row_permutations() {
    let strat = MatrixStrat { rows: 1..=12, cols: 2..=48 };
    check("ranks() rows are permutations of 0..C", 60, &strat, |(r, c, data, _)| {
        let t = Tensor::from_f32(&[*r, *c], data.clone());
        let rk = ranks(&t);
        for row in 0..*r {
            let mut seen = vec![false; *c];
            for j in 0..*c {
                let v = rk.i32s()[row * c + j] as usize;
                if v >= *c || seen[v] {
                    return Err(format!("row {row} invalid rank {v}"));
                }
                seen[v] = true;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_decode_mask_sparsity_matches_alpha() {
    // point-mass theta at index k must prune exactly (k+1)/D of the
    // bucket-aligned columns (C a multiple of D)
    let strat = Zip(UsizeIn(1..=7), UsizeIn(1..=4));
    check("decode_mask point mass -> exact rate", 40, &strat, |(k, mult)| {
        let d = 8usize;
        let c = d * mult;
        let mut logits = vec![-30.0f32; d - 1];
        logits[*k - 1] = 30.0;
        let theta = Tensor::from_f32(&[1, d - 1], logits);
        let rank = Tensor::from_i32(&[1, c], (0..c as i32).collect());
        let (mask, alphas) = decode_mask(&theta, &rank, d);
        let want = *k as f64 / d as f64;
        if (alphas[0] - want).abs() > 1e-9 {
            return Err(format!("alpha {} != {want}", alphas[0]));
        }
        let got = mask.zero_fraction();
        if (got - want).abs() > 1e-9 {
            return Err(format!("sparsity {got} != {want}"));
        }
        Ok(())
    });
}

#[test]
fn prop_decode_mask_never_prunes_top_bucket() {
    let strat = MatrixStrat { rows: 1..=8, cols: 8..=40 };
    check("most-important bucket always kept", 60, &strat, |(r, c, data, seed)| {
        let d = 8usize;
        let theta = Tensor::from_f32(&[*r, d - 1], {
            let mut g = Rng::seed(seed.wrapping_add(1));
            (0..*r * (d - 1)).map(|_| g.normal_f32() * 2.0).collect()
        });
        let scores = Tensor::from_f32(&[*r, *c], data.clone());
        let rk = ranks(&scores);
        let (mask, _) = decode_mask(&theta, &rk, d);
        for row in 0..*r {
            // the element with the maximal rank is in the top bucket
            let (jmax, _) = (0..*c)
                .map(|j| (j, rk.i32s()[row * c + j]))
                .max_by_key(|(_, v)| *v)
                .unwrap();
            if mask.f32s()[row * c + jmax] != 1.0 {
                return Err(format!("row {row}: most important weight pruned"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wanda_reduces_to_magnitude_on_unit_norms() {
    let strat = MatrixStrat { rows: 1..=10, cols: 2..=32 };
    check("wanda == magnitude under unit column norms", 50, &strat, |(r, c, data, _)| {
        let t = Tensor::from_f32(&[*r, *c], data.clone());
        let ws = wanda_scores(&t, &vec![1.0; *c]);
        let ms = magnitude_scores(&t);
        if ws.f32s() != ms.f32s() {
            return Err("scores differ".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sim_macs_monotone_and_cycles_bounded() {
    // NOTE: total *cycles* are not strictly monotone in density — moving a
    // column across the denser/sparser threshold can rebalance the two
    // engines (observed by an earlier, stronger version of this property).
    // The true invariants: processed MACs are monotone in nnz, and cycles
    // are bounded below by perfect-utilization latency.
    let strat = Zip(UsizeIn(32..=128), UsizeIn(32..=128));
    check("sim macs monotone, cycles >= roofline", 25, &strat, |(r, c)| {
        let cfg = SimConfig::default();
        let mut rng = Rng::seed((*r * 1000 + *c) as u64);
        let dense_data: Vec<f32> = (0..r * c).map(|_| rng.normal_f32()).collect();
        let mut prev_macs = u64::MAX;
        for sparsity in [0.9, 0.6, 0.3, 0.0] {
            let data: Vec<f32> = dense_data
                .iter()
                .enumerate()
                .map(|(i, v)| {
                    let mut g = Rng::seed(i as u64);
                    if g.f64() < sparsity {
                        0.0
                    } else {
                        *v
                    }
                })
                .collect();
            let csr = Csr::from_dense(&Tensor::from_f32(&[*r, *c], data));
            let res = simulate_spmm(&csr, &cfg);
            let macs = res.denser_macs + res.sparser_macs;
            if prev_macs != u64::MAX && macs < prev_macs {
                return Err("macs decreased as matrix got denser".into());
            }
            // roofline: nnz MACs over all PEs, per token tile, plus loads
            let total_pes = (cfg.denser_pes + cfg.sparser_pes) as u64;
            if res.cycles < macs / total_pes {
                return Err(format!("cycles {} below roofline {}", res.cycles, macs / total_pes));
            }
            prev_macs = macs;
        }
        // fully dense on the sim should be >= the dense-engine estimate / 4
        let full = Csr::from_dense(&Tensor::from_f32(&[*r, *c], dense_data));
        let sim_cycles = simulate_spmm(&full, &cfg).cycles;
        let dense_est = dense_cycles(*r, *c, &cfg);
        if (sim_cycles as f64) < dense_est as f64 * 0.25 {
            return Err(format!("dense sim {sim_cycles} implausibly beats estimate {dense_est}"));
        }
        Ok(())
    });
}

#[test]
fn prop_bst_roundtrip_random_tensors() {
    let strat = F32Vec { len: 1..=64, lo: -100.0, hi: 100.0 };
    check("bst save/load roundtrip", 30, &strat, |v| {
        let dir = std::env::temp_dir().join(format!("bst_prop_{}", std::process::id()));
        let path = dir.join("t.bst");
        let mut m = std::collections::BTreeMap::new();
        m.insert("x".to_string(), Tensor::from_f32(&[v.len()], v.clone()));
        besa::tensor::io::save(&path, &m).map_err(|e| e.to_string())?;
        let back = besa::tensor::io::load(&path).map_err(|e| e.to_string())?;
        std::fs::remove_dir_all(&dir).ok();
        if back["x"].f32s() != v.as_slice() {
            return Err("data mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_numbers() {
    use besa::util::json::Json;
    let strat = F32Vec { len: 1..=20, lo: -1e6, hi: 1e6 };
    check("json number array roundtrip", 40, &strat, |v| {
        let j = Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect());
        let parsed = Json::parse(&j.to_string()).map_err(|e| e.to_string())?;
        if parsed != j {
            return Err(format!("roundtrip mismatch: {}", j.to_string()));
        }
        Ok(())
    });
}
