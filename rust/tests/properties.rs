//! Property-based tests on coordinator/pruning invariants (offline
//! proptest replacement: besa::util::proptest) plus the model-based
//! fuzz of the paged KV allocator: random alloc / append / fork / free
//! / migrate / rewind sequences against a contiguous reference model,
//! with the pool conservation and COW refcount invariants re-asserted
//! after every single operation.

use besa::prune::importance::{decode_mask, magnitude_scores, ranks, wanda_scores};
use besa::prune::topk_row_mask;
use besa::serve::{PagePool, PageTable};
use besa::sim::{dense_cycles, simulate_spmm, Csr, SimConfig};
use besa::tensor::Tensor;
use besa::util::proptest::{check, F32Vec, Strategy, UsizeIn, Zip};
use besa::util::rng::Rng;

struct MatrixStrat {
    rows: std::ops::RangeInclusive<usize>,
    cols: std::ops::RangeInclusive<usize>,
}

impl Strategy for MatrixStrat {
    type Value = (usize, usize, Vec<f32>, u64);
    fn sample(&self, rng: &mut Rng) -> Self::Value {
        let r = UsizeIn(self.rows.clone()).sample(rng);
        let c = UsizeIn(self.cols.clone()).sample(rng);
        let seed = rng.next_u64();
        let mut g = Rng::seed(seed);
        let data = (0..r * c).map(|_| g.normal_f32()).collect();
        (r, c, data, seed)
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let (r, c, _, seed) = v;
        let mut out = Vec::new();
        for (nr, nc) in [(r / 2, *c), (*r, c / 2), (1, *c), (*r, *self.cols.start())] {
            if nr >= *self.rows.start() && nc >= *self.cols.start() && (nr, nc) != (*r, *c) {
                let mut g = Rng::seed(*seed);
                out.push((nr, nc, (0..nr * nc).map(|_| g.normal_f32()).collect(), *seed));
            }
        }
        out
    }
}

#[test]
fn prop_topk_mask_sparsity_exact() {
    let strat = MatrixStrat { rows: 1..=16, cols: 4..=64 };
    check("topk mask hits requested rate per row", 60, &strat, |(r, c, data, _)| {
        let t = Tensor::from_f32(&[*r, *c], data.clone());
        for sparsity in [0.25, 0.5, 0.75] {
            let m = topk_row_mask(&t, sparsity);
            let expect = ((*c as f64) * sparsity).round() / *c as f64;
            for row in 0..*r {
                let z = m.f32s()[row * c..(row + 1) * c].iter().filter(|v| **v == 0.0).count();
                let got = z as f64 / *c as f64;
                if (got - expect).abs() > 1e-9 {
                    return Err(format!("row {row}: sparsity {got} != {expect}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ranks_are_row_permutations() {
    let strat = MatrixStrat { rows: 1..=12, cols: 2..=48 };
    check("ranks() rows are permutations of 0..C", 60, &strat, |(r, c, data, _)| {
        let t = Tensor::from_f32(&[*r, *c], data.clone());
        let rk = ranks(&t);
        for row in 0..*r {
            let mut seen = vec![false; *c];
            for j in 0..*c {
                let v = rk.i32s()[row * c + j] as usize;
                if v >= *c || seen[v] {
                    return Err(format!("row {row} invalid rank {v}"));
                }
                seen[v] = true;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_decode_mask_sparsity_matches_alpha() {
    // point-mass theta at index k must prune exactly (k+1)/D of the
    // bucket-aligned columns (C a multiple of D)
    let strat = Zip(UsizeIn(1..=7), UsizeIn(1..=4));
    check("decode_mask point mass -> exact rate", 40, &strat, |(k, mult)| {
        let d = 8usize;
        let c = d * mult;
        let mut logits = vec![-30.0f32; d - 1];
        logits[*k - 1] = 30.0;
        let theta = Tensor::from_f32(&[1, d - 1], logits);
        let rank = Tensor::from_i32(&[1, c], (0..c as i32).collect());
        let (mask, alphas) = decode_mask(&theta, &rank, d);
        let want = *k as f64 / d as f64;
        if (alphas[0] - want).abs() > 1e-9 {
            return Err(format!("alpha {} != {want}", alphas[0]));
        }
        let got = mask.zero_fraction();
        if (got - want).abs() > 1e-9 {
            return Err(format!("sparsity {got} != {want}"));
        }
        Ok(())
    });
}

#[test]
fn prop_decode_mask_never_prunes_top_bucket() {
    let strat = MatrixStrat { rows: 1..=8, cols: 8..=40 };
    check("most-important bucket always kept", 60, &strat, |(r, c, data, seed)| {
        let d = 8usize;
        let theta = Tensor::from_f32(&[*r, d - 1], {
            let mut g = Rng::seed(seed.wrapping_add(1));
            (0..*r * (d - 1)).map(|_| g.normal_f32() * 2.0).collect()
        });
        let scores = Tensor::from_f32(&[*r, *c], data.clone());
        let rk = ranks(&scores);
        let (mask, _) = decode_mask(&theta, &rk, d);
        for row in 0..*r {
            // the element with the maximal rank is in the top bucket
            let (jmax, _) = (0..*c)
                .map(|j| (j, rk.i32s()[row * c + j]))
                .max_by_key(|(_, v)| *v)
                .unwrap();
            if mask.f32s()[row * c + jmax] != 1.0 {
                return Err(format!("row {row}: most important weight pruned"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wanda_reduces_to_magnitude_on_unit_norms() {
    let strat = MatrixStrat { rows: 1..=10, cols: 2..=32 };
    check("wanda == magnitude under unit column norms", 50, &strat, |(r, c, data, _)| {
        let t = Tensor::from_f32(&[*r, *c], data.clone());
        let ws = wanda_scores(&t, &vec![1.0; *c]);
        let ms = magnitude_scores(&t);
        if ws.f32s() != ms.f32s() {
            return Err("scores differ".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sim_macs_monotone_and_cycles_bounded() {
    // NOTE: total *cycles* are not strictly monotone in density — moving a
    // column across the denser/sparser threshold can rebalance the two
    // engines (observed by an earlier, stronger version of this property).
    // The true invariants: processed MACs are monotone in nnz, and cycles
    // are bounded below by perfect-utilization latency.
    let strat = Zip(UsizeIn(32..=128), UsizeIn(32..=128));
    check("sim macs monotone, cycles >= roofline", 25, &strat, |(r, c)| {
        let cfg = SimConfig::default();
        let mut rng = Rng::seed((*r * 1000 + *c) as u64);
        let dense_data: Vec<f32> = (0..r * c).map(|_| rng.normal_f32()).collect();
        let mut prev_macs = u64::MAX;
        for sparsity in [0.9, 0.6, 0.3, 0.0] {
            let data: Vec<f32> = dense_data
                .iter()
                .enumerate()
                .map(|(i, v)| {
                    let mut g = Rng::seed(i as u64);
                    if g.f64() < sparsity {
                        0.0
                    } else {
                        *v
                    }
                })
                .collect();
            let csr = Csr::from_dense(&Tensor::from_f32(&[*r, *c], data));
            let res = simulate_spmm(&csr, &cfg);
            let macs = res.denser_macs + res.sparser_macs;
            if prev_macs != u64::MAX && macs < prev_macs {
                return Err("macs decreased as matrix got denser".into());
            }
            // roofline: nnz MACs over all PEs, per token tile, plus loads
            let total_pes = (cfg.denser_pes + cfg.sparser_pes) as u64;
            if res.cycles < macs / total_pes {
                return Err(format!("cycles {} below roofline {}", res.cycles, macs / total_pes));
            }
            prev_macs = macs;
        }
        // fully dense on the sim should be >= the dense-engine estimate / 4
        let full = Csr::from_dense(&Tensor::from_f32(&[*r, *c], dense_data));
        let sim_cycles = simulate_spmm(&full, &cfg).cycles;
        let dense_est = dense_cycles(*r, *c, &cfg);
        if (sim_cycles as f64) < dense_est as f64 * 0.25 {
            return Err(format!("dense sim {sim_cycles} implausibly beats estimate {dense_est}"));
        }
        Ok(())
    });
}

#[test]
fn prop_bst_roundtrip_random_tensors() {
    let strat = F32Vec { len: 1..=64, lo: -100.0, hi: 100.0 };
    check("bst save/load roundtrip", 30, &strat, |v| {
        let dir = std::env::temp_dir().join(format!("bst_prop_{}", std::process::id()));
        let path = dir.join("t.bst");
        let mut m = std::collections::BTreeMap::new();
        m.insert("x".to_string(), Tensor::from_f32(&[v.len()], v.clone()));
        besa::tensor::io::save(&path, &m).map_err(|e| e.to_string())?;
        let back = besa::tensor::io::load(&path).map_err(|e| e.to_string())?;
        std::fs::remove_dir_all(&dir).ok();
        if back["x"].f32s() != v.as_slice() {
            return Err("data mismatch".into());
        }
        Ok(())
    });
}

// ===== paged KV allocator: model-based fuzz ==============================
//
// The reference model is the obvious contiguous one: each table mirrors
// to a `Vec` of rows per block. The real allocator shares pages across
// forks, copy-on-writes them, migrates tables between workers and
// recycles buffers through the pool free list — none of which the model
// has — so any divergence in committed rows, lengths, or pool accounting
// is an allocator bug by definition.

/// KV geometry for the fuzz: small enough that page boundaries, COW
/// clones and pool exhaustion all happen constantly.
const FZ_NB: usize = 2;
const FZ_D: usize = 4;
const FZ_P: usize = 3;

/// One live table plus its contiguous reference: `rows[pos][block]`.
struct ModelEntry {
    table: PageTable,
    rows: Vec<Vec<(Vec<f32>, Vec<f32>)>>,
    /// admission-time capacity in tokens
    cap: usize,
    /// rewind floor: a forked child never rewrites its fully-shared
    /// prefix pages (serving never does either — registry parents are
    /// frozen and children only grow past the fork point)
    floor: usize,
    /// fork sources freeze, like registered prefixes: no appends/rewinds
    frozen: bool,
}

/// Distinct, deterministic row content (a shared counter, not position),
/// so stale page reuse or cross-table aliasing can never pass equality.
fn fz_row(counter: &mut u32) -> (Vec<f32>, Vec<f32>) {
    let c = *counter as f32;
    *counter += 1;
    let k = (0..FZ_D).map(|j| c + j as f32 * 0.125).collect();
    let v = (0..FZ_D).map(|j| -c - j as f32 * 0.25).collect();
    (k, v)
}

/// Committed rows of one block, walked exactly as the attention kernels
/// walk them (ascending-position segments).
fn fz_gathered(t: &PageTable, block: usize) -> (Vec<f32>, Vec<f32>) {
    let mut k = Vec::with_capacity(t.len() * FZ_D);
    let mut v = Vec::with_capacity(t.len() * FZ_D);
    for si in 0..t.n_segments() {
        let seg = t.segment(block, si);
        k.extend_from_slice(seg.k);
        v.extend_from_slice(seg.v);
    }
    (k, v)
}

fn fz_pick(workers: &[Vec<ModelEntry>; 2], rng: &mut Rng) -> Option<(usize, usize)> {
    let total = workers[0].len() + workers[1].len();
    if total == 0 {
        return None;
    }
    let j = rng.below(total);
    if j < workers[0].len() {
        Some((0, j))
    } else {
        Some((1, j - workers[0].len()))
    }
}

/// The full invariant sweep, run after **every** operation:
/// * pool conservation: `live + free == created` (no leak, no double
///   free) and, bounded, `live + reserved <= max_pages`;
/// * every table's committed rows equal its reference model bitwise;
/// * COW refcounts: every page handle is live, and summing `1/refcount`
///   over all table-held pages recovers exactly the pool's live count —
///   shared pages are counted once, unshared pages once, and a page
///   referenced by nobody (or double-counted by a broken COW) breaks
///   the identity.
fn fz_check_all(pool: &PagePool, workers: &[Vec<ModelEntry>; 2], max_pages: usize, step: usize) {
    let s = pool.stats();
    assert_eq!(s.live + s.free, s.created, "step {step}: page conservation broken");
    if max_pages > 0 {
        assert!(
            s.live + s.reserved <= max_pages,
            "step {step}: cap oversubscribed (live {} + reserved {} > {max_pages})",
            s.live,
            s.reserved
        );
    }
    let mut inv_sum = 0.0f64;
    for w in workers {
        for e in w {
            assert_eq!(e.table.len(), e.rows.len(), "step {step}: committed length diverged");
            for b in 0..FZ_NB {
                let (k, v) = fz_gathered(&e.table, b);
                let mk: Vec<f32> = e.rows.iter().flat_map(|r| r[b].0.iter().copied()).collect();
                let mv: Vec<f32> = e.rows.iter().flat_map(|r| r[b].1.iter().copied()).collect();
                assert_eq!(k, mk, "step {step} block {b}: keys diverged from the model");
                assert_eq!(v, mv, "step {step} block {b}: values diverged from the model");
            }
            for rc in e.table.page_refcounts() {
                assert!(rc >= 1, "step {step}: dead page handle");
                inv_sum += 1.0 / rc as f64;
            }
        }
    }
    assert!(
        (inv_sum - s.live as f64).abs() < 1e-6,
        "step {step}: refcount conservation broken ({inv_sum} distinct pages vs {} live)",
        s.live
    );
}

fn fz_run(seed: u64, max_pages: usize, ops: usize) {
    let pool = PagePool::new(FZ_NB, FZ_D, FZ_P, max_pages);
    let mut rng = Rng::seed(seed);
    let mut counter: u32 = 1;
    let mut workers: [Vec<ModelEntry>; 2] = [Vec::new(), Vec::new()];
    let (mut alloc_fails, mut fork_fails, mut forks) = (0usize, 0usize, 0usize);
    for step in 0..ops {
        let n_live = workers[0].len() + workers[1].len();
        match rng.below(16) {
            // ---- alloc: admission reserves the worst case up front ----
            0..=2 => {
                if n_live < 12 {
                    let cost = 1 + rng.below(12);
                    match pool.new_table(cost) {
                        Some(table) => {
                            let w = rng.below(2);
                            workers[w].push(ModelEntry {
                                table,
                                rows: Vec::new(),
                                cap: cost,
                                floor: 0,
                                frozen: false,
                            });
                        }
                        None => {
                            assert!(max_pages > 0, "unbounded pool refused an admission");
                            alloc_fails += 1;
                        }
                    }
                }
            }
            // ---- append one committed position (all blocks + set_len) ----
            3..=8 => {
                if let Some((w, i)) = fz_pick(&workers, &mut rng) {
                    let e = &mut workers[w][i];
                    if !e.frozen && e.rows.len() < e.cap {
                        let pos = e.rows.len();
                        let mut row = Vec::with_capacity(FZ_NB);
                        for b in 0..FZ_NB {
                            let (k, v) = fz_row(&mut counter);
                            e.table.write(b, pos, &k, &v);
                            row.push((k, v));
                        }
                        e.table.set_len(pos + 1);
                        e.rows.push(row);
                    }
                }
            }
            // ---- rewind (benches do this), never below the fork floor ----
            9 => {
                if let Some((w, i)) = fz_pick(&workers, &mut rng) {
                    let e = &mut workers[w][i];
                    if !e.frozen && e.floor < e.rows.len() {
                        let span = e.rows.len() - e.floor;
                        let new_len = e.floor + rng.below(span + 1);
                        e.table.set_len(new_len);
                        e.rows.truncate(new_len);
                    }
                }
            }
            // ---- fork: COW prefix sharing; the source freezes ----
            10 | 11 => {
                if n_live > 0 && n_live < 12 {
                    if let Some((w, i)) = fz_pick(&workers, &mut rng) {
                        let len = workers[w][i].rows.len();
                        if len >= 1 {
                            let p0 = rng.below(len + 1);
                            let cost = p0 + 1 + rng.below(9);
                            match workers[w][i].table.fork(p0, cost) {
                                Some(table) => {
                                    // shared prefix pages are refcounted, not copied
                                    let shared = p0.div_ceil(FZ_P);
                                    let rc = table.page_refcounts();
                                    for (j, c) in rc.iter().enumerate().take(shared) {
                                        assert!(*c >= 2, "fork did not share page {j}");
                                    }
                                    let rows = workers[w][i].rows[..p0].to_vec();
                                    workers[w][i].frozen = true;
                                    forks += 1;
                                    let tw = rng.below(2);
                                    workers[tw].push(ModelEntry {
                                        table,
                                        rows,
                                        cap: cost,
                                        floor: p0,
                                        frozen: false,
                                    });
                                }
                                None => {
                                    assert!(max_pages > 0, "unbounded pool refused a fork");
                                    fork_fails += 1;
                                }
                            }
                        }
                    }
                }
            }
            // ---- free: drop the table; its private pages recycle ----
            12 | 13 => {
                if let Some((w, i)) = fz_pick(&workers, &mut rng) {
                    workers[w].swap_remove(i);
                }
            }
            // ---- migrate: the work-stealing handoff is a plain move ----
            _ => {
                if let Some((w, i)) = fz_pick(&workers, &mut rng) {
                    let e = workers[w].swap_remove(i);
                    workers[1 - w].push(e);
                }
            }
        }
        fz_check_all(&pool, &workers, max_pages, step);
    }
    if max_pages == 0 {
        assert!(forks > 0, "seed {seed}: fuzz never exercised a fork");
        assert_eq!(alloc_fails + fork_fails, 0);
    }

    // drain: every page must come home, every reservation must clear
    workers[0].clear();
    workers[1].clear();
    let s = pool.stats();
    assert_eq!(s.live, 0, "seed {seed}: drained pool still has live pages");
    assert_eq!(s.reserved, 0, "seed {seed}: drained pool still holds reservations");
    assert_eq!(s.free, s.created, "seed {seed}: free list lost pages");

    // free-list reuse: a fresh admission after the drain recycles
    // buffers instead of minting new ones
    let before = s.created;
    assert!(before >= 2, "seed {seed}: fuzz never created two pages");
    let mut t = pool.new_table(2 * FZ_P).expect("drained pool must admit");
    for pos in 0..2 * FZ_P {
        for b in 0..FZ_NB {
            let (k, v) = fz_row(&mut counter);
            t.write(b, pos, &k, &v);
        }
        t.set_len(pos + 1);
    }
    assert_eq!(pool.stats().created, before, "seed {seed}: free-list pages were not reused");
}

#[test]
fn prop_paged_allocator_matches_reference_model_unbounded() {
    for seed in [1u64, 7, 23] {
        fz_run(seed, 0, 1200);
    }
}

#[test]
fn prop_paged_allocator_matches_reference_model_bounded() {
    // 48 pages over ≤12 tables of ≤12 tokens: admissions and forks hit
    // the cap constantly, so the clean-rejection path is exercised too
    for seed in [2u64, 11, 29] {
        fz_run(seed, 48, 1200);
    }
}

#[test]
fn prop_json_roundtrip_numbers() {
    use besa::util::json::Json;
    let strat = F32Vec { len: 1..=20, lo: -1e6, hi: 1e6 };
    check("json number array roundtrip", 40, &strat, |v| {
        let j = Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect());
        let parsed = Json::parse(&j.to_string()).map_err(|e| e.to_string())?;
        if parsed != j {
            return Err(format!("roundtrip mismatch: {}", j.to_string()));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// model-based fuzz of the supervised retry/requeue state machine
// ---------------------------------------------------------------------------

/// A randomly drawn chaos scenario: a fault schedule plus the knobs that
/// shape the retry/requeue state machine around it.
#[derive(Debug, Clone)]
struct ChaosCase {
    spec: String,
    fault_seed: u64,
    retry_budget: u32,
    policy_idx: usize,
    paged: bool,
    n_requests: usize,
}

struct ChaosStrat;

impl Strategy for ChaosStrat {
    type Value = ChaosCase;
    fn sample(&self, rng: &mut Rng) -> Self::Value {
        // 1-3 clauses; counts/periods kept ≥3 so restart-backoff sleeps
        // stay bounded and the run always terminates quickly
        let n_clauses = 1 + (rng.next_u64() % 3) as usize;
        let mut clauses = Vec::new();
        for _ in 0..n_clauses {
            let site = ["prefill", "decode"][(rng.next_u64() % 2) as usize];
            let (action, param) = match rng.next_u64() % 3 {
                0 => ("panic", String::new()),
                1 => ("stall", format!("={}", 1 + rng.next_u64() % 3)),
                _ => ("deny", String::new()),
            };
            let clause = if action == "deny" {
                format!("deny@admit%{}", 4 + rng.next_u64() % 6)
            } else {
                match rng.next_u64() % 3 {
                    0 => format!("{action}@{site}:{}{param}", 3 + rng.next_u64() % 7),
                    1 => format!(
                        "{action}@{site}:{}+{}{param}",
                        3 + rng.next_u64() % 7,
                        4 + rng.next_u64() % 6
                    ),
                    _ => format!("{action}@{site}%{}{param}", 4 + rng.next_u64() % 6),
                }
            };
            clauses.push(clause);
        }
        ChaosCase {
            spec: clauses.join(","),
            fault_seed: rng.next_u64(),
            retry_budget: (rng.next_u64() % 4) as u32,
            policy_idx: (rng.next_u64() % 2) as usize,
            paged: rng.next_u64() % 2 == 0,
            n_requests: 4 + (rng.next_u64() % 5) as usize,
        }
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        // drop clauses one at a time, then shrink the trace
        let clauses: Vec<&str> = v.spec.split(',').collect();
        if clauses.len() > 1 {
            for skip in 0..clauses.len() {
                let spec: Vec<&str> = clauses
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| *i != skip)
                    .map(|(_, c)| *c)
                    .collect();
                out.push(ChaosCase { spec: spec.join(","), ..v.clone() });
            }
        }
        if v.n_requests > 4 {
            out.push(ChaosCase { n_requests: v.n_requests - 1, ..v.clone() });
        }
        out
    }
}

/// The abstract model the engine must refine: every request is admitted
/// at most `retry_budget + 1` times, ends in exactly one terminal state,
/// and — when it finishes — produces the fault-free output bitwise,
/// because replay always restarts from scratch.
#[test]
fn prop_retry_requeue_state_machine() {
    use std::sync::Arc;

    use besa::model::{ModelConfig, ParamStore};
    use besa::serve::bench::magnitude_prune_in_place;
    use besa::serve::engine::ServeContext;
    use besa::serve::model::{PackedModel, WeightFormat};
    use besa::serve::{
        serve_online, serve_online_tiered, FaultPlan, KvMode, OnlineConfig, Pacing, Policy, Qos,
        ReqKind, Request, SchedulerConfig,
    };

    let cfg = ModelConfig::builtin("test").expect("built-in test config");
    let mut params = ParamStore::init(&cfg, 42);
    magnitude_prune_in_place(&mut params, &cfg, 0.5).unwrap();
    let ctxs: Vec<ServeContext> = (0..2)
        .map(|_| {
            ServeContext::new(
                PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
                64,
            )
        })
        .collect();
    let mk_requests = |n: usize| -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                id: i,
                arrival: 0.0,
                tokens: (0..(3 + i % 4)).map(|t| 1 + ((i * 5 + t) % 11) as i32).collect(),
                kind: if i % 3 == 2 {
                    ReqKind::Score
                } else {
                    ReqKind::Generate { max_new: 2 + i % 2 }
                },
                qos: Qos::default(),
            })
            .collect()
    };
    let base = OnlineConfig {
        workers: 2,
        sched: SchedulerConfig { token_budget: 128, max_batch: 3 },
        pacing: Pacing::Replay { time_scale: 0.0 },
        ..OnlineConfig::default()
    };
    // fault-free reference outputs for the largest trace; a prefix of
    // the id space serves every smaller n (outputs are per-request)
    let reference: std::collections::BTreeMap<usize, (Vec<i32>, Option<f64>)> =
        serve_online(&ctxs, mk_requests(8), &base)
            .unwrap()
            .finished
            .iter()
            .map(|f| (f.id, (f.tokens.clone(), f.nll)))
            .collect();

    check("supervised retry/requeue refines the abstract model", 16, &ChaosStrat, |case| {
        let plan = FaultPlan::parse(&case.spec, case.fault_seed)
            .map_err(|e| format!("{:?}: bad spec: {e:#}", case))?;
        let ocfg = OnlineConfig {
            policy: [Policy::Fifo, Policy::Edf][case.policy_idx],
            kv: if case.paged {
                KvMode::Paged { page_tokens: 4, max_pages: 0 }
            } else {
                KvMode::Contig
            },
            faults: Some(Arc::new(plan)),
            retry_budget: case.retry_budget,
            ..base.clone()
        };
        // Ok(_) certifies the engine's own hard invariants: accounting
        // balances and the page pool drained to zero live pages
        let stats = serve_online_tiered(&ctxs, None, mk_requests(case.n_requests), &ocfg, None)
            .map_err(|e| format!("{case:?}: {e:#}"))?;

        let mut seen = std::collections::BTreeSet::new();
        for id in stats
            .finished
            .iter()
            .map(|f| f.id)
            .chain(stats.shed.iter().map(|s| s.id))
            .chain(stats.rejected.iter().map(|r| r.id))
            .chain(stats.failed.iter().map(|f| f.id))
        {
            if !seen.insert(id) {
                return Err(format!("{case:?}: request {id} has two terminal outcomes"));
            }
        }
        if seen.len() != case.n_requests {
            return Err(format!("{case:?}: {} terminals for {} requests", seen.len(), case.n_requests));
        }
        for f in &stats.failed {
            // attempts consumed by a terminal failure can exceed the
            // budget by at most one (the fatal attempt itself)
            if f.attempts == 0 || f.attempts > case.retry_budget + 1 {
                return Err(format!("{case:?}: failure consumed {} attempts", f.attempts));
            }
        }
        if stats.requeues > 0 && stats.restarts == 0 {
            return Err(format!("{case:?}: requeues without a restart"));
        }
        for f in &stats.finished {
            let (want_tokens, want_nll) = &reference[&f.id];
            if &f.tokens != want_tokens || f.nll != *want_nll {
                return Err(format!(
                    "{case:?}: request {} diverged from the fault-free output after {} restarts",
                    f.id, stats.restarts
                ));
            }
        }
        Ok(())
    });
}
