//! Parity suite for the serving subsystem (hermetic, `test` config):
//!
//! * sparse (CSR) serving reproduces the dense path, and dense serving
//!   reproduces the native backend's `block_fwd`/`head_nll` NLL, to well
//!   within 1e-5 on a pruned checkpoint;
//! * KV-cached decode (in-process kernels AND the runtime's
//!   `block_fwd_cached` artifact) matches dense full-prefix recompute
//!   token for token;
//! * the quantized path equals fake-quantizing the checkpoint first;
//! * a full continuous-batching trace replay retires every request with
//!   identical outputs across weight formats;
//! * the online multi-worker engine retires every request with identical
//!   per-request outputs at any worker count, equal to the offline
//!   single-threaded replay (sharding preserves per-request determinism);
//! * the queue policy (FIFO / priority / EDF) changes only *ordering*,
//!   never any request's output;
//! * serving over loopback TCP through the line protocol reproduces the
//!   offline replay token for token, with NLLs bit-exact across the wire.

use std::collections::BTreeMap;

use besa::model::{ModelConfig, ParamStore};
use besa::quant::{quantize_model, QuantSpec};
use besa::runtime::Engine;
use besa::serve::bench::magnitude_prune_in_place;
use besa::serve::engine::{
    block_tensors, decode_step, decode_step_backend, greedy_backend, greedy_cached,
    greedy_recompute, greedy_with_cache, prefill, prefill_continue, score_nll, DecodeScratch,
    ServeContext,
};
use besa::serve::model::{PackedModel, WeightFormat};
use besa::serve::net::{request_line, WireEvent};
use besa::serve::scheduler::SchedulerConfig;
use besa::serve::trace::TraceConfig;
use besa::serve::{
    poisson_trace, run_trace, serve_online, Kv, KvMode, KvSpec, LineClient, NetConfig, NetServer,
    OnlineConfig, Pacing, Policy, ReqKind,
};
use besa::tensor::Tensor;

fn pruned_setup() -> (Engine, ModelConfig, ParamStore) {
    let engine = Engine::native("test").expect("built-in test config");
    let cfg = engine.config().clone();
    let mut params = ParamStore::init(&cfg, 42);
    magnitude_prune_in_place(&mut params, &cfg, 0.5).unwrap();
    assert!((params.prunable_sparsity(cfg.n_blocks) - 0.5).abs() < 0.01);
    (engine, cfg, params)
}

/// Serve-side scoring (dense and sparse) must match the engine's
/// `block_fwd` + `head_nll` NLL on the same tokens to within 1e-5.
#[test]
fn sparse_scoring_matches_dense_block_fwd_nll() {
    let (engine, cfg, params) = pruned_setup();
    let mut batcher = besa::data::Batcher::new(besa::data::Domain::WikiSyn, 9, &cfg);
    let tokens: Tensor = batcher.next_batch();
    let nll_ref = besa::eval::forward_nll(&engine, &params, &tokens).unwrap();

    let dense_ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Dense).unwrap(),
        cfg.seq_len,
    );
    let sparse_ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
        cfg.seq_len,
    );
    let s = cfg.seq_len;
    for b in 0..cfg.batch {
        let row = &tokens.i32s()[b * s..(b + 1) * s];
        let mut c1 = dense_ctx.new_cache();
        let nll_dense = score_nll(&dense_ctx, &prefill(&dense_ctx, row, &mut c1), row);
        let mut c2 = sparse_ctx.new_cache();
        let nll_sparse = score_nll(&sparse_ctx, &prefill(&sparse_ctx, row, &mut c2), row);
        for si in 0..s {
            let want = nll_ref.f32s()[b * s + si];
            assert!(
                (nll_dense[si] - want).abs() < 1e-5,
                "dense serve vs engine NLL at ({b},{si}): {} vs {want}",
                nll_dense[si]
            );
            assert!(
                (nll_sparse[si] - want).abs() < 1e-5,
                "sparse serve vs engine NLL at ({b},{si}): {} vs {want}",
                nll_sparse[si]
            );
            // CSR drops exact zeros only: bitwise equal to dense serving
            assert_eq!(nll_sparse[si], nll_dense[si], "sparse must be bitwise dense");
        }
    }
}

/// KV-cached decode — sparse kernels and the `block_fwd_cached` artifact
/// — must match dense full-prefix recompute token for token.
#[test]
fn cached_decode_matches_full_prefix_recompute() {
    let (engine, cfg, params) = pruned_setup();
    let n = 10;
    let prompt: Vec<i32> = (0..12).map(|i| (i * 13 % cfg.vocab) as i32).collect();
    let max_pos = prompt.len() + n + 1;
    let dense_ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Dense).unwrap(),
        max_pos,
    );
    let sparse_ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
        max_pos,
    );
    let reference = greedy_recompute(&dense_ctx, &prompt, n);
    assert_eq!(reference.len(), n);
    assert_eq!(greedy_cached(&dense_ctx, &prompt, n), reference, "dense cached vs recompute");
    assert_eq!(greedy_cached(&sparse_ctx, &prompt, n), reference, "sparse cached vs recompute");

    // the runtime-op route (engine block_fwd_cached)
    let blocks = block_tensors(&params, &cfg).unwrap();
    let backend = greedy_backend(&dense_ctx, &engine, &blocks, &prompt, n).unwrap();
    assert_eq!(backend, reference, "block_fwd_cached vs recompute");
}

/// Feeding a sequence token-by-token through the runtime's
/// `block_fwd_cached` artifact must leave exactly the same KV state as
/// one full prefill — position p of the cached op reproduces row p of the
/// full forward bitwise.
#[test]
fn block_fwd_cached_matches_block_fwd_rows() {
    let engine = Engine::native("test").unwrap();
    let cfg = engine.config().clone();
    let params = ParamStore::init(&cfg, 7);
    let ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Dense).unwrap(),
        cfg.seq_len,
    );
    let prompt: Vec<i32> = (0..cfg.seq_len).map(|i| (i * 3 % cfg.vocab) as i32).collect();
    let mut full_cache = ctx.new_cache();
    let full_hidden = prefill(&ctx, &prompt, &mut full_cache);
    assert_eq!(full_hidden.len(), prompt.len() * cfg.d_model);

    // incremental: position 0 via a length-1 prefill, the rest one token
    // at a time through the engine op
    let blocks = block_tensors(&params, &cfg).unwrap();
    let mut cache = ctx.new_cache();
    prefill(&ctx, &prompt[..1], &mut cache);
    for p in 1..prompt.len() {
        let last = [prompt[p]];
        let mut caches = [&mut cache];
        decode_step_backend(&ctx, &engine, &blocks, &last, &mut caches).unwrap();
    }
    assert_eq!(cache.len(), full_cache.len());
    let (inc, full) = (cache.as_contig().unwrap(), full_cache.as_contig().unwrap());
    for l in 0..cfg.n_blocks {
        assert_eq!(inc.k_block(l), full.k_block(l), "block {l} keys");
        assert_eq!(inc.v_block(l), full.v_block(l), "block {l} values");
    }
}

/// Quantized serving equals fake-quantizing the checkpoint and serving
/// dense — the fused dequant is bit-exact.
#[test]
fn quant_serving_matches_fake_quant_checkpoint() {
    let (_engine, cfg, params) = pruned_setup();
    let spec = QuantSpec::default();
    let mut params_q = params.clone();
    quantize_model(&mut params_q, &cfg, spec).unwrap();

    let quant_ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Quant(spec)).unwrap(),
        cfg.seq_len,
    );
    let dense_q_ctx = ServeContext::new(
        PackedModel::materialize(&params_q, &cfg, WeightFormat::Dense).unwrap(),
        cfg.seq_len,
    );
    let prompt: Vec<i32> = (0..16).map(|i| (i * 11 % cfg.vocab) as i32).collect();
    let mut c1 = quant_ctx.new_cache();
    let h_quant = prefill(&quant_ctx, &prompt, &mut c1);
    let mut c2 = dense_q_ctx.new_cache();
    let h_dense = prefill(&dense_q_ctx, &prompt, &mut c2);
    // bit-exact up to fake_quant's handling of exact zeros (which the
    // packed form drops and the dense form may carry as ±0 terms)
    for (i, (a, b)) in h_quant.iter().zip(&h_dense).enumerate() {
        assert!((a - b).abs() < 1e-6, "hidden[{i}]: {a} vs {b}");
    }
}

/// Heterogeneous prompt lengths assembled into the backend's static
/// `[B, S]` shape by right-padding must score identically to the serve
/// engine's variable-length path (causality makes the padding exact).
#[test]
fn padded_backend_scoring_matches_serve_engine() {
    let (engine, cfg, params) = pruned_setup();
    let lens = [5usize, 17, 32, 9, 26];
    let prompts: Vec<Vec<i32>> = lens
        .iter()
        .map(|len| (0..*len).map(|i| ((i * 7 + len) % cfg.vocab) as i32).collect())
        .collect();
    let padded = besa::eval::score_prompts_padded(&engine, &params, &prompts).unwrap();
    assert_eq!(padded.len(), prompts.len());
    let ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
        cfg.seq_len,
    );
    for (p, want) in prompts.iter().zip(&padded) {
        let mut c = ctx.new_cache();
        let h = prefill(&ctx, p, &mut c);
        let got: f64 = score_nll(&ctx, &h, p).iter().map(|v| *v as f64).sum();
        assert!(
            (got - want).abs() < 1e-4,
            "prompt len {}: serve {got} vs padded backend {want}",
            p.len()
        );
    }
}

/// Full trace replay: every request retires exactly once, scoring NLLs
/// agree bitwise between dense and sparse, and generated token counts
/// respect the per-request budget.
#[test]
fn trace_replay_consistent_across_formats() {
    let (_engine, cfg, params) = pruned_setup();
    let tcfg = TraceConfig {
        n_requests: 10,
        rate: 200.0,
        prompt_min: 4,
        prompt_max: 12,
        gen_min: 2,
        gen_max: 6,
        score_fraction: 0.3,
        burst: 1,
        seed: 99,
        ..TraceConfig::default()
    };
    let sched = SchedulerConfig { token_budget: 64, max_batch: 3 };
    let requests = poisson_trace(&tcfg);
    let max_new: std::collections::BTreeMap<usize, usize> = requests
        .iter()
        .map(|r| {
            let m = match r.kind {
                ReqKind::Generate { max_new } => max_new,
                ReqKind::Score => 0,
            };
            (r.id, m)
        })
        .collect();

    let mut outputs: Vec<BTreeMap<usize, (Vec<i32>, Option<f64>)>> = Vec::new();
    for format in [WeightFormat::Dense, WeightFormat::Csr] {
        let ctx = ServeContext::new(
            PackedModel::materialize(&params, &cfg, format).unwrap(),
            tcfg.max_request_tokens(),
        );
        let stats = run_trace(&ctx, None, requests.clone(), &sched, &KvSpec::contig()).unwrap();
        assert_eq!(stats.finished.len(), tcfg.n_requests, "{}: all retire", format.name());
        let mut seen = std::collections::BTreeSet::new();
        for f in &stats.finished {
            assert!(seen.insert(f.id), "request {} retired twice", f.id);
            assert_eq!(f.out_tokens, max_new[&f.id], "request {} token budget", f.id);
            assert_eq!(f.tokens.len(), max_new[&f.id], "request {} token record", f.id);
            assert!(f.latency_s >= 0.0);
        }
        assert!(stats.peak_active <= sched.max_batch);
        outputs.push(
            stats
                .finished
                .iter()
                .map(|f| (f.id, (f.tokens.clone(), f.nll)))
                .collect(),
        );
    }
    assert!(
        outputs[0].values().any(|(_, nll)| nll.is_some()),
        "trace should include scoring requests"
    );
    assert_eq!(outputs[0], outputs[1], "tokens + NLLs must agree dense vs sparse");
}

/// The online multi-worker engine must retire every request exactly once
/// with per-request outputs identical to the offline single-threaded
/// replay, at any worker count: which worker (and which batch) serves a
/// request is racy, but greedy decode depends only on the model and the
/// request's own prompt/KV cache, so sharding cannot change outputs.
#[test]
fn sharded_online_matches_single_worker_and_offline_replay() {
    let (_engine, cfg, params) = pruned_setup();
    let tcfg = TraceConfig {
        n_requests: 12,
        rate: 500.0,
        prompt_min: 4,
        prompt_max: 12,
        gen_min: 2,
        gen_max: 6,
        score_fraction: 0.25,
        burst: 3,
        seed: 123,
        ..TraceConfig::default()
    };
    let sched = SchedulerConfig { token_budget: 64, max_batch: 3 };
    let requests = poisson_trace(&tcfg);
    let max_pos = tcfg.max_request_tokens();

    // offline single-threaded replay is the reference
    let ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
        max_pos,
    );
    let offline = run_trace(&ctx, None, requests.clone(), &sched, &KvSpec::contig()).unwrap();
    let reference: BTreeMap<usize, (Vec<i32>, Option<f64>)> = offline
        .finished
        .iter()
        .map(|f| (f.id, (f.tokens.clone(), f.nll)))
        .collect();
    assert_eq!(reference.len(), tcfg.n_requests);

    for workers in [1usize, 3] {
        let ctxs: Vec<ServeContext> = (0..workers)
            .map(|_| {
                ServeContext::new(
                    PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
                    max_pos,
                )
            })
            .collect();
        let ocfg = OnlineConfig {
            workers,
            sched: sched.clone(),
            pacing: Pacing::Replay { time_scale: 0.0 },
            ..OnlineConfig::default()
        };
        let stats = serve_online(&ctxs, requests.clone(), &ocfg).unwrap();
        assert_eq!(stats.finished.len(), tcfg.n_requests, "{workers} workers: all retire");
        let mut seen = std::collections::BTreeSet::new();
        for f in &stats.finished {
            assert!(seen.insert(f.id), "request {} retired twice", f.id);
            assert!(f.worker < workers);
            assert!(f.latency_s >= f.queue_wait_s && f.queue_wait_s >= 0.0);
        }
        let got: BTreeMap<usize, (Vec<i32>, Option<f64>)> = stats
            .finished
            .iter()
            .map(|f| (f.id, (f.tokens.clone(), f.nll)))
            .collect();
        assert_eq!(got, reference, "{workers} workers vs offline replay: bitwise identical");
    }
}

/// The queue policy reorders *service*, never outputs: with QoS fields in
/// the trace (deadlines, priority tiers, clients) but deadlines far too
/// loose to shed, FIFO, priority and EDF must retire every request with
/// identical per-request tokens and NLLs.
#[test]
fn queue_policies_preserve_per_request_outputs() {
    let (_engine, cfg, params) = pruned_setup();
    let tcfg = TraceConfig {
        n_requests: 12,
        rate: 500.0,
        prompt_min: 4,
        prompt_max: 12,
        gen_min: 2,
        gen_max: 6,
        score_fraction: 0.25,
        burst: 3,
        seed: 321,
        deadline_min_s: 10.0,
        deadline_max_s: 30.0,
        priority_tiers: 3,
        clients: 2,
        shared_prefix_len: 0,
    };
    let sched = SchedulerConfig { token_budget: 64, max_batch: 3 };
    let requests = poisson_trace(&tcfg);
    let max_pos = tcfg.max_request_tokens();
    let ctxs: Vec<ServeContext> = (0..2)
        .map(|_| {
            ServeContext::new(
                PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
                max_pos,
            )
        })
        .collect();
    let mut outputs: Vec<BTreeMap<usize, (Vec<i32>, Option<f64>)>> = Vec::new();
    for policy in Policy::ALL {
        let ocfg = OnlineConfig {
            workers: 2,
            sched: sched.clone(),
            pacing: Pacing::Replay { time_scale: 0.0 },
            policy,
            ..OnlineConfig::default()
        };
        let stats = serve_online(&ctxs, requests.clone(), &ocfg).unwrap();
        assert_eq!(stats.finished.len(), tcfg.n_requests, "{}: all retire", policy.name());
        assert!(stats.shed.is_empty(), "{}: loose deadlines never shed", policy.name());
        assert!(stats.rejected.is_empty());
        outputs.push(
            stats
                .finished
                .iter()
                .map(|f| (f.id, (f.tokens.clone(), f.nll)))
                .collect(),
        );
    }
    assert_eq!(outputs[0], outputs[1], "fifo vs priority outputs");
    assert_eq!(outputs[0], outputs[2], "fifo vs edf outputs");
}

/// The tentpole parity pin: serving over loopback TCP through the line
/// protocol reproduces the offline single-threaded replay token for
/// token, with scoring NLLs bit-exact across the JSON wire (the number
/// formatter prints the shortest representation that round-trips).
#[test]
fn loopback_tcp_matches_offline_replay() {
    let (_engine, cfg, params) = pruned_setup();
    let tcfg = TraceConfig {
        n_requests: 8,
        rate: 500.0,
        prompt_min: 4,
        prompt_max: 12,
        gen_min: 2,
        gen_max: 6,
        score_fraction: 0.25,
        burst: 1,
        seed: 77,
        ..TraceConfig::default()
    };
    let sched = SchedulerConfig { token_budget: 64, max_batch: 3 };
    let requests = poisson_trace(&tcfg);
    let max_pos = tcfg.max_request_tokens();

    let ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
        max_pos,
    );
    let offline = run_trace(&ctx, None, requests.clone(), &sched, &KvSpec::contig()).unwrap();
    let reference: BTreeMap<usize, (Vec<i32>, Option<f64>)> = offline
        .finished
        .iter()
        .map(|f| (f.id, (f.tokens.clone(), f.nll)))
        .collect();
    assert_eq!(reference.len(), requests.len());
    assert!(reference.values().any(|(_, nll)| nll.is_some()), "trace includes scoring");

    let server_ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
        max_pos,
    );
    let ncfg = NetConfig { workers: 1, sched: sched.clone(), ..NetConfig::default() };
    let server = NetServer::start(vec![server_ctx], ncfg, None).unwrap();
    let mut client = LineClient::connect(&server.addr()).unwrap();
    for req in &requests {
        let events = client.request(&request_line(req.id as u64, req)).unwrap();
        let (want_tokens, want_nll) = &reference[&req.id];
        match events.last().unwrap() {
            WireEvent::Done { id, tokens, nll, deadline_met, degraded } => {
                assert_eq!(*id, req.id as u64);
                assert!(*deadline_met, "no deadlines in this trace");
                assert!(!*degraded, "no degrade tier in this run");
                assert_eq!(tokens, want_tokens, "request {} tokens over TCP", req.id);
                assert_eq!(*nll, *want_nll, "request {} NLL bit-exact over the wire", req.id);
            }
            other => panic!("request {} got terminal {other:?}", req.id),
        }
        // the streamed token events must equal the final record, in order
        let streamed: Vec<i32> = events
            .iter()
            .filter_map(|e| match e {
                WireEvent::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(&streamed, want_tokens, "request {} streamed tokens", req.id);
    }
    drop(client); // close the connection so the drain barrier clears
    let stats = server.shutdown().unwrap();
    assert!(stats.drained_clean, "loopback client closed before the drain deadline");
    assert!(stats.accounted(), "queued == finished + shed");
    assert_eq!(stats.finished.len(), requests.len());
    assert_eq!(stats.parse_errors, 0);
    assert_eq!(stats.rejected_rate, 0);
}

/// The paged-allocator parity pin: prefill hidden states, the per-block
/// KV rows themselves, and greedy decode tokens must be **bitwise**
/// identical between the contiguous slab and the paged table, at page
/// sizes 1 (every row its own page), 3 and 5 (neither divides the
/// 13-token prompt, so the last page is partial) and 16 (prompt +
/// decode fit one page). Parity is by construction — both backings run
/// the same kernels over ascending-position row runs — and this test
/// keeps it that way.
#[test]
fn paged_matches_contiguous_bitwise_across_page_sizes() {
    let (_engine, cfg, params) = pruned_setup();
    let n = 6;
    let prompt: Vec<i32> = (0..13).map(|i| (i * 5 % cfg.vocab) as i32).collect();
    let max_pos = prompt.len() + n + 1;
    let ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
        max_pos,
    );
    let mut contig = ctx.new_cache();
    let h_ref = prefill(&ctx, &prompt, &mut contig);
    let tok_ref = greedy_cached(&ctx, &prompt, n);
    assert_eq!(tok_ref.len(), n);
    let d = cfg.d_model;
    let cref = contig.as_contig().unwrap();
    for page_tokens in [1usize, 3, 5, 16] {
        let spec =
            KvSpec::for_mode(KvMode::Paged { page_tokens, max_pages: 0 }, cfg.n_blocks, cfg.d_model);
        let mut kv = ctx.new_kv(&spec, max_pos).unwrap();
        let h = prefill(&ctx, &prompt, &mut kv);
        assert_eq!(h, h_ref, "page={page_tokens}: prefill hidden bitwise");
        assert_eq!(kv.len(), contig.len());
        for l in 0..cfg.n_blocks {
            let mut k = vec![0.0f32; kv.len() * d];
            let mut v = vec![0.0f32; kv.len() * d];
            kv.gather_block_into(l, &mut k, &mut v);
            assert_eq!(&k[..], cref.k_block(l), "page={page_tokens} block {l} keys");
            assert_eq!(&v[..], cref.v_block(l), "page={page_tokens} block {l} values");
        }
        let mut kv2 = ctx.new_kv(&spec, max_pos).unwrap();
        let toks = greedy_with_cache(&ctx, &prompt, n, &mut kv2);
        assert_eq!(toks, tok_ref, "page={page_tokens}: greedy decode token-for-token");
    }
}

/// COW prefix sharing: continuing a prefill over a *forked* prefix (at a
/// page-aligned split and at a mid-page split that forces a
/// copy-on-write boundary clone) reproduces the full prefill's final
/// hidden row and KV rows bitwise, and never mutates the parent table.
#[test]
fn forked_prefix_prefill_continue_matches_full_prefill() {
    let (_engine, cfg, params) = pruned_setup();
    let prompt: Vec<i32> = (0..11).map(|i| (i * 7 % cfg.vocab) as i32).collect();
    let s = prompt.len();
    let max_pos = s + 1;
    let ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
        max_pos,
    );
    let d = cfg.d_model;
    let spec = KvSpec::for_mode(
        KvMode::Paged { page_tokens: 4, max_pages: 0 },
        cfg.n_blocks,
        cfg.d_model,
    );
    let mut parent = ctx.new_kv(&spec, max_pos).unwrap();
    let h_full = prefill(&ctx, &prompt, &mut parent);
    let h_last = &h_full[(s - 1) * d..s * d];
    let snapshot: Vec<(Vec<f32>, Vec<f32>)> = (0..cfg.n_blocks)
        .map(|l| {
            let mut k = vec![0.0f32; s * d];
            let mut v = vec![0.0f32; s * d];
            parent.gather_block_into(l, &mut k, &mut v);
            (k, v)
        })
        .collect();

    // p0 = 8 is page-aligned (shares two full pages); p0 = 6 splits page
    // 1 mid-way, so the child's first write COW-clones that page
    for p0 in [8usize, 6] {
        let cow_before = spec.pool().unwrap().stats().cow_clones;
        let table = parent.as_paged().unwrap().fork(p0, max_pos).unwrap();
        let mut child = Kv::Paged(table);
        assert_eq!(child.len(), p0);
        let mut scratch = DecodeScratch::new();
        let h = prefill_continue(&ctx, &prompt, &mut child, &mut scratch);
        assert_eq!(&h[..], h_last, "p0={p0}: final hidden row bitwise");
        assert_eq!(child.len(), s);
        for l in 0..cfg.n_blocks {
            let mut k = vec![0.0f32; s * d];
            let mut v = vec![0.0f32; s * d];
            child.gather_block_into(l, &mut k, &mut v);
            assert_eq!(k, snapshot[l].0, "p0={p0} block {l}: child keys == full prefill");
            assert_eq!(v, snapshot[l].1, "p0={p0} block {l}: child values == full prefill");
        }
        // the parent's rows are untouched (COW isolated the child)
        for l in 0..cfg.n_blocks {
            let mut k = vec![0.0f32; s * d];
            let mut v = vec![0.0f32; s * d];
            parent.gather_block_into(l, &mut k, &mut v);
            assert_eq!(k, snapshot[l].0, "p0={p0} block {l}: parent keys unchanged");
            assert_eq!(v, snapshot[l].1, "p0={p0} block {l}: parent values unchanged");
        }
        let cow_after = spec.pool().unwrap().stats().cow_clones;
        if p0 % 4 == 0 {
            assert_eq!(cow_after, cow_before, "aligned fork never COW-clones");
        } else {
            assert!(cow_after > cow_before, "mid-page fork must COW the boundary page");
        }
    }
}

/// Work stealing is a page-table *move*, not a recompute: decoding k
/// steps on one worker replica, migrating the table, and finishing on a
/// different replica yields the pinned single-worker token sequence
/// exactly, at a page size that forces mid-decode page boundaries.
#[test]
fn stolen_mid_decode_matches_pinned_decode() {
    let (_engine, cfg, params) = pruned_setup();
    let n = 8;
    let prompt: Vec<i32> = (0..9).map(|i| (i * 11 % cfg.vocab) as i32).collect();
    let max_pos = prompt.len() + n + 1;
    let mk = || {
        ServeContext::new(
            PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
            max_pos,
        )
    };
    let (ctx_a, ctx_b) = (mk(), mk());
    let reference = greedy_cached(&ctx_a, &prompt, n);
    assert_eq!(reference.len(), n);

    let spec =
        KvSpec::for_mode(KvMode::Paged { page_tokens: 3, max_pages: 0 }, cfg.n_blocks, cfg.d_model);
    let mut kv = ctx_a.new_kv(&spec, max_pos).unwrap();
    prefill(&ctx_a, &prompt, &mut kv);
    let mut scratch = DecodeScratch::new();
    let mut prev = reference[0];
    for (i, want) in reference.iter().enumerate().skip(1) {
        // steal after 3 decode steps: the table moves, the context changes
        let ctx = if i <= 3 { &ctx_a } else { &ctx_b };
        let last = [prev];
        let mut caches = [&mut kv];
        let got = decode_step(ctx, &last, &mut caches, &mut scratch)[0];
        assert_eq!(got, *want, "stolen decode diverged at step {i}");
        prev = got;
    }
}

/// The online engine with the paged allocator, decode work stealing and
/// prefix sharing all enabled retires every request with outputs
/// identical to the contiguous offline single-threaded replay — the
/// end-to-end pin that none of the allocator machinery (paging, COW
/// forks, page-table migration) leaks into the math.
#[test]
fn online_paged_with_stealing_matches_contig_offline() {
    let (_engine, cfg, params) = pruned_setup();
    let tcfg = TraceConfig {
        n_requests: 12,
        rate: 500.0,
        prompt_min: 4,
        prompt_max: 12,
        gen_min: 2,
        gen_max: 8,
        score_fraction: 0.25,
        burst: 3,
        seed: 4242,
        shared_prefix_len: 6,
        ..TraceConfig::default()
    };
    let sched = SchedulerConfig { token_budget: 64, max_batch: 3 };
    let requests = poisson_trace(&tcfg);
    let max_pos = tcfg.max_request_tokens();

    let ctx = ServeContext::new(
        PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
        max_pos,
    );
    let offline = run_trace(&ctx, None, requests.clone(), &sched, &KvSpec::contig()).unwrap();
    let reference: BTreeMap<usize, (Vec<i32>, Option<f64>)> = offline
        .finished
        .iter()
        .map(|f| (f.id, (f.tokens.clone(), f.nll)))
        .collect();
    assert_eq!(reference.len(), tcfg.n_requests);

    for page_tokens in [3usize, 16] {
        let ctxs: Vec<ServeContext> = (0..2)
            .map(|_| {
                ServeContext::new(
                    PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
                    max_pos,
                )
            })
            .collect();
        let ocfg = OnlineConfig {
            workers: 2,
            sched: sched.clone(),
            pacing: Pacing::Replay { time_scale: 0.0 },
            kv: KvMode::Paged { page_tokens, max_pages: 0 },
            steal: true,
            share_prefix: true,
            ..OnlineConfig::default()
        };
        let stats = serve_online(&ctxs, requests.clone(), &ocfg).unwrap();
        assert_eq!(stats.finished.len(), tcfg.n_requests, "page={page_tokens}: all retire");
        let got: BTreeMap<usize, (Vec<i32>, Option<f64>)> = stats
            .finished
            .iter()
            .map(|f| (f.id, (f.tokens.clone(), f.nll)))
            .collect();
        assert_eq!(got, reference, "page={page_tokens}: paged+steal+share == contig offline");
    }
}
