//! Integration tests over the Engine facade + native backend (config
//! `test`). Fully hermetic: no artifacts, no XLA — `cargo test -q` passes
//! on a bare machine. The python->HLO->rust contract is exercised by the
//! same suite when built with `--features pjrt` and `BESA_BACKEND=pjrt`
//! after `make artifacts`.

use besa::coordinator::{BlockCtx, BlockPruner, Pipeline};
use besa::data::batcher::CalibrationSet;
use besa::data::Domain;
use besa::model::{ParamStore, LAYER_NAMES};
use besa::prune::besa::{two_block_prune, BesaConfig, BesaPruner, Granularity};
use besa::prune::magnitude::MagnitudePruner;
use besa::prune::sparsegpt::SparseGptPruner;
use besa::prune::wanda::WandaPruner;
use besa::prune::{BlockMasks, BlockReport, Method};
use besa::runtime::Engine;
use besa::tensor::Tensor;
use besa::util::rng::Rng;

fn engine() -> Engine {
    Engine::native("test").expect("built-in test config")
}

fn random_x(rng: &mut Rng, cfg: &besa::model::ModelConfig) -> Tensor {
    let n = cfg.batch * cfg.seq_len * cfg.d_model;
    Tensor::from_f32(
        &[cfg.batch, cfg.seq_len, cfg.d_model],
        (0..n).map(|_| rng.normal_f32() * 0.5).collect(),
    )
}

#[test]
fn engine_runs_block_fwd() {
    let e = &engine();
    let cfg = e.config().clone();
    let params = ParamStore::init(&cfg, 7);
    let mut rng = Rng::seed(1);
    let x = random_x(&mut rng, &cfg);
    let mut ins: Vec<&Tensor> = vec![&x];
    for w in LAYER_NAMES {
        ins.push(params.get(&ParamStore::layer_name(0, w)).unwrap());
    }
    ins.push(params.get("blocks.0.norm1").unwrap());
    ins.push(params.get("blocks.0.norm2").unwrap());
    let out = e.run("block_fwd", &ins).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, x.shape);
    assert!(out[0].f32s().iter().all(|v| v.is_finite()));
}

#[test]
fn masked_fwd_with_ones_equals_dense() {
    let e = &engine();
    let cfg = e.config().clone();
    let params = ParamStore::init(&cfg, 9);
    let mut rng = Rng::seed(2);
    let x = random_x(&mut rng, &cfg);
    let weights: Vec<&Tensor> =
        LAYER_NAMES.iter().map(|w| params.get(&ParamStore::layer_name(0, w)).unwrap()).collect();
    let n1 = params.get("blocks.0.norm1").unwrap();
    let n2 = params.get("blocks.0.norm2").unwrap();

    let mut ins: Vec<&Tensor> = vec![&x];
    ins.extend(&weights);
    ins.push(n1);
    ins.push(n2);
    let dense = e.run("block_fwd", &ins).unwrap();

    let ones: Vec<Tensor> = LAYER_NAMES
        .iter()
        .map(|w| {
            let s = cfg.layer_shape(w);
            Tensor::ones(&[s[0], s[1]])
        })
        .collect();
    let mut ins2: Vec<&Tensor> = vec![&x];
    ins2.extend(&weights);
    ins2.push(n1);
    ins2.push(n2);
    ins2.extend(ones.iter());
    let masked = e.run("block_fwd_masked", &ins2).unwrap();

    for (a, b) in dense[0].f32s().iter().zip(masked[0].f32s()) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn pretraining_reduces_loss() {
    let e = &engine();
    let cfg = e.config().clone();
    let mut params = ParamStore::init(&cfg, 11);
    let tc = besa::coordinator::trainer::TrainConfig {
        steps: 30,
        lr: 3e-3,
        seed: 5,
        log_every: 1000,
    };
    let stats = besa::coordinator::trainer::pretrain(e, &mut params, &tc).unwrap();
    let first = besa::util::mean(&stats.losses[..5]);
    let last = besa::util::mean(&stats.losses[stats.losses.len() - 5..]);
    assert!(last < first - 0.1, "loss should drop: {first:.3} -> {last:.3}");
}

#[test]
fn wanda_pipeline_hits_target_sparsity() {
    let e = &engine();
    let cfg = e.config().clone();
    let mut params = ParamStore::init(&cfg, 13);
    let calib = CalibrationSet::sample(&cfg, 2 * cfg.batch, 17);
    let pipeline = Pipeline::new(e, calib.batches);
    let mut pruner = WandaPruner { sparsity: 0.5 };
    let run = pipeline.run(&mut params, &mut pruner).unwrap();
    let s = params.prunable_sparsity(cfg.n_blocks);
    assert!((s - 0.5).abs() < 0.02, "sparsity {s}");
    assert_eq!(run.reports.len(), cfg.n_blocks);
    assert_eq!(run.block_errors.len(), cfg.n_blocks);
    assert!(run.block_errors.iter().all(|e| *e > 0.0));
}

#[test]
fn besa_pipeline_allocates_nonuniform_sparsity_near_target() {
    let e = &engine();
    let cfg = e.config().clone();
    let mut params = ParamStore::init(&cfg, 19);
    let calib = CalibrationSet::sample(&cfg, 2 * cfg.batch, 23);
    let pipeline = Pipeline::new(e, calib.batches);
    let mut pruner = BesaPruner::new(BesaConfig {
        sparsity: 0.5,
        epochs: 12,
        ..Default::default()
    });
    let run = pipeline.run(&mut params, &mut pruner).unwrap();
    let s = params.prunable_sparsity(cfg.n_blocks);
    assert!((s - 0.5).abs() < 0.08, "global sparsity {s} should approach 0.5");
    // layer sparsities should differ (the whole point of BESA)
    let spread: Vec<f64> = run.reports[0].layer_sparsity.values().cloned().collect();
    let min = spread.iter().cloned().fold(1.0, f64::min);
    let max = spread.iter().cloned().fold(0.0, f64::max);
    assert!(max - min > 1e-3, "expected non-uniform allocation, got {spread:?}");
}

/// Dense "pruner": all-ones masks, exercising Method::Dense through the
/// same Pipeline::run path as the real pruners.
struct DensePruner;

impl BlockPruner for DensePruner {
    fn name(&self) -> &str {
        "dense"
    }
    fn prune_block(&mut self, ctx: &mut BlockCtx) -> Result<(BlockMasks, BlockReport), anyhow::Error> {
        let mut masks = BlockMasks::new();
        let mut report = BlockReport::default();
        for w in LAYER_NAMES {
            let s = ctx.cfg.layer_shape(w);
            masks.insert(w.to_string(), Tensor::ones(&[s[0], s[1]]));
            report.layer_sparsity.insert(w.to_string(), 0.0);
        }
        Ok((masks, report))
    }
}

/// End-to-end: every Method through Pipeline::run on the native backend,
/// then perplexity on the pruned model — the hermetic Table-1 loop.
#[test]
fn all_five_methods_end_to_end() {
    let e = &engine();
    let cfg = e.config().clone();
    let dense = ParamStore::init(&cfg, 29);
    let calib = CalibrationSet::sample(&cfg, cfg.batch, 31);
    for method in [
        Method::Dense,
        Method::Magnitude,
        Method::Wanda,
        Method::SparseGpt,
        Method::Besa,
    ] {
        let mut pruner: Box<dyn BlockPruner> = match method {
            Method::Dense => Box::new(DensePruner),
            Method::Magnitude => Box::new(MagnitudePruner { sparsity: 0.5 }),
            Method::Wanda => Box::new(WandaPruner { sparsity: 0.5 }),
            Method::SparseGpt => Box::new(SparseGptPruner { sparsity: 0.5, ..Default::default() }),
            Method::Besa => {
                Box::new(BesaPruner::new(BesaConfig { epochs: 4, ..Default::default() }))
            }
        };
        let mut p = dense.clone();
        let run = Pipeline::new(e, calib.batches.clone()).run(&mut p, pruner.as_mut()).unwrap();
        assert_eq!(run.reports.len(), cfg.n_blocks, "{}", method.name());
        let s = p.prunable_sparsity(cfg.n_blocks);
        match method {
            Method::Dense => assert_eq!(
                run.reports[0].mean_sparsity(&cfg),
                0.0,
                "dense must not prune"
            ),
            Method::Besa => assert!((s - 0.5).abs() < 0.12, "besa sparsity {s}"),
            _ => assert!((s - 0.5).abs() < 0.02, "{} sparsity {s}", method.name()),
        }
        let ppl = besa::eval::perplexity(e, &p, Domain::WikiSyn, 1, 7).unwrap();
        assert!(ppl.is_finite() && ppl > 1.0, "{}: ppl {ppl}", method.name());
    }
}

#[test]
fn besa_granularity_variants_run() {
    let e = &engine();
    let cfg = e.config().clone();
    let dense = ParamStore::init(&cfg, 43);
    let calib = CalibrationSet::sample(&cfg, cfg.batch, 47);

    // attn-mlp grouping
    let mut p = dense.clone();
    let mut pruner = BesaPruner::new(BesaConfig {
        epochs: 3,
        granularity: Granularity::AttnMlp,
        ..Default::default()
    });
    Pipeline::new(e, calib.batches.clone()).run(&mut p, &mut pruner).unwrap();
    assert!(p.prunable_sparsity(cfg.n_blocks) > 0.2);

    // layer-wise thetas
    let mut p = dense.clone();
    let mut pruner = BesaPruner::new(BesaConfig { epochs: 3, row_wise: false, ..Default::default() });
    Pipeline::new(e, calib.batches.clone()).run(&mut p, &mut pruner).unwrap();
    assert!(p.prunable_sparsity(cfg.n_blocks) > 0.2);

    // joint quantization
    let mut p = dense.clone();
    let mut pruner = BesaPruner::new(BesaConfig { epochs: 3, quant: true, ..Default::default() });
    Pipeline::new(e, calib.batches.clone()).run(&mut p, &mut pruner).unwrap();
    assert!(p.prunable_sparsity(cfg.n_blocks) > 0.2);

    // two-block granularity (standalone driver)
    let mut p = dense.clone();
    let bc = BesaConfig { epochs: 3, ..Default::default() };
    let (reports, errs) = two_block_prune(e, &mut p, &calib.batches, &bc).unwrap();
    assert_eq!(reports.len(), cfg.n_blocks);
    assert_eq!(errs.len(), cfg.n_blocks / 2);
    assert!(p.prunable_sparsity(cfg.n_blocks) > 0.2);
}

#[test]
fn eval_and_probes_run_on_pruned_model() {
    let e = &engine();
    let cfg = e.config().clone();
    let mut params = ParamStore::init(&cfg, 29);
    let calib = CalibrationSet::sample(&cfg, cfg.batch, 31);
    let pipeline = Pipeline::new(e, calib.batches);
    let mut pruner = WandaPruner { sparsity: 0.5 };
    pipeline.run(&mut params, &mut pruner).unwrap();
    let ppl = besa::eval::perplexity(e, &params, Domain::WikiSyn, 2, 7).unwrap();
    assert!(ppl.is_finite() && ppl > 1.0);
    let probes = besa::eval::probes::run_all(e, &params, 6, 3).unwrap();
    assert_eq!(probes.len(), 7); // 6 tasks + average
    for p in &probes {
        assert!((0.0..=1.0).contains(&p.accuracy));
    }
}

#[test]
fn engine_rejects_bad_inputs() {
    let e = &engine();
    let cfg = e.config().clone();
    // wrong arity
    let x = Tensor::zeros(&[cfg.batch, cfg.seq_len, cfg.d_model]);
    assert!(e.run("block_fwd", &[&x]).is_err());
    // wrong shape
    let params = ParamStore::init(&cfg, 1);
    let bad = Tensor::zeros(&[1, 2, 3]);
    let mut ins: Vec<&Tensor> = vec![&bad];
    for w in LAYER_NAMES {
        ins.push(params.get(&ParamStore::layer_name(0, w)).unwrap());
    }
    ins.push(params.get("blocks.0.norm1").unwrap());
    ins.push(params.get("blocks.0.norm2").unwrap());
    assert!(e.run("block_fwd", &ins).is_err());
    // unknown artifact
    assert!(e.run("nonexistent", &[]).is_err());
    // wrong dtype
    let xi = Tensor::from_i32(
        &[cfg.batch, cfg.seq_len, cfg.d_model],
        vec![0; cfg.batch * cfg.seq_len * cfg.d_model],
    );
    let mut ins2: Vec<&Tensor> = vec![&xi];
    for w in LAYER_NAMES {
        ins2.push(params.get(&ParamStore::layer_name(0, w)).unwrap());
    }
    ins2.push(params.get("blocks.0.norm1").unwrap());
    ins2.push(params.get("blocks.0.norm2").unwrap());
    assert!(e.run("block_fwd", &ins2).is_err());
}

#[test]
fn besa_step_sparsity_converges_toward_target() {
    // drive the raw artifact op directly: mean_alpha must move toward 0.7
    let e = &engine();
    let cfg = e.config().clone();
    let params = ParamStore::init(&cfg, 37);
    let mut rng = Rng::seed(38);
    let x = random_x(&mut rng, &cfg);

    let weights: Vec<Tensor> = LAYER_NAMES
        .iter()
        .map(|w| params.get(&ParamStore::layer_name(0, w)).unwrap().clone())
        .collect();
    let n1 = params.get("blocks.0.norm1").unwrap().clone();
    let n2 = params.get("blocks.0.norm2").unwrap().clone();
    let mut ins0: Vec<&Tensor> = vec![&x];
    ins0.extend(weights.iter());
    ins0.push(&n1);
    ins0.push(&n2);
    let y = e.run("block_fwd", &ins0).unwrap().into_iter().next().unwrap();

    let ranks: Vec<Tensor> = LAYER_NAMES
        .iter()
        .map(|w| {
            let s = cfg.layer_shape(w);
            let rows: Vec<i32> = (0..s[0])
                .flat_map(|_| rng.permutation(s[1]).into_iter().map(|v| v as i32))
                .collect();
            Tensor::from_i32(&[s[0], s[1]], rows)
        })
        .collect();
    let mut thetas: Vec<Tensor> = LAYER_NAMES
        .iter()
        .map(|w| Tensor::zeros(&[cfg.layer_shape(w)[0], cfg.n_rates - 1]))
        .collect();
    let lam = Tensor::scalar(20.0);
    let ah = Tensor::scalar(0.7);
    let mut adam = besa::prune::adam::Adam::new(
        besa::prune::adam::AdamConfig { lr: 0.05, ..Default::default() },
        7,
    );
    let mut first_alpha = None;
    let mut alpha = 0.0;
    for _ in 0..20 {
        let out = {
            let mut ins: Vec<&Tensor> = thetas.iter().collect();
            ins.push(&x);
            ins.push(&y);
            ins.extend(weights.iter());
            ins.push(&n1);
            ins.push(&n2);
            ins.extend(ranks.iter());
            ins.push(&lam);
            ins.push(&ah);
            e.run("besa_step_row", &ins).unwrap()
        };
        alpha = out[2].scalar_value() as f64;
        first_alpha.get_or_insert(alpha);
        let grads: Vec<&Tensor> = out[3..10].iter().collect();
        let mut ps: Vec<&mut Tensor> = thetas.iter_mut().collect();
        adam.step(&mut ps, &grads);
    }
    let first = first_alpha.unwrap();
    assert!(
        (alpha - 0.7).abs() < (first - 0.7).abs(),
        "alpha {first:.3} -> {alpha:.3} should approach 0.7"
    );
}
