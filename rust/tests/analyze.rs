//! Static-analysis suite: the shipped source tree must pass its own
//! lint pass, and the engine must reject dynamically mis-shaped
//! pipelines at call time (the load-time manifest check is exercised by
//! unit tests in `src/analyze/graph.rs`).

use besa::analyze::analyze_repo;
use besa::model::{ModelConfig, LAYER_NAMES};
use besa::runtime::Engine;
use besa::tensor::Tensor;

/// `besa analyze` on this repository's own sources reports nothing:
/// every hot-path panic is either converted to `Result` or carries a
/// justified `besa-lint: allow`, no deterministic module uses wall-clock
/// or hash-order iteration, and no lock pair is ever acquired in both
/// orders.
#[test]
fn repo_sources_pass_all_lints_and_graph_checks() {
    let src = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let configs = ["test".to_string(), "sm".to_string()];
    let report = analyze_repo(&src, &configs).unwrap();
    assert!(report.files_scanned > 20, "walked only {} files", report.files_scanned);
    let rendered: Vec<String> = report.findings.iter().map(|d| d.render()).collect();
    assert!(report.clean(), "analyze found issues:\n{}", rendered.join("\n"));
}

fn zeros(shape: &[usize]) -> Tensor {
    Tensor::from_f32(shape, vec![0.0; shape.iter().product()])
}

/// Build a full `block_fwd_cached` input list for batch `nb`, cache
/// capacity `cap`, and a given `pos` vector; returns owned tensors.
fn cached_inputs(cfg: &ModelConfig, nb: usize, cap: usize, pos: Vec<i32>) -> Vec<Tensor> {
    let d = cfg.d_model;
    let mut ins = vec![
        zeros(&[nb, 1, d]),
        zeros(&[nb, cap, d]),
        zeros(&[nb, cap, d]),
        Tensor::from_i32(&[pos.len()], pos),
    ];
    for w in LAYER_NAMES.iter() {
        ins.push(zeros(&cfg.layer_shape(w)));
    }
    ins.push(zeros(&[d])); // norm1
    ins.push(zeros(&[d])); // norm2
    ins
}

/// The runtime's call-time validation binds every axis-0 wildcard to one
/// request batch and unifies wildcard dims across same-spec inputs, so a
/// decode call whose `pos` batch disagrees with `x` — or whose k/v cache
/// capacities disagree with each other — is rejected before dispatch.
#[test]
fn engine_rejects_dynamic_batch_and_capacity_mismatches() {
    let engine = Engine::native("test").unwrap();
    let cfg = engine.config().clone();

    // well-formed: batch 2, capacity 4, positions 0
    let good = cached_inputs(&cfg, 2, 4, vec![0, 0]);
    let refs: Vec<&Tensor> = good.iter().collect();
    let out = engine.run("block_fwd_cached", &refs).unwrap();
    assert_eq!(out.len(), 3);

    // pos carries 3 entries while x carries batch 2
    let bad_batch = cached_inputs(&cfg, 2, 4, vec![0, 0, 0]);
    let refs: Vec<&Tensor> = bad_batch.iter().collect();
    let err = engine.run("block_fwd_cached", &refs).unwrap_err().to_string();
    assert!(err.contains("dynamic"), "unexpected error: {err}");

    // k_cache capacity 4 vs v_cache capacity 5 (same wildcard spec)
    let mut bad_cap = cached_inputs(&cfg, 2, 4, vec![0, 0]);
    bad_cap[2] = zeros(&[2, 5, cfg.d_model]);
    let refs: Vec<&Tensor> = bad_cap.iter().collect();
    let err = engine.run("block_fwd_cached", &refs).unwrap_err().to_string();
    assert!(err.contains("dynamic"), "unexpected error: {err}");
}
