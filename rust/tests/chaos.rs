//! Chaos suite for the fault-tolerance plane (hermetic, `test` config):
//! deterministic fault schedules — worker panics mid-prefill and
//! mid-decode, slow-worker stalls, admission denials — crossed with
//! queue policies and KV backings, checking the headline invariants:
//!
//! * accounting: every queued request ends in exactly one of
//!   `finished` / `shed` / `rejected` / `failed`, with disjoint ids;
//! * replay determinism: a request that survives any number of
//!   supervised restarts produces the same tokens as a fault-free run
//!   (replay is from scratch — never splice, never emit a token twice);
//! * pool drain: in paged mode the page pool ends at `live == 0` with
//!   `live + free == created` (checked by a hard bail inside
//!   `serve_online_tiered`, so `Ok(_)` is itself the assertion);
//! * zero-overhead disabled path: a plan whose triggers never fire is
//!   bitwise identical to `faults: None`;
//! * sparsity-tiered degradation: requests routed to the degrade tier
//!   under pressure are bit-exact against a run served entirely by that
//!   tier, and never mix with primary-tier outputs.

use std::collections::BTreeMap;
use std::sync::Arc;

use besa::model::{ModelConfig, ParamStore};
use besa::serve::bench::magnitude_prune_in_place;
use besa::serve::engine::ServeContext;
use besa::serve::model::{PackedModel, WeightFormat};
use besa::serve::{
    serve_online, serve_online_tiered, FaultPlan, KvMode, OnlineConfig, OnlineStats, Pacing,
    Policy, Qos, ReqKind, Request, SchedulerConfig,
};

const MAX_POS: usize = 64;

/// `workers` CSR replicas over a magnitude-pruned test model at
/// `sparsity`.
fn contexts(workers: usize, sparsity: f64) -> Vec<ServeContext> {
    let cfg = ModelConfig::builtin("test").expect("built-in test config");
    let mut params = ParamStore::init(&cfg, 42);
    magnitude_prune_in_place(&mut params, &cfg, sparsity).unwrap();
    (0..workers)
        .map(|_| {
            ServeContext::new(
                PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
                MAX_POS,
            )
        })
        .collect()
}

/// A small deterministic request mix: generation and scoring, varied
/// prompt lengths, no deadlines (so nothing sheds and the finished set
/// is the whole admitted set).
fn requests(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i,
            arrival: 0.0,
            tokens: (0..(3 + i % 5)).map(|t| 1 + ((i * 7 + t) % 13) as i32).collect(),
            kind: if i % 4 == 3 {
                ReqKind::Score
            } else {
                ReqKind::Generate { max_new: 2 + i % 3 }
            },
            qos: Qos::default(),
        })
        .collect()
}

fn flood(workers: usize) -> OnlineConfig {
    OnlineConfig {
        workers,
        sched: SchedulerConfig { token_budget: 128, max_batch: 4 },
        pacing: Pacing::Replay { time_scale: 0.0 },
        ..OnlineConfig::default()
    }
}

/// Per-id terminal outputs of a run, for bitwise comparison.
fn outputs(stats: &OnlineStats) -> BTreeMap<usize, (Vec<i32>, Option<f64>)> {
    stats.finished.iter().map(|f| (f.id, (f.tokens.clone(), f.nll))).collect()
}

/// Every request must end in exactly one terminal set, ids disjoint.
fn assert_exactly_one_terminal(stats: &OnlineStats, n: usize) {
    let mut seen = std::collections::BTreeSet::new();
    for id in stats
        .finished
        .iter()
        .map(|f| f.id)
        .chain(stats.shed.iter().map(|s| s.id))
        .chain(stats.rejected.iter().map(|r| r.id))
        .chain(stats.failed.iter().map(|f| f.id))
    {
        assert!(seen.insert(id), "request {id} has two terminal outcomes");
    }
    assert_eq!(seen.len(), n, "every request ends in exactly one terminal set");
}

#[test]
fn never_firing_plan_is_bitwise_identical_to_disabled() {
    let ctxs = contexts(2, 0.5);
    let reqs = requests(12);
    let baseline = serve_online(&ctxs, reqs.clone(), &flood(2)).unwrap();

    // triggers far beyond anything the trace can reach: the harness is
    // armed but silent, and the run must be bitwise identical
    let plan = FaultPlan::parse("panic@prefill:1000000,stall@decode:1000000=5", 7).unwrap();
    let armed = OnlineConfig { faults: Some(Arc::new(plan)), ..flood(2) };
    let silent = serve_online_tiered(&ctxs, None, reqs.clone(), &armed, None).unwrap();

    assert_eq!(baseline.finished.len(), reqs.len());
    assert_eq!(outputs(&baseline), outputs(&silent), "armed-but-silent run must be bit-exact");
    assert_eq!(silent.restarts, 0);
    assert_eq!(silent.requeues, 0);
    assert!(silent.failed.is_empty());
    assert_eq!(silent.degraded(), 0);
}

/// The chaos matrix: fault schedules × queue policies × KV backings.
/// Survivors must reproduce the fault-free tokens bitwise; accounting
/// and (in paged mode) pool drain must hold under every schedule.
#[test]
fn fault_schedules_preserve_accounting_and_token_parity() {
    let ctxs = contexts(2, 0.5);
    let reqs = requests(16);
    let reference = outputs(&serve_online(&ctxs, reqs.clone(), &flood(2)).unwrap());

    let schedules = [
        "panic@prefill:2+5",
        "panic@decode:3+7",
        "stall@decode:2+9=5",
        "deny@admit%4",
        "panic@prefill:4+9,stall@decode:5+11=3,deny@admit%6",
    ];
    for policy in [Policy::Fifo, Policy::Edf] {
        for kv in [KvMode::Contig, KvMode::Paged { page_tokens: 4, max_pages: 0 }] {
            for spec in schedules {
                let plan = FaultPlan::parse(spec, 0xC4A05).unwrap();
                let ocfg = OnlineConfig {
                    policy,
                    kv,
                    faults: Some(Arc::new(plan)),
                    retry_budget: 8,
                    ..flood(2)
                };
                // Ok(_) already proves the internal hard checks passed:
                // accounting and, in paged mode, a fully drained pool
                let stats = serve_online_tiered(&ctxs, None, reqs.clone(), &ocfg, None)
                    .unwrap_or_else(|e| panic!("[{spec} / {policy:?} / {kv:?}] {e:#}"));
                assert_exactly_one_terminal(&stats, reqs.len());
                let label = format!("{spec} / {policy:?} / {kv:?}");
                if spec.contains("panic") {
                    assert!(stats.restarts > 0, "[{label}] panics must restart the worker");
                    assert!(stats.requeues > 0 || !stats.failed.is_empty(), "[{label}]");
                }
                for f in &stats.finished {
                    assert_eq!(
                        (&f.tokens, f.nll),
                        (&reference[&f.id].0, reference[&f.id].1),
                        "[{label}] request {} must replay to the fault-free output",
                        f.id
                    );
                }
            }
        }
    }
}

/// A retry budget of zero turns every mid-service worker death into a
/// terminal failure — and the accounting still balances.
#[test]
fn exhausted_retry_budget_fails_terminally() {
    let ctxs = contexts(1, 0.5);
    let reqs = requests(8);
    let plan = FaultPlan::parse("panic@prefill:3", 0).unwrap();
    let ocfg = OnlineConfig {
        // batch of 1: exactly one request is ever mid-service, so the
        // one panic dooms exactly one request
        sched: SchedulerConfig { token_budget: 128, max_batch: 1 },
        faults: Some(Arc::new(plan)),
        retry_budget: 0,
        ..flood(1)
    };
    let stats = serve_online_tiered(&ctxs, None, reqs.clone(), &ocfg, None).unwrap();
    assert_exactly_one_terminal(&stats, reqs.len());
    assert_eq!(stats.failed.len(), 1, "the one injected panic fails its request");
    assert_eq!(stats.failed[0].attempts, 1);
    assert_eq!(stats.restarts, 1);
    assert_eq!(stats.requeues, 0, "budget 0 never requeues");
    assert_eq!(stats.finished.len(), reqs.len() - 1);
}

/// Sparsity-tiered degradation under queue pressure: a bounded queue
/// past half full routes admissions to the sparser tier. Degraded
/// outputs are bit-exact against a run served *entirely* by the degrade
/// tier; primary outputs against the primary tier — the two never mix.
#[test]
fn degrade_tier_outputs_are_bit_exact_per_tier() {
    let ctxs = contexts(1, 0.5);
    let dctxs = contexts(1, 0.9);
    let reqs = requests(24);

    let primary_ref = outputs(&serve_online(&ctxs, reqs.clone(), &flood(1)).unwrap());
    let degrade_ref = outputs(&serve_online(&dctxs, reqs.clone(), &flood(1)).unwrap());

    // flood a bounded queue: depth*2 >= cap at service start routes to
    // the degrade tier; overflow past the cap is rejected at push
    let ocfg = OnlineConfig { queue_cap: 4, ..flood(1) };
    let stats = serve_online_tiered(&ctxs, Some(&dctxs), reqs.clone(), &ocfg, None).unwrap();
    assert_exactly_one_terminal(&stats, reqs.len());
    assert!(stats.degraded() > 0, "a flooded bounded queue must trigger degrade routing");
    for f in &stats.finished {
        let want = if f.degraded { &degrade_ref[&f.id] } else { &primary_ref[&f.id] };
        assert_eq!(
            (&f.tokens, f.nll),
            (&want.0, want.1),
            "request {} ({}) must be bit-exact for its tier",
            f.id,
            if f.degraded { "degraded" } else { "primary" }
        );
    }
}

/// Faults and degradation compose: panics restart workers while
/// pressure routes to the sparser tier, and every invariant still
/// holds — including per-tier token parity for replayed requests.
#[test]
fn faults_and_degrade_compose() {
    let ctxs = contexts(2, 0.5);
    let dctxs = contexts(2, 0.9);
    let reqs = requests(24);

    let primary_ref = outputs(&serve_online(&ctxs, reqs.clone(), &flood(2)).unwrap());
    let degrade_ref = outputs(&serve_online(&dctxs, reqs.clone(), &flood(2)).unwrap());

    let plan = FaultPlan::parse("panic@decode:4+9,deny@admit%7", 3).unwrap();
    let ocfg = OnlineConfig {
        queue_cap: 6,
        kv: KvMode::Paged { page_tokens: 4, max_pages: 0 },
        faults: Some(Arc::new(plan)),
        retry_budget: 8,
        ..flood(2)
    };
    let stats = serve_online_tiered(&ctxs, Some(&dctxs), reqs.clone(), &ocfg, None).unwrap();
    assert_exactly_one_terminal(&stats, reqs.len());
    for f in &stats.finished {
        let want = if f.degraded { &degrade_ref[&f.id] } else { &primary_ref[&f.id] };
        assert_eq!((&f.tokens, f.nll), (&want.0, want.1), "request {} per-tier parity", f.id);
    }
}
