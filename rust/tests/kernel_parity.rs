//! Parity pins for the shared microkernel layer (`besa::kernel`).
//!
//! Policy (docs/kernels.md): every micro kernel is **bitwise** equal to
//! its scalar reference. Each test sweeps the edge shapes around the
//! kernel's tiling boundaries — 0, 1, tile−1, tile, tile+1 and a
//! non-multiple — plus degenerate sparse structures (empty CSR, single
//! row), and the dispatching entry points must agree with the scalar
//! reference no matter which mode the process runs in.
//!
//! Tiling constants under test (see `docs/kernels.md`): `mm_nt` packs
//! MR=4 × NR=8 register blocks over KC=512 k-tiles; `mm_nn` / `mm_tn`
//! stream CH=32-lane output chunks (CHD=16 for the f64 matmul); SpMM
//! holds TW=32-wide token stripes; the attention weighted sum uses
//! 16-lane chunks.

use besa::kernel::{attn, fused, gemm, spmm};
use besa::quant::QuantSpec;
use besa::sparse::csr::{Csr, QuantCsr};
use besa::tensor::Tensor;
use besa::util::rng::Rng;

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed(seed);
    (0..n).map(|_| rng.normal_f32()).collect()
}

/// Random values with an exact-zero fraction, to exercise the
/// zero-skip branches of the AXPY-style kernels.
fn randv_sparse(n: usize, zero_frac: f64, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed(seed);
    (0..n)
        .map(|_| if rng.f64() < zero_frac { 0.0 } else { rng.normal_f32() })
        .collect()
}

fn randv64(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed(seed);
    (0..n).map(|_| rng.normal_f32() as f64).collect()
}

fn random_sparse_tensor(rows: usize, cols: usize, sparsity: f64, seed: u64) -> Tensor {
    Tensor::from_f32(&[rows, cols], randv_sparse(rows * cols, sparsity, seed))
}

/// mm_nt tile boundaries: MR=4 rows, NR=8 lanes, KC=512 k-tile. Every
/// combination of {0, 1, tile−1, tile, tile+1, non-multiple} per dim.
#[test]
fn mm_nt_micro_bitwise_on_tile_edges() {
    let ms = [0usize, 1, 3, 4, 5, 6];
    let ns = [0usize, 1, 7, 8, 9, 12];
    let ks = [0usize, 1, 100, 511, 512, 513];
    for &m in &ms {
        for &n in &ns {
            for &k in &ks {
                let x = randv(m * k, 1 + (m * 1000 + n * 10 + k) as u64);
                let w = randv(n * k, 2 + (m * 1000 + n * 10 + k) as u64);
                let scalar = gemm::mm_nt_scalar(&x, &w, m, k, n);
                let micro = gemm::mm_nt_micro(&x, &w, m, k, n);
                assert_eq!(scalar, micro, "mm_nt diverged at m={m} k={k} n={n}");
            }
        }
    }
}

/// The matvec lane (4-dot unroll) must match its reference and the
/// m=1 row of the packed GEMM — cached decode vs prefill rows depend
/// on this agreement.
#[test]
fn matvec_lanes_bitwise_and_match_mm_nt_row() {
    for &k in &[0usize, 1, 5, 32, 100] {
        for &n in &[0usize, 1, 2, 3, 4, 5, 9] {
            let x = randv(k, 7 + (k * 100 + n) as u64);
            let w = randv(n * k, 8 + (k * 100 + n) as u64);
            let mut ys = vec![0.0f32; n];
            let mut ym = vec![0.0f32; n];
            gemm::matvec_scalar_into(&x, &w, k, n, &mut ys);
            gemm::matvec_micro_into(&x, &w, k, n, &mut ym);
            assert_eq!(ys, ym, "matvec diverged at k={k} n={n}");
            assert_eq!(ys, gemm::mm_nt_scalar(&x, &w, 1, k, n), "matvec != mm_nt(m=1)");
        }
    }
}

/// Backward GEMMs stream CH=32-lane output chunks; both skip exact-zero
/// gradient entries, which must stay bitwise-neutral.
#[test]
fn mm_nn_mm_tn_bitwise_with_zero_skip() {
    let dims = [0usize, 1, 3, 8];
    let kdims = [0usize, 1, 31, 32, 33, 50];
    for &m in &dims {
        for &n in &dims {
            for &k in &kdims {
                let seed = (m * 10000 + n * 100 + k) as u64;
                let g = randv_sparse(m * n, 0.3, 11 + seed);
                let w = randv(n * k, 12 + seed);
                let x = randv(m * k, 13 + seed);
                assert_eq!(
                    gemm::mm_nn_scalar(&g, &w, m, n, k),
                    gemm::mm_nn_micro(&g, &w, m, n, k),
                    "mm_nn diverged at m={m} n={n} k={k}"
                );
                assert_eq!(
                    gemm::mm_tn_scalar(&g, &x, m, n, k),
                    gemm::mm_tn_micro(&g, &x, m, n, k),
                    "mm_tn diverged at m={m} n={n} k={k}"
                );
            }
        }
    }
}

/// f64 matmul (the `linalg::Mat` route) chunks CHD=16 output lanes and
/// keeps the historical zero-skip on the left operand.
#[test]
fn matmul_f64_bitwise_on_chunk_edges() {
    let dims = [0usize, 1, 3, 7];
    let ndims = [0usize, 1, 15, 16, 17, 22];
    for &m in &dims {
        for &k in &dims {
            for &n in &ndims {
                let seed = (m * 10000 + k * 100 + n) as u64;
                let mut a = randv64(m * k, 21 + seed);
                // plant exact zeros to hit the skip branch
                for v in a.iter_mut().step_by(3) {
                    *v = 0.0;
                }
                let c = randv64(k * n, 22 + seed);
                assert_eq!(
                    gemm::matmul_f64_scalar(&a, &c, m, k, n),
                    gemm::matmul_f64_micro(&a, &c, m, k, n),
                    "matmul_f64 diverged at m={m} k={k} n={n}"
                );
            }
        }
    }
}

fn spmm_pair(csr: &Csr, t: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let x = randv(csr.cols * t, seed);
    let mut ys = vec![0.0f32; csr.rows * t];
    let mut ym = vec![0.0f32; csr.rows * t];
    let value = |kk: usize| csr.values[kk];
    spmm::spmm_rows_scalar(&csr.row_ptr, &csr.col_idx, value, &x, t, 0, csr.rows, &mut ys);
    spmm::spmm_rows_micro(&csr.row_ptr, &csr.col_idx, value, &x, t, 0, csr.rows, &mut ym);
    (ys, ym)
}

/// SpMM stripe boundaries (TW=32 tokens) plus degenerate structures:
/// empty CSR (0% density), single-row matrices, fully dense rows.
#[test]
fn spmm_bitwise_on_stripe_edges_and_degenerate_csr() {
    for &t in &[0usize, 1, 31, 32, 33, 64] {
        for &(rows, cols, sparsity) in &[
            (16usize, 12usize, 0.0f64), // fully dense
            (16, 12, 0.5),
            (16, 12, 1.0), // empty CSR: no stored nonzeros at all
            (1, 24, 0.5),  // single row
            (24, 1, 0.5),  // single column
        ] {
            let seed = (t * 1000 + rows * 10 + cols) as u64;
            let csr = Csr::from_dense(&random_sparse_tensor(rows, cols, sparsity, 31 + seed));
            let (ys, ym) = spmm_pair(&csr, t, 32 + seed);
            assert_eq!(
                ys, ym,
                "spmm diverged at t={t} rows={rows} cols={cols} sparsity={sparsity}"
            );
        }
    }
}

/// The fused-dequant accessor is a pure function of the nonzero index —
/// parameterizing the value accessor must not change the stripe kernel.
#[test]
fn spmm_quant_accessor_bitwise() {
    for &t in &[1usize, 5, 33] {
        let q = QuantCsr::from_dense(&random_sparse_tensor(20, 14, 0.5, 41), QuantSpec::default());
        let x = randv(14 * t, 42 + t as u64);
        let mut ys = vec![0.0f32; 20 * t];
        let mut ym = vec![0.0f32; 20 * t];
        let value = |kk: usize| q.value(kk);
        spmm::spmm_rows_scalar(&q.row_ptr, &q.col_idx, value, &x, t, 0, 20, &mut ys);
        spmm::spmm_rows_micro(&q.row_ptr, &q.col_idx, value, &x, t, 0, 20, &mut ym);
        assert_eq!(ys, ym, "quant spmm diverged at t={t}");
    }
}

/// Attention score rows (4-key lock-step) and weighted value sums
/// (16-lane chunks), including strided/offset head layouts.
#[test]
fn attn_rows_bitwise_on_chunk_edges() {
    for &dh in &[1usize, 8, 15, 16, 17] {
        for &nkeys in &[0usize, 1, 3, 4, 5, 8] {
            // two heads per position: stride 2·dh, second head at offset dh
            let stride = 2 * dh;
            let seed = (dh * 100 + nkeys) as u64;
            let q = randv(dh, 51 + seed);
            let kmat = randv(nkeys * stride, 52 + seed);
            let p = randv(nkeys, 53 + seed);
            for &off in &[0usize, dh] {
                let mut ys = vec![0.0f32; nkeys];
                let mut ym = vec![0.0f32; nkeys];
                attn::dots_scalar(&q, &kmat, stride, off, nkeys, &mut ys);
                attn::dots_micro(&q, &kmat, stride, off, nkeys, &mut ym);
                assert_eq!(ys, ym, "dots diverged at dh={dh} keys={nkeys} off={off}");

                let mut os = vec![0.0f32; dh];
                let mut om = vec![0.0f32; dh];
                attn::wsum_scalar(&mut os, &p, &kmat, stride, off);
                attn::wsum_micro(&mut om, &p, &kmat, stride, off);
                assert_eq!(os, om, "wsum diverged at dh={dh} keys={nkeys} off={off}");
            }
        }
    }
}

/// `wsum` accumulates into `out` (the cached-decode row adds the new
/// key's value on top) — both kernels must honor a nonzero start.
#[test]
fn wsum_accumulates_from_nonzero_start() {
    let (dh, nkeys) = (17usize, 6usize);
    let init = randv(dh, 61);
    let p = randv(nkeys, 62);
    let vmat = randv(nkeys * dh, 63);
    let mut os = init.clone();
    let mut om = init.clone();
    attn::wsum_scalar(&mut os, &p, &vmat, dh, 0);
    attn::wsum_micro(&mut om, &p, &vmat, dh, 0);
    assert_eq!(os, om);
    assert_ne!(os, init, "wsum must have accumulated something");
}

/// The fused RMSNorm+matvec is the unfused pipeline minus allocations.
#[test]
fn fused_rmsnorm_matvec_matches_unfused() {
    for &(d, rows) in &[(1usize, 1usize), (7, 5), (32, 9), (33, 8)] {
        let x = randv(d, 71 + d as u64);
        let gain = randv(d, 72 + d as u64);
        let w = randv(rows * d, 73 + d as u64);
        let eps = 1e-5f64;

        let mut h = vec![0.0f32; d];
        let mut fused_out = vec![0.0f32; rows];
        fused::rmsnorm_matvec(&x, &gain, eps, &mut h, &w, rows, &mut fused_out);

        let mut norm = vec![0.0f32; d];
        fused::rmsnorm_into(&x, &gain, d, eps, &mut norm);
        assert_eq!(h, norm, "scratch row must hold the normalized activation");
        let unfused = gemm::mm_nt_scalar(&norm, &w, 1, d, rows);
        assert_eq!(fused_out, unfused, "fused path diverged at d={d} rows={rows}");
    }
}

/// Whatever `BESA_KERNEL` resolves to in this process, every dispatching
/// entry point must reproduce the scalar reference bitwise — this is the
/// documented per-kernel parity policy, checked end to end.
#[test]
fn dispatchers_match_scalar_reference_in_any_mode() {
    let (m, k, n) = (5usize, 33usize, 9usize);
    let x = randv(m * k, 81);
    let w = randv(n * k, 82);
    assert_eq!(gemm::mm_nt(&x, &w, m, k, n), gemm::mm_nt_scalar(&x, &w, m, k, n));

    let g = randv_sparse(m * n, 0.3, 83);
    assert_eq!(gemm::mm_nn(&g, &w, m, n, k), gemm::mm_nn_scalar(&g, &w, m, n, k));
    assert_eq!(gemm::mm_tn(&g, &x, m, n, k), gemm::mm_tn_scalar(&g, &x, m, n, k));

    let a = randv64(m * k, 84);
    let c = randv64(k * n, 85);
    assert_eq!(gemm::matmul_f64(&a, &c, m, k, n), gemm::matmul_f64_scalar(&a, &c, m, k, n));

    let mut yd = vec![0.0f32; n];
    let mut ysc = vec![0.0f32; n];
    gemm::matvec_into(&x[..k], &w, k, n, &mut yd);
    gemm::matvec_scalar_into(&x[..k], &w, k, n, &mut ysc);
    assert_eq!(yd, ysc);

    let csr = Csr::from_dense(&random_sparse_tensor(10, 8, 0.5, 86));
    let t = 6;
    let xt = randv(8 * t, 87);
    let value = |kk: usize| csr.values[kk];
    let mut sd = vec![0.0f32; 10 * t];
    let mut ss = vec![0.0f32; 10 * t];
    spmm::spmm_rows(&csr.row_ptr, &csr.col_idx, value, &xt, t, 0, 10, &mut sd);
    spmm::spmm_rows_scalar(&csr.row_ptr, &csr.col_idx, value, &xt, t, 0, 10, &mut ss);
    assert_eq!(sd, ss);

    let (dh, nkeys) = (16usize, 5usize);
    let q = randv(dh, 88);
    let kmat = randv(nkeys * dh, 89);
    let p = randv(nkeys, 90);
    let mut dd = vec![0.0f32; nkeys];
    let mut ds = vec![0.0f32; nkeys];
    attn::dots(&q, &kmat, dh, 0, nkeys, &mut dd);
    attn::dots_scalar(&q, &kmat, dh, 0, nkeys, &mut ds);
    assert_eq!(dd, ds);
    let mut wd = vec![0.0f32; dh];
    let mut ws = vec![0.0f32; dh];
    attn::wsum(&mut wd, &p, &kmat, dh, 0);
    attn::wsum_scalar(&mut ws, &p, &kmat, dh, 0);
    assert_eq!(wd, ws);
}
