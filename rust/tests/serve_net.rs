//! Behavior suite for the TCP front end (hermetic, loopback, `test`
//! config): the HTTP adapter's routes and status codes, line-protocol
//! error recovery, deterministic overload control (per-client token
//! buckets, unmeetable deadlines, paged-pool exhaustion), graceful-drain
//! accounting under deadline pressure, and span telemetry emission.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use besa::model::{ModelConfig, ParamStore};
use besa::serve::bench::magnitude_prune_in_place;
use besa::serve::engine::ServeContext;
use besa::serve::model::{PackedModel, WeightFormat};
use besa::serve::net::WireEvent;
use besa::serve::{KvMode, LineClient, NetConfig, NetServer, SchedulerConfig};
use besa::telemetry::{SpanKind, Tracer};
use besa::util::json::Json;

/// One serving replica per worker over a magnitude-pruned test model.
fn contexts(workers: usize, max_pos: usize) -> (ModelConfig, Vec<ServeContext>) {
    let cfg = ModelConfig::builtin("test").expect("built-in test config");
    let mut params = ParamStore::init(&cfg, 42);
    magnitude_prune_in_place(&mut params, &cfg, 0.5).unwrap();
    let ctxs = (0..workers)
        .map(|_| {
            ServeContext::new(
                PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
                max_pos,
            )
        })
        .collect();
    (cfg, ctxs)
}

/// Send one raw HTTP request and return (status code, body). The server
/// answers `Connection: close`, so reading to EOF frames the response.
fn http_roundtrip(addr: &std::net::SocketAddr, raw: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut text = String::new();
    stream.read_to_string(&mut text).unwrap();
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("unparseable response: {text:?}"));
    let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

fn post_generate(body: &str) -> String {
    format!(
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
}

#[test]
fn http_adapter_routes_and_status_codes() {
    let (_cfg, ctxs) = contexts(1, 64);
    let server = NetServer::start(ctxs, NetConfig::default(), None).unwrap();
    let addr = server.addr();

    let (code, body) = http_roundtrip(&addr, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(code, 200);
    assert_eq!(body, r#"{"status":"ok"}"#);

    let req = post_generate(r#"{"id":1,"prompt":[1,2,3,4],"max_new":3}"#);
    let (code, body) = http_roundtrip(&addr, &req);
    assert_eq!(code, 200, "generate failed: {body}");
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("event").and_then(Json::as_str), Some("done"));
    assert_eq!(v.get("id").and_then(Json::as_usize), Some(1));
    assert_eq!(v.get("tokens").and_then(Json::as_arr).map(|a| a.len()), Some(3));

    let (code, _) = http_roundtrip(&addr, "GET /nope HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(code, 404);

    let (code, _) = http_roundtrip(&addr, &post_generate("this is not json"));
    assert_eq!(code, 400);

    let (code, _) = http_roundtrip(&addr, &post_generate(r#"{"id":2,"prompt":[1],"wat":1}"#));
    assert_eq!(code, 400, "unknown fields must be rejected, not ignored");

    let stats = server.shutdown().unwrap();
    assert!(stats.drained_clean);
    assert!(stats.accounted());
    assert_eq!(stats.finished.len(), 1);
    assert_eq!(stats.parse_errors, 2);
    assert_eq!(stats.accepted_conns, 5);
}

#[test]
fn line_protocol_recovers_from_bad_requests() {
    let (_cfg, ctxs) = contexts(1, 64);
    let server = NetServer::start(ctxs, NetConfig::default(), None).unwrap();
    let mut client = LineClient::connect(&server.addr()).unwrap();

    // malformed JSON: an error event, but the connection survives
    client.send_line("{nope\n").unwrap();
    match client.read_event().unwrap() {
        WireEvent::Error { code, .. } => assert_eq!(code, 400),
        other => panic!("wanted a 400 error, got {other:?}"),
    }

    // unknown field: rejected (silent dropping would hide typos in QoS
    // fields, the worst failure mode for overload control)
    client.send_line("{\"id\":1,\"prompt\":[1,2],\"max_new\":1,\"deadline_m\":5}\n").unwrap();
    match client.read_event().unwrap() {
        WireEvent::Error { code, reason } => {
            assert_eq!(code, 400);
            assert!(reason.contains("deadline_m"), "reason names the field: {reason}");
        }
        other => panic!("wanted a 400 error, got {other:?}"),
    }

    // the same connection still serves valid requests afterwards
    let events = client.request("{\"id\":2,\"prompt\":[1,2,3],\"max_new\":2}\n").unwrap();
    match events.last().unwrap() {
        WireEvent::Done { id, tokens, .. } => {
            assert_eq!(*id, 2);
            assert_eq!(tokens.len(), 2);
        }
        other => panic!("wanted done, got {other:?}"),
    }

    // an oversized line loses framing: answer 413, then close
    let huge = format!("{}\n", "x".repeat(70_000));
    client.send_line(&huge).unwrap();
    match client.read_event().unwrap() {
        WireEvent::Error { code, .. } => assert_eq!(code, 413),
        other => panic!("wanted a 413 error, got {other:?}"),
    }
    assert!(client.read_event().is_err(), "server closes after losing framing");

    drop(client);
    let stats = server.shutdown().unwrap();
    assert!(stats.drained_clean);
    assert!(stats.accounted());
    assert_eq!(stats.finished.len(), 1);
    assert_eq!(stats.parse_errors, 3);
}

#[test]
fn token_bucket_rate_limits_second_request() {
    let (_cfg, ctxs) = contexts(1, 64);
    let ncfg = NetConfig {
        // burst covers exactly one request of cost 7 (4 prompt + 3 gen);
        // refill is negligible over the test's lifetime
        bucket_rate: 1e-6,
        bucket_burst: 7.0,
        ..NetConfig::default()
    };
    let server = NetServer::start(ctxs, ncfg, None).unwrap();
    let mut client = LineClient::connect(&server.addr()).unwrap();

    let line = "{\"id\":1,\"prompt\":[1,2,3,4],\"max_new\":3}\n";
    let events = client.request(line).unwrap();
    assert!(
        matches!(events.last().unwrap(), WireEvent::Done { .. }),
        "first request fits the burst: {events:?}"
    );
    let events = client.request(line).unwrap();
    match events.last().unwrap() {
        WireEvent::Rejected { code, reason, .. } => {
            assert_eq!(*code, 429);
            assert!(reason.contains("rate-limited"), "{reason}");
        }
        other => panic!("wanted a 429 rejection, got {other:?}"),
    }

    drop(client);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.finished.len(), 1);
    assert_eq!(stats.rejected_rate, 1, "bucket refusals never enter the queue");
    assert!(stats.accounted());
}

#[test]
fn expired_deadline_rejected_at_admission() {
    let (_cfg, ctxs) = contexts(1, 64);
    let server = NetServer::start(ctxs, NetConfig::default(), None).unwrap();
    let mut client = LineClient::connect(&server.addr()).unwrap();

    // a sub-nanosecond deadline has already passed by the push check
    let line = "{\"id\":9,\"prompt\":[1,2,3],\"max_new\":2,\"deadline_ms\":1e-9}\n";
    let events = client.request(line).unwrap();
    match events.last().unwrap() {
        WireEvent::Rejected { code, reason, .. } => {
            assert_eq!(*code, 503);
            assert!(reason.contains("deadline"), "{reason}");
        }
        other => panic!("wanted a 503 rejection, got {other:?}"),
    }

    drop(client);
    let stats = server.shutdown().unwrap();
    assert!(stats.accounted());
    assert_eq!(stats.rejected.len(), 1);
    assert!(stats.finished.is_empty());
}

/// Deadline pressure end to end: every request ends in exactly one
/// terminal event, client- and server-side counts agree, the drain is
/// clean, and the tracer saw the whole span vocabulary in action.
#[test]
fn tight_deadlines_account_exactly_and_emit_spans() {
    let (_cfg, ctxs) = contexts(1, 256);
    let tracer = Arc::new(Tracer::new());
    let ncfg = NetConfig {
        sched: SchedulerConfig { token_budget: 256, max_batch: 1 },
        ..NetConfig::default()
    };
    let server = NetServer::start(ctxs, ncfg, Some(Arc::clone(&tracer))).unwrap();

    // two pipelined clients × 4 requests with 5 ms deadlines against a
    // single batch-of-1 worker: late ones shed in-queue, but nothing is
    // ever lost or double-answered
    let counts = std::sync::Mutex::new((0usize, 0usize));
    std::thread::scope(|scope| {
        for c in 0..2u64 {
            let addr = server.addr();
            let counts = &counts;
            scope.spawn(move || {
                let mut client = LineClient::connect(&addr).unwrap();
                for i in 0..4u64 {
                    let line = format!(
                        "{{\"id\":{},\"prompt\":[1,2,3,4],\"max_new\":24,\"deadline_ms\":5}}\n",
                        c * 4 + i
                    );
                    let events = client.request(&line).unwrap();
                    let mut g = counts.lock().unwrap();
                    match events.last().unwrap() {
                        WireEvent::Done { .. } => g.0 += 1,
                        WireEvent::Shed { code, .. } => {
                            assert_eq!(*code, 503);
                            g.1 += 1;
                        }
                        other => panic!("unexpected terminal {other:?}"),
                    }
                }
            });
        }
    });
    let (done, shed) = *counts.lock().unwrap();
    assert_eq!(done + shed, 8, "every request got exactly one terminal event");

    let stats = server.shutdown().unwrap();
    assert!(stats.drained_clean);
    assert!(stats.accounted(), "queued == finished + shed");
    assert_eq!(stats.finished.len(), done);
    assert_eq!(stats.shed.len(), shed);
    assert_eq!(stats.requests, 8);
    assert_eq!(stats.accepted_conns, 2);

    let spans = tracer.drain();
    assert!(!spans.is_empty(), "the net path must emit telemetry");
    let kinds: std::collections::BTreeSet<SpanKind> = spans.iter().map(|s| s.kind).collect();
    assert!(kinds.contains(&SpanKind::Accept));
    assert!(kinds.contains(&SpanKind::Parse));
    if done > 0 {
        assert!(kinds.contains(&SpanKind::Queue));
        assert!(kinds.contains(&SpanKind::Prefill));
        assert!(kinds.contains(&SpanKind::Serialize));
    }
}

/// Tight-memory paged pool: a request whose worst-case KV footprint
/// exceeds the whole pool is a clean 400 at admission (it could never be
/// served), while a burst that merely oversubscribes the pool
/// *transiently* is absorbed as queueing delay or a 503 deadline shed —
/// never a panic, never a wedged drain, and `queued == finished + shed`
/// still holds exactly.
#[test]
fn paged_pool_exhaustion_rejects_and_sheds_clean() {
    let (_cfg, ctxs) = contexts(1, 64);
    let ncfg = NetConfig {
        // 8 pages × 2 tokens = 16 pool tokens, well under the token
        // budget: the pool is the binding admission constraint
        kv: KvMode::Paged { page_tokens: 2, max_pages: 8 },
        sched: SchedulerConfig { token_budget: 64, max_batch: 4 },
        ..NetConfig::default()
    };
    let server = NetServer::start(ctxs, ncfg, None).unwrap();

    // cost 20 > 16 pool tokens: unservable, rejected before the queue
    let mut client = LineClient::connect(&server.addr()).unwrap();
    let line = "{\"id\":1,\"prompt\":[1,2,3,4,5,6,7,8,9,10,11,12],\"max_new\":8}\n";
    let events = client.request(line).unwrap();
    match events.last().unwrap() {
        WireEvent::Rejected { code, reason, .. } => {
            assert_eq!(*code, 400);
            assert!(reason.contains("caps"), "{reason}");
        }
        other => panic!("wanted a 400 rejection, got {other:?}"),
    }
    drop(client);

    // 3 clients × cost-8 requests into 16 pool tokens: at most two fit
    // at once, so the pool runs dry mid-burst and admissions must wait
    // for pages (or shed on deadline) instead of panicking
    let counts = std::sync::Mutex::new((0usize, 0usize));
    std::thread::scope(|scope| {
        for c in 0..3u64 {
            let addr = server.addr();
            let counts = &counts;
            scope.spawn(move || {
                let mut client = LineClient::connect(&addr).unwrap();
                for i in 0..2u64 {
                    let line = format!(
                        "{{\"id\":{},\"prompt\":[5,6,7,8],\"max_new\":4,\"deadline_ms\":2000}}\n",
                        10 + c * 2 + i
                    );
                    let events = client.request(&line).unwrap();
                    let mut g = counts.lock().unwrap();
                    match events.last().unwrap() {
                        WireEvent::Done { .. } => g.0 += 1,
                        WireEvent::Shed { code, .. } => {
                            assert_eq!(*code, 503);
                            g.1 += 1;
                        }
                        other => panic!("unexpected terminal {other:?}"),
                    }
                }
            });
        }
    });
    let (done, shed) = *counts.lock().unwrap();
    assert_eq!(done + shed, 6, "every request got exactly one terminal event");
    assert!(done > 0, "the pool must keep serving one admission at a time");

    let stats = server.shutdown().unwrap();
    assert!(stats.drained_clean, "pool exhaustion must never wedge the drain");
    assert!(stats.accounted(), "queued == finished + shed under pool pressure");
    assert_eq!(stats.finished.len(), done);
    assert_eq!(stats.shed.len(), shed);
    assert_eq!(stats.requests, 6);
    assert!(stats.rejected.is_empty(), "transient exhaustion queues, it does not reject");
}

/// A client that hangs up mid-stream must not leak its KV pages, stall
/// the worker, or corrupt the accounting: the abandoned request counts
/// as `failed` (no terminal event has anywhere to go), the paged pool
/// drains back to zero live pages, and the next request on a fresh
/// connection is served normally.
#[test]
fn client_disconnect_mid_stream_releases_pages_and_counts_failed() {
    let (_cfg, ctxs) = contexts(1, 512);
    let ncfg = NetConfig {
        kv: KvMode::Paged { page_tokens: 2, max_pages: 0 },
        sched: SchedulerConfig { token_budget: 512, max_batch: 4 },
        ..NetConfig::default()
    };
    let server = NetServer::start(ctxs, ncfg, None).unwrap();

    // a long generation, abandoned after the first streamed token: the
    // worker discovers the dead client on a later token send and aborts
    let mut client = LineClient::connect(&server.addr()).unwrap();
    client.send_line("{\"id\":1,\"prompt\":[1,2,3,4],\"max_new\":200}\n").unwrap();
    match client.read_event().unwrap() {
        WireEvent::Token { id, .. } => assert_eq!(id, 1),
        other => panic!("wanted the first token, got {other:?}"),
    }
    drop(client); // hang up with ~199 tokens still to stream

    // the worker is not stalled: a fresh connection is served to
    // completion while (or after) the abort is swept
    let mut client2 = LineClient::connect(&server.addr()).unwrap();
    let events = client2.request("{\"id\":2,\"prompt\":[5,6,7],\"max_new\":3}\n").unwrap();
    match events.last().unwrap() {
        WireEvent::Done { id, tokens, .. } => {
            assert_eq!(*id, 2);
            assert_eq!(tokens.len(), 3);
        }
        other => panic!("wanted done, got {other:?}"),
    }
    drop(client2);

    // the aborted request's pages come back to the pool once the sweep
    // runs; poll rather than sleep — the abort lands on a token send,
    // not at a fixed time
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let ps = server.pool_stats().expect("paged mode has a pool");
        if ps.live == 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "disconnected request still holds {} live pages",
            ps.live
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }

    let stats = server.shutdown().unwrap(); // Err here would mean an undrained pool
    assert!(stats.drained_clean);
    assert!(stats.accounted(), "queued == finished + shed + failed");
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.finished.len(), 1, "only the second request finishes");
    assert_eq!(stats.failed.len(), 1, "the abandoned request counts as failed");
    assert_eq!(stats.failed[0].id, 1);
    assert!(stats.shed.is_empty());
}

#[test]
fn idle_server_drains_clean() {
    let (_cfg, ctxs) = contexts(2, 64);
    let ncfg = NetConfig { workers: 2, ..NetConfig::default() };
    let server = NetServer::start(ctxs, ncfg, None).unwrap();
    let stats = server.shutdown().unwrap();
    assert!(stats.drained_clean);
    assert!(stats.accounted());
    assert_eq!(stats.requests, 0);
    assert_eq!(stats.accepted_conns, 0);
    assert_eq!(stats.workers.len(), 2);
}
