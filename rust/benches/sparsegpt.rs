//! Bench: the SparseGPT OBS solver (baseline infrastructure) — Cholesky +
//! blocked sweep cost per layer shape.

use besa::linalg::Mat;
use besa::prune::sparsegpt::sparsegpt_layer;
use besa::tensor::Tensor;
use besa::util::bench::Bench;
use besa::util::rng::Rng;

fn problem(rows: usize, cols: usize, seed: u64) -> (Tensor, Mat) {
    let mut rng = Rng::seed(seed);
    let w = Tensor::from_f32(&[rows, cols], (0..rows * cols).map(|_| rng.normal_f32()).collect());
    let n = cols * 2;
    let x: Vec<f32> = (0..n * cols).map(|_| rng.normal_f32()).collect();
    let mut h = Mat::zeros(cols, cols);
    h.add_gram_f32(&x, n);
    (w, h)
}

fn main() {
    let mut b = Bench::new("sparsegpt_obs").budget_secs(2.0);
    for (r, c) in [(64usize, 64usize), (128, 128), (344, 128), (128, 344), (512, 512)] {
        let (w0, h) = problem(r, c, 1);
        b.run_throughput(&format!("obs {r}x{c} @50%"), (r * c) as f64, "weights/s", || {
            let mut w = w0.clone();
            sparsegpt_layer(&mut w, &h, 0.5, 32, 0.01).unwrap()
        });
    }
    // cholesky alone, the cubic term
    for n in [128usize, 344, 512] {
        let (_, h) = problem(4, n, 2);
        b.run(&format!("cholesky_inverse_upper {n}x{n}"), || {
            besa::linalg::cholesky_inverse_upper(&h).unwrap()
        });
    }
    b.report();
}
