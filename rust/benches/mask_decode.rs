//! Bench: rust-side BESA mask decode (the paper's "customized CUDA
//! operator" analogue on the coordinator side) across layer shapes.

use besa::prune::importance::{decode_mask, ranks};
use besa::tensor::Tensor;
use besa::util::bench::Bench;
use besa::util::rng::Rng;

fn main() {
    let mut b = Bench::new("mask_decode");
    let mut rng = Rng::seed(1);
    for (r, c, d) in [(64usize, 64usize, 32usize), (128, 128, 100), (344, 128, 100), (512, 512, 100)] {
        let theta =
            Tensor::from_f32(&[r, d - 1], (0..r * (d - 1)).map(|_| rng.normal_f32()).collect());
        let scores = Tensor::from_f32(&[r, c], (0..r * c).map(|_| rng.normal_f32()).collect());
        let rk = ranks(&scores);
        b.run_throughput(&format!("decode {r}x{c} D={d}"), (r * c) as f64, "elem/s", || {
            decode_mask(&theta, &rk, d)
        });
        b.run_throughput(&format!("rank  {r}x{c}"), (r * c) as f64, "elem/s", || ranks(&scores));
    }
    b.report();
}
