//! Bench: ViTCoD simulator throughput (Table 4 infrastructure) across the
//! LLaMA-7B layer shapes the paper reports, scaled and unscaled.

use besa::sim::{dense_cycles, simulate_spmm, Csr, SimConfig};
use besa::tensor::Tensor;
use besa::util::bench::Bench;
use besa::util::rng::Rng;

fn sparse(rows: usize, cols: usize, sparsity: f64, seed: u64) -> Csr {
    let mut rng = Rng::seed(seed);
    let data: Vec<f32> = (0..rows * cols)
        .map(|_| if rng.f64() < sparsity { 0.0 } else { rng.normal_f32() })
        .collect();
    Csr::from_dense(&Tensor::from_f32(&[rows, cols], data))
}

fn main() {
    let mut b = Bench::new("vitcod_simulator");
    let cfg = SimConfig::default();
    // our model-family shapes + the paper's LLaMA-7B shapes
    for (name, r, c) in [
        ("md qkv 128x128", 128usize, 128usize),
        ("md gate 344x128", 344, 128),
        ("llama7b qkv 4096x4096", 4096, 4096),
        ("llama7b gate 11008x4096", 11008, 4096),
    ] {
        let w = sparse(r, c, 0.5, 42);
        b.run_throughput(&format!("simulate {name}"), w.nnz() as f64, "nnz/s", || {
            simulate_spmm(&w, &cfg)
        });
    }
    // sparsity sweep on one shape: the Table-4 "who wins by how much" series
    println!("\n  speedup vs sparsity (1024x1024, ViTCoD default config):");
    for s in [0.3, 0.5, 0.7, 0.9] {
        let w = sparse(1024, 1024, s, 7);
        let cycles = simulate_spmm(&w, &cfg).cycles;
        let dense = dense_cycles(1024, 1024, &cfg);
        println!("    sparsity {s:.1}: speedup {:.2}x", dense as f64 / cycles as f64);
    }
    b.report();
}
