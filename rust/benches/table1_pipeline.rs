//! Bench: end-to-end Table-1 pipeline cost — full block-sequential pruning
//! of the `test` model per method. This is the wall-clock the paper quotes
//! as "prunes LLaMA-70B in five hours on one A100", scaled to our substrate.

use besa::coordinator::Pipeline;
use besa::data::batcher::CalibrationSet;
use besa::model::ParamStore;
use besa::prune::besa::{BesaConfig, BesaPruner};
use besa::prune::magnitude::MagnitudePruner;
use besa::prune::sparsegpt::SparseGptPruner;
use besa::prune::wanda::WandaPruner;
use besa::runtime::Engine;
use besa::util::bench::Bench;

fn main() {
    let engine = Engine::native("test").expect("built-in test config");
    let cfg = engine.config().clone();
    let dense = ParamStore::init(&cfg, 3);
    let calib = CalibrationSet::sample(&cfg, cfg.batch, 11);

    let mut b = Bench::new("table1_pipeline").warmup(1).budget_secs(3.0);
    let params_per = cfg.block_param_count() as f64 * cfg.n_blocks as f64;

    b.run_throughput("magnitude (full model)", params_per, "weights/s", || {
        let mut p = dense.clone();
        Pipeline::new(&engine, calib.batches.clone())
            .run(&mut p, &mut MagnitudePruner { sparsity: 0.5 })
            .unwrap()
    });
    b.run_throughput("wanda (full model)", params_per, "weights/s", || {
        let mut p = dense.clone();
        Pipeline::new(&engine, calib.batches.clone())
            .run(&mut p, &mut WandaPruner { sparsity: 0.5 })
            .unwrap()
    });
    b.run_throughput("sparsegpt (full model)", params_per, "weights/s", || {
        let mut p = dense.clone();
        Pipeline::new(&engine, calib.batches.clone())
            .run(&mut p, &mut SparseGptPruner { sparsity: 0.5, ..Default::default() })
            .unwrap()
    });
    b.run_throughput("besa e4 (full model)", params_per, "weights/s", || {
        let mut p = dense.clone();
        Pipeline::new(&engine, calib.batches.clone())
            .run(
                &mut p,
                &mut BesaPruner::new(BesaConfig { epochs: 4, ..Default::default() }),
            )
            .unwrap()
    });
    b.report();
}
