//! Bench: runtime hot path — per-artifact execution latency through the
//! Engine facade (native backend: hermetic, no artifacts needed).

use besa::model::{ParamStore, LAYER_NAMES};
use besa::runtime::Engine;
use besa::tensor::Tensor;
use besa::util::bench::Bench;
use besa::util::rng::Rng;

fn main() {
    let engine = Engine::native("test").expect("built-in test config");
    let cfg = engine.config().clone();
    let params = ParamStore::init(&cfg, 1);
    let mut rng = Rng::seed(2);
    let n = cfg.batch * cfg.seq_len * cfg.d_model;
    let x = Tensor::from_f32(
        &[cfg.batch, cfg.seq_len, cfg.d_model],
        (0..n).map(|_| rng.normal_f32() * 0.5).collect(),
    );
    let toks = Tensor::from_i32(
        &[cfg.batch, cfg.seq_len],
        (0..cfg.batch * cfg.seq_len).map(|i| (i % 256) as i32).collect(),
    );

    let mut b = Bench::new("runtime_exec").budget_secs(2.0);
    let tokens_per = (cfg.batch * cfg.seq_len) as f64;

    let emb = params.get("embed").unwrap();
    b.run_throughput("embed", tokens_per, "tok/s", || engine.run("embed", &[&toks, emb]).unwrap());

    let mut block_ins: Vec<&Tensor> = vec![&x];
    for w in LAYER_NAMES {
        block_ins.push(params.get(&ParamStore::layer_name(0, w)).unwrap());
    }
    block_ins.push(params.get("blocks.0.norm1").unwrap());
    block_ins.push(params.get("blocks.0.norm2").unwrap());
    b.run_throughput("block_fwd", tokens_per, "tok/s", || {
        engine.run("block_fwd", &block_ins).unwrap()
    });
    b.run_throughput("block_capture", tokens_per, "tok/s", || {
        engine.run("block_capture", &block_ins).unwrap()
    });

    // masked forward: the pruned-model inference path
    let ones: Vec<Tensor> = LAYER_NAMES
        .iter()
        .map(|w| {
            let s = cfg.layer_shape(w);
            Tensor::ones(&[s[0], s[1]])
        })
        .collect();
    let mut masked_ins = block_ins.clone();
    masked_ins.extend(ones.iter());
    b.run_throughput("block_fwd_masked", tokens_per, "tok/s", || {
        engine.run("block_fwd_masked", &masked_ins).unwrap()
    });

    // besa_step: the pruning-loop hot path (fwd + analytic bwd)
    let y = engine.run("block_fwd", &block_ins).unwrap().into_iter().next().unwrap();
    let thetas: Vec<Tensor> = LAYER_NAMES
        .iter()
        .map(|w| Tensor::zeros(&[cfg.layer_shape(w)[0], cfg.n_rates - 1]))
        .collect();
    let ranks: Vec<Tensor> = LAYER_NAMES
        .iter()
        .map(|w| {
            let s = cfg.layer_shape(w);
            let rows: Vec<i32> = (0..s[0])
                .flat_map(|_| rng.permutation(s[1]).into_iter().map(|v| v as i32))
                .collect();
            Tensor::from_i32(&[s[0], s[1]], rows)
        })
        .collect();
    let lam = Tensor::scalar(8.0);
    let ah = Tensor::scalar(0.5);
    let mut ins: Vec<&Tensor> = thetas.iter().collect();
    ins.push(&x);
    ins.push(&y);
    for w in LAYER_NAMES {
        ins.push(params.get(&ParamStore::layer_name(0, w)).unwrap());
    }
    ins.push(params.get("blocks.0.norm1").unwrap());
    ins.push(params.get("blocks.0.norm2").unwrap());
    ins.extend(ranks.iter());
    ins.push(&lam);
    ins.push(&ah);
    b.run_throughput("besa_step_row (fwd+bwd)", tokens_per, "tok/s", || {
        engine.run("besa_step_row", &ins).unwrap()
    });

    // whole-model training step (all-parameter backward)
    let mut train_ins: Vec<&Tensor> = params.ordered();
    train_ins.push(&toks);
    b.run_throughput("lm_train_step", tokens_per, "tok/s", || {
        engine.run("lm_train_step", &train_ins).unwrap()
    });

    b.report();
    let (compile_s, exec_s, calls) = engine.stats();
    println!(
        "engine totals ({}): {calls} calls, exec {exec_s:.2}s, compile {compile_s:.2}s",
        engine.backend_name()
    );
}
