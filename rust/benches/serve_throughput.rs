//! Bench: the serving hot paths — packed linear kernels (dense vs CSR vs
//! fused-dequant CSR), prefill and batched decode per weight format, the
//! `block_fwd_cached` runtime op, a full continuous-batching trace replay
//! per mode (the `besa serve-bench` inner loop, minus the report), and
//! the online multi-worker engine at 1 vs N workers (the `--async` drain
//! mode, showing the sharding scaling).

use besa::model::{ModelConfig, ParamStore};
use besa::quant::QuantSpec;
use besa::runtime::Engine;
use besa::serve::bench::magnitude_prune_in_place;
use besa::serve::engine::{
    block_tensors, decode_step, decode_step_backend, prefill, DecodeScratch, ServeContext,
};
use besa::serve::model::{PackedModel, WeightFormat};
use besa::serve::scheduler::SchedulerConfig;
use besa::serve::trace::{poisson_trace, TraceConfig};
use besa::serve::{run_trace, serve_online, KvSpec, OnlineConfig, Pacing, ServeBenchConfig, ServeMode};
use besa::util::bench::Bench;
use besa::util::rng::Rng;

fn main() {
    let config = std::env::var("BESA_BENCH_CONFIG").unwrap_or_else(|_| "sm".to_string());
    let engine = Engine::native(&config).expect("built-in config");
    let cfg: ModelConfig = engine.config().clone();
    let mut params = ParamStore::init(&cfg, 1);
    magnitude_prune_in_place(&mut params, &cfg, 0.5).unwrap();

    let mut b = Bench::new("serve_throughput").budget_secs(1.5);

    // ---- packed linear kernels on the widest layer shape -----------------
    let (rows, cols) = (cfg.d_ffn, cfg.d_model);
    let n = 64usize;
    let mut rng = Rng::seed(2);
    let x: Vec<f32> = (0..n * cols).map(|_| rng.normal_f32()).collect();
    let w = params.get("blocks.0.wg").unwrap();
    let dense = PackedModel::materialize(&params, &cfg, WeightFormat::Dense).unwrap();
    let csr = PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap();
    let quant =
        PackedModel::materialize(&params, &cfg, WeightFormat::Quant(QuantSpec::default())).unwrap();
    let macs = (n * rows * cols) as f64;
    assert_eq!(w.shape, vec![rows, cols]);
    b.run_throughput(&format!("linear dense {rows}x{cols} n={n}"), macs, "mac/s", || {
        dense.blocks[0].lin[4].forward(&x, n)
    });
    b.run_throughput(&format!("linear csr   {rows}x{cols} n={n}"), macs, "mac/s", || {
        csr.blocks[0].lin[4].forward(&x, n)
    });
    b.run_throughput(&format!("linear quant {rows}x{cols} n={n}"), macs, "mac/s", || {
        quant.blocks[0].lin[4].forward(&x, n)
    });

    // ---- prefill + decode per format -------------------------------------
    let max_pos = cfg.seq_len;
    let prompt: Vec<i32> = (0..cfg.seq_len / 2).map(|i| (i * 7 % 256) as i32).collect();
    let nb = 8usize;
    for format in [
        WeightFormat::Dense,
        WeightFormat::Csr,
        WeightFormat::Quant(QuantSpec::default()),
    ] {
        let ctx =
            ServeContext::new(PackedModel::materialize(&params, &cfg, format).unwrap(), max_pos);
        let name = format.name();
        b.run_throughput(&format!("prefill {name} s={}", prompt.len()), prompt.len() as f64, "tok/s", || {
            let mut cache = ctx.new_cache();
            prefill(&ctx, &prompt, &mut cache)
        });
        // decode over a batch of nb requests with half-full caches
        let mut caches: Vec<_> = (0..nb)
            .map(|_| {
                let mut c = ctx.new_cache();
                prefill(&ctx, &prompt, &mut c);
                c
            })
            .collect();
        let last: Vec<i32> = (0..nb as i32).collect();
        let mut scratch = DecodeScratch::new();
        b.run_throughput(&format!("decode {name} nb={nb}"), nb as f64, "tok/s", || {
            // rewind so the cache never exhausts capacity mid-bench
            for c in caches.iter_mut() {
                c.set_len(prompt.len());
            }
            let mut refs: Vec<&mut _> = caches.iter_mut().collect();
            decode_step(&ctx, &last, &mut refs, &mut scratch)
        });
    }

    // ---- decode through the runtime's block_fwd_cached artifact ----------
    let ctx =
        ServeContext::new(PackedModel::materialize(&params, &cfg, WeightFormat::Dense).unwrap(), max_pos);
    let blocks = block_tensors(&params, &cfg).unwrap();
    let mut caches: Vec<_> = (0..nb)
        .map(|_| {
            let mut c = ctx.new_cache();
            prefill(&ctx, &prompt, &mut c);
            c
        })
        .collect();
    let last: Vec<i32> = (0..nb as i32).collect();
    b.run_throughput(&format!("decode dense-backend nb={nb}"), nb as f64, "tok/s", || {
        for c in caches.iter_mut() {
            c.set_len(prompt.len());
        }
        let mut refs: Vec<&mut _> = caches.iter_mut().collect();
        decode_step_backend(&ctx, &engine, &blocks, &last, &mut refs).unwrap()
    });

    // ---- full trace replay per mode --------------------------------------
    let bcfg = ServeBenchConfig::default();
    let trace_cfg = TraceConfig {
        n_requests: 16,
        prompt_max: cfg.seq_len.max(17) - 1,
        ..bcfg.trace
    };
    let sched = SchedulerConfig { token_budget: 512, max_batch: 8 };
    let kvspec = KvSpec::contig();
    for mode in [ServeMode::Dense, ServeMode::Sparse, ServeMode::Quant] {
        let format = match mode {
            ServeMode::Sparse => WeightFormat::Csr,
            ServeMode::Quant => WeightFormat::Quant(QuantSpec::default()),
            _ => WeightFormat::Dense,
        };
        let ctx = ServeContext::new(
            PackedModel::materialize(&params, &cfg, format).unwrap(),
            trace_cfg.max_request_tokens(),
        );
        let requests = poisson_trace(&trace_cfg);
        let total_tokens: usize = requests.iter().map(|r| r.cost()).sum();
        b.run_throughput(
            &format!("trace x{} {}", trace_cfg.n_requests, mode.name()),
            total_tokens as f64,
            "tok/s",
            || run_trace(&ctx, None, requests.clone(), &sched, &kvspec).unwrap(),
        );
    }

    // ---- online multi-worker drain (sharded scaling) ----------------------
    let requests = poisson_trace(&trace_cfg);
    let total_tokens: usize = requests.iter().map(|r| r.cost()).sum();
    for workers in [1usize, 4] {
        let ctxs: Vec<ServeContext> = (0..workers)
            .map(|_| {
                ServeContext::new(
                    PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
                    trace_cfg.max_request_tokens(),
                )
            })
            .collect();
        let ocfg = OnlineConfig {
            workers,
            sched: SchedulerConfig { token_budget: 512, max_batch: 8 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            ..OnlineConfig::default()
        };
        b.run_throughput(
            &format!("online x{} sparse workers={workers}", trace_cfg.n_requests),
            total_tokens as f64,
            "tok/s",
            || serve_online(&ctxs, requests.clone(), &ocfg).unwrap(),
        );
    }

    b.report();
}
