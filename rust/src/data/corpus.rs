//! Synthetic-grammar corpus generators.
//!
//! Three distinct probabilistic grammars stand in for the paper's
//! evaluation corpora (DESIGN.md §Substitutions):
//!
//! * [`Domain::WikiSyn`]  — encyclopedic sentences: entities, relative
//!   clauses, dates (→ WikiText2 role: shifted-but-related eval set).
//! * [`Domain::C4Syn`]    — web-prose style, the **calibration source**
//!   (the paper calibrates on C4's first shard).
//! * [`Domain::PtbSyn`]   — telegraphic newswire with numbers and
//!   abbreviations (→ PTB role: strongest domain shift).
//!
//! All corpora share the byte vocabulary but differ in word inventory,
//! sentence templates and punctuation statistics, so a model trained on
//! the mixture has learnable structure and the three eval streams rank
//! pruning damage differently — the property the paper's three-dataset
//! tables measure.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    WikiSyn,
    C4Syn,
    PtbSyn,
}

impl Domain {
    pub fn from_name(s: &str) -> Option<Domain> {
        match s {
            "wiki-syn" | "wikitext2" | "wiki" => Some(Domain::WikiSyn),
            "c4-syn" | "c4" => Some(Domain::C4Syn),
            "ptb-syn" | "ptb" => Some(Domain::PtbSyn),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Domain::WikiSyn => "wiki-syn",
            Domain::C4Syn => "c4-syn",
            Domain::PtbSyn => "ptb-syn",
        }
    }

    pub fn all() -> [Domain; 3] {
        [Domain::WikiSyn, Domain::C4Syn, Domain::PtbSyn]
    }
}

const WIKI_ENTITIES: &[&str] = &[
    "aldoria", "brevik", "castellan", "dormund", "elvaria", "fenwick", "galdor",
    "hestia", "ivarstead", "jorvik", "kaldwin", "lorath", "meridia", "norvale",
];
const WIKI_NOUNS: &[&str] = &[
    "province", "dynasty", "treaty", "river", "cathedral", "archive", "garrison",
    "festival", "observatory", "parliament", "harbor", "railway",
];
const WIKI_VERBS: &[&str] = &[
    "established", "annexed", "chronicled", "restored", "governed", "surveyed",
    "commissioned", "abolished", "fortified", "documented",
];

const C4_SUBJECTS: &[&str] = &[
    "the team", "our community", "this product", "the platform", "a new study",
    "the project", "local makers", "the service", "many readers", "the update",
];
const C4_VERBS: &[&str] = &[
    "offers", "improves", "supports", "launches", "explores", "delivers",
    "simplifies", "recommends", "features", "celebrates",
];
const C4_OBJECTS: &[&str] = &[
    "a better workflow", "fresh ideas", "practical tools", "weekly guides",
    "free resources", "great results", "simple recipes", "honest reviews",
    "useful tips", "open data",
];
const C4_TAILS: &[&str] = &[
    "for everyone", "this season", "at no cost", "with ease", "in minutes",
    "around the world", "every day", "on any device",
];

const PTB_TICKERS: &[&str] = &[
    "acme corp", "unitex", "borall inc", "midland gas", "trano plc", "velcor",
    "quorum ltd", "sandric", "paxton co",
];
const PTB_VERBS: &[&str] =
    &["rose", "fell", "gained", "slipped", "climbed", "eased", "jumped", "dropped"];
const PTB_UNITS: &[&str] = &["points", "cents a share", "pct", "dlrs", "mln dlrs"];

/// Streaming corpus: deterministic for a (domain, seed) pair.
pub struct Corpus {
    pub domain: Domain,
    rng: Rng,
    buf: Vec<i32>,
    pos: usize,
}

impl Corpus {
    pub fn new(domain: Domain, seed: u64) -> Corpus {
        Corpus { domain, rng: Rng::seed(seed ^ domain_tag(domain)), buf: Vec::new(), pos: 0 }
    }

    fn sentence(&mut self) -> String {
        let r = &mut self.rng;
        match self.domain {
            Domain::WikiSyn => {
                let e1 = *r.choice(WIKI_ENTITIES);
                let n1 = *r.choice(WIKI_NOUNS);
                let v = *r.choice(WIKI_VERBS);
                let e2 = *r.choice(WIKI_ENTITIES);
                let year = 1100 + r.below(900);
                match r.below(3) {
                    0 => format!("the {n1} of {e1} was {v} in {year}. "),
                    1 => format!("{e1}, a {n1} near {e2}, was {v} by the {} of {e2}. ",
                        *r.choice(WIKI_NOUNS)),
                    _ => format!("in {year} the {n1} at {e1} was {v} and later renamed {e2}. "),
                }
            }
            Domain::C4Syn => {
                let s = *r.choice(C4_SUBJECTS);
                let v = *r.choice(C4_VERBS);
                let o = *r.choice(C4_OBJECTS);
                let t = *r.choice(C4_TAILS);
                match r.below(3) {
                    0 => format!("{s} {v} {o} {t}. "),
                    1 => format!("here is why {s} {v} {o}: it just works {t}. "),
                    _ => format!("{s} now {v} {o}, and {} {} {o} too. ",
                        *r.choice(C4_SUBJECTS), *r.choice(C4_VERBS)),
                }
            }
            Domain::PtbSyn => {
                let t1 = *r.choice(PTB_TICKERS);
                let v = *r.choice(PTB_VERBS);
                let amt = r.below(95) + 1;
                let u = *r.choice(PTB_UNITS);
                match r.below(3) {
                    0 => format!("{t1} shares {v} {amt} {u}. "),
                    1 => format!("{t1} said net {v} to {amt} {u} in the quarter. "),
                    _ => format!("analysts said {t1} {v} {amt} {u} after the report. "),
                }
            }
        }
    }

    fn refill(&mut self) {
        let s = self.sentence();
        self.buf.extend(super::tokenize(&s));
    }

    /// Next `n` tokens of the infinite stream.
    pub fn take(&mut self, n: usize) -> Vec<i32> {
        while self.buf.len() - self.pos < n {
            self.refill();
        }
        let out = self.buf[self.pos..self.pos + n].to_vec();
        self.pos += n;
        // periodically drop consumed prefix
        if self.pos > 1 << 20 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        out
    }

    /// `count` independent sequences of length `seq_len` (each starting at a
    /// sentence boundary for the first, then streaming).
    pub fn sequences(&mut self, count: usize, seq_len: usize) -> Vec<Vec<i32>> {
        (0..count).map(|_| self.take(seq_len)).collect()
    }
}

fn domain_tag(d: Domain) -> u64 {
    match d {
        Domain::WikiSyn => 0x5741,
        Domain::C4Syn => 0xC4C4,
        Domain::PtbSyn => 0x97B9,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = Corpus::new(Domain::C4Syn, 1).take(512);
        let b = Corpus::new(Domain::C4Syn, 1).take(512);
        let c = Corpus::new(Domain::C4Syn, 2).take(512);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn domains_differ() {
        let a = Corpus::new(Domain::WikiSyn, 1).take(2048);
        let b = Corpus::new(Domain::PtbSyn, 1).take(2048);
        assert_ne!(a, b);
        // PTB-syn is digit-heavy relative to wiki-syn's prose
        let digits = |v: &[i32]| v.iter().filter(|t| (b'0' as i32..=b'9' as i32).contains(t)).count();
        assert!(digits(&b) > digits(&a) / 2, "ptb {} wiki {}", digits(&b), digits(&a));
    }

    #[test]
    fn tokens_in_vocab() {
        let v = Corpus::new(Domain::WikiSyn, 3).take(4096);
        assert!(v.iter().all(|t| (0..256).contains(t)));
    }

    #[test]
    fn text_is_readable() {
        let mut c = Corpus::new(Domain::C4Syn, 4);
        let txt = crate::data::detokenize(&c.take(200));
        assert!(txt.contains(' '), "{txt}");
    }

    #[test]
    fn sequences_shape() {
        let mut c = Corpus::new(Domain::PtbSyn, 5);
        let seqs = c.sequences(3, 64);
        assert_eq!(seqs.len(), 3);
        assert!(seqs.iter().all(|s| s.len() == 64));
        assert_ne!(seqs[0], seqs[1]);
    }
}
