//! Batcher: turns corpus streams into fixed-shape `[B, S]` token tensors
//! (the AOT artifacts are shape-specialized), plus the calibration sampler
//! mirroring the paper's "128 sequences × 2048 tokens from C4" protocol.

use crate::model::ModelConfig;
use crate::tensor::Tensor;

use super::corpus::{Corpus, Domain};

pub struct Batcher {
    pub batch: usize,
    pub seq_len: usize,
    corpus: Corpus,
}

impl Batcher {
    pub fn new(domain: Domain, seed: u64, cfg: &ModelConfig) -> Batcher {
        Batcher { batch: cfg.batch, seq_len: cfg.seq_len, corpus: Corpus::new(domain, seed) }
    }

    /// Next `[B, S]` i32 token tensor.
    pub fn next_batch(&mut self) -> Tensor {
        let mut data = Vec::with_capacity(self.batch * self.seq_len);
        for _ in 0..self.batch {
            data.extend(self.corpus.take(self.seq_len));
        }
        Tensor::from_i32(&[self.batch, self.seq_len], data)
    }

    /// `n` batches (deterministic continuation of the stream).
    pub fn batches(&mut self, n: usize) -> Vec<Tensor> {
        (0..n).map(|_| self.next_batch()).collect()
    }
}

/// Calibration set: `n_seqs` sequences drawn from the calibration domain
/// (C4-syn by default, like the paper), packed into `[B, S]` minibatches.
pub struct CalibrationSet {
    pub batches: Vec<Tensor>,
    pub n_seqs: usize,
}

impl CalibrationSet {
    pub fn sample(cfg: &ModelConfig, n_seqs: usize, seed: u64) -> CalibrationSet {
        assert!(n_seqs % cfg.batch == 0, "n_seqs {} must be a multiple of batch {}", n_seqs, cfg.batch);
        let mut b = Batcher::new(Domain::C4Syn, seed, cfg);
        CalibrationSet { batches: b.batches(n_seqs / cfg.batch), n_seqs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;

    #[test]
    fn batch_shapes() {
        let cfg = test_config();
        let mut b = Batcher::new(Domain::C4Syn, 1, &cfg);
        let t = b.next_batch();
        assert_eq!(t.shape, vec![cfg.batch, cfg.seq_len]);
        assert_eq!(t.i32s().len(), cfg.batch * cfg.seq_len);
    }

    #[test]
    fn batches_distinct_and_deterministic() {
        let cfg = test_config();
        let mut b1 = Batcher::new(Domain::WikiSyn, 9, &cfg);
        let mut b2 = Batcher::new(Domain::WikiSyn, 9, &cfg);
        let x1 = b1.next_batch();
        let y1 = b1.next_batch();
        assert_ne!(x1, y1);
        assert_eq!(x1, b2.next_batch());
    }

    #[test]
    fn calibration_counts() {
        let cfg = test_config();
        let c = CalibrationSet::sample(&cfg, 16, 0);
        assert_eq!(c.batches.len(), 16 / cfg.batch);
    }

    #[test]
    #[should_panic]
    fn calibration_requires_multiple_of_batch() {
        let cfg = test_config();
        CalibrationSet::sample(&cfg, cfg.batch + 1, 0);
    }
}
