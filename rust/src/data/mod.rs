//! Data substrate: synthetic-corpus generators standing in for the paper's
//! WikiText2 / C4 / PTB (DESIGN.md §Substitutions), a byte-level tokenizer,
//! a deterministic batcher, and the calibration sampler.

pub mod batcher;
pub mod corpus;

pub use batcher::Batcher;
pub use corpus::{Corpus, Domain};

/// Byte-level tokenizer: vocab = 256, identity mapping. The paper prunes
/// models with subword vocabularies; byte-level keeps the substrate simple
/// while exercising identical model/pruning code paths.
pub fn tokenize(text: &str) -> Vec<i32> {
    text.as_bytes().iter().map(|b| *b as i32).collect()
}

pub fn detokenize(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|t| (*t).clamp(0, 255) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenize_roundtrip() {
        let s = "the quick brown fox 123";
        assert_eq!(detokenize(&tokenize(s)), s);
        assert!(tokenize(s).iter().all(|t| (0..256).contains(t)));
    }
}
