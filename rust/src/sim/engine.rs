//! The cycle model: denser + sparser engines over row-tiled SpMM
//! (ViTCoD Appendix B). Calibrated so a fully-dense matrix on the combined
//! PE budget reproduces the paper's dense-runtime column for LLaMA-7B
//! layer shapes (Table 4) up to a global constant.

use super::csr::Csr;

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// PEs in the denser engine
    pub denser_pes: usize,
    /// PEs in the sparser engine
    pub sparser_pes: usize,
    /// rows of W processed per spatial tile
    pub tile_rows: usize,
    /// dense-operand tokens processed per pass (output-stationary width)
    pub tile_tokens: usize,
    /// column-density threshold (fraction of tile rows) above which a
    /// column is routed to the denser engine
    pub density_threshold: f64,
    /// fixed cycles to load a tile's operands HBM -> on-chip buffers
    pub tile_load_cycles: u64,
    /// total tokens of the activation matrix
    pub tokens: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            denser_pes: 64,
            sparser_pes: 64,
            tile_rows: 64,
            tile_tokens: 64,
            density_threshold: 0.5,
            tile_load_cycles: 32,
            tokens: 64,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct SimResult {
    pub cycles: u64,
    pub denser_macs: u64,
    pub sparser_macs: u64,
    pub tiles: u64,
    /// mean PE utilization over both engines (macs / (pes * busy cycles))
    pub utilization: f64,
}

/// Cycles for the *unpruned* matrix on the same hardware: all columns are
/// maximally dense, so the work is pure dense MACs over all PEs.
pub fn dense_cycles(rows: usize, cols: usize, cfg: &SimConfig) -> u64 {
    let total_pes = (cfg.denser_pes + cfg.sparser_pes) as u64;
    let macs = rows as u64 * cols as u64 * cfg.tokens as u64;
    let row_tiles = rows.div_ceil(cfg.tile_rows) as u64;
    let tok_tiles = cfg.tokens.div_ceil(cfg.tile_tokens) as u64;
    macs.div_ceil(total_pes) + row_tiles * tok_tiles * cfg.tile_load_cycles
}

/// Simulate SpMM of `w` (sparse) against a dense activation of
/// `cfg.tokens` tokens.
pub fn simulate_spmm(w: &Csr, cfg: &SimConfig) -> SimResult {
    let mut res = SimResult::default();
    let tok_tiles = cfg.tokens.div_ceil(cfg.tile_tokens) as u64;
    let mut busy_weighted_macs = 0.0f64;
    let mut busy_cycles_total = 0u64;

    let mut tile_start = 0usize;
    while tile_start < w.rows {
        let tile_end = (tile_start + cfg.tile_rows).min(w.rows);
        let tile_rows = tile_end - tile_start;
        // column nnz inside this row tile
        let mut col_nnz = vec![0u32; w.cols];
        for r in tile_start..tile_end {
            let (lo, hi) = (w.row_ptr[r] as usize, w.row_ptr[r + 1] as usize);
            for k in lo..hi {
                col_nnz[w.col_idx[k] as usize] += 1;
            }
        }
        // density split (Fig. 7): dense columns -> denser engine
        let thresh = (cfg.density_threshold * tile_rows as f64).ceil() as u32;
        let mut denser_nnz = 0u64;
        let mut sparser_nnz = 0u64;
        for &n in &col_nnz {
            if n == 0 {
                continue;
            }
            if n >= thresh {
                denser_nnz += n as u64;
            } else {
                sparser_nnz += n as u64;
            }
        }
        // per token-tile: each engine needs ceil(macs / pes) cycles;
        // engines run concurrently; partial sums flow denser -> sparser
        // accumulator (the transfer overlaps compute, paper Fig. 7).
        let tile_tok = cfg.tile_tokens.min(cfg.tokens) as u64;
        let denser_macs = denser_nnz * tile_tok;
        let sparser_macs = sparser_nnz * tile_tok;
        let denser_cycles = denser_macs.div_ceil(cfg.denser_pes as u64);
        let sparser_cycles = sparser_macs.div_ceil(cfg.sparser_pes as u64);
        let tile_cycles = denser_cycles.max(sparser_cycles) + cfg.tile_load_cycles;

        res.cycles += tile_cycles * tok_tiles;
        res.denser_macs += denser_macs * tok_tiles;
        res.sparser_macs += sparser_macs * tok_tiles;
        res.tiles += tok_tiles;
        busy_weighted_macs += (denser_macs + sparser_macs) as f64 * tok_tiles as f64;
        busy_cycles_total +=
            (denser_cycles.max(sparser_cycles)) * tok_tiles * (cfg.denser_pes + cfg.sparser_pes) as u64;

        tile_start = tile_end;
    }
    res.utilization = if busy_cycles_total > 0 {
        busy_weighted_macs / busy_cycles_total as f64
    } else {
        0.0
    };
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn random_sparse(rows: usize, cols: usize, sparsity: f64, seed: u64) -> Csr {
        let mut rng = Rng::seed(seed);
        let data: Vec<f32> = (0..rows * cols)
            .map(|_| if rng.f64() < sparsity { 0.0 } else { rng.normal_f32() })
            .collect();
        Csr::from_dense(&Tensor::from_f32(&[rows, cols], data))
    }

    #[test]
    fn denser_plus_sparser_covers_all_macs() {
        let w = random_sparse(128, 128, 0.5, 1);
        let cfg = SimConfig::default();
        let res = simulate_spmm(&w, &cfg);
        let tok_tiles = cfg.tokens.div_ceil(cfg.tile_tokens) as u64;
        let expect = w.nnz() as u64 * cfg.tile_tokens.min(cfg.tokens) as u64 * tok_tiles;
        assert_eq!(res.denser_macs + res.sparser_macs, expect);
    }

    #[test]
    fn sparser_matrix_is_faster() {
        let cfg = SimConfig::default();
        let w25 = random_sparse(256, 256, 0.25, 2);
        let w50 = random_sparse(256, 256, 0.50, 2);
        let w75 = random_sparse(256, 256, 0.75, 2);
        let c25 = simulate_spmm(&w25, &cfg).cycles;
        let c50 = simulate_spmm(&w50, &cfg).cycles;
        let c75 = simulate_spmm(&w75, &cfg).cycles;
        assert!(c25 > c50 && c50 > c75, "{c25} {c50} {c75}");
    }

    #[test]
    fn pruned_beats_dense() {
        let cfg = SimConfig::default();
        let w = random_sparse(256, 256, 0.5, 3);
        let sparse = simulate_spmm(&w, &cfg).cycles;
        let dense = dense_cycles(256, 256, &cfg);
        let speedup = dense as f64 / sparse as f64;
        // ~50% sparsity should land near the paper's 1.5-2.0x band
        assert!(speedup > 1.2 && speedup < 2.5, "speedup {speedup}");
    }

    #[test]
    fn empty_matrix_costs_only_loads() {
        let cfg = SimConfig::default();
        let w = Csr::from_dense(&Tensor::zeros(&[64, 64]));
        let res = simulate_spmm(&w, &cfg);
        assert_eq!(res.denser_macs + res.sparser_macs, 0);
        assert_eq!(res.cycles, cfg.tile_load_cycles);
    }

    #[test]
    fn utilization_bounded() {
        let w = random_sparse(128, 344, 0.5, 4);
        let res = simulate_spmm(&w, &SimConfig::default());
        assert!(res.utilization > 0.0 && res.utilization <= 1.0);
    }
}
