//! ViTCoD accelerator cycle simulator (paper §4.5 + Appendix B).
//!
//! The paper evaluates real-hardware speedup of unstructured sparsity on
//! the ViTCoD accelerator's simulator (You et al., HPCA'23): a denser and
//! a sparser engine process sparse-dense matmul (SpMM) workloads in
//! parallel with an output-stationary dataflow. We re-implement that cycle
//! model from the paper's description:
//!
//! * the pruned weight `W [R, C]` is the sparse operand; activations
//!   `X [C, T]` are dense; the engines tile `W` spatially over rows and
//!   accumulate partial sums over C (Fig. 6).
//! * per tile, columns are *split by density*: denser columns go to the
//!   denser engine's PE array, sparser columns to the sparser engine
//!   (Fig. 7); both engines run concurrently and the tile finishes when
//!   the slower engine does.
//! * cycles per engine = ceil(assigned nnz MACs / (PEs * tokens-per-pass)),
//!   plus a fixed per-tile load latency for the HBM→buffer transfer.

pub mod csr;
pub mod engine;
pub mod report;

// The packed format itself lives in `crate::sparse` (shared with the
// `serve` engine, which executes the SpMM the cycle model only costs).
pub use csr::Csr;
pub use engine::{SimConfig, SimResult, simulate_spmm, dense_cycles};
pub use report::{simulate_layer, simulate_block, LayerSim};
