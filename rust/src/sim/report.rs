//! Table-4 style reporting: per-layer runtime (cycles) and speedup for a
//! pruned model on the ViTCoD simulator, averaged across blocks.

use anyhow::Result;

use crate::model::{ModelConfig, ParamStore, LAYER_NAMES};
use crate::tensor::Tensor;

use super::csr::Csr;
use super::engine::{dense_cycles, simulate_spmm, SimConfig};

#[derive(Debug, Clone)]
pub struct LayerSim {
    pub layer: String,
    pub rows: usize,
    pub cols: usize,
    pub sparsity: f64,
    pub dense_cycles: u64,
    pub sparse_cycles: u64,
    pub speedup: f64,
    pub utilization: f64,
}

/// Simulate one pruned weight matrix.
pub fn simulate_layer(name: &str, w: &Tensor, cfg: &SimConfig) -> LayerSim {
    let csr = Csr::from_dense(w);
    let res = simulate_spmm(&csr, cfg);
    let dense = dense_cycles(csr.rows, csr.cols, cfg);
    LayerSim {
        layer: name.to_string(),
        rows: csr.rows,
        cols: csr.cols,
        sparsity: csr.sparsity(),
        dense_cycles: dense,
        sparse_cycles: res.cycles,
        speedup: dense as f64 / res.cycles.max(1) as f64,
        utilization: res.utilization,
    }
}

/// Average per-layer simulation across all transformer blocks of a pruned
/// model (the paper reports block-averaged runtimes, Table 4).
pub fn simulate_block(
    params: &ParamStore,
    cfg: &ModelConfig,
    sim: &SimConfig,
) -> Result<Vec<LayerSim>> {
    let mut out = Vec::new();
    for w in LAYER_NAMES {
        let mut sparse_cycles = 0u64;
        let mut dense_c = 0u64;
        let mut sparsity = 0.0f64;
        let mut util = 0.0f64;
        let mut rows = 0;
        let mut cols = 0;
        for l in 0..cfg.n_blocks {
            let t = params.get(&ParamStore::layer_name(l, w))?;
            let s = simulate_layer(w, t, sim);
            sparse_cycles += s.sparse_cycles;
            dense_c += s.dense_cycles;
            sparsity += s.sparsity;
            util += s.utilization;
            rows = s.rows;
            cols = s.cols;
        }
        let n = cfg.n_blocks as f64;
        out.push(LayerSim {
            layer: w.to_string(),
            rows,
            cols,
            sparsity: sparsity / n,
            dense_cycles: dense_c / cfg.n_blocks as u64,
            sparse_cycles: sparse_cycles / cfg.n_blocks as u64,
            speedup: dense_c as f64 / sparse_cycles.max(1) as f64,
            utilization: util / n,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn layer_sim_fields_consistent() {
        let mut rng = Rng::seed(1);
        let data: Vec<f32> =
            (0..64 * 88).map(|_| if rng.f64() < 0.5 { 0.0 } else { 1.0 }).collect();
        let w = Tensor::from_f32(&[64, 88], data);
        let s = simulate_layer("wq", &w, &SimConfig::default());
        assert_eq!(s.rows, 64);
        assert!((s.sparsity - 0.5).abs() < 0.05);
        assert!(s.speedup > 1.0);
        assert_eq!(s.speedup, s.dense_cycles as f64 / s.sparse_cycles as f64);
    }
}
