//! Re-export shim: the CSR format moved to [`crate::sparse`] so the
//! serving engine and the cycle simulator share one packed representation.
//! Existing `sim::csr::Csr` / `sim::Csr` paths keep working.

pub use crate::sparse::csr::{Csr, QuantCsr};
