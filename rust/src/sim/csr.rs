//! Compressed sparse row structure for pruned weight matrices.

use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from a dense tensor, treating exact zeros as pruned.
    pub fn from_dense(t: &Tensor) -> Csr {
        assert_eq!(t.shape.len(), 2);
        let (rows, cols) = (t.shape[0], t.shape[1]);
        let data = t.f32s();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for r in 0..rows {
            for c in 0..cols {
                let v = data[r * cols + c];
                if v != 0.0 {
                    col_idx.push(c as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Csr { rows, cols, row_ptr, col_idx, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    pub fn row_nnz(&self, r: usize) -> usize {
        (self.row_ptr[r + 1] - self.row_ptr[r]) as usize
    }

    /// nnz per column (used for the denser/sparser split).
    pub fn col_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.cols];
        for c in &self.col_idx {
            counts[*c as usize] += 1;
        }
        counts
    }

    /// SpMM y = W x for a dense x [cols, t] — correctness reference used to
    /// check the simulator handles the same nnz the math does.
    pub fn spmm(&self, x: &[f32], t: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.cols * t);
        let mut y = vec![0.0f32; self.rows * t];
        for r in 0..self.rows {
            let (lo, hi) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            for k in lo..hi {
                let c = self.col_idx[k] as usize;
                let v = self.values[k];
                let xrow = &x[c * t..(c + 1) * t];
                let yrow = &mut y[r * t..(r + 1) * t];
                for j in 0..t {
                    yrow[j] += v * xrow[j];
                }
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_dense_roundtrip() {
        let t = Tensor::from_f32(&[2, 3], vec![1.0, 0.0, 2.0, 0.0, 0.0, 3.0]);
        let c = Csr::from_dense(&t);
        assert_eq!(c.nnz(), 3);
        assert_eq!(c.row_nnz(0), 2);
        assert_eq!(c.row_nnz(1), 1);
        assert_eq!(c.col_counts(), vec![1, 0, 2]);
        assert!((c.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn spmm_matches_dense() {
        let w = Tensor::from_f32(&[2, 3], vec![1.0, 0.0, 2.0, -1.0, 0.5, 0.0]);
        let c = Csr::from_dense(&w);
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [3, 2]
        let y = c.spmm(&x, 2);
        // row0 = 1*[1,2] + 2*[5,6] = [11, 14]; row1 = -1*[1,2]+0.5*[3,4] = [0.5, 0]
        assert_eq!(y, vec![11.0, 14.0, 0.5, 0.0]);
    }
}
