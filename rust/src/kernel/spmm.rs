//! The CSR SpMM lane: `Y^T[rows, t] = W · X^T[cols, t]` over shared CSR
//! structure, parameterized by a value accessor (plain f32 values or
//! fused dequant — see `crate::sparse::spmm`). Scalar reference plus a
//! stripe-register-blocked micro kernel, bitwise equal: each output
//! element accumulates its row's stored nonzeros in ascending `k`
//! (ascending-column) order in both, which is the contract that makes
//! CSR serving reproduce the dense matmul bit for bit.

use super::{mode, Mode};

/// Token-dim stripe held in registers by the micro kernel (eight 128-bit
/// f32 vectors).
const TW: usize = 32;

/// Reference kernel: per stored nonzero, one AXPY of `value(k) · x_row`
/// into the output row — the output element round-trips through memory
/// on every nonzero.
pub fn spmm_rows_scalar<V: Fn(usize) -> f32>(
    row_ptr: &[u32],
    col_idx: &[u32],
    value: V,
    x: &[f32],
    t: usize,
    lo_row: usize,
    hi_row: usize,
    out: &mut [f32],
) {
    for r in lo_row..hi_row {
        let yrow = &mut out[(r - lo_row) * t..(r - lo_row + 1) * t];
        let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
        for k in lo..hi {
            let c = col_idx[k] as usize;
            let v = value(k);
            let xrow = &x[c * t..(c + 1) * t];
            for (yv, xv) in yrow.iter_mut().zip(xrow) {
                *yv += v * xv;
            }
        }
    }
}

/// Micro kernel: loop order swapped to stripe-outer / nonzero-inner. A
/// [`TW`]-wide stripe of the output row stays in registers while *all*
/// of the row's nonzeros stream past in ascending-k order — no output
/// load/store per nonzero (the reference pays two y memory ops per
/// nonzero per lane) and [`TW`]/4 independent vector accumulator chains
/// instead of a store-forwarding chain. Per-element accumulation order
/// is unchanged, so the result is bitwise equal to [`spmm_rows_scalar`]
/// for both value accessors (the dequant accessor is a pure function of
/// `k` — re-evaluating it per stripe yields identical values).
pub fn spmm_rows_micro<V: Fn(usize) -> f32>(
    row_ptr: &[u32],
    col_idx: &[u32],
    value: V,
    x: &[f32],
    t: usize,
    lo_row: usize,
    hi_row: usize,
    out: &mut [f32],
) {
    for r in lo_row..hi_row {
        let yrow = &mut out[(r - lo_row) * t..(r - lo_row + 1) * t];
        let (lo, hi) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
        let mut tc = 0;
        while tc + TW <= t {
            let mut acc = [0.0f32; TW];
            for k in lo..hi {
                let c = col_idx[k] as usize;
                let v = value(k);
                let xrow = &x[c * t + tc..c * t + tc + TW];
                for u in 0..TW {
                    acc[u] += v * xrow[u];
                }
            }
            yrow[tc..tc + TW].copy_from_slice(&acc);
            tc += TW;
        }
        if tc < t {
            let tw = t - tc;
            let mut acc = [0.0f32; TW];
            for k in lo..hi {
                let c = col_idx[k] as usize;
                let v = value(k);
                let xrow = &x[c * t + tc..c * t + tc + tw];
                for u in 0..tw {
                    acc[u] += v * xrow[u];
                }
            }
            yrow[tc..].copy_from_slice(&acc[..tw]);
        }
    }
}

/// Dispatching row-range SpMM — the one sparse inner loop in the crate
/// (`crate::sparse::spmm` routes both the plain and fused-dequant
/// drivers through it).
///
/// `out` must be zeroed by the caller: the reference accumulates into it
/// while the micro kernel overwrites each stripe, so the two agree (and
/// the result is well-defined) only from a zero start.
pub fn spmm_rows<V: Fn(usize) -> f32>(
    row_ptr: &[u32],
    col_idx: &[u32],
    value: V,
    x: &[f32],
    t: usize,
    lo_row: usize,
    hi_row: usize,
    out: &mut [f32],
) {
    match mode() {
        Mode::Scalar => spmm_rows_scalar(row_ptr, col_idx, value, x, t, lo_row, hi_row, out),
        Mode::Micro => spmm_rows_micro(row_ptr, col_idx, value, x, t, lo_row, hi_row, out),
    }
}
