//! Fused / allocation-free composites for the decode hot loop:
//! RMSNorm into caller scratch, and RMSNorm+matvec in one call (the
//! `block_fwd_cached` per-token path: normalize once into a scratch row,
//! run the first projection immediately, let the caller reuse the
//! normalized row for the sibling projections).
//!
//! The RMSNorm sum-of-squares is deliberately a single serial chain in
//! both kernel modes: its reduction order is part of the cross-path
//! bitwise contract (prefill rows, cached decode and the training-side
//! `ops::rmsnorm` must agree bit for bit), and at `O(d)` per row it is
//! noise next to the `O(d·n)` matmuls it feeds.

use super::gemm;

/// RMSNorm rows of length `d` into a caller buffer:
/// `out = x / sqrt(mean(x²) + eps) * gain`. Identical arithmetic and
/// reduction order in both kernel modes.
pub fn rmsnorm_into(x: &[f32], gain: &[f32], d: usize, eps: f64, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (xr, yr) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let var: f32 = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (var + eps as f32).sqrt();
        for ((yv, xv), gv) in yr.iter_mut().zip(xr).zip(gain) {
            *yv = xv * r * gv;
        }
    }
}

/// Fused RMSNorm + matvec for one `[d]` activation row: normalizes into
/// `h` (caller scratch, reusable for the sibling projections of the same
/// normalized activation via [`gemm::matvec_into`]) and immediately runs
/// `out[rows] = h @ w[rows,d]^T` while `h` is cache-hot. Bitwise equal
/// to `rmsnorm` followed by `mm_nt(m=1)` — the fusion removes the two
/// intermediate allocations of the unfused path, not any arithmetic.
pub fn rmsnorm_matvec(
    x: &[f32],
    gain: &[f32],
    eps: f64,
    h: &mut [f32],
    w: &[f32],
    rows: usize,
    out: &mut [f32],
) {
    let d = x.len();
    rmsnorm_into(x, gain, d, eps, h);
    gemm::matvec_into(h, w, d, rows, out);
}
