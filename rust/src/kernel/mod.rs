//! Shared microkernel layer: every dense/sparse inner loop in the crate
//! in two pinned implementations — `scalar` (the reference loops the
//! golden vectors were generated against) and `micro` (cache-blocked,
//! register-tiled, hand-unrolled for autovectorization; no new deps, no
//! intrinsics, no `unsafe`).
//!
//! # The accumulation-order contract
//!
//! The repo pins *bitwise* cross-path equalities end to end: CSR serving
//! == dense serving, KV-cached decode == full-prefix recompute,
//! `block_fwd_cached` == prefill rows, quant CSR == fake-quant dense
//! (`tests/serve_parity.rs`). All of them hold for one reason — every
//! path accumulates each output element **in ascending reduction-index
//! order** (ascending k / column / position), so dropping exact-zero
//! terms or splitting work across rows never reassociates a float sum.
//!
//! The micro kernels keep that contract: they tile over *output*
//! elements (register blocks of rows × lanes) and stream the reduction
//! dimension through each block in ascending order, so every output
//! element sees the same multiplies and adds in the same order as the
//! scalar reference — `micro` is **bitwise equal** to `scalar` for every
//! kernel in this module, and all existing parity tests run unchanged
//! with `micro` as the default. The speedup comes from instruction-level
//! parallelism *across* independent output elements (the scalar loops
//! are serial FP dependency chains the compiler cannot reassociate) and
//! from keeping accumulators in registers instead of round-tripping
//! through memory per reduction step — not from reordering any sum.
//!
//! Per-kernel parity policy (enforced by `tests/kernel_parity.rs`, see
//! `docs/kernels.md` for rationale): **bitwise for every kernel**. The
//! tolerance class the policy reserves for reduction-reordering tilings
//! is intentionally unused — in this codebase a reordered reduction
//! would forfeit the cross-path bitwise invariants above, which are
//! worth more than the last fraction of throughput.
//!
//! # Selection
//!
//! `BESA_KERNEL=scalar|micro` (default `micro`), read once per process.
//! The `*_scalar` / `*_micro` entry points stay public so tests and
//! `besa kernel-bench` can pin both paths inside one process.

use std::sync::OnceLock;

pub mod attn;
pub mod fused;
pub mod gemm;
pub mod spmm;

/// Which implementation the dispatching entry points run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Reference loops (golden-vector generation order).
    Scalar,
    /// Register-blocked kernels, bitwise equal to `Scalar` (see module
    /// docs). The default.
    Micro,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::Scalar => "scalar",
            Mode::Micro => "micro",
        }
    }
}

/// Process-wide kernel selection: `BESA_KERNEL=scalar` opts into the
/// reference loops; anything else (including unset) is `Micro`. Cached in
/// a `OnceLock` so hot paths pay one relaxed load, not an env lookup.
pub fn mode() -> Mode {
    static MODE: OnceLock<Mode> = OnceLock::new();
    *MODE.get_or_init(|| match std::env::var("BESA_KERNEL") {
        Ok(v) if v == "scalar" => Mode::Scalar,
        _ => Mode::Micro,
    })
}
