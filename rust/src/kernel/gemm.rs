//! Dense matmul family. One scalar reference and one register-blocked
//! micro implementation per entry point, bitwise-identical by the
//! accumulation-order contract in [`crate::kernel`]: every output
//! element is a single ascending-k chain of `mul` + `add` (Rust never
//! contracts those into an FMA), so the micro tilings — which only
//! regroup *independent* output elements into register blocks — produce
//! the exact bits of the scalar loops.

use super::{mode, Mode};

/// Rows per register block of the packed `mm_nt` kernel.
const MR: usize = 4;
/// Output lanes per packed panel (two 128-bit f32 vectors).
const NR: usize = 8;
/// Reduction-dim tile: `KC · NR · 4` bytes of panel (16 KiB) stays
/// L1-resident while the row loop streams over `x`.
const KC: usize = 512;
/// Register-resident output chunk of the `mm_nn` / `mm_tn` /
/// f64-`matmul` stream kernels (eight 128-bit f32 vectors).
const CH: usize = 32;
/// f64 variant of [`CH`] (same eight 128-bit vectors).
const CHD: usize = 16;

// ---------------------------------------------------------------------------
// mm_nt: y[M,N] = x[M,K] @ w[N,K]^T
// ---------------------------------------------------------------------------

/// Reference `y[M,N] = x[M,K] @ w[N,K]^T`: one serial ascending-k dot
/// chain per output element (the golden-vector order).
pub fn mm_nt_scalar(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), n * k);
    let mut y = vec![0.0f32; m * n];
    for i in 0..m {
        let xi = &x[i * k..(i + 1) * k];
        let yi = &mut y[i * n..(i + 1) * n];
        for (j, yj) in yi.iter_mut().enumerate() {
            let wj = &w[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (a, b) in xi.iter().zip(wj) {
                acc += a * b;
            }
            *yj = acc;
        }
    }
    y
}

/// Pack `w[N,K]` into k-major panels of [`NR`] adjacent output rows
/// (`panel[jp][kk][jj] = w[jp·NR + jj][kk]`, tail rows zero-padded), so
/// the inner kernel reads one contiguous [`NR`]-lane vector per k step.
fn pack_panels(w: &[f32], n: usize, k: usize) -> Vec<f32> {
    let np = n.div_ceil(NR);
    let mut p = vec![0.0f32; np * k * NR];
    for jp in 0..np {
        let panel = &mut p[jp * k * NR..(jp + 1) * k * NR];
        let jw = (n - jp * NR).min(NR);
        for jj in 0..jw {
            let row = &w[(jp * NR + jj) * k..(jp * NR + jj + 1) * k];
            for (kk, v) in row.iter().enumerate() {
                panel[kk * NR + jj] = *v;
            }
        }
    }
    p
}

/// Micro `mm_nt`: packed cache-tiled outer-product kernel. An
/// [`MR`]`×`[`NR`] register block of output elements advances through
/// the k dimension together (ascending, tiled by [`KC`] with partial
/// sums parked in `y` between tiles), giving `MR·NR` independent FP
/// chains where the scalar loop has one — bitwise equal to
/// [`mm_nt_scalar`] because each element's chain is unchanged.
///
/// Row counts below [`MR`] skip the packing (which would cost as much
/// as the multiply) and take the [`matvec_micro_into`] lane instead —
/// same ascending-k order, so decode (`m=1`) and prefill (`m=s`) agree
/// bitwise, which `greedy_cached == greedy_recompute` depends on.
pub fn mm_nt_micro(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), n * k);
    let mut y = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return y;
    }
    if m < MR {
        for i in 0..m {
            matvec_micro_into(&x[i * k..(i + 1) * k], w, k, n, &mut y[i * n..(i + 1) * n]);
        }
        return y;
    }
    let packed = pack_panels(w, n, k);
    let np = n.div_ceil(NR);
    let mut kc = 0;
    while kc < k.max(1) {
        let kw = (k - kc).min(KC);
        for jp in 0..np {
            let panel = &packed[(jp * k + kc) * NR..(jp * k + kc + kw) * NR];
            let j0 = jp * NR;
            let jw = (n - j0).min(NR);
            let mut i = 0;
            while i + MR <= m {
                let mut acc = [[0.0f32; NR]; MR];
                if kc > 0 {
                    for (r, ar) in acc.iter_mut().enumerate() {
                        ar[..jw].copy_from_slice(&y[(i + r) * n + j0..(i + r) * n + j0 + jw]);
                    }
                }
                for kk in 0..kw {
                    let wrow = &panel[kk * NR..kk * NR + NR];
                    for (r, ar) in acc.iter_mut().enumerate() {
                        let xv = x[(i + r) * k + kc + kk];
                        for c in 0..NR {
                            ar[c] += xv * wrow[c];
                        }
                    }
                }
                for (r, ar) in acc.iter().enumerate() {
                    y[(i + r) * n + j0..(i + r) * n + j0 + jw].copy_from_slice(&ar[..jw]);
                }
                i += MR;
            }
            while i < m {
                let mut acc = [0.0f32; NR];
                if kc > 0 {
                    acc[..jw].copy_from_slice(&y[i * n + j0..i * n + j0 + jw]);
                }
                for kk in 0..kw {
                    let wrow = &panel[kk * NR..kk * NR + NR];
                    let xv = x[i * k + kc + kk];
                    for c in 0..NR {
                        acc[c] += xv * wrow[c];
                    }
                }
                y[i * n + j0..i * n + j0 + jw].copy_from_slice(&acc[..jw]);
                i += 1;
            }
        }
        kc += KC.max(1);
        if k == 0 {
            break;
        }
    }
    y
}

/// Dispatching `mm_nt` (the linear-layer kernel every caller routes
/// through): [`mm_nt_micro`] by default, [`mm_nt_scalar`] under
/// `BESA_KERNEL=scalar`. Both produce identical bits.
pub fn mm_nt(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    match mode() {
        Mode::Scalar => mm_nt_scalar(x, w, m, k, n),
        Mode::Micro => mm_nt_micro(x, w, m, k, n),
    }
}

// ---------------------------------------------------------------------------
// matvec: y[N] = x[K] @ w[N,K]^T (the decode fast path, m == 1)
// ---------------------------------------------------------------------------

/// Reference single-row `mm_nt` writing into a caller buffer.
pub fn matvec_scalar_into(x: &[f32], w: &[f32], k: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(y.len(), n);
    for (j, yj) in y.iter_mut().enumerate() {
        let wj = &w[j * k..(j + 1) * k];
        let mut acc = 0.0f32;
        for (a, b) in x.iter().zip(wj) {
            acc += a * b;
        }
        *yj = acc;
    }
}

/// Micro matvec: four output dots advance in lock-step, four independent
/// scalar FP chains where the reference has one. Each dot is still a
/// single ascending-k chain — bitwise equal to [`matvec_scalar_into`].
pub fn matvec_micro_into(x: &[f32], w: &[f32], k: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(y.len(), n);
    let x = &x[..k];
    let mut j = 0;
    while j + 4 <= n {
        let w0 = &w[j * k..j * k + k];
        let w1 = &w[(j + 1) * k..(j + 1) * k + k];
        let w2 = &w[(j + 2) * k..(j + 2) * k + k];
        let w3 = &w[(j + 3) * k..(j + 3) * k + k];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for kk in 0..k {
            let xv = x[kk];
            a0 += xv * w0[kk];
            a1 += xv * w1[kk];
            a2 += xv * w2[kk];
            a3 += xv * w3[kk];
        }
        y[j] = a0;
        y[j + 1] = a1;
        y[j + 2] = a2;
        y[j + 3] = a3;
        j += 4;
    }
    while j < n {
        let wj = &w[j * k..j * k + k];
        let mut acc = 0.0f32;
        for kk in 0..k {
            acc += x[kk] * wj[kk];
        }
        y[j] = acc;
        j += 1;
    }
}

/// Dispatching single-row linear into a caller buffer — the alloc-free
/// decode path (`block_fwd_cached`, fused RMSNorm+matvec).
pub fn matvec_into(x: &[f32], w: &[f32], k: usize, n: usize, y: &mut [f32]) {
    match mode() {
        Mode::Scalar => matvec_scalar_into(x, w, k, n, y),
        Mode::Micro => matvec_micro_into(x, w, k, n, y),
    }
}

// ---------------------------------------------------------------------------
// mm_nn: dx[M,K] = g[M,N] @ w[N,K]
// ---------------------------------------------------------------------------

/// Reference `dx[M,K] = g[M,N] @ w[N,K]`: ascending-j AXPY sweep per row,
/// skipping exact-zero `g` entries (which is bitwise-neutral: adding a
/// `0.0·w` term never changes a finite partial sum's bits).
pub fn mm_nn_scalar(g: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(w.len(), n * k);
    let mut dx = vec![0.0f32; m * k];
    for i in 0..m {
        let gi = &g[i * n..(i + 1) * n];
        let di = &mut dx[i * k..(i + 1) * k];
        for (j, gj) in gi.iter().enumerate() {
            if *gj == 0.0 {
                continue;
            }
            let wj = &w[j * k..(j + 1) * k];
            for (d, wv) in di.iter_mut().zip(wj) {
                *d += gj * wv;
            }
        }
    }
    dx
}

/// Micro `mm_nn`: a [`CH`]-wide chunk of the output row lives in
/// registers while the whole ascending-j reduction streams past it —
/// eliminating the per-j load/store round-trip of the reference AXPY.
/// Same per-element ascending-j order and zero-skip: bitwise equal.
pub fn mm_nn_micro(g: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(w.len(), n * k);
    let mut dx = vec![0.0f32; m * k];
    for i in 0..m {
        let gi = &g[i * n..(i + 1) * n];
        let di = &mut dx[i * k..(i + 1) * k];
        let mut kc = 0;
        while kc + CH <= k {
            let mut acc = [0.0f32; CH];
            for (j, gj) in gi.iter().enumerate() {
                if *gj == 0.0 {
                    continue;
                }
                let wrow = &w[j * k + kc..j * k + kc + CH];
                for c in 0..CH {
                    acc[c] += gj * wrow[c];
                }
            }
            di[kc..kc + CH].copy_from_slice(&acc);
            kc += CH;
        }
        if kc < k {
            let kw = k - kc;
            let mut acc = [0.0f32; CH];
            for (j, gj) in gi.iter().enumerate() {
                if *gj == 0.0 {
                    continue;
                }
                let wrow = &w[j * k + kc..j * k + kc + kw];
                for c in 0..kw {
                    acc[c] += gj * wrow[c];
                }
            }
            di[kc..].copy_from_slice(&acc[..kw]);
        }
    }
    dx
}

/// Dispatching `dx = g @ w` (linear-layer input gradient).
pub fn mm_nn(g: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    match mode() {
        Mode::Scalar => mm_nn_scalar(g, w, m, n, k),
        Mode::Micro => mm_nn_micro(g, w, m, n, k),
    }
}

// ---------------------------------------------------------------------------
// mm_tn: gw[N,K] = g[M,N]^T @ x[M,K]
// ---------------------------------------------------------------------------

/// Reference `gw[N,K] = g[M,N]^T @ x[M,K]`: ascending-i AXPY sweep,
/// zero-skip on `g` (bitwise-neutral, as in [`mm_nn_scalar`]).
pub fn mm_tn_scalar(g: &[f32], x: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(x.len(), m * k);
    let mut gw = vec![0.0f32; n * k];
    for i in 0..m {
        let gi = &g[i * n..(i + 1) * n];
        let xi = &x[i * k..(i + 1) * k];
        for (j, gj) in gi.iter().enumerate() {
            if *gj == 0.0 {
                continue;
            }
            let row = &mut gw[j * k..(j + 1) * k];
            for (d, xv) in row.iter_mut().zip(xi) {
                *d += gj * xv;
            }
        }
    }
    gw
}

/// Micro `mm_tn`: loops reordered to k-chunk-outer / output-row / i so a
/// [`CH`]-wide output chunk stays register-resident through the whole
/// ascending-i reduction, and the `x` column chunk is reused across all
/// `n` output rows from cache. Per-element order and zero-skip match the
/// reference: bitwise equal.
pub fn mm_tn_micro(g: &[f32], x: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(x.len(), m * k);
    let mut gw = vec![0.0f32; n * k];
    let mut kc = 0;
    while kc < k {
        let kw = (k - kc).min(CH);
        if kw == CH {
            for j in 0..n {
                let mut acc = [0.0f32; CH];
                for i in 0..m {
                    let gij = g[i * n + j];
                    if gij == 0.0 {
                        continue;
                    }
                    let xrow = &x[i * k + kc..i * k + kc + CH];
                    for c in 0..CH {
                        acc[c] += gij * xrow[c];
                    }
                }
                gw[j * k + kc..j * k + kc + CH].copy_from_slice(&acc);
            }
        } else {
            for j in 0..n {
                let mut acc = [0.0f32; CH];
                for i in 0..m {
                    let gij = g[i * n + j];
                    if gij == 0.0 {
                        continue;
                    }
                    let xrow = &x[i * k + kc..i * k + kc + kw];
                    for c in 0..kw {
                        acc[c] += gij * xrow[c];
                    }
                }
                gw[j * k + kc..j * k + kc + kw].copy_from_slice(&acc[..kw]);
            }
        }
        kc += CH;
    }
    gw
}

/// Dispatching `gw = g^T @ x` (linear-layer weight gradient).
pub fn mm_tn(g: &[f32], x: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    match mode() {
        Mode::Scalar => mm_tn_scalar(g, x, m, n, k),
        Mode::Micro => mm_tn_micro(g, x, m, n, k),
    }
}

// ---------------------------------------------------------------------------
// f64 matmul (linalg substrate: SparseGPT's Hessian algebra)
// ---------------------------------------------------------------------------

/// Reference f64 `y[M,N] = a[M,K] @ b[K,N]`: ascending-k AXPY sweep per
/// row with zero-skip on `a` (the historical `linalg::Mat::matmul` loop).
pub fn matmul_f64_scalar(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut y = vec![0.0f64; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let dst = &mut y[i * n..(i + 1) * n];
            for j in 0..n {
                dst[j] += av * brow[j];
            }
        }
    }
    y
}

/// Micro f64 matmul: [`CHD`]-wide register-resident output chunks per
/// ascending-k sweep (the f64 twin of [`mm_nn_micro`]). Bitwise equal to
/// [`matmul_f64_scalar`].
pub fn matmul_f64_micro(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut y = vec![0.0f64; m * n];
    for i in 0..m {
        let ai = &a[i * k..(i + 1) * k];
        let yi = &mut y[i * n..(i + 1) * n];
        let mut jc = 0;
        while jc + CHD <= n {
            let mut acc = [0.0f64; CHD];
            for (kk, av) in ai.iter().enumerate() {
                if *av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n + jc..kk * n + jc + CHD];
                for c in 0..CHD {
                    acc[c] += av * brow[c];
                }
            }
            yi[jc..jc + CHD].copy_from_slice(&acc);
            jc += CHD;
        }
        if jc < n {
            let jw = n - jc;
            let mut acc = [0.0f64; CHD];
            for (kk, av) in ai.iter().enumerate() {
                if *av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n + jc..kk * n + jc + jw];
                for c in 0..jw {
                    acc[c] += av * brow[c];
                }
            }
            yi[jc..].copy_from_slice(&acc[..jw]);
        }
    }
    y
}

/// Dispatching f64 matmul (routes `linalg::Mat::matmul`).
pub fn matmul_f64(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    match mode() {
        Mode::Scalar => matmul_f64_scalar(a, b, m, k, n),
        Mode::Micro => matmul_f64_micro(a, b, m, k, n),
    }
}
