//! Attention inner loops: query·key score rows and probability-weighted
//! value sums, shared by the fixed-shape `ops::attention`, the serving
//! prefill (`attention_causal`) and the KV-cached decode row — all three
//! call these with their own key/value stride, so every attention path
//! in the crate accumulates in the same ascending-position order (the
//! cached == recompute bitwise invariant).

use super::{mode, Mode};

/// Reference score row: `out[j] = q · kmat[j·stride+off ..][..dh]` for
/// `j < n`, one serial dot chain per key.
pub fn dots_scalar(q: &[f32], kmat: &[f32], stride: usize, off: usize, n: usize, out: &mut [f32]) {
    let dh = q.len();
    for (j, o) in out.iter_mut().enumerate().take(n) {
        let kj = &kmat[j * stride + off..j * stride + off + dh];
        let mut dot = 0.0f32;
        for (a, b) in q.iter().zip(kj) {
            dot += a * b;
        }
        *o = dot;
    }
}

/// Micro score row: four keys advance in lock-step (four independent
/// chains); each dot is still a single ascending-feature chain, so every
/// `out[j]` matches [`dots_scalar`] bitwise.
pub fn dots_micro(q: &[f32], kmat: &[f32], stride: usize, off: usize, n: usize, out: &mut [f32]) {
    let dh = q.len();
    let mut j = 0;
    while j + 4 <= n {
        let k0 = &kmat[j * stride + off..j * stride + off + dh];
        let k1 = &kmat[(j + 1) * stride + off..(j + 1) * stride + off + dh];
        let k2 = &kmat[(j + 2) * stride + off..(j + 2) * stride + off + dh];
        let k3 = &kmat[(j + 3) * stride + off..(j + 3) * stride + off + dh];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for (tt, qv) in q.iter().enumerate() {
            a0 += qv * k0[tt];
            a1 += qv * k1[tt];
            a2 += qv * k2[tt];
            a3 += qv * k3[tt];
        }
        out[j] = a0;
        out[j + 1] = a1;
        out[j + 2] = a2;
        out[j + 3] = a3;
        j += 4;
    }
    while j < n {
        let kj = &kmat[j * stride + off..j * stride + off + dh];
        let mut dot = 0.0f32;
        for (a, b) in q.iter().zip(kj) {
            dot += a * b;
        }
        out[j] = dot;
        j += 1;
    }
}

/// Dispatching score row.
pub fn dots(q: &[f32], kmat: &[f32], stride: usize, off: usize, n: usize, out: &mut [f32]) {
    match mode() {
        Mode::Scalar => dots_scalar(q, kmat, stride, off, n, out),
        Mode::Micro => dots_micro(q, kmat, stride, off, n, out),
    }
}

/// One query·key dot (the "new key" term of the cached decode row).
/// Single ascending-feature chain in both modes by definition.
pub fn dot1(q: &[f32], kj: &[f32]) -> f32 {
    let mut dot = 0.0f32;
    for (a, b) in q.iter().zip(kj) {
        dot += a * b;
    }
    dot
}

/// Reference weighted value sum: `out[u] += Σ_j p[j] · vmat[j·stride+off+u]`
/// as a j-outer AXPY sweep — `out` round-trips through memory per key.
pub fn wsum_scalar(out: &mut [f32], p: &[f32], vmat: &[f32], stride: usize, off: usize) {
    let dh = out.len();
    for (j, pj) in p.iter().enumerate() {
        let vj = &vmat[j * stride + off..j * stride + off + dh];
        for (ov, vv) in out.iter_mut().zip(vj) {
            *ov += pj * vv;
        }
    }
}

/// Micro weighted value sum: the output head (≤ 16-lane chunks) stays in
/// registers while all keys stream past in ascending-j order — same
/// per-element order as [`wsum_scalar`], bitwise equal.
pub fn wsum_micro(out: &mut [f32], p: &[f32], vmat: &[f32], stride: usize, off: usize) {
    const CW: usize = 16;
    let dh = out.len();
    let mut c0 = 0;
    while c0 < dh {
        let cw = (dh - c0).min(CW);
        let mut acc = [0.0f32; CW];
        acc[..cw].copy_from_slice(&out[c0..c0 + cw]);
        if cw == CW {
            for (j, pj) in p.iter().enumerate() {
                let vj = &vmat[j * stride + off + c0..j * stride + off + c0 + CW];
                for u in 0..CW {
                    acc[u] += pj * vj[u];
                }
            }
        } else {
            for (j, pj) in p.iter().enumerate() {
                let vj = &vmat[j * stride + off + c0..j * stride + off + c0 + cw];
                for u in 0..cw {
                    acc[u] += pj * vj[u];
                }
            }
        }
        out[c0..c0 + cw].copy_from_slice(&acc[..cw]);
        c0 += CW;
    }
}

/// Dispatching weighted value sum.
pub fn wsum(out: &mut [f32], p: &[f32], vmat: &[f32], stride: usize, off: usize) {
    match mode() {
        Mode::Scalar => wsum_scalar(out, p, vmat, stride, off),
        Mode::Micro => wsum_micro(out, p, vmat, stride, off),
    }
}

/// `out[u] += a · v[u]` — the single-key tail of the cached decode row
/// (the new key/value at the decoded position). Elementwise; one add per
/// element in either mode, so there is nothing to reorder.
pub fn axpy(out: &mut [f32], a: f32, v: &[f32]) {
    for (ov, vv) in out.iter_mut().zip(v) {
        *ov += a * vv;
    }
}
