//! Attention inner loops: query·key score rows and probability-weighted
//! value sums, shared by the fixed-shape `ops::attention`, the serving
//! prefill (`attention_causal`) and the KV-cached decode row — all three
//! call these with their own key/value stride, so every attention path
//! in the crate accumulates in the same ascending-position order (the
//! cached == recompute bitwise invariant).

use super::{mode, Mode};

/// Reference score row: `out[j] = q · kmat[j·stride+off ..][..dh]` for
/// `j < n`, one serial dot chain per key.
pub fn dots_scalar(q: &[f32], kmat: &[f32], stride: usize, off: usize, n: usize, out: &mut [f32]) {
    let dh = q.len();
    for (j, o) in out.iter_mut().enumerate().take(n) {
        let kj = &kmat[j * stride + off..j * stride + off + dh];
        let mut dot = 0.0f32;
        for (a, b) in q.iter().zip(kj) {
            dot += a * b;
        }
        *o = dot;
    }
}

/// Micro score row: four keys advance in lock-step (four independent
/// chains); each dot is still a single ascending-feature chain, so every
/// `out[j]` matches [`dots_scalar`] bitwise.
pub fn dots_micro(q: &[f32], kmat: &[f32], stride: usize, off: usize, n: usize, out: &mut [f32]) {
    let dh = q.len();
    let mut j = 0;
    while j + 4 <= n {
        let k0 = &kmat[j * stride + off..j * stride + off + dh];
        let k1 = &kmat[(j + 1) * stride + off..(j + 1) * stride + off + dh];
        let k2 = &kmat[(j + 2) * stride + off..(j + 2) * stride + off + dh];
        let k3 = &kmat[(j + 3) * stride + off..(j + 3) * stride + off + dh];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for (tt, qv) in q.iter().enumerate() {
            a0 += qv * k0[tt];
            a1 += qv * k1[tt];
            a2 += qv * k2[tt];
            a3 += qv * k3[tt];
        }
        out[j] = a0;
        out[j + 1] = a1;
        out[j + 2] = a2;
        out[j + 3] = a3;
        j += 4;
    }
    while j < n {
        let kj = &kmat[j * stride + off..j * stride + off + dh];
        let mut dot = 0.0f32;
        for (a, b) in q.iter().zip(kj) {
            dot += a * b;
        }
        out[j] = dot;
        j += 1;
    }
}

/// Dispatching score row.
pub fn dots(q: &[f32], kmat: &[f32], stride: usize, off: usize, n: usize, out: &mut [f32]) {
    match mode() {
        Mode::Scalar => dots_scalar(q, kmat, stride, off, n, out),
        Mode::Micro => dots_micro(q, kmat, stride, off, n, out),
    }
}

/// One query·key dot (the "new key" term of the cached decode row).
/// Single ascending-feature chain in both modes by definition.
pub fn dot1(q: &[f32], kj: &[f32]) -> f32 {
    let mut dot = 0.0f32;
    for (a, b) in q.iter().zip(kj) {
        dot += a * b;
    }
    dot
}

/// Reference weighted value sum: `out[u] += Σ_j p[j] · vmat[j·stride+off+u]`
/// as a j-outer AXPY sweep — `out` round-trips through memory per key.
pub fn wsum_scalar(out: &mut [f32], p: &[f32], vmat: &[f32], stride: usize, off: usize) {
    let dh = out.len();
    for (j, pj) in p.iter().enumerate() {
        let vj = &vmat[j * stride + off..j * stride + off + dh];
        for (ov, vv) in out.iter_mut().zip(vj) {
            *ov += pj * vv;
        }
    }
}

/// Micro weighted value sum: the output head (≤ 16-lane chunks) stays in
/// registers while all keys stream past in ascending-j order — same
/// per-element order as [`wsum_scalar`], bitwise equal.
pub fn wsum_micro(out: &mut [f32], p: &[f32], vmat: &[f32], stride: usize, off: usize) {
    const CW: usize = 16;
    let dh = out.len();
    let mut c0 = 0;
    while c0 < dh {
        let cw = (dh - c0).min(CW);
        let mut acc = [0.0f32; CW];
        acc[..cw].copy_from_slice(&out[c0..c0 + cw]);
        if cw == CW {
            for (j, pj) in p.iter().enumerate() {
                let vj = &vmat[j * stride + off + c0..j * stride + off + c0 + CW];
                for u in 0..CW {
                    acc[u] += pj * vj[u];
                }
            }
        } else {
            for (j, pj) in p.iter().enumerate() {
                let vj = &vmat[j * stride + off + c0..j * stride + off + c0 + cw];
                for u in 0..cw {
                    acc[u] += pj * vj[u];
                }
            }
        }
        out[c0..c0 + cw].copy_from_slice(&acc[..cw]);
        c0 += CW;
    }
}

/// Dispatching weighted value sum.
pub fn wsum(out: &mut [f32], p: &[f32], vmat: &[f32], stride: usize, off: usize) {
    match mode() {
        Mode::Scalar => wsum_scalar(out, p, vmat, stride, off),
        Mode::Micro => wsum_micro(out, p, vmat, stride, off),
    }
}

/// One contiguous run of key/value rows — how a paged KV cache exposes a
/// block's committed positions to the attention kernels (`serve::paged`).
/// `k`/`v` hold `rows` rows at the caller's stride; consecutive segments
/// cover consecutive position ranges.
#[derive(Clone, Copy)]
pub struct KvSegment<'a> {
    /// roped keys, `rows` rows at the caller's stride
    pub k: &'a [f32],
    /// raw values, `rows` rows at the caller's stride
    pub v: &'a [f32],
    /// committed rows in this run
    pub rows: usize,
}

/// Score row over segmented keys: [`dots`] on each segment in order.
/// Every `out[j]` is one independent dot chain, so where a position lands
/// (which segment holds it) cannot change its value — the gather view is
/// bitwise identical to [`dots`] over the concatenated rows, in either
/// dispatch mode.
pub fn dots_gather<'a>(
    q: &[f32],
    segs: impl Fn(usize) -> KvSegment<'a>,
    n_segs: usize,
    stride: usize,
    off: usize,
    out: &mut [f32],
) {
    let mut j0 = 0;
    for si in 0..n_segs {
        let seg = segs(si);
        dots(q, seg.k, stride, off, seg.rows, &mut out[j0..]);
        j0 += seg.rows;
    }
}

/// Weighted value sum over segmented values: [`wsum`] on each segment in
/// ascending position order. Both lanes accumulate strictly ascending in
/// j — scalar as a j-outer AXPY, micro restarting its register chunks
/// from the partial `out` at each segment boundary without altering any
/// f32 — so the gather view is bitwise identical to [`wsum`] over the
/// concatenated rows.
pub fn wsum_gather<'a>(
    out: &mut [f32],
    p: &[f32],
    segs: impl Fn(usize) -> KvSegment<'a>,
    n_segs: usize,
    stride: usize,
    off: usize,
) {
    let mut j0 = 0;
    for si in 0..n_segs {
        let seg = segs(si);
        wsum(out, &p[j0..j0 + seg.rows], seg.v, stride, off);
        j0 += seg.rows;
    }
}

/// `out[u] += a · v[u]` — the single-key tail of the cached decode row
/// (the new key/value at the decoded position). Elementwise; one add per
/// element in either mode, so there is nothing to reorder.
pub fn axpy(out: &mut [f32], a: f32, v: &[f32]) {
    for (ov, vv) in out.iter_mut().zip(v) {
        *ov += a * vv;
    }
}
