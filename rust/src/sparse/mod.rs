//! Shared sparse-weight substrate: packed formats + SpMM kernels.
//!
//! Promoted out of [`crate::sim`] (which only *models* sparse execution
//! cycles) so that real consumers can share one format:
//!
//! * [`csr::Csr`] — compressed sparse row over exact-zero pruned weights.
//!   Used by the ViTCoD cycle simulator ([`crate::sim`]) and executed for
//!   real by the serving engine ([`crate::serve`]).
//! * [`csr::QuantCsr`] — CSR with 1-byte quantization codes on the same
//!   min-max grid as [`crate::quant::fake_quant`] (bit-exact dequant), so
//!   a jointly pruned+quantized checkpoint stores ~4x less weight memory
//!   and dequantizes inside the SpMM inner loop.
//! * [`spmm`] — cache-friendly row-blocked SpMM kernels in the AXPY
//!   orientation (`Y^T = W · X^T`: per stored nonzero, a contiguous
//!   vectorizable update over all tokens), fanned out across row blocks
//!   via [`crate::util::par`] when the workload is large enough to pay
//!   for scoped-thread spawn.
//!
//! Accumulation-order contract: for one output element, kernels add the
//! stored nonzeros in ascending-column order — the same order the dense
//! `mm_nt` kernel scans them — so a CSR built from a masked weight
//! reproduces the dense result *bitwise* (adding an exact 0.0 is exact).
//! The serve parity suite (`tests/serve_parity.rs`) pins this.

pub mod csr;
pub mod spmm;

pub use csr::{Csr, QuantCsr};
pub use spmm::{linear_csr, linear_quant, spmm, spmm_quant, transpose};
