//! Row-blocked SpMM kernels over [`Csr`] / [`QuantCsr`] weights.
//!
//! Orientation: `Y^T[rows, t] = W[rows, cols] · X^T[cols, t]`. Each stored
//! nonzero performs one AXPY over the `t` tokens — a contiguous,
//! reassociation-free (per output element) update the compiler can
//! vectorize, unlike the gather a `y = x Wᵀ`-oriented sparse kernel would
//! need. Linear-layer wrappers transpose the `[n, cols]` activations in
//! (O(n·cols), negligible next to the O(nnz·n) multiply) and transpose the
//! result back.
//!
//! Work is split into contiguous row blocks and fanned out with
//! [`crate::util::par::par_map`] once the MAC count covers scoped-thread
//! spawn cost; below the threshold the kernels run sequentially so tiny
//! decode-step matrices pay zero threading overhead.

use super::csr::{Csr, QuantCsr};
use crate::util::par::{par_map, workers_for};

/// Minimum multiply-accumulate count before a kernel fans out across
/// threads; below this, scoped-thread spawn dominates the work.
const PAR_MIN_MACS: usize = 1 << 22;

/// `[rows, cols] -> [cols, rows]` dense transpose (row-major slices).
pub fn transpose(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * cols);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        for (c, v) in row.iter().enumerate() {
            out[c * rows + r] = *v;
        }
    }
    out
}

/// Contiguous row ranges covering `rows`, one per useful worker.
fn row_blocks(rows: usize, macs: usize) -> Vec<(usize, usize)> {
    let workers = if macs >= PAR_MIN_MACS { workers_for(rows) } else { 1 };
    let chunk = rows.div_ceil(workers.max(1)).max(1);
    let mut out = Vec::new();
    let mut lo = 0;
    while lo < rows {
        let hi = (lo + chunk).min(rows);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// One row block of `Y^T = W · X^T` over shared CSR structure
/// (`row_ptr`/`col_idx`). The `value` accessor is the *only* difference
/// between the plain and fused-dequant kernels — monomorphized and
/// inlined away, so merging them costs nothing in the inner loop and
/// both paths share one accumulation order (the bitwise-parity contract
/// of [`crate::sparse`]). The inner loop itself lives in
/// [`crate::kernel::spmm`]: a scalar AXPY reference and a
/// register-blocked token-stripe micro kernel, selected by `BESA_KERNEL`
/// and bitwise equal.
#[inline]
fn spmm_rows_with<V: Fn(usize) -> f32>(
    row_ptr: &[u32],
    col_idx: &[u32],
    value: V,
    x: &[f32],
    t: usize,
    lo_row: usize,
    hi_row: usize,
    out: &mut [f32],
) {
    crate::kernel::spmm::spmm_rows(row_ptr, col_idx, value, x, t, lo_row, hi_row, out);
}

/// Row-blocked, optionally parallel driver shared by [`spmm`] and
/// [`spmm_quant`]: split `rows` into contiguous blocks, run
/// [`spmm_rows_with`] per block (fanning out when `macs` covers thread
/// spawn cost), stitch the parts back in row order.
fn spmm_with<V: Fn(usize) -> f32 + Sync>(
    rows: usize,
    cols: usize,
    row_ptr: &[u32],
    col_idx: &[u32],
    value: V,
    x: &[f32],
    t: usize,
    macs: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), cols * t, "x must be [cols={cols}, t={t}]");
    let blocks = row_blocks(rows, macs);
    if blocks.len() <= 1 {
        let mut y = vec![0.0f32; rows * t];
        spmm_rows_with(row_ptr, col_idx, &value, x, t, 0, rows, &mut y);
        return y;
    }
    let parts = par_map(&blocks, |&(lo, hi)| {
        let mut part = vec![0.0f32; (hi - lo) * t];
        spmm_rows_with(row_ptr, col_idx, &value, x, t, lo, hi, &mut part);
        Ok(part)
    })
    // besa-lint: allow(hot-path-panic) — closure is infallible; par_map errs only on worker panic
    .expect("spmm row-block workers are infallible");
    let mut y = vec![0.0f32; rows * t];
    for (&(lo, hi), part) in blocks.iter().zip(parts) {
        y[lo * t..hi * t].copy_from_slice(&part);
    }
    y
}

/// `y[rows, t] = W @ x` for dense `x [cols, t]`, row-blocked + parallel.
pub fn spmm(w: &Csr, x: &[f32], t: usize) -> Vec<f32> {
    spmm_with(w.rows, w.cols, &w.row_ptr, &w.col_idx, |k| w.values[k], x, t, w.nnz() * t)
}

/// Fused dequant-SpMM: `y[rows, t] = dequant(W) @ x` for `x [cols, t]`.
/// Same kernel as [`spmm`] with the dequantizing accessor
/// ([`QuantCsr::value`]: one sub+mul per nonzero, amortized over `t`).
pub fn spmm_quant(w: &QuantCsr, x: &[f32], t: usize) -> Vec<f32> {
    spmm_with(w.rows, w.cols, &w.row_ptr, &w.col_idx, |k| w.value(k), x, t, w.nnz() * t)
}

/// Linear layer over CSR weights: `y[n, rows] = x[n, cols] @ W^T`.
/// Same result (bitwise) as `ops::mm_nt(x, to_dense(W))` — see the
/// accumulation-order contract in the module docs of [`crate::sparse`].
pub fn linear_csr(w: &Csr, x: &[f32], n: usize) -> Vec<f32> {
    let xt = transpose(x, n, w.cols);
    let yt = spmm(w, &xt, n);
    transpose(&yt, w.rows, n)
}

/// Linear layer over quantized CSR weights, dequant fused into the SpMM.
pub fn linear_quant(w: &QuantCsr, x: &[f32], n: usize) -> Vec<f32> {
    let xt = transpose(x, n, w.cols);
    let yt = spmm_quant(w, &xt, n);
    transpose(&yt, w.rows, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fake_quant, QuantSpec};
    use crate::runtime::native::ops::mm_nt;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn random_sparse(rows: usize, cols: usize, sparsity: f64, seed: u64) -> Tensor {
        let mut rng = Rng::seed(seed);
        let data: Vec<f32> = (0..rows * cols)
            .map(|_| if rng.f64() < sparsity { 0.0 } else { rng.normal_f32() })
            .collect();
        Tensor::from_f32(&[rows, cols], data)
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::seed(3);
        let x: Vec<f32> = (0..5 * 7).map(|_| rng.normal_f32()).collect();
        assert_eq!(transpose(&transpose(&x, 5, 7), 7, 5), x);
    }

    #[test]
    fn linear_csr_bitwise_matches_dense_mm() {
        let w = random_sparse(24, 40, 0.5, 1);
        let csr = Csr::from_dense(&w);
        let mut rng = Rng::seed(2);
        let n = 9;
        let x: Vec<f32> = (0..n * 40).map(|_| rng.normal_f32()).collect();
        let dense = mm_nt(&x, w.f32s(), n, 40, 24);
        let sparse = linear_csr(&csr, &x, n);
        // skipping exact zeros must not change the accumulation: bitwise
        assert_eq!(dense, sparse);
    }

    #[test]
    fn linear_quant_matches_fake_quant_dense_mm() {
        let w = random_sparse(16, 32, 0.5, 4);
        let spec = QuantSpec::default();
        let q = QuantCsr::from_dense(&w, spec);
        let wq = fake_quant(&w, spec);
        let mut rng = Rng::seed(5);
        let n = 6;
        let x: Vec<f32> = (0..n * 32).map(|_| rng.normal_f32()).collect();
        let dense = mm_nt(&x, wq.f32s(), n, 32, 16);
        let fused = linear_quant(&q, &x, n);
        assert_eq!(dense, fused);
    }

    #[test]
    fn spmm_row_blocking_is_exact() {
        // force multiple row blocks by checking block assembly directly
        let w = random_sparse(64, 48, 0.4, 6);
        let csr = Csr::from_dense(&w);
        let mut rng = Rng::seed(7);
        let t = 5;
        let x: Vec<f32> = (0..48 * t).map(|_| rng.normal_f32()).collect();
        let whole = spmm(&csr, &x, t);
        let mut stitched = vec![0.0f32; 64 * t];
        for (lo, hi) in [(0usize, 20usize), (20, 41), (41, 64)] {
            let mut part = vec![0.0f32; (hi - lo) * t];
            spmm_rows_with(&csr.row_ptr, &csr.col_idx, |k| csr.values[k], &x, t, lo, hi, &mut part);
            stitched[lo * t..hi * t].copy_from_slice(&part);
        }
        assert_eq!(whole, stitched);
    }

    /// The merged kernel serves both accessors: the plain path must equal
    /// the dense matmul of the raw weight bitwise, and the fused-dequant
    /// path must equal the dense matmul of the fake-quantized weight
    /// bitwise — i.e. parameterizing the value accessor changed nothing.
    #[test]
    fn merged_kernel_paths_bit_exact_with_dense_references() {
        let w = random_sparse(48, 36, 0.5, 8);
        let spec = QuantSpec::default();
        let csr = Csr::from_dense(&w);
        let qcsr = QuantCsr::from_dense(&w, spec);
        let wq = fake_quant(&w, spec);
        let mut rng = Rng::seed(9);
        let t = 7;
        // x is [cols, t] for the SpMM orientation
        let x: Vec<f32> = (0..36 * t).map(|_| rng.normal_f32()).collect();

        // dense references in the same orientation: y^T = W · x
        let xt = transpose(&x, 36, t); // [t, cols] rows for mm_nt
        let plain_ref = transpose(&mm_nt(&xt, w.f32s(), t, 36, 48), t, 48);
        let quant_ref = transpose(&mm_nt(&xt, wq.f32s(), t, 36, 48), t, 48);

        assert_eq!(spmm(&csr, &x, t), plain_ref, "plain accessor vs dense W");
        assert_eq!(spmm_quant(&qcsr, &x, t), quant_ref, "dequant accessor vs dense fake_quant(W)");
    }

    #[test]
    fn row_blocks_cover_rows() {
        for rows in [1usize, 7, 64, 1000] {
            for macs in [0usize, PAR_MIN_MACS * 2] {
                let blocks = row_blocks(rows, macs);
                assert_eq!(blocks.first().unwrap().0, 0);
                assert_eq!(blocks.last().unwrap().1, rows);
                for w in blocks.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
            }
        }
    }
}
