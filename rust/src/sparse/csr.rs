//! Compressed sparse row structures for pruned weight matrices.
//!
//! [`Csr`] stores f32 values; [`QuantCsr`] stores 1-byte codes on the
//! [`crate::quant::fake_quant`] min-max grid and dequantizes on the fly
//! (bit-exact with fake-quantizing the dense tensor first).

use crate::quant::QuantSpec;
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from a dense tensor, treating exact zeros as pruned.
    /// Single pass to count nnz, exact reservations, no per-element
    /// branch-and-grow in the fill loop.
    pub fn from_dense(t: &Tensor) -> Csr {
        assert_eq!(t.shape.len(), 2);
        let (rows, cols) = (t.shape[0], t.shape[1]);
        let data = t.f32s();
        let nnz = data.iter().filter(|v| **v != 0.0).count();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        row_ptr.push(0u32);
        for r in 0..rows {
            let row = &data[r * cols..(r + 1) * cols];
            for (c, v) in row.iter().enumerate().filter(|(_, v)| **v != 0.0) {
                col_idx.push(c as u32);
                values.push(*v);
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Csr { rows, cols, row_ptr, col_idx, values }
    }

    /// Scatter back to a dense tensor (pruned entries become exact zeros).
    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let (lo, hi) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            for k in lo..hi {
                out[r * self.cols + self.col_idx[k] as usize] = self.values[k];
            }
        }
        Tensor::from_f32(&[self.rows, self.cols], out)
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    pub fn row_nnz(&self, r: usize) -> usize {
        (self.row_ptr[r + 1] - self.row_ptr[r]) as usize
    }

    /// nnz per column (used for the denser/sparser split).
    pub fn col_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.cols];
        for c in &self.col_idx {
            counts[*c as usize] += 1;
        }
        counts
    }

    /// Packed size in bytes (row_ptr + col_idx + values).
    pub fn mem_bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 4 + self.values.len() * 4
    }

    /// SpMM `y = W x` for a dense `x [cols, t]` — delegates to the shared
    /// row-blocked kernel in [`super::spmm`].
    pub fn spmm(&self, x: &[f32], t: usize) -> Vec<f32> {
        super::spmm::spmm(self, x, t)
    }
}

/// CSR with 1-byte quantization codes instead of f32 values. The grid
/// (scale `h`, zero-point `z`, clamp range) is computed over the *full*
/// dense tensor — zeros included — exactly like
/// [`crate::quant::fake_quant`], so `(code - zero) * scale` reproduces the
/// fake-quantized weight bit-for-bit while storing 4x less value memory.
#[derive(Debug, Clone)]
pub struct QuantCsr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    /// quantization codes in `0..=2^bits - 1`, one per stored nonzero
    pub codes: Vec<u8>,
    /// dequant: `value = (code - zero) * scale`
    pub scale: f32,
    pub zero: f32,
    pub bits: u32,
}

impl QuantCsr {
    /// Quantize + pack a dense tensor, treating exact zeros as pruned.
    pub fn from_dense(t: &Tensor, spec: QuantSpec) -> QuantCsr {
        assert_eq!(t.shape.len(), 2);
        assert!(spec.bits >= 1 && spec.bits <= 8, "QuantCsr codes are u8 (1..=8 bits)");
        let (rows, cols) = (t.shape[0], t.shape[1]);
        let data = t.f32s();
        // same grid arithmetic as quant::fake_quant, term for term
        let qmax = (2f64.powi(spec.bits as i32) - 1.0) as f32;
        let wmin = data.iter().cloned().fold(f32::INFINITY, f32::min) * spec.gamma0;
        let wmax = data.iter().cloned().fold(f32::NEG_INFINITY, f32::max) * spec.gamma1;
        let h = ((wmax - wmin) / qmax).max(1e-8);
        let z = (-wmin / h).round();
        let nnz = data.iter().filter(|v| **v != 0.0).count();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut codes = Vec::with_capacity(nnz);
        row_ptr.push(0u32);
        for r in 0..rows {
            let row = &data[r * cols..(r + 1) * cols];
            for (c, v) in row.iter().enumerate().filter(|(_, v)| **v != 0.0) {
                let q = ((v / h).round() + z).clamp(0.0, qmax);
                col_idx.push(c as u32);
                codes.push(q as u8);
            }
            row_ptr.push(col_idx.len() as u32);
        }
        QuantCsr { rows, cols, row_ptr, col_idx, codes, scale: h, zero: z, bits: spec.bits }
    }

    pub fn nnz(&self) -> usize {
        self.codes.len()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Dequantized value of stored entry `k`.
    #[inline]
    pub fn value(&self, k: usize) -> f32 {
        (self.codes[k] as f32 - self.zero) * self.scale
    }

    /// Packed size in bytes (row_ptr + col_idx + codes).
    pub fn mem_bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 4 + self.codes.len()
    }

    /// Dequantize back to a dense tensor (diagnostics + parity tests).
    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let (lo, hi) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            for k in lo..hi {
                out[r * self.cols + self.col_idx[k] as usize] = self.value(k);
            }
        }
        Tensor::from_f32(&[self.rows, self.cols], out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fake_quant;
    use crate::util::rng::Rng;

    #[test]
    fn from_dense_roundtrip() {
        let t = Tensor::from_f32(&[2, 3], vec![1.0, 0.0, 2.0, 0.0, 0.0, 3.0]);
        let c = Csr::from_dense(&t);
        assert_eq!(c.nnz(), 3);
        assert_eq!(c.row_nnz(0), 2);
        assert_eq!(c.row_nnz(1), 1);
        assert_eq!(c.col_counts(), vec![1, 0, 2]);
        assert!((c.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn to_dense_roundtrips_exactly() {
        let mut rng = Rng::seed(11);
        let data: Vec<f32> = (0..37 * 23)
            .map(|_| if rng.f64() < 0.6 { 0.0 } else { rng.normal_f32() })
            .collect();
        let t = Tensor::from_f32(&[37, 23], data);
        let back = Csr::from_dense(&t).to_dense();
        assert_eq!(back, t);
    }

    #[test]
    fn spmm_matches_dense() {
        let w = Tensor::from_f32(&[2, 3], vec![1.0, 0.0, 2.0, -1.0, 0.5, 0.0]);
        let c = Csr::from_dense(&w);
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [3, 2]
        let y = c.spmm(&x, 2);
        // row0 = 1*[1,2] + 2*[5,6] = [11, 14]; row1 = -1*[1,2]+0.5*[3,4] = [0.5, 0]
        assert_eq!(y, vec![11.0, 14.0, 0.5, 0.0]);
    }

    #[test]
    fn quant_csr_matches_fake_quant() {
        let mut rng = Rng::seed(7);
        let data: Vec<f32> = (0..32 * 16)
            .map(|_| if rng.f64() < 0.5 { 0.0 } else { rng.normal_f32() })
            .collect();
        let t = Tensor::from_f32(&[32, 16], data);
        let spec = QuantSpec::default();
        let q = QuantCsr::from_dense(&t, spec);
        let deq = q.to_dense();
        let fq = fake_quant(&t, spec);
        // pruned entries stay exact zeros in the packed form
        for (a, b) in deq.f32s().iter().zip(t.f32s()) {
            if *b == 0.0 {
                assert_eq!(*a, 0.0);
            }
        }
        // stored entries dequantize bit-exactly to the fake-quant grid
        for (i, (a, b)) in deq.f32s().iter().zip(fq.f32s()).enumerate() {
            if t.f32s()[i] != 0.0 {
                assert_eq!(a, b, "entry {i}");
            }
        }
        assert!((q.sparsity() - 0.5).abs() < 0.1);
        assert!(q.mem_bytes() < Csr::from_dense(&t).mem_bytes());
    }
}
