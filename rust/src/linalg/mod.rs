//! Dense linear-algebra substrate (no external BLAS): matrix type,
//! symmetric accumulation, Cholesky factorization/inversion and
//! triangular solves — everything SparseGPT's OBS solver needs.

use anyhow::{bail, Result};

/// Row-major dense square-capable matrix of f64 (numerical code keeps f64
/// internally; model tensors are f32 at the boundaries).
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub d: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, d: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = rows[0].len();
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c);
            m.d[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    /// self += x^T x for a batch of row-vectors x [n, cols] (Hessian accum).
    pub fn add_gram_f32(&mut self, x: &[f32], n: usize) {
        assert_eq!(self.rows, self.cols);
        let c = self.cols;
        assert_eq!(x.len(), n * c);
        for s in 0..n {
            let row = &x[s * c..(s + 1) * c];
            for i in 0..c {
                let xi = row[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                let out = &mut self.d[i * c..(i + 1) * c];
                for j in 0..c {
                    out[j] += xi * row[j] as f64;
                }
            }
        }
    }

    pub fn add_diag(&mut self, v: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self[(i, i)] += v;
        }
    }

    /// `self @ other`; the f64 inner loop lives in
    /// [`crate::kernel::gemm::matmul_f64`] (scalar AXPY reference vs
    /// register-chunked micro kernel, bitwise equal).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let d = crate::kernel::gemm::matmul_f64(&self.d, &other.d, m, k, n);
        Mat { rows: m, cols: n, d }
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        self.d
            .iter()
            .zip(&other.d)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.d[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.d[i * self.cols + j]
    }
}

/// Cholesky factorization A = L L^T (lower). Fails on non-PD input.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (sum={sum:.3e})");
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve L y = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[(i, k)] * y[k];
        }
        y[i] = sum / l[(i, i)];
    }
    y
}

/// Solve L^T x = y (backward substitution).
pub fn solve_lower_t(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[(k, i)] * x[k];
        }
        x[i] = sum / l[(i, i)];
    }
    x
}

/// Full inverse via Cholesky: A^-1 = (L L^T)^-1. O(n^3).
pub fn cholesky_inverse(a: &Mat) -> Result<Mat> {
    let l = cholesky(a)?;
    let n = a.rows;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0; n];
    for col in 0..n {
        e.iter_mut().for_each(|v| *v = 0.0);
        e[col] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for row in 0..n {
            inv[(row, col)] = x[row];
        }
    }
    Ok(inv)
}

/// Invert a lower-triangular matrix in-place-free: N = L^-1 (lower).
/// Column-by-column forward substitution on the triangular structure —
/// ~n^3/6 multiply-adds, no RHS assembly.
pub fn invert_lower(l: &Mat) -> Mat {
    assert_eq!(l.rows, l.cols);
    let n = l.rows;
    let mut inv = Mat::zeros(n, n);
    for j in 0..n {
        inv[(j, j)] = 1.0 / l[(j, j)];
        for i in j + 1..n {
            let mut sum = 0.0;
            for k in j..i {
                sum -= l[(i, k)] * inv[(k, j)];
            }
            inv[(i, j)] = sum / l[(i, i)];
        }
    }
    inv
}

/// Upper-triangular Cholesky factor of the *inverse*: returns U with
/// A^-1 = U^T U — exactly torch's `linalg.cholesky(inv(H), upper=True)`,
/// the factor SparseGPT's OBS sweep consumes.
///
/// Direct path (no explicit inverse, no second factorization): with J the
/// index-reversal permutation, factor JAJ = L̄ L̄^T once, invert the
/// triangular L̄, and un-reverse: U = J L̄^-1 J is upper-triangular with
/// U^T U = J L̄^-T L̄^-1 J = J (JAJ)^-1 J = A^-1. One O(n^3/3)
/// factorization plus one O(n^3/6) triangular inverse, replacing the old
/// invert-then-refactor 2x O(n^3) route.
pub fn cholesky_inverse_upper(a: &Mat) -> Result<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    // JAJ: reverse both row and column order
    let mut rev = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            rev[(i, j)] = a[(n - 1 - i, n - 1 - j)];
        }
    }
    let lbar = cholesky(&rev)?;
    let ninv = invert_lower(&lbar);
    // U = J N J (flipping a lower-triangular matrix both ways gives upper)
    let mut u = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            u[(i, j)] = ninv[(n - 1 - i, n - 1 - j)];
        }
    }
    Ok(u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::seed(seed);
        let mut b = Mat::zeros(n, n);
        for v in b.d.iter_mut() {
            *v = rng.normal();
        }
        let mut a = b.matmul(&b.transpose());
        a.add_diag(n as f64 * 0.1);
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(24, 1);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        assert!(a.max_abs_diff(&rec) < 1e-9, "{}", a.max_abs_diff(&rec));
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(16, 2);
        let inv = cholesky_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.max_abs_diff(&Mat::eye(16)) < 1e-8);
    }

    #[test]
    fn upper_factor_of_inverse() {
        let a = random_spd(12, 3);
        let u = cholesky_inverse_upper(&a).unwrap();
        // U must be upper triangular
        for i in 0..12 {
            for j in 0..i {
                assert!(u[(i, j)].abs() < 1e-12);
            }
        }
        // inv = U^T U (torch upper-cholesky convention)
        let rec = u.transpose().matmul(&u);
        let inv = cholesky_inverse(&a).unwrap();
        assert!(rec.max_abs_diff(&inv) < 1e-8);
    }

    #[test]
    fn invert_lower_is_inverse() {
        let a = random_spd(20, 7);
        let l = cholesky(&a).unwrap();
        let inv = invert_lower(&l);
        // strictly lower-triangular inverse
        for i in 0..20 {
            for j in i + 1..20 {
                assert_eq!(inv[(i, j)], 0.0);
            }
        }
        let prod = inv.matmul(&l);
        assert!(prod.max_abs_diff(&Mat::eye(20)) < 1e-9);
    }

    #[test]
    fn triangular_solves() {
        let a = random_spd(8, 4);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..8).map(|i| i as f64 - 3.0).collect();
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // L L^T x = b  =>  A x = b
        let ax: Vec<f64> = (0..8)
            .map(|i| (0..8).map(|j| a[(i, j)] * x[j]).sum::<f64>())
            .collect();
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn gram_accumulation() {
        let mut h = Mat::zeros(3, 3);
        let x: Vec<f32> = vec![1., 2., 3., 4., 5., 6.]; // two rows
        h.add_gram_f32(&x, 2);
        // H = x^T x
        assert_eq!(h[(0, 0)], 1.0 + 16.0);
        assert_eq!(h[(1, 2)], 2.0 * 3.0 + 5.0 * 6.0);
    }

    #[test]
    fn non_pd_rejected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }
}
