//! A lightweight Rust tokenizer for the repo-specific lint pass — enough
//! lexical structure to reason about panics, lock acquisitions and
//! iteration order without pulling in `syn` (the build is hermetic:
//! vendored path deps only).
//!
//! Comment- and string-aware: `//` / `/* */` (nested) comments, plain and
//! raw strings (`r"…"`, `r#"…"#`, byte variants), char literals vs
//! lifetimes, and numeric literals that stop before `..` range operators.
//! Comments are kept as tokens — the lints read `// besa-lint: allow(…)`
//! escape hatches and `//!` parity declarations out of them.

/// Token classes. Punctuation is emitted one character at a time
/// (`>>` is two `Punct('>')` tokens), which is all the lints need.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// identifier or keyword
    Ident,
    /// string / char / numeric literal (one token, escapes resolved away)
    Literal,
    /// single punctuation character
    Punct,
    /// `// …` including `///` and `//!` doc comments (text kept)
    LineComment,
    /// `/* … */`, nested (text kept)
    BlockComment,
    /// `'a` in `<'a>` position (NOT a char literal)
    Lifetime,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based line of the token's first character
    pub line: usize,
}

/// Tokenize `src`. Never fails: unterminated constructs are consumed to
/// end of input (the lints then simply see fewer tokens — the real
/// compiler is the authority on well-formedness).
pub fn lex(src: &str) -> Vec<Tok> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;

    let is_ident_start = |c: char| c.is_ascii_alphabetic() || c == '_';
    let is_ident_cont = |c: char| c.is_ascii_alphanumeric() || c == '_';

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // ---- comments -------------------------------------------------
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::LineComment,
                text: chars[start..i].iter().collect(),
                line,
            });
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            toks.push(Tok {
                kind: TokKind::BlockComment,
                text: chars[start..i].iter().collect(),
                line: start_line,
            });
            continue;
        }
        // ---- raw / byte strings --------------------------------------
        // r"…", r#"…"#, b"…", br#"…"# — detect before the ident path.
        if is_ident_start(c) {
            let mut j = i;
            if chars[j] == 'b' && j + 1 < n && (chars[j + 1] == 'r' || chars[j + 1] == '"') {
                j += 1;
            }
            if chars[j] == 'r' || chars[j] == '"' {
                let mut k = j;
                if chars[k] == 'r' {
                    k += 1;
                }
                let mut hashes = 0usize;
                while k < n && chars[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                let raw = j < n && chars[j] == 'r';
                if k < n && chars[k] == '"' && (raw || k == j) {
                    // a raw (or byte) string starts at i
                    let start = i;
                    let start_line = line;
                    i = k + 1;
                    if raw {
                        // ends at `"` + `hashes` `#`s, no escapes
                        loop {
                            if i >= n {
                                break;
                            }
                            if chars[i] == '\n' {
                                line += 1;
                                i += 1;
                                continue;
                            }
                            if chars[i] == '"' {
                                let mut h = 0usize;
                                while i + 1 + h < n && h < hashes && chars[i + 1 + h] == '#' {
                                    h += 1;
                                }
                                if h == hashes {
                                    i += 1 + hashes;
                                    break;
                                }
                            }
                            i += 1;
                        }
                    } else {
                        consume_string_body(&chars, &mut i, &mut line);
                    }
                    toks.push(Tok {
                        kind: TokKind::Literal,
                        text: chars[start..i.min(n)].iter().collect(),
                        line: start_line,
                    });
                    continue;
                }
            }
            // plain identifier / keyword
            let start = i;
            i += 1;
            while i < n && is_ident_cont(chars[i]) {
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Ident, text: chars[start..i].iter().collect(), line });
            continue;
        }
        // ---- plain strings -------------------------------------------
        if c == '"' {
            let start = i;
            let start_line = line;
            i += 1;
            consume_string_body(&chars, &mut i, &mut line);
            toks.push(Tok {
                kind: TokKind::Literal,
                text: chars[start..i.min(n)].iter().collect(),
                line: start_line,
            });
            continue;
        }
        // ---- char literal vs lifetime --------------------------------
        if c == '\'' {
            // char literal: '\…' or 'x' (any single scalar then ');
            // otherwise a lifetime: 'ident
            let is_char = if i + 1 < n && chars[i + 1] == '\\' {
                true
            } else {
                i + 2 < n && chars[i + 2] == '\''
            };
            if is_char {
                let start = i;
                i += 1; // opening '
                if i < n && chars[i] == '\\' {
                    i += 1; // backslash
                    if i < n {
                        i += 1; // escaped char (enough for \n \' \\ \0; \x.. \u{..}
                                // fall through to the closing-quote scan below)
                    }
                    while i < n && chars[i] != '\'' {
                        i += 1;
                    }
                } else if i < n {
                    i += 1; // the char itself
                }
                if i < n && chars[i] == '\'' {
                    i += 1; // closing '
                }
                toks.push(Tok {
                    kind: TokKind::Literal,
                    text: chars[start..i.min(n)].iter().collect(),
                    line,
                });
            } else {
                let start = i;
                i += 1;
                while i < n && is_ident_cont(chars[i]) {
                    i += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: chars[start..i].iter().collect(),
                    line,
                });
            }
            continue;
        }
        // ---- numbers --------------------------------------------------
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n {
                let d = chars[i];
                if d.is_ascii_alphanumeric() || d == '_' {
                    i += 1;
                } else if d == '.' {
                    // consume a decimal point, but not a `..` range
                    if i + 1 < n && chars[i + 1] == '.' {
                        break;
                    }
                    i += 1;
                } else if (d == '+' || d == '-')
                    && matches!(chars[i - 1], 'e' | 'E')
                    && chars[start..i].iter().any(|x| x.is_ascii_digit())
                {
                    // exponent sign: 1e-9, 2.5E+3
                    i += 1;
                } else {
                    break;
                }
            }
            toks.push(Tok {
                kind: TokKind::Literal,
                text: chars[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // ---- single punctuation --------------------------------------
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    toks
}

/// Consume a double-quoted string body with `\` escapes; `*i` points just
/// past the opening quote on entry and just past the closing quote on
/// exit (or end of input for unterminated strings).
fn consume_string_body(chars: &[char], i: &mut usize, line: &mut usize) {
    let n = chars.len();
    while *i < n {
        match chars[*i] {
            '\\' => {
                *i += 1;
                if *i < n {
                    if chars[*i] == '\n' {
                        *line += 1;
                    }
                    *i += 1;
                }
            }
            '"' => {
                *i += 1;
                return;
            }
            '\n' => {
                *line += 1;
                *i += 1;
            }
            _ => *i += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_and_puncts() {
        let t = kinds("let x = a.unwrap();");
        let idents: Vec<&str> = t
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(idents, vec!["let", "x", "a", "unwrap"]);
        assert!(t.iter().any(|(k, s)| *k == TokKind::Punct && s == "."));
    }

    #[test]
    fn strings_hide_their_contents() {
        // an `unwrap(` inside a string must not become tokens
        let t = kinds(r#"let s = "call .unwrap() maybe \" or { ";"#);
        assert!(!t.iter().any(|(k, s)| *k == TokKind::Ident && s == "unwrap"));
        assert!(!t.iter().any(|(k, s)| *k == TokKind::Punct && s == "{"));
        assert_eq!(t.iter().filter(|(k, _)| *k == TokKind::Literal).count(), 1);
    }

    #[test]
    fn comments_are_single_tokens() {
        let t = lex("a // trailing .unwrap()\n/* block\n with .lock() */ b");
        assert_eq!(t[0].text, "a");
        assert_eq!(t[1].kind, TokKind::LineComment);
        assert!(t[1].text.contains("unwrap"));
        assert_eq!(t[2].kind, TokKind::BlockComment);
        assert_eq!(t[3].text, "b");
        assert_eq!(t[3].line, 3, "block comment newlines advance the line counter");
    }

    #[test]
    fn nested_block_comments() {
        let t = lex("/* outer /* inner */ still comment */ x");
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].kind, TokKind::BlockComment);
        assert_eq!(t[1].text, "x");
    }

    #[test]
    fn raw_strings() {
        let t = kinds(r##"let re = r#"quote " and // slash"#; y"##);
        let lit: Vec<&str> = t
            .iter()
            .filter(|(k, _)| *k == TokKind::Literal)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(lit.len(), 1);
        assert!(lit[0].contains("slash"));
        assert!(t.iter().any(|(k, s)| *k == TokKind::Ident && s == "y"));
        // r without a quote is a plain identifier path
        let t2 = kinds("rows r#raw_ident");
        let ids: Vec<&str> = t2
            .iter()
            .filter(|(k, _)| *k == TokKind::Ident)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(ids, vec!["rows", "r", "raw_ident"]);
    }

    #[test]
    fn char_vs_lifetime() {
        let t = kinds("let c = 'x'; fn f<'a>(v: &'a str) { let nl = '\\n'; }");
        let lifetimes: Vec<&str> = t
            .iter()
            .filter(|(k, _)| *k == TokKind::Lifetime)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'a"]);
        let chars: Vec<&str> = t
            .iter()
            .filter(|(k, s)| *k == TokKind::Literal && s.starts_with('\''))
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(chars, vec!["'x'", "'\\n'"]);
    }

    #[test]
    fn nested_generics_and_ranges() {
        let t = kinds("Vec<Vec<f32>> x; for i in 0..n {}");
        // >> lexes as two separate puncts
        assert_eq!(t.iter().filter(|(k, s)| *k == TokKind::Punct && s == ">").count(), 2);
        // 0..n keeps `0` and `n` apart with two dot puncts between
        let zero = t.iter().position(|(k, s)| *k == TokKind::Literal && s == "0").unwrap();
        assert_eq!(t[zero + 1], (TokKind::Punct, ".".to_string()));
        assert_eq!(t[zero + 2], (TokKind::Punct, ".".to_string()));
        assert_eq!(t[zero + 3], (TokKind::Ident, "n".to_string()));
    }

    #[test]
    fn float_literals_and_exponents() {
        let t = kinds("1.5 + 2e-9 - 0.5f32");
        let lits: Vec<&str> = t
            .iter()
            .filter(|(k, _)| *k == TokKind::Literal)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(lits, vec!["1.5", "2e-9", "0.5f32"]);
    }

    #[test]
    fn line_numbers() {
        let t = lex("a\nb\n\nc");
        let lines: Vec<usize> = t.iter().map(|x| x.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }
}
