//! Pillar (a): the artifact-graph checker — an abstract interpreter over
//! [`TensorSpec`] op sequences that verifies whole pipelines *statically*,
//! before any tensor is allocated.
//!
//! The runtime's [`crate::runtime::Engine`] validates each call in
//! isolation (arity / shape / dtype against the manifest). What it cannot
//! see is *composition*: whether `embed`'s output actually feeds
//! `block_fwd`'s input, whether `block_fwd_cached`'s `k_new` can be
//! appended to the `k_cache` it will be fed back into, whether every
//! `theta_*` input of a BESA step has a matching `dtheta_*` gradient
//! output. [`verify_manifest`] walks those pipelines symbolically —
//! propagating shapes through shape *unification* where a dim of 0 is a
//! wildcard binding any extent (the dynamic batch / cache-capacity dims
//! of the serving decode op) — and reports every mismatch as a structured
//! [`Diagnostic`] at load time instead of a mid-run error.
//!
//! [`check_dynamic_call`] is the per-call companion: for ops with
//! wildcard dims it enforces *cross-input* consistency (all leading
//! dynamic axes bind one request batch; inputs with identical specs, like
//! the two KV caches, must agree on every dynamic dim), which per-input
//! validation alone cannot express.

use crate::model::config::LAYER_NAMES;
use crate::runtime::{ArtifactSpec, Manifest, TensorSpec};
use crate::tensor::Tensor;

use super::report::Diagnostic;

use anyhow::{bail, Result};

/// Unify two dims where 0 is a wildcard: `0∪x = x`, `x∪x = x`, else fail.
pub fn unify_dims(a: usize, b: usize) -> Option<usize> {
    if a == 0 {
        Some(b)
    } else if b == 0 || a == b {
        Some(a)
    } else {
        None
    }
}

/// Dimension-wise unification of two shapes; ranks must match exactly
/// (wildcards never absorb a rank difference).
pub fn unify_shapes(a: &[usize], b: &[usize]) -> std::result::Result<Vec<usize>, String> {
    if a.len() != b.len() {
        return Err(format!("rank mismatch: {} vs {}", a.len(), b.len()));
    }
    let mut out = Vec::with_capacity(a.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        match unify_dims(x, y) {
            Some(d) => out.push(d),
            None => return Err(format!("dim {i}: {x} vs {y}")),
        }
    }
    Ok(out)
}

/// Cross-input consistency for a call with dynamic (0) spec dims, run
/// after per-input validation (so ranks already match the spec):
///
/// 1. every wildcard on axis 0 binds the same extent — one request batch
///    per call (`x`, `k_cache`, `v_cache`, `pos` of `block_fwd_cached`);
/// 2. inputs with *identical* spec shapes containing wildcards must agree
///    on every wildcard dim (the two KV caches share one capacity).
pub fn check_dynamic_call(spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
    let mut batch: Option<(usize, &str)> = None;
    for (t, s) in inputs.iter().zip(&spec.inputs) {
        if s.shape.first() == Some(&0) && !t.shape.is_empty() {
            let actual = t.shape[0];
            match &batch {
                None => batch = Some((actual, &s.name)),
                Some((b, first)) => {
                    if *b != actual {
                        bail!(
                            "artifact '{}': dynamic batch mismatch — input '{}' has {} rows but \
                             '{}' has {}",
                            spec.name,
                            s.name,
                            actual,
                            first,
                            b
                        );
                    }
                }
            }
        }
    }
    for i in 0..inputs.len() {
        for j in i + 1..inputs.len() {
            let (si, sj) = (&spec.inputs[i], &spec.inputs[j]);
            if si.shape != sj.shape || !si.shape.contains(&0) {
                continue;
            }
            for (d, sd) in si.shape.iter().enumerate() {
                if *sd == 0 && inputs[i].shape[d] != inputs[j].shape[d] {
                    bail!(
                        "artifact '{}': inputs '{}' and '{}' share spec {:?} but disagree on \
                         dynamic dim {} ({} vs {})",
                        spec.name,
                        si.name,
                        sj.name,
                        si.shape,
                        d,
                        inputs[i].shape[d],
                        inputs[j].shape[d]
                    );
                }
            }
        }
    }
    Ok(())
}

/// Statically verify every pipeline the repo composes from `m`'s op set.
/// Returns one diagnostic per mismatch (empty = the manifest is
/// composable). Findings use file `manifest:<config>` and line 0.
pub fn verify_manifest(m: &Manifest) -> Vec<Diagnostic> {
    let mut c = Checker { m, file: format!("manifest:{}", m.config.name), diags: Vec::new() };
    c.prefill_pipeline();
    c.decode_pipeline();
    c.besa_steps();
    c.mask_and_quant();
    c.train_step();
    c.diags
}

struct Checker<'a> {
    m: &'a Manifest,
    file: String,
    diags: Vec<Diagnostic>,
}

impl Checker<'_> {
    fn push(&mut self, rule: &str, msg: String) {
        self.diags.push(Diagnostic::new(rule, &self.file, 0, msg));
    }

    fn art(&mut self, name: &str) -> Option<ArtifactSpec> {
        match self.m.artifacts.get(name) {
            Some(a) => Some(a.clone()),
            None => {
                self.push("graph-missing", format!("required artifact '{name}' is absent"));
                None
            }
        }
    }

    fn io<'s>(
        &mut self,
        spec: &'s ArtifactSpec,
        list: &'s [TensorSpec],
        which: &str,
        name: &str,
    ) -> Option<&'s TensorSpec> {
        let found = list.iter().find(|t| t.name == name);
        if found.is_none() {
            self.push(
                "graph-missing",
                format!("artifact '{}' has no {which} named '{name}'", spec.name),
            );
        }
        found
    }

    fn input(&mut self, spec: &ArtifactSpec, name: &str) -> Option<TensorSpec> {
        self.io(spec, &spec.inputs, "input", name).cloned()
    }

    fn output(&mut self, spec: &ArtifactSpec, name: &str) -> Option<TensorSpec> {
        self.io(spec, &spec.outputs, "output", name).cloned()
    }

    /// "`producer` feeds `consumer`": dtypes equal, shapes unify.
    fn feed(&mut self, ctx: &str, producer: &TensorSpec, consumer: &TensorSpec) {
        if producer.dtype != consumer.dtype {
            self.push(
                "graph-dtype",
                format!(
                    "{ctx}: '{}' ({}) cannot feed '{}' ({})",
                    producer.name, producer.dtype, consumer.name, consumer.dtype
                ),
            );
        }
        if let Err(why) = unify_shapes(&producer.shape, &consumer.shape) {
            self.push(
                "graph-shape",
                format!(
                    "{ctx}: '{}' {:?} cannot feed '{}' {:?} — {why}",
                    producer.name, producer.shape, consumer.name, consumer.shape
                ),
            );
        }
    }

    /// embed → block_fwd* chain → head_nll (the prefill / eval pipeline),
    /// plus the masked and capture block variants that must stay
    /// chain-compatible with the dense block.
    fn prefill_pipeline(&mut self) {
        let embed = match self.art("embed") {
            Some(a) => a,
            None => return,
        };
        let block = match self.art("block_fwd") {
            Some(a) => a,
            None => return,
        };
        let head = match self.art("head_nll") {
            Some(a) => a,
            None => return,
        };
        let x_in = match self.input(&block, "x") {
            Some(t) => t,
            None => return,
        };
        if let Some(x) = self.output(&embed, "x") {
            self.feed("embed → block_fwd", &x, &x_in);
        }
        if let Some(y) = self.output(&block, "y") {
            self.feed("block_fwd → block_fwd (layer chain)", &y, &x_in);
            if let Some(hx) = self.input(&head, "x") {
                self.feed("block_fwd → head_nll", &y, &hx);
            }
        }
        if let (Some(et), Some(ht)) = (self.input(&embed, "tokens"), self.input(&head, "tokens")) {
            self.feed("embed/head_nll token agreement", &et, &ht);
        }
        if let (Some(nll), Some(toks)) = (self.output(&head, "nll"), self.input(&head, "tokens")) {
            if let Err(why) = unify_shapes(&nll.shape, &toks.shape) {
                self.push(
                    "graph-shape",
                    format!(
                        "head_nll: per-token loss {:?} vs tokens {:?} — {why}",
                        nll.shape, toks.shape
                    ),
                );
            }
        }
        for variant in ["block_fwd_masked", "block_capture"] {
            if let Some(v) = self.art(variant) {
                if let Some(y) = self.output(&v, "y") {
                    self.feed(&format!("{variant} → block_fwd"), &y, &x_in);
                }
            }
        }
    }

    /// The serving decode loop: `block_fwd_cached`'s outputs must chain
    /// back into its own inputs (y → x, k_new appended to k_cache, v_new
    /// to v_cache), and its per-token x must carry the same model dim as
    /// the prefill block.
    fn decode_pipeline(&mut self) {
        let cached = match self.art("block_fwd_cached") {
            Some(a) => a,
            None => return,
        };
        let x = match self.input(&cached, "x") {
            Some(t) => t,
            None => return,
        };
        if let Some(y) = self.output(&cached, "y") {
            self.feed("block_fwd_cached decode chain (y → x)", &y, &x);
        }
        for (new_name, cache_name) in [("k_new", "k_cache"), ("v_new", "v_cache")] {
            let newt = match self.output(&cached, new_name) {
                Some(t) => t,
                None => continue,
            };
            let cache = match self.input(&cached, cache_name) {
                Some(t) => t,
                None => continue,
            };
            // append compatibility: same rank, same batch (dim 0) and
            // feature (trailing) dims; the capacity dim (1) grows
            if newt.shape.len() != cache.shape.len() {
                self.push(
                    "graph-shape",
                    format!(
                        "block_fwd_cached: '{new_name}' rank {} cannot append to '{cache_name}' \
                         rank {}",
                        newt.shape.len(),
                        cache.shape.len()
                    ),
                );
                continue;
            }
            for d in [0usize, 2] {
                if d < newt.shape.len() && unify_dims(newt.shape[d], cache.shape[d]).is_none() {
                    self.push(
                        "graph-shape",
                        format!(
                            "block_fwd_cached: '{new_name}' dim {d} ({}) cannot append to \
                             '{cache_name}' ({})",
                            newt.shape[d], cache.shape[d]
                        ),
                    );
                }
            }
        }
        if let Some(pos) = self.input(&cached, "pos") {
            if pos.dtype != "int32" || pos.shape.len() != 1 {
                self.push(
                    "graph-dtype",
                    format!(
                        "block_fwd_cached: 'pos' must be rank-1 int32, got {} {:?}",
                        pos.dtype, pos.shape
                    ),
                );
            }
        }
        // prefill → decode handoff: same model dim on the hidden axis
        if let Some(block) = self.m.artifacts.get("block_fwd") {
            if let Some(bx) = block.inputs.iter().find(|t| t.name == "x") {
                if bx.shape.len() == 3
                    && x.shape.len() == 3
                    && unify_dims(bx.shape[2], x.shape[2]).is_none()
                {
                    self.push(
                        "graph-shape",
                        format!(
                            "prefill → decode handoff: block_fwd d_model {} != block_fwd_cached \
                             d_model {}",
                            bx.shape[2], x.shape[2]
                        ),
                    );
                }
            }
        }
    }

    /// Every BESA step (`besa_step_*`, `besa_quant_step_row`,
    /// `two_block_step`): its calibration activations must match the dense
    /// block's output, and every `theta_*` / `gamma_*` input must have a
    /// matching `dtheta_*` / `dgamma_*` gradient output of identical spec.
    fn besa_steps(&mut self) {
        let block_y = self
            .m
            .artifacts
            .get("block_fwd")
            .and_then(|b| b.outputs.iter().find(|t| t.name == "y").cloned());
        let names: Vec<String> = self
            .m
            .artifacts
            .keys()
            .filter(|k| k.starts_with("besa_") || *k == "two_block_step")
            .cloned()
            .collect();
        for name in names {
            let step = match self.art(&name) {
                Some(a) => a,
                None => continue,
            };
            for act in ["x_pruned", "y_dense"] {
                if let (Some(y), Some(a)) = (block_y.as_ref(), self.input(&step, act)) {
                    let y = y.clone();
                    self.feed(&format!("block_fwd → {name}"), &y, &a);
                }
            }
            self.grad_pairing(&step, "theta_", "dtheta_");
            self.grad_pairing(&step, "gamma_", "dgamma_");
            for scalar in ["loss", "recon", "mean_alpha"] {
                if let Some(t) = self.output(&step, scalar) {
                    if !t.shape.is_empty() {
                        self.push(
                            "graph-shape",
                            format!("{name}: '{scalar}' must be scalar, got {:?}", t.shape),
                        );
                    }
                }
            }
        }
    }

    /// For every input whose name contains `pat` (e.g. `theta_`), the op
    /// must emit a gradient output with `grad_pat` in its place and the
    /// identical shape/dtype — otherwise the optimizer would apply an
    /// update of the wrong shape.
    fn grad_pairing(&mut self, spec: &ArtifactSpec, pat: &str, grad_pat: &str) {
        let pairs: Vec<(TensorSpec, String)> = spec
            .inputs
            .iter()
            .filter(|t| t.name.contains(pat))
            .map(|t| (t.clone(), t.name.replacen(pat, grad_pat, 1)))
            .collect();
        for (input, grad_name) in pairs {
            match spec.outputs.iter().find(|t| t.name == grad_name) {
                None => self.push(
                    "graph-missing",
                    format!(
                        "artifact '{}': no gradient output '{grad_name}' for input '{}'",
                        spec.name, input.name
                    ),
                ),
                Some(g) => {
                    let g = g.clone();
                    self.feed(&format!("{} gradient pairing", spec.name), &g, &input);
                }
            }
        }
    }

    /// One `mask_decode_{r}x{c}` / `quant_apply_{r}x{c}` per distinct
    /// layer shape, internally consistent and agreeing with the per-layer
    /// theta specs of `besa_step_row`.
    fn mask_and_quant(&mut self) {
        let row_step = self.m.artifacts.get("besa_step_row").cloned();
        for w in LAYER_NAMES {
            let [r, c] = self.m.config.layer_shape(w);
            let md = match self.art(&format!("mask_decode_{r}x{c}")) {
                Some(a) => a,
                None => continue,
            };
            if let (Some(mask), Some(rank)) = (self.output(&md, "mask"), self.input(&md, "rank")) {
                if let Err(why) = unify_shapes(&mask.shape, &rank.shape) {
                    self.push(
                        "graph-shape",
                        format!(
                            "{}: mask {:?} vs rank {:?} — {why}",
                            md.name, mask.shape, rank.shape
                        ),
                    );
                }
                if rank.dtype != "int32" {
                    self.push(
                        "graph-dtype",
                        format!("{}: rank must be int32, got {}", md.name, rank.dtype),
                    );
                }
            }
            if let Some(alpha) = self.output(&md, "alpha") {
                if alpha.shape != [r] {
                    self.push(
                        "graph-shape",
                        format!("{}: alpha {:?}, expected [{r}]", md.name, alpha.shape),
                    );
                }
            }
            if let Some(step) = row_step.as_ref() {
                let theta_name = format!("theta_{w}");
                if let Some(st) = step.inputs.iter().find(|t| t.name == theta_name) {
                    let st = st.clone();
                    if let Some(mt) = self.input(&md, "theta") {
                        self.feed(&format!("besa_step_row → {}", md.name), &st, &mt);
                    }
                }
            }
            let qa = match self.art(&format!("quant_apply_{r}x{c}")) {
                Some(a) => a,
                None => continue,
            };
            if let (Some(wq), Some(win)) = (self.output(&qa, "wq"), self.input(&qa, "w")) {
                self.feed(&format!("{} (in-place weight update)", qa.name), &wq, &win);
            }
            if let Some(g) = self.input(&qa, "gamma") {
                if g.shape != [2] {
                    self.push(
                        "graph-shape",
                        format!("{}: gamma {:?}, expected [2]", qa.name, g.shape),
                    );
                }
            }
        }
    }

    /// `lm_train_step`: a `d_<param>` output of identical spec for every
    /// parameter input, and token agreement with `embed`.
    fn train_step(&mut self) {
        let step = match self.art("lm_train_step") {
            Some(a) => a,
            None => return,
        };
        let params: Vec<TensorSpec> =
            step.inputs.iter().filter(|t| t.name != "tokens").cloned().collect();
        for p in params {
            let grad_name = format!("d_{}", p.name);
            match step.outputs.iter().find(|t| t.name == grad_name) {
                None => self.push(
                    "graph-missing",
                    format!("lm_train_step: no gradient output '{grad_name}' for '{}'", p.name),
                ),
                Some(g) => {
                    let g = g.clone();
                    self.feed("lm_train_step gradient pairing", &g, &p);
                }
            }
        }
        if let (Some(t), Some(embed)) =
            (self.input(&step, "tokens"), self.m.artifacts.get("embed").cloned())
        {
            if let Some(et) = self.input(&embed, "tokens") {
                self.feed("lm_train_step/embed token agreement", &t, &et);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn unify_wildcards_and_conflicts() {
        assert_eq!(unify_dims(0, 0), Some(0));
        assert_eq!(unify_dims(0, 5), Some(5));
        assert_eq!(unify_dims(5, 0), Some(5));
        assert_eq!(unify_dims(5, 5), Some(5));
        assert_eq!(unify_dims(5, 6), None);
        assert_eq!(unify_shapes(&[0, 1, 32], &[4, 1, 32]), Ok(vec![4, 1, 32]));
        assert_eq!(unify_shapes(&[0, 0], &[0, 7]), Ok(vec![0, 7]));
        assert!(unify_shapes(&[2, 3], &[2, 3, 1]).is_err(), "rank mismatch");
        assert!(unify_shapes(&[2, 3], &[2, 4]).is_err(), "conflicting concrete dims");
    }

    #[test]
    fn builtin_manifests_verify_clean() {
        for name in ["test", "sm"] {
            let m = Manifest::synthesize(ModelConfig::builtin(name).unwrap());
            let diags = verify_manifest(&m);
            assert!(diags.is_empty(), "{name}: {:?}", diags);
        }
    }

    #[test]
    fn mutated_manifest_is_rejected() {
        let mut m = Manifest::synthesize(ModelConfig::builtin("test").unwrap());
        // widen the dense block's output hidden dim: breaks the layer
        // chain, the head feed and the BESA calibration feeds at once
        let block = m.artifacts.get_mut("block_fwd").unwrap();
        block.outputs[0].shape[2] += 1;
        let diags = verify_manifest(&m);
        assert!(!diags.is_empty());
        assert!(diags.iter().all(|d| d.file == "manifest:test" && d.line == 0));
        assert!(diags.iter().any(|d| d.rule == "graph-shape"));
    }

    #[test]
    fn missing_gradient_output_is_reported() {
        let mut m = Manifest::synthesize(ModelConfig::builtin("test").unwrap());
        let step = m.artifacts.get_mut("besa_step_row").unwrap();
        let dropped = step.outputs.iter().position(|t| t.name.starts_with("dtheta_")).unwrap();
        step.outputs.remove(dropped);
        let diags = verify_manifest(&m);
        assert!(diags.iter().any(|d| d.rule == "graph-missing"), "{diags:?}");
    }

    #[test]
    fn dynamic_call_binds_one_batch_and_one_capacity() {
        let m = Manifest::synthesize(ModelConfig::builtin("test").unwrap());
        let spec = m.artifact("block_fwd_cached").unwrap();
        let d = m.config.d_model;
        let x = Tensor::from_f32(&[2, 1, d], vec![0.0; 2 * d]);
        let k = Tensor::from_f32(&[2, 4, d], vec![0.0; 2 * 4 * d]);
        let v = Tensor::from_f32(&[2, 4, d], vec![0.0; 2 * 4 * d]);
        let pos = Tensor::from_i32(&[2], vec![4, 4]);
        let mut inputs: Vec<&Tensor> = vec![&x, &k, &v, &pos];
        // weights/norms are static; any placeholder works for this check
        let extras: Vec<Tensor> = spec.inputs[4..]
            .iter()
            .map(|s| Tensor::from_f32(&s.shape, vec![0.0; s.shape.iter().product()]))
            .collect();
        inputs.extend(extras.iter());
        assert!(check_dynamic_call(spec, &inputs).is_ok());

        // batch mismatch: pos says 3 requests, x says 2
        let bad_pos = Tensor::from_i32(&[3], vec![4, 4, 4]);
        let mut bad: Vec<&Tensor> = vec![&x, &k, &v, &bad_pos];
        bad.extend(extras.iter());
        let err = check_dynamic_call(spec, &bad).unwrap_err().to_string();
        assert!(err.contains("dynamic batch mismatch"), "{err}");

        // capacity mismatch between the two same-spec caches
        let v5 = Tensor::from_f32(&[2, 5, d], vec![0.0; 2 * 5 * d]);
        let mut bad2: Vec<&Tensor> = vec![&x, &k, &v5, &pos];
        bad2.extend(extras.iter());
        let err2 = check_dynamic_call(spec, &bad2).unwrap_err().to_string();
        assert!(err2.contains("dynamic dim"), "{err2}");
    }
}
