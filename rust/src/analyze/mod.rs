//! `besa analyze` — static analysis for the repo's parity discipline.
//!
//! Blockwise reconstruction (PAPER.md §3) is only meaningful under three
//! bit-exactness invariants: sparse==dense, cached==recompute, and
//! sharded==single-worker. Every one of them is enforced *dynamically* by
//! the parity test suites — after a panic-prone runtime path already
//! executed. This subsystem catches the same bug classes *before*
//! execution, on every CI run, with two pillars:
//!
//! * [`graph`] — an abstract interpreter over [`crate::runtime::TensorSpec`]
//!   op sequences. It verifies whole pipelines (embed → block chain →
//!   head, the `block_fwd_cached` decode loop, BESA step gradient
//!   pairings, `two_block_step`, mask-decode / quant-apply) by unifying
//!   shapes where a dim of 0 is a dynamic wildcard. `Engine` construction
//!   runs it, so a corrupt or hand-edited manifest is rejected at load
//!   time with structured diagnostics instead of panicking mid-run.
//! * [`lints`] — five repo-specific source lints over a `syn`-free lexer
//!   ([`lexer`]), each guarding a named invariant: `hot-path-panic` and
//!   `lock-order` keep the serve/sparse/native paths abort- and
//!   deadlock-free, `nondeterministic-iter` and `float-reduction-order`
//!   guard bit-exact reproducibility, `wallclock-in-replay` guards
//!   deterministic replay. `// besa-lint: allow(<rule>)` is the audited
//!   escape hatch.
//!
//! [`analyze_repo`] is the CLI/CI entry point: scan a source tree, graph-
//! check the built-in configs, and merge everything into one
//! [`report::AnalysisReport`] (JSON-emittable for machines).

pub mod graph;
pub mod lexer;
pub mod lints;
pub mod report;

pub use report::{AnalysisReport, Diagnostic};

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::model::config::ModelConfig;
use crate::runtime::Manifest;

use lints::SourceFile;

/// Run the full analysis: every `.rs` file under `src_root` through the
/// lint pass, plus a graph verification of each named built-in config's
/// synthesized manifest. Deterministic: files are scanned in sorted path
/// order.
pub fn analyze_repo(src_root: &Path, configs: &[String]) -> Result<AnalysisReport> {
    let mut paths = Vec::new();
    collect_rs(src_root, &mut paths)?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let src = std::fs::read_to_string(p).with_context(|| format!("reading {}", p.display()))?;
        let rel = p.strip_prefix(src_root).unwrap_or(p).to_string_lossy().replace('\\', "/");
        files.push(SourceFile::parse(&rel, &src));
    }
    let (mut findings, suppressed) = lints::run_lints(&files);
    let mut report = AnalysisReport {
        findings: Vec::new(),
        suppressed,
        files_scanned: files.len(),
        configs_checked: Vec::new(),
    };
    for name in configs {
        let cfg = ModelConfig::builtin(name)?;
        let m = Manifest::synthesize(cfg);
        findings.extend(graph::verify_manifest(&m));
        report.configs_checked.push(name.clone());
    }
    report.findings = findings;
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("scanning {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analyze_repo_walks_and_merges() {
        let dir = std::env::temp_dir().join("besa_analyze_mod_test");
        let sub = dir.join("serve");
        std::fs::create_dir_all(&sub).unwrap();
        std::fs::write(sub.join("bad.rs"), "fn f(x: Option<u8>) -> u8 { x.unwrap() }").unwrap();
        std::fs::write(dir.join("ok.rs"), "fn g() {}").unwrap();
        let report = analyze_repo(&dir, &["test".to_string()]).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(report.files_scanned, 2);
        assert_eq!(report.configs_checked, vec!["test"]);
        assert_eq!(report.findings.len(), 1);
        assert_eq!(report.findings[0].rule, "hot-path-panic");
        assert_eq!(report.findings[0].file, "serve/bad.rs");
    }
}
