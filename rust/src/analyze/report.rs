//! Structured diagnostics: what every analyzer pillar (graph checker,
//! source lints) emits, and the machine-readable report `besa analyze
//! --json` writes for CI.

use crate::util::json::{self, Json};

/// One finding. `file` is a source path relative to the scanned root for
/// lint findings, or `manifest:<config>` for graph-checker findings
/// (whose `line` is 0 — specs have no source location).
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    /// rule identifier, e.g. `hot-path-panic` or `graph-shape`
    pub rule: String,
    pub file: String,
    /// 1-based source line (0 for graph findings)
    pub line: usize,
    pub message: String,
}

impl Diagnostic {
    pub fn new(rule: &str, file: &str, line: usize, message: String) -> Diagnostic {
        Diagnostic { rule: rule.to_string(), file: file.to_string(), line, message }
    }

    /// `file:line: [rule] message` — the text form printed to stderr.
    pub fn render(&self) -> String {
        if self.line == 0 {
            format!("{}: [{}] {}", self.file, self.rule, self.message)
        } else {
            format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
        }
    }
}

/// The merged result of one `besa analyze` run.
#[derive(Debug, Default)]
pub struct AnalysisReport {
    /// unsuppressed findings — any entry here fails the run
    pub findings: Vec<Diagnostic>,
    /// findings silenced by an inline `// besa-lint: allow(<rule>)`
    pub suppressed: usize,
    pub files_scanned: usize,
    /// built-in configs whose synthesized manifests were graph-checked
    pub configs_checked: Vec<String>,
}

impl AnalysisReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("clean", Json::Bool(self.clean())),
            ("files_scanned", json::num(self.files_scanned as f64)),
            ("suppressed", json::num(self.suppressed as f64)),
            (
                "configs_checked",
                json::arr(self.configs_checked.iter().map(|c| json::s(c))),
            ),
            (
                "findings",
                json::arr(self.findings.iter().map(|d| {
                    json::obj(vec![
                        ("rule", json::s(&d.rule)),
                        ("file", json::s(&d.file)),
                        ("line", json::num(d.line as f64)),
                        ("message", json::s(&d.message)),
                    ])
                })),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_with_and_without_line() {
        let d = Diagnostic::new("hot-path-panic", "serve/x.rs", 7, "unwrap".into());
        assert_eq!(d.render(), "serve/x.rs:7: [hot-path-panic] unwrap");
        let g = Diagnostic::new("graph-shape", "manifest:test", 0, "mismatch".into());
        assert_eq!(g.render(), "manifest:test: [graph-shape] mismatch");
    }

    #[test]
    fn json_roundtrips() {
        let mut r = AnalysisReport::default();
        r.files_scanned = 3;
        r.configs_checked.push("test".into());
        assert!(r.clean());
        r.findings.push(Diagnostic::new("lock-order", "a.rs", 1, "cycle".into()));
        let j = r.to_json();
        assert_eq!(j.at(&["clean"]), &Json::Bool(false));
        assert_eq!(j.at(&["files_scanned"]).as_usize(), Some(3));
        let txt = j.to_string_pretty();
        assert_eq!(Json::parse(&txt).unwrap(), j);
    }
}
