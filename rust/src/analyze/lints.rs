//! The repo-specific lint pass: five lexical rules over [`super::lexer`]
//! token streams that mechanically enforce the parity invariants the
//! rustdoc promises.
//!
//! * `hot-path-panic` — no `.unwrap()` / `.expect()` / `panic!`-family
//!   macros in `serve/`, `sparse/`, `runtime/native/`, `kernel/`,
//!   `telemetry/`: request-serving and kernel code must propagate
//!   errors, not abort mid-batch.
//! * `nondeterministic-iter` — no `HashMap` / `HashSet` in the same
//!   parity-pinned modules: iteration order would silently break the
//!   sparse==dense and sharded==single-worker bit-exactness guarantees.
//! * `lock-order` — extract the mutex acquisition graph (both
//!   `<recv>.lock()` and the `util::par::locked(&…)` helper count as
//!   acquisitions) and flag nested-acquisition cycles and re-acquisition
//!   of a mutex already held.
//! * `float-reduction-order` — in files whose comments declare bitwise /
//!   bit-exact / parity guarantees, flag compound assignments to captured
//!   variables inside `par_map(…)` / `scoped_workers(…)` regions: an
//!   unordered parallel float reduction is not reproducible.
//! * `wallclock-in-replay` — no `Instant` / `SystemTime` in deterministic
//!   replay paths (`serve/` outside the wall-clock-by-design ingest /
//!   online / bench modules and the socket front end `serve/net/`, plus
//!   `sparse/` and `runtime/native/`; `telemetry/` is excluded — span
//!   timing *is* wall-clock measurement).
//!
//! `#[cfg(test)]` items are skipped entirely, and any finding can be
//! silenced with an inline `// besa-lint: allow(<rule>)` comment on the
//! same or the preceding line (a one-line safety justification is
//! expected after the closing paren).

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{lex, Tok, TokKind};
use super::report::Diagnostic;

/// Every rule the pass implements, in the order they run.
pub const RULES: [&str; 5] = [
    "hot-path-panic",
    "nondeterministic-iter",
    "lock-order",
    "float-reduction-order",
    "wallclock-in-replay",
];

/// One tokenized source file. `path` is relative to the scanned source
/// root and uses forward slashes — the rules scope themselves by prefix
/// (`serve/`, `sparse/`, `runtime/native/`, `kernel/`).
pub struct SourceFile {
    pub path: String,
    pub toks: Vec<Tok>,
}

impl SourceFile {
    pub fn parse(path: &str, src: &str) -> SourceFile {
        SourceFile { path: path.to_string(), toks: lex(src) }
    }
}

/// Run every rule over `files`; returns the unsuppressed findings plus
/// the count of findings silenced by inline allows. The lock-order graph
/// is global (edges from all files merge before cycle detection).
pub fn run_lints(files: &[SourceFile]) -> (Vec<Diagnostic>, usize) {
    let mut sink = Sink::default();
    let mut edges: BTreeMap<(String, String), Edge> = BTreeMap::new();
    for f in files {
        let mask = test_mask(&f.toks);
        let allows = allow_map(&f.toks);
        lint_hot_path_panic(f, &mask, &allows, &mut sink);
        lint_nondeterministic_iter(f, &mask, &allows, &mut sink);
        lint_wallclock(f, &mask, &allows, &mut sink);
        lint_float_reduction(f, &mask, &allows, &mut sink);
        collect_lock_edges(f, &mask, &allows, &mut sink, &mut edges);
    }
    lock_cycles(&edges, &mut sink);
    (sink.findings, sink.suppressed)
}

#[derive(Default)]
struct Sink {
    findings: Vec<Diagnostic>,
    suppressed: usize,
}

impl Sink {
    fn emit(
        &mut self,
        allows: &BTreeSet<(String, usize)>,
        rule: &str,
        file: &str,
        line: usize,
        message: String,
    ) {
        if allowed(allows, rule, line) {
            self.suppressed += 1;
        } else {
            self.findings.push(Diagnostic::new(rule, file, line, message));
        }
    }
}

/// A finding at `line` is silenced by an allow comment on the same line
/// (trailing comment) or the line directly above.
fn allowed(allows: &BTreeSet<(String, usize)>, rule: &str, line: usize) -> bool {
    allows.contains(&(rule.to_string(), line))
        || (line > 1 && allows.contains(&(rule.to_string(), line - 1)))
}

/// `(rule, comment line)` pairs from `// besa-lint: allow(a, b)` comments.
fn allow_map(toks: &[Tok]) -> BTreeSet<(String, usize)> {
    let mut out = BTreeSet::new();
    for t in toks {
        if t.kind != TokKind::LineComment || !t.text.contains("besa-lint:") {
            continue;
        }
        if let Some(p) = t.text.find("allow(") {
            let rest = &t.text[p + "allow(".len()..];
            if let Some(q) = rest.find(')') {
                for rule in rest[..q].split(',') {
                    out.insert((rule.trim().to_string(), t.line));
                }
            }
        }
    }
    out
}

/// Mark every token inside a `#[cfg(test)]`-attributed item. The item
/// extends to its first balanced `{…}` block (or a bare `;` for
/// declarations that have no body).
fn test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        let is_attr = is_p(toks, i, "#")
            && is_p(toks, i + 1, "[")
            && is_id(toks, i + 2, "cfg")
            && is_p(toks, i + 3, "(")
            && is_id(toks, i + 4, "test")
            && is_p(toks, i + 5, ")")
            && is_p(toks, i + 6, "]");
        if !is_attr {
            i += 1;
            continue;
        }
        let mut j = i + 7;
        let mut depth = 0i32;
        let mut saw_brace = false;
        while j < toks.len() {
            if toks[j].kind == TokKind::Punct {
                match toks[j].text.as_str() {
                    "{" => {
                        depth += 1;
                        saw_brace = true;
                    }
                    "}" => {
                        depth -= 1;
                        if saw_brace && depth == 0 {
                            break;
                        }
                    }
                    ";" if !saw_brace => break,
                    _ => {}
                }
            }
            j += 1;
        }
        let end = j.min(toks.len().saturating_sub(1));
        for k in i..=end {
            mask[k] = true;
        }
        i = end + 1;
    }
    mask
}

fn is_p(toks: &[Tok], i: usize, ch: &str) -> bool {
    i < toks.len() && toks[i].kind == TokKind::Punct && toks[i].text == ch
}

fn is_id(toks: &[Tok], i: usize, s: &str) -> bool {
    i < toks.len() && toks[i].kind == TokKind::Ident && toks[i].text == s
}

// ---- scoping ---------------------------------------------------------

/// Modules whose runtime paths must not panic and must iterate
/// deterministically.
fn hot_path_scope(path: &str) -> bool {
    path.starts_with("serve/")
        || path.starts_with("sparse/")
        || path.starts_with("runtime/native/")
        || path.starts_with("kernel/")
        || path.starts_with("telemetry/")
}

/// Deterministic-replay paths: the hot-path modules minus the serve
/// modules that measure wall-clock time by design — arrival pacing,
/// latency metrics, throughput benchmarks, the socket front end
/// (`serve/net/`: socket deadlines and drain timeouts are wall-clock by
/// nature), and `telemetry/` (span timing *is* wall-clock measurement).
fn replay_scope(path: &str) -> bool {
    const WALLCLOCK_BY_DESIGN: [&str; 3] = ["serve/ingest.rs", "serve/online.rs", "serve/bench.rs"];
    hot_path_scope(path)
        && !WALLCLOCK_BY_DESIGN.contains(&path)
        && !path.starts_with("serve/net/")
        && !path.starts_with("telemetry/")
}

// ---- simple per-token rules ------------------------------------------

const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

fn lint_hot_path_panic(
    f: &SourceFile,
    mask: &[bool],
    allows: &BTreeSet<(String, usize)>,
    sink: &mut Sink,
) {
    if !hot_path_scope(&f.path) {
        return;
    }
    let toks = &f.toks;
    for i in 0..toks.len() {
        if mask[i] || toks[i].kind != TokKind::Ident {
            continue;
        }
        let s = toks[i].text.as_str();
        if (s == "unwrap" || s == "expect")
            && i >= 1
            && is_p(toks, i - 1, ".")
            && is_p(toks, i + 1, "(")
        {
            sink.emit(
                allows,
                "hot-path-panic",
                &f.path,
                toks[i].line,
                format!("`.{s}()` on a hot path — propagate an error or add a justified allow"),
            );
        } else if PANIC_MACROS.contains(&s) && is_p(toks, i + 1, "!") {
            sink.emit(
                allows,
                "hot-path-panic",
                &f.path,
                toks[i].line,
                format!("`{s}!` on a hot path — propagate an error or add a justified allow"),
            );
        }
    }
}

fn lint_nondeterministic_iter(
    f: &SourceFile,
    mask: &[bool],
    allows: &BTreeSet<(String, usize)>,
    sink: &mut Sink,
) {
    if !hot_path_scope(&f.path) {
        return;
    }
    let toks = &f.toks;
    for i in 0..toks.len() {
        if mask[i] || toks[i].kind != TokKind::Ident {
            continue;
        }
        let s = toks[i].text.as_str();
        if s == "HashMap" || s == "HashSet" {
            sink.emit(
                allows,
                "nondeterministic-iter",
                &f.path,
                toks[i].line,
                format!("`{s}` in a parity-pinned module — use BTreeMap/BTreeSet so iteration order is deterministic"),
            );
        }
    }
}

fn lint_wallclock(
    f: &SourceFile,
    mask: &[bool],
    allows: &BTreeSet<(String, usize)>,
    sink: &mut Sink,
) {
    if !replay_scope(&f.path) {
        return;
    }
    let toks = &f.toks;
    for i in 0..toks.len() {
        if mask[i] || toks[i].kind != TokKind::Ident {
            continue;
        }
        let s = toks[i].text.as_str();
        if s == "Instant" || s == "SystemTime" {
            sink.emit(
                allows,
                "wallclock-in-replay",
                &f.path,
                toks[i].line,
                format!("`{s}` in a deterministic replay path — time must come from the recorded trace, not the wall clock"),
            );
        }
    }
}

// ---- float-reduction-order -------------------------------------------

fn lint_float_reduction(
    f: &SourceFile,
    mask: &[bool],
    allows: &BTreeSet<(String, usize)>,
    sink: &mut Sink,
) {
    let toks = &f.toks;
    let declares_parity = toks.iter().any(|t| {
        matches!(t.kind, TokKind::LineComment | TokKind::BlockComment)
            && (t.text.contains("bitwise")
                || t.text.contains("bit-exact")
                || t.text.contains("parity"))
    });
    if !declares_parity {
        return;
    }
    let mut i = 0usize;
    while i < toks.len() {
        let head = !mask[i]
            && toks[i].kind == TokKind::Ident
            && (toks[i].text == "par_map" || toks[i].text == "scoped_workers")
            && is_p(toks, i + 1, "(");
        if !head {
            i += 1;
            continue;
        }
        // the balanced (…) argument region of the parallel call
        let mut depth = 1i32;
        let mut j = i + 2;
        while j < toks.len() {
            if toks[j].kind == TokKind::Punct {
                match toks[j].text.as_str() {
                    "(" => depth += 1,
                    ")" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
            }
            j += 1;
        }
        check_reduction_region(f, mask, allows, sink, i + 2, j);
        i = j + 1;
    }
}

/// Idents bound inside the region (`let` bindings and closure parameter
/// lists) — compound assignment to these is a private per-item
/// accumulator, which is fine.
fn region_locals(toks: &[Tok], lo: usize, hi: usize) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut k = lo;
    while k < hi {
        if is_id(toks, k, "let") {
            let mut m = k + 1;
            if is_id(toks, m, "mut") {
                m += 1;
            }
            if m < hi && toks[m].kind == TokKind::Ident {
                out.insert(toks[m].text.clone());
            }
        }
        if is_p(toks, k, "|") {
            // closure head: collect idents until the closing `|`; bail if
            // a `{` or `;` shows up first (then it was a bit-or, not a
            // parameter list)
            let mut m = k + 1;
            while m < hi {
                if is_p(toks, m, "|") {
                    k = m;
                    break;
                }
                if is_p(toks, m, "{") || is_p(toks, m, ";") {
                    break;
                }
                if toks[m].kind == TokKind::Ident {
                    out.insert(toks[m].text.clone());
                }
                m += 1;
            }
        }
        k += 1;
    }
    out
}

fn check_reduction_region(
    f: &SourceFile,
    mask: &[bool],
    allows: &BTreeSet<(String, usize)>,
    sink: &mut Sink,
    lo: usize,
    hi: usize,
) {
    let toks = &f.toks;
    let locals = region_locals(toks, lo, hi);
    for k in lo..hi {
        if mask[k] || k + 1 >= hi {
            continue;
        }
        let op_ok = toks[k].kind == TokKind::Punct
            && matches!(toks[k].text.as_str(), "+" | "-" | "*")
            && is_p(toks, k + 1, "=");
        if !op_ok || k == 0 {
            continue;
        }
        if let Some(name) = recv_name(toks, k as isize - 1) {
            if !locals.contains(&name) {
                sink.emit(
                    allows,
                    "float-reduction-order",
                    &f.path,
                    toks[k].line,
                    format!("compound assignment to captured '{name}' inside an unordered parallel region of a parity-declared kernel — reduction order is nondeterministic"),
                );
            }
        }
    }
}

// ---- lock-order -------------------------------------------------------

/// A representative site for a "second acquired while first held" edge.
struct Edge {
    file: String,
    line: usize,
    allowed: bool,
}

/// A mutex guard currently live during the linear scan.
struct Held {
    lock: String,
    /// the `let`-bound guard variable, if any (released by `drop(var)`)
    var: Option<String>,
    /// brace depth at acquisition; the guard dies when the enclosing
    /// block closes (or, for temporaries, at the next statement `;`)
    depth: i32,
}

/// Walk backwards from `j` to the identifier that names the receiver /
/// argument, skipping one balanced `[…]` or `(…)` group (indexing or a
/// call on the path).
fn recv_name(toks: &[Tok], mut j: isize) -> Option<String> {
    while j >= 0 {
        let t = &toks[j as usize];
        match t.kind {
            TokKind::LineComment | TokKind::BlockComment => j -= 1,
            TokKind::Ident => return Some(t.text.clone()),
            TokKind::Punct if t.text == "]" || t.text == ")" => {
                let (open, close) = if t.text == "]" { ("[", "]") } else { ("(", ")") };
                let mut depth = 1i32;
                j -= 1;
                while j >= 0 && depth > 0 {
                    let u = &toks[j as usize];
                    if u.kind == TokKind::Punct && u.text == close {
                        depth += 1;
                    } else if u.kind == TokKind::Punct && u.text == open {
                        depth -= 1;
                    }
                    j -= 1;
                }
            }
            _ => return None,
        }
    }
    None
}

/// Last identifier inside the balanced parens opening at `open` — the
/// lock field in `locked(&self.state)`.
fn last_ident_in_parens(toks: &[Tok], open: usize) -> Option<String> {
    let mut depth = 1i32;
    let mut j = open + 1;
    let mut last = None;
    while j < toks.len() && depth > 0 {
        match toks[j].kind {
            TokKind::Punct if toks[j].text == "(" => depth += 1,
            TokKind::Punct if toks[j].text == ")" => depth -= 1,
            TokKind::Ident => last = Some(toks[j].text.clone()),
            _ => {}
        }
        j += 1;
    }
    last
}

/// If the statement containing token `from` is a `let` binding, return
/// the bound name (`let mut g = …` → `g`).
fn stmt_let_binding(toks: &[Tok], from: usize) -> Option<String> {
    let mut j = from as isize - 1;
    while j >= 0 {
        let t = &toks[j as usize];
        if t.kind == TokKind::Punct && matches!(t.text.as_str(), ";" | "{" | "}") {
            break;
        }
        j -= 1;
    }
    let mut k = (j + 1) as usize;
    while k < toks.len() && matches!(toks[k].kind, TokKind::LineComment | TokKind::BlockComment) {
        k += 1;
    }
    if !is_id(toks, k, "let") {
        return None;
    }
    k += 1;
    if is_id(toks, k, "mut") {
        k += 1;
    }
    if k < toks.len() && toks[k].kind == TokKind::Ident {
        return Some(toks[k].text.clone());
    }
    None
}

/// Linear scan of one file: track live guards through brace depth,
/// statement ends and `drop(…)` calls; record an edge for every lock
/// acquired while another is held; flag re-acquisition of a held lock
/// immediately.
fn collect_lock_edges(
    f: &SourceFile,
    mask: &[bool],
    allows: &BTreeSet<(String, usize)>,
    sink: &mut Sink,
    edges: &mut BTreeMap<(String, String), Edge>,
) {
    let toks = &f.toks;
    let mut depth: i32 = 0;
    let mut held: Vec<Held> = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if mask[i] {
            i += 1;
            continue;
        }
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    held.retain(|h| h.depth <= depth);
                }
                // a statement end drops un-bound (temporary) guards
                ";" => held.retain(|h| h.var.is_some() || h.depth < depth),
                _ => {}
            }
            i += 1;
            continue;
        }
        if t.kind == TokKind::Ident {
            if t.text == "drop"
                && is_p(toks, i + 1, "(")
                && i + 3 < toks.len()
                && toks[i + 2].kind == TokKind::Ident
                && is_p(toks, i + 3, ")")
            {
                let name = toks[i + 2].text.clone();
                held.retain(|h| h.var.as_deref() != Some(name.as_str()));
                i += 4;
                continue;
            }
            let acquired = if t.text == "lock"
                && i >= 2
                && is_p(toks, i - 1, ".")
                && is_p(toks, i + 1, "(")
            {
                recv_name(toks, i as isize - 2)
            } else if t.text == "locked" && is_p(toks, i + 1, "(") {
                last_ident_in_parens(toks, i + 1)
            } else {
                None
            };
            if let Some(lock) = acquired {
                for h in &held {
                    if h.lock == lock {
                        sink.emit(
                            allows,
                            "lock-order",
                            &f.path,
                            t.line,
                            format!("mutex '{lock}' acquired while already held — self-deadlock"),
                        );
                    } else {
                        edges.entry((h.lock.clone(), lock.clone())).or_insert(Edge {
                            file: f.path.clone(),
                            line: t.line,
                            allowed: allowed(allows, "lock-order", t.line),
                        });
                    }
                }
                let var = stmt_let_binding(toks, i);
                held.push(Held { lock, var, depth });
            }
        }
        i += 1;
    }
}

/// An edge `u → v` ("v acquired while u held") is part of a deadlock
/// cycle iff `u` is reachable from `v` through the edge graph.
fn lock_cycles(edges: &BTreeMap<(String, String), Edge>, sink: &mut Sink) {
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (u, v) in edges.keys() {
        adj.entry(u.as_str()).or_default().push(v.as_str());
    }
    for ((u, v), e) in edges {
        if reaches(&adj, v.as_str(), u.as_str()) {
            if e.allowed {
                sink.suppressed += 1;
            } else {
                sink.findings.push(Diagnostic::new(
                    "lock-order",
                    &e.file,
                    e.line,
                    format!(
                        "lock-order inversion: '{v}' acquired while holding '{u}', but '{u}' is \
                         also acquired while '{v}' is held elsewhere — deadlock cycle"
                    ),
                ));
            }
        }
    }
}

fn reaches(adj: &BTreeMap<&str, Vec<&str>>, from: &str, to: &str) -> bool {
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    let mut stack = vec![from];
    while let Some(x) = stack.pop() {
        if x == to {
            return true;
        }
        if !seen.insert(x) {
            continue;
        }
        if let Some(next) = adj.get(x) {
            stack.extend(next.iter().copied());
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_one(path: &str, src: &str) -> (Vec<Diagnostic>, usize) {
        run_lints(&[SourceFile::parse(path, src)])
    }

    fn rules(findings: &[Diagnostic]) -> Vec<&str> {
        findings.iter().map(|d| d.rule.as_str()).collect()
    }

    #[test]
    fn hot_path_unwrap_flagged_only_in_scope() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }";
        let (f, _) = run_one("serve/a.rs", src);
        assert_eq!(rules(&f), vec!["hot-path-panic"]);
        assert_eq!(f[0].line, 1);
        let (f2, _) = run_one("util/a.rs", src);
        assert!(f2.is_empty(), "util/ is outside the hot-path scope");
    }

    #[test]
    fn kernel_modules_are_in_hot_path_scope() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }";
        let (f, _) = run_one("kernel/gemm.rs", src);
        assert_eq!(rules(&f), vec!["hot-path-panic"]);
    }

    #[test]
    fn panic_macros_flagged() {
        let (f, _) = run_one("sparse/k.rs", "fn f() { panic!(\"boom\") }");
        assert_eq!(rules(&f), vec!["hot-path-panic"]);
        let (f2, _) = run_one("runtime/native/k.rs", "fn f() { unreachable!() }");
        assert_eq!(rules(&f2), vec!["hot-path-panic"]);
    }

    #[test]
    fn allow_comment_suppresses_same_and_next_line() {
        let src = "fn f(x: Option<u8>) -> u8 {\n    // besa-lint: allow(hot-path-panic) — checked by caller\n    x.unwrap()\n}";
        let (f, suppressed) = run_one("serve/a.rs", src);
        assert!(f.is_empty());
        assert_eq!(suppressed, 1);
        let trailing =
            "fn f(x: Option<u8>) -> u8 { x.unwrap() } // besa-lint: allow(hot-path-panic) — ok";
        let (f2, s2) = run_one("serve/a.rs", trailing);
        assert!(f2.is_empty());
        assert_eq!(s2, 1);
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    fn g() { None::<u8>.unwrap(); panic!(\"x\"); }\n}\nfn h(v: Option<u8>) -> u8 { v.unwrap() }";
        let (f, _) = run_one("serve/a.rs", src);
        assert_eq!(rules(&f), vec!["hot-path-panic"]);
        assert_eq!(f[0].line, 5, "only the non-test unwrap is flagged");
    }

    #[test]
    fn nondeterministic_collections_flagged() {
        let src = "use std::collections::HashMap;\nfn f() {}";
        let (f, _) = run_one("runtime/native/x.rs", src);
        assert_eq!(rules(&f), vec!["nondeterministic-iter"]);
        let (f2, _) = run_one("runtime/native/x.rs", "use std::collections::BTreeMap;\n");
        assert!(f2.is_empty());
    }

    #[test]
    fn wallclock_scope_excludes_by_design_modules() {
        let src = "fn f() { let _t = Instant::now(); }";
        let (f, _) = run_one("serve/engine.rs", src);
        assert_eq!(rules(&f), vec!["wallclock-in-replay"]);
        let (f2, _) = run_one("serve/bench.rs", src);
        assert!(f2.is_empty(), "bench measures wall-clock by design");
    }

    #[test]
    fn wallclock_scope_excludes_net_and_telemetry() {
        let src = "fn f() { let _t = Instant::now(); }";
        let (f, _) = run_one("serve/net/server.rs", src);
        assert!(f.is_empty(), "socket deadlines are wall-clock by nature");
        let (f2, _) = run_one("telemetry/mod.rs", src);
        assert!(f2.is_empty(), "span timing is wall-clock measurement");
    }

    #[test]
    fn fault_injection_is_in_every_scope() {
        // serve/fault.rs is deterministic by contract: panics only fire
        // through the audited inject() allow, and triggers are seeded —
        // so it stays inside BOTH the hot-path and replay scopes
        let (f, _) = run_one("serve/fault.rs", "fn f(x: Option<u8>) -> u8 { x.unwrap() }");
        assert_eq!(rules(&f), vec!["hot-path-panic"]);
        let (f2, _) = run_one("serve/fault.rs", "fn f() { let _t = Instant::now(); }");
        assert_eq!(rules(&f2), vec!["wallclock-in-replay"]);
        let (f3, _) = run_one("serve/fault.rs", "use std::collections::HashMap;\n");
        assert_eq!(rules(&f3), vec!["nondeterministic-iter"]);
    }

    #[test]
    fn telemetry_and_net_are_in_hot_path_scope() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }";
        let (f, _) = run_one("telemetry/mod.rs", src);
        assert_eq!(rules(&f), vec!["hot-path-panic"]);
        let (f2, _) = run_one("serve/net/proto.rs", src);
        assert_eq!(rules(&f2), vec!["hot-path-panic"]);
        let (f3, _) = run_one("serve/net/server.rs", "use std::collections::HashMap;\n");
        assert_eq!(rules(&f3), vec!["nondeterministic-iter"]);
    }

    #[test]
    fn lock_order_inversion_is_flagged() {
        let src = "fn one(s: &S) {\n    let g = s.state.lock().unwrap();\n    let h = s.queue.lock().unwrap();\n    drop(h);\n    drop(g);\n}\nfn two(s: &S) {\n    let h = s.queue.lock().unwrap();\n    let g = s.state.lock().unwrap();\n    drop(g);\n    drop(h);\n}";
        let (f, _) = run_one("util/fixture.rs", src);
        assert_eq!(rules(&f), vec!["lock-order", "lock-order"], "both edges of the cycle");
    }

    #[test]
    fn lock_order_clean_when_never_nested() {
        let src = "fn one(s: &S) {\n    let g = s.state.lock().unwrap();\n    drop(g);\n    let h = s.queue.lock().unwrap();\n    drop(h);\n}\nfn two(s: &S) {\n    let h = s.queue.lock().unwrap();\n    let g = s.state.lock().unwrap();\n    drop(g);\n    drop(h);\n}";
        let (f, _) = run_one("util/fixture.rs", src);
        assert!(f.is_empty(), "consistent nesting direction has no cycle: {f:?}");
    }

    #[test]
    fn locked_helper_counts_as_acquisition() {
        let src = "fn one(s: &S) {\n    let g = locked(&s.state);\n    let h = locked(&s.queue);\n    drop(h);\n    drop(g);\n}\nfn two(s: &S) {\n    let h = locked(&s.queue);\n    let g = locked(&s.state);\n    drop(g);\n    drop(h);\n}";
        let (f, _) = run_one("util/fixture.rs", src);
        assert_eq!(rules(&f), vec!["lock-order", "lock-order"]);
    }

    #[test]
    fn self_deadlock_is_flagged() {
        let src = "fn f(s: &S) {\n    let a = s.state.lock().unwrap();\n    let b = s.state.lock().unwrap();\n    drop(b);\n    drop(a);\n}";
        let (f, _) = run_one("util/fixture.rs", src);
        assert_eq!(rules(&f), vec!["lock-order"]);
        assert!(f[0].message.contains("self-deadlock"));
    }

    #[test]
    fn block_scoped_guard_releases_at_brace() {
        let src = "fn f(s: &S) {\n    {\n        let g = s.state.lock().unwrap();\n    }\n    let h = s.queue.lock().unwrap();\n    drop(h);\n}\nfn g2(s: &S) {\n    let h = s.queue.lock().unwrap();\n    let g = s.state.lock().unwrap();\n}";
        let (f, _) = run_one("util/fixture.rs", src);
        assert!(f.is_empty(), "guard scoped to an inner block creates no edge: {f:?}");
    }

    #[test]
    fn float_reduction_on_captured_accumulator() {
        let src = "//! Kernel with bitwise parity guarantee.\nfn k(xs: &[f32]) -> f32 {\n    let mut total = 0.0;\n    par_map(xs, |x| {\n        total += x;\n        Ok(())\n    });\n    total\n}";
        let (f, _) = run_one("sparse/k.rs", src);
        assert_eq!(rules(&f), vec!["float-reduction-order"]);
        assert!(f[0].message.contains("total"));
    }

    #[test]
    fn float_reduction_local_accumulator_is_clean() {
        let src = "//! bit-exact row kernel\nfn k(xs: &[Vec<f32>]) {\n    par_map(xs, |row| {\n        let mut part = 0.0;\n        for v in row {\n            part += v;\n        }\n        Ok(part)\n    });\n}";
        let (f, _) = run_one("sparse/k.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn float_reduction_needs_parity_declaration() {
        let src = "fn k(xs: &[f32]) -> f32 {\n    let mut total = 0.0;\n    par_map(xs, |x| {\n        total += x;\n        Ok(())\n    });\n    total\n}";
        let (f, _) = run_one("sparse/k.rs", src);
        assert!(f.is_empty(), "no parity promise in comments → rule is silent");
    }
}
