//! Host tensor substrate: a minimal dense tensor (f32 / i32), the `.bst`
//! binary checkpoint format, and (behind the `pjrt` feature) PJRT literal
//! conversion.

pub mod io;

#[cfg(feature = "pjrt")]
use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Dense host tensor with row-major layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: Data::F32(vec![0.0; numel(shape)]) }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: Data::F32(vec![1.0; numel(shape)]) }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape {shape:?} vs len {}", data.len());
        Tensor { shape: shape.to_vec(), data: Data::F32(data) }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(numel(shape), data.len());
        Tensor { shape: shape.to_vec(), data: Data::I32(data) }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor::from_f32(&[], vec![v])
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn is_f32(&self) -> bool {
        matches!(self.data, Data::F32(_))
    }

    pub fn f32s(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn f32s_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            Data::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            Data::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    pub fn dtype_str(&self) -> &'static str {
        match self.data {
            Data::F32(_) => "float32",
            Data::I32(_) => "int32",
        }
    }

    /// 2D accessor (row-major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.f32s()[i * self.shape[1] + j]
    }

    pub fn scalar_value(&self) -> f32 {
        self.f32s()[0]
    }

    pub fn reshaped(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(numel(shape), self.numel());
        self.shape = shape.to_vec();
        self
    }

    /// Fraction of exact zeros (sparsity of a masked weight).
    pub fn zero_fraction(&self) -> f64 {
        let d = self.f32s();
        if d.is_empty() {
            return 0.0;
        }
        d.iter().filter(|x| **x == 0.0).count() as f64 / d.len() as f64
    }

    pub fn l2(&self) -> f64 {
        self.f32s().iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
    }

    /// Convert to a PJRT literal (copies).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<usize> = self.shape.clone();
        match &self.data {
            Data::F32(v) => {
                let bytes =
                    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &dims,
                    bytes,
                )
                .map_err(|e| anyhow::anyhow!("literal create failed: {e:?}"))
            }
            Data::I32(v) => {
                let bytes =
                    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    &dims,
                    bytes,
                )
                .map_err(|e| anyhow::anyhow!("literal create failed: {e:?}"))
            }
        }
    }

    /// Convert back from a PJRT literal.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.shape().map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
        let (dims, ty) = match shape {
            xla::Shape::Array(a) => {
                let dims: Vec<usize> = a.dims().iter().map(|d| *d as usize).collect();
                (dims, a.ty())
            }
            other => bail!("expected array literal, got {other:?}"),
        };
        match ty {
            xla::ElementType::F32 => {
                let v = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("literal to_vec f32: {e:?}"))?;
                Ok(Tensor::from_f32(&dims, v))
            }
            xla::ElementType::S32 => {
                let v = lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("literal to_vec i32: {e:?}"))?;
                Ok(Tensor::from_i32(&dims, v))
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.dtype_str(), "float32");
    }

    #[test]
    fn zero_fraction() {
        let t = Tensor::from_f32(&[4], vec![0., 1., 0., 2.]);
        assert_eq!(t.zero_fraction(), 0.5);
    }

    #[test]
    #[should_panic]
    fn dtype_mismatch_panics() {
        Tensor::from_i32(&[1], vec![3]).f32s();
    }

    #[test]
    fn reshape_checks() {
        let t = Tensor::zeros(&[2, 3]).reshaped(&[6]);
        assert_eq!(t.shape, vec![6]);
    }
}
