//! `.bst` ("besa tensors") checkpoint format — a safetensors-style
//! single-file container built from scratch for the offline toolchain.
//!
//! Layout (all little-endian):
//! ```text
//! magic  b"BST1"
//! u32    header_len
//! header JSON: {"name": {"dtype": "float32", "shape": [..], "offset": N, "nbytes": M}, ...}
//! data   concatenated raw tensor bytes (8-byte aligned per entry)
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

use super::{Data, Tensor};

const MAGIC: &[u8; 4] = b"BST1";

pub fn save(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let mut header = BTreeMap::new();
    let mut offset = 0usize;
    for (name, t) in tensors {
        let nbytes = t.numel() * 4;
        header.insert(
            name.clone(),
            json::obj(vec![
                ("dtype", json::s(t.dtype_str())),
                ("shape", Json::Arr(t.shape.iter().map(|d| Json::Num(*d as f64)).collect())),
                ("offset", Json::Num(offset as f64)),
                ("nbytes", Json::Num(nbytes as f64)),
            ]),
        );
        offset += (nbytes + 7) / 8 * 8;
    }
    let header_str = Json::Obj(header).to_string();

    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&(header_str.len() as u32).to_le_bytes())?;
    f.write_all(header_str.as_bytes())?;
    let mut written = 0usize;
    for t in tensors.values() {
        let bytes: &[u8] = match &t.data {
            Data::F32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
            Data::I32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
        };
        f.write_all(bytes)?;
        written += bytes.len();
        let pad = (written + 7) / 8 * 8 - written;
        f.write_all(&[0u8; 8][..pad])?;
        written += pad;
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a .bst file (bad magic)", path.display());
    }
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?)?;
    let mut data = Vec::new();
    f.read_to_end(&mut data)?;

    let mut out = BTreeMap::new();
    let obj = header.as_obj().context("bst header is not an object")?;
    for (name, meta) in obj {
        let dtype = meta.at(&["dtype"]).as_str().context("dtype")?.to_string();
        let shape: Vec<usize> = meta
            .at(&["shape"])
            .as_arr()
            .context("shape")?
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        let offset = meta.at(&["offset"]).as_usize().context("offset")?;
        let nbytes = meta.at(&["nbytes"]).as_usize().context("nbytes")?;
        if offset + nbytes > data.len() {
            bail!("tensor {name} out of bounds in {}", path.display());
        }
        let raw = &data[offset..offset + nbytes];
        let t = match dtype.as_str() {
            "float32" => {
                let mut v = vec![0f32; nbytes / 4];
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        raw.as_ptr(),
                        v.as_mut_ptr() as *mut u8,
                        nbytes,
                    )
                };
                Tensor::from_f32(&shape, v)
            }
            "int32" => {
                let mut v = vec![0i32; nbytes / 4];
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        raw.as_ptr(),
                        v.as_mut_ptr() as *mut u8,
                        nbytes,
                    )
                };
                Tensor::from_i32(&shape, v)
            }
            other => bail!("unknown dtype {other}"),
        };
        out.insert(name.clone(), t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("bst_test_{}", std::process::id()));
        let path = dir.join("ckpt.bst");
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]));
        m.insert("b.ranks".to_string(), Tensor::from_i32(&[3], vec![7, -1, 0]));
        m.insert("c".to_string(), Tensor::from_f32(&[3], vec![0.5, -0.5, 1e-9]));
        save(&path, &m).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(m, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("bst_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bst");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
