//! `besa` — leader entrypoint for the BESA pruning framework.
//! See `besa help` or README.md for usage.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = besa::cli::main(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
