//! Wanda baseline (Sun et al., 2023): prune the lowest
//! `|W| * ||X||_2`-scored fraction of each row, uniform rate everywhere.
//! This is also the paper's "layer" granularity row in Table 6.

use anyhow::Result;

use crate::coordinator::{BlockCtx, BlockPruner};
use crate::model::LAYER_NAMES;
use crate::prune::importance::{ranks, wanda_scores};
use crate::prune::{topk_row_mask, BlockMasks, BlockReport};

pub struct WandaPruner {
    pub sparsity: f64,
}

impl BlockPruner for WandaPruner {
    fn name(&self) -> &str {
        "wanda"
    }

    fn prune_block(&mut self, ctx: &mut BlockCtx) -> Result<(BlockMasks, BlockReport)> {
        let mut masks = BlockMasks::new();
        let mut report = BlockReport::default();
        for w in LAYER_NAMES {
            let weight = ctx.weight(w);
            let colnorm = ctx.colnorms.for_layer(w);
            let scores = wanda_scores(weight, &colnorm);
            let mask = topk_row_mask(&scores, self.sparsity);
            report.layer_sparsity.insert(w.to_string(), mask.zero_fraction());
            masks.insert(w.to_string(), mask);
        }
        Ok((masks, report))
    }
}

/// Precomputed per-layer ranks for a block (used by BESA and tests).
pub fn block_ranks(ctx: &BlockCtx, metric: crate::prune::importance::Metric) -> Vec<crate::tensor::Tensor> {
    use crate::prune::importance::{magnitude_scores, sparsegpt_scores, Metric};
    LAYER_NAMES
        .iter()
        .map(|w| {
            let weight = ctx.weight(w);
            let scores = match metric {
                Metric::WeightMagnitude => magnitude_scores(weight),
                Metric::Wanda => wanda_scores(weight, &ctx.colnorms.for_layer(w)),
                Metric::SparseGpt => {
                    let h = ctx.hessian_for(w);
                    let mut damped = h.clone();
                    let mean_diag =
                        (0..h.rows).map(|i| h[(i, i)]).sum::<f64>() / h.rows as f64;
                    damped.add_diag(0.01 * mean_diag + 1e-8);
                    let inv = crate::linalg::cholesky_inverse(&damped)
                        .expect("damped hessian must be PD");
                    let diag: Vec<f64> = (0..inv.rows).map(|i| inv[(i, i)]).collect();
                    sparsegpt_scores(weight, &diag)
                }
            };
            ranks(&scores)
        })
        .collect()
}
