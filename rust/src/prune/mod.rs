//! Pruning: the paper's BESA algorithm plus the three baselines it is
//! evaluated against (magnitude, Wanda, SparseGPT), all operating on the
//! same block-sequential calibration pipeline ([`crate::coordinator`]).

pub mod adam;
pub mod besa;
pub mod importance;
pub mod magnitude;
pub mod sparsegpt;
pub mod wanda;

use std::collections::BTreeMap;

use crate::tensor::Tensor;

/// Per-layer masks for one transformer block, keyed by layer name
/// (`wq`..`wd`), values are 0/1 f32 tensors of the weight shape.
pub type BlockMasks = BTreeMap<String, Tensor>;

/// Which pruning algorithm to run over the block pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Dense,
    Magnitude,
    Wanda,
    SparseGpt,
    Besa,
}

impl Method {
    pub fn from_name(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "dense" => Some(Method::Dense),
            "magnitude" | "mag" => Some(Method::Magnitude),
            "wanda" => Some(Method::Wanda),
            "sparsegpt" => Some(Method::SparseGpt),
            "besa" => Some(Method::Besa),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Dense => "dense",
            Method::Magnitude => "magnitude",
            Method::Wanda => "wanda",
            Method::SparseGpt => "sparsegpt",
            Method::Besa => "besa",
        }
    }
}

/// Summary of pruning one block: achieved sparsity per layer + losses.
#[derive(Debug, Clone, Default)]
pub struct BlockReport {
    pub block: usize,
    pub layer_sparsity: BTreeMap<String, f64>,
    pub recon_error: f64,
    pub steps: usize,
}

impl BlockReport {
    pub fn mean_sparsity(&self, cfg: &crate::model::ModelConfig) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for (name, s) in &self.layer_sparsity {
            let sh = cfg.layer_shape(name);
            let n = (sh[0] * sh[1]) as f64;
            num += s * n;
            den += n;
        }
        if den > 0.0 {
            num / den
        } else {
            0.0
        }
    }
}

/// Build a 0/1 mask pruning the lowest-scored `sparsity` fraction of each
/// row. Hot path of Wanda/magnitude pruning (called for every layer of
/// every block): partial selection via `select_nth_unstable_by` — O(C) per
/// row instead of a full O(C log C) sort — with NaN-safe `total_cmp`
/// ordering (NaN ranks highest, i.e. is never preferred for pruning).
pub fn topk_row_mask(scores: &Tensor, sparsity: f64) -> Tensor {
    let rows = scores.shape[0];
    let cols = scores.shape[1];
    let prune = (((cols as f64) * sparsity).round() as usize).min(cols);
    let mut mask = vec![1.0f32; rows * cols];
    if prune == 0 {
        return Tensor::from_f32(&[rows, cols], mask);
    }
    let mut idx: Vec<usize> = Vec::with_capacity(cols);
    for r in 0..rows {
        let row = &scores.f32s()[r * cols..(r + 1) * cols];
        idx.clear();
        idx.extend(0..cols);
        if prune < cols {
            idx.select_nth_unstable_by(prune - 1, |a, b| row[*a].total_cmp(&row[*b]));
        }
        for &j in &idx[..prune] {
            mask[r * cols + j] = 0.0;
        }
    }
    Tensor::from_f32(&[rows, cols], mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_names_roundtrip() {
        for m in [Method::Dense, Method::Magnitude, Method::Wanda, Method::SparseGpt, Method::Besa]
        {
            assert_eq!(Method::from_name(m.name()), Some(m));
        }
        assert_eq!(Method::from_name("nope"), None);
    }

    #[test]
    fn topk_mask_exact_sparsity() {
        let scores = Tensor::from_f32(&[2, 4], vec![0.1, 0.4, 0.3, 0.2, 9.0, 1.0, 5.0, 3.0]);
        let m = topk_row_mask(&scores, 0.5);
        assert_eq!(m.f32s(), &[0., 1., 1., 0., 1., 0., 1., 0.]);
        assert_eq!(m.zero_fraction(), 0.5);
    }

    #[test]
    fn block_report_weighted_mean() {
        let cfg = crate::model::config::tests::test_config();
        let mut r = BlockReport::default();
        for w in crate::model::LAYER_NAMES {
            r.layer_sparsity.insert(w.to_string(), 0.5);
        }
        assert!((r.mean_sparsity(&cfg) - 0.5).abs() < 1e-12);
    }
}
