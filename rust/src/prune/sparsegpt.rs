//! SparseGPT baseline (Frantar & Alistarh, 2023): blocked Optimal Brain
//! Surgeon with weight updates, implemented from scratch on the
//! [`crate::linalg`] substrate.
//!
//! Per layer with weight `W [R, C]` and input Hessian `H = X^T X + λI`:
//! compute `Hinv = U U^T` (upper Cholesky factor of `H^{-1}`), then sweep
//! columns in blocks of `blocksize`; inside a block, per row, mark the
//! lowest-score entries (`w^2 / [U]_{jj}^2`) for pruning at the target
//! rate and propagate the OBS error compensation
//! `w_k -= (w_j / [U]_{jj}) * [U]_{j,k}` to the remaining columns.

use anyhow::{Context, Result};

use crate::coordinator::{BlockCtx, BlockPruner};
use crate::linalg::{cholesky_inverse_upper, Mat};
use crate::model::LAYER_NAMES;
use crate::prune::{BlockMasks, BlockReport};
use crate::tensor::Tensor;

pub struct SparseGptPruner {
    pub sparsity: f64,
    pub blocksize: usize,
    /// Hessian dampening as a fraction of the mean diagonal (paper: 0.01).
    pub percdamp: f64,
}

impl Default for SparseGptPruner {
    fn default() -> Self {
        SparseGptPruner { sparsity: 0.5, blocksize: 32, percdamp: 0.01 }
    }
}

/// Prune one weight matrix in place; returns the 0/1 mask.
pub fn sparsegpt_layer(
    w: &mut Tensor,
    hessian: &Mat,
    sparsity: f64,
    blocksize: usize,
    percdamp: f64,
) -> Result<Tensor> {
    let (rows, cols) = (w.shape[0], w.shape[1]);
    assert_eq!(hessian.rows, cols);

    // dead columns (never-activated inputs) are zeroed and skipped via damping
    let mut h = hessian.clone();
    let mean_diag = (0..cols).map(|i| h[(i, i)]).sum::<f64>() / cols as f64;
    h.add_diag(percdamp * mean_diag + 1e-10);
    for j in 0..cols {
        if hessian[(j, j)] == 0.0 {
            for i in 0..rows {
                w.f32s_mut()[i * cols + j] = 0.0;
            }
        }
    }

    let u = cholesky_inverse_upper(&h).context("cholesky of inverse hessian")?;

    let mut mask = vec![1.0f32; rows * cols];
    let wdata = w.f32s_mut();
    // error accumulator per row for cross-block compensation
    let mut err = vec![0.0f64; blocksize];

    let mut j0 = 0;
    while j0 < cols {
        let j1 = (j0 + blocksize).min(cols);
        let bs = j1 - j0;
        for r in 0..rows {
            // score entries of this block slice for this row
            let mut scored: Vec<(f64, usize)> = (j0..j1)
                .map(|j| {
                    let wv = wdata[r * cols + j] as f64;
                    let d = u[(j, j)];
                    ((wv * wv) / (d * d).max(1e-18), j)
                })
                .collect();
            scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let n_prune = ((bs as f64) * sparsity).round() as usize;
            let prune_set: Vec<usize> = scored[..n_prune].iter().map(|(_, j)| *j).collect();

            err[..bs].iter_mut().for_each(|e| *e = 0.0);
            for j in j0..j1 {
                let wv = wdata[r * cols + j] as f64;
                let d = u[(j, j)];
                let q = if prune_set.contains(&j) { 0.0 } else { wv };
                let e = (wv - q) / d;
                if q == 0.0 && prune_set.contains(&j) {
                    mask[r * cols + j] = 0.0;
                    wdata[r * cols + j] = 0.0;
                }
                if e != 0.0 {
                    // compensate remaining columns inside the block
                    for k in j + 1..j1 {
                        wdata[r * cols + k] -= (e * u[(j, k)]) as f32;
                    }
                    err[j - j0] = e;
                }
            }
            // propagate compensation to all later blocks
            for j in j0..j1 {
                let e = err[j - j0];
                if e == 0.0 {
                    continue;
                }
                for k in j1..cols {
                    wdata[r * cols + k] -= (e * u[(j, k)]) as f32;
                }
            }
        }
        j0 = j1;
    }

    // re-zero masked entries (compensation from later columns never touches
    // earlier ones because U is upper-triangular, but keep the invariant
    // explicit and cheap)
    for r in 0..rows {
        for j in 0..cols {
            if mask[r * cols + j] == 0.0 {
                wdata[r * cols + j] = 0.0;
            }
        }
    }
    Ok(Tensor::from_f32(&[rows, cols], mask))
}

impl BlockPruner for SparseGptPruner {
    fn name(&self) -> &str {
        "sparsegpt"
    }

    fn needs_hessian(&self) -> bool {
        true
    }

    fn prune_block(&mut self, ctx: &mut BlockCtx) -> Result<(BlockMasks, BlockReport)> {
        let mut masks = BlockMasks::new();
        let mut report = BlockReport::default();
        for w in LAYER_NAMES {
            let hess = ctx.hessian_for(w).clone();
            let weight = ctx.weights.get_mut(w).unwrap();
            let mask = sparsegpt_layer(weight, &hess, self.sparsity, self.blocksize, self.percdamp)?;
            report.layer_sparsity.insert(w.to_string(), mask.zero_fraction());
            masks.insert(w.to_string(), mask);
        }
        Ok((masks, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_problem(rows: usize, cols: usize, n: usize, seed: u64) -> (Tensor, Mat, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let w = Tensor::from_f32(
            &[rows, cols],
            (0..rows * cols).map(|_| rng.normal_f32()).collect(),
        );
        // correlated inputs (x = z A, low-rank-ish mixing): the regime where
        // OBS compensation matters — with isotropic x, H ~ nI and SparseGPT
        // degenerates to magnitude pruning
        let k = (cols / 2).max(1);
        let a: Vec<f32> = (0..k * cols).map(|_| rng.normal_f32()).collect();
        let mut x = vec![0.0f32; n * cols];
        for s in 0..n {
            let z: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
            for j in 0..cols {
                let mut v = 0.0;
                for t in 0..k {
                    v += z[t] * a[t * cols + j];
                }
                x[s * cols + j] = v / (k as f32).sqrt() + 0.1 * rng.normal_f32();
            }
        }
        let mut h = Mat::zeros(cols, cols);
        h.add_gram_f32(&x, n);
        (w, h, x)
    }

    fn recon_error(w0: &Tensor, w1: &Tensor, x: &[f32], n: usize) -> f64 {
        // || X w0^T - X w1^T ||^2
        let cols = w0.shape[1];
        let rows = w0.shape[0];
        let mut err = 0.0;
        for s in 0..n {
            let xi = &x[s * cols..(s + 1) * cols];
            for r in 0..rows {
                let mut y0 = 0.0f64;
                let mut y1 = 0.0f64;
                for j in 0..cols {
                    y0 += xi[j] as f64 * w0.f32s()[r * cols + j] as f64;
                    y1 += xi[j] as f64 * w1.f32s()[r * cols + j] as f64;
                }
                err += (y0 - y1) * (y0 - y1);
            }
        }
        err
    }

    #[test]
    fn achieves_target_sparsity() {
        let (mut w, h, _) = random_problem(16, 64, 256, 1);
        let mask = sparsegpt_layer(&mut w, &h, 0.5, 16, 0.01).unwrap();
        assert!((mask.zero_fraction() - 0.5).abs() < 0.02, "{}", mask.zero_fraction());
        assert!((w.zero_fraction() - 0.5).abs() < 0.02);
    }

    #[test]
    fn masked_entries_are_zero() {
        let (mut w, h, _) = random_problem(8, 32, 128, 2);
        let mask = sparsegpt_layer(&mut w, &h, 0.6, 8, 0.01).unwrap();
        for (wv, mv) in w.f32s().iter().zip(mask.f32s()) {
            if *mv == 0.0 {
                assert_eq!(*wv, 0.0);
            }
        }
    }

    /// The OBS weight update must beat pure magnitude pruning on the
    /// calibration reconstruction objective — the entire point of SparseGPT.
    #[test]
    fn beats_magnitude_on_reconstruction() {
        let (w0, h, x) = random_problem(24, 96, 384, 3);
        let n = 384;

        let mut w_sgpt = w0.clone();
        sparsegpt_layer(&mut w_sgpt, &h, 0.5, 24, 0.01).unwrap();
        let e_sgpt = recon_error(&w0, &w_sgpt, &x, n);

        let mag_mask = crate::prune::topk_row_mask(&crate::prune::importance::magnitude_scores(&w0), 0.5);
        let mut w_mag = w0.clone();
        for (v, m) in w_mag.f32s_mut().iter_mut().zip(mag_mask.f32s()) {
            *v *= m;
        }
        let e_mag = recon_error(&w0, &w_mag, &x, n);
        assert!(
            e_sgpt < e_mag * 0.9,
            "sparsegpt {e_sgpt:.3} should beat magnitude {e_mag:.3}"
        );
    }

    #[test]
    fn dead_columns_pruned() {
        let (mut w, mut h, _) = random_problem(4, 16, 64, 4);
        // kill column 3's activations
        for j in 0..16 {
            h[(3, j)] = 0.0;
            h[(j, 3)] = 0.0;
        }
        sparsegpt_layer(&mut w, &h, 0.25, 8, 0.01).unwrap();
        for r in 0..4 {
            assert_eq!(w.f32s()[r * 16 + 3], 0.0);
        }
    }
}
