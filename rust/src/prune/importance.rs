//! Weight-importance scoring (paper Eqn. 2) and rank computation.
//!
//! Column activation norms are accumulated streaming over calibration
//! minibatches from the `block_capture` artifact outputs; scores are
//! `|W| * colnorm` (Wanda), `|W|` (magnitude ablation) or the SparseGPT
//! metric `w^2 / diag(H^-1)` (importance-metric ablation, Table 5 right).
//! Ranks (ascending per-row importance positions) are computed **once per
//! block** — Algorithm 1 line 4 — and fed to the besa_step artifact.

use anyhow::Result;

use crate::model::config::ModelConfig;
use crate::tensor::Tensor;

/// Which importance metric sorts the weights (Table 5 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    WeightMagnitude,
    Wanda,
    SparseGpt,
}

impl Metric {
    pub fn from_name(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().as_str() {
            "weight" | "magnitude" => Some(Metric::WeightMagnitude),
            "wanda" => Some(Metric::Wanda),
            "sparsegpt" => Some(Metric::SparseGpt),
            _ => None,
        }
    }
}

/// Streaming accumulator for per-column squared activation norms of the
/// four capture points of a block (inputs of {q,k,v}, {o}, {gate,up}, {down}).
#[derive(Debug, Clone)]
pub struct ColNorms {
    /// sum of squares per column, one vec per capture point
    pub h1: Vec<f64>,
    pub att: Vec<f64>,
    pub h2: Vec<f64>,
    pub act: Vec<f64>,
    pub tokens: usize,
}

impl ColNorms {
    pub fn new(cfg: &ModelConfig) -> ColNorms {
        ColNorms {
            h1: vec![0.0; cfg.d_model],
            att: vec![0.0; cfg.d_model],
            h2: vec![0.0; cfg.d_model],
            act: vec![0.0; cfg.d_ffn],
            tokens: 0,
        }
    }

    /// Accumulate from one `block_capture` output set ([B,S,d] tensors).
    pub fn accumulate(&mut self, h1: &Tensor, att: &Tensor, h2: &Tensor, act: &Tensor) {
        accumulate_sq(&mut self.h1, h1);
        accumulate_sq(&mut self.att, att);
        accumulate_sq(&mut self.h2, h2);
        accumulate_sq(&mut self.act, act);
        self.tokens += h1.numel() / self.h1.len();
    }

    /// L2 norm vector for the input columns of a given layer.
    pub fn for_layer(&self, layer: &str) -> Vec<f32> {
        let sq = match layer {
            "wq" | "wk" | "wv" => &self.h1,
            "wo" => &self.att,
            "wg" | "wu" => &self.h2,
            "wd" => &self.act,
            other => panic!("unknown layer {other}"),
        };
        sq.iter().map(|v| (v.sqrt()) as f32).collect()
    }
}

fn accumulate_sq(acc: &mut [f64], x: &Tensor) {
    let c = acc.len();
    let data = x.f32s();
    debug_assert_eq!(data.len() % c, 0);
    for row in data.chunks_exact(c) {
        for (a, v) in acc.iter_mut().zip(row) {
            *a += (*v as f64) * (*v as f64);
        }
    }
}

/// Wanda scores: |W_ij| * ||X_:,j||_2.
pub fn wanda_scores(w: &Tensor, colnorm: &[f32]) -> Tensor {
    let (r, c) = (w.shape[0], w.shape[1]);
    assert_eq!(c, colnorm.len());
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        let wrow = &w.f32s()[i * c..(i + 1) * c];
        let orow = &mut out[i * c..(i + 1) * c];
        for j in 0..c {
            orow[j] = wrow[j].abs() * colnorm[j];
        }
    }
    Tensor::from_f32(&[r, c], out)
}

/// Magnitude scores: |W_ij|.
pub fn magnitude_scores(w: &Tensor) -> Tensor {
    Tensor::from_f32(&w.shape, w.f32s().iter().map(|v| v.abs()).collect())
}

/// SparseGPT metric scores: w_ij^2 / diag(H^-1)_j (importance ablation).
pub fn sparsegpt_scores(w: &Tensor, hinv_diag: &[f64]) -> Tensor {
    let (r, c) = (w.shape[0], w.shape[1]);
    assert_eq!(c, hinv_diag.len());
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        for j in 0..c {
            let wv = w.f32s()[i * c + j] as f64;
            out[i * c + j] = (wv * wv / hinv_diag[j].max(1e-12)) as f32;
        }
    }
    Tensor::from_f32(&[r, c], out)
}

/// Ascending per-row ranks: rank 0 = least important. Ties broken by
/// column index (stable), matching jnp.argsort(argsort(.)).
pub fn ranks(scores: &Tensor) -> Tensor {
    let (r, c) = (scores.shape[0], scores.shape[1]);
    let mut out = vec![0i32; r * c];
    let mut idx: Vec<usize> = Vec::with_capacity(c);
    for i in 0..r {
        let row = &scores.f32s()[i * c..(i + 1) * c];
        idx.clear();
        idx.extend(0..c);
        idx.sort_by(|a, b| {
            row[*a]
                .partial_cmp(&row[*b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(b))
        });
        for (pos, &j) in idx.iter().enumerate() {
            out[i * c + j] = pos as i32;
        }
    }
    Tensor::from_i32(&[r, c], out)
}

/// Decode BESA theta logits into a hard 0/1 mask — the rust-side mirror of
/// the `besa_mask` Pallas kernel (cross-checked against the `mask_decode`
/// artifact in integration tests).
///
/// theta: [R or 1, D-1] logits; ranks: [R, C]. Returns (mask, per-row alpha).
pub fn decode_mask(theta: &Tensor, ranks: &Tensor, n_rates: usize) -> (Tensor, Vec<f64>) {
    let (r, c) = (ranks.shape[0], ranks.shape[1]);
    let trows = theta.shape[0];
    assert!(trows == r || trows == 1, "theta rows {trows} vs ranks rows {r}");
    let dm1 = theta.shape[1];
    assert_eq!(dm1 + 1, n_rates);
    let mut mask = vec![1.0f32; r * c];
    let mut alphas = vec![0.0f64; r];
    let mut beta = vec![0.0f64; n_rates];
    let mut cum = vec![0.0f64; n_rates];
    for i in 0..r {
        let trow = if trows == 1 { 0 } else { i };
        let logits = &theta.f32s()[trow * dm1..(trow + 1) * dm1];
        // softmax over D-1 logits; beta_D = 0
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let mut z = 0.0f64;
        for (d, l) in logits.iter().enumerate() {
            beta[d] = ((*l as f64) - mx).exp();
            z += beta[d];
        }
        for b in beta[..dm1].iter_mut() {
            *b /= z;
        }
        beta[n_rates - 1] = 0.0;
        // alpha = sum beta_d * p_d, p_d = (d+1)/D for array index d
        let mut alpha = 0.0f64;
        for (d, b) in beta.iter().enumerate() {
            alpha += b * (d + 1) as f64 / n_rates as f64;
        }
        alphas[i] = alpha;
        // exclusive cumsum: keep-prob of bucket k is cum[k] = sum_{d<k} beta_d
        cum[0] = 0.0;
        for d in 1..n_rates {
            cum[d] = cum[d - 1] + beta[d - 1];
        }
        for j in 0..c {
            let rank = ranks.i32s()[i * c + j] as usize;
            let k = ((rank * n_rates) / c).min(n_rates - 1);
            let prune_prob = 1.0 - cum[k];
            if prune_prob >= alpha {
                mask[i * c + j] = 0.0;
            }
        }
    }
    (Tensor::from_f32(&[r, c], mask), alphas)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn colnorm_accumulation() {
        let cfg = crate::model::config::tests::test_config();
        let mut cn = ColNorms::new(&cfg);
        let ones = Tensor::ones(&[2, 4, cfg.d_model]);
        let act = Tensor::ones(&[2, 4, cfg.d_ffn]);
        cn.accumulate(&ones, &ones, &ones, &act);
        cn.accumulate(&ones, &ones, &ones, &act);
        let n = cn.for_layer("wq");
        // 16 tokens of 1.0 -> sqrt(16) = 4
        assert!((n[0] - 4.0).abs() < 1e-6);
        assert_eq!(cn.tokens, 16);
        assert_eq!(cn.for_layer("wd").len(), cfg.d_ffn);
    }

    #[test]
    fn wanda_vs_magnitude() {
        let w = Tensor::from_f32(&[1, 3], vec![-2.0, 1.0, 0.5]);
        let ws = wanda_scores(&w, &[1.0, 4.0, 2.0]);
        assert_eq!(ws.f32s(), &[2.0, 4.0, 1.0]);
        let ms = magnitude_scores(&w);
        assert_eq!(ms.f32s(), &[2.0, 1.0, 0.5]);
    }

    #[test]
    fn ranks_are_ascending_positions() {
        let s = Tensor::from_f32(&[2, 4], vec![0.3, 0.1, 0.4, 0.2, 5., 5., 1., 9.]);
        let r = ranks(&s);
        assert_eq!(&r.i32s()[..4], &[2, 0, 3, 1]);
        // ties broken by column: cols 0,1 scored 5,5 -> ranks 1,2
        assert_eq!(&r.i32s()[4..], &[1, 2, 0, 3]);
    }

    #[test]
    fn decode_mask_point_mass() {
        // theta point mass at rate index k -> sparsity (k+1)/D over each row
        let n_rates = 8;
        let c = 32;
        let mut logits = vec![-30.0f32; n_rates - 1];
        logits[3] = 30.0; // p = 4/8 = 0.5
        let theta = Tensor::from_f32(&[1, n_rates - 1], logits);
        let mut rng = crate::util::rng::Rng::seed(0);
        let perm: Vec<i32> = rng.permutation(c).iter().map(|v| *v as i32).collect();
        let ranks_t = Tensor::from_i32(&[1, c], perm);
        let (mask, alphas) = decode_mask(&theta, &ranks_t, n_rates);
        assert!((alphas[0] - 0.5).abs() < 1e-9);
        assert!((mask.zero_fraction() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn decode_mask_prunes_least_important() {
        let n_rates = 4;
        let c = 8;
        let mut logits = vec![-30.0f32; 3];
        logits[1] = 30.0; // alpha = 2/4 = 0.5
        let theta = Tensor::from_f32(&[1, 3], logits);
        let ranks_t = Tensor::from_i32(&[1, c], (0..c as i32).collect());
        let (mask, _) = decode_mask(&theta, &ranks_t, n_rates);
        // ranks 0..3 pruned, 4..7 kept
        assert_eq!(mask.f32s(), &[0., 0., 0., 0., 1., 1., 1., 1.]);
    }

    #[test]
    fn sparsegpt_metric_shape() {
        let w = Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]);
        let s = sparsegpt_scores(&w, &[1.0, 0.25]);
        assert_eq!(s.f32s(), &[1., 16., 9., 64.]);
    }
}
