//! Adam optimizer over named tensors. The AOT step artifacts return
//! gradients; parameter state and the update rule live here in rust so the
//! request path stays python-free.

use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

#[derive(Debug)]
pub struct Adam {
    pub cfg: AdamConfig,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
}

impl Adam {
    /// One slot per parameter tensor (sized lazily on first step).
    pub fn new(cfg: AdamConfig, n_params: usize) -> Adam {
        Adam { cfg, m: vec![Vec::new(); n_params], v: vec![Vec::new(); n_params], t: 0 }
    }

    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// In-place update: params[i] -= lr * mhat / (sqrt(vhat) + eps).
    pub fn step(&mut self, params: &mut [&mut Tensor], grads: &[&Tensor]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let t = self.t as f32;
        let bc1 = 1.0 - self.cfg.beta1.powf(t);
        let bc2 = 1.0 - self.cfg.beta2.powf(t);
        for i in 0..params.len() {
            let p = params[i].f32s_mut();
            let g = grads[i].f32s();
            assert_eq!(p.len(), g.len(), "param/grad length mismatch at {i}");
            if self.m[i].is_empty() {
                self.m[i] = vec![0.0; p.len()];
                self.v[i] = vec![0.0; p.len()];
            }
            let m = &mut self.m[i];
            let v = &mut self.v[i];
            for j in 0..p.len() {
                let mut gj = g[j];
                if self.cfg.weight_decay > 0.0 {
                    gj += self.cfg.weight_decay * p[j];
                }
                m[j] = self.cfg.beta1 * m[j] + (1.0 - self.cfg.beta1) * gj;
                v[j] = self.cfg.beta2 * v[j] + (1.0 - self.cfg.beta2) * gj * gj;
                let mhat = m[j] / bc1;
                let vhat = v[j] / bc2;
                p[j] -= self.cfg.lr * mhat / (vhat.sqrt() + self.cfg.eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam on f(x) = (x - 3)^2 converges to 3.
    #[test]
    fn converges_on_quadratic() {
        let mut x = Tensor::from_f32(&[1], vec![0.0]);
        let mut opt = Adam::new(AdamConfig { lr: 0.1, ..Default::default() }, 1);
        for _ in 0..300 {
            let g = Tensor::from_f32(&[1], vec![2.0 * (x.f32s()[0] - 3.0)]);
            opt.step(&mut [&mut x], &[&g]);
        }
        assert!((x.f32s()[0] - 3.0).abs() < 1e-2, "{}", x.f32s()[0]);
    }

    /// Adam is approximately scale-invariant in the gradient magnitude —
    /// the property that makes it the right optimizer for the tiny STE
    /// gradients flowing out of the mask kernel.
    #[test]
    fn scale_invariance() {
        let run = |scale: f32| {
            let mut x = Tensor::from_f32(&[1], vec![0.0]);
            let mut opt = Adam::new(AdamConfig { lr: 0.05, ..Default::default() }, 1);
            for _ in 0..100 {
                let g = Tensor::from_f32(&[1], vec![scale * (x.f32s()[0] - 1.0)]);
                opt.step(&mut [&mut x], &[&g]);
            }
            x.f32s()[0]
        };
        let a = run(1.0);
        let b = run(1e-6);
        assert!((a - b).abs() < 0.05, "{a} vs {b}");
    }

    #[test]
    fn multi_param_independent() {
        let mut x = Tensor::from_f32(&[2], vec![0.0, 0.0]);
        let mut y = Tensor::from_f32(&[1], vec![5.0]);
        let mut opt = Adam::new(AdamConfig { lr: 0.2, ..Default::default() }, 2);
        for _ in 0..200 {
            let gx = Tensor::from_f32(&[2], vec![x.f32s()[0] + 1.0, x.f32s()[1] - 2.0]);
            let gy = Tensor::from_f32(&[1], vec![y.f32s()[0]]);
            opt.step(&mut [&mut x, &mut y], &[&gx, &gy]);
        }
        assert!((x.f32s()[0] + 1.0).abs() < 0.05);
        assert!((x.f32s()[1] - 2.0).abs() < 0.05);
        assert!(y.f32s()[0].abs() < 0.05);
    }
}
