//! BESA (the paper's contribution): differentiable sparsity allocation via
//! learnable per-rate probabilities, optimized per block against the
//! blockwise reconstruction loss (Eqn. 1) with Adam — the rust half of
//! Algorithm 1. The heavy math (STE masks, masked block forward, gradients)
//! runs inside the `besa_step_*` artifact op (native interpreter or PJRT,
//! behind the [`crate::runtime::Engine`] facade); this module owns theta
//! state, the optimizer loop, convergence control and final mask decode.
//!
//! Invariants pinned by `tests/native_parity.rs`: the rust-side
//! [`crate::prune::importance::decode_mask`] reproduces the op-side
//! `mask_decode` bit for bit (same rate grid, same tie-break), the
//! `besa_step*` losses/gradients match the cross-language golden vectors
//! (with FD-validated backwards), and layer-wise theta gradients are the
//! row-wise sums. Downstream, a pruned checkpoint's exact zeros are what
//! the serving engine's CSR packing relies on ([`crate::sparse`]:
//! skipping them reproduces the dense result bitwise).

use anyhow::{bail, Result};

use crate::coordinator::{BlockCtx, BlockPruner};
use crate::model::LAYER_NAMES;
use crate::prune::adam::{Adam, AdamConfig};
use crate::prune::importance::{decode_mask, Metric};
use crate::prune::{BlockMasks, BlockReport};
use crate::runtime::{Arg, Prepared};
use crate::tensor::Tensor;
use crate::util::par::par_map;

/// Sparsity-allocation granularity (paper Table 6). `Layer` is Wanda and
/// lives in [`crate::prune::wanda`]; `TwoBlocks` is driven by
/// [`two_block_prune`] from the experiment harness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    AttnMlp,
    Block,
}

#[derive(Debug, Clone)]
pub struct BesaConfig {
    pub sparsity: f64,
    /// epochs over the calibration minibatches (paper default: 1 on
    /// 128x2048 tokens; our minibatches are smaller so default higher)
    pub epochs: usize,
    pub lr: f32,
    /// sparsity-penalty weight λ (Eqn. 1)
    pub lambda: f32,
    /// row-wise (paper default, D*C_out params/layer) or layer-wise (D)
    pub row_wise: bool,
    pub granularity: Granularity,
    pub metric: Metric,
    /// joint weight-quantization (paper §3.3): learn clipping strengths too
    pub quant: bool,
    /// microbatches per optimizer step. `1` (default) is the classic
    /// sequential loop. `> 1` evaluates each group of microbatches
    /// thread-parallel against the *same* frozen thetas
    /// ([`crate::util::par::par_map`]; `Engine` is `Sync`), averages the
    /// gradients in fixed microbatch-index order outside the parallel
    /// region, and takes one Adam step per group — deterministic for any
    /// worker count, a different (averaged-step) trajectory than `1`.
    pub grad_accum: usize,
}

impl Default for BesaConfig {
    fn default() -> Self {
        BesaConfig {
            sparsity: 0.5,
            epochs: 24,
            lr: 5e-2,
            lambda: 8.0,
            row_wise: true,
            granularity: Granularity::Block,
            metric: Metric::Wanda,
            quant: false,
            grad_accum: 1,
        }
    }
}

pub struct BesaPruner {
    pub cfg: BesaConfig,
    /// use the `besa_step_row_d<N>` artifact with N candidate rates instead
    /// of the config default (Table 5 sparsity-step ablation)
    pub rate_override: Option<usize>,
    /// per-block training curves: (loss, recon, mean_alpha) per step
    pub curves: Vec<Vec<(f64, f64, f64)>>,
}

impl BesaPruner {
    pub fn new(cfg: BesaConfig) -> BesaPruner {
        BesaPruner { cfg, rate_override: None, curves: Vec::new() }
    }

    fn n_rates(&self, ctx: &BlockCtx) -> usize {
        self.rate_override.unwrap_or(ctx.cfg.n_rates)
    }

    fn artifact_name(&self) -> String {
        if let Some(d) = self.rate_override {
            return format!("besa_step_row_d{d}");
        }
        if self.cfg.quant {
            "besa_quant_step_row".to_string()
        } else {
            match (self.cfg.row_wise, self.cfg.granularity) {
                (true, Granularity::Block) => "besa_step_row",
                (true, Granularity::AttnMlp) => "besa_step_attnmlp",
                (false, Granularity::Block) => "besa_step_layer",
                (false, Granularity::AttnMlp) => "besa_step_attnmlp",
            }
            .to_string()
        }
    }

    fn init_thetas(&self, ctx: &BlockCtx) -> Vec<Tensor> {
        let n_rates = self.n_rates(ctx);
        LAYER_NAMES
            .iter()
            .map(|w| {
                let shape = ctx.cfg.layer_shape(w);
                let rows = if self.cfg.row_wise { shape[0] } else { 1 };
                Tensor::zeros(&[rows, n_rates - 1])
            })
            .collect()
    }
}

impl BlockPruner for BesaPruner {
    fn name(&self) -> &str {
        if self.cfg.quant {
            "besa+quant"
        } else {
            "besa"
        }
    }

    fn needs_hessian(&self) -> bool {
        self.cfg.metric == Metric::SparseGpt
    }

    fn prune_block(&mut self, ctx: &mut BlockCtx) -> Result<(BlockMasks, BlockReport)> {
        if !self.cfg.row_wise && self.cfg.quant {
            bail!("joint quantization is only lowered row-wise (besa_quant_step_row)");
        }
        let n_rates = self.n_rates(ctx);
        let ranks = crate::prune::wanda::block_ranks(ctx, self.cfg.metric);
        let mut thetas = self.init_thetas(ctx);
        let mut gammas: Vec<Tensor> =
            LAYER_NAMES.iter().map(|_| Tensor::from_f32(&[2], vec![1.0, 1.0])).collect();

        let n_opt = if self.cfg.quant { 14 } else { 7 };
        let mut adam = Adam::new(AdamConfig { lr: self.cfg.lr, ..Default::default() }, n_opt);
        let lam = Tensor::scalar(self.cfg.lambda);
        let alpha_hat = Tensor::scalar(self.cfg.sparsity as f32);
        let artifact = self.artifact_name();
        let weights: Vec<&Tensor> = LAYER_NAMES.iter().map(|w| &ctx.weights[*w]).collect();

        // Everything except the theta/gamma optimizer state is invariant
        // across the whole epoch loop. On backends with a host/device
        // boundary (PJRT), prepare those inputs once per block so every
        // besa_step reuses the cached device literal — restoring the
        // once-per-block conversion the trait refactor had regressed
        // (ROADMAP "Open items"). On the native interpreter, preparation
        // would only deep-copy host tensors, so the loop borrows instead.
        struct PreparedInvariants {
            x: Vec<Prepared>,
            y: Vec<Prepared>,
            w: Vec<Prepared>,
            norms: [Prepared; 2],
            ranks: Vec<Prepared>,
            lam: Prepared,
            alpha_hat: Prepared,
        }
        let prepared: Option<PreparedInvariants> = if ctx.engine.caches_prepared() {
            Some(PreparedInvariants {
                x: ctx.x_pruned.iter().map(|t| ctx.engine.prepare(t)).collect::<Result<_>>()?,
                y: ctx.y_dense.iter().map(|t| ctx.engine.prepare(t)).collect::<Result<_>>()?,
                w: weights.iter().map(|t| ctx.engine.prepare(t)).collect::<Result<_>>()?,
                norms: [ctx.engine.prepare(&ctx.norms[0])?, ctx.engine.prepare(&ctx.norms[1])?],
                ranks: ranks.iter().map(|t| ctx.engine.prepare(t)).collect::<Result<_>>()?,
                lam: ctx.engine.prepare(&lam)?,
                alpha_hat: ctx.engine.prepare(&alpha_hat)?,
            })
        } else {
            None
        };

        let n_batches = ctx.x_pruned.len();
        let group_len = self.cfg.grad_accum.max(1);
        let quant = self.cfg.quant;
        let mut curve = Vec::new();
        let mut last = (0.0, 0.0, 0.0);
        let engine = &ctx.engine;
        let x_pruned = &ctx.x_pruned;
        let y_dense = &ctx.y_dense;
        let ctx_norms = &ctx.norms;
        for _epoch in 0..self.cfg.epochs {
            let mut b0 = 0;
            while b0 < n_batches {
                let group: Vec<usize> = (b0..(b0 + group_len).min(n_batches)).collect();
                b0 += group.len();
                // Every microbatch of the group is evaluated against the
                // same frozen thetas/gammas; `Engine` is `Sync`, so groups
                // fan out over scoped threads (one besa_step per worker).
                let outs = par_map(&group, |&bi| {
                    let mut ins: Vec<Arg> = thetas.iter().map(Arg::Host).collect();
                    match &prepared {
                        Some(p) => {
                            ins.push(Arg::Prep(&p.x[bi]));
                            ins.push(Arg::Prep(&p.y[bi]));
                            ins.extend(p.w.iter().map(Arg::Prep));
                            ins.push(Arg::Prep(&p.norms[0]));
                            ins.push(Arg::Prep(&p.norms[1]));
                            ins.extend(p.ranks.iter().map(Arg::Prep));
                            ins.push(Arg::Prep(&p.lam));
                            ins.push(Arg::Prep(&p.alpha_hat));
                        }
                        None => {
                            ins.push(Arg::Host(&x_pruned[bi]));
                            ins.push(Arg::Host(&y_dense[bi]));
                            ins.extend(weights.iter().copied().map(Arg::Host));
                            ins.push(Arg::Host(&ctx_norms[0]));
                            ins.push(Arg::Host(&ctx_norms[1]));
                            ins.extend(ranks.iter().map(Arg::Host));
                            ins.push(Arg::Host(&lam));
                            ins.push(Arg::Host(&alpha_hat));
                        }
                    }
                    if quant {
                        ins.extend(gammas.iter().map(Arg::Host));
                    }
                    engine.run_args(&artifact, &ins)
                })?;
                for out in &outs {
                    last = (
                        out[0].scalar_value() as f64,
                        out[1].scalar_value() as f64,
                        out[2].scalar_value() as f64,
                    );
                    curve.push(last);
                }
                // Average the group's gradients in fixed microbatch-index
                // order, *outside* the parallel region — bit-identical for
                // any worker count, and exactly the per-batch gradient
                // (no averaging at all) when the group has one member.
                let mut avg: Vec<Tensor> = outs[0][3..3 + n_opt].to_vec();
                for out in outs.iter().skip(1) {
                    for (a, g) in avg.iter_mut().zip(&out[3..3 + n_opt]) {
                        for (av, gv) in a.f32s_mut().iter_mut().zip(g.f32s()) {
                            *av += *gv;
                        }
                    }
                }
                if outs.len() > 1 {
                    let inv = 1.0 / outs.len() as f32;
                    for a in avg.iter_mut() {
                        for v in a.f32s_mut() {
                            *v *= inv;
                        }
                    }
                }
                let grads: Vec<&Tensor> = avg.iter().collect();
                if quant {
                    let mut params: Vec<&mut Tensor> = thetas.iter_mut().collect();
                    params.extend(gammas.iter_mut());
                    adam.step(&mut params, &grads);
                } else {
                    let mut params: Vec<&mut Tensor> = thetas.iter_mut().collect();
                    adam.step(&mut params, &grads);
                }
            }
        }

        // quantize weights with the learned clipping before masking
        if self.cfg.quant {
            for (i, w) in LAYER_NAMES.iter().enumerate() {
                let shape = ctx.cfg.layer_shape(w);
                let tag = format!("quant_apply_{}x{}", shape[0], shape[1]);
                let wt = ctx.weights[*w].clone();
                let out = ctx.engine.run(&tag, &[&wt, &gammas[i]])?;
                *ctx.weights.get_mut(*w).unwrap() = out.into_iter().next().unwrap();
            }
        }

        let mut masks = BlockMasks::new();
        let mut report = BlockReport::default();
        for (i, w) in LAYER_NAMES.iter().enumerate() {
            let (mask, _alphas) = decode_mask(&thetas[i], &ranks[i], n_rates);
            report.layer_sparsity.insert((*w).to_string(), mask.zero_fraction());
            masks.insert((*w).to_string(), mask);
        }
        report.recon_error = last.1;
        report.steps = curve.len();
        self.curves.push(curve);
        Ok((masks, report))
    }
}

/// Two-block granularity (paper Table 6 "Two Blocks"): prunes blocks
/// `2i, 2i+1` jointly against the dense output after both. Standalone
/// driver because the pipeline advances one block at a time.
pub fn two_block_prune(
    engine: &crate::runtime::Engine,
    params: &mut crate::model::ParamStore,
    calib: &[Tensor],
    cfg: &BesaConfig,
) -> Result<(Vec<BlockReport>, Vec<f64>)> {
    let mcfg = engine.config().clone();
    if mcfg.n_blocks % 2 != 0 {
        bail!("two-block granularity needs an even block count");
    }
    let emb = params.get("embed")?.clone();
    let mut x_fp: Vec<Tensor> = calib
        .iter()
        .map(|t| Ok(engine.run("embed", &[t, &emb])?.into_iter().next().unwrap()))
        .collect::<Result<_>>()?;
    let mut x_p = x_fp.clone();
    let mut reports = Vec::new();
    let mut block_errors = Vec::new();

    for pair in 0..mcfg.n_blocks / 2 {
        let (l0, l1) = (2 * pair, 2 * pair + 1);
        let weights: Vec<Vec<Tensor>> = [l0, l1]
            .iter()
            .map(|l| {
                LAYER_NAMES
                    .iter()
                    .map(|w| {
                        params.get(&crate::model::ParamStore::layer_name(*l, w)).unwrap().clone()
                    })
                    .collect()
            })
            .collect();
        let norms: Vec<[Tensor; 2]> = [l0, l1]
            .iter()
            .map(|l| {
                [
                    params.get(&format!("blocks.{l}.norm1")).unwrap().clone(),
                    params.get(&format!("blocks.{l}.norm2")).unwrap().clone(),
                ]
            })
            .collect();

        // dense target after two blocks + per-pair colnorms on pruned path
        let mut y_dense = Vec::new();
        for x in &x_fp {
            let mut cur = x.clone();
            for b in 0..2 {
                let mut ins: Vec<&Tensor> = vec![&cur];
                ins.extend(weights[b].iter());
                ins.push(&norms[b][0]);
                ins.push(&norms[b][1]);
                cur = engine.run("block_fwd", &ins)?.into_iter().next().unwrap();
            }
            y_dense.push(cur);
        }
        let mut colnorms = [
            crate::prune::importance::ColNorms::new(&mcfg),
            crate::prune::importance::ColNorms::new(&mcfg),
        ];
        for x in &x_p {
            let mut cur = x.clone();
            for b in 0..2 {
                let mut ins: Vec<&Tensor> = vec![&cur];
                ins.extend(weights[b].iter());
                ins.push(&norms[b][0]);
                ins.push(&norms[b][1]);
                let out = engine.run("block_capture", &ins)?;
                colnorms[b].accumulate(&out[1], &out[2], &out[3], &out[4]);
                cur = out.into_iter().next().unwrap();
            }
        }

        // ranks per block
        let ranks: Vec<Vec<Tensor>> = (0..2)
            .map(|b| {
                LAYER_NAMES
                    .iter()
                    .enumerate()
                    .map(|(i, w)| {
                        let scores = crate::prune::importance::wanda_scores(
                            &weights[b][i],
                            &colnorms[b].for_layer(w),
                        );
                        crate::prune::importance::ranks(&scores)
                    })
                    .collect()
            })
            .collect();

        // theta optimization over 14 logits tensors
        let mut thetas: Vec<Tensor> = (0..2)
            .flat_map(|_| {
                LAYER_NAMES.iter().map(|w| {
                    let shape = mcfg.layer_shape(w);
                    Tensor::zeros(&[shape[0], mcfg.n_rates - 1])
                })
            })
            .collect();
        let mut adam = Adam::new(AdamConfig { lr: cfg.lr, ..Default::default() }, 14);
        let lam = Tensor::scalar(cfg.lambda);
        let alpha_hat = Tensor::scalar(cfg.sparsity as f32);
        let mut last_recon = 0.0;
        for _ in 0..cfg.epochs {
            for (x, y) in x_p.iter().zip(&y_dense) {
                let out = {
                    let mut ins: Vec<&Tensor> = thetas.iter().collect();
                    ins.push(x);
                    ins.push(y);
                    for b in 0..2 {
                        ins.extend(weights[b].iter());
                    }
                    for n in &norms {
                        ins.push(&n[0]);
                        ins.push(&n[1]);
                    }
                    for b in 0..2 {
                        ins.extend(ranks[b].iter());
                    }
                    ins.push(&lam);
                    ins.push(&alpha_hat);
                    engine.run("two_block_step", &ins)?
                };
                last_recon = out[1].scalar_value() as f64;
                let grads: Vec<&Tensor> = out[3..17].iter().collect();
                let mut ps: Vec<&mut Tensor> = thetas.iter_mut().collect();
                adam.step(&mut ps, &grads);
            }
        }

        // decode + apply masks, advance streams
        for b in 0..2 {
            let l = 2 * pair + b;
            let mut report = BlockReport { block: l, ..Default::default() };
            for (i, w) in LAYER_NAMES.iter().enumerate() {
                let (mask, _) = decode_mask(&thetas[b * 7 + i], &ranks[b][i], mcfg.n_rates);
                report.layer_sparsity.insert((*w).to_string(), mask.zero_fraction());
                let name = crate::model::ParamStore::layer_name(l, w);
                let mut t = params.get(&name)?.clone();
                for (v, m) in t.f32s_mut().iter_mut().zip(mask.f32s()) {
                    *v *= m;
                }
                params.set(&name, t)?;
            }
            report.recon_error = last_recon;
            reports.push(report);
        }
        // advance pruned + dense paths through the (now masked) pair
        let mut err_num = 0.0;
        let mut err_den = 0.0;
        for (i, x) in x_p.iter_mut().enumerate() {
            let mut cur = x.clone();
            for l in [l0, l1] {
                let w_now: Vec<&Tensor> = LAYER_NAMES
                    .iter()
                    .map(|w| params.get(&crate::model::ParamStore::layer_name(l, w)).unwrap())
                    .collect();
                let n1 = params.get(&format!("blocks.{l}.norm1"))?;
                let n2 = params.get(&format!("blocks.{l}.norm2"))?;
                let mut ins: Vec<&Tensor> = vec![&cur];
                ins.extend(w_now);
                ins.push(n1);
                ins.push(n2);
                cur = engine.run("block_fwd", &ins)?.into_iter().next().unwrap();
            }
            for (a, b) in cur.f32s().iter().zip(y_dense[i].f32s()) {
                let d = (*a - *b) as f64;
                err_num += d * d;
                err_den += (*b as f64) * (*b as f64);
            }
            *x = cur;
        }
        block_errors.push(err_num / err_den.max(1e-12));
        x_fp = y_dense;
    }
    Ok((reports, block_errors))
}
