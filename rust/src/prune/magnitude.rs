//! Magnitude-pruning baseline: lowest-|W| fraction of each row (the
//! "Weight" column of the paper's importance-metric ablation, Table 5).

use anyhow::Result;

use crate::coordinator::{BlockCtx, BlockPruner};
use crate::model::LAYER_NAMES;
use crate::prune::importance::magnitude_scores;
use crate::prune::{topk_row_mask, BlockMasks, BlockReport};

pub struct MagnitudePruner {
    pub sparsity: f64,
}

impl BlockPruner for MagnitudePruner {
    fn name(&self) -> &str {
        "magnitude"
    }

    fn prune_block(&mut self, ctx: &mut BlockCtx) -> Result<(BlockMasks, BlockReport)> {
        let mut masks = BlockMasks::new();
        let mut report = BlockReport::default();
        for w in LAYER_NAMES {
            let mask = topk_row_mask(&magnitude_scores(ctx.weight(w)), self.sparsity);
            report.layer_sparsity.insert(w.to_string(), mask.zero_fraction());
            masks.insert(w.to_string(), mask);
        }
        Ok((masks, report))
    }
}
