//! Zero-shot probe tasks (Table 2 substitute, DESIGN.md §Substitutions).
//!
//! Protocol mirrors LM-Eval's ranked-choice scoring: each item is a prompt
//! plus two candidate continuations (correct / corrupted); the model scores
//! both by continuation NLL and accuracy is the fraction where the correct
//! one wins. Six tasks over the synthetic domains measure capability
//! retention after pruning:
//!
//! * `wiki-cloze`, `c4-cloze`, `ptb-cloze` — grammatical continuation vs.
//!   a word-swapped corruption, one per domain (PIQA/BoolQ role).
//! * `copy`     — verbatim repetition of an earlier fragment vs. novel text
//!   (HellaSwag-like surface coherence).
//! * `retrieval`— an entity mentioned in the prompt vs. an unseen one
//!   (WinoGrande-like binding).
//! * `numeric`  — well-formed amount-unit pattern vs. malformed (ARC-like).

use anyhow::Result;

use crate::data::corpus::{Corpus, Domain};
use crate::data::tokenize;
use crate::model::{ModelConfig, ParamStore};
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct ProbeItem {
    pub prompt: String,
    pub correct: String,
    pub wrong: String,
}

#[derive(Debug, Clone)]
pub struct ProbeResult {
    pub task: String,
    pub accuracy: f64,
    pub items: usize,
}

fn swap_words(s: &str, rng: &mut Rng) -> String {
    let mut words: Vec<&str> = s.split(' ').collect();
    if words.len() >= 2 {
        let i = rng.below(words.len());
        let mut j = rng.below(words.len());
        let mut guard = 0;
        while (words[j] == words[i]) && guard < 8 {
            j = rng.below(words.len());
            guard += 1;
        }
        words.swap(i, j);
    }
    words.join(" ")
}

fn gen_cloze(domain: Domain, n: usize, seed: u64) -> Vec<ProbeItem> {
    let mut c = Corpus::new(domain, seed);
    let mut rng = Rng::seed(seed ^ 0xC102E);
    let mut out = Vec::new();
    while out.len() < n {
        let text = crate::data::detokenize(&c.take(160));
        // split at a sentence boundary: prompt = first part, continuation = rest
        if let Some(dot) = text[..100.min(text.len())].rfind(". ") {
            let prompt = text[..dot + 2].to_string();
            let cont: String = text[dot + 2..].chars().take(40).collect();
            if cont.len() < 12 {
                continue;
            }
            let wrong = swap_words(&cont, &mut rng);
            if wrong == cont {
                continue;
            }
            out.push(ProbeItem { prompt, correct: cont, wrong });
        }
    }
    out
}

fn gen_copy(n: usize, seed: u64) -> Vec<ProbeItem> {
    let mut c = Corpus::new(Domain::C4Syn, seed ^ 7);
    let mut d = Corpus::new(Domain::WikiSyn, seed ^ 9);
    (0..n)
        .map(|_| {
            let frag = crate::data::detokenize(&c.take(28));
            let other = crate::data::detokenize(&d.take(28));
            ProbeItem {
                prompt: format!("{frag} {frag} {frag} "),
                correct: frag,
                wrong: other,
            }
        })
        .collect()
}

fn gen_retrieval(n: usize, seed: u64) -> Vec<ProbeItem> {
    let mut rng = Rng::seed(seed ^ 0xE7);
    let entities = [
        "aldoria", "brevik", "castellan", "dormund", "elvaria", "fenwick", "galdor", "hestia",
    ];
    (0..n)
        .map(|_| {
            let a = *rng.choice(&entities);
            let mut b = *rng.choice(&entities);
            while b == a {
                b = *rng.choice(&entities);
            }
            ProbeItem {
                prompt: format!(
                    "the province of {a} was established in 1200. the province of "
                ),
                correct: a.to_string(),
                wrong: b.to_string(),
            }
        })
        .collect()
}

fn gen_numeric(n: usize, seed: u64) -> Vec<ProbeItem> {
    let mut rng = Rng::seed(seed ^ 0x4242);
    (0..n)
        .map(|_| {
            let amt = rng.below(90) + 1;
            ProbeItem {
                prompt: format!("acme corp shares rose {amt} "),
                correct: "points. ".to_string(),
                wrong: "pzq#!x. ".to_string(),
            }
        })
        .collect()
}

pub fn all_tasks(n_items: usize, seed: u64) -> Vec<(String, Vec<ProbeItem>)> {
    vec![
        ("wiki-cloze".into(), gen_cloze(Domain::WikiSyn, n_items, seed)),
        ("c4-cloze".into(), gen_cloze(Domain::C4Syn, n_items, seed + 1)),
        ("ptb-cloze".into(), gen_cloze(Domain::PtbSyn, n_items, seed + 2)),
        ("copy".into(), gen_copy(n_items, seed + 3)),
        ("retrieval".into(), gen_retrieval(n_items, seed + 4)),
        ("numeric".into(), gen_numeric(n_items, seed + 5)),
    ]
}

/// Pack `prompt + continuation` into one fixed-shape row; returns the
/// token row and the continuation span `[lo, hi)`.
fn pack(cfg: &ModelConfig, prompt: &str, cont: &str) -> (Vec<i32>, usize, usize) {
    let s = cfg.seq_len;
    let mut toks = tokenize(prompt);
    let mut cont_toks = tokenize(cont);
    // left-truncate prompt if needed, keep the continuation whole
    if toks.len() + cont_toks.len() > s {
        let keep = s.saturating_sub(cont_toks.len());
        toks = toks[toks.len() - keep..].to_vec();
    }
    let lo = toks.len();
    toks.append(&mut cont_toks);
    let hi = toks.len().min(s);
    toks.truncate(s);
    // pad with spaces (in-vocab, low-information)
    while toks.len() < s {
        toks.push(b' ' as i32);
    }
    (toks, lo, hi)
}

/// Score one task: batched ranked-choice accuracy.
pub fn run_task(
    engine: &Engine,
    params: &ParamStore,
    items: &[ProbeItem],
) -> Result<f64> {
    let cfg = engine.config().clone();
    let b = cfg.batch;
    let mut correct = 0usize;
    let mut total = 0usize;
    // two rows per item (correct / wrong); process b/2 items per batch
    let per_batch = (b / 2).max(1);
    for chunk in items.chunks(per_batch) {
        let mut rows = Vec::with_capacity(b * cfg.seq_len);
        let mut spans = Vec::new();
        for item in chunk {
            for cand in [&item.correct, &item.wrong] {
                let (toks, lo, hi) = pack(&cfg, &item.prompt, cand);
                rows.extend(toks);
                spans.push((lo, hi));
            }
        }
        // pad the batch dimension
        while rows.len() < b * cfg.seq_len {
            rows.extend(vec![b' ' as i32; cfg.seq_len]);
            spans.push((0, 0));
        }
        let tokens = Tensor::from_i32(&[b, cfg.seq_len], rows);
        let nll = crate::eval::forward_nll(engine, params, &tokens)?;
        for (i, _item) in chunk.iter().enumerate() {
            let (lo_c, hi_c) = spans[2 * i];
            let (lo_w, hi_w) = spans[2 * i + 1];
            let len_c = (hi_c - lo_c).max(1) as f64;
            let len_w = (hi_w - lo_w).max(1) as f64;
            let s_c = crate::eval::span_nll(&nll, &cfg, 2 * i, lo_c, hi_c) / len_c;
            let s_w = crate::eval::span_nll(&nll, &cfg, 2 * i + 1, lo_w, hi_w) / len_w;
            if s_c < s_w {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Full Table-2 row: all six tasks plus the average.
pub fn run_all(
    engine: &Engine,
    params: &ParamStore,
    n_items: usize,
    seed: u64,
) -> Result<Vec<ProbeResult>> {
    let mut out = Vec::new();
    for (task, items) in all_tasks(n_items, seed) {
        let accuracy = run_task(engine, params, &items)?;
        out.push(ProbeResult { task, accuracy, items: items.len() });
    }
    let avg = out.iter().map(|r| r.accuracy).sum::<f64>() / out.len() as f64;
    out.push(ProbeResult { task: "average".into(), accuracy: avg, items: 0 });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tasks_generate_distinct_candidates() {
        for (name, items) in all_tasks(8, 42) {
            assert_eq!(items.len(), 8, "{name}");
            for it in &items {
                assert_ne!(it.correct, it.wrong, "{name}: {it:?}");
                assert!(!it.prompt.is_empty());
            }
        }
    }

    #[test]
    fn pack_respects_seq_len() {
        let cfg = crate::model::config::tests::test_config();
        let long_prompt = "x".repeat(100);
        let (toks, lo, hi) = pack(&cfg, &long_prompt, "yes it is");
        assert_eq!(toks.len(), cfg.seq_len);
        assert!(lo < hi && hi <= cfg.seq_len);
    }

    #[test]
    fn cloze_deterministic() {
        let a = gen_cloze(Domain::WikiSyn, 4, 1);
        let b = gen_cloze(Domain::WikiSyn, 4, 1);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[0].prompt, b[0].prompt);
    }
}
