//! Evaluation harnesses: streaming perplexity (the paper's primary metric)
//! and prompt-based zero-shot probes (Table 2's protocol on synthetic
//! tasks — DESIGN.md §Substitutions).

pub mod probes;

use anyhow::Result;

use crate::data::{Batcher, Domain};
use crate::model::{ModelConfig, ParamStore, LAYER_NAMES};
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// Forward a `[B,S]` token batch through the whole model, returning the
/// per-position NLL `[B,S]` (last position zeroed). Blocks stream one at a
/// time through the shape-static `block_fwd` artifact — the same execution
/// layout the pruning pipeline uses.
pub fn forward_nll(engine: &Engine, params: &ParamStore, tokens: &Tensor) -> Result<Tensor> {
    let cfg = engine.config();
    let emb = params.get("embed")?;
    let mut x = engine.run("embed", &[tokens, emb])?.into_iter().next().unwrap();
    for l in 0..cfg.n_blocks {
        let mut ins: Vec<&Tensor> = vec![&x];
        for w in LAYER_NAMES {
            ins.push(params.get(&ParamStore::layer_name(l, w))?);
        }
        ins.push(params.get(&format!("blocks.{l}.norm1"))?);
        ins.push(params.get(&format!("blocks.{l}.norm2"))?);
        x = engine.run("block_fwd", &ins)?.into_iter().next().unwrap();
    }
    let nll = engine
        .run("head_nll", &[&x, params.get("norm_f")?, emb, tokens])?
        .into_iter()
        .next()
        .unwrap();
    Ok(nll)
}

/// Byte-level perplexity over `n_batches` fresh batches of `domain`.
pub fn perplexity(
    engine: &Engine,
    params: &ParamStore,
    domain: Domain,
    n_batches: usize,
    seed: u64,
) -> Result<f64> {
    let cfg = engine.config().clone();
    let mut batcher = Batcher::new(domain, seed, &cfg);
    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    for _ in 0..n_batches {
        let tokens = batcher.next_batch();
        let nll = forward_nll(engine, params, &tokens)?;
        total_nll += nll.f32s().iter().map(|v| *v as f64).sum::<f64>();
        total_tok += cfg.batch * (cfg.seq_len - 1); // last position is zeroed
    }
    Ok((total_nll / total_tok as f64).exp())
}

/// Perplexity on all three evaluation domains (one Table-1 row).
pub fn perplexity_all(
    engine: &Engine,
    params: &ParamStore,
    n_batches: usize,
    seed: u64,
) -> Result<Vec<(String, f64)>> {
    Domain::all()
        .iter()
        .map(|d| Ok((d.name().to_string(), perplexity(engine, params, *d, n_batches, seed)?)))
        .collect()
}

/// Serving-style batch scoring through the execution backend: assemble
/// heterogeneous-length prompts into the model's static `[B, S]` shape by
/// right-padding, forward once through the shape-static block artifacts,
/// and mask each prompt's NLL beyond its true length. Right-padding is
/// *exact* under causal attention — position `i` only attends to `<= i`,
/// so activations at real positions are unaffected by the padding tail —
/// which is what lets the fixed-shape backend serve variable-length
/// requests (parity vs the `serve` engine is pinned in
/// `tests/serve_parity.rs`). Returns the summed prompt NLL per request.
pub fn score_prompts_padded(
    engine: &Engine,
    params: &ParamStore,
    prompts: &[Vec<i32>],
) -> Result<Vec<f64>> {
    let cfg = engine.config().clone();
    let (b, s) = (cfg.batch, cfg.seq_len);
    let mut out = Vec::with_capacity(prompts.len());
    for chunk in prompts.chunks(b) {
        let mut data = vec![0i32; b * s];
        for (i, p) in chunk.iter().enumerate() {
            anyhow::ensure!(p.len() <= s, "prompt of {} tokens exceeds seq_len {s}", p.len());
            data[i * s..i * s + p.len()].copy_from_slice(p);
        }
        let tokens = Tensor::from_i32(&[b, s], data);
        let nll = forward_nll(engine, params, &tokens)?;
        for (i, p) in chunk.iter().enumerate() {
            // positions 0..len-1 score tokens 1..len-1; everything past the
            // prompt (incl. the first padding target) is masked out
            let row = &nll.f32s()[i * s..(i + 1) * s];
            let total: f64 =
                row[..p.len().saturating_sub(1)].iter().map(|v| *v as f64).sum();
            out.push(total);
        }
    }
    Ok(out)
}

/// Sum of NLL over a token span `[lo, hi)` of sequence `b` — scoring a
/// continuation: NLL of token t is stored at position t-1.
pub fn span_nll(nll: &Tensor, cfg: &ModelConfig, b: usize, lo: usize, hi: usize) -> f64 {
    let s = cfg.seq_len;
    let row = &nll.f32s()[b * s..(b + 1) * s];
    row[lo.saturating_sub(1)..hi.saturating_sub(1).min(s)]
        .iter()
        .map(|v| *v as f64)
        .sum()
}
