//! Per-request span timing for the serving stack.
//!
//! A [`Tracer`] is the run-scoped collector: workers and connection
//! handlers each hold a [`SpanSink`] — a plain per-thread buffer — and
//! record [`SpanRecord`]s locally with no synchronization on the hot
//! path. A sink flushes its whole buffer into the tracer's shard list in
//! one lock acquisition (explicitly via [`SpanSink::flush`], and always
//! on drop), so the mutex is touched once per worker lifetime plus once
//! per explicit flush, never per span. The run ends with
//! [`Tracer::drain`] (all spans, time-sorted) or [`Tracer::write_jsonl`]
//! (`--trace-out`).
//!
//! # Label discipline
//!
//! Span labels are a stable, closed vocabulary ([`SpanKind::ALL`], one
//! lowercase token each — see `docs/telemetry.md`): `accept`, `parse`,
//! `queue`, `admit`, `prefill`, `decode`, `serialize`, `migrate`,
//! `steal`, `fault`, `restart`, `requeue`, `degrade`. Consumers may rely
//! on these strings never being renamed; new stages extend the enum (and
//! the doc table) rather than repurposing an existing label.
//!
//! Timestamps are microseconds since the tracer's epoch (its creation
//! instant), so one run's spans are mutually comparable and diffable
//! across runs; they are *not* wall-clock dates. `worker` is the serving
//! worker index, or -1 for front-end spans (accept/parse/serialize happen
//! on connection handler threads). `req` is the engine-side request id.

use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};
use crate::util::par::locked;

/// One stage of a request's life. The wire label ([`SpanKind::label`])
/// is stable — see the module docs for the discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// connection accepted → protocol sniffed (front end)
    Accept,
    /// request line/body read → parsed (front end)
    Parse,
    /// enqueued → popped by a worker
    Queue,
    /// popped → prefill starts (admission bookkeeping)
    Admit,
    /// prompt prefill through the blocks
    Prefill,
    /// first batched decode step → retire
    Decode,
    /// terminal reply serialized and written (front end)
    Serialize,
    /// decode parked for handover: decode start (or admission) → park,
    /// recorded by the origin worker
    Migrate,
    /// parked → stolen, recorded by the thief worker (its index)
    Steal,
    /// an injected fault fired (`--faults`, `ok:false`; zero-length —
    /// the instant of the fault, recorded by the affected worker)
    Fault,
    /// supervised worker restart: panic caught → loop re-entered
    /// (duration covers recovery plus the backoff sleep)
    Restart,
    /// an interrupted request was returned to the queue for replay
    Requeue,
    /// the request was routed to the degrade (higher-sparsity) tier
    Degrade,
}

impl SpanKind {
    pub const ALL: [SpanKind; 13] = [
        SpanKind::Accept,
        SpanKind::Parse,
        SpanKind::Queue,
        SpanKind::Admit,
        SpanKind::Prefill,
        SpanKind::Decode,
        SpanKind::Serialize,
        SpanKind::Migrate,
        SpanKind::Steal,
        SpanKind::Fault,
        SpanKind::Restart,
        SpanKind::Requeue,
        SpanKind::Degrade,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            SpanKind::Accept => "accept",
            SpanKind::Parse => "parse",
            SpanKind::Queue => "queue",
            SpanKind::Admit => "admit",
            SpanKind::Prefill => "prefill",
            SpanKind::Decode => "decode",
            SpanKind::Serialize => "serialize",
            SpanKind::Migrate => "migrate",
            SpanKind::Steal => "steal",
            SpanKind::Fault => "fault",
            SpanKind::Restart => "restart",
            SpanKind::Requeue => "requeue",
            SpanKind::Degrade => "degrade",
        }
    }

    pub fn from_label(s: &str) -> Option<SpanKind> {
        SpanKind::ALL.into_iter().find(|k| k.label() == s)
    }
}

/// One timed stage of one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    /// engine-side request id (0 for connection-scoped front-end spans)
    pub req: u64,
    pub kind: SpanKind,
    /// serving worker index; -1 = front-end (connection handler) thread
    pub worker: i64,
    /// microseconds since the tracer epoch
    pub start_us: u64,
    pub dur_us: u64,
    /// false when the stage failed (e.g. a parse error)
    pub ok: bool,
}

/// Run-scoped span collector. Cheap to share by reference (workers) or
/// `Arc` (detached server threads).
pub struct Tracer {
    epoch: Instant,
    shards: Mutex<Vec<Vec<SpanRecord>>>,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new()
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer { epoch: Instant::now(), shards: Mutex::new(Vec::new()) }
    }

    /// Microseconds from the tracer epoch to `t` (0 for pre-epoch
    /// instants — saturating, never panicking).
    pub fn us_since_epoch(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// A buffering sink bound to this tracer. One per worker thread.
    pub fn sink(&self) -> SpanSink<'_> {
        SpanSink { tracer: Some(self), buf: Vec::new() }
    }

    /// Absorb one sink's buffer as a shard (one lock acquisition).
    fn absorb(&self, buf: Vec<SpanRecord>) {
        if !buf.is_empty() {
            locked(&self.shards).push(buf);
        }
    }

    /// All spans recorded so far, sorted by (start, request, kind);
    /// shards are consumed (a second drain returns only newer spans).
    pub fn drain(&self) -> Vec<SpanRecord> {
        let shards = std::mem::take(&mut *locked(&self.shards));
        let mut out: Vec<SpanRecord> = shards.into_iter().flatten().collect();
        out.sort_by_key(|s| (s.start_us, s.req, s.kind));
        out
    }

    /// Drain and dump as JSONL (one span object per line, schema in
    /// `docs/telemetry.md`). Returns the number of spans written; errors
    /// name the path.
    pub fn write_jsonl(&self, path: &Path) -> Result<usize> {
        let spans = self.drain();
        let mut out = String::with_capacity(spans.len() * 96);
        for s in &spans {
            let line = json::obj(vec![
                ("req", json::num(s.req as f64)),
                ("span", json::s(s.kind.label())),
                ("worker", json::num(s.worker as f64)),
                ("t_us", json::num(s.start_us as f64)),
                ("dur_us", json::num(s.dur_us as f64)),
                ("ok", Json::Bool(s.ok)),
            ]);
            out.push_str(&line.to_string());
            out.push('\n');
        }
        std::fs::write(path, out)
            .with_context(|| format!("writing telemetry JSONL to {}", path.display()))?;
        Ok(spans.len())
    }
}

/// Per-thread span buffer. Records are local (no locking); the buffer
/// flushes into the tracer on [`SpanSink::flush`] and on drop. A
/// disabled sink ([`SpanSink::disabled`] / [`sink_or_disabled`] with
/// `None`) makes every record a no-op, so call sites stay unconditional.
pub struct SpanSink<'a> {
    tracer: Option<&'a Tracer>,
    buf: Vec<SpanRecord>,
}

impl SpanSink<'_> {
    /// A sink that drops everything (tracing off).
    pub fn disabled() -> SpanSink<'static> {
        SpanSink { tracer: None, buf: Vec::new() }
    }

    pub fn is_enabled(&self) -> bool {
        self.tracer.is_some()
    }

    /// Record one span from a start/end instant pair. No-op when
    /// disabled; pre-epoch instants saturate to 0.
    pub fn record(
        &mut self,
        req: u64,
        kind: SpanKind,
        worker: i64,
        start: Instant,
        end: Instant,
        ok: bool,
    ) {
        if let Some(t) = self.tracer {
            let start_us = t.us_since_epoch(start);
            let dur_us = t.us_since_epoch(end).saturating_sub(start_us);
            self.buf.push(SpanRecord { req, kind, worker, start_us, dur_us, ok });
        }
    }

    /// Push the buffered spans into the tracer now (one lock).
    pub fn flush(&mut self) {
        if let Some(t) = self.tracer {
            t.absorb(std::mem::take(&mut self.buf));
        }
    }
}

impl Drop for SpanSink<'_> {
    fn drop(&mut self) {
        self.flush();
    }
}

/// The usual construction: a live sink when a tracer is attached, a
/// no-op sink otherwise.
pub fn sink_or_disabled(tracer: Option<&Tracer>) -> SpanSink<'_> {
    match tracer {
        Some(t) => t.sink(),
        None => SpanSink::disabled(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_stable_and_round_trip() {
        let want = [
            "accept", "parse", "queue", "admit", "prefill", "decode", "serialize", "migrate",
            "steal", "fault", "restart", "requeue", "degrade",
        ];
        let got: Vec<&str> = SpanKind::ALL.iter().map(|k| k.label()).collect();
        assert_eq!(got, want, "span labels are a frozen vocabulary (docs/telemetry.md)");
        for k in SpanKind::ALL {
            assert_eq!(SpanKind::from_label(k.label()), Some(k));
        }
        assert_eq!(SpanKind::from_label("nope"), None);
    }

    #[test]
    fn sinks_buffer_and_flush_on_drop() {
        let tracer = Tracer::new();
        let t0 = Instant::now();
        let t1 = t0 + std::time::Duration::from_micros(250);
        {
            let mut sink = tracer.sink();
            sink.record(7, SpanKind::Prefill, 0, t0, t1, true);
            sink.record(7, SpanKind::Decode, 0, t1, t1, true);
            // nothing visible until the sink flushes
            assert!(tracer.drain().is_empty());
        } // drop flushes
        let spans = tracer.drain();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].kind, SpanKind::Prefill);
        assert!(spans[0].dur_us >= 250);
        assert_eq!(spans[1].dur_us, 0, "zero-length spans are representable");
        // drained exactly once
        assert!(tracer.drain().is_empty());
    }

    #[test]
    fn disabled_sink_is_a_no_op() {
        let mut sink = SpanSink::disabled();
        assert!(!sink.is_enabled());
        let now = Instant::now();
        sink.record(1, SpanKind::Queue, -1, now, now, true);
        sink.flush(); // must not panic with no tracer
        assert!(sink.buf.is_empty());
    }

    #[test]
    fn pre_epoch_instants_saturate() {
        let before = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let tracer = Tracer::new();
        assert_eq!(tracer.us_since_epoch(before), 0);
        let mut sink = tracer.sink();
        sink.record(0, SpanKind::Accept, -1, before, before, true);
        sink.flush();
        let spans = tracer.drain();
        assert_eq!((spans[0].start_us, spans[0].dur_us), (0, 0));
    }

    #[test]
    fn jsonl_dump_round_trips_through_the_json_parser() {
        let dir = std::env::temp_dir().join("besa_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("spans.jsonl");
        let tracer = Tracer::new();
        let t0 = Instant::now();
        {
            let mut sink = tracer.sink();
            sink.record(3, SpanKind::Queue, 1, t0, t0 + std::time::Duration::from_micros(10), true);
            sink.record(3, SpanKind::Parse, -1, t0, t0, false);
        }
        let n = tracer.write_jsonl(&path).unwrap();
        assert_eq!(n, 2);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let v = Json::parse(line).unwrap();
            let span = v.get("span").and_then(Json::as_str).unwrap();
            assert!(SpanKind::from_label(span).is_some(), "unknown label {span}");
            assert!(v.get("t_us").and_then(Json::as_f64).is_some());
            assert!(v.get("dur_us").and_then(Json::as_f64).is_some());
            assert!(v.get("req").and_then(Json::as_f64).is_some());
            assert!(v.get("worker").and_then(Json::as_f64).is_some());
            assert!(matches!(v.get("ok"), Some(Json::Bool(_))));
        }
        // the front-end span keeps its -1 worker and failed-ok flag
        assert!(text.contains("\"worker\":-1") || text.contains("\"worker\": -1"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_jsonl_fails_loudly_on_unwritable_path() {
        let tracer = Tracer::new();
        let t0 = Instant::now();
        {
            let mut sink = tracer.sink();
            sink.record(0, SpanKind::Queue, 0, t0, t0, true);
        }
        let bad = Path::new("/nonexistent-besa-dir/spans.jsonl");
        let err = tracer.write_jsonl(bad).unwrap_err();
        assert!(err.to_string().contains("spans.jsonl"), "error names the path: {err}");
    }
}
