//! Artifact specs: the contract between graph definitions and the rust
//! runtime. Two ways to obtain one:
//!
//! * [`Manifest::load`] parses `artifacts/<config>/manifest.json` written by
//!   `python/compile/aot.py` (PJRT backend — specs describe lowered HLO).
//! * [`Manifest::synthesize`] derives the identical specs directly from a
//!   [`ModelConfig`] (native backend — no files on disk at all). The two
//!   must agree; `python/tests/test_aot_manifest.py` and the rust parity
//!   suite both assert the shared invariants.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::config::{ModelConfig, LAYER_NAMES};
use crate::util::json::Json;

/// A dimension of 0 in `shape` is *dynamic*: the engine accepts any
/// extent there (rank and the remaining dims still must match). Static
/// specs — everything AOT-lowered — never contain 0-sized dims, so the
/// wildcard is unambiguous; it exists for serving-style ops
/// (`block_fwd_cached`) whose batch and cache length vary per call.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn f32(name: impl Into<String>, shape: &[usize]) -> TensorSpec {
        TensorSpec { name: name.into(), dtype: "float32".into(), shape: shape.to_vec() }
    }

    fn i32(name: impl Into<String>, shape: &[usize]) -> TensorSpec {
        TensorSpec { name: name.into(), dtype: "int32".into(), shape: shape.to_vec() }
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text path (PJRT backend only; empty for synthesized specs).
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn specs_of(v: &Json) -> Result<Vec<TensorSpec>> {
    let mut out = Vec::new();
    for item in v.as_arr().context("expected spec array")? {
        out.push(TensorSpec {
            name: item.at(&["name"]).as_str().context("spec name")?.to_string(),
            dtype: item.at(&["dtype"]).as_str().context("spec dtype")?.to_string(),
            shape: item
                .at(&["shape"])
                .as_arr()
                .context("spec shape")?
                .iter()
                .map(|d| d.as_usize().unwrap())
                .collect(),
        });
    }
    Ok(out)
}

impl Manifest {
    /// Load `artifacts/<config>/manifest.json`.
    pub fn load(artifacts_root: &Path, config: &str) -> Result<Manifest> {
        let dir = artifacts_root.join(config);
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` (configs are built by python/compile/aot.py)",
                path.display()
            )
        })?;
        let root = Json::parse(&src)?;
        let mut config = ModelConfig::from_json(root.at(&["config"]))?;
        let mut artifacts = BTreeMap::new();
        let arts = root.at(&["artifacts"]).as_obj().context("artifacts object")?;
        for (name, spec) in arts {
            let file = dir.join(spec.at(&["file"]).as_str().context("file")?);
            if !file.exists() {
                bail!("artifact file missing: {}", file.display());
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file,
                    inputs: specs_of(spec.at(&["inputs"]))?,
                    outputs: specs_of(spec.at(&["outputs"]))?,
                },
            );
        }
        // Manifests don't record alt_rates explicitly; recover it from the
        // lowered besa_step_row_d<N> variants so a manifest-derived config
        // synthesizes the same op set (Table 5 sparsity-step ablation).
        config.alt_rates = artifacts
            .keys()
            .filter_map(|k| k.strip_prefix("besa_step_row_d").and_then(|s| s.parse().ok()))
            .collect();
        config.alt_rates.sort_unstable();
        Ok(Manifest { dir, config, artifacts })
    }

    /// Synthesize the full artifact spec set from a config — the exact
    /// mirror of `python/compile/aot.py::emit_config`, minus the HLO files.
    pub fn synthesize(config: ModelConfig) -> Manifest {
        let cfg = &config;
        let (b, s, d, f, v) = (cfg.batch, cfg.seq_len, cfg.d_model, cfg.d_ffn, cfg.vocab);
        let x3 = [b, s, d];
        let mut artifacts = BTreeMap::new();
        let mut add = |name: &str, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>| {
            artifacts.insert(
                name.to_string(),
                ArtifactSpec { name: name.to_string(), file: PathBuf::new(), inputs, outputs },
            );
        };

        let weight_specs = |prefix: &str| -> Vec<TensorSpec> {
            LAYER_NAMES
                .iter()
                .map(|w| TensorSpec::f32(format!("{prefix}{w}"), &cfg.layer_shape(w)))
                .collect()
        };
        let norm_specs = |prefix: &str| -> Vec<TensorSpec> {
            vec![
                TensorSpec::f32(format!("{prefix}norm1"), &[d]),
                TensorSpec::f32(format!("{prefix}norm2"), &[d]),
            ]
        };
        let rank_specs = |prefix: &str| -> Vec<TensorSpec> {
            LAYER_NAMES
                .iter()
                .map(|w| TensorSpec::i32(format!("{prefix}rank_{w}"), &cfg.layer_shape(w)))
                .collect()
        };
        let theta_specs = |rowwise: bool, n_rates: usize, prefix: &str| -> Vec<TensorSpec> {
            LAYER_NAMES
                .iter()
                .map(|w| {
                    let rows = if rowwise { cfg.layer_shape(w)[0] } else { 1 };
                    TensorSpec::f32(format!("{prefix}theta_{w}"), &[rows, n_rates - 1])
                })
                .collect()
        };
        let gamma_specs = || -> Vec<TensorSpec> {
            LAYER_NAMES.iter().map(|w| TensorSpec::f32(format!("gamma_{w}"), &[2])).collect()
        };

        // --- embedding / head ------------------------------------------------
        add(
            "embed",
            vec![TensorSpec::i32("tokens", &[b, s]), TensorSpec::f32("emb", &[v, d])],
            vec![TensorSpec::f32("x", &x3)],
        );
        add(
            "head_nll",
            vec![
                TensorSpec::f32("x", &x3),
                TensorSpec::f32("norm_f", &[d]),
                TensorSpec::f32("emb", &[v, d]),
                TensorSpec::i32("tokens", &[b, s]),
            ],
            vec![TensorSpec::f32("nll", &[b, s])],
        );

        // --- block forward (dense / masked / capture) ------------------------
        let mut base_in = vec![TensorSpec::f32("x", &x3)];
        base_in.extend(weight_specs(""));
        base_in.extend(norm_specs(""));
        add("block_fwd", base_in.clone(), vec![TensorSpec::f32("y", &x3)]);
        let mut masked_in = base_in.clone();
        masked_in.extend(
            LAYER_NAMES
                .iter()
                .map(|w| TensorSpec::f32(format!("mask_{w}"), &cfg.layer_shape(w))),
        );
        add("block_fwd_masked", masked_in, vec![TensorSpec::f32("y", &x3)]);

        // KV-cached single-token decode (native-only, serving hot path):
        // dynamic dims (0) for the request batch and cache capacity. The
        // caller passes roped key / raw value caches holding `pos[i]`
        // entries per sequence and appends the returned k_new/v_new.
        let mut cached_in = vec![
            TensorSpec::f32("x", &[0, 1, d]),
            TensorSpec::f32("k_cache", &[0, 0, d]),
            TensorSpec::f32("v_cache", &[0, 0, d]),
            TensorSpec::i32("pos", &[0]),
        ];
        cached_in.extend(weight_specs(""));
        cached_in.extend(norm_specs(""));
        add(
            "block_fwd_cached",
            cached_in,
            vec![
                TensorSpec::f32("y", &[0, 1, d]),
                TensorSpec::f32("k_new", &[0, 1, d]),
                TensorSpec::f32("v_new", &[0, 1, d]),
            ],
        );

        add(
            "block_capture",
            base_in.clone(),
            vec![
                TensorSpec::f32("y", &x3),
                TensorSpec::f32("h1", &x3),
                TensorSpec::f32("att", &x3),
                TensorSpec::f32("h2", &x3),
                TensorSpec::f32("act", &[b, s, f]),
            ],
        );

        // --- BESA steps -------------------------------------------------------
        let besa_inputs = |rowwise: bool, n_rates: usize, quant: bool| -> Vec<TensorSpec> {
            let mut ins = theta_specs(rowwise, n_rates, "");
            ins.push(TensorSpec::f32("x_pruned", &x3));
            ins.push(TensorSpec::f32("y_dense", &x3));
            ins.extend(weight_specs(""));
            ins.extend(norm_specs(""));
            ins.extend(rank_specs(""));
            ins.push(TensorSpec::f32("lam", &[]));
            ins.push(TensorSpec::f32("alpha_hat", &[]));
            if quant {
                ins.extend(gamma_specs());
            }
            ins
        };
        let besa_outputs = |quant: bool, rowwise: bool, n_rates: usize| -> Vec<TensorSpec> {
            let mut outs = vec![
                TensorSpec::f32("loss", &[]),
                TensorSpec::f32("recon", &[]),
                TensorSpec::f32("mean_alpha", &[]),
            ];
            outs.extend(LAYER_NAMES.iter().map(|w| {
                let rows = if rowwise { cfg.layer_shape(w)[0] } else { 1 };
                TensorSpec::f32(format!("dtheta_{w}"), &[rows, n_rates - 1])
            }));
            if quant {
                outs.extend(
                    LAYER_NAMES.iter().map(|w| TensorSpec::f32(format!("dgamma_{w}"), &[2])),
                );
            }
            outs
        };
        add(
            "besa_step_row",
            besa_inputs(true, cfg.n_rates, false),
            besa_outputs(false, true, cfg.n_rates),
        );
        for &alt in &cfg.alt_rates {
            add(
                &format!("besa_step_row_d{alt}"),
                besa_inputs(true, alt, false),
                besa_outputs(false, true, alt),
            );
        }
        add(
            "besa_step_layer",
            besa_inputs(false, cfg.n_rates, false),
            besa_outputs(false, false, cfg.n_rates),
        );
        add(
            "besa_step_attnmlp",
            besa_inputs(true, cfg.n_rates, false),
            besa_outputs(false, true, cfg.n_rates),
        );
        add(
            "besa_quant_step_row",
            besa_inputs(true, cfg.n_rates, true),
            besa_outputs(true, true, cfg.n_rates),
        );

        // --- two-block granularity (Table 6) ----------------------------------
        let mut tb_in = theta_specs(true, cfg.n_rates, "b0_");
        tb_in.extend(theta_specs(true, cfg.n_rates, "b1_"));
        tb_in.push(TensorSpec::f32("x_pruned", &x3));
        tb_in.push(TensorSpec::f32("y_dense", &x3));
        tb_in.extend(weight_specs("b0_"));
        tb_in.extend(weight_specs("b1_"));
        tb_in.extend(norm_specs("b0_"));
        tb_in.extend(norm_specs("b1_"));
        tb_in.extend(rank_specs("b0_"));
        tb_in.extend(rank_specs("b1_"));
        tb_in.push(TensorSpec::f32("lam", &[]));
        tb_in.push(TensorSpec::f32("alpha_hat", &[]));
        let mut tb_out = vec![
            TensorSpec::f32("loss", &[]),
            TensorSpec::f32("recon", &[]),
            TensorSpec::f32("mean_alpha", &[]),
        ];
        for prefix in ["b0_", "b1_"] {
            tb_out.extend(LAYER_NAMES.iter().map(|w| {
                TensorSpec::f32(
                    format!("{prefix}dtheta_{w}"),
                    &[cfg.layer_shape(w)[0], cfg.n_rates - 1],
                )
            }));
        }
        add("two_block_step", tb_in, tb_out);

        // --- mask decode + quant apply per distinct layer shape ----------------
        let mut distinct: Vec<[usize; 2]> = Vec::new();
        for w in LAYER_NAMES {
            let sh = cfg.layer_shape(w);
            if !distinct.contains(&sh) {
                distinct.push(sh);
            }
        }
        for sh in distinct {
            let [r, c] = sh;
            add(
                &format!("mask_decode_{r}x{c}"),
                vec![
                    TensorSpec::f32("theta", &[r, cfg.n_rates - 1]),
                    TensorSpec::i32("rank", &[r, c]),
                ],
                vec![TensorSpec::f32("mask", &[r, c]), TensorSpec::f32("alpha", &[r])],
            );
            add(
                &format!("quant_apply_{r}x{c}"),
                vec![TensorSpec::f32("w", &[r, c]), TensorSpec::f32("gamma", &[2])],
                vec![TensorSpec::f32("wq", &[r, c])],
            );
        }

        // --- whole-model pretraining step --------------------------------------
        let mut train_in: Vec<TensorSpec> = cfg
            .param_order
            .iter()
            .map(|n| TensorSpec::f32(n.clone(), &cfg.param_shape(n)))
            .collect();
        train_in.push(TensorSpec::i32("tokens", &[b, s]));
        let mut train_out = vec![TensorSpec::f32("loss", &[])];
        train_out.extend(
            cfg.param_order
                .iter()
                .map(|n| TensorSpec::f32(format!("d_{n}"), &cfg.param_shape(n))),
        );
        add("lm_train_step", train_in, train_out);

        Manifest { dir: PathBuf::new(), config, artifacts }
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| {
            format!(
                "artifact '{name}' not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesized_manifest_matches_aot_contract() {
        let cfg = ModelConfig::builtin("test").unwrap();
        let m = Manifest::synthesize(cfg);
        // same counts python/tests/test_aot_manifest.py pins for the real one
        let b = m.artifact("besa_step_row").unwrap();
        assert_eq!(b.inputs.len(), 27);
        assert_eq!(b.outputs.len(), 10);
        assert_eq!(b.inputs[0].dtype, "float32");
        assert_eq!(b.inputs[0].shape, vec![32, 15]);
        let q = m.artifact("besa_quant_step_row").unwrap();
        assert_eq!(q.inputs.len(), 34);
        assert_eq!(q.outputs.len(), 17);
        let tb = m.artifact("two_block_step").unwrap();
        // 14 thetas + x + y + 14 weights + 4 norms + 14 ranks + lam + alpha_hat
        assert_eq!(tb.inputs.len(), 50);
        assert_eq!(tb.outputs.len(), 17);
        let t = m.artifact("lm_train_step").unwrap();
        assert_eq!(t.inputs.len(), m.config.param_order.len() + 1);
        assert_eq!(t.outputs.len(), m.config.param_order.len() + 1);
        // serving decode op: x + 2 caches + pos + 7 weights + 2 norms,
        // dynamic (0) batch/capacity dims
        let cfwd = m.artifact("block_fwd_cached").unwrap();
        assert_eq!(cfwd.inputs.len(), 13);
        assert_eq!(cfwd.outputs.len(), 3);
        assert_eq!(cfwd.inputs[1].shape, vec![0, 0, 32]);
        assert_eq!(cfwd.inputs[3].dtype, "int32");
        // the three distinct layer shapes of the test config
        for tag in ["32x32", "88x32", "32x88"] {
            assert!(m.artifact(&format!("mask_decode_{tag}")).is_ok(), "{tag}");
            assert!(m.artifact(&format!("quant_apply_{tag}")).is_ok(), "{tag}");
        }
        assert!(m.artifact("nonexistent").is_err());
    }

    #[test]
    fn alt_rates_synthesize_step_variants() {
        let cfg = ModelConfig::builtin("sm").unwrap();
        let m = Manifest::synthesize(cfg);
        let alt = m.artifact("besa_step_row_d8").unwrap();
        assert_eq!(alt.inputs[0].shape, vec![64, 7]);
        assert!(m.artifact("besa_step_row_d64").is_ok());
    }

    #[test]
    fn loads_test_manifest_when_built() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("test").exists() {
            eprintln!("skipping: artifacts/test not built");
            return;
        }
        let m = Manifest::load(&root, "test").unwrap();
        assert_eq!(m.config.name, "test");
        let b = m.artifact("besa_step_row").unwrap();
        assert_eq!(b.inputs.len(), 27);
        assert_eq!(b.outputs.len(), 10);
    }
}
