//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parses `artifacts/<config>/manifest.json` and exposes the
//! per-artifact positional input/output tensor specs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::config::ModelConfig;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn specs_of(v: &Json) -> Result<Vec<TensorSpec>> {
    let mut out = Vec::new();
    for item in v.as_arr().context("expected spec array")? {
        out.push(TensorSpec {
            name: item.at(&["name"]).as_str().context("spec name")?.to_string(),
            dtype: item.at(&["dtype"]).as_str().context("spec dtype")?.to_string(),
            shape: item
                .at(&["shape"])
                .as_arr()
                .context("spec shape")?
                .iter()
                .map(|d| d.as_usize().unwrap())
                .collect(),
        });
    }
    Ok(out)
}

impl Manifest {
    /// Load `artifacts/<config>/manifest.json`.
    pub fn load(artifacts_root: &Path, config: &str) -> Result<Manifest> {
        let dir = artifacts_root.join(config);
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` (configs are built by python/compile/aot.py)",
                path.display()
            )
        })?;
        let root = Json::parse(&src)?;
        let config = ModelConfig::from_json(root.at(&["config"]))?;
        let mut artifacts = BTreeMap::new();
        let arts = root.at(&["artifacts"]).as_obj().context("artifacts object")?;
        for (name, spec) in arts {
            let file = dir.join(spec.at(&["file"]).as_str().context("file")?);
            if !file.exists() {
                bail!("artifact file missing: {}", file.display());
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file,
                    inputs: specs_of(spec.at(&["inputs"]))?,
                    outputs: specs_of(spec.at(&["outputs"]))?,
                },
            );
        }
        Ok(Manifest { dir, config, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| {
            format!(
                "artifact '{name}' not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_test_manifest() {
        let root = artifacts_root();
        if !root.join("test").exists() {
            eprintln!("skipping: artifacts/test not built");
            return;
        }
        let m = Manifest::load(&root, "test").unwrap();
        assert_eq!(m.config.name, "test");
        let b = m.artifact("besa_step_row").unwrap();
        assert_eq!(b.inputs.len(), 27);
        assert_eq!(b.outputs.len(), 10);
        assert_eq!(b.inputs[0].dtype, "float32");
        assert!(m.artifact("nonexistent").is_err());
    }
}
