//! PJRT runtime: loads AOT HLO-text artifacts, compiles them once per
//! process, executes them from the (python-free) hot path.

pub mod artifact;
pub mod engine;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use engine::Engine;
