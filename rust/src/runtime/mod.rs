//! Pluggable execution runtime.
//!
//! Everything above this layer — coordinator, pruners, eval, CLI — talks
//! to [`Engine`], a thin facade over the [`Backend`] trait:
//!
//! * [`native`] — pure-rust interpreter of the full artifact op set, specs
//!   synthesized from [`crate::model::ModelConfig`]. Default; hermetic.
//! * [`pjrt`] (cargo feature `pjrt`) — compiles AOT HLO-text artifacts
//!   once per process and executes them via the PJRT C API.
//!
//! Select with `--backend native|pjrt` on the CLI or `BESA_BACKEND` in the
//! environment.

pub mod artifact;
pub mod engine;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use engine::{Arg, Backend, BackendKind, Engine, Prepared};
pub use native::NativeBackend;
