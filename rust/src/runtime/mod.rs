//! Pluggable execution runtime.
//!
//! Everything above this layer — coordinator, pruners, eval, CLI — talks
//! to [`Engine`], a thin facade over the [`Backend`] trait:
//!
//! * [`native`] — pure-rust interpreter of the full artifact op set, specs
//!   synthesized from [`crate::model::ModelConfig`]. Default; hermetic.
//! * `pjrt` (cargo feature `pjrt`; absent from a default-feature doc
//!   build) — compiles AOT HLO-text artifacts once per process and
//!   executes them via the PJRT C API.
//!
//! Select with `--backend native|pjrt` on the CLI or `BESA_BACKEND` in the
//! environment.
//!
//! # Invariants the parity suites pin
//!
//! * **Spec agreement** — [`artifact::Manifest::synthesize`] (native)
//!   derives specs identical to what `python/compile/aot.py` writes for
//!   PJRT; `python/tests/test_aot_manifest.py` and `tests/native_parity.rs`
//!   assert the shared contract.
//! * **Dynamic dims** — a `0` extent in a [`TensorSpec`] shape is a
//!   wildcard: `Engine::validate` accepts any extent there (rank and the
//!   remaining dims still must match). Static specs — everything
//!   AOT-lowered — never contain 0-sized dims, so the wildcard is
//!   unambiguous; it exists for serving-style ops (`block_fwd_cached`)
//!   whose batch size and cache length vary per call.
//! * **Numeric parity** — the native interpreter reproduces the golden
//!   vectors of the float64 reference transliteration
//!   (`python/tools/gen_golden.py` → `tests/golden/`); its backwards are
//!   finite-difference-validated; and the `block_fwd_cached` op matches a
//!   full-prefix `block_fwd` recompute bitwise (`tests/serve_parity.rs`).

pub mod artifact;
pub mod engine;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use engine::{Arg, Backend, BackendKind, Engine, Prepared};
pub use native::NativeBackend;
