//! PJRT execution backend (behind the `pjrt` cargo feature): loads AOT
//! HLO-text artifacts, compiles each once per process, executes them
//! through the PJRT C API. This is the original concrete `Engine`
//! refactored onto the [`Backend`] trait.
//!
//! Note: the workspace vendors an API *stub* of the `xla` crate so this
//! module always typechecks offline; executing for real requires pointing
//! the `xla` path dependency at the actual bindings.
//!
//! Hot-loop inputs: the trait-level `run(name, &[&Tensor])` interface
//! re-converts every input tensor to a PJRT literal per call. For
//! loop-invariant inputs, callers go through [`Backend::prepare`] /
//! [`Backend::run_args`] instead — this backend caches the literal inside
//! the [`Prepared`] handle at prepare time and reuses it on every call,
//! restoring the once-per-block conversion the old concrete engine had
//! (§Perf in EXPERIMENTS.md).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::Stopwatch;

use super::engine::{Arg, Backend, Prepared};
use super::{ArtifactSpec, Manifest};

struct Inner {
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// cumulative (compile_secs, execute_secs, execute_calls)
    stats: (f64, f64, u64),
}

pub struct PjrtBackend {
    manifest: Manifest,
    inner: Mutex<Inner>,
}

// SAFETY: every access to the PJRT client and executables goes through the
// `inner` mutex, so the non-Sync xla handles are only ever touched by one
// thread at a time. The PJRT CPU client tolerates serialized cross-thread
// use (single logical stream).
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl PjrtBackend {
    pub fn new(artifacts_root: &Path, config: &str) -> Result<PjrtBackend> {
        let manifest = Manifest::load(artifacts_root, config)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtBackend {
            manifest,
            inner: Mutex::new(Inner {
                client,
                executables: BTreeMap::new(),
                stats: (0.0, 0.0, 0),
            }),
        })
    }

    /// Compile (or fetch from cache) an artifact; must hold the lock.
    fn ensure_compiled(inner: &mut Inner, spec: &ArtifactSpec) -> Result<()> {
        if inner.executables.contains_key(&spec.name) {
            return Ok(());
        }
        let sw = Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = inner
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", spec.name))?;
        inner.stats.0 += sw.secs();
        crate::debuglog!("compiled artifact '{}' in {:.2}s", spec.name, sw.secs());
        inner.executables.insert(spec.name.clone(), exe);
        Ok(())
    }

    /// Shared execute path for `run` / `run_args`: compile-once, execute,
    /// untuple, convert outputs back to host tensors.
    fn execute_literals(
        &self,
        name: &str,
        spec: &ArtifactSpec,
        refs: &[&xla::Literal],
    ) -> Result<Vec<Tensor>> {
        let mut inner = self.inner.lock().unwrap();
        Self::ensure_compiled(&mut inner, spec)?;
        let sw = Stopwatch::start();
        let exe = inner.executables.get(name).unwrap();
        let result = exe
            .execute::<&xla::Literal>(refs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of {name}: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact '{}' returned {} outputs, manifest says {}",
                name,
                parts.len(),
                spec.outputs.len()
            );
        }
        let out: Vec<Tensor> =
            parts.iter().map(Tensor::from_literal).collect::<Result<_>>()?;
        inner.stats.1 += sw.secs();
        inner.stats.2 += 1;
        Ok(out)
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(name)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.execute_literals(name, spec, &refs)
    }

    /// Prepared inputs carry a device literal here — worth the host copy.
    fn caches_prepared(&self) -> bool {
        true
    }

    /// Cache the device literal at prepare time; `run_args` then skips the
    /// per-call host→literal conversion for this input entirely.
    fn prepare(&self, t: &Tensor) -> Result<Prepared> {
        let literal = t.to_literal()?;
        Ok(Prepared { host: t.clone(), literal: Some(literal) })
    }

    fn run_args(&self, name: &str, inputs: &[Arg]) -> Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(name)?;
        // Convert only the plain-host args; prepared args reuse their
        // cached literal. `owned` is fully populated before any ref is
        // taken, so the borrows below are stable.
        let mut owned: Vec<xla::Literal> = Vec::new();
        let mut cached: Vec<Option<&xla::Literal>> = Vec::with_capacity(inputs.len());
        for a in inputs {
            match a {
                Arg::Prep(p) if p.literal.is_some() => cached.push(p.literal.as_ref()),
                other => {
                    owned.push(other.host().to_literal()?);
                    cached.push(None);
                }
            }
        }
        let mut next_owned = owned.iter();
        let refs: Vec<&xla::Literal> = cached
            .into_iter()
            .map(|slot| slot.unwrap_or_else(|| next_owned.next().unwrap()))
            .collect();
        self.execute_literals(name, spec, &refs)
    }

    fn stats(&self) -> (f64, f64, u64) {
        self.inner.lock().unwrap().stats
    }
}
