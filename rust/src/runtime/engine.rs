//! The execution facade: a [`Backend`] trait with pluggable
//! implementations, wrapped by [`Engine`] — the single choke point every
//! consumer (coordinator, pruners, eval, CLI, benches) executes through.
//!
//! Backends:
//! * `native` ([`super::native::NativeBackend`]) — pure-rust interpreter of
//!   the full artifact op set. Hermetic: specs are synthesized from the
//!   built-in config table, nothing is read from disk. `Sync`, so the
//!   coordinator fans minibatches out across threads.
//! * `pjrt` ([`super::pjrt::PjrtBackend`], behind the `pjrt` cargo
//!   feature) — compiles AOT HLO-text artifacts once per process and
//!   executes them through the PJRT C API.
//!
//! Selection: `Engine::from_args`-style callers pass a [`BackendKind`];
//! [`BackendKind::from_env`] reads `BESA_BACKEND=native|pjrt` with native
//! as the default.

use std::path::Path;

use anyhow::{bail, Result};

use crate::model::config::ModelConfig;
use crate::tensor::Tensor;

use super::{ArtifactSpec, Manifest};

/// A pluggable execution backend: everything the pipeline needs to run a
/// named artifact over host tensors. Implementations must be `Send + Sync`
/// — the coordinator dispatches calibration minibatches from scoped
/// threads against one shared backend.
pub trait Backend: Send + Sync {
    /// Short stable identifier ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// Artifact specs + model config this backend executes against.
    fn manifest(&self) -> &Manifest;

    /// Execute an artifact; inputs are pre-validated against the manifest
    /// spec by the [`Engine`] facade. Returns outputs in spec order.
    fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>>;

    /// Cumulative (compile_secs, execute_secs, execute_calls).
    fn stats(&self) -> (f64, f64, u64) {
        (0.0, 0.0, 0)
    }
}

/// Which backend implementation to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Pjrt,
}

impl BackendKind {
    pub fn from_name(s: &str) -> Option<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "interp" | "cpu" => Some(BackendKind::Native),
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    /// `BESA_BACKEND` env var, defaulting to the hermetic native backend.
    pub fn from_env() -> BackendKind {
        match std::env::var("BESA_BACKEND") {
            Ok(v) if !v.is_empty() => BackendKind::from_name(&v).unwrap_or_else(|| {
                crate::warnlog!("unknown BESA_BACKEND '{v}', using native");
                BackendKind::Native
            }),
            _ => BackendKind::Native,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Thin facade over a boxed [`Backend`]: input validation + dispatch.
/// `Engine` is `Sync`; share it freely across scoped threads.
pub struct Engine {
    backend: Box<dyn Backend>,
}

impl Engine {
    /// Construct with an explicit backend kind. `artifacts_root` is
    /// consulted by the PJRT backend, and by native only as a fallback
    /// config source for names outside the built-in table (built-in
    /// names always resolve from the table).
    pub fn with_backend(
        kind: BackendKind,
        artifacts_root: &Path,
        config: &str,
    ) -> Result<Engine> {
        let backend: Box<dyn Backend> = match kind {
            BackendKind::Native => {
                Box::new(super::native::NativeBackend::for_config(artifacts_root, config)?)
            }
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => Box::new(super::pjrt::PjrtBackend::new(artifacts_root, config)?),
            #[cfg(not(feature = "pjrt"))]
            BackendKind::Pjrt => bail!(
                "backend 'pjrt' requires building with `--features pjrt` \
                 (and real xla bindings in place of vendor/xla)"
            ),
        };
        Ok(Engine { backend })
    }

    /// Backend from the `BESA_BACKEND` env var (default: native).
    pub fn new(artifacts_root: &Path, config: &str) -> Result<Engine> {
        Engine::with_backend(BackendKind::from_env(), artifacts_root, config)
    }

    /// Hermetic native engine for a built-in config — what tests and
    /// benches use; touches no files.
    pub fn native(config: &str) -> Result<Engine> {
        let cfg = ModelConfig::builtin(config)?;
        Ok(Engine { backend: Box::new(super::native::NativeBackend::new(cfg)) })
    }

    /// Wrap an already-constructed backend (custom implementations).
    pub fn from_backend(backend: Box<dyn Backend>) -> Engine {
        Engine { backend }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn config(&self) -> &ModelConfig {
        &self.backend.manifest().config
    }

    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    /// Validate inputs against the manifest spec (arity + shape + dtype).
    fn validate(&self, spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&spec.inputs) {
            if t.shape != s.shape {
                bail!(
                    "artifact '{}' input '{}': shape {:?} != manifest {:?}",
                    spec.name,
                    s.name,
                    t.shape,
                    s.shape
                );
            }
            if t.dtype_str() != s.dtype {
                bail!(
                    "artifact '{}' input '{}': dtype {} != manifest {}",
                    spec.name,
                    s.name,
                    t.dtype_str(),
                    s.dtype
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact; returns output tensors in manifest order.
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.backend.manifest().artifact(name)?;
        self.validate(spec, inputs)?;
        let out = self.backend.run(name, inputs)?;
        if out.len() != spec.outputs.len() {
            bail!(
                "artifact '{}' returned {} outputs, manifest says {}",
                name,
                out.len(),
                spec.outputs.len()
            );
        }
        Ok(out)
    }

    /// (compile_secs, execute_secs, execute_calls)
    pub fn stats(&self) -> (f64, f64, u64) {
        self.backend.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_names() {
        assert_eq!(BackendKind::from_name("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::from_name("PJRT"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::from_name("xla"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::from_name("gpu"), None);
        assert_eq!(BackendKind::Native.name(), "native");
    }

    #[test]
    fn engine_is_sync() {
        fn assert_sync<T: Sync + Send>() {}
        assert_sync::<Engine>();
    }
    // input-validation behavior (arity / shape / dtype / unknown artifact)
    // is covered end-to-end by tests/integration.rs::engine_rejects_bad_inputs
}
