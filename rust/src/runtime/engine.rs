//! Execution engine: one PJRT CPU client + a lazily-populated cache of
//! compiled executables (compile once, execute many — the pruning loop
//! calls `besa_step` thousands of times).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::Stopwatch;

use super::{ArtifactSpec, Manifest};

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    /// cumulative (compile_secs, execute_secs, execute_calls) metrics
    stats: RefCell<(f64, f64, u64)>,
}

impl Engine {
    pub fn new(artifacts_root: &Path, config: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_root, config)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            executables: RefCell::new(BTreeMap::new()),
            stats: RefCell::new((0.0, 0.0, 0)),
        })
    }

    pub fn config(&self) -> &crate::model::config::ModelConfig {
        &self.manifest.config
    }

    /// Compile (or fetch from cache) an artifact by name.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.executables.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.artifact(name)?;
        let sw = Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        self.stats.borrow_mut().0 += sw.secs();
        crate::debuglog!("compiled artifact '{name}' in {:.2}s", sw.secs());
        self.executables.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Validate inputs against the manifest spec (shape + dtype).
    fn validate(&self, spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&spec.inputs) {
            if t.shape != s.shape {
                bail!(
                    "artifact '{}' input '{}': shape {:?} != manifest {:?}",
                    spec.name,
                    s.name,
                    t.shape,
                    s.shape
                );
            }
            if t.dtype_str() != s.dtype {
                bail!(
                    "artifact '{}' input '{}': dtype {} != manifest {}",
                    spec.name,
                    s.name,
                    t.dtype_str(),
                    s.dtype
                );
            }
        }
        Ok(())
    }

    /// Execute an artifact; returns output tensors in manifest order.
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.ensure_compiled(name)?;
        let spec = self.manifest.artifact(name)?;
        self.validate(spec, inputs)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_literals(name, &refs)
    }

    /// Execute with pre-converted literals — the hot-loop entry point.
    /// Callers (e.g. the BESA β-loop) convert loop-invariant tensors once
    /// per block and pay only the per-step θ conversion (§Perf, L3).
    pub fn run_literals(&self, name: &str, literals: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        self.ensure_compiled(name)?;
        let spec = self.manifest.artifact(name)?;
        if literals.len() != spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                literals.len()
            );
        }
        let sw = Stopwatch::start();
        let exes = self.executables.borrow();
        let exe = exes.get(name).unwrap();
        let result = exe
            .execute::<&xla::Literal>(literals)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of {name}: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact '{}' returned {} outputs, manifest says {}",
                name,
                parts.len(),
                spec.outputs.len()
            );
        }
        let out: Vec<Tensor> =
            parts.iter().map(Tensor::from_literal).collect::<Result<_>>()?;
        {
            let mut st = self.stats.borrow_mut();
            st.1 += sw.secs();
            st.2 += 1;
        }
        Ok(out)
    }

    /// (compile_secs, execute_secs, execute_calls)
    pub fn stats(&self) -> (f64, f64, u64) {
        *self.stats.borrow()
    }

    pub fn compiled_count(&self) -> usize {
        self.executables.borrow().len()
    }
}
