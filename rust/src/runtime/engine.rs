//! The execution facade: a [`Backend`] trait with pluggable
//! implementations, wrapped by [`Engine`] — the single choke point every
//! consumer (coordinator, pruners, eval, CLI, benches) executes through.
//!
//! Backends:
//! * `native` ([`super::native::NativeBackend`]) — pure-rust interpreter of
//!   the full artifact op set. Hermetic: specs are synthesized from the
//!   built-in config table, nothing is read from disk. `Sync`, so the
//!   coordinator fans minibatches out across threads.
//! * `pjrt` (`super::pjrt::PjrtBackend`, behind the `pjrt` cargo feature
//!   and therefore absent from a default-feature doc build) — compiles
//!   AOT HLO-text artifacts once per process and executes them through
//!   the PJRT C API.
//!
//! Selection: `Engine::from_args`-style callers pass a [`BackendKind`];
//! [`BackendKind::from_env`] reads `BESA_BACKEND=native|pjrt` with native
//! as the default.

use std::path::Path;

use anyhow::{bail, Result};

use crate::model::config::ModelConfig;
use crate::tensor::Tensor;

use super::{ArtifactSpec, Manifest};

/// A backend-resident prepared input: the host tensor plus (for backends
/// with a device boundary) a cached device-side form. Create one via
/// [`Engine::prepare`] for inputs that stay constant across a hot loop —
/// the PJRT backend then skips its per-call host→literal conversion;
/// the native backend executes on host tensors directly, so preparation
/// is a free wrapper.
///
/// Memory tradeoff: the handle owns a host copy (needed so the default
/// host-executing `run_args` stays correct for any backend), so device
/// backends hold prepared data twice. Acceptable while prepared inputs
/// are per-block calibration slices; a metadata-only host (shape + dtype
/// for validation) is the known follow-up if that ever dominates.
pub struct Prepared {
    pub(crate) host: Tensor,
    /// cached device literal (pjrt backend only)
    #[cfg(feature = "pjrt")]
    pub(crate) literal: Option<xla::Literal>,
}

impl Prepared {
    pub(crate) fn host_only(host: Tensor) -> Prepared {
        Prepared {
            host,
            #[cfg(feature = "pjrt")]
            literal: None,
        }
    }

    pub fn host(&self) -> &Tensor {
        &self.host
    }
}

/// One positional artifact input: a plain host tensor (converted per call
/// as the backend requires) or a [`Prepared`] handle (converted once).
#[derive(Clone, Copy)]
pub enum Arg<'a> {
    Host(&'a Tensor),
    Prep(&'a Prepared),
}

impl<'a> Arg<'a> {
    pub fn host(&self) -> &'a Tensor {
        match *self {
            Arg::Host(t) => t,
            Arg::Prep(p) => &p.host,
        }
    }
}

/// A pluggable execution backend: everything the pipeline needs to run a
/// named artifact over host tensors. Implementations must be `Send + Sync`
/// — the coordinator dispatches calibration minibatches from scoped
/// threads against one shared backend.
pub trait Backend: Send + Sync {
    /// Short stable identifier ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// Artifact specs + model config this backend executes against.
    fn manifest(&self) -> &Manifest;

    /// Execute an artifact; inputs are pre-validated against the manifest
    /// spec by the [`Engine`] facade. Returns outputs in spec order.
    fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>>;

    /// Whether [`Backend::prepare`] produces a backend-resident form that
    /// makes repeated `run_args` calls cheaper. When false (the default —
    /// true for the native interpreter, which executes host tensors
    /// directly), callers should skip preparation: it would only deep-copy
    /// the host tensor for zero benefit.
    fn caches_prepared(&self) -> bool {
        false
    }

    /// Prepare a loop-invariant input once. Backends with a host/device
    /// boundary cache the device form here; the default is a host-copy
    /// wrapper (correct, but pointless — see [`Backend::caches_prepared`]).
    fn prepare(&self, t: &Tensor) -> Result<Prepared> {
        Ok(Prepared::host_only(t.clone()))
    }

    /// Execute with a mix of host tensors and prepared handles. The
    /// default degrades to [`Backend::run`] on the host views, which is
    /// exactly right for backends whose `prepare` is a no-op.
    fn run_args(&self, name: &str, inputs: &[Arg]) -> Result<Vec<Tensor>> {
        let hosts: Vec<&Tensor> = inputs.iter().map(|a| a.host()).collect();
        self.run(name, &hosts)
    }

    /// Cumulative (compile_secs, execute_secs, execute_calls).
    fn stats(&self) -> (f64, f64, u64) {
        (0.0, 0.0, 0)
    }
}

/// Which backend implementation to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Pjrt,
}

impl BackendKind {
    pub fn from_name(s: &str) -> Option<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "interp" | "cpu" => Some(BackendKind::Native),
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    /// `BESA_BACKEND` env var, defaulting to the hermetic native backend.
    pub fn from_env() -> BackendKind {
        match std::env::var("BESA_BACKEND") {
            Ok(v) if !v.is_empty() => BackendKind::from_name(&v).unwrap_or_else(|| {
                crate::warnlog!("unknown BESA_BACKEND '{v}', using native");
                BackendKind::Native
            }),
            _ => BackendKind::Native,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Thin facade over a boxed [`Backend`]: input validation + dispatch.
/// `Engine` is `Sync`; share it freely across scoped threads.
pub struct Engine {
    backend: Box<dyn Backend>,
}

impl Engine {
    /// Construct with an explicit backend kind. `artifacts_root` is
    /// consulted by the PJRT backend, and by native only as a fallback
    /// config source for names outside the built-in table (built-in
    /// names always resolve from the table).
    pub fn with_backend(
        kind: BackendKind,
        artifacts_root: &Path,
        config: &str,
    ) -> Result<Engine> {
        let backend: Box<dyn Backend> = match kind {
            BackendKind::Native => {
                Box::new(super::native::NativeBackend::for_config(artifacts_root, config)?)
            }
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => Box::new(super::pjrt::PjrtBackend::new(artifacts_root, config)?),
            #[cfg(not(feature = "pjrt"))]
            BackendKind::Pjrt => bail!(
                "backend 'pjrt' requires building with `--features pjrt` \
                 (and real xla bindings in place of vendor/xla)"
            ),
        };
        Engine::verified(backend)
    }

    /// Backend from the `BESA_BACKEND` env var (default: native).
    pub fn new(artifacts_root: &Path, config: &str) -> Result<Engine> {
        Engine::with_backend(BackendKind::from_env(), artifacts_root, config)
    }

    /// Hermetic native engine for a built-in config — what tests and
    /// benches use; touches no files.
    pub fn native(config: &str) -> Result<Engine> {
        let cfg = ModelConfig::builtin(config)?;
        Engine::verified(Box::new(super::native::NativeBackend::new(cfg)))
    }

    /// Statically verify the backend's manifest with the artifact-graph
    /// checker before handing it out: a spec set whose pipelines don't
    /// compose (shape/dtype mismatches across op boundaries, missing
    /// gradient outputs) is rejected here, at load time, instead of
    /// producing a mid-run error.
    fn verified(backend: Box<dyn Backend>) -> Result<Engine> {
        let diags = crate::analyze::graph::verify_manifest(backend.manifest());
        if !diags.is_empty() {
            let rendered: Vec<String> = diags.iter().map(|d| d.render()).collect();
            bail!(
                "manifest failed static graph verification ({} finding(s)):\n  {}",
                rendered.len(),
                rendered.join("\n  ")
            );
        }
        Ok(Engine { backend })
    }

    /// Wrap an already-constructed backend (custom implementations).
    /// Escape hatch: skips the static graph verification that
    /// [`Engine::with_backend`] / [`Engine::native`] perform — callers
    /// supplying a custom backend own its spec consistency.
    pub fn from_backend(backend: Box<dyn Backend>) -> Engine {
        Engine { backend }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn config(&self) -> &ModelConfig {
        &self.backend.manifest().config
    }

    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    /// Validate inputs against the manifest spec (arity + shape + dtype).
    /// Spec dims of 0 are dynamic and match any extent (see
    /// [`super::artifact::TensorSpec`]).
    fn validate(&self, spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{}' expects {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&spec.inputs) {
            let shape_ok = t.shape.len() == s.shape.len()
                && t.shape.iter().zip(&s.shape).all(|(td, sd)| *sd == 0 || td == sd);
            if !shape_ok {
                bail!(
                    "artifact '{}' input '{}': shape {:?} != manifest {:?}",
                    spec.name,
                    s.name,
                    t.shape,
                    s.shape
                );
            }
            if t.dtype_str() != s.dtype {
                bail!(
                    "artifact '{}' input '{}': dtype {} != manifest {}",
                    spec.name,
                    s.name,
                    t.dtype_str(),
                    s.dtype
                );
            }
        }
        // per-input checks passed; now the cross-input wildcard classes
        // (one request batch per call, shared cache capacity)
        crate::analyze::graph::check_dynamic_call(spec, inputs)?;
        Ok(())
    }

    fn check_outputs(name: &str, spec: &ArtifactSpec, out: &[Tensor]) -> Result<()> {
        if out.len() != spec.outputs.len() {
            bail!(
                "artifact '{}' returned {} outputs, manifest says {}",
                name,
                out.len(),
                spec.outputs.len()
            );
        }
        Ok(())
    }

    /// Execute an artifact; returns output tensors in manifest order.
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.backend.manifest().artifact(name)?;
        self.validate(spec, inputs)?;
        let out = self.backend.run(name, inputs)?;
        Self::check_outputs(name, spec, &out)?;
        Ok(out)
    }

    /// Whether preparing inputs buys anything on this backend (false for
    /// native — hot loops should pass plain [`Arg::Host`] there).
    pub fn caches_prepared(&self) -> bool {
        self.backend.caches_prepared()
    }

    /// Prepare a loop-invariant input once for repeated [`Engine::run_args`]
    /// calls (host-copy wrapper for native, cached device literal for pjrt).
    pub fn prepare(&self, t: &Tensor) -> Result<Prepared> {
        self.backend.prepare(t)
    }

    /// Execute an artifact over a mix of host tensors and [`Prepared`]
    /// handles — the hot-loop variant of [`Engine::run`]. Validation runs
    /// against the host views, so prepared inputs get the same arity /
    /// shape / dtype checking.
    pub fn run_args(&self, name: &str, inputs: &[Arg]) -> Result<Vec<Tensor>> {
        let spec = self.backend.manifest().artifact(name)?;
        let hosts: Vec<&Tensor> = inputs.iter().map(|a| a.host()).collect();
        self.validate(spec, &hosts)?;
        let out = self.backend.run_args(name, inputs)?;
        Self::check_outputs(name, spec, &out)?;
        Ok(out)
    }

    /// (compile_secs, execute_secs, execute_calls)
    pub fn stats(&self) -> (f64, f64, u64) {
        self.backend.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_names() {
        assert_eq!(BackendKind::from_name("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::from_name("PJRT"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::from_name("xla"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::from_name("gpu"), None);
        assert_eq!(BackendKind::Native.name(), "native");
    }

    #[test]
    fn engine_is_sync() {
        fn assert_sync<T: Sync + Send>() {}
        assert_sync::<Engine>();
    }

    #[test]
    fn run_args_matches_run_on_native() {
        let e = Engine::native("test").unwrap();
        let cfg = e.config().clone();
        let toks = Tensor::from_i32(&[cfg.batch, cfg.seq_len], vec![1; cfg.batch * cfg.seq_len]);
        let emb = Tensor::ones(&[cfg.vocab, cfg.d_model]);
        let direct = e.run("embed", &[&toks, &emb]).unwrap();
        let p_toks = e.prepare(&toks).unwrap();
        let p_emb = e.prepare(&emb).unwrap();
        let prepped = e.run_args("embed", &[Arg::Prep(&p_toks), Arg::Prep(&p_emb)]).unwrap();
        assert_eq!(direct[0], prepped[0]);
        // prepared inputs still go through shape validation
        let bad = Tensor::ones(&[1]);
        let p_bad = e.prepare(&bad).unwrap();
        assert!(e.run_args("embed", &[Arg::Prep(&p_toks), Arg::Prep(&p_bad)]).is_err());
    }
    // input-validation behavior (arity / shape / dtype / unknown artifact)
    // is covered end-to-end by tests/integration.rs::engine_rejects_bad_inputs
}
