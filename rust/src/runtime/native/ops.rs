//! Math primitives of the native interpreter: (masked) matmul, RMSNorm,
//! RoPE, causal attention, SiLU — forward *and* hand-derived backward.
//!
//! Semantics mirror `python/compile/kernels/ref.py` and
//! `python/compile/model.py` (f32 arithmetic, f32 accumulation, Wanda
//! `W[out, in]` weight convention applied as `x @ W.T`). Every backward
//! here was validated against central finite differences before being
//! transliterated (see tests in `tests/native_parity.rs`).

use crate::kernel::{attn, fused, gemm};
use crate::model::config::ModelConfig;

// ---------------------------------------------------------------------------
// matmul family (row-major slices) — routed through the shared
// microkernel layer (`crate::kernel::gemm`): scalar reference or
// register-blocked micro per `BESA_KERNEL`, bitwise-identical either way
// (ascending-k accumulation per output element in both).
// ---------------------------------------------------------------------------

/// `y[M,N] = x[M,K] @ w[N,K]^T` — the linear layer (both operands
/// K-contiguous, the cache-friendly orientation).
pub fn mm_nt(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    gemm::mm_nt(x, w, m, k, n)
}

/// `dx[M,K] = g[M,N] @ w[N,K]` — input gradient of the linear layer.
pub fn mm_nn(g: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    gemm::mm_nn(g, w, m, n, k)
}

/// `gw[N,K] = g[M,N]^T @ x[M,K]` — weight gradient of the linear layer.
pub fn mm_tn(g: &[f32], x: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    gemm::mm_tn(g, x, m, n, k)
}

/// Elementwise product (masked weight `W ∘ M`).
pub fn hadamard(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).collect()
}

// ---------------------------------------------------------------------------
// RMSNorm
// ---------------------------------------------------------------------------

/// `y = x / sqrt(mean(x^2) + eps) * gain`, rows of length `d`.
pub fn rmsnorm(x: &[f32], gain: &[f32], d: usize, eps: f64) -> Vec<f32> {
    let mut y = vec![0.0f32; x.len()];
    fused::rmsnorm_into(x, gain, d, eps, &mut y);
    y
}

/// Backward of [`rmsnorm`]: returns (gx, ggain).
///
/// With `r = (mean_j x_j^2 + eps)^{-1/2}`:
///   `gx_i = gy_i * g_i * r - (r^3 / d) * x_i * sum_j gy_j g_j x_j`
///   `ggain_i = sum_rows gy_i * x_i * r`
pub fn rmsnorm_bwd(
    x: &[f32],
    gain: &[f32],
    gy: &[f32],
    d: usize,
    eps: f64,
) -> (Vec<f32>, Vec<f32>) {
    let mut gx = vec![0.0f32; x.len()];
    let mut ggain = vec![0.0f32; d];
    for ((xr, gyr), gxr) in
        x.chunks_exact(d).zip(gy.chunks_exact(d)).zip(gx.chunks_exact_mut(d))
    {
        let var: f32 = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (var + eps as f32).sqrt();
        let mut s = 0.0f32; // sum_j gy_j g_j x_j
        for ((gyv, gv), xv) in gyr.iter().zip(gain).zip(xr) {
            s += gyv * gv * xv;
        }
        let coef = r * r * r / d as f32 * s;
        for i in 0..d {
            gxr[i] = gyr[i] * gain[i] * r - coef * xr[i];
            ggain[i] += gyr[i] * xr[i] * r;
        }
    }
    (gx, ggain)
}

// ---------------------------------------------------------------------------
// SiLU
// ---------------------------------------------------------------------------

pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// d silu / dx = sigmoid(x) * (1 + x * (1 - sigmoid(x)))
pub fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

// ---------------------------------------------------------------------------
// RoPE + causal attention
// ---------------------------------------------------------------------------

/// cos/sin angles of one position, written into `[dh/2]` buffers. The
/// single source of the RoPE angle expression: [`rope_tables_for`] calls
/// it per position and `block_fwd_cached` calls it for the one position
/// it decodes, so every path rotates with bit-identical angles — the
/// cache-parity invariant `tests/serve_parity.rs` pins.
pub fn rope_angles_at(pos: usize, dh: usize, rope_base: f64, cos_p: &mut [f32], sin_p: &mut [f32]) {
    let half = dh / 2;
    debug_assert!(cos_p.len() == half && sin_p.len() == half);
    for t in 0..half {
        let inv = 1.0 / (rope_base as f32).powf((2 * t) as f32 / dh as f32);
        let ang = pos as f32 * inv;
        cos_p[t] = ang.cos();
        sin_p[t] = ang.sin();
    }
}

/// (cos, sin) tables for positions `0..s`, each `[s, dh/2]` row-major.
/// Shared by the fixed-shape block ops (via [`rope_tables`]) and the
/// variable-length serving path (`serve::ServeContext`).
pub fn rope_tables_for(s: usize, dh: usize, rope_base: f64) -> (Vec<f32>, Vec<f32>) {
    let half = dh / 2;
    let mut cos = vec![0.0f32; s * half];
    let mut sin = vec![0.0f32; s * half];
    for pos in 0..s {
        rope_angles_at(
            pos,
            dh,
            rope_base,
            &mut cos[pos * half..(pos + 1) * half],
            &mut sin[pos * half..(pos + 1) * half],
        );
    }
    (cos, sin)
}

/// Rotate the `n_heads` heads of one `[n_heads * dh]` activation row with
/// the single-position angle buffers `cos_p`/`sin_p` (`[dh/2]` each),
/// interleaved even/odd pairing (the `q[0::2]/q[1::2] -> stack(-1)`
/// layout of model.py). `inverse` applies the transpose rotation (used by
/// the attention backward). This is the one RoPE rotation in the crate:
/// the `[S, dh]` per-head path (`rope_head`), the serving prefill /
/// decode rows and `block_fwd_cached` all go through it, so their
/// rotations agree bitwise.
pub fn rope_rotate_row(
    row: &mut [f32],
    cos_p: &[f32],
    sin_p: &[f32],
    n_heads: usize,
    dh: usize,
    inverse: bool,
) {
    let half = dh / 2;
    for h in 0..n_heads {
        let base = h * dh;
        for t in 0..half {
            let c = cos_p[t];
            let n = if inverse { -sin_p[t] } else { sin_p[t] };
            let (a, b) = (row[base + 2 * t], row[base + 2 * t + 1]);
            row[base + 2 * t] = a * c - b * n;
            row[base + 2 * t + 1] = a * n + b * c;
        }
    }
}

/// (cos, sin) tables, each `[S, dh/2]` row-major.
pub fn rope_tables(cfg: &ModelConfig) -> (Vec<f32>, Vec<f32>) {
    rope_tables_for(cfg.seq_len, cfg.d_head(), cfg.rope_base)
}

/// Rotate one `[S, dh]` head in place: [`rope_rotate_row`] per position
/// with that position's row of the angle tables.
fn rope_head(q: &mut [f32], cos: &[f32], sin: &[f32], s: usize, dh: usize, inverse: bool) {
    let half = dh / 2;
    for pos in 0..s {
        rope_rotate_row(
            &mut q[pos * dh..(pos + 1) * dh],
            &cos[pos * half..(pos + 1) * half],
            &sin[pos * half..(pos + 1) * half],
            1,
            dh,
            inverse,
        );
    }
}

/// Saved forward state of one attention call (for the backward pass).
pub struct AttnSaved {
    /// roped q, k and raw v in `[B,H,S,dh]` layout
    pub qr: Vec<f32>,
    pub kr: Vec<f32>,
    pub vh: Vec<f32>,
    /// softmax probabilities `[B,H,S,S]`
    pub probs: Vec<f32>,
}

/// `[B,S,D] -> [B,H,S,dh]`
fn split_heads(x: &[f32], b: usize, s: usize, h: usize, dh: usize) -> Vec<f32> {
    let d = h * dh;
    let mut out = vec![0.0f32; x.len()];
    for bi in 0..b {
        for si in 0..s {
            for hi in 0..h {
                let src = bi * s * d + si * d + hi * dh;
                let dst = bi * h * s * dh + hi * s * dh + si * dh;
                out[dst..dst + dh].copy_from_slice(&x[src..src + dh]);
            }
        }
    }
    out
}

/// `[B,H,S,dh] -> [B,S,D]`
fn merge_heads(x: &[f32], b: usize, s: usize, h: usize, dh: usize) -> Vec<f32> {
    let d = h * dh;
    let mut out = vec![0.0f32; x.len()];
    for bi in 0..b {
        for hi in 0..h {
            for si in 0..s {
                let src = bi * h * s * dh + hi * s * dh + si * dh;
                let dst = bi * s * d + si * d + hi * dh;
                out[dst..dst + dh].copy_from_slice(&x[src..src + dh]);
            }
        }
    }
    out
}

/// Causal RoPE attention over `[B,S,D]` activations; returns the merged
/// output and (optionally) the state the backward pass needs.
pub fn attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    cfg: &ModelConfig,
    save: bool,
) -> (Vec<f32>, Option<AttnSaved>) {
    let (b, s, h, dh) = (cfg.batch, cfg.seq_len, cfg.n_heads, cfg.d_head());
    let (cos, sin) = rope_tables(cfg);
    let mut qr = split_heads(q, b, s, h, dh);
    let mut kr = split_heads(k, b, s, h, dh);
    let vh = split_heads(v, b, s, h, dh);
    for head in 0..b * h {
        rope_head(&mut qr[head * s * dh..(head + 1) * s * dh], &cos, &sin, s, dh, false);
        rope_head(&mut kr[head * s * dh..(head + 1) * s * dh], &cos, &sin, s, dh, false);
    }
    let scale = 1.0 / (dh as f32).sqrt();
    let mut probs = vec![0.0f32; b * h * s * s];
    let mut out_h = vec![0.0f32; b * h * s * dh];
    for head in 0..b * h {
        let qh = &qr[head * s * dh..(head + 1) * s * dh];
        let kh = &kr[head * s * dh..(head + 1) * s * dh];
        let vv = &vh[head * s * dh..(head + 1) * s * dh];
        let ph = &mut probs[head * s * s..(head + 1) * s * s];
        let oh = &mut out_h[head * s * dh..(head + 1) * s * dh];
        for qi in 0..s {
            // causal row: keys 0..=qi
            let row = &mut ph[qi * s..(qi + 1) * s];
            attn::dots(&qh[qi * dh..(qi + 1) * dh], kh, dh, 0, qi + 1, row);
            let mut mx = f32::NEG_INFINITY;
            for item in row.iter_mut().take(qi + 1) {
                *item *= scale;
                mx = mx.max(*item);
            }
            let mut z = 0.0f32;
            for item in row.iter_mut().take(qi + 1) {
                *item = (*item - mx).exp();
                z += *item;
            }
            for item in row.iter_mut().take(qi + 1) {
                *item /= z;
            }
            // masked tail stays exactly 0.0
            for item in row.iter_mut().skip(qi + 1) {
                *item = 0.0;
            }
            let orow = &mut oh[qi * dh..(qi + 1) * dh];
            attn::wsum(orow, &row[..qi + 1], vv, dh, 0);
        }
    }
    let y = merge_heads(&out_h, b, s, h, dh);
    let saved = save.then_some(AttnSaved { qr, kr, vh, probs });
    (y, saved)
}

/// Backward of [`attention`]: returns (gq, gk, gv) in `[B,S,D]` layout.
pub fn attention_bwd(saved: &AttnSaved, gy: &[f32], cfg: &ModelConfig) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (b, s, h, dh) = (cfg.batch, cfg.seq_len, cfg.n_heads, cfg.d_head());
    let (cos, sin) = rope_tables(cfg);
    let scale = 1.0 / (dh as f32).sqrt();
    let go = split_heads(gy, b, s, h, dh);
    let mut gqr = vec![0.0f32; b * h * s * dh];
    let mut gkr = vec![0.0f32; b * h * s * dh];
    let mut gvh = vec![0.0f32; b * h * s * dh];
    let mut ga = vec![0.0f32; s]; // one attention row at a time
    for head in 0..b * h {
        let qh = &saved.qr[head * s * dh..(head + 1) * s * dh];
        let kh = &saved.kr[head * s * dh..(head + 1) * s * dh];
        let vv = &saved.vh[head * s * dh..(head + 1) * s * dh];
        let ph = &saved.probs[head * s * s..(head + 1) * s * s];
        let goh = &go[head * s * dh..(head + 1) * s * dh];
        let gq = &mut gqr[head * s * dh..(head + 1) * s * dh];
        let gk = &mut gkr[head * s * dh..(head + 1) * s * dh];
        let gv = &mut gvh[head * s * dh..(head + 1) * s * dh];
        for qi in 0..s {
            let prow = &ph[qi * s..(qi + 1) * s];
            let grow = &goh[qi * dh..(qi + 1) * dh];
            // gp[ki] = go . v_ki ; softmax bwd: ga = p * (gp - sum(gp*p))
            let mut dot_sum = 0.0f32;
            for ki in 0..=qi {
                let mut gp = 0.0f32;
                for t in 0..dh {
                    gp += grow[t] * vv[ki * dh + t];
                }
                ga[ki] = gp;
                dot_sum += gp * prow[ki];
            }
            for ki in 0..=qi {
                ga[ki] = prow[ki] * (ga[ki] - dot_sum);
                // gv += p * go
                let p = prow[ki];
                if p != 0.0 {
                    for t in 0..dh {
                        gv[ki * dh + t] += p * grow[t];
                    }
                }
                // gq_row += ga * k_ki * scale ; gk_ki += ga * q_row * scale
                let a = ga[ki] * scale;
                if a != 0.0 {
                    for t in 0..dh {
                        gq[qi * dh + t] += a * kh[ki * dh + t];
                        gk[ki * dh + t] += a * qh[qi * dh + t];
                    }
                }
            }
        }
        // inverse rotation (transpose of the RoPE rotation)
        rope_head(&mut gq[..], &cos, &sin, s, dh, true);
        rope_head(&mut gk[..], &cos, &sin, s, dh, true);
    }
    (
        merge_heads(&gqr, b, s, h, dh),
        merge_heads(&gkr, b, s, h, dh),
        merge_heads(&gvh, b, s, h, dh),
    )
}

/// Attention of one new roped query row over `len` cached positions plus
/// the new key/value at logical position `len` — the KV-cached decode
/// step. All row args are `[d]` with heads side by side in the feature
/// dim; the caches are `[len, d]` row-major. Returns `[d]`.
///
/// The one cached-attention kernel in the crate: the serving decode path
/// (`serve::engine::decode_step`) and the `block_fwd_cached` runtime op
/// both call it. Per head it scans keys `0..=len` in ascending position
/// order with the same max-subtracted softmax and accumulation order as
/// [`attention`], so incremental decode reproduces a full-prefix
/// recompute bitwise (`tests/serve_parity.rs` pins this).
pub fn attention_cached_row(
    q: &[f32],
    k_new: &[f32],
    v_new: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    len: usize,
    n_heads: usize,
    dh: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; n_heads * dh];
    let mut row = Vec::new();
    attention_cached_row_into(
        q, k_new, v_new, k_cache, v_cache, len, n_heads, dh, &mut row, &mut out,
    );
    out
}

/// Allocation-free body of [`attention_cached_row`]: writes the `[d]`
/// attention output into `out` (overwritten, not accumulated) and uses
/// `row` as the reusable softmax scratch (resized to `len + 1`). The
/// decode hot loops (`serve::engine::decode_step`, `block_fwd_cached`)
/// call this directly with per-request scratch so the per-token
/// temporaries of the old path disappear.
#[allow(clippy::too_many_arguments)]
pub fn attention_cached_row_into(
    q: &[f32],
    k_new: &[f32],
    v_new: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    len: usize,
    n_heads: usize,
    dh: usize,
    row: &mut Vec<f32>,
    out: &mut [f32],
) {
    let seg = attn::KvSegment { k: k_cache, v: v_cache, rows: len };
    attention_cached_row_gather_into(q, k_new, v_new, |_| seg, 1, len, n_heads, dh, row, out);
}

/// [`attention_cached_row_into`] reading the cached positions through a
/// page-gather view: `segs(0..n_segs)` yields contiguous runs covering
/// positions `0..len` in ascending order (a paged `serve::paged::Kv`
/// exposes one run per page). The softmax body is position-blind and the
/// gather kernels ([`attn::dots_gather`], [`attn::wsum_gather`]) keep the
/// per-position accumulation order of their contiguous forms, so paging
/// the cache cannot change a single bit of the output — the paged ==
/// contiguous parity `tests/serve_parity.rs` pins.
#[allow(clippy::too_many_arguments)]
pub fn attention_cached_row_gather_into<'a>(
    q: &[f32],
    k_new: &[f32],
    v_new: &[f32],
    segs: impl Fn(usize) -> attn::KvSegment<'a>,
    n_segs: usize,
    len: usize,
    n_heads: usize,
    dh: usize,
    row: &mut Vec<f32>,
    out: &mut [f32],
) {
    let d = n_heads * dh;
    debug_assert_eq!(out.len(), d);
    let scale = 1.0 / (dh as f32).sqrt();
    out.fill(0.0);
    row.clear();
    row.resize(len + 1, 0.0);
    for h in 0..n_heads {
        let off = h * dh;
        let qh = &q[off..off + dh];
        // score row: cached keys 0..len at stride d, then the new key
        attn::dots_gather(qh, &segs, n_segs, d, off, row);
        row[len] = attn::dot1(qh, &k_new[off..off + dh]);
        let mut mx = f32::NEG_INFINITY;
        for item in row.iter_mut() {
            *item *= scale;
            mx = mx.max(*item);
        }
        let mut z = 0.0f32;
        for item in row.iter_mut() {
            *item = (*item - mx).exp();
            z += *item;
        }
        for item in row.iter_mut() {
            *item /= z;
        }
        let oh = &mut out[off..off + dh];
        attn::wsum_gather(oh, &row[..len], &segs, n_segs, d, off);
        attn::axpy(oh, row[len], &v_new[off..off + dh]);
    }
}

// ---------------------------------------------------------------------------
// small reductions
// ---------------------------------------------------------------------------

/// `sum((a - b)^2)` in f64.
pub fn sq_diff_sum(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum()
}

/// `sum(x^2)` in f64.
pub fn sq_sum(x: &[f32]) -> f64 {
    x.iter().map(|v| (*v as f64) * (*v as f64)).sum()
}
