//! Native implementation of the BESA training-step ops: `besa_step_row`,
//! `besa_step_layer`, `besa_step_attnmlp`, `besa_step_row_d<N>`,
//! `besa_quant_step_row`, `two_block_step`, plus the standalone
//! `mask_decode_*` / `quant_apply_*` helpers.
//!
//! Mirrors `python/compile/besa.py` + `kernels/{besa_mask,fake_quant}.py`:
//! theta -> softmax beta (beta_D pinned to 0) -> exclusive-cumsum keep
//! probabilities -> hard STE mask -> masked block forward -> blockwise
//! reconstruction + per-group sparsity penalty -> gradients w.r.t. theta
//! (and gamma). The straight-through backward routes mask cotangents into
//! cumbeta buckets (Eqn. 6); alpha only receives gradient through the
//! differentiable sparsity penalty.

use anyhow::{bail, Result};

use crate::model::config::{ModelConfig, LAYER_NAMES};
use crate::tensor::Tensor;

use super::{block, ops};

/// Which layers share one sparsity constraint (paper Table 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Grouping {
    Block,
    AttnMlp,
}

const ATTN: [usize; 4] = [0, 1, 2, 3]; // wq wk wv wo
const MLP: [usize; 3] = [4, 5, 6]; // wg wu wd

// ---------------------------------------------------------------------------
// theta chain: softmax -> (beta, cumbeta, alpha)
// ---------------------------------------------------------------------------

/// Per-layer theta state after the forward chain. `rows` is the broadcast
/// row count R (theta itself may have 1 row, layer-wise).
pub struct ThetaChain {
    pub theta_rows: usize,
    pub rows: usize,
    pub n_rates: usize,
    /// softmax(theta) with beta_D = 0 appended — `[theta_rows, D]`
    pub beta: Vec<f64>,
    /// exclusive cumsum of beta — `[theta_rows, D]`
    pub cumb: Vec<f64>,
    /// per-row expected sparsity `sum_d beta_d p_d` — `[theta_rows]`
    pub alpha: Vec<f64>,
}

impl ThetaChain {
    pub fn cumb_row(&self, r: usize) -> &[f64] {
        let tr = if self.theta_rows == 1 { 0 } else { r };
        &self.cumb[tr * self.n_rates..(tr + 1) * self.n_rates]
    }

    pub fn alpha_row(&self, r: usize) -> f64 {
        self.alpha[if self.theta_rows == 1 { 0 } else { r }]
    }

    /// Sum of alpha over the broadcast rows.
    pub fn alpha_sum(&self) -> f64 {
        if self.theta_rows == 1 {
            self.alpha[0] * self.rows as f64
        } else {
            self.alpha.iter().sum()
        }
    }
}

/// Forward the theta chain (f64 internally, mirroring decode_mask).
pub fn theta_chain(theta: &Tensor, rows: usize, n_rates: usize) -> ThetaChain {
    let theta_rows = theta.shape[0];
    let dm1 = theta.shape[1];
    debug_assert_eq!(dm1 + 1, n_rates);
    let mut beta = vec![0.0f64; theta_rows * n_rates];
    let mut cumb = vec![0.0f64; theta_rows * n_rates];
    let mut alpha = vec![0.0f64; theta_rows];
    for r in 0..theta_rows {
        let logits = &theta.f32s()[r * dm1..(r + 1) * dm1];
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let brow = &mut beta[r * n_rates..(r + 1) * n_rates];
        let mut z = 0.0f64;
        for (d, l) in logits.iter().enumerate() {
            brow[d] = ((*l as f64) - mx).exp();
            z += brow[d];
        }
        for b in brow[..dm1].iter_mut() {
            *b /= z;
        }
        brow[n_rates - 1] = 0.0;
        let crow = &mut cumb[r * n_rates..(r + 1) * n_rates];
        crow[0] = 0.0;
        for d in 1..n_rates {
            crow[d] = crow[d - 1] + beta[r * n_rates + d - 1];
        }
        let mut a = 0.0f64;
        for (d, b) in beta[r * n_rates..(r + 1) * n_rates].iter().enumerate() {
            a += b * (d + 1) as f64 / n_rates as f64;
        }
        alpha[r] = a;
    }
    ThetaChain { theta_rows, rows, n_rates, beta, cumb, alpha }
}

/// Backward of the theta chain: given cotangents for cumb (`[rows, D]`)
/// and alpha (`[rows]`), return dtheta (`[theta_rows, D-1]`).
///
/// gbeta_d = sum_{k > d} gcumb_k + galpha * p_d, then softmax backward
/// over the first D-1 entries (beta_D is the pinned zero). Broadcast
/// (theta_rows == 1) sums the per-row gradients first.
pub fn theta_chain_bwd(
    tc: &ThetaChain,
    gcumb: &[f64],
    galpha: &[f64],
) -> Vec<f32> {
    let (rows, nr) = (tc.rows, tc.n_rates);
    debug_assert_eq!(gcumb.len(), rows * nr);
    debug_assert_eq!(galpha.len(), rows);
    // accumulate gbeta per broadcast-source row
    let mut gbeta = vec![0.0f64; tc.theta_rows * nr];
    for r in 0..rows {
        let tr = if tc.theta_rows == 1 { 0 } else { r };
        let gc = &gcumb[r * nr..(r + 1) * nr];
        let gb = &mut gbeta[tr * nr..(tr + 1) * nr];
        // suffix sums: gbeta[d] += sum_{k>d} gc[k]
        let mut suf = 0.0f64;
        for d in (0..nr).rev() {
            gb[d] += suf + galpha[r] * (d + 1) as f64 / nr as f64;
            suf += gc[d];
        }
    }
    // softmax backward per theta row over the first D-1 entries
    let dm1 = nr - 1;
    let mut dtheta = vec![0.0f32; tc.theta_rows * dm1];
    for r in 0..tc.theta_rows {
        let b = &tc.beta[r * nr..r * nr + dm1];
        let gb = &gbeta[r * nr..r * nr + dm1];
        let dot: f64 = b.iter().zip(gb).map(|(x, y)| x * y).sum();
        for d in 0..dm1 {
            dtheta[r * dm1 + d] = (b[d] * (gb[d] - dot)) as f32;
        }
    }
    dtheta
}

// ---------------------------------------------------------------------------
// STE mask
// ---------------------------------------------------------------------------

/// Bucket index k(r) = min(floor(rank * D / C), D-1).
#[inline]
pub fn bucket(rank: i32, cols: usize, n_rates: usize) -> usize {
    ((rank as usize * n_rates) / cols).min(n_rates - 1)
}

/// Hard mask + per-row alpha from a theta chain and ranks (`[R, C]` i32).
/// mask_ij = 1 iff (1 - cumb[k(rank_ij)]) < alpha_i.
pub fn hard_mask(tc: &ThetaChain, ranks: &Tensor) -> Vec<f32> {
    let (r, c) = (ranks.shape[0], ranks.shape[1]);
    debug_assert_eq!(r, tc.rows);
    let mut mask = vec![0.0f32; r * c];
    for i in 0..r {
        let crow = tc.cumb_row(i);
        let alpha = tc.alpha_row(i);
        for j in 0..c {
            let k = bucket(ranks.i32s()[i * c + j], c, tc.n_rates);
            let prune_prob = 1.0 - crow[k];
            mask[i * c + j] = if prune_prob < alpha { 1.0 } else { 0.0 };
        }
    }
    mask
}

/// STE backward: route the mask cotangent into cumbeta buckets.
/// gcumb[i, d] = sum_j gmask[i, j] * 1[k(rank_ij) == d]
pub fn mask_bwd_to_cumb(ranks: &Tensor, gmask: &[f32], n_rates: usize) -> Vec<f64> {
    let (r, c) = (ranks.shape[0], ranks.shape[1]);
    let mut gcumb = vec![0.0f64; r * n_rates];
    for i in 0..r {
        let row = &mut gcumb[i * n_rates..(i + 1) * n_rates];
        for j in 0..c {
            let k = bucket(ranks.i32s()[i * c + j], c, n_rates);
            row[k] += gmask[i * c + j] as f64;
        }
    }
    gcumb
}

// ---------------------------------------------------------------------------
// fake quantization (Eqn. 7) + clipping-strength gradients
// ---------------------------------------------------------------------------

/// Forward min-max fake quantization — identical to `quant::fake_quant`
/// and `kernels/ref.py::fake_quant_ref` with bits=4 by default.
pub fn fake_quant_fwd(w: &[f32], gamma0: f32, gamma1: f32, bits: u32) -> Vec<f32> {
    let qmax = (2f64.powi(bits as i32) - 1.0) as f32;
    let mw = w.iter().cloned().fold(f32::INFINITY, f32::min);
    let mxw = w.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let wmin = gamma0 * mw;
    let wmax = gamma1 * mxw;
    let h = ((wmax - wmin) / qmax).max(1e-8);
    let z = (-wmin / h).round();
    w.iter()
        .map(|v| {
            let q = ((v / h).round() + z).clamp(0.0, qmax);
            (q - z) * h
        })
        .collect()
}

/// d(STE surrogate)/d(gamma0, gamma1): the round ops are treated as
/// identity, matching `kernels/fake_quant.py::_soft_fake_quant`'s vjp.
pub fn fake_quant_gamma_bwd(
    w: &[f32],
    gamma0: f32,
    gamma1: f32,
    gout: &[f32],
    bits: u32,
) -> (f32, f32) {
    let qmax = 2f64.powi(bits as i32) - 1.0;
    let mw = w.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let mxw = w.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let a0 = gamma0 as f64 * mw;
    let a1 = gamma1 as f64 * mxw;
    let raw_h = (a1 - a0) / qmax;
    let floored = raw_h <= 1e-8;
    let h = raw_h.max(1e-8);
    let z = -a0 / h;
    let (dh0, dh1) = if floored { (0.0, 0.0) } else { (-1.0 / qmax, 1.0 / qmax) };
    let dz0 = -1.0 / h + a0 / (h * h) * dh0;
    let dz1 = a0 / (h * h) * dh1;
    let (mut da0, mut da1) = (0.0f64, 0.0f64);
    for (v, g) in w.iter().zip(gout) {
        let wv = *v as f64;
        let gv = *g as f64;
        let u = wv / h + z;
        let inside = (0.0..=qmax).contains(&u);
        let c = u.clamp(0.0, qmax);
        for (dh, dz, acc) in [(dh0, dz0, &mut da0), (dh1, dz1, &mut da1)] {
            let du = -wv / (h * h) * dh + dz;
            let dc = if inside { du } else { 0.0 };
            let dout = (dc - dz) * h + (c - z) * dh;
            *acc += gv * dout;
        }
    }
    ((da0 * mw) as f32, (da1 * mxw) as f32)
}

// ---------------------------------------------------------------------------
// besa_step / two_block_step drivers
// ---------------------------------------------------------------------------

struct LayerCtx {
    chain: ThetaChain,
    mask: Vec<f32>,
    cols: usize,
    rows: usize,
}

fn layer_contexts(
    cfg: &ModelConfig,
    thetas: &[&Tensor],
    ranks: &[&Tensor],
    n_rates: usize,
) -> Vec<LayerCtx> {
    LAYER_NAMES
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let [r, c] = cfg.layer_shape(w);
            let chain = theta_chain(thetas[i], r, n_rates);
            let mask = hard_mask(&chain, ranks[i]);
            LayerCtx { chain, mask, cols: c, rows: r }
        })
        .collect()
}

/// One `besa_step` execution: returns
/// `[loss, recon, mean_alpha, dtheta x7, (dgamma x7)]`.
#[allow(clippy::too_many_arguments)]
pub fn besa_step(
    cfg: &ModelConfig,
    inputs: &[&Tensor],
    n_rates: usize,
    grouping: Grouping,
    quant: bool,
) -> Result<Vec<Tensor>> {
    // positional layout (aot.py besa_inputs): theta7, x, y, w7, norms2,
    // rank7, lam, alpha_hat, [gamma7]
    let thetas = &inputs[0..7];
    let x = inputs[7];
    let y_dense = inputs[8];
    let weights = &inputs[9..16];
    let norms = [inputs[16].f32s().to_vec(), inputs[17].f32s().to_vec()];
    let ranks = &inputs[18..25];
    let lam = inputs[25].scalar_value() as f64;
    let alpha_hat = inputs[26].scalar_value() as f64;
    let gammas: Option<&[&Tensor]> = if quant { Some(&inputs[27..34]) } else { None };

    let layers = layer_contexts(cfg, thetas, ranks, n_rates);

    // effective weights: (fake-quantized) W ∘ hard mask
    let qweights: Vec<Vec<f32>> = LAYER_NAMES
        .iter()
        .enumerate()
        .map(|(i, _)| match gammas {
            Some(gm) => fake_quant_fwd(
                weights[i].f32s(),
                gm[i].f32s()[0],
                gm[i].f32s()[1],
                4,
            ),
            None => weights[i].f32s().to_vec(),
        })
        .collect();
    let mut eff: [Vec<f32>; 7] = Default::default();
    for i in 0..7 {
        eff[i] = ops::hadamard(&qweights[i], &layers[i].mask);
    }

    let (y, saved, _) = block::forward(cfg, x.f32s(), eff, norms, true, false);
    let saved = saved.unwrap(); // besa-lint: allow(hot-path-panic) — save=true always returns Some

    // recon = sum((y - y_dense)^2) / max(sum(y_dense^2), 1e-9)
    let denom = ops::sq_sum(y_dense.f32s()).max(1e-9);
    let recon = ops::sq_diff_sum(&y, y_dense.f32s()) / denom;

    // sparsity penalty per group + mean alpha
    let group_term = |idx: &[usize]| -> (f64, f64, f64) {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for &i in idx {
            num += layers[i].chain.alpha_sum() * layers[i].cols as f64;
            den += (layers[i].rows * layers[i].cols) as f64;
        }
        (num / den - alpha_hat, num, den)
    };
    let groups: Vec<Vec<usize>> = match grouping {
        Grouping::Block => vec![(0..7).collect()],
        Grouping::AttnMlp => vec![ATTN.to_vec(), MLP.to_vec()],
    };
    let mut sparse = 0.0f64;
    for g in &groups {
        let (dev, _, _) = group_term(g);
        sparse += dev * dev;
    }
    let (_, mean_num, mean_den) = group_term(&(0..7).collect::<Vec<_>>());
    let mean_alpha = mean_num / mean_den;
    let loss = recon + lam * sparse;

    // ---- backward -------------------------------------------------------
    // d recon / d y
    let gy: Vec<f32> = y
        .iter()
        .zip(y_dense.f32s())
        .map(|(a, b)| ((2.0 * ((*a as f64) - (*b as f64))) / denom) as f32)
        .collect();
    let grads = block::backward(cfg, &saved, &gy);

    // per-group alpha cotangent coefficient: 2 lam (ag - alpha_hat) / den_g
    let mut galpha_coef = [0.0f64; 7];
    for g in &groups {
        let (dev, _, den) = group_term(g);
        for &i in g {
            galpha_coef[i] = 2.0 * lam * dev * layers[i].cols as f64 / den;
        }
    }

    let mut out = vec![
        Tensor::scalar(loss as f32),
        Tensor::scalar(recon as f32),
        Tensor::scalar(mean_alpha as f32),
    ];
    let mut dgammas: Vec<Tensor> = Vec::new();
    for i in 0..7 {
        let lc = &layers[i];
        // dL/dmask = gw_eff ∘ (quantized) W ; STE -> cumbeta buckets
        let gmask = ops::hadamard(&grads.gw_eff[i], &qweights[i]);
        let gcumb = mask_bwd_to_cumb(ranks[i], &gmask, n_rates);
        let galpha = vec![galpha_coef[i]; lc.rows];
        let dtheta = theta_chain_bwd(&lc.chain, &gcumb, &galpha);
        out.push(Tensor::from_f32(&[lc.chain.theta_rows, n_rates - 1], dtheta));
        if let Some(gm) = gammas {
            // dL/d(quantized W) = gw_eff ∘ mask, then through fake_quant
            let gqw = ops::hadamard(&grads.gw_eff[i], &lc.mask);
            let (d0, d1) = fake_quant_gamma_bwd(
                weights[i].f32s(),
                gm[i].f32s()[0],
                gm[i].f32s()[1],
                &gqw,
                4,
            );
            dgammas.push(Tensor::from_f32(&[2], vec![d0, d1]));
        }
    }
    out.extend(dgammas);
    Ok(out)
}

/// `two_block_step`: two chained blocks, one sparsity constraint over all
/// 14 layers. Returns `[loss, recon, mean_alpha, b0_dtheta x7, b1_dtheta x7]`.
pub fn two_block_step(cfg: &ModelConfig, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let nr = cfg.n_rates;
    // layout: b0_theta7, b1_theta7, x, y, b0_w7, b1_w7, b0_norms2,
    // b1_norms2, b0_rank7, b1_rank7, lam, alpha_hat
    let thetas = [&inputs[0..7], &inputs[7..14]];
    let x = inputs[14];
    let y_dense = inputs[15];
    let weights = [&inputs[16..23], &inputs[23..30]];
    let norms = [&inputs[30..32], &inputs[32..34]];
    let ranks = [&inputs[34..41], &inputs[41..48]];
    let lam = inputs[48].scalar_value() as f64;
    let alpha_hat = inputs[49].scalar_value() as f64;

    let mut layer_ctx: Vec<Vec<LayerCtx>> = Vec::with_capacity(2);
    let mut saves = Vec::with_capacity(2);
    let mut cur = x.f32s().to_vec();
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for b in 0..2 {
        let layers = layer_contexts(cfg, thetas[b], ranks[b], nr);
        let mut eff: [Vec<f32>; 7] = Default::default();
        for i in 0..7 {
            eff[i] = ops::hadamard(weights[b][i].f32s(), &layers[i].mask);
            num += layers[i].chain.alpha_sum() * layers[i].cols as f64;
            den += (layers[i].rows * layers[i].cols) as f64;
        }
        let nb = [norms[b][0].f32s().to_vec(), norms[b][1].f32s().to_vec()];
        let (y, sv, _) = block::forward(cfg, &cur, eff, nb, true, false);
        cur = y;
        saves.push(sv.unwrap()); // besa-lint: allow(hot-path-panic) — save=true always returns Some
        layer_ctx.push(layers);
    }
    let denom = ops::sq_sum(y_dense.f32s()).max(1e-9);
    let recon = ops::sq_diff_sum(&cur, y_dense.f32s()) / denom;
    let mean_alpha = num / den;
    let loss = recon + lam * (mean_alpha - alpha_hat) * (mean_alpha - alpha_hat);

    // backward through both blocks
    let mut gy: Vec<f32> = cur
        .iter()
        .zip(y_dense.f32s())
        .map(|(a, b)| ((2.0 * ((*a as f64) - (*b as f64))) / denom) as f32)
        .collect();
    let galpha_scale = 2.0 * lam * (mean_alpha - alpha_hat) / den;
    let mut dthetas: [Vec<Tensor>; 2] = Default::default();
    for b in (0..2).rev() {
        let grads = block::backward(cfg, &saves[b], &gy);
        for i in 0..7 {
            let lc = &layer_ctx[b][i];
            let gmask = ops::hadamard(&grads.gw_eff[i], weights[b][i].f32s());
            let gcumb = mask_bwd_to_cumb(ranks[b][i], &gmask, nr);
            let galpha = vec![galpha_scale * lc.cols as f64; lc.rows];
            let dtheta = theta_chain_bwd(&lc.chain, &gcumb, &galpha);
            dthetas[b].push(Tensor::from_f32(&[lc.rows, nr - 1], dtheta));
        }
        gy = grads.gx;
    }

    let mut out = vec![
        Tensor::scalar(loss as f32),
        Tensor::scalar(recon as f32),
        Tensor::scalar(mean_alpha as f32),
    ];
    let [d0, d1] = dthetas;
    out.extend(d0);
    out.extend(d1);
    Ok(out)
}

/// `mask_decode_<RxC>`: (theta, rank) -> (hard mask, per-row alpha).
pub fn mask_decode(cfg: &ModelConfig, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let theta = inputs[0];
    let ranks = inputs[1];
    let (r, c) = (ranks.shape[0], ranks.shape[1]);
    let chain = theta_chain(theta, r, cfg.n_rates);
    let mask = hard_mask(&chain, ranks);
    let alpha: Vec<f32> = (0..r).map(|i| chain.alpha_row(i) as f32).collect();
    Ok(vec![Tensor::from_f32(&[r, c], mask), Tensor::from_f32(&[r], alpha)])
}

/// `quant_apply_<RxC>`: (w, gamma[2]) -> 4-bit fake-quantized w.
pub fn quant_apply(inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let w = inputs[0];
    let gamma = inputs[1].f32s();
    if gamma.len() != 2 {
        bail!("quant_apply expects gamma of shape [2]");
    }
    let q = fake_quant_fwd(w.f32s(), gamma[0], gamma[1], 4);
    Ok(vec![Tensor::from_f32(&w.shape, q)])
}
