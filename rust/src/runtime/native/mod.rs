//! The pure-rust native execution backend: interprets the full artifact op
//! set (`embed`, `block_fwd*`, `block_capture`, `besa_step*`,
//! `two_block_step`, `lm_train_step`, `head_nll`, `mask_decode_*`,
//! `quant_apply_*`) directly on the [`crate::tensor`] substrate, with
//! specs synthesized from the built-in config table — no `manifest.json`,
//! no HLO artifacts, no XLA shared library.
//!
//! The backend is stateless apart from cumulative timing metrics, hence
//! `Sync`: the coordinator shares one instance across scoped threads for
//! batch-parallel minibatch dispatch.

pub mod besa;
pub mod block;
pub mod ops;
pub mod train;

use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::model::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::Stopwatch;

use super::engine::Backend;
use super::Manifest;

pub struct NativeBackend {
    manifest: Manifest,
    /// cumulative (execute_secs, execute_calls)
    stats: Mutex<(f64, u64)>,
}

impl NativeBackend {
    /// Build from an in-memory config (specs are synthesized).
    pub fn new(cfg: ModelConfig) -> NativeBackend {
        NativeBackend { manifest: Manifest::synthesize(cfg), stats: Mutex::new((0.0, 0)) }
    }

    /// Resolve `config` by name: the built-in table first; if unknown,
    /// fall back to reading the config section of an artifact manifest
    /// under `artifacts_root` (custom configs lowered by aot.py).
    pub fn for_config(artifacts_root: &Path, config: &str) -> Result<NativeBackend> {
        let cfg = match ModelConfig::builtin(config) {
            Ok(c) => c,
            Err(builtin_err) => match Manifest::load(artifacts_root, config) {
                Ok(m) => m.config,
                Err(_) => return Err(builtin_err),
            },
        };
        Ok(NativeBackend::new(cfg))
    }

    fn dispatch(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let cfg = &self.manifest.config;
        match name {
            "embed" => train::embed(cfg, inputs),
            "head_nll" => train::head_nll(cfg, inputs),
            "block_fwd" => block::run_block_op(cfg, inputs, false, false),
            "block_fwd_masked" => block::run_block_op(cfg, inputs, true, false),
            "block_fwd_cached" => block::block_fwd_cached(cfg, inputs),
            "block_capture" => block::run_block_op(cfg, inputs, false, true),
            "lm_train_step" => train::lm_train_step(cfg, inputs),
            "two_block_step" => besa::two_block_step(cfg, inputs),
            "besa_step_row" => {
                besa::besa_step(cfg, inputs, cfg.n_rates, besa::Grouping::Block, false)
            }
            "besa_step_layer" => {
                besa::besa_step(cfg, inputs, cfg.n_rates, besa::Grouping::Block, false)
            }
            "besa_step_attnmlp" => {
                besa::besa_step(cfg, inputs, cfg.n_rates, besa::Grouping::AttnMlp, false)
            }
            "besa_quant_step_row" => {
                besa::besa_step(cfg, inputs, cfg.n_rates, besa::Grouping::Block, true)
            }
            other => {
                if let Some(dstr) = other.strip_prefix("besa_step_row_d") {
                    let d: usize = dstr
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad rate-count suffix in '{other}'"))?;
                    return besa::besa_step(cfg, inputs, d, besa::Grouping::Block, false);
                }
                if other.starts_with("mask_decode_") {
                    return besa::mask_decode(cfg, inputs);
                }
                if other.starts_with("quant_apply_") {
                    return besa::quant_apply(inputs);
                }
                bail!("native backend: unimplemented artifact '{other}'")
            }
        }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let sw = Stopwatch::start();
        let out = self.dispatch(name, inputs)?;
        let mut st = crate::util::par::locked(&self.stats);
        st.0 += sw.secs();
        st.1 += 1;
        Ok(out)
    }

    fn stats(&self) -> (f64, f64, u64) {
        let st = crate::util::par::locked(&self.stats);
        (0.0, st.0, st.1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<NativeBackend>();
    }

    #[test]
    fn resolves_builtin_configs() {
        let b = NativeBackend::for_config(Path::new("artifacts"), "test").unwrap();
        assert_eq!(b.manifest().config.name, "test");
        assert!(NativeBackend::for_config(Path::new("artifacts"), "zz").is_err());
    }
}
