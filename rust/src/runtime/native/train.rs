//! Native whole-model ops: `embed`, `head_nll` and the full pretraining
//! step `lm_train_step` (forward through every block + tied-embedding
//! head, analytic backward over all parameters).
//!
//! Mirrors `python/compile/model.py::{embed, head_nll, lm_train_step}`:
//! the head is tied to the embedding (`logits = rmsnorm(x) @ emb.T`), the
//! target is `roll(tokens, -1)` with the last position zeroed, and the
//! loss is `sum(nll) / count_nonzero(nll)`.

use anyhow::Result;

use crate::model::config::{ModelConfig, LAYER_NAMES};
use crate::tensor::Tensor;

use super::{block, ops};

/// `embed`: gather rows of the embedding table. tokens `[B,S]` i32,
/// emb `[V,D]` -> x `[B,S,D]`.
pub fn embed(cfg: &ModelConfig, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let tokens = inputs[0].i32s();
    let emb = inputs[1].f32s();
    let d = cfg.d_model;
    let mut x = vec![0.0f32; tokens.len() * d];
    for (i, t) in tokens.iter().enumerate() {
        let t = (*t).clamp(0, cfg.vocab as i32 - 1) as usize;
        x[i * d..(i + 1) * d].copy_from_slice(&emb[t * d..(t + 1) * d]);
    }
    Ok(vec![Tensor::from_f32(&[cfg.batch, cfg.seq_len, cfg.d_model], x)])
}

/// Per-position NLL with the tied head. Returns (nll `[B*S]`, and when
/// `save_bwd` the log-probs `[B*S, V]` + normalized h `[B*S, D]` needed
/// by the backward pass).
struct HeadFwd {
    nll: Vec<f32>,
    /// log softmax of logits, `[B*S, V]` (only when saving)
    logp: Option<Vec<f32>>,
    /// rmsnorm(x, norm_f), `[B*S, D]` (only when saving)
    h: Option<Vec<f32>>,
    /// rolled targets per position, `[B*S]`
    tgt: Vec<usize>,
}

fn head_forward(
    cfg: &ModelConfig,
    x: &[f32],
    norm_f: &[f32],
    emb: &[f32],
    tokens: &[i32],
    save: bool,
) -> HeadFwd {
    let (b, s, d, v) = (cfg.batch, cfg.seq_len, cfg.d_model, cfg.vocab);
    let n = b * s;
    let h = ops::rmsnorm(x, norm_f, d, cfg.norm_eps);
    let logits = ops::mm_nt(&h, emb, n, d, v);
    let mut logp = vec![0.0f32; n * v];
    let mut nll = vec![0.0f32; n];
    let mut tgt = vec![0usize; n];
    for bi in 0..b {
        for si in 0..s {
            let i = bi * s + si;
            // tgt = roll(tokens, -1, axis=1)
            let tj = if si + 1 < s { si + 1 } else { 0 };
            let t = tokens[bi * s + tj].clamp(0, v as i32 - 1) as usize;
            tgt[i] = t;
            let row = &logits[i * v..(i + 1) * v];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = row.iter().map(|l| (l - mx).exp()).sum();
            let lse = mx + z.ln();
            let lrow = &mut logp[i * v..(i + 1) * v];
            for (o, l) in lrow.iter_mut().zip(row) {
                *o = l - lse;
            }
            // last position zeroed (no next token inside the window)
            nll[i] = if si + 1 < s { -lrow[t] } else { 0.0 };
        }
    }
    HeadFwd {
        nll,
        logp: save.then_some(logp),
        h: save.then_some(h),
        tgt,
    }
}

/// `head_nll` artifact: x, norm_f, emb, tokens -> nll `[B,S]`.
pub fn head_nll(cfg: &ModelConfig, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let hf = head_forward(
        cfg,
        inputs[0].f32s(),
        inputs[1].f32s(),
        inputs[2].f32s(),
        inputs[3].i32s(),
        false,
    );
    Ok(vec![Tensor::from_f32(&[cfg.batch, cfg.seq_len], hf.nll)])
}

/// `lm_train_step`: params in canonical order + tokens -> loss + gradient
/// per parameter (same order).
pub fn lm_train_step(cfg: &ModelConfig, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let (b, s, d, v) = (cfg.batch, cfg.seq_len, cfg.d_model, cfg.vocab);
    let n = b * s;
    let n_params = cfg.param_order.len();
    let tokens = inputs[n_params].i32s();
    // index params by name position: embed = 0, per block 7 weights + 2
    // norms, norm_f last (canonical_param_order layout)
    let emb = inputs[0].f32s();
    let norm_f = inputs[n_params - 1].f32s();
    let block_param = |l: usize, j: usize| inputs[1 + l * 9 + j];

    // ---- forward ---------------------------------------------------------
    let mut x = vec![0.0f32; n * d];
    for (i, t) in tokens.iter().enumerate() {
        let t = (*t).clamp(0, v as i32 - 1) as usize;
        x[i * d..(i + 1) * d].copy_from_slice(&emb[t * d..(t + 1) * d]);
    }
    let mut saves = Vec::with_capacity(cfg.n_blocks);
    for l in 0..cfg.n_blocks {
        let weights: Vec<&Tensor> = (0..7).map(|j| block_param(l, j)).collect();
        let eff = block::effective_weights(&weights, None);
        let norms = [
            block_param(l, 7).f32s().to_vec(),
            block_param(l, 8).f32s().to_vec(),
        ];
        let (y, sv, _) = block::forward(cfg, &x, eff, norms, true, false);
        x = y;
        saves.push(sv.unwrap()); // besa-lint: allow(hot-path-panic) — save=true always returns Some
    }
    let hf = head_forward(cfg, &x, norm_f, emb, tokens, true);
    let logp = hf.logp.unwrap(); // besa-lint: allow(hot-path-panic) — keep=true always captures logp
    let h = hf.h.unwrap(); // besa-lint: allow(hot-path-panic) — keep=true always captures h
    let count = hf.nll.iter().filter(|x| **x != 0.0).count().max(1);
    let loss: f64 = hf.nll.iter().map(|x| *x as f64).sum::<f64>() / count as f64;

    // ---- backward --------------------------------------------------------
    // d loss / d logits = gnll * (softmax - onehot(tgt)); gnll = 1/count at
    // contributing positions.
    let mut glogits = vec![0.0f32; n * v];
    let inv = 1.0 / count as f32;
    for i in 0..n {
        if hf.nll[i] == 0.0 {
            continue;
        }
        let lrow = &logp[i * v..(i + 1) * v];
        let grow = &mut glogits[i * v..(i + 1) * v];
        for (g, lp) in grow.iter_mut().zip(lrow) {
            *g = inv * lp.exp();
        }
        grow[hf.tgt[i]] -= inv;
    }
    // logits = h @ emb^T: gh = glogits @ emb ; gemb(head) = glogits^T @ h
    let gh = ops::mm_nn(&glogits, emb, n, v, d);
    let mut gemb = ops::mm_tn(&glogits, &h, n, v, d);
    let (mut gx, gnorm_f) = ops::rmsnorm_bwd(&x, norm_f, &gh, d, cfg.norm_eps);

    // through the blocks, collecting gradients in reverse
    let mut per_block: Vec<([Vec<f32>; 7], Vec<f32>, Vec<f32>)> =
        Vec::with_capacity(cfg.n_blocks);
    for sv in saves.iter().rev() {
        let grads = block::backward(cfg, sv, &gx);
        gx = grads.gx;
        per_block.push((grads.gw_eff, grads.gnorm1, grads.gnorm2));
    }
    per_block.reverse();

    // embed gather backward (tied head already accumulated)
    for (i, t) in tokens.iter().enumerate() {
        let t = (*t).clamp(0, v as i32 - 1) as usize;
        let row = &mut gemb[t * d..(t + 1) * d];
        for (g, gv) in row.iter_mut().zip(&gx[i * d..(i + 1) * d]) {
            *g += gv;
        }
    }

    // ---- outputs in param_order ------------------------------------------
    let mut out = Vec::with_capacity(1 + n_params);
    out.push(Tensor::scalar(loss as f32));
    out.push(Tensor::from_f32(&[v, d], gemb));
    for (gw, gn1, gn2) in per_block {
        for (j, g) in gw.into_iter().enumerate() {
            let sh = cfg.layer_shape(LAYER_NAMES[j]);
            out.push(Tensor::from_f32(&sh, g));
        }
        out.push(Tensor::from_f32(&[d], gn1));
        out.push(Tensor::from_f32(&[d], gn2));
    }
    out.push(Tensor::from_f32(&[d], gnorm_f));
    Ok(out)
}
