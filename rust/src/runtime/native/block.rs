//! One transformer block of the native interpreter — forward (dense,
//! masked, capture) and the hand-derived backward used by `besa_step*`,
//! `two_block_step` and `lm_train_step`.
//!
//! Mirrors `python/compile/model.py::block_forward`: pre-norm attention
//! with RoPE, SwiGLU MLP, residuals, `W[out, in]` weights applied as
//! `x @ (W ∘ M)^T`.

use anyhow::Result;

use crate::kernel::{fused, gemm};
use crate::model::config::ModelConfig;
use crate::tensor::Tensor;

use super::ops;

/// Forward state kept for the backward pass. All activations are flat
/// row-major `[B*S, ·]` slices; `eff` holds the effective (masked,
/// possibly quantized) weights actually used by the linears.
pub struct BlockSaved {
    pub x: Vec<f32>,
    pub h1: Vec<f32>,
    pub attout: Vec<f32>,
    pub x2: Vec<f32>,
    pub h2: Vec<f32>,
    pub gate: Vec<f32>,
    pub up: Vec<f32>,
    pub act: Vec<f32>,
    pub attn: ops::AttnSaved,
    /// effective weights in LAYER_NAMES order
    pub eff: [Vec<f32>; 7],
    pub norms: [Vec<f32>; 2],
}

/// Gradients produced by [`backward`]. `gw_eff[l]` is the gradient w.r.t.
/// the *effective* weight of layer `l` (callers turn it into a mask
/// gradient via `∘ W` or a weight gradient via `∘ M`).
pub struct BlockGrads {
    pub gx: Vec<f32>,
    pub gw_eff: [Vec<f32>; 7],
    pub gnorm1: Vec<f32>,
    pub gnorm2: Vec<f32>,
}

/// Captured linear-layer inputs (h1, att, h2, act) for Wanda/SparseGPT.
pub struct Capture {
    pub h1: Vec<f32>,
    pub att: Vec<f32>,
    pub h2: Vec<f32>,
    pub act: Vec<f32>,
}

/// Effective weights: `W ∘ M` when masks are given, else a copy of `W`.
pub fn effective_weights(weights: &[&Tensor], masks: Option<&[Vec<f32>]>) -> [Vec<f32>; 7] {
    let mut out: [Vec<f32>; 7] = Default::default();
    for i in 0..7 {
        out[i] = match masks {
            Some(ms) => ops::hadamard(weights[i].f32s(), &ms[i]),
            None => weights[i].f32s().to_vec(),
        };
    }
    out
}

/// Run one block. `eff` are the effective weights (LAYER_NAMES order),
/// `norms` the two RMSNorm gains. Returns `y` plus optional saved state
/// and optional capture tensors.
pub fn forward(
    cfg: &ModelConfig,
    x: &[f32],
    eff: [Vec<f32>; 7],
    norms: [Vec<f32>; 2],
    save: bool,
    capture: bool,
) -> (Vec<f32>, Option<BlockSaved>, Option<Capture>) {
    let (d, f) = (cfg.d_model, cfg.d_ffn);
    let n = cfg.batch * cfg.seq_len; // token rows
    let eps = cfg.norm_eps;
    let [wq, wk, wv, wo, wg, wu, wd] = &eff;

    let h1 = ops::rmsnorm(x, &norms[0], d, eps);
    let q = ops::mm_nt(&h1, wq, n, d, d);
    let k = ops::mm_nt(&h1, wk, n, d, d);
    let v = ops::mm_nt(&h1, wv, n, d, d);
    let (attout, attn_saved) = ops::attention(&q, &k, &v, cfg, save);
    let o = ops::mm_nt(&attout, wo, n, d, d);
    let x2: Vec<f32> = x.iter().zip(&o).map(|(a, b)| a + b).collect();
    let h2 = ops::rmsnorm(&x2, &norms[1], d, eps);
    let gate = ops::mm_nt(&h2, wg, n, d, f);
    let up = ops::mm_nt(&h2, wu, n, d, f);
    let act: Vec<f32> = gate.iter().zip(&up).map(|(g, u)| ops::silu(*g) * u).collect();
    let down = ops::mm_nt(&act, wd, n, f, d);
    let y: Vec<f32> = x2.iter().zip(&down).map(|(a, b)| a + b).collect();

    let cap = capture.then(|| Capture {
        h1: h1.clone(),
        att: attout.clone(),
        h2: h2.clone(),
        act: act.clone(),
    });
    let saved = if save {
        Some(BlockSaved {
            x: x.to_vec(),
            h1,
            attout,
            x2,
            h2,
            gate,
            up,
            act,
            attn: attn_saved.unwrap(), // besa-lint: allow(hot-path-panic) — save=true always captures attn
            eff,
            norms,
        })
    } else {
        None
    };
    (y, saved, cap)
}

/// Backward through one block given `gy = dL/dy`.
pub fn backward(cfg: &ModelConfig, sv: &BlockSaved, gy: &[f32]) -> BlockGrads {
    let (d, f) = (cfg.d_model, cfg.d_ffn);
    let n = cfg.batch * cfg.seq_len;
    let eps = cfg.norm_eps;
    let [wq, wk, wv, wo, wg, wu, wd] = &sv.eff;

    // y = x2 + down
    let g_down = gy;
    let gwd = ops::mm_tn(g_down, &sv.act, n, d, f);
    let g_act = ops::mm_nn(g_down, wd, n, d, f);
    // act = silu(gate) * up
    let mut g_gate = vec![0.0f32; n * f];
    let mut g_up = vec![0.0f32; n * f];
    for i in 0..n * f {
        g_gate[i] = g_act[i] * sv.up[i] * ops::silu_grad(sv.gate[i]);
        g_up[i] = g_act[i] * ops::silu(sv.gate[i]);
    }
    let gwg = ops::mm_tn(&g_gate, &sv.h2, n, f, d);
    let gwu = ops::mm_tn(&g_up, &sv.h2, n, f, d);
    let mut g_h2 = ops::mm_nn(&g_gate, wg, n, f, d);
    for (a, b) in g_h2.iter_mut().zip(ops::mm_nn(&g_up, wu, n, f, d)) {
        *a += b;
    }
    let (gx2_rms, gnorm2) = ops::rmsnorm_bwd(&sv.x2, &sv.norms[1], &g_h2, d, eps);
    // total gradient at x2: direct residual (gy) + through h2
    let g_x2: Vec<f32> = gy.iter().zip(&gx2_rms).map(|(a, b)| a + b).collect();

    // x2 = x + o
    let g_o = &g_x2;
    let gwo = ops::mm_tn(g_o, &sv.attout, n, d, d);
    let g_attout = ops::mm_nn(g_o, wo, n, d, d);
    let (gq, gk, gv) = ops::attention_bwd(&sv.attn, &g_attout, cfg);
    let gwq = ops::mm_tn(&gq, &sv.h1, n, d, d);
    let gwk = ops::mm_tn(&gk, &sv.h1, n, d, d);
    let gwv = ops::mm_tn(&gv, &sv.h1, n, d, d);
    let mut g_h1 = ops::mm_nn(&gq, wq, n, d, d);
    for (a, b) in g_h1.iter_mut().zip(ops::mm_nn(&gk, wk, n, d, d)) {
        *a += b;
    }
    for (a, b) in g_h1.iter_mut().zip(ops::mm_nn(&gv, wv, n, d, d)) {
        *a += b;
    }
    let (gx1_rms, gnorm1) = ops::rmsnorm_bwd(&sv.x, &sv.norms[0], &g_h1, d, eps);
    let gx: Vec<f32> = g_x2.iter().zip(&gx1_rms).map(|(a, b)| a + b).collect();

    BlockGrads {
        gx,
        gw_eff: [gwq, gwk, gwv, gwo, gwg, gwu, gwd],
        gnorm1,
        gnorm2,
    }
}

/// `block_fwd_cached`: one transformer block over a batch of single new
/// tokens with per-sequence KV caches — the serving decode hot path.
/// O(1) block work per token (7 matvecs) plus O(prefix) attention,
/// instead of re-running the whole prefix through the block.
///
/// Inputs: `x [nb,1,d]` (new-token activations), `k_cache`/`v_cache`
/// `[nb,cap,d]` (roped keys / raw values for positions `0..pos[i]`),
/// `pos [nb]` i32, then the 7 weights + 2 norms. Outputs: `y [nb,1,d]`
/// plus `k_new`/`v_new` `[nb,1,d]` for the caller to append — the op
/// itself stays stateless, like every other native artifact.
///
/// Numerics reproduce [`forward`] row-for-row: the RoPE angles/rotation
/// and the cached attention are the hoisted [`ops::rope_angles_at`] /
/// [`ops::rope_rotate_row`] / [`ops::attention_cached_row`] kernels — the
/// same code the in-process serving decode runs — so incremental decode
/// reproduces a full-prefix recompute bitwise; `tests/serve_parity.rs`
/// pins this.
pub fn block_fwd_cached(cfg: &ModelConfig, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let (x_t, kc_t, vc_t) = (inputs[0], inputs[1], inputs[2]);
    let pos = inputs[3].i32s();
    let nb = x_t.shape[0];
    let cap = kc_t.shape[1];
    let (d, f) = (cfg.d_model, cfg.d_ffn);
    let (nh, dh) = (cfg.n_heads, cfg.d_head());
    let half = dh / 2;
    if kc_t.shape[0] != nb || vc_t.shape != kc_t.shape || pos.len() != nb {
        anyhow::bail!(
            "block_fwd_cached: inconsistent batch dims x={:?} k={:?} v={:?} pos={}",
            x_t.shape,
            kc_t.shape,
            vc_t.shape,
            pos.len()
        );
    }
    let max_p = pos.iter().map(|p| *p as usize).max().unwrap_or(0);
    if max_p > cap {
        anyhow::bail!("block_fwd_cached: cache capacity {cap} < position {max_p}");
    }
    let xs = x_t.f32s();
    let kcs = kc_t.f32s();
    let vcs = vc_t.f32s();
    let weights: Vec<&[f32]> = inputs[4..11].iter().map(|t| t.f32s()).collect();
    let norm1 = inputs[11].f32s();
    let norm2 = inputs[12].f32s();
    let eps = cfg.norm_eps;

    let mut y = vec![0.0f32; nb * d];
    let mut k_new = vec![0.0f32; nb * d];
    let mut v_new = vec![0.0f32; nb * d];
    let mut cos_p = vec![0.0f32; half];
    let mut sin_p = vec![0.0f32; half];
    // Per-token scratch, hoisted out of the request loop so the decode
    // hot path performs no per-token allocations; projections run through
    // the fused RMSNorm+matvec / matvec lanes of [`crate::kernel`]
    // (bitwise equal to the unfused rmsnorm + mm_nt they replace).
    let mut h = vec![0.0f32; d];
    let mut q = vec![0.0f32; d];
    let mut k = vec![0.0f32; d];
    let mut v = vec![0.0f32; d];
    let mut att = vec![0.0f32; d];
    let mut o = vec![0.0f32; d];
    let mut x2 = vec![0.0f32; d];
    let mut gate = vec![0.0f32; f];
    let mut up = vec![0.0f32; f];
    let mut down = vec![0.0f32; d];
    let mut row = Vec::new();
    for i in 0..nb {
        let p = pos[i] as usize;
        let xi = &xs[i * d..(i + 1) * d];
        // fused norm + q projection; the normalized row in `h` then feeds
        // the sibling k/v projections
        fused::rmsnorm_matvec(xi, norm1, eps, &mut h, weights[0], d, &mut q);
        gemm::matvec_into(&h, weights[1], d, d, &mut k);
        gemm::matvec_into(&h, weights[2], d, d, &mut v);
        // RoPE angles for this position only — O(dh) per sequence, not a
        // full O(prefix·dh) table per call.
        ops::rope_angles_at(p, dh, cfg.rope_base, &mut cos_p, &mut sin_p);
        ops::rope_rotate_row(&mut q, &cos_p, &sin_p, nh, dh, false);
        ops::rope_rotate_row(&mut k, &cos_p, &sin_p, nh, dh, false);
        // attention over cached keys 0..p plus the new key at p — the
        // same hoisted kernel the in-process serving decode uses
        let kci = &kcs[i * cap * d..(i + 1) * cap * d];
        let vci = &vcs[i * cap * d..(i + 1) * cap * d];
        ops::attention_cached_row_into(
            &q,
            &k,
            &v,
            &kci[..p * d],
            &vci[..p * d],
            p,
            nh,
            dh,
            &mut row,
            &mut att,
        );
        gemm::matvec_into(&att, weights[3], d, d, &mut o);
        for (x2v, (a, b)) in x2.iter_mut().zip(xi.iter().zip(&o)) {
            *x2v = a + b;
        }
        // fused norm + gate projection, sibling up projection, SwiGLU
        // activation computed in place over `gate`
        fused::rmsnorm_matvec(&x2, norm2, eps, &mut h, weights[4], f, &mut gate);
        gemm::matvec_into(&h, weights[5], d, f, &mut up);
        for (g, u) in gate.iter_mut().zip(&up) {
            *g = ops::silu(*g) * u;
        }
        gemm::matvec_into(&gate, weights[6], f, d, &mut down);
        for (t, yv) in y[i * d..(i + 1) * d].iter_mut().enumerate() {
            *yv = x2[t] + down[t];
        }
        k_new[i * d..(i + 1) * d].copy_from_slice(&k);
        v_new[i * d..(i + 1) * d].copy_from_slice(&v);
    }
    Ok(vec![
        Tensor::from_f32(&[nb, 1, d], y),
        Tensor::from_f32(&[nb, 1, d], k_new),
        Tensor::from_f32(&[nb, 1, d], v_new),
    ])
}

/// Convenience used by the `block_fwd*` / `block_capture` dispatch:
/// assemble inputs from positional tensors.
pub fn run_block_op(
    cfg: &ModelConfig,
    inputs: &[&Tensor],
    masked: bool,
    capture: bool,
) -> Result<Vec<Tensor>> {
    let x = inputs[0].f32s();
    let weights = &inputs[1..8];
    let norms = [inputs[8].f32s().to_vec(), inputs[9].f32s().to_vec()];
    let eff = if masked {
        let masks: Vec<Vec<f32>> = inputs[10..17].iter().map(|m| m.f32s().to_vec()).collect();
        effective_weights(weights, Some(&masks))
    } else {
        effective_weights(weights, None)
    };
    let (y, _, cap) = forward(cfg, x, eff, norms, false, capture);
    let x3 = [cfg.batch, cfg.seq_len, cfg.d_model];
    let mut out = vec![Tensor::from_f32(&x3, y)];
    if capture {
        let c = cap.unwrap(); // besa-lint: allow(hot-path-panic) — forward(capture=true) always saves
        out.push(Tensor::from_f32(&x3, c.h1));
        out.push(Tensor::from_f32(&x3, c.att));
        out.push(Tensor::from_f32(&x3, c.h2));
        out.push(Tensor::from_f32(&[cfg.batch, cfg.seq_len, cfg.d_ffn], c.act));
    }
    Ok(out)
}
