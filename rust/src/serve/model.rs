//! Packed inference weights: a [`crate::model::ParamStore`] checkpoint
//! materialized into the format the serving kernels execute —
//! dense rows, CSR over the pruned zeros, or quantized CSR with fused
//! dequant (see [`crate::sparse`]).

use anyhow::Result;

use crate::model::{ModelConfig, ParamStore, LAYER_NAMES};
use crate::quant::QuantSpec;
use crate::runtime::native::ops;
use crate::sparse::{linear_csr, linear_quant, Csr, QuantCsr};

/// How to pack the seven prunable projections of every block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WeightFormat {
    /// f32 rows, executed with the native backend's `mm_nt` kernel — the
    /// dense baseline every speedup is measured against.
    Dense,
    /// CSR over exact-zero pruned entries, row-blocked SpMM.
    Csr,
    /// CSR with 1-byte codes, dequant fused into the SpMM inner loop.
    Quant(QuantSpec),
}

impl WeightFormat {
    pub fn name(&self) -> &'static str {
        match self {
            WeightFormat::Dense => "dense",
            WeightFormat::Csr => "sparse",
            WeightFormat::Quant(_) => "quant",
        }
    }
}

/// One packed projection `W [out, in]`.
pub enum PackedLinear {
    Dense { w: Vec<f32>, rows: usize, cols: usize },
    Csr(Csr),
    Quant(QuantCsr),
}

impl PackedLinear {
    pub fn rows(&self) -> usize {
        match self {
            PackedLinear::Dense { rows, .. } => *rows,
            PackedLinear::Csr(c) => c.rows,
            PackedLinear::Quant(q) => q.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            PackedLinear::Dense { cols, .. } => *cols,
            PackedLinear::Csr(c) => c.cols,
            PackedLinear::Quant(q) => q.cols,
        }
    }

    /// `y[n, rows] = x[n, cols] @ W^T`. All three formats accumulate each
    /// output element in ascending-column order, so a CSR packed from a
    /// masked weight reproduces the dense result bitwise.
    pub fn forward(&self, x: &[f32], n: usize) -> Vec<f32> {
        match self {
            PackedLinear::Dense { w, rows, cols } => ops::mm_nt(x, w, n, *cols, *rows),
            PackedLinear::Csr(c) => linear_csr(c, x, n),
            PackedLinear::Quant(q) => linear_quant(q, x, n),
        }
    }

    /// Resident weight bytes in this format.
    pub fn mem_bytes(&self) -> usize {
        match self {
            PackedLinear::Dense { w, .. } => w.len() * 4,
            PackedLinear::Csr(c) => c.mem_bytes(),
            PackedLinear::Quant(q) => q.mem_bytes(),
        }
    }
}

/// One packed transformer block: the seven projections in
/// [`LAYER_NAMES`] order plus the two RMSNorm gains.
pub struct PackedBlock {
    pub lin: Vec<PackedLinear>,
    pub norm1: Vec<f32>,
    pub norm2: Vec<f32>,
}

/// A whole checkpoint packed for inference.
pub struct PackedModel {
    pub cfg: ModelConfig,
    pub format: WeightFormat,
    /// tied embedding / LM-head table `[vocab, d]`
    pub embed: Vec<f32>,
    pub norm_f: Vec<f32>,
    pub blocks: Vec<PackedBlock>,
}

/// Reject a checkpoint tensor whose shape disagrees with the config
/// (same unification as the artifact-graph checker; no wildcards here).
/// Without this, a mismatched checkpoint would pack silently and fail —
/// or worse, run — deep inside a serving kernel.
fn expect_shape(name: &str, got: &[usize], want: &[usize]) -> Result<()> {
    if let Err(why) = crate::analyze::graph::unify_shapes(got, want) {
        anyhow::bail!(
            "packed weight '{name}': checkpoint shape {got:?} vs config {want:?} — {why}"
        );
    }
    Ok(())
}

impl PackedModel {
    /// Pack `params` in the given format. Pruned (exact-zero) entries are
    /// dropped by the sparse formats; dense keeps them. Every tensor's
    /// shape is verified against the config before packing.
    pub fn materialize(
        params: &ParamStore,
        cfg: &ModelConfig,
        format: WeightFormat,
    ) -> Result<PackedModel> {
        let d = cfg.d_model;
        let mut blocks = Vec::with_capacity(cfg.n_blocks);
        for l in 0..cfg.n_blocks {
            let mut lin = Vec::with_capacity(7);
            for w in LAYER_NAMES {
                let t = params.get(&ParamStore::layer_name(l, w))?;
                expect_shape(&ParamStore::layer_name(l, w), &t.shape, &cfg.layer_shape(w))?;
                lin.push(match format {
                    WeightFormat::Dense => {
                        let sh = cfg.layer_shape(w);
                        PackedLinear::Dense { w: t.f32s().to_vec(), rows: sh[0], cols: sh[1] }
                    }
                    WeightFormat::Csr => PackedLinear::Csr(Csr::from_dense(t)),
                    WeightFormat::Quant(spec) => {
                        PackedLinear::Quant(QuantCsr::from_dense(t, spec))
                    }
                });
            }
            let norm1 = params.get(&format!("blocks.{l}.norm1"))?;
            let norm2 = params.get(&format!("blocks.{l}.norm2"))?;
            expect_shape(&format!("blocks.{l}.norm1"), &norm1.shape, &[d])?;
            expect_shape(&format!("blocks.{l}.norm2"), &norm2.shape, &[d])?;
            blocks.push(PackedBlock {
                lin,
                norm1: norm1.f32s().to_vec(),
                norm2: norm2.f32s().to_vec(),
            });
        }
        let embed = params.get("embed")?;
        let norm_f = params.get("norm_f")?;
        expect_shape("embed", &embed.shape, &[cfg.vocab, d])?;
        expect_shape("norm_f", &norm_f.shape, &[d])?;
        Ok(PackedModel {
            cfg: cfg.clone(),
            format,
            embed: embed.f32s().to_vec(),
            norm_f: norm_f.f32s().to_vec(),
            blocks,
        })
    }

    /// Fraction of prunable weights dropped by the packing (0 for dense).
    pub fn sparsity(&self) -> f64 {
        let mut kept = 0usize;
        let mut total = 0usize;
        for b in &self.blocks {
            for l in &b.lin {
                total += l.rows() * l.cols();
                kept += match l {
                    PackedLinear::Dense { w, .. } => w.len(),
                    PackedLinear::Csr(c) => c.nnz(),
                    PackedLinear::Quant(q) => q.nnz(),
                };
            }
        }
        1.0 - kept as f64 / total.max(1) as f64
    }

    /// Resident bytes of all packed projections (excl. embed/norms, which
    /// are format-independent).
    pub fn weight_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.lin.iter().map(|l| l.mem_bytes()).sum::<usize>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;
    use crate::util::rng::Rng;

    fn pruned_params(cfg: &ModelConfig, sparsity: f64) -> ParamStore {
        let mut p = ParamStore::init(cfg, 5);
        let mut rng = Rng::seed(6);
        for l in 0..cfg.n_blocks {
            for w in LAYER_NAMES {
                let t = p.get_mut(&ParamStore::layer_name(l, w)).unwrap();
                for v in t.f32s_mut() {
                    if rng.f64() < sparsity {
                        *v = 0.0;
                    }
                }
            }
        }
        p
    }

    #[test]
    fn formats_agree_on_forward() {
        let cfg = test_config();
        let p = pruned_params(&cfg, 0.5);
        let dense = PackedModel::materialize(&p, &cfg, WeightFormat::Dense).unwrap();
        let csr = PackedModel::materialize(&p, &cfg, WeightFormat::Csr).unwrap();
        let mut rng = Rng::seed(7);
        let n = 6;
        let x: Vec<f32> = (0..n * cfg.d_model).map(|_| rng.normal_f32()).collect();
        for j in 0..7 {
            let a = dense.blocks[0].lin[j].forward(&x, n);
            let b = csr.blocks[0].lin[j].forward(&x, n);
            assert_eq!(a, b, "layer {j} dense vs csr");
        }
        assert!((csr.sparsity() - 0.5).abs() < 0.05);
        assert_eq!(dense.sparsity(), 0.0);
        assert!(csr.weight_bytes() < dense.weight_bytes() * 3 / 2);
    }

    /// Materialization runs the same shape unification as the artifact
    /// graph checker: a checkpoint whose tensors disagree with the config
    /// is rejected up front, not deep inside a serving kernel.
    #[test]
    fn mismatched_checkpoint_is_rejected() {
        let cfg = test_config();
        let p = ParamStore::init(&cfg, 5);
        let mut bigger = cfg.clone();
        bigger.d_model *= 2;
        let err = PackedModel::materialize(&p, &bigger, WeightFormat::Dense)
            .unwrap_err()
            .to_string();
        assert!(err.contains("checkpoint shape"), "unexpected error: {err}");
    }

    #[test]
    fn quant_format_packs_smaller() {
        let cfg = test_config();
        let p = pruned_params(&cfg, 0.5);
        let csr = PackedModel::materialize(&p, &cfg, WeightFormat::Csr).unwrap();
        let q =
            PackedModel::materialize(&p, &cfg, WeightFormat::Quant(QuantSpec::default())).unwrap();
        assert!(q.weight_bytes() < csr.weight_bytes());
        assert!((q.sparsity() - csr.sparsity()).abs() < 1e-12);
    }
}
