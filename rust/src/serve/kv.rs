//! Per-request KV cache: roped keys + raw values for every block, written
//! once per position and read by every subsequent decode step.
//!
//! Layout is `[n_blocks, capacity, d]` row-major per tensor, one `len`
//! shared by all blocks (a position is committed with [`KvCache::set_len`]
//! after every block has written its row, keeping the cache consistent
//! even if a forward pass is abandoned midway).

/// KV storage for one request.
#[derive(Debug, Clone)]
pub struct KvCache {
    n_blocks: usize,
    d: usize,
    capacity: usize,
    len: usize,
    /// roped keys, `[n_blocks, capacity, d]`
    k: Vec<f32>,
    /// raw values, `[n_blocks, capacity, d]`
    v: Vec<f32>,
}

impl KvCache {
    pub fn new(n_blocks: usize, d: usize, capacity: usize) -> KvCache {
        KvCache {
            n_blocks,
            d,
            capacity,
            len: 0,
            k: vec![0.0; n_blocks * capacity * d],
            v: vec![0.0; n_blocks * capacity * d],
        }
    }

    /// Committed positions (same for every block).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Write the roped key / raw value rows of `block` at `pos`. Does not
    /// change `len`; commit with [`KvCache::set_len`] once every block has
    /// written the position.
    pub fn write(&mut self, block: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        assert!(block < self.n_blocks && pos < self.capacity, "kv write out of range");
        assert!(k_row.len() == self.d && v_row.len() == self.d);
        let off = (block * self.capacity + pos) * self.d;
        self.k[off..off + self.d].copy_from_slice(k_row);
        self.v[off..off + self.d].copy_from_slice(v_row);
    }

    /// Commit positions `0..len` (capped by capacity). Shrinking is
    /// allowed — benches rewind a cache to replay decode steps.
    pub fn set_len(&mut self, len: usize) {
        assert!(len <= self.capacity, "kv len {len} > capacity {}", self.capacity);
        self.len = len;
    }

    /// Committed key rows of `block`: `[len, d]` row-major.
    pub fn k_block(&self, block: usize) -> &[f32] {
        let base = block * self.capacity * self.d;
        &self.k[base..base + self.len * self.d]
    }

    /// Committed value rows of `block`: `[len, d]` row-major.
    pub fn v_block(&self, block: usize) -> &[f32] {
        let base = block * self.capacity * self.d;
        &self.v[base..base + self.len * self.d]
    }

    /// Resident bytes of the backing buffers.
    pub fn mem_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

// Unit tests live in `super::paged::tests`, side by side with the paged
// representation they are compared against.
