//! Continuous-batching admission control: a FIFO request queue admitted
//! by token budget and batch-slot cap.
//!
//! A request's *cost* is the worst case KV footprint it can reach
//! (prompt tokens + maximum new tokens); the scheduler keeps the summed
//! cost of everything in flight under `token_budget` and the batch under
//! `max_batch` slots. Admission is FIFO in arrival order — no request can
//! starve — and a request's cost is released back when it retires.

use std::collections::VecDeque;

use anyhow::{bail, Result};

/// What the client asked for.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReqKind {
    /// Greedy generation of up to `max_new` tokens.
    Generate { max_new: usize },
    /// Log-likelihood scoring of the prompt (retires at prefill).
    Score,
}

/// Quality-of-service metadata a request carries through admission and
/// scheduling. QoS never changes *what* a request computes — greedy
/// decode depends only on the model and the prompt — it only changes
/// *whether* and *when* the request is served (admission control,
/// deadline shedding, [`Policy`] ordering).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Qos {
    /// completion deadline, seconds after the request becomes visible to
    /// the queue; `f64::INFINITY` means no deadline
    pub deadline_s: f64,
    /// scheduling tier under [`Policy::Priority`]: lower is more urgent
    pub priority: u8,
    /// client identity, the per-client token-bucket key (`serve::net`)
    pub client: u32,
}

impl Default for Qos {
    fn default() -> Self {
        Qos { deadline_s: f64::INFINITY, priority: 1, client: 0 }
    }
}

impl Qos {
    /// QoS with only a relative deadline set.
    pub fn with_deadline(deadline_s: f64) -> Qos {
        Qos { deadline_s, ..Qos::default() }
    }
}

/// Queue ordering policy of the online arrival queue
/// ([`super::ingest::IngestQueue`]). Changes *order*, never *outputs*:
/// per-request tokens are policy-invariant (pinned by
/// `tests/serve_parity.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// strict arrival order (the default; head-of-line blocking is
    /// deliberate so nothing starves)
    Fifo,
    /// [`Qos::priority`] tiers, FIFO inside a tier (lower tier first)
    Priority,
    /// earliest deadline first; deadline-free requests sort last,
    /// FIFO among themselves
    Edf,
}

impl Policy {
    pub const ALL: [Policy; 3] = [Policy::Fifo, Policy::Priority, Policy::Edf];

    pub fn from_name(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "priority" | "prio" => Some(Policy::Priority),
            "edf" | "deadline" => Some(Policy::Edf),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::Priority => "priority",
            Policy::Edf => "edf",
        }
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    /// arrival time on the trace clock, seconds
    pub arrival: f64,
    pub tokens: Vec<i32>,
    pub kind: ReqKind,
    pub qos: Qos,
}

impl Request {
    /// Worst-case token footprint: prompt plus everything it may generate.
    pub fn cost(&self) -> usize {
        self.tokens.len()
            + match self.kind {
                ReqKind::Generate { max_new } => max_new,
                ReqKind::Score => 0,
            }
    }
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// cap on summed [`Request::cost`] of admitted-but-unfinished requests
    pub token_budget: usize,
    /// cap on concurrently decoding requests
    pub max_batch: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { token_budget: 4096, max_batch: 8 }
    }
}

/// FIFO queue + budget accounting.
pub struct Scheduler {
    cfg: SchedulerConfig,
    pending: VecDeque<Request>,
    in_flight_tokens: usize,
}

impl Scheduler {
    /// `requests` are sorted by arrival (Poisson traces already are; any
    /// other source is normalized here). Errors if the configuration can
    /// never admit some request — with `max_batch` 0 or a request costing
    /// more than the whole budget, the serving loop would spin forever.
    pub fn new(cfg: SchedulerConfig, mut requests: Vec<Request>) -> Result<Scheduler> {
        if cfg.max_batch == 0 {
            bail!("scheduler max_batch must be >= 1");
        }
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for r in &requests {
            if r.cost() > cfg.token_budget {
                bail!(
                    "request {} cost {} exceeds the whole token budget {}",
                    r.id,
                    r.cost(),
                    cfg.token_budget
                );
            }
        }
        Ok(Scheduler { cfg, pending: requests.into(), in_flight_tokens: 0 })
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Arrival time of the next queued request, if any.
    pub fn next_arrival(&self) -> Option<f64> {
        self.pending.front().map(|r| r.arrival)
    }

    pub fn in_flight_tokens(&self) -> usize {
        self.in_flight_tokens
    }

    /// Admit arrived requests FIFO while the token budget and batch slots
    /// allow. `active` is the number of requests currently decoding.
    pub fn admit(&mut self, now: f64, active: usize) -> Vec<Request> {
        let mut out = Vec::new();
        loop {
            let fits = match self.pending.front() {
                Some(front) => {
                    front.arrival <= now
                        && active + out.len() < self.cfg.max_batch
                        && self.in_flight_tokens + front.cost() <= self.cfg.token_budget
                }
                None => false,
            };
            if !fits {
                break;
            }
            match self.pending.pop_front() {
                Some(r) => {
                    self.in_flight_tokens += r.cost();
                    out.push(r);
                }
                None => break,
            }
        }
        out
    }

    /// Return a retired request's cost to the budget.
    pub fn release(&mut self, cost: usize) {
        debug_assert!(cost <= self.in_flight_tokens);
        self.in_flight_tokens = self.in_flight_tokens.saturating_sub(cost);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, arrival: f64, prompt: usize, max_new: usize) -> Request {
        Request {
            id,
            arrival,
            tokens: vec![0; prompt],
            kind: ReqKind::Generate { max_new },
            qos: Qos::default(),
        }
    }

    #[test]
    fn policy_names_round_trip() {
        for p in Policy::ALL {
            assert_eq!(Policy::from_name(p.name()), Some(p));
        }
        assert_eq!(Policy::from_name("nope"), None);
        let q = Qos::default();
        assert!(q.deadline_s.is_infinite() && q.priority == 1 && q.client == 0);
        assert_eq!(Qos::with_deadline(0.5).deadline_s, 0.5);
    }

    #[test]
    fn fifo_admission_respects_arrival_and_budget() {
        let cfg = SchedulerConfig { token_budget: 50, max_batch: 8 };
        let reqs = vec![req(0, 0.0, 10, 10), req(1, 0.0, 10, 10), req(2, 5.0, 10, 10)];
        let mut s = Scheduler::new(cfg, reqs).unwrap();
        // t=0: request 2 hasn't arrived; 0 and 1 fit (20+20 <= 50)
        let a = s.admit(0.0, 0);
        assert_eq!(a.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(s.in_flight_tokens(), 40);
        // t=5: request 2 arrived but 40+20 > 50
        assert!(s.admit(5.0, 2).is_empty());
        // retiring one frees budget
        s.release(20);
        let b = s.admit(5.0, 1);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].id, 2);
        assert_eq!(s.pending_len(), 0);
    }

    #[test]
    fn batch_slots_cap_admission() {
        let cfg = SchedulerConfig { token_budget: 10_000, max_batch: 2 };
        let reqs = (0..5).map(|i| req(i, 0.0, 4, 4)).collect();
        let mut s = Scheduler::new(cfg, reqs).unwrap();
        assert_eq!(s.admit(0.0, 0).len(), 2);
        assert_eq!(s.admit(0.0, 2).len(), 0);
        assert_eq!(s.admit(0.0, 1).len(), 1);
        assert_eq!(s.pending_len(), 2);
    }

    #[test]
    fn unsorted_traces_are_normalized() {
        let cfg = SchedulerConfig::default();
        let mut s = Scheduler::new(cfg, vec![req(0, 3.0, 1, 1), req(1, 1.0, 1, 1)]).unwrap();
        assert_eq!(s.next_arrival(), Some(1.0));
        let a = s.admit(10.0, 0);
        assert_eq!(a[0].id, 1);
    }

    #[test]
    fn impossible_configs_are_rejected_up_front() {
        // a request that can never fit the budget would starve forever
        let cfg = SchedulerConfig { token_budget: 8, max_batch: 2 };
        assert!(Scheduler::new(cfg, vec![req(0, 0.0, 16, 16)]).is_err());
        // zero batch slots can never admit anything
        let cfg = SchedulerConfig { token_budget: 100, max_batch: 0 };
        assert!(Scheduler::new(cfg, vec![req(0, 0.0, 4, 4)]).is_err());
    }

    /// A request whose cost exceeds the *remaining* (not total) budget
    /// stalls at the head of the queue — and, FIFO being deliberate,
    /// blocks cheaper requests behind it — until enough cost is released.
    #[test]
    fn oversized_for_remaining_budget_waits_and_blocks_fifo() {
        let cfg = SchedulerConfig { token_budget: 40, max_batch: 8 };
        // 30 in flight after the first; the 25-cost request must wait
        // even though the 5-cost request behind it would fit
        let reqs = vec![req(0, 0.0, 20, 10), req(1, 0.0, 15, 10), req(2, 0.0, 3, 2)];
        let mut s = Scheduler::new(cfg, reqs).unwrap();
        let a = s.admit(0.0, 0);
        assert_eq!(a.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
        assert_eq!(s.in_flight_tokens(), 30);
        assert!(s.admit(0.0, 1).is_empty(), "head-of-line request must not be skipped");
        assert_eq!(s.pending_len(), 2);
        // releasing the first request frees the whole line
        s.release(30);
        let b = s.admit(0.0, 0);
        assert_eq!(b.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(s.pending_len(), 0);
    }

    /// Draining: releases may interleave with admissions in any order and
    /// the accounting must return to zero once everything retires.
    #[test]
    fn budget_accounting_returns_to_zero_on_drain() {
        let cfg = SchedulerConfig { token_budget: 64, max_batch: 4 };
        let reqs = (0..6).map(|i| req(i, i as f64 * 0.1, 5, 5)).collect();
        let mut s = Scheduler::new(cfg, reqs).unwrap();
        let mut done = 0;
        let mut active = 0usize;
        let mut t = 0.0;
        while done < 6 {
            let admitted = s.admit(t, active);
            active += admitted.len();
            if active > 0 {
                // retire one per tick, releasing its cost
                s.release(10);
                active -= 1;
                done += 1;
            }
            t += 0.1;
        }
        assert_eq!(s.in_flight_tokens(), 0, "all cost returned after drain");
        assert_eq!(s.pending_len(), 0);
    }
}
