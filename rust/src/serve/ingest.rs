//! Real-time request ingestion: the front end of the online serving
//! engine ([`super::online`]).
//!
//! A producer thread ([`run_producer`]) replays a trace in *wall-clock*
//! time — sleeping until each request's arrival stamp under
//! [`Pacing::Replay`] (a `time_scale` of 0 floods the whole trace
//! immediately, the pure-backlog "drain" mode), or holding a fixed number
//! of outstanding requests under [`Pacing::ClosedLoop`] (arrival stamps
//! ignored; the next request is released as soon as a completion frees a
//! client slot, the classic closed-loop load generator).
//!
//! Arrived requests land in an [`IngestQueue`]: a mutex-guarded FIFO with
//! condvar wakeups that serving workers pop from *conditionally* — a
//! worker only takes the front request when its own admission predicate
//! (token budget + batch slots, see [`super::online`]) accepts it, so
//! admission control stays with the workers while arrival order stays
//! FIFO. The queue also tracks how many popped requests are still in
//! flight, which is what the closed-loop producer throttles on, and
//! stamps every request with its enqueue instant so the metrics pipeline
//! can split latency into queue wait vs compute.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::par::{locked, wait_on, wait_timeout_on};

use super::scheduler::Request;

/// One request made visible to the workers, stamped with the wall-clock
/// instant it became visible (the online arrival time: queue wait and
/// end-to-end latency are measured from here).
pub struct ArrivedRequest {
    pub req: Request,
    pub enqueued: Instant,
}

/// How the producer paces the trace into the queue.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pacing {
    /// Replay arrival stamps in wall-clock time, scaled by `time_scale`
    /// (2.0 = half speed, 0.5 = double speed, 0.0 = flood everything
    /// immediately and measure pure drain throughput).
    Replay { time_scale: f64 },
    /// Keep exactly `clients` requests outstanding (queued or in flight);
    /// arrival stamps are ignored.
    ClosedLoop { clients: usize },
}

impl Pacing {
    pub fn name(&self) -> &'static str {
        match self {
            Pacing::Replay { .. } => "replay",
            Pacing::ClosedLoop { .. } => "closed-loop",
        }
    }
}

/// Outcome of a conditional pop.
pub enum Pop {
    /// The front request passed the caller's admission predicate.
    Got(ArrivedRequest),
    /// A front request exists but the caller declined it (budget full).
    Refused,
    /// Nothing queued right now; the producer is still running.
    Empty,
    /// Queue empty and closed — no more work will ever arrive.
    Drained,
}

struct QueueState {
    ready: VecDeque<ArrivedRequest>,
    closed: bool,
    /// popped by a worker and not yet retired (closed-loop accounting)
    in_flight: usize,
}

/// Shared arrival queue between one producer and N serving workers.
pub struct IngestQueue {
    state: Mutex<QueueState>,
    /// signaled on push / close: workers waiting for work
    arrived: Condvar,
    /// signaled on retire: a closed-loop producer waiting for a slot
    retired: Condvar,
}

impl Default for IngestQueue {
    fn default() -> Self {
        IngestQueue::new()
    }
}

impl IngestQueue {
    pub fn new() -> IngestQueue {
        IngestQueue {
            state: Mutex::new(QueueState {
                ready: VecDeque::new(),
                closed: false,
                in_flight: 0,
            }),
            arrived: Condvar::new(),
            retired: Condvar::new(),
        }
    }

    /// Make one request visible to the workers (stamped now).
    pub fn push(&self, req: Request) {
        let mut g = locked(&self.state);
        g.ready.push_back(ArrivedRequest { req, enqueued: Instant::now() });
        drop(g);
        self.arrived.notify_all();
    }

    /// No more pushes will follow; workers drain what is queued and exit.
    pub fn close(&self) {
        locked(&self.state).closed = true;
        self.arrived.notify_all();
    }

    /// Pop the front request iff `admit` accepts it. FIFO is preserved:
    /// a declined front request stays at the front (head-of-line blocking
    /// is deliberate — no request can starve behind later arrivals).
    pub fn try_pop(&self, admit: impl FnOnce(&Request) -> bool) -> Pop {
        let mut g = locked(&self.state);
        let decision = g.ready.front().map(|front| admit(&front.req));
        match decision {
            Some(true) => match g.ready.pop_front() {
                Some(a) => {
                    g.in_flight += 1;
                    Pop::Got(a)
                }
                // unreachable (front() just matched under this guard),
                // but Empty is the safe answer if it ever weren't
                None => Pop::Empty,
            },
            Some(false) => Pop::Refused,
            None if g.closed => Pop::Drained,
            None => Pop::Empty,
        }
    }

    /// Block until something arrives or the queue closes, up to `timeout`
    /// (bounded so callers can re-check their own state).
    pub fn wait_arrival(&self, timeout: Duration) {
        let g = locked(&self.state);
        if g.ready.is_empty() && !g.closed {
            let _ = wait_timeout_on(&self.arrived, g, timeout);
        }
    }

    /// A popped request retired; frees one closed-loop client slot.
    pub fn note_done(&self) {
        let mut g = locked(&self.state);
        debug_assert!(g.in_flight > 0, "note_done without a matching pop");
        g.in_flight = g.in_flight.saturating_sub(1);
        drop(g);
        self.retired.notify_all();
    }

    /// Closed-loop producer throttle: block until fewer than `clients`
    /// requests are outstanding (queued + in flight).
    pub fn wait_capacity(&self, clients: usize) {
        let mut g = locked(&self.state);
        while g.ready.len() + g.in_flight >= clients {
            g = wait_on(&self.retired, g);
        }
    }

    /// True once the queue is closed and empty — in-flight work may still
    /// be decoding, but no worker will ever pop again.
    pub fn is_drained(&self) -> bool {
        let g = locked(&self.state);
        g.closed && g.ready.is_empty()
    }
}

/// Feed `requests` (sorted by arrival for [`Pacing::Replay`]) into the
/// queue under the given pacing, then close it. Runs on its own scoped
/// thread next to the serving workers.
pub fn run_producer(queue: &IngestQueue, requests: Vec<Request>, pacing: Pacing) {
    let start = Instant::now();
    match pacing {
        Pacing::Replay { time_scale } => {
            for r in requests {
                let due = r.arrival * time_scale;
                let elapsed = start.elapsed().as_secs_f64();
                if due > elapsed {
                    std::thread::sleep(Duration::from_secs_f64(due - elapsed));
                }
                queue.push(r);
            }
        }
        Pacing::ClosedLoop { clients } => {
            for r in requests {
                queue.wait_capacity(clients.max(1));
                queue.push(r);
            }
        }
    }
    queue.close();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::ReqKind;

    fn req(id: usize, cost: usize) -> Request {
        Request { id, arrival: 0.0, tokens: vec![0; cost], kind: ReqKind::Score }
    }

    #[test]
    fn fifo_pop_with_admission_predicate() {
        let q = IngestQueue::new();
        q.push(req(0, 8));
        q.push(req(1, 2));
        // front declined: later cheaper request must NOT jump the queue
        assert!(matches!(q.try_pop(|r| r.cost() <= 4), Pop::Refused));
        match q.try_pop(|r| r.cost() <= 8) {
            Pop::Got(a) => assert_eq!(a.req.id, 0),
            _ => panic!("front should be admitted"),
        }
        match q.try_pop(|_| true) {
            Pop::Got(a) => assert_eq!(a.req.id, 1),
            _ => panic!("second request should be admitted"),
        }
        assert!(matches!(q.try_pop(|_| true), Pop::Empty));
        q.close();
        assert!(matches!(q.try_pop(|_| true), Pop::Drained));
        assert!(q.is_drained());
    }

    #[test]
    fn closed_loop_throttles_outstanding() {
        let q = IngestQueue::new();
        let requests: Vec<Request> = (0..6).map(|i| req(i, 1)).collect();
        let served = crate::util::par::scoped_workers(2, |i| {
            if i == 0 {
                run_producer(&q, requests.clone(), Pacing::ClosedLoop { clients: 2 });
                0
            } else {
                // consumer: at most 2 can ever be queued+in-flight
                let mut got = 0usize;
                loop {
                    match q.try_pop(|_| true) {
                        Pop::Got(_) => {
                            got += 1;
                            q.note_done();
                        }
                        Pop::Drained => break,
                        _ => q.wait_arrival(Duration::from_millis(1)),
                    }
                }
                got
            }
        });
        assert_eq!(served[1], 6, "all requests flow through the closed loop");
    }

    #[test]
    fn replay_flood_preserves_order() {
        let q = IngestQueue::new();
        let requests: Vec<Request> = (0..5).map(|i| req(i, 1)).collect();
        run_producer(&q, requests, Pacing::Replay { time_scale: 0.0 });
        let mut ids = Vec::new();
        loop {
            match q.try_pop(|_| true) {
                Pop::Got(a) => {
                    ids.push(a.req.id);
                    q.note_done();
                }
                Pop::Drained => break,
                _ => unreachable!("flooded queue is never empty before drain"),
            }
        }
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }
}
