//! Real-time request ingestion: the front end of the online serving
//! engine ([`super::online`]) and of the socket edge ([`super::net`]).
//!
//! A producer thread ([`run_producer`]) replays a trace in *wall-clock*
//! time — sleeping until each request's arrival stamp under
//! [`Pacing::Replay`] (a `time_scale` of 0 floods the whole trace
//! immediately, the pure-backlog "drain" mode), or holding a fixed number
//! of outstanding requests under [`Pacing::ClosedLoop`] (arrival stamps
//! ignored; the next request is released as soon as a completion frees a
//! client slot, the classic closed-loop load generator). The TCP front
//! end pushes directly from connection handler threads instead.
//!
//! Arrived requests land in an [`IngestQueue`]: a mutex-guarded queue
//! with condvar wakeups that serving workers pop from *conditionally* — a
//! worker only takes the front request when its own admission predicate
//! (token budget + batch slots, see [`super::online`]) accepts it, so
//! admission control stays with the workers while arrival order follows
//! the configured [`Policy`] (FIFO by default; priority tiers or
//! earliest-deadline-first reorder *who is served next*, never what any
//! request computes). Head-of-line blocking within the policy order is
//! deliberate — no admitted request can starve behind later arrivals.
//!
//! Overload control is built in ([`QueueConfig`]):
//!
//! - **bounded queue** — pushes beyond `capacity` are rejected
//!   ([`RejectReason::QueueFull`], a 503 at the wire);
//! - **deadline shedding, admit-time** — a request whose deadline is
//!   already unmeetable (expired, or predicted-late from the EWMA service
//!   time when `admit_reject` is on) is rejected at push
//!   ([`RejectReason::DeadlineUnmeetable`]);
//! - **deadline shedding, in-queue** — every pop first sweeps out queued
//!   requests whose deadline has passed ([`Reply::Shed`], so a waiting
//!   connection learns immediately);
//! - **draining** — pushes after [`IngestQueue::close`] are rejected
//!   ([`RejectReason::Draining`]), which is what makes graceful shutdown
//!   race-free: nothing can slip into a closing queue.
//!
//! Every outcome is recorded exactly once: a request either reaches a
//! worker (and retires through `note_done`, or terminally fails through
//! `note_failed` under fault injection), is shed, or is rejected —
//! [`IngestQueue::take_outcomes`] returns the shed/rejected ledgers so
//! callers can assert `finished + failed + shed + rejected == submitted`.
//! A supervised restart may `requeue` a popped-but-unserved request; it
//! re-enters at its original place in line and retires exactly once like
//! any other.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::par::{locked, wait_on, wait_timeout_on};

use super::scheduler::{Policy, Request};

/// One request made visible to the workers, stamped with the wall-clock
/// instant it became visible (the online arrival time: queue wait and
/// end-to-end latency are measured from here).
pub struct ArrivedRequest {
    pub req: Request,
    pub enqueued: Instant,
    /// absolute completion deadline (None = no deadline)
    pub deadline_at: Option<Instant>,
    /// streaming reply channel of the connection that submitted this
    /// request (None for trace replay, where nobody is waiting)
    pub reply: Option<Sender<Reply>>,
    /// arrival sequence number — the FIFO tiebreak inside every policy
    pub(crate) seq: u64,
    /// completed service attempts (0 on arrival; bumped each time a
    /// supervised restart requeues this request for replay from scratch)
    pub(crate) attempts: u32,
}

/// How the producer paces the trace into the queue.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pacing {
    /// Replay arrival stamps in wall-clock time, scaled by `time_scale`
    /// (2.0 = half speed, 0.5 = double speed, 0.0 = flood everything
    /// immediately and measure pure drain throughput).
    Replay { time_scale: f64 },
    /// Keep exactly `clients` requests outstanding (queued or in flight);
    /// arrival stamps are ignored.
    ClosedLoop { clients: usize },
}

impl Pacing {
    pub fn name(&self) -> &'static str {
        match self {
            Pacing::Replay { .. } => "replay",
            Pacing::ClosedLoop { .. } => "closed-loop",
        }
    }
}

/// Streaming events sent back to whoever submitted a request.
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    /// One generated token (index 0 is the prefill argmax).
    Token { index: usize, token: i32 },
    /// The request retired normally. `tokens` is the full generated
    /// sequence (empty for scoring requests, which carry `nll` instead).
    /// `degraded` marks an answer served from the sparser degrade tier
    /// (bit-exact for *that* checkpoint, not the primary).
    Done { tokens: Vec<i32>, nll: Option<f64>, deadline_met: bool, degraded: bool },
    /// The request was shed from the queue after its deadline passed.
    Shed { waited_s: f64 },
    /// The request terminally failed after `attempts` service attempts
    /// (worker died mid-service and the retry budget or deadline was
    /// exhausted — or tokens had already streamed, so a replay could
    /// never be spliced without emitting a token twice).
    Failed { attempts: u32 },
}

/// Why a push was turned away at the door.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// per-client token bucket empty (checked by the caller, `serve::net`)
    RateLimited,
    /// bounded queue at capacity
    QueueFull,
    /// deadline already passed, or predicted unmeetable at admission
    DeadlineUnmeetable,
    /// the queue is closed — the server is draining
    Draining,
}

impl RejectReason {
    pub fn label(&self) -> &'static str {
        match self {
            RejectReason::RateLimited => "rate-limited",
            RejectReason::QueueFull => "queue-full",
            RejectReason::DeadlineUnmeetable => "deadline-unmeetable",
            RejectReason::Draining => "draining",
        }
    }

    /// HTTP-style status for the wire: 429 for rate limiting (the client
    /// should back off and retry), 503 for server-side overload.
    pub fn http_code(&self) -> u16 {
        match self {
            RejectReason::RateLimited => 429,
            _ => 503,
        }
    }
}

/// Outcome of a push.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admit {
    Queued,
    Rejected(RejectReason),
}

/// A request shed from the queue (deadline passed while waiting).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShedOutcome {
    pub id: usize,
    pub waited_s: f64,
}

/// A request rejected at push.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RejectOutcome {
    pub id: usize,
    pub reason: RejectReason,
}

/// Overload-control knobs of an [`IngestQueue`].
#[derive(Debug, Clone, Copy)]
pub struct QueueConfig {
    /// pop-order policy (output-invariant; see [`Policy`])
    pub policy: Policy,
    /// queued-request cap; 0 = unbounded (trace-replay benches)
    pub capacity: usize,
    /// how many workers drain this queue — scales the admit-time
    /// wait estimate
    pub workers_hint: usize,
    /// predictive admit-time shedding: reject a deadline-carrying request
    /// when `(queued + in_flight + 1) * ewma_service / workers` already
    /// exceeds its deadline (a conservative scalar estimate — batching
    /// makes real service faster, so this only trips when the backlog is
    /// hopeless)
    pub admit_reject: bool,
}

impl Default for QueueConfig {
    fn default() -> Self {
        QueueConfig { policy: Policy::Fifo, capacity: 0, workers_hint: 1, admit_reject: false }
    }
}

struct QueueState {
    ready: VecDeque<ArrivedRequest>,
    closed: bool,
    /// popped by a worker and not yet retired (closed-loop accounting)
    in_flight: usize,
    /// arrival counter, the stable tiebreak for every policy
    next_seq: u64,
    /// EWMA of per-request service seconds (admit-time wait estimate)
    ewma_service_s: f64,
    shed: Vec<ShedOutcome>,
    rejected: Vec<RejectOutcome>,
}

/// Shared arrival queue between producers (trace replay or connection
/// handlers) and N serving workers.
pub struct IngestQueue {
    cfg: QueueConfig,
    state: Mutex<QueueState>,
    /// signaled on push / close: workers waiting for work
    arrived: Condvar,
    /// signaled on retire: a closed-loop producer waiting for a slot
    retired: Condvar,
}

impl Default for IngestQueue {
    fn default() -> Self {
        IngestQueue::new()
    }
}

/// True when `a` should be served before `b` under `policy`. Strict —
/// equal keys keep arrival order, so every policy is a stable sort.
fn orders_before(a: &ArrivedRequest, b: &ArrivedRequest, policy: Policy) -> bool {
    match policy {
        Policy::Fifo => false,
        Policy::Priority => (a.req.qos.priority, a.seq) < (b.req.qos.priority, b.seq),
        // deadline-free requests (None) sort after every deadline via the
        // is_none() bool; FIFO among themselves via seq
        Policy::Edf => {
            (a.deadline_at.is_none(), a.deadline_at, a.seq)
                < (b.deadline_at.is_none(), b.deadline_at, b.seq)
        }
    }
}

impl IngestQueue {
    pub fn new() -> IngestQueue {
        IngestQueue::with_config(QueueConfig::default())
    }

    pub fn with_config(cfg: QueueConfig) -> IngestQueue {
        IngestQueue {
            cfg,
            state: Mutex::new(QueueState {
                ready: VecDeque::new(),
                closed: false,
                in_flight: 0,
                next_seq: 0,
                ewma_service_s: 0.0,
                shed: Vec::new(),
                rejected: Vec::new(),
            }),
            arrived: Condvar::new(),
            retired: Condvar::new(),
        }
    }

    /// Make one request visible to the workers (stamped now). Trace
    /// replay: nobody waits on a reply channel, rejections only land in
    /// the ledger.
    pub fn push(&self, req: Request) -> Admit {
        self.push_opts(req, None)
    }

    /// Full-control push: overload checks in order — draining, bounded
    /// capacity, deadline (expired now / predicted unmeetable) — then
    /// policy-ordered insertion. `reply` receives streamed tokens and the
    /// terminal event if the caller is a live connection.
    pub fn push_opts(&self, req: Request, reply: Option<Sender<Reply>>) -> Admit {
        let now = Instant::now();
        let deadline_at = deadline_after(now, req.qos.deadline_s);
        let rejection = {
            let mut g = locked(&self.state);
            let reason = if g.closed {
                Some(RejectReason::Draining)
            } else if self.cfg.capacity > 0 && g.ready.len() >= self.cfg.capacity {
                Some(RejectReason::QueueFull)
            } else if matches!(deadline_at, Some(d) if d <= now) {
                Some(RejectReason::DeadlineUnmeetable)
            } else if self.cfg.admit_reject
                && req.qos.deadline_s.is_finite()
                && g.ewma_service_s > 0.0
                && {
                    let backlog = (g.ready.len() + g.in_flight + 1) as f64;
                    backlog * g.ewma_service_s / self.cfg.workers_hint.max(1) as f64
                        > req.qos.deadline_s
                }
            {
                Some(RejectReason::DeadlineUnmeetable)
            } else {
                None
            };
            match reason {
                Some(r) => {
                    g.rejected.push(RejectOutcome { id: req.id, reason: r });
                    Some(r)
                }
                None => {
                    let seq = g.next_seq;
                    g.next_seq += 1;
                    let arrived =
                        ArrivedRequest { req, enqueued: now, deadline_at, reply, seq, attempts: 0 };
                    // stable back-scan insertion: arrivals are usually
                    // near their final slot, and FIFO never scans at all
                    let mut pos = g.ready.len();
                    while pos > 0 && orders_before(&arrived, &g.ready[pos - 1], self.cfg.policy) {
                        pos -= 1;
                    }
                    if pos == g.ready.len() {
                        g.ready.push_back(arrived);
                    } else {
                        g.ready.insert(pos, arrived);
                    }
                    None
                }
            }
        };
        match rejection {
            Some(r) => Admit::Rejected(r),
            None => {
                self.arrived.notify_all();
                Admit::Queued
            }
        }
    }

    /// No more pushes will follow; workers drain what is queued and exit.
    pub fn close(&self) {
        locked(&self.state).closed = true;
        self.arrived.notify_all();
    }

    /// Pop the front request iff `admit` accepts it, after sweeping out
    /// every queued request whose deadline has already passed (in-queue
    /// shedding). Within the policy order a declined front request stays
    /// at the front — head-of-line blocking is deliberate, no admitted
    /// request can starve behind later arrivals.
    pub fn try_pop(&self, admit: impl FnOnce(&Request) -> bool) -> Pop {
        let mut expired: Vec<(Option<Sender<Reply>>, f64)> = Vec::new();
        let popped = {
            let mut g = locked(&self.state);
            let now = Instant::now();
            let mut i = 0;
            while i < g.ready.len() {
                if matches!(g.ready[i].deadline_at, Some(d) if d <= now) {
                    if let Some(dead) = g.ready.remove(i) {
                        let waited_s = now.saturating_duration_since(dead.enqueued).as_secs_f64();
                        g.shed.push(ShedOutcome { id: dead.req.id, waited_s });
                        expired.push((dead.reply, waited_s));
                    }
                } else {
                    i += 1;
                }
            }
            let decision = g.ready.front().map(|front| admit(&front.req));
            match decision {
                Some(true) => match g.ready.pop_front() {
                    Some(a) => {
                        g.in_flight += 1;
                        Pop::Got(a)
                    }
                    // unreachable (front() just matched under this guard),
                    // but Empty is the safe answer if it ever weren't
                    None => Pop::Empty,
                },
                Some(false) => Pop::Refused,
                None if g.closed => Pop::Drained,
                None => Pop::Empty,
            }
        };
        // shed notifications go out after the lock is released
        for (reply, waited_s) in expired {
            if let Some(tx) = reply {
                let _ = tx.send(Reply::Shed { waited_s });
            }
        }
        popped
    }

    /// Block until something arrives or the queue closes, up to `timeout`
    /// (bounded so callers can re-check their own state).
    pub fn wait_arrival(&self, timeout: Duration) {
        let g = locked(&self.state);
        if g.ready.is_empty() && !g.closed {
            let _ = wait_timeout_on(&self.arrived, g, timeout);
        }
    }

    /// A popped request retired after `service_s` seconds of service;
    /// frees one closed-loop client slot and feeds the admit-time wait
    /// estimate (ignored when not positive).
    pub fn note_done(&self, service_s: f64) {
        let mut g = locked(&self.state);
        debug_assert!(g.in_flight > 0, "note_done without a matching pop");
        g.in_flight = g.in_flight.saturating_sub(1);
        if service_s > 0.0 {
            g.ewma_service_s = if g.ewma_service_s > 0.0 {
                0.8 * g.ewma_service_s + 0.2 * service_s
            } else {
                service_s
            };
        }
        drop(g);
        self.retired.notify_all();
    }

    /// Return a popped-but-unserved request to the queue for replay from
    /// scratch (supervised-restart recovery). A requeue is *not* a new
    /// arrival: it bypasses the draining/capacity/deadline admission
    /// checks (the request was already admitted once — its expired
    /// deadline, if any, is the pop-time sweep's business) and reinserts
    /// by its **original** seq, so under every policy the request takes
    /// exactly the place in line it held before the worker died.
    pub(crate) fn requeue(&self, a: ArrivedRequest) {
        {
            let mut g = locked(&self.state);
            debug_assert!(g.in_flight > 0, "requeue without a matching pop");
            g.in_flight = g.in_flight.saturating_sub(1);
            let mut pos = g.ready.len();
            // orders_before alone is total for Priority/Edf (seq is in the
            // key); Fifo compares nothing, so fall through to raw seq
            while pos > 0 && {
                let b = &g.ready[pos - 1];
                orders_before(&a, b, self.cfg.policy)
                    || (!orders_before(b, &a, self.cfg.policy) && a.seq < b.seq)
            } {
                pos -= 1;
            }
            if pos == g.ready.len() {
                g.ready.push_back(a);
            } else {
                g.ready.insert(pos, a);
            }
        }
        self.arrived.notify_all();
    }

    /// A popped request terminally failed (its ledger entry is the
    /// caller's business — the queue only releases the in-flight slot so
    /// closed-loop pacing and drain accounting stay exact).
    pub(crate) fn note_failed(&self) {
        {
            let mut g = locked(&self.state);
            debug_assert!(g.in_flight > 0, "note_failed without a matching pop");
            g.in_flight = g.in_flight.saturating_sub(1);
        }
        self.retired.notify_all();
    }

    /// Queue pressure snapshot for degrade routing: (queued depth, EWMA
    /// of per-request service seconds; 0 before any retirement).
    pub fn pressure(&self) -> (usize, f64) {
        let g = locked(&self.state);
        (g.ready.len(), g.ewma_service_s)
    }

    /// Closed-loop producer throttle: block until fewer than `clients`
    /// requests are outstanding (queued + in flight).
    pub fn wait_capacity(&self, clients: usize) {
        let mut g = locked(&self.state);
        while g.ready.len() + g.in_flight >= clients {
            g = wait_on(&self.retired, g);
        }
    }

    /// True once the queue is closed and empty — in-flight work may still
    /// be decoding, but no worker will ever pop again.
    pub fn is_drained(&self) -> bool {
        let g = locked(&self.state);
        g.closed && g.ready.is_empty()
    }

    /// Drain the shed/rejected ledgers (each outcome reported once).
    pub fn take_outcomes(&self) -> (Vec<ShedOutcome>, Vec<RejectOutcome>) {
        let mut g = locked(&self.state);
        (std::mem::take(&mut g.shed), std::mem::take(&mut g.rejected))
    }
}

/// Absolute deadline for a relative one; None when there is no deadline
/// (infinite or otherwise unrepresentable).
fn deadline_after(now: Instant, deadline_s: f64) -> Option<Instant> {
    if !deadline_s.is_finite() || deadline_s < 0.0 {
        return None;
    }
    Duration::try_from_secs_f64(deadline_s).ok().and_then(|d| now.checked_add(d))
}

/// Outcome of a conditional pop.
pub enum Pop {
    /// The front request passed the caller's admission predicate.
    Got(ArrivedRequest),
    /// A front request exists but the caller declined it (budget full).
    Refused,
    /// Nothing queued right now; the producer is still running.
    Empty,
    /// Queue empty and closed — no more work will ever arrive.
    Drained,
}

/// Feed `requests` (sorted by arrival for [`Pacing::Replay`]) into the
/// queue under the given pacing, then close it. Runs on its own scoped
/// thread next to the serving workers. Rejected pushes (bounded queue,
/// unmeetable deadlines) land in the queue's ledger.
pub fn run_producer(queue: &IngestQueue, requests: Vec<Request>, pacing: Pacing) {
    let start = Instant::now();
    match pacing {
        Pacing::Replay { time_scale } => {
            for r in requests {
                let due = r.arrival * time_scale;
                let elapsed = start.elapsed().as_secs_f64();
                if due > elapsed {
                    std::thread::sleep(Duration::from_secs_f64(due - elapsed));
                }
                let _ = queue.push(r);
            }
        }
        Pacing::ClosedLoop { clients } => {
            for r in requests {
                queue.wait_capacity(clients.max(1));
                let _ = queue.push(r);
            }
        }
    }
    queue.close();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::{Qos, ReqKind};

    fn req(id: usize, cost: usize) -> Request {
        Request {
            id,
            arrival: 0.0,
            tokens: vec![0; cost],
            kind: ReqKind::Score,
            qos: Qos::default(),
        }
    }

    fn req_qos(id: usize, qos: Qos) -> Request {
        Request { id, arrival: 0.0, tokens: vec![0; 4], kind: ReqKind::Score, qos }
    }

    fn pop_ids(q: &IngestQueue) -> Vec<usize> {
        let mut ids = Vec::new();
        while let Pop::Got(a) = q.try_pop(|_| true) {
            ids.push(a.req.id);
            q.note_done(0.0);
        }
        ids
    }

    #[test]
    fn fifo_pop_with_admission_predicate() {
        let q = IngestQueue::new();
        q.push(req(0, 8));
        q.push(req(1, 2));
        // front declined: later cheaper request must NOT jump the queue
        assert!(matches!(q.try_pop(|r| r.cost() <= 4), Pop::Refused));
        match q.try_pop(|r| r.cost() <= 8) {
            Pop::Got(a) => assert_eq!(a.req.id, 0),
            _ => panic!("front should be admitted"),
        }
        match q.try_pop(|_| true) {
            Pop::Got(a) => assert_eq!(a.req.id, 1),
            _ => panic!("second request should be admitted"),
        }
        assert!(matches!(q.try_pop(|_| true), Pop::Empty));
        q.close();
        assert!(matches!(q.try_pop(|_| true), Pop::Drained));
        assert!(q.is_drained());
    }

    #[test]
    fn closed_loop_throttles_outstanding() {
        let q = IngestQueue::new();
        let requests: Vec<Request> = (0..6).map(|i| req(i, 1)).collect();
        let served = crate::util::par::scoped_workers(2, |i| {
            if i == 0 {
                run_producer(&q, requests.clone(), Pacing::ClosedLoop { clients: 2 });
                0
            } else {
                // consumer: at most 2 can ever be queued+in-flight
                let mut got = 0usize;
                loop {
                    match q.try_pop(|_| true) {
                        Pop::Got(_) => {
                            got += 1;
                            q.note_done(0.0);
                        }
                        Pop::Drained => break,
                        _ => q.wait_arrival(Duration::from_millis(1)),
                    }
                }
                got
            }
        });
        assert_eq!(served[1], 6, "all requests flow through the closed loop");
    }

    #[test]
    fn replay_flood_preserves_order() {
        let q = IngestQueue::new();
        let requests: Vec<Request> = (0..5).map(|i| req(i, 1)).collect();
        run_producer(&q, requests, Pacing::Replay { time_scale: 0.0 });
        let mut ids = Vec::new();
        loop {
            match q.try_pop(|_| true) {
                Pop::Got(a) => {
                    ids.push(a.req.id);
                    q.note_done(0.0);
                }
                Pop::Drained => break,
                _ => unreachable!("flooded queue is never empty before drain"),
            }
        }
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn priority_policy_orders_tiers_fifo_within_tier() {
        let q = IngestQueue::with_config(QueueConfig {
            policy: Policy::Priority,
            ..Default::default()
        });
        for (id, tier) in [(0, 2u8), (1, 0), (2, 1), (3, 0), (4, 2)] {
            let admit = q.push(req_qos(id, Qos { priority: tier, ..Qos::default() }));
            assert_eq!(admit, Admit::Queued);
        }
        // tier 0 first (arrival order 1 then 3), then tier 1, then tier 2
        assert_eq!(pop_ids(&q), vec![1, 3, 2, 0, 4]);
    }

    #[test]
    fn edf_policy_orders_by_deadline_none_last() {
        let q = IngestQueue::with_config(QueueConfig { policy: Policy::Edf, ..Default::default() });
        q.push(req_qos(0, Qos::with_deadline(5.0)));
        q.push(req_qos(1, Qos::default())); // no deadline → last
        q.push(req_qos(2, Qos::with_deadline(1.0)));
        q.push(req_qos(3, Qos::with_deadline(3.0)));
        assert_eq!(pop_ids(&q), vec![2, 3, 0, 1]);
    }

    #[test]
    fn bounded_queue_rejects_when_full() {
        let q = IngestQueue::with_config(QueueConfig { capacity: 2, ..Default::default() });
        assert_eq!(q.push(req(0, 1)), Admit::Queued);
        assert_eq!(q.push(req(1, 1)), Admit::Queued);
        assert_eq!(q.push(req(2, 1)), Admit::Rejected(RejectReason::QueueFull));
        // popping one frees a slot
        assert!(matches!(q.try_pop(|_| true), Pop::Got(_)));
        assert_eq!(q.push(req(3, 1)), Admit::Queued);
        let (_, rejected) = q.take_outcomes();
        assert_eq!(rejected, vec![RejectOutcome { id: 2, reason: RejectReason::QueueFull }]);
    }

    #[test]
    fn expired_deadline_rejected_at_push() {
        let q = IngestQueue::new();
        let admit = q.push(req_qos(7, Qos::with_deadline(0.0)));
        assert_eq!(admit, Admit::Rejected(RejectReason::DeadlineUnmeetable));
    }

    #[test]
    fn predictive_admit_reject_uses_service_ewma() {
        let q = IngestQueue::with_config(QueueConfig {
            workers_hint: 1,
            admit_reject: true,
            ..Default::default()
        });
        // no service history yet: deadline-carrying requests are admitted
        assert_eq!(q.push(req_qos(0, Qos::with_deadline(0.5))), Admit::Queued);
        assert!(matches!(q.try_pop(|_| true), Pop::Got(_)));
        q.note_done(1.0); // EWMA seeds at 1s per request
        // 1 request of backlog (itself) * 1s > 0.5s deadline → hopeless
        assert_eq!(
            q.push(req_qos(1, Qos::with_deadline(0.5))),
            Admit::Rejected(RejectReason::DeadlineUnmeetable)
        );
        // a relaxed deadline still gets in
        assert_eq!(q.push(req_qos(2, Qos::with_deadline(5.0))), Admit::Queued);
        // deadline-free requests are never predictively rejected
        assert_eq!(q.push(req_qos(3, Qos::default())), Admit::Queued);
    }

    #[test]
    fn in_queue_shedding_notifies_and_records() {
        let q = IngestQueue::new();
        let (tx, rx) = std::sync::mpsc::channel();
        let admit = q.push_opts(req_qos(4, Qos::with_deadline(0.002)), Some(tx));
        assert_eq!(admit, Admit::Queued);
        std::thread::sleep(Duration::from_millis(10));
        // the sweep runs at pop time: the expired request never reaches
        // a worker, and the waiting connection hears about it
        assert!(matches!(q.try_pop(|_| true), Pop::Empty));
        match rx.try_recv() {
            Ok(Reply::Shed { waited_s }) => assert!(waited_s >= 0.002),
            other => panic!("expected a shed notification, got {other:?}"),
        }
        let (shed, rejected) = q.take_outcomes();
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 4);
        assert!(rejected.is_empty());
        // ledgers drain exactly once
        let (shed2, _) = q.take_outcomes();
        assert!(shed2.is_empty());
    }

    #[test]
    fn requeue_restores_original_position() {
        // FIFO: a requeued request goes back to the *front* of later
        // arrivals (its original seq), not the back of the line
        let q = IngestQueue::new();
        for i in 0..3 {
            q.push(req(i, 1));
        }
        let mut a = match q.try_pop(|_| true) {
            Pop::Got(a) => a,
            _ => panic!("front should pop"),
        };
        assert_eq!(a.req.id, 0);
        a.attempts += 1;
        q.requeue(a);
        assert_eq!(pop_ids(&q), vec![0, 1, 2]);

        // EDF: requeue honors the deadline order, seq only as tiebreak
        let q = IngestQueue::with_config(QueueConfig { policy: Policy::Edf, ..Default::default() });
        q.push(req_qos(0, Qos::with_deadline(5.0)));
        q.push(req_qos(1, Qos::with_deadline(1.0)));
        let a = match q.try_pop(|_| true) {
            Pop::Got(a) => a,
            _ => panic!("front should pop"),
        };
        assert_eq!(a.req.id, 1, "EDF serves the tighter deadline first");
        q.requeue(a);
        assert_eq!(pop_ids(&q), vec![1, 0]);
    }

    #[test]
    fn requeue_bypasses_admission_checks() {
        // a full, closed queue still takes a requeue — it is a replay of
        // an already-admitted request, not a new arrival
        let q = IngestQueue::with_config(QueueConfig { capacity: 1, ..Default::default() });
        q.push(req(0, 1));
        let a = match q.try_pop(|_| true) {
            Pop::Got(a) => a,
            _ => panic!("front should pop"),
        };
        q.push(req(1, 1)); // refills capacity
        q.close();
        q.requeue(a);
        assert_eq!(pop_ids(&q), vec![0, 1]);
        assert!(q.is_drained());
    }

    #[test]
    fn note_failed_frees_the_in_flight_slot() {
        let q = IngestQueue::new();
        q.push(req(0, 1));
        assert!(matches!(q.try_pop(|_| true), Pop::Got(_)));
        q.note_failed();
        // wait_capacity(1) would deadlock if the slot leaked
        q.wait_capacity(1);
        let (depth, ewma) = q.pressure();
        assert_eq!(depth, 0);
        assert_eq!(ewma, 0.0, "failures never feed the service-time EWMA");
    }

    #[test]
    fn push_after_close_rejected_as_draining() {
        let q = IngestQueue::new();
        q.close();
        assert_eq!(q.push(req(0, 1)), Admit::Rejected(RejectReason::Draining));
        assert!(q.is_drained());
    }

    /// Graceful-drain accounting: every submitted request lands in
    /// exactly one ledger (popped, shed, or rejected) — none lost, none
    /// double-counted.
    #[test]
    fn drain_accounting_is_exact() {
        let q = IngestQueue::with_config(QueueConfig { capacity: 3, ..Default::default() });
        let mut queued = 0usize;
        let mut rejected_now = 0usize;
        for i in 0..5 {
            // one of the five has an already-expired deadline
            let r = if i == 2 { req_qos(i, Qos::with_deadline(0.0)) } else { req(i, 1) };
            match q.push(r) {
                Admit::Queued => queued += 1,
                Admit::Rejected(_) => rejected_now += 1,
            }
        }
        // capacity 3 + one expired: 3 queued, 2 rejected
        assert_eq!((queued, rejected_now), (3, 2));
        q.close();
        let popped = pop_ids(&q).len();
        let (shed, rejected) = q.take_outcomes();
        assert_eq!(popped + shed.len() + rejected.len(), 5);
        assert_eq!(rejected.len(), rejected_now);
    }
}
