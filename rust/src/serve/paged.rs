//! Paged KV allocation: fixed-size pages drawn from a shared
//! [`PagePool`], one [`PageTable`] per request, refcounted copy-on-write
//! prefix sharing and free-list reuse — the serving-memory counterpart of
//! the contiguous [`KvCache`].
//!
//! # Page layout
//!
//! A [`Page`] holds `page_tokens` consecutive positions for *every*
//! block: `[n_blocks, page_tokens, d]` row-major per tensor — exactly a
//! contiguous [`KvCache`] with `capacity == page_tokens`. Page `i` of a
//! table covers positions `i*page_tokens .. (i+1)*page_tokens`, so the
//! committed rows of a block are a sequence of contiguous runs
//! ([`Kv::segment`]) and the cached-attention kernel walks them in
//! ascending position order — the paged read path is bitwise identical
//! to the contiguous one (see [`crate::kernel::attn::dots_gather`]).
//!
//! # Pool invariants
//!
//! * **Conservation** — every buffer the pool ever created is either
//!   referenced by a live page or parked on the free list:
//!   `live + free == created`, always ([`PoolStats`]). A page's buffers
//!   return to the free list exactly once, when its last `Arc` drops.
//! * **Bounded residency** — with `max_pages > 0`,
//!   `live + reserved <= max_pages`, always. Admission *reserves* the
//!   worst-case page count of a request up front
//!   ([`PagePool::new_table`]); an admitted request therefore never runs
//!   out of pages mid-decode, and exhaustion is a deterministic
//!   admission-time event (surfacing as 503/shed in `serve/net`, never a
//!   panic).
//! * **Copy-on-write** — pages are shared between tables by refcount
//!   ([`PageTable::fork`]); the first write into a shared page clones it
//!   into a fresh page first, so a fork never mutates its parent's
//!   pages. The clone is paid for by the forking table's reservation.
//!
//! # Lock order
//!
//! The pool mutex (`PoolCore::state`) is a leaf lock: nothing else is
//! ever acquired while holding it. [`PrefixRegistry`] acquires its entry
//! lock first and may then take the pool lock (fork/reserve) — always in
//! that order.

use std::sync::{Arc, Mutex};

use crate::kernel::attn::KvSegment;
use crate::util::par::locked;

use super::kv::KvCache;

/// Pages needed to hold `tokens` positions at `page_tokens` per page.
pub fn pages_for(tokens: usize, page_tokens: usize) -> usize {
    tokens.div_ceil(page_tokens)
}

/// One fixed-size page of KV rows: `[n_blocks, page_tokens, d]` row-major
/// per tensor. Shared between tables via `Arc`; dropping the last
/// reference recycles the buffers into the owning pool's free list
/// (never freeing them behind the pool's accounting).
pub struct Page {
    /// roped keys, `[n_blocks, page_tokens, d]`
    k: Vec<f32>,
    /// raw values, `[n_blocks, page_tokens, d]`
    v: Vec<f32>,
    core: Arc<PoolCore>,
}

impl Drop for Page {
    fn drop(&mut self) {
        let k = std::mem::take(&mut self.k);
        let v = std::mem::take(&mut self.v);
        let mut g = locked(&self.core.state);
        debug_assert!(g.live > 0, "page drop without a live count");
        g.live = g.live.saturating_sub(1);
        g.free.push((k, v));
    }
}

/// Mutable pool state behind the (leaf) pool mutex.
struct PoolState {
    /// recycled `(k, v)` buffers awaiting reuse
    free: Vec<(Vec<f32>, Vec<f32>)>,
    /// pages currently referenced by at least one table (shared once)
    live: usize,
    /// admission reservations not yet materialized into pages
    reserved: usize,
    /// buffers ever created; `live + free.len() == created`, always
    created: usize,
    /// high-water mark of `live` (resident-bytes reporting)
    peak_live: usize,
    /// copy-on-write clones performed
    cow_clones: usize,
}

struct PoolCore {
    n_blocks: usize,
    d: usize,
    page_tokens: usize,
    /// cap on `live + reserved`; 0 = unbounded
    max_pages: usize,
    state: Mutex<PoolState>,
}

/// Snapshot of a pool's accounting, for benches and invariant checks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    pub live: usize,
    pub free: usize,
    pub reserved: usize,
    pub created: usize,
    pub peak_live: usize,
    pub cow_clones: usize,
}

impl PoolStats {
    /// The chaos suite's pool-drain invariant: every page released
    /// (`live == 0`) and every created page accounted for
    /// (`live + free == created`) — true after any clean shutdown,
    /// including one that survived injected faults and client
    /// disconnects.
    pub fn drained(&self) -> bool {
        self.live == 0 && self.live + self.free == self.created
    }
}

/// A shared pool of fixed-size KV pages (cheaply clonable handle).
///
/// # Invariants
///
/// * `live + free == created` — no buffer leaks, none is double-freed
///   (pinned after every step by `tests/properties.rs`).
/// * with `max_pages > 0`: `live + reserved <= max_pages` — admission
///   reservations and resident pages never oversubscribe the cap.
#[derive(Clone)]
pub struct PagePool {
    core: Arc<PoolCore>,
}

impl PagePool {
    /// `max_pages == 0` leaves residency unbounded.
    pub fn new(n_blocks: usize, d: usize, page_tokens: usize, max_pages: usize) -> PagePool {
        assert!(n_blocks > 0 && d > 0 && page_tokens > 0, "degenerate page shape");
        PagePool {
            core: Arc::new(PoolCore {
                n_blocks,
                d,
                page_tokens,
                max_pages,
                state: Mutex::new(PoolState {
                    free: Vec::new(),
                    live: 0,
                    reserved: 0,
                    created: 0,
                    peak_live: 0,
                    cow_clones: 0,
                }),
            }),
        }
    }

    pub fn page_tokens(&self) -> usize {
        self.core.page_tokens
    }

    /// `live + reserved` cap; 0 = unbounded.
    pub fn max_pages(&self) -> usize {
        self.core.max_pages
    }

    /// Resident bytes of one page (both tensors).
    pub fn page_bytes(&self) -> usize {
        2 * self.core.n_blocks * self.core.page_tokens * self.core.d * 4
    }

    pub fn stats(&self) -> PoolStats {
        let g = locked(&self.core.state);
        PoolStats {
            live: g.live,
            free: g.free.len(),
            reserved: g.reserved,
            created: g.created,
            peak_live: g.peak_live,
            cow_clones: g.cow_clones,
        }
    }

    /// Would a fresh table of `cost` tokens fit right now? Advisory (the
    /// answer can go stale); [`PagePool::new_table`] is the committing
    /// call.
    pub fn can_admit(&self, cost: usize) -> bool {
        if self.core.max_pages == 0 {
            return true;
        }
        let need = pages_for(cost, self.core.page_tokens);
        let g = locked(&self.core.state);
        g.live + g.reserved + need <= self.core.max_pages
    }

    /// Largest request cost any table could ever hold, `None` when the
    /// pool is unbounded. Requests above this must be rejected up front
    /// or they would wait for pages forever.
    pub fn max_cost_tokens(&self) -> Option<usize> {
        if self.core.max_pages == 0 {
            None
        } else {
            Some(self.core.max_pages * self.core.page_tokens)
        }
    }

    /// Reserve `n` future pages against the cap. False when they do not
    /// fit — nothing is taken.
    fn try_reserve(&self, n: usize) -> bool {
        let mut g = locked(&self.core.state);
        if self.core.max_pages > 0 && g.live + g.reserved + n > self.core.max_pages {
            return false;
        }
        g.reserved += n;
        true
    }

    /// Return `n` unused reservations to the cap.
    fn release(&self, n: usize) {
        if n > 0 {
            let mut g = locked(&self.core.state);
            debug_assert!(g.reserved >= n, "releasing more reservations than held");
            g.reserved = g.reserved.saturating_sub(n);
        }
    }

    /// Materialize one page, preferring the caller's reservation
    /// (`table_reserved` is decremented); without one, a fresh page is
    /// authorized against the cap — and the pool being full there is an
    /// allocator-misuse bug (admission must reserve first), reported as
    /// an assert, not a quiet corruption.
    fn take_page(&self, table_reserved: &mut usize) -> Arc<Page> {
        let core = &self.core;
        let (k, v) = {
            let mut g = locked(&core.state);
            if *table_reserved > 0 {
                *table_reserved -= 1;
                debug_assert!(g.reserved > 0, "table reservation not mirrored in pool");
                g.reserved = g.reserved.saturating_sub(1);
            } else {
                assert!(
                    core.max_pages == 0 || g.live + g.reserved < core.max_pages,
                    "page pool exhausted (live {}, reserved {}, cap {}): \
                     admission must reserve before writing",
                    g.live,
                    g.reserved,
                    core.max_pages
                );
            }
            g.live += 1;
            g.peak_live = g.peak_live.max(g.live);
            match g.free.pop() {
                Some(buf) => buf,
                None => {
                    g.created += 1;
                    let n = core.n_blocks * core.page_tokens * core.d;
                    (vec![0.0; n], vec![0.0; n])
                }
            }
        };
        Arc::new(Page { k, v, core: Arc::clone(core) })
    }

    fn note_cow(&self) {
        locked(&self.core.state).cow_clones += 1;
    }

    /// Open a fresh table able to hold `cost` tokens, reserving its
    /// worst-case page count up front. `None` when the pool cap cannot
    /// cover the reservation — the caller's clean-rejection path.
    pub fn new_table(&self, cost: usize) -> Option<PageTable> {
        let need = pages_for(cost, self.core.page_tokens);
        if !self.try_reserve(need) {
            return None;
        }
        Some(PageTable {
            pages: Vec::new(),
            len: 0,
            cap_tokens: cost,
            reserved: need,
            pool: self.clone(),
        })
    }
}

/// One request's view of pool pages: shared `Arc` pages plus the
/// outstanding admission reservation.
///
/// # Invariants
///
/// * `len <= pages.len() * page_tokens` — committed positions are backed
///   by materialized pages; `set_len` only commits rows already written.
/// * The table can always materialize up to `cap_tokens` positions: its
///   reservation covers every page it may still need, *including* the
///   copy-on-write clone of a partially-shared boundary page after
///   [`PageTable::fork`]. Writes past `cap_tokens` are a caller bug
///   (asserted), mirroring [`KvCache`]'s capacity check.
/// * Writes never mutate a page another table can see: a shared page
///   (refcount > 1) is cloned before the row lands.
///
/// Dropping the table releases its unused reservation and unpins its
/// pages; pages it alone referenced recycle into the pool free list.
/// Moving a `PageTable` between workers migrates the whole cache without
/// copying any KV bytes — the decode work-stealing handoff.
pub struct PageTable {
    pages: Vec<Arc<Page>>,
    len: usize,
    /// capacity in tokens fixed at admission (the request's cost)
    cap_tokens: usize,
    /// pages this table may still materialize without re-asking the cap
    reserved: usize,
    pool: PagePool,
}

impl Drop for PageTable {
    fn drop(&mut self) {
        self.pool.release(self.reserved);
        self.reserved = 0;
    }
}

impl std::fmt::Debug for PageTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PageTable")
            .field("len", &self.len)
            .field("pages", &self.pages.len())
            .field("cap_tokens", &self.cap_tokens)
            .field("reserved", &self.reserved)
            .finish()
    }
}

impl PageTable {
    /// Committed positions (same for every block).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Token capacity fixed at admission.
    pub fn capacity(&self) -> usize {
        self.cap_tokens
    }

    /// Materialized pages (shared ones count; see [`PagePool::stats`] for
    /// the deduplicated pool-wide view).
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Per-page `Arc` strong counts — the COW refcounts the property
    /// suite asserts against its reference model.
    pub fn page_refcounts(&self) -> Vec<usize> {
        self.pages.iter().map(Arc::strong_count).collect()
    }

    /// Write the roped key / raw value rows of `block` at `pos`,
    /// materializing (and, for shared pages, copy-on-write cloning) the
    /// covering page first. Does not change `len`; commit with
    /// [`PageTable::set_len`] once every block has written the position.
    pub fn write(&mut self, block: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        let (nb, d, p) = (self.pool.core.n_blocks, self.pool.core.d, self.pool.core.page_tokens);
        assert!(block < nb && pos < self.cap_tokens, "kv write out of range");
        assert!(k_row.len() == d && v_row.len() == d);
        let pi = pos / p;
        while self.pages.len() <= pi {
            let page = self.pool.take_page(&mut self.reserved);
            self.pages.push(page);
        }
        if Arc::get_mut(&mut self.pages[pi]).is_none() {
            // shared page: clone before the first write lands in it
            let mut fresh = self.pool.take_page(&mut self.reserved);
            let fp = Arc::get_mut(&mut fresh);
            debug_assert!(fp.is_some(), "freshly allocated page is uniquely owned");
            if let Some(fp) = fp {
                fp.k.copy_from_slice(&self.pages[pi].k);
                fp.v.copy_from_slice(&self.pages[pi].v);
            }
            self.pool.note_cow();
            self.pages[pi] = fresh;
        }
        let off = (block * p + pos % p) * d;
        if let Some(pg) = Arc::get_mut(&mut self.pages[pi]) {
            pg.k[off..off + d].copy_from_slice(k_row);
            pg.v[off..off + d].copy_from_slice(v_row);
        }
    }

    /// Commit positions `0..len`. Shrinking is allowed (benches rewind);
    /// growing requires the rows to have been written (their pages exist).
    pub fn set_len(&mut self, len: usize) {
        let p = self.pool.core.page_tokens;
        assert!(len <= self.cap_tokens, "kv len {len} > capacity {}", self.cap_tokens);
        assert!(len <= self.pages.len() * p, "kv len {len} commits unwritten positions");
        self.len = len;
    }

    /// Contiguous runs of committed rows: `ceil(len / page_tokens)`.
    pub fn n_segments(&self) -> usize {
        pages_for(self.len, self.pool.core.page_tokens)
    }

    /// Committed rows of `block` inside page `si`, in ascending position
    /// order across `si` — the page-gather view the attention kernels
    /// walk.
    pub fn segment(&self, block: usize, si: usize) -> KvSegment<'_> {
        let (d, p) = (self.pool.core.d, self.pool.core.page_tokens);
        let rows = (self.len - si * p).min(p);
        let base = block * p * d;
        let page = &self.pages[si];
        KvSegment {
            k: &page.k[base..base + rows * d],
            v: &page.v[base..base + rows * d],
            rows,
        }
    }

    /// Fork a child sharing this table's pages over positions
    /// `0..prefix` (refcount bump — no KV bytes are copied) and able to
    /// grow to `cost` total tokens. The child's reservation covers its
    /// tail pages *plus* the copy-on-write clone of the boundary page
    /// when `prefix` is not page-aligned, so a forked admission still
    /// never fails mid-decode. `None` when the pool cap cannot cover the
    /// reservation.
    pub fn fork(&self, prefix: usize, cost: usize) -> Option<PageTable> {
        let p = self.pool.core.page_tokens;
        assert!(prefix <= self.len, "fork prefix {prefix} > committed {}", self.len);
        assert!(prefix <= cost, "fork prefix {prefix} > target capacity {cost}");
        let full = prefix / p;
        let shared = pages_for(prefix, p);
        let need = pages_for(cost, p).saturating_sub(full);
        if !self.pool.try_reserve(need) {
            return None;
        }
        Some(PageTable {
            pages: self.pages[..shared].to_vec(),
            len: prefix,
            cap_tokens: cost,
            reserved: need,
            pool: self.pool.clone(),
        })
    }
}

/// A per-request KV handle: one contiguous slab ([`KvCache`]) or a paged
/// table over a shared pool. The serving engine only ever goes through
/// this enum, so both representations run the *same* cached-attention
/// code — the paged == contiguous bitwise parity is by construction
/// (`tests/serve_parity.rs` pins it anyway).
pub enum Kv {
    Contig(KvCache),
    Paged(PageTable),
}

impl Kv {
    /// Committed positions (same for every block).
    pub fn len(&self) -> usize {
        match self {
            Kv::Contig(c) => c.len(),
            Kv::Paged(t) => t.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// See [`KvCache::write`] / [`PageTable::write`].
    pub fn write(&mut self, block: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        match self {
            Kv::Contig(c) => c.write(block, pos, k_row, v_row),
            Kv::Paged(t) => t.write(block, pos, k_row, v_row),
        }
    }

    /// See [`KvCache::set_len`] / [`PageTable::set_len`].
    pub fn set_len(&mut self, len: usize) {
        match self {
            Kv::Contig(c) => c.set_len(len),
            Kv::Paged(t) => t.set_len(len),
        }
    }

    /// Contiguous runs the committed rows of any block split into: 1 for
    /// a non-empty contiguous cache, `ceil(len / page_tokens)` pages for
    /// a paged one, 0 when empty.
    pub fn n_segments(&self) -> usize {
        match self {
            Kv::Contig(c) => usize::from(c.len() > 0),
            Kv::Paged(t) => t.n_segments(),
        }
    }

    /// Segment `si` of `block`'s committed rows, ascending in position
    /// across `si`.
    pub fn segment(&self, block: usize, si: usize) -> KvSegment<'_> {
        match self {
            Kv::Contig(c) => {
                debug_assert_eq!(si, 0);
                KvSegment { k: c.k_block(block), v: c.v_block(block), rows: c.len() }
            }
            Kv::Paged(t) => t.segment(block, si),
        }
    }

    /// Copy `block`'s committed rows (`len * d` floats per tensor) into
    /// contiguous buffers — the backend packing path and the parity
    /// suite's byte-compare view.
    pub fn gather_block_into(&self, block: usize, k_dst: &mut [f32], v_dst: &mut [f32]) {
        let mut at = 0;
        for si in 0..self.n_segments() {
            let seg = self.segment(block, si);
            k_dst[at..at + seg.k.len()].copy_from_slice(seg.k);
            v_dst[at..at + seg.v.len()].copy_from_slice(seg.v);
            at += seg.k.len();
        }
        debug_assert!(at == k_dst.len() && at == v_dst.len());
    }

    /// Resident bytes backing this handle (a paged table counts its
    /// materialized pages, shared ones included — see [`PagePool::stats`]
    /// for the deduplicated pool-wide number).
    pub fn mem_bytes(&self) -> usize {
        match self {
            Kv::Contig(c) => c.mem_bytes(),
            Kv::Paged(t) => t.n_pages() * t.pool.page_bytes(),
        }
    }

    /// The contiguous representation, when that is what this handle is.
    pub fn as_contig(&self) -> Option<&KvCache> {
        match self {
            Kv::Contig(c) => Some(c),
            Kv::Paged(_) => None,
        }
    }

    /// The page table, when this handle is paged.
    pub fn as_paged(&self) -> Option<&PageTable> {
        match self {
            Kv::Contig(_) => None,
            Kv::Paged(t) => Some(t),
        }
    }
}

/// Runtime choice of KV backing for a serving run (CLI `--kv`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvMode {
    /// one `[n_blocks, max_pos, d]` slab per request
    Contig,
    /// fixed-size pages from a shared pool; `max_pages == 0` = unbounded
    Paged { page_tokens: usize, max_pages: usize },
}

impl KvMode {
    pub fn name(&self) -> &'static str {
        match self {
            KvMode::Contig => "contig",
            KvMode::Paged { .. } => "paged",
        }
    }
}

/// A [`KvMode`] bound to its live pool for one run — what workers
/// allocate caches through.
#[derive(Clone)]
pub enum KvSpec {
    Contig,
    Paged(PagePool),
}

impl KvSpec {
    pub fn contig() -> KvSpec {
        KvSpec::Contig
    }

    /// Bind `mode` for a model with `n_blocks` blocks of width `d`
    /// (creates the shared pool in paged mode).
    pub fn for_mode(mode: KvMode, n_blocks: usize, d: usize) -> KvSpec {
        match mode {
            KvMode::Contig => KvSpec::Contig,
            KvMode::Paged { page_tokens, max_pages } => {
                KvSpec::Paged(PagePool::new(n_blocks, d, page_tokens, max_pages))
            }
        }
    }

    pub fn pool(&self) -> Option<&PagePool> {
        match self {
            KvSpec::Contig => None,
            KvSpec::Paged(p) => Some(p),
        }
    }

    /// Advisory: could a request of `cost` tokens get a cache right now?
    /// Contiguous allocation always can.
    pub fn can_admit(&self, cost: usize) -> bool {
        match self {
            KvSpec::Contig => true,
            KvSpec::Paged(p) => p.can_admit(cost),
        }
    }

    /// Largest request cost this spec can ever hold (`None` = no bound
    /// beyond the context length). Larger requests must be rejected up
    /// front — admitted, they would wait for pages forever.
    pub fn max_cost_tokens(&self) -> Option<usize> {
        match self {
            KvSpec::Contig => None,
            KvSpec::Paged(p) => p.max_cost_tokens(),
        }
    }

    /// Allocate a cache for one request: `capacity` positions for the
    /// contiguous slab (the context length), `cost` tokens reserved for
    /// the paged table. `None` only in paged mode, when the pool cap
    /// cannot cover the reservation.
    pub fn new_kv(&self, n_blocks: usize, d: usize, capacity: usize, cost: usize) -> Option<Kv> {
        match self {
            KvSpec::Contig => Some(Kv::Contig(KvCache::new(n_blocks, d, capacity))),
            KvSpec::Paged(p) => {
                debug_assert!(p.core.n_blocks == n_blocks && p.core.d == d, "pool/model shape");
                p.new_table(cost).map(Kv::Paged)
            }
        }
    }
}

/// Collect the per-request `&mut Kv` views of a batch for one decode
/// step — the one shared gather for every continuous-batching loop
/// (`serve::online`, `serve::bench`, `benches/serve_throughput`).
pub fn gather_caches<T>(items: &mut [T], kv: fn(&mut T) -> &mut Kv) -> Vec<&mut Kv> {
    items.iter_mut().map(kv).collect()
}

/// Shared-prompt registry: registered prompts keep a frozen [`PageTable`]
/// of their prefill KV state alive; later admissions fork from the
/// longest matching prefix instead of recomputing it. Entries pin pool
/// pages, so the registry is best-effort by design: registration skips
/// when the registry is full or the pool cannot cover the boundary-page
/// COW reservation, and [`PrefixRegistry::clear`] drops every entry when
/// the pool runs dry (admissions always beat caching).
///
/// Lock order: the entry lock is acquired first, the pool lock (inside
/// fork/reserve) second — never the reverse.
pub struct PrefixRegistry {
    entries: Mutex<Vec<(Vec<i32>, PageTable)>>,
    cap: usize,
}

impl PrefixRegistry {
    /// `cap` bounds the number of registered prompts.
    pub fn new(cap: usize) -> PrefixRegistry {
        PrefixRegistry { entries: Mutex::new(Vec::new()), cap }
    }

    pub fn len(&self) -> usize {
        locked(&self.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every entry, releasing the pages it pinned (modulo sharing).
    pub fn clear(&self) {
        locked(&self.entries).clear();
    }

    /// Fork the longest common prefix between `tokens` and any registered
    /// prompt into a fresh table able to hold `cost` tokens. Under causal
    /// attention a KV row depends only on the tokens at or before its
    /// position, so any shared prefix of the *tokens* makes the cached
    /// rows reusable — the registered prompt need not be a whole-prompt
    /// match. The prefix is capped at `tokens.len() - 1` so the final
    /// prompt position is always recomputed (its hidden row feeds the
    /// first-token logits), and prefixes shorter than one full page are
    /// skipped (the boundary page would copy-on-write immediately, so
    /// nothing would actually be shared). `None` when nothing qualifies
    /// or the pool cannot cover the fork.
    pub fn fork_longest(&self, tokens: &[i32], cost: usize) -> Option<(usize, PageTable)> {
        let g = locked(&self.entries);
        let limit = tokens.len().saturating_sub(1);
        let mut best: Option<(usize, &PageTable)> = None;
        for (key, table) in g.iter() {
            let cap = key.len().min(limit);
            let p0 = (0..cap).take_while(|&i| tokens[i] == key[i]).count();
            if p0 < table.pool.core.page_tokens {
                continue;
            }
            if best.map_or(true, |(b, _)| p0 > b) {
                best = Some((p0, table));
            }
        }
        let (p0, table) = best?;
        let forked = table.fork(p0, cost)?;
        Some((p0, forked))
    }

    /// Register `tokens`' prefill state by sharing `table`'s pages
    /// (refcount bump, no copy). When the prompt does not end on a page
    /// boundary the serving table's next decode write will COW the shared
    /// boundary page, so one extra page is reserved onto `table` here —
    /// if the pool cannot cover it (or the registry is full, or the
    /// prompt is already registered) registration is skipped.
    pub fn register(&self, tokens: &[i32], table: &mut PageTable) {
        let s = tokens.len();
        if s == 0 || s > table.len {
            return;
        }
        let mut g = locked(&self.entries);
        if g.len() >= self.cap || g.iter().any(|(k, _)| k == tokens) {
            return;
        }
        let p = table.pool.core.page_tokens;
        if s % p != 0 {
            if !table.pool.try_reserve(1) {
                return;
            }
            table.reserved += 1;
        }
        let frozen = PageTable {
            pages: table.pages[..pages_for(s, p)].to_vec(),
            len: s,
            cap_tokens: s,
            reserved: 0,
            pool: table.pool.clone(),
        };
        g.push((tokens.to_vec(), frozen));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- contiguous KvCache unit tests (moved from kv.rs so the two
    // representations are covered side by side) ------------------------

    #[test]
    fn contig_write_commit_read() {
        let mut c = KvCache::new(2, 3, 4);
        assert!(c.is_empty());
        c.write(0, 0, &[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        c.write(1, 0, &[7.0, 8.0, 9.0], &[1.0, 1.0, 1.0]);
        assert!(c.k_block(0).is_empty(), "uncommitted rows stay invisible");
        c.set_len(1);
        assert_eq!(c.k_block(0), &[1.0, 2.0, 3.0]);
        assert_eq!(c.v_block(1), &[1.0, 1.0, 1.0]);
        c.write(0, 1, &[0.5; 3], &[0.25; 3]);
        c.write(1, 1, &[0.5; 3], &[0.25; 3]);
        c.set_len(2);
        assert_eq!(c.len(), 2);
        assert_eq!(&c.k_block(0)[3..], &[0.5; 3]);
    }

    #[test]
    #[should_panic]
    fn contig_write_past_capacity_panics() {
        let mut c = KvCache::new(1, 2, 2);
        c.write(0, 2, &[0.0, 0.0], &[0.0, 0.0]);
    }

    // ---- paged ---------------------------------------------------------

    fn rows(kv: &Kv, block: usize) -> (Vec<f32>, Vec<f32>) {
        let d = match kv {
            Kv::Contig(c) => c.k_block(block).len() / kv.len().max(1),
            Kv::Paged(t) => t.pool.core.d,
        };
        let mut k = vec![0.0; kv.len() * d];
        let mut v = vec![0.0; kv.len() * d];
        kv.gather_block_into(block, &mut k, &mut v);
        (k, v)
    }

    #[test]
    fn paged_write_commit_read_matches_contig() {
        let pool = PagePool::new(2, 3, 2, 0);
        let mut t = Kv::Paged(pool.new_table(5).expect("unbounded pool"));
        let mut c = Kv::Contig(KvCache::new(2, 3, 5));
        for pos in 0..5 {
            for b in 0..2 {
                let kr = [pos as f32, b as f32, 0.5];
                let vr = [1.0, pos as f32, b as f32];
                t.write(b, pos, &kr, &vr);
                c.write(b, pos, &kr, &vr);
            }
            t.set_len(pos + 1);
            c.set_len(pos + 1);
        }
        for b in 0..2 {
            assert_eq!(rows(&t, b), rows(&c, b), "block {b}");
        }
        // 5 tokens at 2/page = 3 pages materialized
        assert_eq!(pool.stats().live, 3);
        let st = pool.stats();
        assert_eq!(st.live + st.free, st.created, "conservation");
    }

    #[test]
    fn free_list_recycles_buffers() {
        let pool = PagePool::new(1, 2, 2, 0);
        {
            let mut t = pool.new_table(4).expect("fits");
            t.write(0, 0, &[1.0, 2.0], &[3.0, 4.0]);
            t.write(0, 2, &[1.0, 2.0], &[3.0, 4.0]);
            assert_eq!(pool.stats().live, 2);
        }
        let st = pool.stats();
        assert_eq!((st.live, st.free, st.created), (0, 2, 2), "pages recycled on drop");
        {
            let mut t = pool.new_table(4).expect("fits");
            t.write(0, 0, &[0.0; 2], &[0.0; 2]);
            assert_eq!(pool.stats().created, 2, "reuse, not fresh allocation");
        }
    }

    #[test]
    fn pool_cap_bounds_reservations() {
        let pool = PagePool::new(1, 2, 2, 3);
        let t1 = pool.new_table(4).expect("2 pages fit");
        assert!(pool.new_table(4).is_none(), "2 + 2 > 3 pages");
        let t2 = pool.new_table(2).expect("third page fits");
        assert_eq!(pool.max_cost_tokens(), Some(6));
        drop(t1);
        drop(t2);
        assert!(pool.new_table(6).is_some(), "reservations released on drop");
    }

    #[test]
    fn fork_shares_then_cow_isolates() {
        let pool = PagePool::new(1, 2, 2, 0);
        let mut parent = pool.new_table(4).expect("fits");
        for pos in 0..3 {
            parent.write(0, pos, &[pos as f32, 1.0], &[pos as f32, 2.0]);
        }
        parent.set_len(3);
        assert_eq!(pool.stats().live, 2);

        // share positions 0..3: page 0 fully, page 1 partially
        let mut child = parent.fork(3, 4).expect("unbounded pool");
        assert_eq!(child.page_refcounts(), vec![2, 2], "both pages shared");
        assert_eq!(pool.stats().live, 2, "fork copies no pages");

        // the child's first write into the shared boundary page clones it
        child.write(0, 3, &[9.0, 9.0], &[8.0, 8.0]);
        child.set_len(4);
        assert_eq!(child.page_refcounts(), vec![2, 1]);
        assert_eq!(pool.stats().cow_clones, 1);
        assert_eq!(pool.stats().live, 3);

        // parent rows are untouched
        let pk = parent.segment(0, 1);
        assert_eq!(pk.k, &[2.0, 1.0], "parent boundary row survives the child's write");
        let ck = child.segment(0, 1);
        assert_eq!(&ck.k[..2], &[2.0, 1.0], "clone kept the shared row");
        assert_eq!(&ck.k[2..], &[9.0, 9.0]);
    }

    #[test]
    fn fork_reservation_covers_cow_and_tail() {
        // cap exactly: parent 2 pages live; child needs the boundary COW
        // clone + 1 tail page = 2 reservations; cap 4 fits, cap 3 refuses
        let pool = PagePool::new(1, 2, 2, 3);
        let mut parent = pool.new_table(4).expect("fits");
        for pos in 0..3 {
            parent.write(0, pos, &[0.0; 2], &[0.0; 2]);
        }
        parent.set_len(3);
        assert!(parent.fork(3, 6).is_none(), "3 live/reserved + 2 > 3");
        drop(parent);

        let pool = PagePool::new(1, 2, 2, 4);
        let mut parent = pool.new_table(4).expect("fits");
        for pos in 0..3 {
            parent.write(0, pos, &[0.0; 2], &[0.0; 2]);
        }
        parent.set_len(3);
        let mut child = parent.fork(3, 6).expect("2 reservations fit");
        for pos in 3..6 {
            child.write(0, pos, &[1.0; 2], &[1.0; 2]);
        }
        child.set_len(6);
        assert_eq!(child.len(), 6, "admitted fork never runs out of pages");
    }

    #[test]
    fn registry_forks_longest_prefix_and_clears_under_pressure() {
        let pool = PagePool::new(1, 2, 2, 0);
        let reg = PrefixRegistry::new(4);
        let prompt = vec![5, 6, 7, 8];
        let mut table = pool.new_table(6).expect("fits");
        for pos in 0..4 {
            table.write(0, pos, &[pos as f32; 2], &[0.0; 2]);
        }
        table.set_len(4);
        reg.register(&prompt, &mut table);
        assert_eq!(reg.len(), 1);

        // identical prompt: capped at len-1 so the last row is recomputed
        let hit = reg.fork_longest(&[5, 6, 7, 8], 6).expect("prefix hit");
        assert_eq!(hit.0, 3);
        // longer prompt sharing the prefix: full 4 positions reused
        let hit = reg.fork_longest(&[5, 6, 7, 8, 9, 9], 8).expect("prefix hit");
        assert_eq!(hit.0, 4);
        // divergent suffix: the longest *common* prefix is what forks
        let hit = reg.fork_longest(&[5, 6, 9, 9, 9], 8).expect("lcp hit");
        assert_eq!(hit.0, 2);
        // a common prefix below one full page shares no pages: skipped
        assert!(reg.fork_longest(&[5, 9, 7, 8], 6).is_none());

        reg.clear();
        assert!(reg.is_empty());
        assert!(reg.fork_longest(&[5, 6, 7, 8], 6).is_none());
    }
}
