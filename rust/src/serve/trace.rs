//! Synthetic request traces: Poisson or bursty arrivals over prompts
//! drawn from the calibration-domain corpus, mixing generation and
//! scoring requests. Consumed two ways: replayed on the trace clock by
//! the offline driver ([`super::bench::run_trace`]) or fed through a
//! wall-clock producer thread into the online multi-worker engine
//! ([`super::online::serve_online`], where a closed-loop pacing mode
//! ignores the arrival stamps entirely).

use crate::data::corpus::Corpus;
use crate::data::Domain;
use crate::util::rng::Rng;

use super::scheduler::{ReqKind, Request};

#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub n_requests: usize,
    /// mean arrival rate, requests/second (Poisson process)
    pub rate: f64,
    /// prompt length drawn uniformly from `prompt_min..=prompt_max`
    pub prompt_min: usize,
    pub prompt_max: usize,
    /// generation length drawn uniformly from `gen_min..=gen_max`
    pub gen_min: usize,
    pub gen_max: usize,
    /// fraction of requests that are scoring-only
    pub score_fraction: f64,
    /// arrival burst size: 1 is a plain Poisson process; `b > 1` makes
    /// requests arrive in simultaneous groups of `b`, with Exp(rate/b)
    /// gaps between groups so the mean rate stays `rate`
    pub burst: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_requests: 32,
            rate: 16.0,
            prompt_min: 16,
            prompt_max: 48,
            gen_min: 8,
            gen_max: 16,
            score_fraction: 0.25,
            burst: 1,
            seed: 0x7ACE,
        }
    }
}

impl TraceConfig {
    /// Largest KV footprint any request of this trace can reach.
    pub fn max_request_tokens(&self) -> usize {
        self.prompt_max + self.gen_max
    }
}

/// Sample a deterministic trace: exponential interarrival gaps at `rate`
/// (between bursts of `cfg.burst` simultaneous requests when `burst > 1`),
/// prompt text from the C4-style synthetic corpus.
pub fn poisson_trace(cfg: &TraceConfig) -> Vec<Request> {
    assert!(cfg.prompt_min >= 1 && cfg.prompt_min <= cfg.prompt_max);
    assert!(cfg.gen_min >= 1 && cfg.gen_min <= cfg.gen_max);
    assert!(cfg.rate > 0.0);
    assert!(cfg.burst >= 1, "burst size must be >= 1");
    let mut rng = Rng::seed(cfg.seed);
    let mut corpus = Corpus::new(Domain::C4Syn, cfg.seed ^ 0x5EED);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for id in 0..cfg.n_requests {
        // Exp(rate) interarrival; 1 - u keeps the log argument positive.
        // With bursts, one Exp(rate / burst) gap per group of `burst`.
        if id % cfg.burst == 0 {
            t += -(1.0 - rng.f64()).ln() / (cfg.rate / cfg.burst as f64);
        }
        let plen = cfg.prompt_min + rng.below(cfg.prompt_max - cfg.prompt_min + 1);
        let kind = if rng.f64() < cfg.score_fraction {
            ReqKind::Score
        } else {
            ReqKind::Generate {
                max_new: cfg.gen_min + rng.below(cfg.gen_max - cfg.gen_min + 1),
            }
        };
        out.push(Request { id, arrival: t, tokens: corpus.take(plen), kind });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_in_bounds() {
        let cfg = TraceConfig { n_requests: 40, ..Default::default() };
        let a = poisson_trace(&cfg);
        let b = poisson_trace(&cfg);
        assert_eq!(a.len(), 40);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.arrival, y.arrival);
        }
        let mut prev = 0.0;
        for r in &a {
            assert!(r.arrival > prev, "arrivals strictly increase");
            prev = r.arrival;
            assert!(r.tokens.len() >= cfg.prompt_min && r.tokens.len() <= cfg.prompt_max);
            assert!(r.cost() <= cfg.max_request_tokens());
            if let ReqKind::Generate { max_new } = r.kind {
                assert!(max_new >= cfg.gen_min && max_new <= cfg.gen_max);
            }
        }
    }

    #[test]
    fn mean_interarrival_tracks_rate() {
        let cfg = TraceConfig { n_requests: 2000, rate: 50.0, ..Default::default() };
        let t = poisson_trace(&cfg);
        let mean_gap = t.last().unwrap().arrival / t.len() as f64;
        assert!((mean_gap - 0.02).abs() < 0.004, "mean gap {mean_gap}");
    }

    #[test]
    fn bursty_arrivals_group_and_keep_mean_rate() {
        let cfg = TraceConfig { n_requests: 2000, rate: 50.0, burst: 4, ..Default::default() };
        let t = poisson_trace(&cfg);
        // arrivals come in simultaneous groups of `burst`
        for group in t.chunks(4) {
            for r in group {
                assert_eq!(r.arrival, group[0].arrival, "burst members arrive together");
            }
        }
        // non-decreasing overall, and the mean rate is preserved
        for w in t.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let mean_gap = t.last().unwrap().arrival / t.len() as f64;
        assert!((mean_gap - 0.02).abs() < 0.006, "mean gap {mean_gap}");
    }

    #[test]
    fn score_fraction_respected_roughly() {
        let cfg = TraceConfig { n_requests: 1000, score_fraction: 0.3, ..Default::default() };
        let t = poisson_trace(&cfg);
        let scores = t.iter().filter(|r| r.kind == ReqKind::Score).count();
        let frac = scores as f64 / t.len() as f64;
        assert!((frac - 0.3).abs() < 0.06, "score fraction {frac}");
    }
}
