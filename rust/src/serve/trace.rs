//! Synthetic request traces: Poisson or bursty arrivals over prompts
//! drawn from the calibration-domain corpus, mixing generation and
//! scoring requests. Consumed two ways: replayed on the trace clock by
//! the offline driver ([`super::bench::run_trace`]) or fed through a
//! wall-clock producer thread into the online multi-worker engine
//! ([`super::online::serve_online`], where a closed-loop pacing mode
//! ignores the arrival stamps entirely).

use crate::data::corpus::Corpus;
use crate::data::Domain;
use crate::util::rng::Rng;

use super::scheduler::{Qos, ReqKind, Request};

#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub n_requests: usize,
    /// mean arrival rate, requests/second (Poisson process)
    pub rate: f64,
    /// prompt length drawn uniformly from `prompt_min..=prompt_max`
    pub prompt_min: usize,
    pub prompt_max: usize,
    /// generation length drawn uniformly from `gen_min..=gen_max`
    pub gen_min: usize,
    pub gen_max: usize,
    /// fraction of requests that are scoring-only
    pub score_fraction: f64,
    /// arrival burst size: 1 is a plain Poisson process; `b > 1` makes
    /// requests arrive in simultaneous groups of `b`, with Exp(rate/b)
    /// gaps between groups so the mean rate stays `rate`
    pub burst: usize,
    pub seed: u64,
    /// per-request relative deadline drawn uniformly from
    /// `deadline_min_s..=deadline_max_s` wall seconds; `deadline_max_s`
    /// of 0 disables deadlines (every request gets `f64::INFINITY`)
    pub deadline_min_s: f64,
    pub deadline_max_s: f64,
    /// priority tiers drawn uniformly from `0..priority_tiers` (0 is the
    /// most urgent); 1 leaves every request at the default tier
    pub priority_tiers: u8,
    /// distinct client ids drawn uniformly from `0..clients` (the
    /// token-bucket key in `serve::net`); 1 leaves everyone as client 0
    pub clients: u32,
    /// tokens of a common prefix prepended to *every* prompt — the
    /// shared-prompt workload for paged COW prefix sharing. The prefix
    /// comes from its own corpus stream, so at 0 the trace stays
    /// byte-identical to a prefix-free trace of the same seed
    pub shared_prefix_len: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_requests: 32,
            rate: 16.0,
            prompt_min: 16,
            prompt_max: 48,
            gen_min: 8,
            gen_max: 16,
            score_fraction: 0.25,
            burst: 1,
            seed: 0x7ACE,
            deadline_min_s: 0.0,
            deadline_max_s: 0.0,
            priority_tiers: 1,
            clients: 1,
            shared_prefix_len: 0,
        }
    }
}

impl TraceConfig {
    /// Largest KV footprint any request of this trace can reach.
    pub fn max_request_tokens(&self) -> usize {
        self.shared_prefix_len + self.prompt_max + self.gen_max
    }
}

/// Sample a deterministic trace: exponential interarrival gaps at `rate`
/// (between bursts of `cfg.burst` simultaneous requests when `burst > 1`),
/// prompt text from the C4-style synthetic corpus.
pub fn poisson_trace(cfg: &TraceConfig) -> Vec<Request> {
    assert!(cfg.prompt_min >= 1 && cfg.prompt_min <= cfg.prompt_max);
    assert!(cfg.gen_min >= 1 && cfg.gen_min <= cfg.gen_max);
    assert!(cfg.rate > 0.0);
    assert!(cfg.burst >= 1, "burst size must be >= 1");
    assert!(cfg.priority_tiers >= 1, "priority_tiers must be >= 1");
    assert!(cfg.clients >= 1, "clients must be >= 1");
    if cfg.deadline_max_s > 0.0 {
        assert!(
            cfg.deadline_min_s >= 0.0 && cfg.deadline_min_s <= cfg.deadline_max_s,
            "deadline range must satisfy 0 <= min <= max"
        );
    }
    let mut rng = Rng::seed(cfg.seed);
    let mut corpus = Corpus::new(Domain::C4Syn, cfg.seed ^ 0x5EED);
    // QoS draws come from their own stream so the arrival/prompt/kind
    // streams above stay byte-identical to QoS-free traces of the same
    // seed — policy comparisons then run the exact same workload.
    let mut qrng = Rng::seed(cfg.seed ^ 0x0905);
    // the shared prompt prefix draws from its own corpus stream so the
    // arrival/prompt/kind streams stay untouched when it is disabled
    let prefix: Vec<i32> = if cfg.shared_prefix_len > 0 {
        Corpus::new(Domain::C4Syn, cfg.seed ^ 0xCAFE).take(cfg.shared_prefix_len)
    } else {
        Vec::new()
    };
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for id in 0..cfg.n_requests {
        // Exp(rate) interarrival; 1 - u keeps the log argument positive.
        // With bursts, one Exp(rate / burst) gap per group of `burst`.
        if id % cfg.burst == 0 {
            t += -(1.0 - rng.f64()).ln() / (cfg.rate / cfg.burst as f64);
        }
        let plen = cfg.prompt_min + rng.below(cfg.prompt_max - cfg.prompt_min + 1);
        let kind = if rng.f64() < cfg.score_fraction {
            ReqKind::Score
        } else {
            ReqKind::Generate {
                max_new: cfg.gen_min + rng.below(cfg.gen_max - cfg.gen_min + 1),
            }
        };
        let deadline_s = if cfg.deadline_max_s > 0.0 {
            cfg.deadline_min_s + qrng.f64() * (cfg.deadline_max_s - cfg.deadline_min_s)
        } else {
            f64::INFINITY
        };
        let priority = if cfg.priority_tiers > 1 {
            qrng.below(cfg.priority_tiers as usize) as u8
        } else {
            1
        };
        let client = if cfg.clients > 1 { qrng.below(cfg.clients as usize) as u32 } else { 0 };
        let qos = Qos { deadline_s, priority, client };
        let mut tokens = prefix.clone();
        tokens.extend(corpus.take(plen));
        out.push(Request { id, arrival: t, tokens, kind, qos });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_in_bounds() {
        let cfg = TraceConfig { n_requests: 40, ..Default::default() };
        let a = poisson_trace(&cfg);
        let b = poisson_trace(&cfg);
        assert_eq!(a.len(), 40);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.arrival, y.arrival);
        }
        let mut prev = 0.0;
        for r in &a {
            assert!(r.arrival > prev, "arrivals strictly increase");
            prev = r.arrival;
            assert!(r.tokens.len() >= cfg.prompt_min && r.tokens.len() <= cfg.prompt_max);
            assert!(r.cost() <= cfg.max_request_tokens());
            if let ReqKind::Generate { max_new } = r.kind {
                assert!(max_new >= cfg.gen_min && max_new <= cfg.gen_max);
            }
        }
    }

    #[test]
    fn mean_interarrival_tracks_rate() {
        let cfg = TraceConfig { n_requests: 2000, rate: 50.0, ..Default::default() };
        let t = poisson_trace(&cfg);
        let mean_gap = t.last().unwrap().arrival / t.len() as f64;
        assert!((mean_gap - 0.02).abs() < 0.004, "mean gap {mean_gap}");
    }

    #[test]
    fn bursty_arrivals_group_and_keep_mean_rate() {
        let cfg = TraceConfig { n_requests: 2000, rate: 50.0, burst: 4, ..Default::default() };
        let t = poisson_trace(&cfg);
        // arrivals come in simultaneous groups of `burst`
        for group in t.chunks(4) {
            for r in group {
                assert_eq!(r.arrival, group[0].arrival, "burst members arrive together");
            }
        }
        // non-decreasing overall, and the mean rate is preserved
        for w in t.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let mean_gap = t.last().unwrap().arrival / t.len() as f64;
        assert!((mean_gap - 0.02).abs() < 0.006, "mean gap {mean_gap}");
    }

    #[test]
    fn qos_stream_leaves_base_trace_untouched() {
        let plain = poisson_trace(&TraceConfig::default());
        let qcfg = TraceConfig {
            deadline_min_s: 0.1,
            deadline_max_s: 0.5,
            priority_tiers: 3,
            clients: 4,
            ..Default::default()
        };
        let with_qos = poisson_trace(&qcfg);
        // same seed, same workload: QoS comes from its own rng stream
        for (a, b) in plain.iter().zip(&with_qos) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.kind, b.kind);
        }
        for r in &with_qos {
            assert!(r.qos.deadline_s >= 0.1 && r.qos.deadline_s <= 0.5);
            assert!(r.qos.priority < 3);
            assert!(r.qos.client < 4);
        }
        assert!(
            with_qos.iter().any(|r| r.qos.priority != with_qos[0].qos.priority),
            "priority tiers actually vary across the trace"
        );
        for r in &plain {
            assert!(r.qos.deadline_s.is_infinite());
            assert_eq!((r.qos.priority, r.qos.client), (1, 0));
        }
    }

    #[test]
    fn shared_prefix_prepends_without_touching_base_streams() {
        let plain = poisson_trace(&TraceConfig::default());
        let cfg = TraceConfig { shared_prefix_len: 6, ..Default::default() };
        let shared = poisson_trace(&cfg);
        let prefix = &shared[0].tokens[..6];
        for (a, b) in plain.iter().zip(&shared) {
            assert_eq!(a.arrival, b.arrival, "arrival stream untouched");
            assert_eq!(a.kind, b.kind, "kind stream untouched");
            assert_eq!(&b.tokens[..6], prefix, "every prompt shares the prefix");
            assert_eq!(&b.tokens[6..], &a.tokens[..], "suffix is the base prompt");
            assert!(b.cost() <= cfg.max_request_tokens());
        }
    }

    #[test]
    fn score_fraction_respected_roughly() {
        let cfg = TraceConfig { n_requests: 1000, score_fraction: 0.3, ..Default::default() };
        let t = poisson_trace(&cfg);
        let scores = t.iter().filter(|r| r.kind == ReqKind::Score).count();
        let frac = scores as f64 / t.len() as f64;
        assert!((frac - 0.3).abs() < 0.06, "score fraction {frac}");
    }
}
