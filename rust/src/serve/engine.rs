//! Serving forward passes over a [`PackedModel`]: variable-length prefill
//! (which fills the per-request KV cache), batched single-token decode,
//! and prompt scoring.
//!
//! Numerics are the native backend's block math: RMSNorm/SiLU/matmul come
//! straight from [`ops`], and the RoPE rotation and cached attention are
//! the *same* hoisted kernels ([`ops::rope_rotate_row`],
//! [`ops::attention_cached_row`]) the `block_fwd_cached` runtime op
//! executes — shared code, not mirrored copies. Invariants pinned by
//! `tests/serve_parity.rs`:
//! * dense-format serving reproduces `block_fwd` / `head_nll` bitwise,
//! * CSR serving reproduces dense bitwise (exact zeros drop out of the
//!   ascending-column accumulation without rounding — see
//!   [`crate::sparse`]),
//! * KV-cached decode reproduces a full-prefix recompute token-for-token.

use anyhow::Result;

use crate::model::{ModelConfig, ParamStore, LAYER_NAMES};
use crate::runtime::native::ops;
use crate::runtime::Engine;
use crate::tensor::Tensor;

use super::kv::KvCache;
use super::model::PackedModel;
use super::paged::{Kv, KvSpec};

/// A packed model plus the RoPE tables for every position it may serve.
pub struct ServeContext {
    pub model: PackedModel,
    /// cos/sin tables `[max_pos, dh/2]`
    cos: Vec<f32>,
    sin: Vec<f32>,
    max_pos: usize,
}

impl ServeContext {
    /// `max_pos` bounds prompt length + generated tokens per request.
    pub fn new(model: PackedModel, max_pos: usize) -> ServeContext {
        let (cos, sin) =
            ops::rope_tables_for(max_pos, model.cfg.d_head(), model.cfg.rope_base);
        ServeContext { model, cos, sin, max_pos }
    }

    pub fn max_pos(&self) -> usize {
        self.max_pos
    }

    /// Fresh contiguous KV cache sized for this context's full window.
    pub fn new_cache(&self) -> Kv {
        Kv::Contig(KvCache::new(self.model.cfg.n_blocks, self.model.cfg.d_model, self.max_pos))
    }

    /// KV cache for one request through a [`KvSpec`]: the contiguous slab
    /// spans the full window, a paged table reserves exactly `cost`
    /// tokens. `None` only in paged mode when the pool cap cannot cover
    /// the reservation (the clean-rejection path).
    pub fn new_kv(&self, spec: &KvSpec, cost: usize) -> Option<Kv> {
        spec.new_kv(self.model.cfg.n_blocks, self.model.cfg.d_model, self.max_pos, cost)
    }

    /// Can `other` serve as a degrade tier behind this context? The two
    /// checkpoints must agree on every shape the serving plumbing bakes
    /// in — KV layout (blocks × width), vocabulary, and position window —
    /// so a request can be routed to either replica interchangeably.
    /// Weights (and so sparsity) are free to differ: that is the point.
    pub fn compatible_tier(&self, other: &ServeContext) -> bool {
        self.model.cfg.n_blocks == other.model.cfg.n_blocks
            && self.model.cfg.d_model == other.model.cfg.d_model
            && self.model.cfg.n_heads == other.model.cfg.n_heads
            && self.model.cfg.vocab == other.model.cfg.vocab
            && self.max_pos == other.max_pos
    }
}

/// Gather embedding rows: tokens `[n]` -> `[n, d]`.
pub fn embed_rows(embed: &[f32], tokens: &[i32], d: usize, vocab: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; tokens.len() * d];
    for (i, t) in tokens.iter().enumerate() {
        let t = (*t).clamp(0, vocab as i32 - 1) as usize;
        x[i * d..(i + 1) * d].copy_from_slice(&embed[t * d..(t + 1) * d]);
    }
    x
}

/// Rotate every head of one `[d]` row at `pos`: [`ops::rope_rotate_row`]
/// with this position's slice of the context's angle tables.
fn rope_row(row: &mut [f32], pos: usize, cos: &[f32], sin: &[f32], n_heads: usize, dh: usize) {
    let half = dh / 2;
    ops::rope_rotate_row(
        row,
        &cos[pos * half..(pos + 1) * half],
        &sin[pos * half..(pos + 1) * half],
        n_heads,
        dh,
        false,
    );
}

/// Causal attention over one sequence: roped `q`/`k` and raw `v`, all
/// `[s, d]` with heads side by side in the feature dim. Returns `[s, d]`.
/// Score/value inner loops are the shared [`crate::kernel::attn`] lanes,
/// so prefill accumulates in the same ascending-position order as the
/// cached decode row (the cached == recompute bitwise invariant).
fn attention_causal(q: &[f32], k: &[f32], v: &[f32], s: usize, n_heads: usize, dh: usize) -> Vec<f32> {
    use crate::kernel::attn;
    let d = n_heads * dh;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0.0f32; s * d];
    let mut row = vec![0.0f32; s];
    for h in 0..n_heads {
        let off = h * dh;
        for qi in 0..s {
            let qrow = &q[qi * d + off..qi * d + off + dh];
            attn::dots(qrow, k, d, off, qi + 1, &mut row);
            let mut mx = f32::NEG_INFINITY;
            for item in row.iter_mut().take(qi + 1) {
                *item *= scale;
                mx = mx.max(*item);
            }
            let mut z = 0.0f32;
            for item in row.iter_mut().take(qi + 1) {
                *item = (*item - mx).exp();
                z += *item;
            }
            for item in row.iter_mut().take(qi + 1) {
                *item /= z;
            }
            let orow = &mut out[qi * d + off..qi * d + off + dh];
            attn::wsum(orow, &row[..qi + 1], v, d, off);
        }
    }
    out
}

/// Run the whole prompt through the model, filling `cache` with roped
/// keys / raw values for every block and position. Returns the final
/// hidden states `[s, d]` (pre-`norm_f`).
pub fn prefill(ctx: &ServeContext, tokens: &[i32], cache: &mut Kv) -> Vec<f32> {
    let cfg = &ctx.model.cfg;
    let (d, nh, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head());
    let s = tokens.len();
    assert!(s > 0 && s <= ctx.max_pos, "prompt length {s} outside 1..={}", ctx.max_pos);
    let eps = cfg.norm_eps;
    let mut x = embed_rows(&ctx.model.embed, tokens, d, cfg.vocab);
    for (l, blk) in ctx.model.blocks.iter().enumerate() {
        let h1 = ops::rmsnorm(&x, &blk.norm1, d, eps);
        let mut q = blk.lin[0].forward(&h1, s);
        let mut k = blk.lin[1].forward(&h1, s);
        let v = blk.lin[2].forward(&h1, s);
        for pos in 0..s {
            rope_row(&mut q[pos * d..(pos + 1) * d], pos, &ctx.cos, &ctx.sin, nh, dh);
            rope_row(&mut k[pos * d..(pos + 1) * d], pos, &ctx.cos, &ctx.sin, nh, dh);
            cache.write(l, pos, &k[pos * d..(pos + 1) * d], &v[pos * d..(pos + 1) * d]);
        }
        let att = attention_causal(&q, &k, &v, s, nh, dh);
        let o = blk.lin[3].forward(&att, s);
        let x2: Vec<f32> = x.iter().zip(&o).map(|(a, b)| a + b).collect();
        let h2 = ops::rmsnorm(&x2, &blk.norm2, d, eps);
        let gate = blk.lin[4].forward(&h2, s);
        let up = blk.lin[5].forward(&h2, s);
        let act: Vec<f32> = gate.iter().zip(&up).map(|(g, u)| ops::silu(*g) * u).collect();
        let down = blk.lin[6].forward(&act, s);
        x = x2.iter().zip(&down).map(|(a, b)| a + b).collect();
    }
    cache.set_len(s);
    x
}

/// Per-position NLL of the prompt under the model (last position zeroed),
/// from the prefill hidden states — the scoring-request path. Matches
/// `head_nll` on the native backend.
pub fn score_nll(ctx: &ServeContext, hidden: &[f32], tokens: &[i32]) -> Vec<f32> {
    let cfg = &ctx.model.cfg;
    let (d, v) = (cfg.d_model, cfg.vocab);
    let s = tokens.len();
    let h = ops::rmsnorm(hidden, &ctx.model.norm_f, d, cfg.norm_eps);
    let logits = ops::mm_nt(&h, &ctx.model.embed, s, d, v);
    let mut nll = vec![0.0f32; s];
    for si in 0..s.saturating_sub(1) {
        let row = &logits[si * v..(si + 1) * v];
        let t = tokens[si + 1].clamp(0, v as i32 - 1) as usize;
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|l| (l - mx).exp()).sum();
        let lse = mx + z.ln();
        nll[si] = lse - row[t];
    }
    nll
}

/// Tied-head logits of one hidden row `[d]`.
pub fn last_logits(ctx: &ServeContext, hidden_row: &[f32]) -> Vec<f32> {
    let cfg = &ctx.model.cfg;
    let h = ops::rmsnorm(hidden_row, &ctx.model.norm_f, cfg.d_model, cfg.norm_eps);
    ops::mm_nt(&h, &ctx.model.embed, 1, cfg.d_model, cfg.vocab)
}

/// Index of the maximum element (first on ties — deterministic greedy).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate() {
        if *v > xs[best] {
            best = i;
        }
    }
    best
}

/// Reusable per-decode-step scratch: the attention activations and the
/// softmax row the cached-attention kernel works in. One instance lives
/// for the whole generation loop (offline replay, online worker, greedy
/// reference), so the decode hot loop performs no per-token scratch
/// allocations — the buffers grow to the high-water mark once and are
/// reused.
#[derive(Default)]
pub struct DecodeScratch {
    /// `[nb, d]` attention output of the current block.
    att: Vec<f32>,
    /// softmax row of the cached-attention kernel (`len + 1` entries).
    row: Vec<f32>,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        DecodeScratch::default()
    }
}

/// Transformer body of one continuous-batching decode step: each active
/// request contributes its last token; linears run batched over all
/// requests, attention runs per request against its own KV cache through
/// the segment-gather view (one segment for a contiguous cache, one per
/// page for a paged one — bitwise identical either way). Appends this
/// position to every cache and returns the new hidden rows `[nb, d]`
/// (pre-`norm_f`). `scratch` carries the reusable attention buffers.
pub fn decode_hidden(
    ctx: &ServeContext,
    last_tokens: &[i32],
    caches: &mut [&mut Kv],
    scratch: &mut DecodeScratch,
) -> Vec<f32> {
    let cfg = &ctx.model.cfg;
    let (d, nh, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head());
    let nb = last_tokens.len();
    assert_eq!(nb, caches.len());
    let eps = cfg.norm_eps;
    let positions: Vec<usize> = caches.iter().map(|c| c.len()).collect();
    for (i, p) in positions.iter().enumerate() {
        assert!(*p < ctx.max_pos, "request {i} exhausted cache capacity {}", ctx.max_pos);
    }
    let mut x = embed_rows(&ctx.model.embed, last_tokens, d, cfg.vocab);
    for (l, blk) in ctx.model.blocks.iter().enumerate() {
        let h1 = ops::rmsnorm(&x, &blk.norm1, d, eps);
        let mut q = blk.lin[0].forward(&h1, nb);
        let mut k = blk.lin[1].forward(&h1, nb);
        let v = blk.lin[2].forward(&h1, nb);
        scratch.att.clear();
        scratch.att.resize(nb * d, 0.0);
        for i in 0..nb {
            let p = positions[i];
            rope_row(&mut q[i * d..(i + 1) * d], p, &ctx.cos, &ctx.sin, nh, dh);
            rope_row(&mut k[i * d..(i + 1) * d], p, &ctx.cos, &ctx.sin, nh, dh);
            let cache = &caches[i];
            ops::attention_cached_row_gather_into(
                &q[i * d..(i + 1) * d],
                &k[i * d..(i + 1) * d],
                &v[i * d..(i + 1) * d],
                |si| cache.segment(l, si),
                cache.n_segments(),
                p,
                nh,
                dh,
                &mut scratch.row,
                &mut scratch.att[i * d..(i + 1) * d],
            );
            caches[i].write(l, p, &k[i * d..(i + 1) * d], &v[i * d..(i + 1) * d]);
        }
        let o = blk.lin[3].forward(&scratch.att, nb);
        let x2: Vec<f32> = x.iter().zip(&o).map(|(a, b)| a + b).collect();
        let h2 = ops::rmsnorm(&x2, &blk.norm2, d, eps);
        let gate = blk.lin[4].forward(&h2, nb);
        let up = blk.lin[5].forward(&h2, nb);
        let act: Vec<f32> = gate.iter().zip(&up).map(|(g, u)| ops::silu(*g) * u).collect();
        let down = blk.lin[6].forward(&act, nb);
        x = x2.iter().zip(&down).map(|(a, b)| a + b).collect();
    }
    for c in caches.iter_mut() {
        let n = c.len();
        c.set_len(n + 1);
    }
    x
}

/// One continuous-batching decode step: [`decode_hidden`] plus the tied
/// head — returns the next (greedy) token per request.
pub fn decode_step(
    ctx: &ServeContext,
    last_tokens: &[i32],
    caches: &mut [&mut Kv],
    scratch: &mut DecodeScratch,
) -> Vec<i32> {
    let cfg = &ctx.model.cfg;
    let (d, nb) = (cfg.d_model, last_tokens.len());
    let x = decode_hidden(ctx, last_tokens, caches, scratch);
    let h = ops::rmsnorm(&x, &ctx.model.norm_f, d, cfg.norm_eps);
    let logits = ops::mm_nt(&h, &ctx.model.embed, nb, d, cfg.vocab);
    (0..nb).map(|i| argmax(&logits[i * cfg.vocab..(i + 1) * cfg.vocab]) as i32).collect()
}

/// Continue a prefill over an already-cached prefix: `cache` holds
/// positions `0..start` (e.g. shared from a registered prompt prefix —
/// [`super::paged::PrefixRegistry`]); the remaining prompt positions
/// `start..s` run one cached decode row at a time, appending to `cache`.
/// Returns the final hidden row `[d]` (pre-`norm_f`).
///
/// Bitwise identical to the same row of a full [`prefill`]: the batched
/// linears are row-independent, and the cached attention row replicates
/// `attention_causal`'s exact per-position operation sequence — the
/// cached == recompute invariant `tests/serve_parity.rs` pins, applied
/// mid-prompt.
pub fn prefill_continue(
    ctx: &ServeContext,
    tokens: &[i32],
    cache: &mut Kv,
    scratch: &mut DecodeScratch,
) -> Vec<f32> {
    let s = tokens.len();
    let start = cache.len();
    assert!(start >= 1 && start < s, "cached prefix {start} outside 1..{s}");
    assert!(s <= ctx.max_pos, "prompt length {s} outside 1..={}", ctx.max_pos);
    let mut x = Vec::new();
    for pos in start..s {
        let mut caches = [&mut *cache];
        x = decode_hidden(ctx, &tokens[pos..pos + 1], &mut caches, scratch);
    }
    x
}

/// Per-block host tensors for routing decode through the execution
/// backend's `block_fwd_cached` artifact.
pub struct BlockTensors {
    pub weights: Vec<Tensor>,
    pub norm1: Tensor,
    pub norm2: Tensor,
}

/// Clone the per-block tensors out of a checkpoint once, for repeated
/// [`decode_step_backend`] calls.
pub fn block_tensors(params: &ParamStore, cfg: &ModelConfig) -> Result<Vec<BlockTensors>> {
    let mut out = Vec::with_capacity(cfg.n_blocks);
    for l in 0..cfg.n_blocks {
        let mut weights = Vec::with_capacity(7);
        for w in LAYER_NAMES {
            weights.push(params.get(&ParamStore::layer_name(l, w))?.clone());
        }
        out.push(BlockTensors {
            weights,
            norm1: params.get(&format!("blocks.{l}.norm1"))?.clone(),
            norm2: params.get(&format!("blocks.{l}.norm2"))?.clone(),
        });
    }
    Ok(out)
}

/// [`decode_step`] routed through the runtime's `block_fwd_cached`
/// artifact — the "serving through the execution backend" path (dense
/// weights; the packed model is only used for embed/norm_f/head). Same
/// math as the in-process kernels; `tests/serve_parity.rs` pins equality.
pub fn decode_step_backend(
    ctx: &ServeContext,
    engine: &Engine,
    blocks: &[BlockTensors],
    last_tokens: &[i32],
    caches: &mut [&mut Kv],
) -> Result<Vec<i32>> {
    let cfg = &ctx.model.cfg;
    let d = cfg.d_model;
    let nb = last_tokens.len();
    assert_eq!(nb, caches.len());
    assert_eq!(blocks.len(), cfg.n_blocks);
    let positions: Vec<usize> = caches.iter().map(|c| c.len()).collect();
    let cap = positions.iter().copied().max().unwrap_or(0);
    let pos_t = Tensor::from_i32(&[nb], positions.iter().map(|p| *p as i32).collect());
    let mut x = embed_rows(&ctx.model.embed, last_tokens, d, cfg.vocab);
    for (l, bt) in blocks.iter().enumerate() {
        // pack this block's caches [nb, cap, d] (gathering paged tables
        // into contiguous rows); rows past a request's fill level stay
        // zero and are never read (pos masks them)
        let mut kc = vec![0.0f32; nb * cap * d];
        let mut vc = vec![0.0f32; nb * cap * d];
        for i in 0..nb {
            let n = caches[i].len() * d;
            caches[i].gather_block_into(
                l,
                &mut kc[i * cap * d..i * cap * d + n],
                &mut vc[i * cap * d..i * cap * d + n],
            );
        }
        let x_t = Tensor::from_f32(&[nb, 1, d], x);
        let kc_t = Tensor::from_f32(&[nb, cap, d], kc);
        let vc_t = Tensor::from_f32(&[nb, cap, d], vc);
        let mut ins: Vec<&Tensor> = vec![&x_t, &kc_t, &vc_t, &pos_t];
        for w in &bt.weights {
            ins.push(w);
        }
        ins.push(&bt.norm1);
        ins.push(&bt.norm2);
        let out = engine.run("block_fwd_cached", &ins)?;
        x = out[0].f32s().to_vec();
        let k_new = out[1].f32s();
        let v_new = out[2].f32s();
        for i in 0..nb {
            caches[i].write(l, positions[i], &k_new[i * d..(i + 1) * d], &v_new[i * d..(i + 1) * d]);
        }
    }
    for c in caches.iter_mut() {
        let n = c.len();
        c.set_len(n + 1);
    }
    let h = ops::rmsnorm(&x, &ctx.model.norm_f, d, cfg.norm_eps);
    let logits = ops::mm_nt(&h, &ctx.model.embed, nb, d, cfg.vocab);
    Ok((0..nb).map(|i| argmax(&logits[i * cfg.vocab..(i + 1) * cfg.vocab]) as i32).collect())
}

/// Greedy-generate `n` tokens into a caller-provided (empty) cache: one
/// prefill, then KV-cached decode steps. The cache may be contiguous or
/// paged — the tokens are bitwise identical either way (parity-pinned).
pub fn greedy_with_cache(ctx: &ServeContext, prompt: &[i32], n: usize, cache: &mut Kv) -> Vec<i32> {
    if n == 0 {
        return Vec::new();
    }
    assert!(cache.is_empty(), "greedy_with_cache expects a fresh cache");
    let d = ctx.model.cfg.d_model;
    let hidden = prefill(ctx, prompt, cache);
    let s = prompt.len();
    let mut prev = argmax(&last_logits(ctx, &hidden[(s - 1) * d..s * d])) as i32;
    let mut out = vec![prev];
    let mut scratch = DecodeScratch::new();
    for _ in 1..n {
        let last = [prev];
        let mut caches = [&mut *cache];
        prev = decode_step(ctx, &last, &mut caches, &mut scratch)[0];
        out.push(prev);
    }
    out
}

/// Greedy-generate `n` tokens: one prefill, then KV-cached decode steps.
/// The shared reference loop for benches and the parity suite.
pub fn greedy_cached(ctx: &ServeContext, prompt: &[i32], n: usize) -> Vec<i32> {
    let mut cache = ctx.new_cache();
    greedy_with_cache(ctx, prompt, n, &mut cache)
}

/// Greedy-generate `n` tokens by re-running the full prefix for every
/// token — the cache-free recompute reference the cached paths are
/// parity-checked against.
pub fn greedy_recompute(ctx: &ServeContext, prompt: &[i32], n: usize) -> Vec<i32> {
    let d = ctx.model.cfg.d_model;
    let mut seq = prompt.to_vec();
    let mut out = Vec::new();
    for _ in 0..n {
        let mut scratch = ctx.new_cache();
        let h = prefill(ctx, &seq, &mut scratch);
        let t = argmax(&last_logits(ctx, &h[(seq.len() - 1) * d..seq.len() * d])) as i32;
        out.push(t);
        seq.push(t);
    }
    out
}

/// [`greedy_cached`] with decode routed through the runtime's
/// `block_fwd_cached` artifact.
pub fn greedy_backend(
    ctx: &ServeContext,
    engine: &Engine,
    blocks: &[BlockTensors],
    prompt: &[i32],
    n: usize,
) -> Result<Vec<i32>> {
    if n == 0 {
        return Ok(Vec::new());
    }
    let d = ctx.model.cfg.d_model;
    let mut cache = ctx.new_cache();
    let hidden = prefill(ctx, prompt, &mut cache);
    let s = prompt.len();
    let mut prev = argmax(&last_logits(ctx, &hidden[(s - 1) * d..s * d])) as i32;
    let mut out = vec![prev];
    for _ in 1..n {
        let last = [prev];
        let mut caches = [&mut cache];
        let next = decode_step_backend(ctx, engine, blocks, &last, &mut caches)?;
        prev = next[0];
        out.push(prev);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;
    use crate::serve::model::{PackedModel, WeightFormat};
    use crate::util::rng::Rng;

    #[test]
    fn attention_causal_matches_native_ops() {
        // compare against ops::attention (which ropes internally) on a
        // single-sequence config
        let mut cfg = test_config();
        cfg.batch = 1;
        cfg.seq_len = 5;
        let (s, d, nh, dh) = (cfg.seq_len, cfg.d_model, cfg.n_heads, cfg.d_head());
        let mut rng = Rng::seed(21);
        let q: Vec<f32> = (0..s * d).map(|_| rng.normal_f32()).collect();
        let k: Vec<f32> = (0..s * d).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..s * d).map(|_| rng.normal_f32()).collect();
        let (want, _) = ops::attention(&q, &k, &v, &cfg, false);

        let (cos, sin) = ops::rope_tables_for(s, dh, cfg.rope_base);
        let (mut qr, mut kr) = (q.clone(), k.clone());
        for pos in 0..s {
            rope_row(&mut qr[pos * d..(pos + 1) * d], pos, &cos, &sin, nh, dh);
            rope_row(&mut kr[pos * d..(pos + 1) * d], pos, &cos, &sin, nh, dh);
        }
        let got = attention_causal(&qr, &kr, &v, s, nh, dh);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn cached_decode_matches_full_recompute() {
        let cfg = test_config();
        let params = crate::model::ParamStore::init(&cfg, 33);
        let model = PackedModel::materialize(&params, &cfg, WeightFormat::Dense).unwrap();
        let ctx = ServeContext::new(model, 24);
        let mut rng = Rng::seed(34);
        let prompt: Vec<i32> = (0..7).map(|_| rng.below(cfg.vocab) as i32).collect();
        assert_eq!(
            greedy_cached(&ctx, &prompt, 7),
            greedy_recompute(&ctx, &prompt, 7),
            "KV-cached decode must match full-prefix recompute"
        );
    }
}
