//! The `besa serve-bench` driver: replay a Poisson/bursty trace through
//! the continuous-batching loop in each requested weight format (offline,
//! trace clock), optionally run the async multi-worker mode (wall-clock
//! ingestion + sharded workers, [`super::online`]) at one and N workers
//! to report scaling, measure throughput + latency percentiles + the
//! queue-wait vs compute split, parity-check the fast paths against dense
//! full-prefix recompute (and sharded against single-worker), and emit a
//! machine-readable `BENCH_serve.json` record for the perf trajectory.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::model::{ModelConfig, ParamStore, LAYER_NAMES};
use crate::quant::QuantSpec;
use crate::runtime::Engine;
use crate::telemetry::Tracer;
use crate::util::json::{self, Json};
use crate::util::par::par_map;
use crate::util::{mean, percentile, Stopwatch};

use super::engine::{
    argmax, block_tensors, decode_step, decode_step_backend, greedy_backend, greedy_cached,
    greedy_recompute, last_logits, prefill, score_nll, BlockTensors, DecodeScratch, ServeContext,
};
use super::fault::FaultPlan;
use super::ingest::Pacing;
use super::model::{PackedModel, WeightFormat};
use super::paged::{gather_caches, Kv, KvMode, KvSpec, PagePool, PrefixRegistry};
use super::online::{serve_online_tiered, serve_online_traced, OnlineConfig, OnlineStats};
use super::scheduler::{Policy, ReqKind, Request, Scheduler, SchedulerConfig};
use super::trace::{poisson_trace, TraceConfig};

/// Which execution path serves the trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServeMode {
    /// f32 weights through the native `mm_nt` kernel — the baseline.
    Dense,
    /// CSR weights through the row-blocked SpMM kernels.
    Sparse,
    /// quantized CSR with fused dequant.
    Quant,
    /// dense weights with decode routed through the runtime backend's
    /// `block_fwd_cached` artifact (serving through the `Engine` facade).
    DenseBackend,
}

impl ServeMode {
    pub fn from_name(s: &str) -> Option<ServeMode> {
        match s {
            "dense" => Some(ServeMode::Dense),
            "sparse" | "csr" => Some(ServeMode::Sparse),
            "quant" => Some(ServeMode::Quant),
            "dense-backend" | "backend" => Some(ServeMode::DenseBackend),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ServeMode::Dense => "dense",
            ServeMode::Sparse => "sparse",
            ServeMode::Quant => "quant",
            ServeMode::DenseBackend => "dense-backend",
        }
    }
}

/// One retired request.
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: usize,
    /// finish time minus arrival on the trace clock
    pub latency_s: f64,
    pub out_tokens: usize,
    /// greedy tokens in generation order (empty for scoring requests) —
    /// what the cross-format and sharded-vs-offline parity checks compare
    pub tokens: Vec<i32>,
    /// total prompt NLL (scoring requests only)
    pub nll: Option<f64>,
}

/// Raw counters of one trace replay.
pub struct TraceStats {
    pub finished: Vec<FinishedRequest>,
    pub wall_s: f64,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    pub peak_active: usize,
    /// high-water resident KV bytes: the pool's peak live pages in paged
    /// mode (COW-shared pages counted once), the peak sum of active slab
    /// bytes in contiguous mode
    pub peak_kv_bytes: usize,
}

/// Replay `requests` through the continuous-batching loop: admit by token
/// budget, prefill new admissions (parallel across prompts), then one
/// batched decode step per iteration for everything active. `kv` picks
/// the cache backing (`KvSpec::contig()` reproduces the historical
/// per-request slabs bitwise).
pub fn run_trace(
    ctx: &ServeContext,
    backend: Option<(&Engine, &[BlockTensors])>,
    requests: Vec<Request>,
    scfg: &SchedulerConfig,
    kv: &KvSpec,
) -> Result<TraceStats> {
    struct Active {
        req: Request,
        cache: Kv,
        last: i32,
        produced: usize,
        tokens: Vec<i32>,
    }
    let total = requests.len();
    for r in &requests {
        if r.cost() > ctx.max_pos() {
            bail!(
                "request {} needs {} positions but the context allows {}",
                r.id,
                r.cost(),
                ctx.max_pos()
            );
        }
    }
    let d = ctx.model.cfg.d_model;
    let mut sched = Scheduler::new(scfg.clone(), requests)?;
    let mut active: Vec<Active> = Vec::new();
    let mut finished: Vec<FinishedRequest> = Vec::with_capacity(total);
    let mut scratch = DecodeScratch::new();
    let sw = Stopwatch::start();
    // Work-conserving replay: when the system drains before the next
    // arrival, the trace clock jumps forward instead of busy-waiting, so
    // latencies keep their Poisson waits but the bench never idles.
    let mut clock_offset = 0.0f64;
    let mut prompt_tokens = 0usize;
    let mut gen_tokens = 0usize;
    let mut peak_active = 0usize;
    let mut peak_contig_bytes = 0usize;
    while finished.len() < total {
        let mut now = sw.secs() + clock_offset;
        if active.is_empty() {
            if let Some(na) = sched.next_arrival() {
                if na > now {
                    clock_offset += na - now;
                    now = na;
                }
            }
        }
        let admitted = sched.admit(now, active.len());
        if !admitted.is_empty() {
            let prefilled = par_map(&admitted, |req| {
                let mut cache = match ctx.new_kv(kv, req.cost()) {
                    Some(c) => c,
                    None => bail!(
                        "page pool cannot cover admitted request {} ({} tokens)",
                        req.id,
                        req.cost()
                    ),
                };
                let hidden = prefill(ctx, &req.tokens, &mut cache);
                Ok((cache, hidden))
            })?;
            for (req, (cache, hidden)) in admitted.into_iter().zip(prefilled) {
                prompt_tokens += req.tokens.len();
                let s = req.tokens.len();
                match req.kind {
                    ReqKind::Score => {
                        let nll = score_nll(ctx, &hidden, &req.tokens);
                        let cost = req.cost();
                        finished.push(FinishedRequest {
                            id: req.id,
                            latency_s: (sw.secs() + clock_offset - req.arrival).max(0.0),
                            out_tokens: 0,
                            tokens: Vec::new(),
                            nll: Some(nll.iter().map(|v| *v as f64).sum()),
                        });
                        sched.release(cost);
                    }
                    ReqKind::Generate { max_new } => {
                        let first =
                            argmax(&last_logits(ctx, &hidden[(s - 1) * d..s * d])) as i32;
                        gen_tokens += 1;
                        if max_new <= 1 {
                            let cost = req.cost();
                            finished.push(FinishedRequest {
                                id: req.id,
                                latency_s: (sw.secs() + clock_offset - req.arrival).max(0.0),
                                out_tokens: 1,
                                tokens: vec![first],
                                nll: None,
                            });
                            sched.release(cost);
                        } else {
                            active.push(Active {
                                req,
                                cache,
                                last: first,
                                produced: 1,
                                tokens: vec![first],
                            });
                        }
                    }
                }
            }
        }
        peak_active = peak_active.max(active.len());
        peak_contig_bytes =
            peak_contig_bytes.max(active.iter().map(|a| a.cache.mem_bytes()).sum());
        if !active.is_empty() {
            let last: Vec<i32> = active.iter().map(|a| a.last).collect();
            let next = {
                let mut caches = gather_caches(&mut active, |a| &mut a.cache);
                match backend {
                    Some((engine, blocks)) => {
                        decode_step_backend(ctx, engine, blocks, &last, &mut caches)?
                    }
                    None => decode_step(ctx, &last, &mut caches, &mut scratch),
                }
            };
            gen_tokens += next.len();
            for (a, t) in active.iter_mut().zip(&next) {
                a.last = *t;
                a.produced += 1;
                a.tokens.push(*t);
            }
            let done_now = sw.secs() + clock_offset;
            let mut i = 0;
            while i < active.len() {
                let max_new = match active[i].req.kind {
                    ReqKind::Generate { max_new } => max_new,
                    ReqKind::Score => 0,
                };
                if active[i].produced >= max_new {
                    let a = active.swap_remove(i);
                    sched.release(a.req.cost());
                    finished.push(FinishedRequest {
                        id: a.req.id,
                        latency_s: (done_now - a.req.arrival).max(0.0),
                        out_tokens: a.produced,
                        tokens: a.tokens,
                        nll: None,
                    });
                } else {
                    i += 1;
                }
            }
        }
    }
    let peak_kv_bytes = match kv.pool() {
        Some(p) => p.stats().peak_live * p.page_bytes(),
        None => peak_contig_bytes,
    };
    Ok(TraceStats {
        finished,
        wall_s: sw.secs(),
        prompt_tokens,
        gen_tokens,
        peak_active,
        peak_kv_bytes,
    })
}

/// Aggregated metrics of one mode's replay.
pub struct ModeReport {
    pub mode: String,
    pub requests: usize,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub peak_active: usize,
    pub weight_mbytes: f64,
    pub peak_kv_mbytes: f64,
}

fn mode_report(mode: ServeMode, weight_bytes: usize, stats: &TraceStats) -> ModeReport {
    let lat_ms: Vec<f64> = stats.finished.iter().map(|f| f.latency_s * 1e3).collect();
    let tokens = stats.prompt_tokens + stats.gen_tokens;
    ModeReport {
        mode: mode.name().to_string(),
        requests: stats.finished.len(),
        prompt_tokens: stats.prompt_tokens,
        gen_tokens: stats.gen_tokens,
        wall_s: stats.wall_s,
        tokens_per_s: tokens as f64 / stats.wall_s.max(1e-9),
        mean_ms: mean(&lat_ms),
        p50_ms: percentile(&lat_ms, 50.0),
        p95_ms: percentile(&lat_ms, 95.0),
        p99_ms: percentile(&lat_ms, 99.0),
        peak_active: stats.peak_active,
        weight_mbytes: weight_bytes as f64 / (1024.0 * 1024.0),
        peak_kv_mbytes: stats.peak_kv_bytes as f64 / (1024.0 * 1024.0),
    }
}

/// The async multi-worker section (`besa serve-bench --async`): replay
/// the trace through the online engine at one worker and at `workers`
/// workers, so the record shows the sharding scaling on the same trace.
pub struct OnlineBenchConfig {
    /// workers in the sharded run (the single-worker baseline is extra)
    pub workers: usize,
    /// weight format every replica packs
    pub format: WeightFormat,
    pub pacing: Pacing,
    /// arrival-queue pop order (output-invariant)
    pub policy: Policy,
    /// arrival-queue capacity; 0 = unbounded
    pub queue_cap: usize,
}

impl Default for OnlineBenchConfig {
    fn default() -> Self {
        OnlineBenchConfig {
            workers: 4,
            format: WeightFormat::Csr,
            pacing: Pacing::Replay { time_scale: 1.0 },
            policy: Policy::Fifo,
            queue_cap: 0,
        }
    }
}

/// The overload section (`besa serve-bench --overload-sweep`): the same
/// seeded, deadline-carrying trace replayed at several offered-load
/// multipliers, once per queue policy, measuring *goodput* — requests
/// completed within their deadline per second — plus shed/reject counts.
/// The interesting claim is graceful degradation: past saturation,
/// goodput should flatten (work is shed early) instead of collapsing
/// (everything finishes late).
pub struct OverloadSweepConfig {
    /// offered-load multipliers (1.0 = the trace's own rate; replayed at
    /// `time_scale = 1/m`)
    pub multipliers: Vec<f64>,
    /// queue policies to compare
    pub policies: Vec<Policy>,
    pub workers: usize,
    /// weight format every replica packs
    pub format: WeightFormat,
    /// per-request completion deadline, seconds
    pub deadline_s: f64,
    /// bounded arrival-queue capacity
    pub queue_cap: usize,
    /// predictive admit-time shedding
    pub admit_reject: bool,
    /// sparsity of the degrade tier (`--degrade`): add a shed-only vs
    /// degrade goodput comparison served from a second, sparser replica
    /// set; None skips the section
    pub degrade_sparsity: Option<f64>,
}

impl Default for OverloadSweepConfig {
    fn default() -> Self {
        OverloadSweepConfig {
            multipliers: vec![0.5, 1.0, 2.0, 4.0, 8.0],
            policies: Policy::ALL.to_vec(),
            workers: 2,
            format: WeightFormat::Csr,
            deadline_s: 0.25,
            queue_cap: 64,
            admit_reject: true,
            degrade_sparsity: None,
        }
    }
}

/// Everything `besa serve-bench` needs.
pub struct ServeBenchConfig {
    pub modes: Vec<ServeMode>,
    pub trace: TraceConfig,
    pub sched: SchedulerConfig,
    pub quant: QuantSpec,
    /// KV-cache backing for every replay (`--kv contig|paged`); paged
    /// mode adds the paged-vs-contiguous section to the record
    pub kv: KvMode,
    /// register prompts in a [`PrefixRegistry`] so later admissions fork
    /// their shared prefix instead of recomputing it (paged mode only)
    pub share_prefix: bool,
    /// tokens generated in the KV-vs-recompute parity check
    pub parity_decode_tokens: usize,
    /// run the async multi-worker section too
    pub online: Option<OnlineBenchConfig>,
    /// run the goodput-vs-offered-load overload sweep too
    pub overload: Option<OverloadSweepConfig>,
    /// where to write the machine-readable record; None skips the file
    pub json_path: Option<PathBuf>,
    /// dump per-request telemetry spans of the online sections as JSONL
    pub trace_out: Option<PathBuf>,
    /// deterministic fault injection for the online sections
    /// (`--faults`/`--fault-seed`); None is the zero-overhead path
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for ServeBenchConfig {
    fn default() -> Self {
        ServeBenchConfig {
            modes: vec![
                ServeMode::Dense,
                ServeMode::Sparse,
                ServeMode::Quant,
                ServeMode::DenseBackend,
            ],
            trace: TraceConfig::default(),
            sched: SchedulerConfig::default(),
            quant: QuantSpec::default(),
            kv: KvMode::Contig,
            share_prefix: false,
            parity_decode_tokens: 8,
            online: None,
            overload: None,
            json_path: Some(PathBuf::from("BENCH_serve.json")),
            trace_out: None,
            faults: None,
        }
    }
}

/// Parity of the fast paths against dense full-prefix recompute.
pub struct ParityReport {
    /// max |NLL| gap, sparse scoring vs dense scoring, over one prompt
    pub max_score_nll_diff: f64,
    /// sparse KV-cached greedy tokens == dense full-recompute tokens
    pub sparse_decode_matches: bool,
    /// backend-routed (`block_fwd_cached`) tokens == dense full-recompute
    pub backend_decode_matches: bool,
    /// fused-dequant path vs dense serving of the fake-quantized
    /// checkpoint (its exact reference — quant legitimately differs from
    /// the raw dense model): (max NLL gap, decode tokens match).
    /// None when the quant mode was not requested.
    pub quant: Option<(f64, bool)>,
}

fn parity_check(
    engine: &Engine,
    params: &ParamStore,
    cfg: &ModelConfig,
    bcfg: &ServeBenchConfig,
    prompt: &[i32],
) -> Result<ParityReport> {
    let n = bcfg.parity_decode_tokens.max(1);
    let max_pos = prompt.len() + n + 1;
    let dense_ctx =
        ServeContext::new(PackedModel::materialize(params, cfg, WeightFormat::Dense)?, max_pos);
    let sparse_ctx =
        ServeContext::new(PackedModel::materialize(params, cfg, WeightFormat::Csr)?, max_pos);

    // scoring parity on the prompt
    let mut c1 = dense_ctx.new_cache();
    let h_dense = prefill(&dense_ctx, prompt, &mut c1);
    let mut c2 = sparse_ctx.new_cache();
    let h_sparse = prefill(&sparse_ctx, prompt, &mut c2);
    let nll_dense = score_nll(&dense_ctx, &h_dense, prompt);
    let nll_sparse = score_nll(&sparse_ctx, &h_sparse, prompt);
    let max_score_nll_diff = nll_dense
        .iter()
        .zip(&nll_sparse)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);

    // decode parity: cached fast paths vs dense full-prefix recompute
    let reference = greedy_recompute(&dense_ctx, prompt, n);
    let sparse_decode_matches = greedy_cached(&sparse_ctx, prompt, n) == reference;
    let backend_decode_matches = if bcfg.modes.contains(&ServeMode::DenseBackend) {
        let blocks = block_tensors(params, cfg)?;
        greedy_backend(&dense_ctx, engine, &blocks, prompt, n)? == reference
    } else {
        true
    };

    // quant parity against its exact reference: dense serving of the
    // fake-quantized checkpoint (the fused dequant must reproduce it)
    let quant = if bcfg.modes.contains(&ServeMode::Quant) {
        let mut params_q = params.clone();
        crate::quant::quantize_model(&mut params_q, cfg, bcfg.quant)?;
        let dense_q_ctx = ServeContext::new(
            PackedModel::materialize(&params_q, cfg, WeightFormat::Dense)?,
            max_pos,
        );
        let quant_ctx = ServeContext::new(
            PackedModel::materialize(params, cfg, WeightFormat::Quant(bcfg.quant))?,
            max_pos,
        );
        let mut cq = quant_ctx.new_cache();
        let nll_q = score_nll(&quant_ctx, &prefill(&quant_ctx, prompt, &mut cq), prompt);
        let mut cd = dense_q_ctx.new_cache();
        let nll_d = score_nll(&dense_q_ctx, &prefill(&dense_q_ctx, prompt, &mut cd), prompt);
        let diff = nll_q
            .iter()
            .zip(&nll_d)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0f64, f64::max);
        let decode_ok =
            greedy_cached(&quant_ctx, prompt, n) == greedy_recompute(&dense_q_ctx, prompt, n);
        Some((diff, decode_ok))
    } else {
        None
    };
    Ok(ParityReport { max_score_nll_diff, sparse_decode_matches, backend_decode_matches, quant })
}

/// Aggregate numbers of one online run, plus its JSON record.
struct OnlineRunSummary {
    tokens_per_s: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_queue_wait_ms: f64,
    mean_service_ms: f64,
    /// mean worker utilization: busy seconds / (workers · wall seconds)
    utilization: f64,
    record: Json,
}

fn online_run_summary(stats: &OnlineStats, workers: usize) -> OnlineRunSummary {
    let lat_ms: Vec<f64> = stats.finished.iter().map(|f| f.latency_s * 1e3).collect();
    let wait_ms: Vec<f64> = stats.finished.iter().map(|f| f.queue_wait_s * 1e3).collect();
    let service_ms: Vec<f64> = stats
        .finished
        .iter()
        .map(|f| (f.latency_s - f.queue_wait_s) * 1e3)
        .collect();
    let wall = stats.wall_s.max(1e-9);
    let prompt_tokens: usize = stats.workers.iter().map(|w| w.prompt_tokens).sum();
    let gen_tokens: usize = stats.workers.iter().map(|w| w.gen_tokens).sum();
    let busy_s: f64 = stats.workers.iter().map(|w| w.busy_s).sum();
    let tokens_per_s = (prompt_tokens + gen_tokens) as f64 / wall;
    let mean_queue_wait_ms = mean(&wait_ms);
    let mean_service_ms = mean(&service_ms);
    let per_worker: Vec<Json> = stats
        .workers
        .iter()
        .map(|w| {
            json::obj(vec![
                ("worker", json::num(w.worker as f64)),
                ("requests", json::num(w.requests as f64)),
                ("prompt_tokens", json::num(w.prompt_tokens as f64)),
                ("gen_tokens", json::num(w.gen_tokens as f64)),
                ("tokens_per_s", json::num((w.prompt_tokens + w.gen_tokens) as f64 / wall)),
                ("busy_s", json::num(w.busy_s)),
                ("utilization", json::num(w.busy_s / wall)),
                ("peak_active", json::num(w.peak_active as f64)),
            ])
        })
        .collect();
    let record = json::obj(vec![
        ("workers", json::num(workers as f64)),
        ("requests", json::num(stats.finished.len() as f64)),
        ("prompt_tokens", json::num(prompt_tokens as f64)),
        ("gen_tokens", json::num(gen_tokens as f64)),
        ("wall_s", json::num(stats.wall_s)),
        ("tokens_per_s", json::num(tokens_per_s)),
        ("p50_ms", json::num(percentile(&lat_ms, 50.0))),
        ("p95_ms", json::num(percentile(&lat_ms, 95.0))),
        ("p99_ms", json::num(percentile(&lat_ms, 99.0))),
        ("mean_queue_wait_ms", json::num(mean_queue_wait_ms)),
        ("p95_queue_wait_ms", json::num(percentile(&wait_ms, 95.0))),
        ("mean_service_ms", json::num(mean_service_ms)),
        (
            "queue_wait_fraction",
            json::num(mean_queue_wait_ms / (mean_queue_wait_ms + mean_service_ms).max(1e-12)),
        ),
        ("shed", json::num(stats.shed.len() as f64)),
        ("rejected", json::num(stats.rejected.len() as f64)),
        ("failed", json::num(stats.failed.len() as f64)),
        ("restarts", json::num(stats.restarts as f64)),
        ("requeues", json::num(stats.requeues as f64)),
        ("degraded", json::num(stats.degraded() as f64)),
        ("per_worker", Json::Arr(per_worker)),
    ]);
    OnlineRunSummary {
        tokens_per_s,
        p50_ms: percentile(&lat_ms, 50.0),
        p95_ms: percentile(&lat_ms, 95.0),
        p99_ms: percentile(&lat_ms, 99.0),
        mean_queue_wait_ms,
        mean_service_ms,
        utilization: busy_s / (workers as f64 * wall),
        record,
    }
}

/// The async multi-worker section: run the same trace through the online
/// engine at one worker and at `ocfg.workers` workers (fresh
/// [`PackedModel`] replicas each), print the scaling table, check that
/// sharded per-request outputs match the single-worker run, and return
/// the `online` record for `BENCH_serve.json`.
fn run_online_bench(
    params: &ParamStore,
    cfg: &ModelConfig,
    bcfg: &ServeBenchConfig,
    ocfg: &OnlineBenchConfig,
    tracer: Option<&Tracer>,
) -> Result<Json> {
    if ocfg.workers == 0 {
        bail!("async serving needs at least one worker");
    }
    let requests = poisson_trace(&bcfg.trace);
    if requests.is_empty() {
        bail!("trace produced no requests");
    }
    let max_pos = bcfg.trace.max_request_tokens();
    let counts: Vec<usize> = if ocfg.workers > 1 { vec![1, ocfg.workers] } else { vec![1] };
    println!(
        "\n== serve-bench async: format {}, pacing {}, up to {} workers ==",
        ocfg.format.name(),
        ocfg.pacing.name(),
        ocfg.workers
    );
    println!(
        "{:<8} {:>10} {:>9} {:>9} {:>9} {:>10} {:>11} {:>6}",
        "workers", "tok/s", "p50 ms", "p95 ms", "p99 ms", "q-wait ms", "service ms", "util"
    );
    let mut runs: Vec<Json> = Vec::new();
    let mut tps: Vec<f64> = Vec::new();
    // id -> (greedy tokens, scoring NLL): the sharded parity signature
    let mut outputs: Vec<BTreeMap<usize, (Vec<i32>, Option<f64>)>> = Vec::new();
    for &w in &counts {
        let ctxs = (0..w)
            .map(|_| {
                Ok(ServeContext::new(
                    PackedModel::materialize(params, cfg, ocfg.format)?,
                    max_pos,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let stats = serve_online_traced(
            &ctxs,
            requests.clone(),
            &OnlineConfig {
                workers: w,
                sched: bcfg.sched.clone(),
                pacing: ocfg.pacing,
                policy: ocfg.policy,
                queue_cap: ocfg.queue_cap,
                kv: bcfg.kv,
                share_prefix: bcfg.share_prefix,
                faults: bcfg.faults.clone(),
                ..OnlineConfig::default()
            },
            tracer,
        )?;
        let summary = online_run_summary(&stats, w);
        println!(
            "{:<8} {:>10.0} {:>9.2} {:>9.2} {:>9.2} {:>10.2} {:>11.2} {:>5.0}%",
            w,
            summary.tokens_per_s,
            summary.p50_ms,
            summary.p95_ms,
            summary.p99_ms,
            summary.mean_queue_wait_ms,
            summary.mean_service_ms,
            summary.utilization * 100.0
        );
        outputs.push(
            stats.finished.iter().map(|f| (f.id, (f.tokens.clone(), f.nll))).collect(),
        );
        tps.push(summary.tokens_per_s);
        runs.push(summary.record);
    }
    let sharded_matches = outputs.windows(2).all(|w| w[0] == w[1]);
    let scaling = match (tps.first(), tps.last()) {
        (Some(first), Some(last)) => last / first.max(1e-9),
        _ => 1.0,
    };
    if counts.len() > 1 {
        println!(
            "async scaling: {:.2}x tok/s at {} workers vs 1; sharded outputs {} single-worker",
            scaling,
            ocfg.workers,
            if sharded_matches { "match" } else { "MISMATCH" }
        );
        if !sharded_matches {
            crate::warnlog!("sharded serving changed per-request outputs vs a single worker");
        }
    }
    let mut fields = vec![
        ("format", json::s(ocfg.format.name())),
        ("pacing", json::s(ocfg.pacing.name())),
        ("policy", json::s(ocfg.policy.name())),
        ("queue_cap", json::num(ocfg.queue_cap as f64)),
    ];
    match ocfg.pacing {
        Pacing::Replay { time_scale } => fields.push(("time_scale", json::num(time_scale))),
        Pacing::ClosedLoop { clients } => fields.push(("clients", json::num(clients as f64))),
    }
    fields.push(("workers", json::num(ocfg.workers as f64)));
    fields.push(("runs", Json::Arr(runs)));
    fields.push(("sharded_matches_single", Json::Bool(sharded_matches)));
    if counts.len() > 1 {
        fields.push(("scaling_vs_single_worker", json::num(scaling)));
    }
    Ok(json::obj(fields))
}

/// The overload sweep: goodput-vs-offered-load curves per queue policy.
/// Every cell replays the *same* seeded trace (deadlines, priority tiers
/// and client ids baked in) at `time_scale = 1/multiplier`, so the only
/// thing that varies along a curve is how hard the arrivals press.
fn run_overload_sweep(
    params: &ParamStore,
    cfg: &ModelConfig,
    bcfg: &ServeBenchConfig,
    swcfg: &OverloadSweepConfig,
    tracer: Option<&Tracer>,
) -> Result<Json> {
    if swcfg.workers == 0 {
        bail!("overload sweep needs at least one worker");
    }
    if !swcfg.deadline_s.is_finite() || swcfg.deadline_s <= 0.0 {
        bail!("overload sweep needs a positive finite deadline");
    }
    if swcfg.multipliers.is_empty() || swcfg.policies.is_empty() {
        bail!("overload sweep needs at least one multiplier and one policy");
    }
    for &m in &swcfg.multipliers {
        if !m.is_finite() || m <= 0.0 {
            bail!("offered-load multipliers must be positive, got {m}");
        }
    }
    // the sweep trace: the bench trace plus uniform deadlines, 3 priority
    // tiers and 4 clients (so priority/EDF have something to order by)
    let tcfg = TraceConfig {
        deadline_min_s: swcfg.deadline_s,
        deadline_max_s: swcfg.deadline_s,
        priority_tiers: 3,
        clients: 4,
        ..bcfg.trace.clone()
    };
    let requests = poisson_trace(&tcfg);
    if requests.is_empty() {
        bail!("trace produced no requests");
    }
    let n = requests.len();
    let max_pos = tcfg.max_request_tokens();
    let ctxs = (0..swcfg.workers)
        .map(|_| {
            Ok(ServeContext::new(PackedModel::materialize(params, cfg, swcfg.format)?, max_pos))
        })
        .collect::<Result<Vec<_>>>()?;
    println!(
        "\n== overload sweep: format {}, {} workers, deadline {:.0} ms, queue cap {} ==",
        swcfg.format.name(),
        swcfg.workers,
        swcfg.deadline_s * 1e3,
        swcfg.queue_cap
    );
    println!(
        "{:<10} {:>6} {:>12} {:>10} {:>9} {:>6} {:>9} {:>12} {:>8}",
        "policy",
        "xload",
        "offered r/s",
        "completed",
        "in-dl",
        "shed",
        "rejected",
        "goodput r/s",
        "frac"
    );
    let mut policy_rows: Vec<Json> = Vec::new();
    for &policy in &swcfg.policies {
        let mut points: Vec<Json> = Vec::new();
        for &m in &swcfg.multipliers {
            let ocfg = OnlineConfig {
                workers: swcfg.workers,
                sched: bcfg.sched.clone(),
                pacing: Pacing::Replay { time_scale: 1.0 / m },
                policy,
                queue_cap: swcfg.queue_cap,
                admit_reject: swcfg.admit_reject,
                kv: bcfg.kv,
                share_prefix: bcfg.share_prefix,
                faults: bcfg.faults.clone(),
                ..OnlineConfig::default()
            };
            let stats = serve_online_traced(&ctxs, requests.clone(), &ocfg, tracer)?;
            let within = stats.within_deadline();
            let wall = stats.wall_s.max(1e-9);
            let goodput_rps = within as f64 / wall;
            let goodput_frac = within as f64 / n as f64;
            println!(
                "{:<10} {:>5.1}x {:>12.1} {:>10} {:>9} {:>6} {:>9} {:>12.1} {:>7.1}%",
                policy.name(),
                m,
                tcfg.rate * m,
                stats.finished.len(),
                within,
                stats.shed.len(),
                stats.rejected.len(),
                goodput_rps,
                goodput_frac * 100.0
            );
            points.push(json::obj(vec![
                ("multiplier", json::num(m)),
                ("offered_rps", json::num(tcfg.rate * m)),
                ("wall_s", json::num(stats.wall_s)),
                ("completed", json::num(stats.finished.len() as f64)),
                ("within_deadline", json::num(within as f64)),
                ("shed", json::num(stats.shed.len() as f64)),
                ("rejected", json::num(stats.rejected.len() as f64)),
                ("goodput_rps", json::num(goodput_rps)),
                ("goodput_frac", json::num(goodput_frac)),
            ]));
        }
        policy_rows.push(json::obj(vec![
            ("policy", json::s(policy.name())),
            ("points", Json::Arr(points)),
        ]));
    }

    // sparsity-tiered degradation: the same overload points served twice
    // — shed-only vs routing pressured admissions to a sparser (faster)
    // replica set instead of letting them miss their deadlines. The
    // interesting claim: past saturation, degraded goodput holds above
    // shed-only, because a sparse answer beats a 503.
    let degrade = match swcfg.degrade_sparsity {
        Some(ds) => Some(run_degrade_sweep(params, cfg, bcfg, swcfg, &tcfg, &requests, ds, tracer)?),
        None => None,
    };

    let mut fields = vec![
        ("deadline_ms", json::num(swcfg.deadline_s * 1e3)),
        ("workers", json::num(swcfg.workers as f64)),
        ("queue_cap", json::num(swcfg.queue_cap as f64)),
        ("admit_reject", Json::Bool(swcfg.admit_reject)),
        ("format", json::s(swcfg.format.name())),
        ("requests", json::num(n as f64)),
        ("base_rate", json::num(tcfg.rate)),
        ("policies", Json::Arr(policy_rows)),
    ];
    if let Some(d) = degrade {
        fields.push(("degrade", d));
    }
    Ok(json::obj(fields))
}

/// The shed-only vs degrade goodput comparison: every overload multiplier
/// runs once without a degrade tier and once with one (a second replica
/// set magnitude-pruned to `degrade_sparsity`, same weight format), on the
/// same seeded trace and the sweep's first policy.
#[allow(clippy::too_many_arguments)]
fn run_degrade_sweep(
    params: &ParamStore,
    cfg: &ModelConfig,
    bcfg: &ServeBenchConfig,
    swcfg: &OverloadSweepConfig,
    tcfg: &TraceConfig,
    requests: &[Request],
    degrade_sparsity: f64,
    tracer: Option<&Tracer>,
) -> Result<Json> {
    if !(0.0..1.0).contains(&degrade_sparsity) {
        bail!("degrade sparsity must be in [0, 1), got {degrade_sparsity}");
    }
    let policy = swcfg.policies[0];
    let max_pos = tcfg.max_request_tokens();
    let n = requests.len();
    let ctxs = (0..swcfg.workers)
        .map(|_| {
            Ok(ServeContext::new(PackedModel::materialize(params, cfg, swcfg.format)?, max_pos))
        })
        .collect::<Result<Vec<_>>>()?;
    let mut degraded_params = params.clone();
    magnitude_prune_in_place(&mut degraded_params, cfg, degrade_sparsity)?;
    let dctxs = (0..swcfg.workers)
        .map(|_| {
            Ok(ServeContext::new(
                PackedModel::materialize(&degraded_params, cfg, swcfg.format)?,
                max_pos,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    println!(
        "\n== degrade sweep: tier sparsity {:.2}, policy {}, {} workers ==",
        degrade_sparsity,
        policy.name(),
        swcfg.workers
    );
    println!(
        "{:<6} {:>14} {:>13} {:>9} {:>6} {:>7}",
        "xload", "shed-only r/s", "degrade r/s", "degraded", "shed", "failed"
    );
    let mut points: Vec<Json> = Vec::new();
    for &m in &swcfg.multipliers {
        let ocfg = OnlineConfig {
            workers: swcfg.workers,
            sched: bcfg.sched.clone(),
            pacing: Pacing::Replay { time_scale: 1.0 / m },
            policy,
            queue_cap: swcfg.queue_cap,
            admit_reject: swcfg.admit_reject,
            kv: bcfg.kv,
            share_prefix: bcfg.share_prefix,
            faults: bcfg.faults.clone(),
            ..OnlineConfig::default()
        };
        let shed_only =
            serve_online_tiered(&ctxs, None, requests.to_vec(), &ocfg, tracer)?;
        let tiered =
            serve_online_tiered(&ctxs, Some(dctxs.as_slice()), requests.to_vec(), &ocfg, tracer)?;
        let shed_goodput = shed_only.within_deadline() as f64 / shed_only.wall_s.max(1e-9);
        let tier_goodput = tiered.within_deadline() as f64 / tiered.wall_s.max(1e-9);
        println!(
            "{:>5.1}x {:>14.1} {:>13.1} {:>9} {:>6} {:>7}",
            m,
            shed_goodput,
            tier_goodput,
            tiered.degraded(),
            tiered.shed.len(),
            tiered.failed.len()
        );
        points.push(json::obj(vec![
            ("multiplier", json::num(m)),
            ("offered_rps", json::num(tcfg.rate * m)),
            ("shed_only_goodput_rps", json::num(shed_goodput)),
            ("shed_only_within_deadline", json::num(shed_only.within_deadline() as f64)),
            ("shed_only_shed", json::num(shed_only.shed.len() as f64)),
            ("degrade_goodput_rps", json::num(tier_goodput)),
            ("degrade_within_deadline", json::num(tiered.within_deadline() as f64)),
            ("degraded", json::num(tiered.degraded() as f64)),
            ("degrade_shed", json::num(tiered.shed.len() as f64)),
            ("degrade_failed", json::num(tiered.failed.len() as f64)),
        ]));
    }
    Ok(json::obj(vec![
        ("sparsity", json::num(degrade_sparsity)),
        ("policy", json::s(policy.name())),
        ("requests", json::num(n as f64)),
        ("points", Json::Arr(points)),
    ]))
}

/// The paged-vs-contiguous section (`--kv paged`): the same trace under
/// both cache backings (resident-KV high-water mark + output parity),
/// admission concurrency under a fixed memory budget, the prefix-sharing
/// residency reduction on a shared-prompt trace, and park/steal counts
/// under a skewed decode-length trace with work stealing on.
fn run_paged_bench(
    params: &ParamStore,
    cfg: &ModelConfig,
    bcfg: &ServeBenchConfig,
    tracer: Option<&Tracer>,
) -> Result<Json> {
    let (page_tokens, max_pages) = match bcfg.kv {
        KvMode::Paged { page_tokens, max_pages } => (page_tokens, max_pages),
        KvMode::Contig => bail!("the paged section needs --kv paged"),
    };
    let (nb, d) = (cfg.n_blocks, cfg.d_model);
    let requests = poisson_trace(&bcfg.trace);
    if requests.is_empty() {
        bail!("trace produced no requests");
    }
    let max_pos = bcfg.trace.max_request_tokens();
    let ctx =
        ServeContext::new(PackedModel::materialize(params, cfg, WeightFormat::Dense)?, max_pos);
    let page_bytes = PagePool::new(nb, d, page_tokens, 0).page_bytes();
    println!(
        "\n== serve-bench paged: {} tokens/page ({:.1} KiB/page), cap {} pages ==",
        page_tokens,
        page_bytes as f64 / 1024.0,
        max_pages
    );

    // the same trace under both backings: resident KV + output parity
    let contig = run_trace(&ctx, None, requests.clone(), &bcfg.sched, &KvSpec::contig())?;
    let paged_spec = KvSpec::for_mode(bcfg.kv, nb, d);
    let paged = run_trace(&ctx, None, requests.clone(), &bcfg.sched, &paged_spec)?;
    let contig_map: BTreeMap<usize, Vec<i32>> =
        contig.finished.iter().map(|f| (f.id, f.tokens.clone())).collect();
    let paged_map: BTreeMap<usize, Vec<i32>> =
        paged.finished.iter().map(|f| (f.id, f.tokens.clone())).collect();
    let outputs_match = contig_map == paged_map;
    if !outputs_match {
        crate::warnlog!("paged replay changed per-request outputs vs contiguous");
    }
    println!(
        "resident KV (same trace): contig peak {:.3} MB, paged peak {:.3} MB; outputs {}",
        contig.peak_kv_bytes as f64 / (1024.0 * 1024.0),
        paged.peak_kv_bytes as f64 / (1024.0 * 1024.0),
        if outputs_match { "match" } else { "MISMATCH" }
    );

    // fixed memory budget: whole contiguous slabs vs cost-sized paged
    // reservations (no model run — pure admission accounting)
    let contig_bytes = 2 * nb * max_pos * d * 4;
    let budget = 4 * contig_bytes;
    let contig_fit = budget / contig_bytes;
    let pool = PagePool::new(nb, d, page_tokens, budget / page_bytes);
    let mut held = Vec::new();
    for r in &requests {
        match pool.new_table(r.cost()) {
            Some(t) => held.push(t),
            None => break,
        }
    }
    let paged_fit = held.len();
    drop(held);
    println!(
        "fixed memory ({:.3} MB): contig fits {} requests, paged admits {}",
        budget as f64 / (1024.0 * 1024.0),
        contig_fit,
        paged_fit
    );

    // prefix sharing on a shared-prompt trace: materialize every prompt's
    // pages with and without the registry (dummy rows — the residency
    // accounting is what's measured) and compare live pool pages
    let prefix_tokens = (4 * page_tokens).max(8);
    let shared_cfg = TraceConfig { shared_prefix_len: prefix_tokens, ..bcfg.trace.clone() };
    let shared_reqs = poisson_trace(&shared_cfg);
    let (zk, zv) = (vec![0.0f32; d], vec![0.0f32; d]);
    let pool_a = PagePool::new(nb, d, page_tokens, 0);
    let mut held_a = Vec::new();
    for r in &shared_reqs {
        let s = r.tokens.len();
        let mut t = match pool_a.new_table(s) {
            Some(t) => t,
            None => bail!("unbounded pool refused a table"),
        };
        for pos in 0..s {
            t.write(0, pos, &zk, &zv);
        }
        t.set_len(s);
        held_a.push(t);
    }
    let unshared_bytes = pool_a.stats().live * page_bytes;
    drop(held_a);
    let pool_b = PagePool::new(nb, d, page_tokens, 0);
    let reg = PrefixRegistry::new(shared_reqs.len().max(1));
    let mut forks = 0usize;
    let mut held_b = Vec::new();
    for r in &shared_reqs {
        let s = r.tokens.len();
        let t = match reg.fork_longest(&r.tokens, s) {
            Some((p0, mut t)) => {
                forks += 1;
                for pos in p0..s {
                    t.write(0, pos, &zk, &zv);
                }
                t.set_len(s);
                t
            }
            None => {
                let mut t = match pool_b.new_table(s) {
                    Some(t) => t,
                    None => bail!("unbounded pool refused a table"),
                };
                for pos in 0..s {
                    t.write(0, pos, &zk, &zv);
                }
                t.set_len(s);
                reg.register(&r.tokens, &mut t);
                t
            }
        };
        held_b.push(t);
    }
    let shared_bytes = pool_b.stats().live * page_bytes;
    let cow_clones = pool_b.stats().cow_clones;
    drop(held_b);
    reg.clear();
    println!(
        "prefix sharing ({} shared tokens, {} requests): {:.3} MB -> {:.3} MB resident \
         ({} forks, {} cow clones)",
        prefix_tokens,
        shared_reqs.len(),
        unshared_bytes as f64 / (1024.0 * 1024.0),
        shared_bytes as f64 / (1024.0 * 1024.0),
        forks,
        cow_clones
    );

    // work stealing under a skewed decode-length trace: two workers, one
    // draws the long decodes, the idle one steals them mid-flight
    let skew_cfg = TraceConfig {
        score_fraction: 0.0,
        gen_min: 1,
        gen_max: (bcfg.trace.gen_max * 4).max(16),
        ..bcfg.trace.clone()
    };
    let skew_reqs = poisson_trace(&skew_cfg);
    // a cap sized for the base trace may not hold the stretched decodes
    let skew_kv = match bcfg.kv {
        KvMode::Paged { page_tokens, max_pages }
            if max_pages > 0
                && skew_reqs.iter().any(|r| r.cost() > max_pages * page_tokens) =>
        {
            KvMode::Paged { page_tokens, max_pages: 0 }
        }
        mode => mode,
    };
    let skew_max_pos = skew_cfg.max_request_tokens();
    let ctxs = (0..2)
        .map(|_| {
            Ok(ServeContext::new(
                PackedModel::materialize(params, cfg, WeightFormat::Dense)?,
                skew_max_pos,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    let stats = serve_online_traced(
        &ctxs,
        skew_reqs.clone(),
        &OnlineConfig {
            workers: 2,
            sched: bcfg.sched.clone(),
            pacing: Pacing::Replay { time_scale: 0.1 },
            kv: skew_kv,
            steal: true,
            share_prefix: bcfg.share_prefix,
            ..OnlineConfig::default()
        },
        tracer,
    )?;
    println!(
        "work stealing (skewed trace, 2 workers): {} finished, {} parks, {} steals",
        stats.finished.len(),
        stats.parks,
        stats.steals
    );

    Ok(json::obj(vec![
        ("page_tokens", json::num(page_tokens as f64)),
        ("max_pages", json::num(max_pages as f64)),
        ("page_bytes", json::num(page_bytes as f64)),
        ("outputs_match_contig", Json::Bool(outputs_match)),
        (
            "resident",
            json::obj(vec![
                ("contig_peak_bytes", json::num(contig.peak_kv_bytes as f64)),
                ("paged_peak_bytes", json::num(paged.peak_kv_bytes as f64)),
            ]),
        ),
        (
            "fixed_memory",
            json::obj(vec![
                ("budget_bytes", json::num(budget as f64)),
                ("contig_requests", json::num(contig_fit as f64)),
                ("paged_requests", json::num(paged_fit as f64)),
            ]),
        ),
        (
            "prefix_sharing",
            json::obj(vec![
                ("prefix_tokens", json::num(prefix_tokens as f64)),
                ("requests", json::num(shared_reqs.len() as f64)),
                ("forks", json::num(forks as f64)),
                ("cow_clones", json::num(cow_clones as f64)),
                ("resident_bytes_unshared", json::num(unshared_bytes as f64)),
                ("resident_bytes_shared", json::num(shared_bytes as f64)),
            ]),
        ),
        (
            "steal",
            json::obj(vec![
                ("workers", json::num(2.0)),
                ("requests", json::num(stats.finished.len() as f64)),
                ("parks", json::num(stats.parks as f64)),
                ("steals", json::num(stats.steals as f64)),
            ]),
        ),
    ]))
}

/// Zero the smallest-magnitude fraction of every prunable weight — the
/// hermetic stand-in checkpoint for `--smoke` / `--synthetic` runs (the
/// real flow serves a `besa prune` checkpoint via `--ckpt`).
pub fn magnitude_prune_in_place(
    params: &mut ParamStore,
    cfg: &ModelConfig,
    sparsity: f64,
) -> Result<()> {
    for l in 0..cfg.n_blocks {
        for w in LAYER_NAMES {
            let t = params.get_mut(&ParamStore::layer_name(l, w))?;
            let data = t.f32s_mut();
            let n_zero = (data.len() as f64 * sparsity).round() as usize;
            let mut idx: Vec<usize> = (0..data.len()).collect();
            // O(n) NaN-safe partial selection, same pattern as
            // prune::topk_row_mask
            if n_zero > 0 && n_zero < data.len() {
                idx.select_nth_unstable_by(n_zero - 1, |a, b| {
                    data[*a].abs().total_cmp(&data[*b].abs())
                });
            }
            for k in idx.into_iter().take(n_zero) {
                data[k] = 0.0;
            }
        }
    }
    Ok(())
}

/// Run the full serve benchmark: every requested mode over the same
/// trace, plus the parity section. Prints the human table and returns
/// (and optionally writes) the machine-readable record.
pub fn run_serve_bench(
    engine: &Engine,
    params: &ParamStore,
    bcfg: &ServeBenchConfig,
) -> Result<Json> {
    let cfg = engine.config().clone();
    let requests = poisson_trace(&bcfg.trace);
    if requests.is_empty() {
        bail!("trace produced no requests");
    }
    let max_pos = bcfg.trace.max_request_tokens();
    let n_score = requests.iter().filter(|r| r.kind == ReqKind::Score).count();
    let sparsity = params.prunable_sparsity(cfg.n_blocks);
    println!(
        "\n== serve-bench: config {}, backend {}, kv {}, sparsity {:.2}, {} requests ({} gen / {} score) ==",
        cfg.name,
        engine.backend_name(),
        bcfg.kv.name(),
        sparsity,
        requests.len(),
        requests.len() - n_score,
        n_score
    );
    let mut reports: Vec<ModeReport> = Vec::new();
    for mode in &bcfg.modes {
        let format = match mode {
            ServeMode::Dense | ServeMode::DenseBackend => WeightFormat::Dense,
            ServeMode::Sparse => WeightFormat::Csr,
            ServeMode::Quant => WeightFormat::Quant(bcfg.quant),
        };
        let model = PackedModel::materialize(params, &cfg, format)?;
        let weight_bytes = model.weight_bytes();
        let ctx = ServeContext::new(model, max_pos);
        let blocks;
        let backend = match mode {
            ServeMode::DenseBackend => {
                blocks = block_tensors(params, &cfg)?;
                Some((engine, blocks.as_slice()))
            }
            _ => None,
        };
        // fresh KV spec (and pool, in paged mode) per replay so resident
        // accounting never mixes across modes
        let kvspec = KvSpec::for_mode(bcfg.kv, cfg.n_blocks, cfg.d_model);
        let stats = run_trace(&ctx, backend, requests.clone(), &bcfg.sched, &kvspec)?;
        reports.push(mode_report(*mode, weight_bytes, &stats));
    }

    // report after all modes ran so speedups don't depend on mode order;
    // no dense baseline in the run -> no speedup column/record at all
    let dense_tps = reports
        .iter()
        .find(|r| r.mode == "dense")
        .map(|r| r.tokens_per_s)
        .filter(|tps| *tps > 0.0);
    println!(
        "{:<14} {:>10} {:>9} {:>9} {:>9} {:>10} {:>10} {:>9} {:>8}",
        "mode", "tok/s", "p50 ms", "p95 ms", "p99 ms", "wall s", "weights", "kv peak", "speedup"
    );
    for report in &reports {
        let speedup = match dense_tps {
            Some(base) => format!("{:.2}x", report.tokens_per_s / base),
            None => "-".to_string(),
        };
        println!(
            "{:<14} {:>10.0} {:>9.2} {:>9.2} {:>9.2} {:>10.2} {:>8.2}MB {:>7.2}MB {:>8}",
            report.mode,
            report.tokens_per_s,
            report.p50_ms,
            report.p95_ms,
            report.p99_ms,
            report.wall_s,
            report.weight_mbytes,
            report.peak_kv_mbytes,
            speedup
        );
    }

    // parity section only when a fast path is in the run — scoring +
    // decode parity of sparse/backend against dense full-prefix recompute
    // on the longest generation prompt of the trace
    let wants_parity = bcfg
        .modes
        .iter()
        .any(|m| matches!(m, ServeMode::Sparse | ServeMode::Quant | ServeMode::DenseBackend));
    let parity = if wants_parity {
        let parity_prompt = requests
            .iter()
            .filter(|r| matches!(r.kind, ReqKind::Generate { .. }))
            .max_by_key(|r| r.tokens.len())
            .map(|r| r.tokens.clone())
            .unwrap_or_else(|| requests[0].tokens.clone());
        let parity = parity_check(engine, params, &cfg, bcfg, &parity_prompt)?;
        println!(
            "parity: score nll diff {:.2e} (sparse vs dense), cached decode vs dense recompute: sparse {}, backend {}",
            parity.max_score_nll_diff,
            if parity.sparse_decode_matches { "match" } else { "MISMATCH" },
            if parity.backend_decode_matches { "match" } else { "MISMATCH" },
        );
        if let Some((diff, ok)) = parity.quant {
            println!(
                "parity: quant vs fake-quantized dense: nll diff {:.2e}, decode {}",
                diff,
                if ok { "match" } else { "MISMATCH" }
            );
        }
        if parity.max_score_nll_diff > 1e-5 {
            crate::warnlog!(
                "sparse scoring drifted {:.3e} from dense (tolerance 1e-5)",
                parity.max_score_nll_diff
            );
        }
        Some(parity)
    } else {
        None
    };

    // telemetry: one tracer shared by every traced section of the run
    let tracer = bcfg.trace_out.as_ref().map(|_| Tracer::new());

    // the traced sections run inside a closure so the spans collected up
    // to a failure still reach --trace-out: an abnormal end (e.g. a fault
    // schedule that exhausts every retry budget) is exactly when the
    // trace is worth having
    let traced = (|| -> Result<_> {
        // async multi-worker section
        let online = match &bcfg.online {
            Some(ocfg) => Some(run_online_bench(params, &cfg, bcfg, ocfg, tracer.as_ref())?),
            None => None,
        };

        // overload sweep: goodput-vs-offered-load curves per queue policy
        let overload = match &bcfg.overload {
            Some(swcfg) => Some(run_overload_sweep(params, &cfg, bcfg, swcfg, tracer.as_ref())?),
            None => None,
        };

        // paged-vs-contiguous section: residency, fixed-memory concurrency,
        // prefix sharing, work stealing
        let paged = match bcfg.kv {
            KvMode::Paged { .. } => Some(run_paged_bench(params, &cfg, bcfg, tracer.as_ref())?),
            KvMode::Contig => None,
        };
        Ok((online, overload, paged))
    })();
    if let (Some(path), Some(t)) = (&bcfg.trace_out, &tracer) {
        let n = t.write_jsonl(path)?;
        println!("[telemetry: {n} spans -> {}]", path.display());
    }
    let (online, overload, paged) = traced?;

    // machine-readable record
    let mode_rows: Vec<Json> = reports
        .iter()
        .map(|r| {
            json::obj(vec![
                ("mode", json::s(&r.mode)),
                ("requests", json::num(r.requests as f64)),
                ("prompt_tokens", json::num(r.prompt_tokens as f64)),
                ("gen_tokens", json::num(r.gen_tokens as f64)),
                ("wall_s", json::num(r.wall_s)),
                ("tokens_per_s", json::num(r.tokens_per_s)),
                ("mean_ms", json::num(r.mean_ms)),
                ("p50_ms", json::num(r.p50_ms)),
                ("p95_ms", json::num(r.p95_ms)),
                ("p99_ms", json::num(r.p99_ms)),
                ("peak_active", json::num(r.peak_active as f64)),
                ("weight_mbytes", json::num(r.weight_mbytes)),
                ("peak_kv_mbytes", json::num(r.peak_kv_mbytes)),
            ])
        })
        .collect();
    // speedups only exist relative to a measured dense baseline
    let speedups: Vec<(&str, Json)> = match dense_tps {
        Some(base) => reports
            .iter()
            .filter(|r| r.mode != "dense")
            .map(|r| (r.mode.as_str(), json::num(r.tokens_per_s / base)))
            .collect(),
        None => Vec::new(),
    };
    let mut payload_fields = vec![
        ("bench", json::s("serve_throughput")),
        ("config", json::s(&cfg.name)),
        ("backend", json::s(engine.backend_name())),
        ("kv", json::s(bcfg.kv.name())),
        ("sparsity", json::num(sparsity)),
        (
            "trace",
            json::obj(vec![
                ("n_requests", json::num(bcfg.trace.n_requests as f64)),
                ("rate", json::num(bcfg.trace.rate)),
                ("prompt_min", json::num(bcfg.trace.prompt_min as f64)),
                ("prompt_max", json::num(bcfg.trace.prompt_max as f64)),
                ("gen_min", json::num(bcfg.trace.gen_min as f64)),
                ("gen_max", json::num(bcfg.trace.gen_max as f64)),
                ("score_fraction", json::num(bcfg.trace.score_fraction)),
                ("burst", json::num(bcfg.trace.burst as f64)),
                ("seed", json::num(bcfg.trace.seed as f64)),
            ]),
        ),
        (
            "scheduler",
            json::obj(vec![
                ("token_budget", json::num(bcfg.sched.token_budget as f64)),
                ("max_batch", json::num(bcfg.sched.max_batch as f64)),
            ]),
        ),
        ("modes", Json::Arr(mode_rows)),
    ];
    if !speedups.is_empty() {
        payload_fields.push(("speedup_vs_dense", json::obj(speedups)));
    }
    if let Some(p) = &parity {
        let mut parity_fields = vec![
            ("max_score_nll_diff", json::num(p.max_score_nll_diff)),
            ("sparse_decode_matches", Json::Bool(p.sparse_decode_matches)),
            ("backend_decode_matches", Json::Bool(p.backend_decode_matches)),
        ];
        if let Some((diff, ok)) = p.quant {
            parity_fields.push(("quant_score_nll_diff", json::num(diff)));
            parity_fields.push(("quant_decode_matches", Json::Bool(ok)));
        }
        payload_fields.push(("parity", json::obj(parity_fields)));
    }
    if let Some(o) = online {
        payload_fields.push(("online", o));
    }
    if let Some(o) = overload {
        payload_fields.push(("overload", o));
    }
    if let Some(p) = paged {
        payload_fields.push(("paged", p));
    }
    let payload = json::obj(payload_fields);
    if let Some(path) = &bcfg.json_path {
        std::fs::write(path, payload.to_string_pretty())
            .with_context(|| format!("writing serve bench record to {}", path.display()))?;
        println!("[results -> {}]", path.display());
    }
    Ok(payload)
}
