//! Online multi-worker serving: a sharded worker pool behind the
//! real-time ingestion front end ([`super::ingest`]).
//!
//! [`serve_online`] runs one producer thread plus `workers` serving
//! workers on [`crate::util::par::scoped_workers`]. Each worker owns a
//! full [`ServeContext`] replica (packed weights + RoPE tables) and the
//! KV caches of the requests it admitted — nothing but the arrival queue
//! is shared, so workers never contend on model state. Every worker runs
//! its own continuous-batching loop: pull admissions from the shared
//! queue while its token budget and batch slots allow, prefill them, then
//! one batched decode step per iteration for everything active —
//! the same loop as the offline [`super::bench::run_trace`], sharded.
//! The same `worker_loop` also serves the TCP front end ([`super::net`]),
//! where it additionally streams tokens back over per-request reply
//! channels.
//!
//! # Determinism / parity
//!
//! Which worker serves a request (and which other requests share its
//! batch) is racy, but the *output* of a request is not: greedy decode
//! depends only on the model and the request's own prompt — batched
//! linears are row-independent and attention reads only the request's own
//! KV cache — so any worker count, and any queue [`Policy`], produces
//! identical per-request tokens and NLLs. `tests/serve_parity.rs` pins
//! sharded == single-worker == offline replay, and FIFO == priority ==
//! EDF per-request outputs. The same argument covers the paged KV mode
//! (`--kv paged`, [`super::paged`]): paging, copy-on-write prefix
//! sharing and decode work stealing move *where* a request's KV rows
//! live, never their values or read order, so paged == contiguous and
//! stolen-mid-decode == pinned are bitwise too (same suite).
//!
//! # Work stealing
//!
//! With `steal` enabled, workers share a [`StealBoard`]: an idle worker
//! posts demand and takes parked decodes; a busy worker with at least
//! two in flight parks its longest-remaining decode in response. The
//! handover moves the request's [`Active`] — page table included — so
//! no cache contents are copied. A parker never parks its last decode,
//! so it always keeps retiring work and parked pages always drain; a
//! worker only exits when the queue is drained *and* the board is empty,
//! so a parked decode can never be orphaned at shutdown.
//!
//! # Overload
//!
//! [`OnlineConfig`] exposes the queue's overload knobs (policy, bounded
//! capacity, predictive admit-time shedding); requests carrying deadlines
//! can be shed in-queue or rejected at push, and every outcome lands in
//! [`OnlineStats`] — `finished + shed + rejected == submitted`, always.
//!
//! # Metrics
//!
//! Per worker: requests served, prompt/generated tokens, busy (compute)
//! seconds vs pool wall-clock, peak batch occupancy. Per request: queue
//! wait (enqueue → admission) vs service (admission → retire) split, and
//! whether the deadline was met. With a [`Tracer`] attached
//! ([`serve_online_traced`]), workers also record queue/admit/prefill/
//! decode spans per request (see [`crate::telemetry`]). [`super::bench`]
//! merges these into aggregate throughput and latency percentiles for
//! `BENCH_serve.json`.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::telemetry::{sink_or_disabled, SpanKind, SpanSink, Tracer};
use crate::util::par::{locked, scoped_workers};

use super::engine::{
    argmax, decode_step, last_logits, prefill, prefill_continue, score_nll, DecodeScratch,
    ServeContext,
};
use super::fault::{self, FaultAction, FaultPlan, FaultSite};
use super::ingest::{
    run_producer, ArrivedRequest, IngestQueue, Pacing, Pop, QueueConfig, RejectOutcome, Reply,
    ShedOutcome,
};
use super::paged::{gather_caches, Kv, KvMode, KvSpec, PrefixRegistry};
use super::scheduler::{Policy, ReqKind, Request, SchedulerConfig};

/// How long an idle worker sleeps before re-checking the queue.
const IDLE_POLL: Duration = Duration::from_millis(1);

/// Configuration of one online run.
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    /// serving workers (the producer thread is extra)
    pub workers: usize,
    /// per-worker admission caps (token budget + batch slots)
    pub sched: SchedulerConfig,
    pub pacing: Pacing,
    /// arrival-queue pop order (output-invariant)
    pub policy: Policy,
    /// arrival-queue capacity; 0 = unbounded
    pub queue_cap: usize,
    /// predictive admit-time deadline shedding (see
    /// [`QueueConfig::admit_reject`])
    pub admit_reject: bool,
    /// KV cache backing per request (`--kv contig|paged`)
    pub kv: KvMode,
    /// decode work stealing: workers park long-running decodes on a
    /// shared board and idle workers take them over by page-table
    /// migration (`--steal`)
    pub steal: bool,
    /// copy-on-write prompt-prefix sharing across requests — paged mode
    /// only (`--share-prefix`)
    pub share_prefix: bool,
    /// seeded fault-injection schedule (`--faults`); None — the default —
    /// is the zero-overhead disabled path, bitwise identical to a run
    /// without the harness (pinned by `tests/chaos.rs`)
    pub faults: Option<Arc<FaultPlan>>,
    /// failed service attempts tolerated per request before a supervised
    /// restart terminal-fails it instead of requeueing for replay
    pub retry_budget: u32,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            workers: 1,
            sched: SchedulerConfig::default(),
            pacing: Pacing::Replay { time_scale: 1.0 },
            policy: Policy::Fifo,
            queue_cap: 0,
            admit_reject: false,
            kv: KvMode::Contig,
            steal: false,
            share_prefix: false,
            faults: None,
            retry_budget: 2,
        }
    }
}

/// One retired request, with the queue-wait vs compute split.
#[derive(Debug, Clone)]
pub struct OnlineFinished {
    pub id: usize,
    /// worker that served it
    pub worker: usize,
    /// enqueue → admission, seconds (wall clock)
    pub queue_wait_s: f64,
    /// enqueue → retire, seconds (wall clock); service time is
    /// `latency_s - queue_wait_s`
    pub latency_s: f64,
    pub out_tokens: usize,
    /// greedy tokens in generation order (empty for scoring requests)
    pub tokens: Vec<i32>,
    /// total prompt NLL (scoring requests only)
    pub nll: Option<f64>,
    /// retired before its deadline (always true without a deadline)
    pub deadline_met: bool,
    /// served by the sparser degrade tier under queue pressure
    /// (`--degrade`) — bit-exact for *that* checkpoint, not the primary
    pub degraded: bool,
}

/// A request that terminally failed: its worker died mid-service and the
/// retry budget or deadline was exhausted, its stream had already seen
/// tokens (a replay could never splice without emitting one twice), or
/// its client disconnected mid-stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailedOutcome {
    pub id: usize,
    /// service attempts consumed, the last one included
    pub attempts: u32,
}

/// Counters of one worker's whole run.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub worker: usize,
    pub requests: usize,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    /// seconds spent in prefill/decode compute (vs idle polling)
    pub busy_s: f64,
    pub peak_active: usize,
}

/// Merged result of one online run.
pub struct OnlineStats {
    pub finished: Vec<OnlineFinished>,
    pub workers: Vec<WorkerStats>,
    /// requests shed in-queue after their deadline passed
    pub shed: Vec<ShedOutcome>,
    /// requests rejected at push (bounded queue, unmeetable deadline)
    pub rejected: Vec<RejectOutcome>,
    /// requests that terminally failed under fault injection (always
    /// empty without `--faults` and a live TCP client)
    pub failed: Vec<FailedOutcome>,
    /// wall-clock seconds from pool start to last worker exit
    pub wall_s: f64,
    /// decodes parked for handover (with `steal` enabled)
    pub parks: usize,
    /// parked decodes taken over by another worker
    pub steals: usize,
    /// supervised worker restarts (panics caught and recovered)
    pub restarts: usize,
    /// requests requeued for replay from scratch across restarts
    pub requeues: usize,
}

impl OnlineStats {
    /// prompt + generated tokens across all workers.
    pub fn total_tokens(&self) -> usize {
        self.workers.iter().map(|w| w.prompt_tokens + w.gen_tokens).sum()
    }

    /// retired requests that met their deadline (the goodput numerator).
    pub fn within_deadline(&self) -> usize {
        self.finished.iter().filter(|f| f.deadline_met).count()
    }

    /// retired requests served by the degrade tier.
    pub fn degraded(&self) -> usize {
        self.finished.iter().filter(|f| f.degraded).count()
    }
}

/// A request being decoded by one worker.
struct Active {
    req: Request,
    enqueued: Instant,
    /// pop instant — the start of service
    admitted_at: Instant,
    deadline_at: Option<Instant>,
    reply: Option<std::sync::mpsc::Sender<Reply>>,
    queue_wait_s: f64,
    cache: Kv,
    last: i32,
    produced: usize,
    tokens: Vec<i32>,
    /// first batched decode step this request took part in
    decode_started: Option<Instant>,
    /// original arrival seq — preserved so a supervised-restart requeue
    /// puts the request back in its place in line
    seq: u64,
    /// failed service attempts before this one
    attempts: u32,
    /// decoding on the sparser degrade tier
    degraded: bool,
    /// the reply channel died mid-stream (client disconnect) — tear down
    /// at the next retire sweep instead of decoding for nobody
    aborted: bool,
}

/// A decode parked for handover: the whole [`Active`] (page table
/// included) moves to the thief — no KV bytes are copied.
struct Parked {
    x: Active,
    /// origin worker
    from: usize,
    /// park instant (start of the thief's Steal span)
    at: Instant,
}

/// Work-stealing board shared by every worker of one run: idle workers
/// post demand; busy workers park their longest-remaining decode in
/// response; idle workers steal parked entries by moving the whole
/// [`Active`] (a page-table migration — cache contents are never
/// copied). Stealing cannot change any request's tokens — greedy decode
/// depends only on the request's own KV state, which moves with it
/// (`tests/serve_parity.rs` pins stolen == pinned per token).
pub(crate) struct StealBoard {
    state: Mutex<BoardState>,
}

struct BoardState {
    parked: Vec<Parked>,
    /// idle workers currently asking for work (capped at the pool size)
    demand: usize,
    workers: usize,
    parks: usize,
    steals: usize,
}

impl StealBoard {
    fn new(workers: usize) -> StealBoard {
        StealBoard {
            state: Mutex::new(BoardState {
                parked: Vec::new(),
                demand: 0,
                workers,
                parks: 0,
                steals: 0,
            }),
        }
    }

    fn is_empty(&self) -> bool {
        locked(&self.state).parked.is_empty()
    }

    /// An idle worker asks for work (bounded, so a long idle phase cannot
    /// inflate demand past the pool size).
    fn note_demand(&self) {
        let mut g = locked(&self.state);
        let cap = g.workers.max(1);
        g.demand = (g.demand + 1).min(cap);
    }

    /// Should a busy worker park one of its decodes? Only while demand
    /// outstrips what is already parked.
    fn should_park(&self) -> bool {
        let g = locked(&self.state);
        g.demand > g.parked.len()
    }

    fn park(&self, x: Active, from: usize, at: Instant) {
        let mut g = locked(&self.state);
        g.parks += 1;
        g.demand = g.demand.saturating_sub(1);
        g.parked.push(Parked { x, from, at });
    }

    /// Take the oldest parked entry whose cost fits in `room` budget
    /// tokens (FIFO among the fitting — deterministic given the board
    /// contents).
    fn try_steal(&self, room: usize) -> Option<Parked> {
        let mut g = locked(&self.state);
        let idx = g.parked.iter().position(|p| p.x.req.cost() <= room)?;
        g.steals += 1;
        g.demand = g.demand.saturating_sub(1);
        Some(g.parked.remove(idx))
    }

    fn counts(&self) -> (usize, usize) {
        let g = locked(&self.state);
        (g.parks, g.steals)
    }
}

/// Per-run serving environment shared by every worker: how KV caches are
/// allocated ([`KvSpec`]), the optional work-stealing board, and the
/// optional shared-prompt prefix registry. One instance per
/// [`serve_online`] run (or per [`super::net::NetServer`]).
pub(crate) struct WorkerEnv {
    kv: KvSpec,
    board: Option<StealBoard>,
    registry: Option<PrefixRegistry>,
}

/// Registered shared prompts the registry holds at most (each pins its
/// prefill pages until evicted by [`PrefixRegistry::clear`]).
const REGISTRY_CAP: usize = 32;

impl WorkerEnv {
    pub(crate) fn new(kv: KvSpec, steal: bool, share_prefix: bool, workers: usize) -> WorkerEnv {
        let board = if steal { Some(StealBoard::new(workers)) } else { None };
        // prefix sharing shares *pages*, so it needs the paged allocator
        let registry = if share_prefix && matches!(kv, KvSpec::Paged(_)) {
            Some(PrefixRegistry::new(REGISTRY_CAP))
        } else {
            None
        };
        WorkerEnv { kv, board, registry }
    }

    /// The plain environment: contiguous caches, no stealing, no sharing.
    pub(crate) fn contig() -> WorkerEnv {
        WorkerEnv::new(KvSpec::contig(), false, false, 0)
    }

    pub(crate) fn kv(&self) -> &KvSpec {
        &self.kv
    }

    /// Largest request cost this environment can ever serve (`None` = no
    /// bound beyond the context length).
    pub(crate) fn max_cost_tokens(&self) -> Option<usize> {
        self.kv.max_cost_tokens()
    }

    /// Advisory admission check (pool-capacity half of the worker's pop
    /// predicate).
    fn can_admit(&self, cost: usize) -> bool {
        self.kv.can_admit(cost)
    }

    /// Allocate the KV cache for one admitted request. Generation
    /// requests first try to fork a registered prompt prefix (sharing its
    /// pages copy-on-write); the returned `usize` is the number of
    /// positions already cached (0 = fresh cache, full prefill needed).
    /// On pool exhaustion the registry is dropped and allocation retried
    /// once — admissions always beat caching. `None` means genuinely no
    /// room now: the caller holds the request and retries later.
    /// `allow_fork` is false for degrade-tier requests: registered
    /// prefixes were prefilled by the *primary* model, so sharing them
    /// across tiers would mix KV contents from two checkpoints.
    fn acquire(&self, ctx: &ServeContext, req: &Request, allow_fork: bool) -> Option<(Kv, usize)> {
        if let Some(reg) = &self.registry {
            if allow_fork && matches!(req.kind, ReqKind::Generate { .. }) {
                if let Some((p0, table)) = reg.fork_longest(&req.tokens, req.cost()) {
                    return Some((Kv::Paged(table), p0));
                }
            }
        }
        if let Some(kv) = ctx.new_kv(&self.kv, req.cost()) {
            return Some((kv, 0));
        }
        if let Some(reg) = &self.registry {
            reg.clear();
            if let Some(kv) = ctx.new_kv(&self.kv, req.cost()) {
                return Some((kv, 0));
            }
        }
        None
    }

    /// Offer a freshly prefilled generation prompt to the prefix registry
    /// (no-op without sharing or for contiguous caches).
    fn register(&self, tokens: &[i32], cache: &mut Kv) {
        if let Some(reg) = &self.registry {
            if let Kv::Paged(t) = cache {
                reg.register(tokens, t);
            }
        }
    }

    fn board(&self) -> Option<&StealBoard> {
        self.board.as_ref()
    }

    fn board_is_drained(&self) -> bool {
        match &self.board {
            Some(b) => b.is_empty(),
            None => true,
        }
    }

    /// (parks, steals) counters of the whole run.
    pub(crate) fn steal_counts(&self) -> (usize, usize) {
        match &self.board {
            Some(b) => b.counts(),
            None => (0, 0),
        }
    }
}

/// Serve `requests` through `ocfg.workers` sharded workers, one
/// [`ServeContext`] replica each (`ctxs.len() == ocfg.workers`). Returns
/// after the producer finished, the queue drained and every in-flight
/// request retired (drain-on-shutdown: closing the queue never drops
/// admitted work).
pub fn serve_online(
    ctxs: &[ServeContext],
    requests: Vec<Request>,
    ocfg: &OnlineConfig,
) -> Result<OnlineStats> {
    serve_online_traced(ctxs, requests, ocfg, None)
}

/// [`serve_online`] with optional per-request span tracing: each worker
/// flushes queue/admit/prefill/decode spans into `tracer` through its own
/// buffered [`SpanSink`].
pub fn serve_online_traced(
    ctxs: &[ServeContext],
    requests: Vec<Request>,
    ocfg: &OnlineConfig,
    tracer: Option<&Tracer>,
) -> Result<OnlineStats> {
    serve_online_tiered(ctxs, None, requests, ocfg, tracer)
}

/// [`serve_online_traced`] with an optional sparsity-tiered degrade pool
/// (`--degrade`): one *sparser* [`ServeContext`] replica per worker.
/// When queue pressure crosses the shed threshold (a request's remaining
/// deadline falls under the EWMA service estimate, or a bounded queue
/// fills past half), a worker routes the request to the degrade replica
/// instead of letting it shed — the answer is marked `degraded` and is
/// bit-exact for the sparser checkpoint, not the primary.
pub fn serve_online_tiered(
    ctxs: &[ServeContext],
    degrade_ctxs: Option<&[ServeContext]>,
    requests: Vec<Request>,
    ocfg: &OnlineConfig,
    tracer: Option<&Tracer>,
) -> Result<OnlineStats> {
    if ocfg.workers == 0 {
        bail!("online serving needs at least one worker");
    }
    if ctxs.len() != ocfg.workers {
        bail!("got {} model replicas for {} workers", ctxs.len(), ocfg.workers);
    }
    if ocfg.sched.max_batch == 0 {
        bail!("scheduler max_batch must be >= 1");
    }
    if let Pacing::ClosedLoop { clients } = ocfg.pacing {
        if clients == 0 {
            bail!("closed-loop pacing needs at least one client");
        }
    }
    if let Some(dctxs) = degrade_ctxs {
        if dctxs.len() != ocfg.workers {
            bail!("got {} degrade-tier replicas for {} workers", dctxs.len(), ocfg.workers);
        }
        for (i, (p, d)) in ctxs.iter().zip(dctxs).enumerate() {
            if !p.compatible_tier(d) {
                bail!("degrade-tier replica {i} has a different shape than the primary");
            }
        }
    }
    // reject up front anything that could never be admitted — with a
    // per-worker budget (or replica capacity: any worker may admit any
    // request, so the smallest bounds all) below a request's cost every
    // worker would refuse it forever and the queue would starve behind it
    // ctxs is non-empty (checked above); 0 if it somehow weren't, which
    // rejects any nonzero-cost request instead of panicking
    let min_pos = ctxs.iter().map(|c| c.max_pos()).min().unwrap_or(0);
    if let KvMode::Paged { page_tokens, .. } = ocfg.kv {
        if page_tokens == 0 {
            bail!("paged KV needs a page size of at least one token");
        }
    }
    let cfg0 = &ctxs[0].model.cfg;
    let env = WorkerEnv::new(
        KvSpec::for_mode(ocfg.kv, cfg0.n_blocks, cfg0.d_model),
        ocfg.steal,
        ocfg.share_prefix,
        ocfg.workers,
    );
    // with a capped page pool, a request larger than the whole pool
    // could never allocate and would stall its worker forever
    let kv_cap = env.max_cost_tokens().unwrap_or(usize::MAX);
    for r in &requests {
        if r.cost() > ocfg.sched.token_budget {
            bail!(
                "request {} cost {} exceeds the per-worker token budget {}",
                r.id,
                r.cost(),
                ocfg.sched.token_budget
            );
        }
        if r.cost() > min_pos {
            bail!(
                "request {} needs {} positions but a replica allows only {}",
                r.id,
                r.cost(),
                min_pos
            );
        }
        if r.cost() > kv_cap {
            bail!(
                "request {} cost {} exceeds the page-pool capacity {}",
                r.id,
                r.cost(),
                kv_cap
            );
        }
    }
    let total = requests.len();
    let queue = IngestQueue::with_config(QueueConfig {
        policy: ocfg.policy,
        capacity: ocfg.queue_cap,
        workers_hint: ocfg.workers,
        admit_reject: ocfg.admit_reject,
    });
    // hand the owned request vec to the producer without cloning the
    // token buffers (scoped_workers takes Fn, so no direct move)
    let pending = Mutex::new(Some(requests));
    let start = Instant::now();
    // index 0 is the producer; 1..=workers are serving workers
    let results = scoped_workers(ocfg.workers + 1, |i| {
        if i == 0 {
            match locked(&pending).take() {
                // the producer runs exactly once (index 0); if the vec
                // were somehow gone, closing the queue lets the workers
                // drain and exit instead of panicking the pool
                Some(reqs) => run_producer(&queue, reqs, ocfg.pacing),
                None => queue.close(),
            }
            None
        } else {
            let mut sink = sink_or_disabled(tracer);
            let run = WorkerRun {
                wid: i - 1,
                ctx: &ctxs[i - 1],
                degrade: degrade_ctxs.map(|d| &d[i - 1]),
                queue: &queue,
                scfg: &ocfg.sched,
                env: &env,
                faults: ocfg.faults.as_deref(),
                retry_budget: ocfg.retry_budget,
                queue_cap: ocfg.queue_cap,
            };
            Some(supervised_worker(&run, &mut sink))
        }
    });
    let wall_s = start.elapsed().as_secs_f64();
    let mut finished = Vec::with_capacity(total);
    let mut failed = Vec::new();
    let mut workers = Vec::with_capacity(ocfg.workers);
    let (mut restarts, mut requeues) = (0usize, 0usize);
    for rep in results.into_iter().flatten() {
        workers.push(rep.stats);
        finished.extend(rep.finished);
        failed.extend(rep.failed);
        restarts += rep.restarts;
        requeues += rep.requeues;
    }
    finished.sort_by_key(|f| f.id);
    failed.sort_by_key(|f| f.id);
    let (shed, rejected) = queue.take_outcomes();
    // the chaos headline invariants hold under *any* fault schedule —
    // hard checks, not debug asserts, so CI's chaos matrix can trust a
    // green run of the release binary
    if finished.len() + shed.len() + rejected.len() + failed.len() != total {
        bail!(
            "accounting violated: {} queued but {} finished + {} shed + {} rejected + {} failed",
            total,
            finished.len(),
            shed.len(),
            rejected.len(),
            failed.len()
        );
    }
    if let Some(pool) = env.kv().pool() {
        let ps = pool.stats();
        if !ps.drained() {
            bail!(
                "page pool failed to drain: live {} free {} created {}",
                ps.live,
                ps.free,
                ps.created
            );
        }
    }
    let (parks, steals) = env.steal_counts();
    Ok(OnlineStats {
        finished,
        workers,
        shed,
        rejected,
        failed,
        wall_s,
        parks,
        steals,
        restarts,
        requeues,
    })
}

/// Retire one request: release its budget, answer the reply channel,
/// record the finished entry and feed the queue's service estimate.
#[allow(clippy::too_many_arguments)]
fn retire(
    x: Active,
    wid: usize,
    queue: &IngestQueue,
    sink: &mut SpanSink<'_>,
    finished: &mut Vec<OnlineFinished>,
    stats: &mut WorkerStats,
    nll: Option<f64>,
) {
    let now = Instant::now();
    stats.requests += 1;
    let deadline_met = match x.deadline_at {
        Some(dl) => now <= dl,
        None => true,
    };
    let wire = x.req.id as u64;
    if let Some(start) = x.decode_started {
        sink.record(wire, SpanKind::Decode, wid as i64, start, now, true);
    }
    if let Some(tx) = &x.reply {
        let _ = tx.send(Reply::Done {
            tokens: x.tokens.clone(),
            nll,
            deadline_met,
            degraded: x.degraded,
        });
    }
    finished.push(OnlineFinished {
        id: x.req.id,
        worker: wid,
        queue_wait_s: x.queue_wait_s,
        latency_s: now.saturating_duration_since(x.enqueued).as_secs_f64(),
        out_tokens: x.produced,
        tokens: x.tokens,
        nll,
        deadline_met,
        degraded: x.degraded,
    });
    queue.note_done(now.saturating_duration_since(x.admitted_at).as_secs_f64());
}

/// Steal the oldest parked decode that fits in `room` budget tokens,
/// moving its whole [`Active`] (page table included) into this worker's
/// batch. Records the Steal span (parked → now, thief's index) and
/// resets `decode_started` so the thief's Decode span covers only its own
/// stretch.
fn steal_one(
    env: &WorkerEnv,
    wid: usize,
    room: usize,
    sink: &mut SpanSink<'_>,
    active: &mut Vec<Active>,
    in_flight_tokens: &mut usize,
) -> bool {
    let board = match env.board() {
        Some(b) => b,
        None => return false,
    };
    let p = match board.try_steal(room) {
        Some(p) => p,
        None => return false,
    };
    let mut x = p.x;
    sink.record(x.req.id as u64, SpanKind::Steal, wid as i64, p.at, Instant::now(), true);
    x.decode_started = None;
    *in_flight_tokens += x.req.cost();
    active.push(x);
    true
}

/// Everything one supervised worker needs, bundled so the supervisor,
/// recovery, and inner loop share one view (and so `serve::net` can
/// spawn the same worker from its connection-handling front end).
pub(crate) struct WorkerRun<'a> {
    pub wid: usize,
    pub ctx: &'a ServeContext,
    /// sparser degrade-tier replica (`--degrade`); None disables routing
    pub degrade: Option<&'a ServeContext>,
    pub queue: &'a IngestQueue,
    pub scfg: &'a SchedulerConfig,
    pub env: &'a WorkerEnv,
    /// seeded fault-injection schedule; None is the zero-overhead path
    pub faults: Option<&'a FaultPlan>,
    /// failed attempts tolerated before a recovery terminal-fails
    pub retry_budget: u32,
    /// arrival-queue capacity (0 = unbounded) — the degrade router's
    /// backlog-pressure threshold
    pub queue_cap: usize,
}

/// What one supervised worker hands back at exit.
pub(crate) struct WorkerReport {
    pub stats: WorkerStats,
    pub finished: Vec<OnlineFinished>,
    pub failed: Vec<FailedOutcome>,
    pub restarts: usize,
    pub requeues: usize,
}

/// Recovery snapshot of the request whose service is in flight *right
/// now* (between pop and retire-or-activate): a clone of the original's
/// routing info, held outside the unwindable frame. If the worker dies
/// mid-prefill, recovery rebuilds the [`ArrivedRequest`] from this and
/// requeues it — replay from scratch is deterministic, so nothing is
/// lost but time.
struct Slot {
    req: Request,
    enqueued: Instant,
    deadline_at: Option<Instant>,
    reply: Option<std::sync::mpsc::Sender<Reply>>,
    seq: u64,
    attempts: u32,
    /// token 0 was sent (or was about to be) — a replay would emit it
    /// twice, so a streamed request can only terminal-fail on recovery
    streamed: bool,
}

impl Slot {
    fn of(a: &ArrivedRequest) -> Slot {
        Slot {
            req: a.req.clone(),
            enqueued: a.enqueued,
            deadline_at: a.deadline_at,
            reply: a.reply.clone(),
            seq: a.seq,
            attempts: a.attempts,
            streamed: false,
        }
    }
}

/// Worker state that lives *outside* `catch_unwind`: everything a panic
/// must not take down with it — popped-but-unserved requests, active
/// decodes (their KV caches release pages on drop during recovery), the
/// in-service slot, counters and ledgers.
struct WorkerState {
    active: Vec<Active>,
    /// popped but waiting for pool pages: budget-counted, retried in
    /// arrival order before fresh admissions
    pending: Vec<ArrivedRequest>,
    /// the admission round being consumed front-first — whatever a panic
    /// leaves here goes back to the queue whole during recovery
    batch: Vec<ArrivedRequest>,
    slot: Option<Slot>,
    in_flight_tokens: usize,
    finished: Vec<OnlineFinished>,
    failed: Vec<FailedOutcome>,
    stats: WorkerStats,
    requeues: usize,
}

/// Supervisor cap on the doubling restart backoff.
const RESTART_BACKOFF_MAX: Duration = Duration::from_millis(32);

/// One worker's whole supervised lifetime: run the continuous-batching
/// loop inside `catch_unwind`; on a panic, recover the interrupted
/// requests ([`recover`]), sleep a capped exponential backoff, record a
/// Restart span, and re-enter. The worker only returns when the queue is
/// drained — a death can never abort the pool or strand admitted work.
pub(crate) fn supervised_worker(run: &WorkerRun<'_>, sink: &mut SpanSink<'_>) -> WorkerReport {
    let mut st = WorkerState {
        active: Vec::new(),
        pending: Vec::new(),
        batch: Vec::new(),
        slot: None,
        in_flight_tokens: 0,
        finished: Vec::new(),
        failed: Vec::new(),
        stats: WorkerStats {
            worker: run.wid,
            requests: 0,
            prompt_tokens: 0,
            gen_tokens: 0,
            busy_s: 0.0,
            peak_active: 0,
        },
        requeues: 0,
    };
    let mut restarts = 0usize;
    let mut backoff = Duration::from_millis(1);
    loop {
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            worker_loop_inner(run, &mut st, sink)
        }));
        match outcome {
            Ok(()) => break,
            Err(_payload) => {
                let died = Instant::now();
                restarts += 1;
                recover(run, &mut st, sink);
                std::thread::sleep(backoff);
                sink.record(0, SpanKind::Restart, run.wid as i64, died, Instant::now(), false);
                backoff = (backoff * 2).min(RESTART_BACKOFF_MAX);
            }
        }
    }
    WorkerReport {
        stats: st.stats,
        finished: st.finished,
        failed: st.failed,
        restarts,
        requeues: st.requeues,
    }
}

/// Roll the worker back to a clean restart point after a caught panic.
/// Every interrupted request either goes back to the queue for
/// deterministic replay from scratch (original seq — it retakes its
/// exact place in line) or terminally fails (retry budget or deadline
/// exhausted, or its stream already saw tokens — never emit a token
/// twice). Active KV caches are dropped here, releasing their pages and
/// prefix refcounts *before* anything is requeued, so the pool can
/// absorb the replays.
fn recover(run: &WorkerRun<'_>, st: &mut WorkerState, sink: &mut SpanSink<'_>) {
    let now = Instant::now();
    // the request whose service the panic interrupted, if any
    if let Some(s) = st.slot.take() {
        let streamed = s.streamed && s.reply.is_some();
        let a = ArrivedRequest {
            req: s.req,
            enqueued: s.enqueued,
            deadline_at: s.deadline_at,
            reply: s.reply,
            seq: s.seq,
            attempts: s.attempts,
        };
        requeue_or_fail(run, st, sink, a, streamed, now);
    }
    // a mid-decode panic can leave *any* active's KV half-appended
    // (decode_step mutates the whole batch), so every active is torn
    // down — cache dropped, pages released — and replayed or failed
    for x in std::mem::take(&mut st.active) {
        // token 0 streams as soon as a live reply exists, so any active
        // with a reply channel has already emitted
        let streamed = x.reply.is_some();
        let a = ArrivedRequest {
            req: x.req,
            enqueued: x.enqueued,
            deadline_at: x.deadline_at,
            reply: x.reply,
            seq: x.seq,
            attempts: x.attempts,
        };
        requeue_or_fail(run, st, sink, a, streamed, now);
        // x.cache drops here, after the requeue decision, which is fine:
        // replay allocates a fresh cache when the request is re-admitted
    }
    // popped but never served: back in line whole, no attempt consumed
    for a in std::mem::take(&mut st.batch) {
        run.queue.requeue(a);
    }
    for a in std::mem::take(&mut st.pending) {
        run.queue.requeue(a);
    }
    st.in_flight_tokens = 0;
}

/// One interrupted request: requeue for replay (an attempt is consumed)
/// or terminal-fail when replay is impossible (tokens already streamed)
/// or pointless (budget or deadline exhausted). The failure is the
/// stream's single terminal event.
fn requeue_or_fail(
    run: &WorkerRun<'_>,
    st: &mut WorkerState,
    sink: &mut SpanSink<'_>,
    mut a: ArrivedRequest,
    streamed: bool,
    now: Instant,
) {
    a.attempts += 1;
    let expired = matches!(a.deadline_at, Some(d) if d <= now);
    if streamed || expired || a.attempts > run.retry_budget {
        if let Some(tx) = &a.reply {
            let _ = tx.send(Reply::Failed { attempts: a.attempts });
        }
        st.failed.push(FailedOutcome { id: a.req.id, attempts: a.attempts });
        run.queue.note_failed();
    } else {
        sink.record(a.req.id as u64, SpanKind::Requeue, run.wid as i64, now, now, false);
        st.requeues += 1;
        run.queue.requeue(a);
    }
}

/// Perform a worker-side injected fault: record the Fault span, then
/// panic or stall *here*, at the real call site — to the supervisor an
/// injected death is indistinguishable from a genuine mid-service bug.
fn inject(action: FaultAction, req: u64, wid: usize, sink: &mut SpanSink<'_>, site: &str) {
    let now = Instant::now();
    sink.record(req, SpanKind::Fault, wid as i64, now, now, false);
    match action {
        // besa-lint: allow(hot-path-panic) — injected worker death; the supervisor catches and recovers
        FaultAction::Panic => panic!("injected fault: worker panic {site}"),
        FaultAction::Stall(ms) => std::thread::sleep(Duration::from_millis(ms)),
        // Deny fires inside the admission predicate and Disconnect is
        // client-side; neither routes through here
        FaultAction::Deny | FaultAction::Disconnect => {}
    }
}

/// Degrade-tier routing decision at service start: route to the sparser
/// replica when the queue says shedding is imminent — the request's
/// remaining deadline is under the EWMA service estimate, or a bounded
/// queue has filled past half.
fn wants_degrade(run: &WorkerRun<'_>, a: &ArrivedRequest) -> bool {
    if run.degrade.is_none() {
        return false;
    }
    let (depth, ewma) = run.queue.pressure();
    if run.queue_cap > 0 && depth * 2 >= run.queue_cap {
        return true;
    }
    if let Some(d) = a.deadline_at {
        if ewma > 0.0 {
            return d.saturating_duration_since(Instant::now()).as_secs_f64() < ewma;
        }
    }
    false
}

/// One worker's continuous-batching loop: admit from the shared queue
/// while budget and slots allow, prefill admissions (continuing from a
/// shared prompt prefix when the registry has one), one batched decode
/// step per iteration, retire at each request's token budget. With
/// stealing enabled, an idle worker takes parked decodes from `env`'s
/// board, and a busy worker parks its longest-remaining decode when idle
/// workers ask — never its last one, so a parker always keeps retiring
/// work (parked pages drain, no stuck shutdown). Exits when the queue is
/// drained, the board is empty and nothing is left in flight. Streams
/// each generated token to the request's reply channel (when one is
/// attached) as soon as it exists, and records per-request spans into
/// `sink`. Runs inside [`supervised_worker`]'s `catch_unwind`; all
/// request-holding state lives in `st`, outside the unwindable frame.
fn worker_loop_inner(run: &WorkerRun<'_>, st: &mut WorkerState, sink: &mut SpanSink<'_>) {
    let wid = run.wid;
    let (queue, scfg, env) = (run.queue, run.scfg, run.env);
    let d = run.ctx.model.cfg.d_model;
    let mut scratch = DecodeScratch::new();
    loop {
        // admit while the per-worker budget and batch slots allow; the
        // queue wait ends here, at the pop. Admissions go straight into
        // st.batch so a panic can never strand them.
        let mut denied: Option<u64> = None;
        while st.active.len() + st.pending.len() + st.batch.len() < scfg.max_batch {
            match queue.try_pop(|r| {
                let fits = st.in_flight_tokens + r.cost() <= scfg.token_budget
                    && env.can_admit(r.cost());
                // injected admission pressure: refuse a request the pool
                // would have taken (it stays at the front and is retried)
                if fits
                    && matches!(
                        fault::fire(run.faults, FaultSite::Admit),
                        Some(FaultAction::Deny)
                    )
                {
                    denied = Some(r.id as u64);
                    return false;
                }
                fits
            }) {
                Pop::Got(a) => {
                    st.in_flight_tokens += a.req.cost();
                    st.batch.push(a);
                }
                Pop::Refused | Pop::Empty | Pop::Drained => break,
            }
        }
        if let Some(id) = denied {
            let now = Instant::now();
            sink.record(id, SpanKind::Fault, wid as i64, now, now, false);
        }
        if st.batch.is_empty() && st.pending.is_empty() && st.active.is_empty() {
            // idle: take over a parked decode before sleeping or exiting
            if steal_one(env, wid, scfg.token_budget, sink, &mut st.active, &mut st.in_flight_tokens)
            {
                continue;
            }
            if let Some(board) = env.board() {
                board.note_demand();
            }
            if queue.is_drained() && env.board_is_drained() {
                break;
            }
            queue.wait_arrival(IDLE_POLL);
            continue;
        }
        let work = Instant::now();
        // pending first (arrival fairness), then this round's admissions
        let mut round = std::mem::take(&mut st.pending);
        round.append(&mut st.batch);
        st.batch = round;
        let mut progressed = false;
        while !st.batch.is_empty() {
            // snapshot the front into the recovery slot *before* moving
            // it out of st.batch: from here to retire-or-activate, the
            // slot is the request's panic-survivable record
            let degraded = wants_degrade(run, &st.batch[0]);
            st.slot = Some(Slot::of(&st.batch[0]));
            let a = st.batch.remove(0);
            // degraded requests run every stage on the sparser replica —
            // never mixing tiers within one request's KV
            let tctx = match run.degrade {
                Some(dc) if degraded => dc,
                _ => run.ctx,
            };
            let (mut cache, prefix) = match env.acquire(tctx, &a.req, !degraded) {
                Some(got) => got,
                None => {
                    // pool dry right now: hold the request (budget stays
                    // counted) and retry once pages free up
                    st.slot = None;
                    st.pending.push(a);
                    continue;
                }
            };
            progressed = true;
            let ArrivedRequest { req, enqueued, deadline_at, reply, seq, attempts } = a;
            let admitted_at = work;
            let queue_wait_s = admitted_at.saturating_duration_since(enqueued).as_secs_f64();
            let wire = req.id as u64;
            sink.record(wire, SpanKind::Queue, wid as i64, enqueued, admitted_at, true);
            if degraded {
                sink.record(wire, SpanKind::Degrade, wid as i64, admitted_at, admitted_at, true);
            }
            st.stats.prompt_tokens += req.tokens.len();
            let s = req.tokens.len();
            let t_prefill = Instant::now();
            sink.record(wire, SpanKind::Admit, wid as i64, admitted_at, t_prefill, true);
            if let Some(action) = fault::fire(run.faults, FaultSite::Prefill) {
                inject(action, wire, wid, sink, "mid-prefill");
            }
            match req.kind {
                ReqKind::Score => {
                    // scoring reads every position's hidden row, so it
                    // always runs the full prefill (acquire never forks
                    // a prefix for Score)
                    let hidden = prefill(tctx, &req.tokens, &mut cache);
                    sink.record(wire, SpanKind::Prefill, wid as i64, t_prefill, Instant::now(), true);
                    let nll = score_nll(tctx, &hidden, &req.tokens);
                    let nll_sum: f64 = nll.iter().map(|v| *v as f64).sum();
                    st.in_flight_tokens -= req.cost();
                    retire(
                        Active {
                            req,
                            enqueued,
                            admitted_at,
                            deadline_at,
                            reply,
                            queue_wait_s,
                            cache,
                            last: 0,
                            produced: 0,
                            tokens: Vec::new(),
                            decode_started: None,
                            seq,
                            attempts,
                            degraded,
                            aborted: false,
                        },
                        wid,
                        queue,
                        sink,
                        &mut st.finished,
                        &mut st.stats,
                        Some(nll_sum),
                    );
                    st.slot = None;
                }
                ReqKind::Generate { max_new } => {
                    // a forked cache already holds `prefix` positions;
                    // the remaining prompt rows run as cached decode
                    // rows — bitwise identical to the full prefill's
                    // final row (parity-pinned)
                    let first = if prefix > 0 {
                        let row = prefill_continue(tctx, &req.tokens, &mut cache, &mut scratch);
                        argmax(&last_logits(tctx, &row)) as i32
                    } else {
                        let hidden = prefill(tctx, &req.tokens, &mut cache);
                        if !degraded {
                            env.register(&req.tokens, &mut cache);
                        }
                        argmax(&last_logits(tctx, &hidden[(s - 1) * d..s * d])) as i32
                    };
                    sink.record(wire, SpanKind::Prefill, wid as i64, t_prefill, Instant::now(), true);
                    st.stats.gen_tokens += 1;
                    // from the send on, a replay would duplicate token 0:
                    // recovery may only terminal-fail this request now
                    if let Some(slot) = st.slot.as_mut() {
                        slot.streamed = true;
                    }
                    let mut dead_client = false;
                    if let Some(tx) = &reply {
                        dead_client = tx.send(Reply::Token { index: 0, token: first }).is_err();
                    }
                    if dead_client {
                        // client gone before its first token: release the
                        // cache and the queue slot, count the failure —
                        // no terminal event, nobody is listening
                        st.in_flight_tokens -= req.cost();
                        st.failed.push(FailedOutcome { id: req.id, attempts: attempts + 1 });
                        queue.note_failed();
                        st.slot = None;
                        continue;
                    }
                    let x = Active {
                        req,
                        enqueued,
                        admitted_at,
                        deadline_at,
                        reply,
                        queue_wait_s,
                        cache,
                        last: first,
                        produced: 1,
                        tokens: vec![first],
                        decode_started: None,
                        seq,
                        attempts,
                        degraded,
                        aborted: false,
                    };
                    if max_new <= 1 {
                        st.in_flight_tokens -= x.req.cost();
                        retire(x, wid, queue, sink, &mut st.finished, &mut st.stats, None);
                    } else {
                        st.active.push(x);
                    }
                    st.slot = None;
                }
            }
        }
        st.stats.peak_active = st.stats.peak_active.max(st.active.len());
        // park one decode when idle workers are asking — the one with
        // the most tokens left, and never the last one (the parker must
        // keep retiring work so parked pages always drain)
        if let Some(board) = env.board() {
            if st.active.len() >= 2 && board.should_park() {
                let mut pick = 0;
                let mut most = 0usize;
                for (i, x) in st.active.iter().enumerate() {
                    let remaining = match x.req.kind {
                        ReqKind::Generate { max_new } => max_new.saturating_sub(x.produced),
                        ReqKind::Score => 0,
                    };
                    if remaining > most {
                        most = remaining;
                        pick = i;
                    }
                }
                let mut x = st.active.remove(pick);
                let now = Instant::now();
                let from = x.decode_started.unwrap_or(x.admitted_at);
                sink.record(x.req.id as u64, SpanKind::Migrate, wid as i64, from, now, true);
                x.decode_started = None;
                st.in_flight_tokens -= x.req.cost();
                board.park(x, wid, now);
            }
        }
        if !st.active.is_empty() {
            let t_step = Instant::now();
            for x in st.active.iter_mut() {
                if x.decode_started.is_none() {
                    x.decode_started = Some(t_step);
                }
            }
            if let Some(action) = fault::fire(run.faults, FaultSite::Decode) {
                inject(action, 0, wid, sink, "mid-decode");
            }
            // tier partition: primary first, degrade after. The sort is
            // stable and keyed only by the flag, so with degradation off
            // (every key false) it is the identity — batch order, and
            // with it bitwise parity, is untouched
            if run.degrade.is_some() {
                st.active.sort_by_key(|x| x.degraded);
            }
            let split = st.active.iter().position(|x| x.degraded).unwrap_or(st.active.len());
            let next = {
                let (prim, degr) = st.active.split_at_mut(split);
                let dctx = run.degrade.unwrap_or(run.ctx);
                let mut next: Vec<i32> = Vec::with_capacity(prim.len() + degr.len());
                for (group, tctx) in [(prim, run.ctx), (degr, dctx)] {
                    if group.is_empty() {
                        continue;
                    }
                    let last: Vec<i32> = group.iter().map(|x| x.last).collect();
                    let mut caches = gather_caches(group, |x| &mut x.cache);
                    next.extend(decode_step(tctx, &last, &mut caches, &mut scratch));
                }
                next
            };
            st.stats.gen_tokens += next.len();
            for (x, t) in st.active.iter_mut().zip(&next) {
                x.last = *t;
                x.produced += 1;
                x.tokens.push(*t);
                if let Some(tx) = &x.reply {
                    if tx.send(Reply::Token { index: x.produced - 1, token: *t }).is_err() {
                        // client vanished mid-stream: stop decoding for
                        // nobody at the next sweep
                        x.aborted = true;
                    }
                }
            }
            let mut i = 0;
            while i < st.active.len() {
                if st.active[i].aborted {
                    let x = st.active.swap_remove(i);
                    st.in_flight_tokens -= x.req.cost();
                    st.failed.push(FailedOutcome { id: x.req.id, attempts: x.attempts + 1 });
                    queue.note_failed();
                    // x.cache drops here: the disconnect releases every
                    // page the request held
                    continue;
                }
                let max_new = match st.active[i].req.kind {
                    ReqKind::Generate { max_new } => max_new,
                    ReqKind::Score => 0,
                };
                if st.active[i].produced >= max_new {
                    let x = st.active.swap_remove(i);
                    st.in_flight_tokens -= x.req.cost();
                    retire(x, wid, queue, sink, &mut st.finished, &mut st.stats, None);
                } else {
                    i += 1;
                }
            }
        } else if !progressed && !st.pending.is_empty() {
            // nothing to compute and the pool is dry: try to take over a
            // parked decode (its retirement frees pages), else wait for
            // another worker to release some
            let room = scfg.token_budget.saturating_sub(st.in_flight_tokens);
            if !steal_one(env, wid, room, sink, &mut st.active, &mut st.in_flight_tokens) {
                std::thread::sleep(IDLE_POLL);
            }
        }
        st.stats.busy_s += work.elapsed().as_secs_f64();
    }
    debug_assert!(st.pending.is_empty(), "drained with requests still waiting for pages");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;
    use crate::model::ParamStore;
    use crate::serve::bench::magnitude_prune_in_place;
    use crate::serve::model::{PackedModel, WeightFormat};
    use crate::serve::trace::{poisson_trace, TraceConfig};

    fn small_trace(n: usize, seed: u64) -> (TraceConfig, Vec<Request>) {
        let tcfg = TraceConfig {
            n_requests: n,
            rate: 1000.0,
            prompt_min: 3,
            prompt_max: 8,
            gen_min: 2,
            gen_max: 5,
            score_fraction: 0.3,
            burst: 1,
            seed,
            ..TraceConfig::default()
        };
        let reqs = poisson_trace(&tcfg);
        (tcfg, reqs)
    }

    fn contexts(n: usize, max_pos: usize) -> Vec<ServeContext> {
        let cfg = test_config();
        let mut params = ParamStore::init(&cfg, 42);
        magnitude_prune_in_place(&mut params, &cfg, 0.5).unwrap();
        (0..n)
            .map(|_| {
                ServeContext::new(
                    PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
                    max_pos,
                )
            })
            .collect()
    }

    #[test]
    fn invalid_configs_are_rejected() {
        let (tcfg, reqs) = small_trace(3, 1);
        let ctxs = contexts(1, tcfg.max_request_tokens());
        let sched = SchedulerConfig { token_budget: 64, max_batch: 2 };
        // zero workers can never serve: queued requests would starve
        let ocfg = OnlineConfig {
            workers: 0,
            sched: sched.clone(),
            pacing: Pacing::Replay { time_scale: 0.0 },
            ..OnlineConfig::default()
        };
        assert!(serve_online(&[], reqs.clone(), &ocfg).is_err());
        // zero batch slots is the same starvation with workers alive
        let ocfg = OnlineConfig {
            workers: 1,
            sched: SchedulerConfig { token_budget: 64, max_batch: 0 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            ..OnlineConfig::default()
        };
        assert!(serve_online(&ctxs, reqs.clone(), &ocfg).is_err());
        // a request that exceeds the per-worker budget would starve the
        // whole FIFO behind it — rejected up front (every request costs
        // at least prompt_min = 3 tokens)
        let ocfg = OnlineConfig {
            workers: 1,
            sched: SchedulerConfig { token_budget: 2, max_batch: 2 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            ..OnlineConfig::default()
        };
        assert!(serve_online(&ctxs, reqs.clone(), &ocfg).is_err());
        // zero closed-loop clients would deadlock the producer
        let ocfg = OnlineConfig {
            workers: 1,
            sched,
            pacing: Pacing::ClosedLoop { clients: 0 },
            ..OnlineConfig::default()
        };
        assert!(serve_online(&ctxs, reqs, &ocfg).is_err());
    }

    #[test]
    fn drain_on_shutdown_retires_in_flight_decodes() {
        // time_scale 0 floods + closes the queue while every generation
        // request is still decoding: the pool must drain them all
        let (tcfg, reqs) = small_trace(8, 2);
        let n = reqs.len();
        let gens: usize = reqs
            .iter()
            .filter(|r| matches!(r.kind, ReqKind::Generate { .. }))
            .count();
        let ctxs = contexts(2, tcfg.max_request_tokens());
        let ocfg = OnlineConfig {
            workers: 2,
            sched: SchedulerConfig { token_budget: 64, max_batch: 2 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            ..OnlineConfig::default()
        };
        let stats = serve_online(&ctxs, reqs.clone(), &ocfg).unwrap();
        assert_eq!(stats.finished.len(), n);
        let mut seen = std::collections::BTreeSet::new();
        for f in &stats.finished {
            assert!(seen.insert(f.id), "request {} retired twice", f.id);
            assert!(f.latency_s >= f.queue_wait_s && f.queue_wait_s >= 0.0);
            assert!(f.deadline_met, "deadline-free requests always report met");
        }
        // every generation request produced its full token budget
        for (f, r) in stats.finished.iter().zip(&reqs) {
            assert_eq!(f.id, r.id);
            match r.kind {
                ReqKind::Generate { max_new } => {
                    assert_eq!(f.out_tokens, max_new);
                    assert_eq!(f.tokens.len(), max_new);
                }
                ReqKind::Score => {
                    assert!(f.nll.is_some());
                    assert!(f.tokens.is_empty());
                }
            }
        }
        assert!(gens > 0, "trace should include generation requests");
        let served: usize = stats.workers.iter().map(|w| w.requests).sum();
        assert_eq!(served, n);
        assert!(stats.shed.is_empty() && stats.rejected.is_empty());
    }

    #[test]
    fn closed_loop_serves_every_request() {
        let (tcfg, reqs) = small_trace(10, 3);
        let n = reqs.len();
        let ctxs = contexts(2, tcfg.max_request_tokens());
        let ocfg = OnlineConfig {
            workers: 2,
            sched: SchedulerConfig { token_budget: 64, max_batch: 2 },
            pacing: Pacing::ClosedLoop { clients: 3 },
            ..OnlineConfig::default()
        };
        let stats = serve_online(&ctxs, reqs, &ocfg).unwrap();
        assert_eq!(stats.finished.len(), n);
        // at most `clients` could ever be in flight pool-wide
        let peak: usize = stats.workers.iter().map(|w| w.peak_active).sum();
        assert!(peak <= 2 * 3, "peak occupancy {peak} vs 3 clients");
    }

    /// Overload accounting under hopeless deadlines: a flooded queue with
    /// microsecond deadlines must shed (in-queue) or reject (at push)
    /// most requests — and every one of the `n` lands in exactly one of
    /// the three ledgers.
    #[test]
    fn deadline_shedding_accounts_for_every_request() {
        let (tcfg, mut reqs) = small_trace(8, 4);
        for r in &mut reqs {
            r.qos.deadline_s = 1e-6;
        }
        let n = reqs.len();
        let ctxs = contexts(1, tcfg.max_request_tokens());
        let ocfg = OnlineConfig {
            workers: 1,
            sched: SchedulerConfig { token_budget: 16, max_batch: 1 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            policy: Policy::Edf,
            ..OnlineConfig::default()
        };
        let stats = serve_online(&ctxs, reqs, &ocfg).unwrap();
        assert_eq!(
            stats.finished.len() + stats.shed.len() + stats.rejected.len(),
            n,
            "no request lost or double-counted under shedding"
        );
        // with a 1µs budget and max_batch 1, the flood cannot all be
        // served in time: something must have been shed or rejected
        assert!(
            stats.shed.len() + stats.rejected.len() > 0,
            "hopeless deadlines must trigger shedding"
        );
        for f in &stats.finished {
            assert!(!f.deadline_met, "nothing completes within 1µs");
        }
    }

    /// Paged KV with stealing and prefix sharing on: every request still
    /// retires exactly once, and the steal ledger stays consistent
    /// (nothing stolen that was never parked).
    #[test]
    fn paged_mode_with_stealing_and_sharing_serves_every_request() {
        let (tcfg, reqs) = small_trace(10, 6);
        let n = reqs.len();
        let ctxs = contexts(2, tcfg.max_request_tokens());
        let ocfg = OnlineConfig {
            workers: 2,
            sched: SchedulerConfig { token_budget: 64, max_batch: 2 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            kv: KvMode::Paged { page_tokens: 4, max_pages: 0 },
            steal: true,
            share_prefix: true,
            ..OnlineConfig::default()
        };
        let stats = serve_online(&ctxs, reqs, &ocfg).unwrap();
        assert_eq!(stats.finished.len(), n);
        assert!(stats.steals <= stats.parks, "every steal takes a previously parked decode");
        let mut seen = std::collections::BTreeSet::new();
        for f in &stats.finished {
            assert!(seen.insert(f.id), "request {} retired twice", f.id);
        }
    }

    /// A page pool that fits roughly one request at a time serializes
    /// the run through the exhaustion/retry path instead of losing or
    /// duplicating work — and a request bigger than the whole pool is
    /// rejected up front instead of stalling its worker forever.
    #[test]
    fn tight_page_pool_still_serves_every_request() {
        use crate::serve::paged::pages_for;
        let (tcfg, reqs) = small_trace(8, 7);
        let n = reqs.len();
        let max_req = tcfg.max_request_tokens();
        let ctxs = contexts(2, max_req);
        let ocfg = OnlineConfig {
            workers: 2,
            sched: SchedulerConfig { token_budget: 64, max_batch: 2 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            kv: KvMode::Paged { page_tokens: 2, max_pages: pages_for(max_req, 2) },
            ..OnlineConfig::default()
        };
        let stats = serve_online(&ctxs, reqs.clone(), &ocfg).unwrap();
        assert_eq!(stats.finished.len(), n, "exhaustion must delay, never drop");

        // a pool smaller than the largest admissible request is an error
        let ocfg = OnlineConfig {
            workers: 2,
            sched: SchedulerConfig { token_budget: 64, max_batch: 2 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            kv: KvMode::Paged { page_tokens: 2, max_pages: 1 },
            ..OnlineConfig::default()
        };
        assert!(serve_online(&ctxs, reqs, &ocfg).is_err());
    }

    /// A tracer attached to an online run records spans for every
    /// retired request, with queue/prefill spans present per request.
    #[test]
    fn traced_run_records_spans_per_request() {
        let (tcfg, reqs) = small_trace(5, 5);
        let n = reqs.len();
        let ctxs = contexts(1, tcfg.max_request_tokens());
        let ocfg = OnlineConfig {
            workers: 1,
            sched: SchedulerConfig { token_budget: 64, max_batch: 2 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            ..OnlineConfig::default()
        };
        let tracer = Tracer::new();
        let stats = serve_online_traced(&ctxs, reqs, &ocfg, Some(&tracer)).unwrap();
        assert_eq!(stats.finished.len(), n);
        let spans = tracer.drain();
        let mut reqs_with_queue = std::collections::BTreeSet::new();
        let mut reqs_with_prefill = std::collections::BTreeSet::new();
        for s in &spans {
            match s.kind {
                SpanKind::Queue => {
                    reqs_with_queue.insert(s.req);
                }
                SpanKind::Prefill => {
                    reqs_with_prefill.insert(s.req);
                }
                _ => {}
            }
        }
        assert_eq!(reqs_with_queue.len(), n, "a queue span per retired request");
        assert_eq!(reqs_with_prefill.len(), n, "a prefill span per retired request");
        // drained once: a second drain is empty
        assert!(tracer.drain().is_empty());
    }
}
