//! Online multi-worker serving: a sharded worker pool behind the
//! real-time ingestion front end ([`super::ingest`]).
//!
//! [`serve_online`] runs one producer thread plus `workers` serving
//! workers on [`crate::util::par::scoped_workers`]. Each worker owns a
//! full [`ServeContext`] replica (packed weights + RoPE tables) and the
//! KV caches of the requests it admitted — nothing but the arrival queue
//! is shared, so workers never contend on model state. Every worker runs
//! its own continuous-batching loop: pull admissions from the shared
//! queue while its token budget and batch slots allow, prefill them, then
//! one batched decode step per iteration for everything active —
//! the same loop as the offline [`super::bench::run_trace`], sharded.
//! The same `worker_loop` also serves the TCP front end ([`super::net`]),
//! where it additionally streams tokens back over per-request reply
//! channels.
//!
//! # Determinism / parity
//!
//! Which worker serves a request (and which other requests share its
//! batch) is racy, but the *output* of a request is not: greedy decode
//! depends only on the model and the request's own prompt — batched
//! linears are row-independent and attention reads only the request's own
//! KV cache — so any worker count, and any queue [`Policy`], produces
//! identical per-request tokens and NLLs. `tests/serve_parity.rs` pins
//! sharded == single-worker == offline replay, and FIFO == priority ==
//! EDF per-request outputs.
//!
//! # Overload
//!
//! [`OnlineConfig`] exposes the queue's overload knobs (policy, bounded
//! capacity, predictive admit-time shedding); requests carrying deadlines
//! can be shed in-queue or rejected at push, and every outcome lands in
//! [`OnlineStats`] — `finished + shed + rejected == submitted`, always.
//!
//! # Metrics
//!
//! Per worker: requests served, prompt/generated tokens, busy (compute)
//! seconds vs pool wall-clock, peak batch occupancy. Per request: queue
//! wait (enqueue → admission) vs service (admission → retire) split, and
//! whether the deadline was met. With a [`Tracer`] attached
//! ([`serve_online_traced`]), workers also record queue/admit/prefill/
//! decode spans per request (see [`crate::telemetry`]). [`super::bench`]
//! merges these into aggregate throughput and latency percentiles for
//! `BENCH_serve.json`.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::telemetry::{sink_or_disabled, SpanKind, SpanSink, Tracer};
use crate::util::par::{locked, scoped_workers};

use super::engine::{
    argmax, decode_step, last_logits, prefill, score_nll, DecodeScratch, ServeContext,
};
use super::ingest::{
    run_producer, ArrivedRequest, IngestQueue, Pacing, Pop, QueueConfig, RejectOutcome, Reply,
    ShedOutcome,
};
use super::kv::KvCache;
use super::scheduler::{Policy, ReqKind, Request, SchedulerConfig};

/// How long an idle worker sleeps before re-checking the queue.
const IDLE_POLL: Duration = Duration::from_millis(1);

/// Configuration of one online run.
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    /// serving workers (the producer thread is extra)
    pub workers: usize,
    /// per-worker admission caps (token budget + batch slots)
    pub sched: SchedulerConfig,
    pub pacing: Pacing,
    /// arrival-queue pop order (output-invariant)
    pub policy: Policy,
    /// arrival-queue capacity; 0 = unbounded
    pub queue_cap: usize,
    /// predictive admit-time deadline shedding (see
    /// [`QueueConfig::admit_reject`])
    pub admit_reject: bool,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            workers: 1,
            sched: SchedulerConfig::default(),
            pacing: Pacing::Replay { time_scale: 1.0 },
            policy: Policy::Fifo,
            queue_cap: 0,
            admit_reject: false,
        }
    }
}

/// One retired request, with the queue-wait vs compute split.
#[derive(Debug, Clone)]
pub struct OnlineFinished {
    pub id: usize,
    /// worker that served it
    pub worker: usize,
    /// enqueue → admission, seconds (wall clock)
    pub queue_wait_s: f64,
    /// enqueue → retire, seconds (wall clock); service time is
    /// `latency_s - queue_wait_s`
    pub latency_s: f64,
    pub out_tokens: usize,
    /// greedy tokens in generation order (empty for scoring requests)
    pub tokens: Vec<i32>,
    /// total prompt NLL (scoring requests only)
    pub nll: Option<f64>,
    /// retired before its deadline (always true without a deadline)
    pub deadline_met: bool,
}

/// Counters of one worker's whole run.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub worker: usize,
    pub requests: usize,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    /// seconds spent in prefill/decode compute (vs idle polling)
    pub busy_s: f64,
    pub peak_active: usize,
}

/// Merged result of one online run.
pub struct OnlineStats {
    pub finished: Vec<OnlineFinished>,
    pub workers: Vec<WorkerStats>,
    /// requests shed in-queue after their deadline passed
    pub shed: Vec<ShedOutcome>,
    /// requests rejected at push (bounded queue, unmeetable deadline)
    pub rejected: Vec<RejectOutcome>,
    /// wall-clock seconds from pool start to last worker exit
    pub wall_s: f64,
}

impl OnlineStats {
    /// prompt + generated tokens across all workers.
    pub fn total_tokens(&self) -> usize {
        self.workers.iter().map(|w| w.prompt_tokens + w.gen_tokens).sum()
    }

    /// retired requests that met their deadline (the goodput numerator).
    pub fn within_deadline(&self) -> usize {
        self.finished.iter().filter(|f| f.deadline_met).count()
    }
}

/// A request being decoded by one worker.
struct Active {
    req: Request,
    enqueued: Instant,
    /// pop instant — the start of service
    admitted_at: Instant,
    deadline_at: Option<Instant>,
    reply: Option<std::sync::mpsc::Sender<Reply>>,
    queue_wait_s: f64,
    cache: KvCache,
    last: i32,
    produced: usize,
    tokens: Vec<i32>,
    /// first batched decode step this request took part in
    decode_started: Option<Instant>,
}

/// Serve `requests` through `ocfg.workers` sharded workers, one
/// [`ServeContext`] replica each (`ctxs.len() == ocfg.workers`). Returns
/// after the producer finished, the queue drained and every in-flight
/// request retired (drain-on-shutdown: closing the queue never drops
/// admitted work).
pub fn serve_online(
    ctxs: &[ServeContext],
    requests: Vec<Request>,
    ocfg: &OnlineConfig,
) -> Result<OnlineStats> {
    serve_online_traced(ctxs, requests, ocfg, None)
}

/// [`serve_online`] with optional per-request span tracing: each worker
/// flushes queue/admit/prefill/decode spans into `tracer` through its own
/// buffered [`SpanSink`].
pub fn serve_online_traced(
    ctxs: &[ServeContext],
    requests: Vec<Request>,
    ocfg: &OnlineConfig,
    tracer: Option<&Tracer>,
) -> Result<OnlineStats> {
    if ocfg.workers == 0 {
        bail!("online serving needs at least one worker");
    }
    if ctxs.len() != ocfg.workers {
        bail!("got {} model replicas for {} workers", ctxs.len(), ocfg.workers);
    }
    if ocfg.sched.max_batch == 0 {
        bail!("scheduler max_batch must be >= 1");
    }
    if let Pacing::ClosedLoop { clients } = ocfg.pacing {
        if clients == 0 {
            bail!("closed-loop pacing needs at least one client");
        }
    }
    // reject up front anything that could never be admitted — with a
    // per-worker budget (or replica capacity: any worker may admit any
    // request, so the smallest bounds all) below a request's cost every
    // worker would refuse it forever and the queue would starve behind it
    // ctxs is non-empty (checked above); 0 if it somehow weren't, which
    // rejects any nonzero-cost request instead of panicking
    let min_pos = ctxs.iter().map(|c| c.max_pos()).min().unwrap_or(0);
    for r in &requests {
        if r.cost() > ocfg.sched.token_budget {
            bail!(
                "request {} cost {} exceeds the per-worker token budget {}",
                r.id,
                r.cost(),
                ocfg.sched.token_budget
            );
        }
        if r.cost() > min_pos {
            bail!(
                "request {} needs {} positions but a replica allows only {}",
                r.id,
                r.cost(),
                min_pos
            );
        }
    }
    let total = requests.len();
    let queue = IngestQueue::with_config(QueueConfig {
        policy: ocfg.policy,
        capacity: ocfg.queue_cap,
        workers_hint: ocfg.workers,
        admit_reject: ocfg.admit_reject,
    });
    // hand the owned request vec to the producer without cloning the
    // token buffers (scoped_workers takes Fn, so no direct move)
    let pending = Mutex::new(Some(requests));
    let start = Instant::now();
    // index 0 is the producer; 1..=workers are serving workers
    let results = scoped_workers(ocfg.workers + 1, |i| {
        if i == 0 {
            match locked(&pending).take() {
                // the producer runs exactly once (index 0); if the vec
                // were somehow gone, closing the queue lets the workers
                // drain and exit instead of panicking the pool
                Some(reqs) => run_producer(&queue, reqs, ocfg.pacing),
                None => queue.close(),
            }
            None
        } else {
            let mut sink = sink_or_disabled(tracer);
            Some(worker_loop(i - 1, &ctxs[i - 1], &queue, &ocfg.sched, &mut sink))
        }
    });
    let wall_s = start.elapsed().as_secs_f64();
    let mut finished = Vec::with_capacity(total);
    let mut workers = Vec::with_capacity(ocfg.workers);
    for (stats, fin) in results.into_iter().flatten() {
        workers.push(stats);
        finished.extend(fin);
    }
    finished.sort_by_key(|f| f.id);
    let (shed, rejected) = queue.take_outcomes();
    debug_assert_eq!(
        finished.len() + shed.len() + rejected.len(),
        total,
        "every request retires, sheds, or is rejected exactly once"
    );
    Ok(OnlineStats { finished, workers, shed, rejected, wall_s })
}

/// Retire one request: release its budget, answer the reply channel,
/// record the finished entry and feed the queue's service estimate.
#[allow(clippy::too_many_arguments)]
fn retire(
    x: Active,
    wid: usize,
    queue: &IngestQueue,
    sink: &mut SpanSink<'_>,
    finished: &mut Vec<OnlineFinished>,
    stats: &mut WorkerStats,
    nll: Option<f64>,
) {
    let now = Instant::now();
    stats.requests += 1;
    let deadline_met = match x.deadline_at {
        Some(dl) => now <= dl,
        None => true,
    };
    let wire = x.req.id as u64;
    if let Some(start) = x.decode_started {
        sink.record(wire, SpanKind::Decode, wid as i64, start, now, true);
    }
    if let Some(tx) = &x.reply {
        let _ = tx.send(Reply::Done { tokens: x.tokens.clone(), nll, deadline_met });
    }
    finished.push(OnlineFinished {
        id: x.req.id,
        worker: wid,
        queue_wait_s: x.queue_wait_s,
        latency_s: now.saturating_duration_since(x.enqueued).as_secs_f64(),
        out_tokens: x.produced,
        tokens: x.tokens,
        nll,
        deadline_met,
    });
    queue.note_done(now.saturating_duration_since(x.admitted_at).as_secs_f64());
}

/// One worker's continuous-batching loop: admit from the shared queue
/// while budget and slots allow, prefill admissions, one batched decode
/// step per iteration, retire at each request's token budget. Exits when
/// the queue is drained and nothing is left in flight. Streams each
/// generated token to the request's reply channel (when one is attached)
/// as soon as it exists, and records per-request spans into `sink`.
pub(crate) fn worker_loop(
    wid: usize,
    ctx: &ServeContext,
    queue: &IngestQueue,
    scfg: &SchedulerConfig,
    sink: &mut SpanSink<'_>,
) -> (WorkerStats, Vec<OnlineFinished>) {
    let d = ctx.model.cfg.d_model;
    let mut active: Vec<Active> = Vec::new();
    let mut in_flight_tokens = 0usize;
    let mut finished: Vec<OnlineFinished> = Vec::new();
    let mut scratch = DecodeScratch::new();
    let mut stats = WorkerStats {
        worker: wid,
        requests: 0,
        prompt_tokens: 0,
        gen_tokens: 0,
        busy_s: 0.0,
        peak_active: 0,
    };
    loop {
        // admit while the per-worker budget and batch slots allow; the
        // queue wait ends here, at the pop
        let mut admitted: Vec<ArrivedRequest> = Vec::new();
        while active.len() + admitted.len() < scfg.max_batch {
            match queue.try_pop(|r| in_flight_tokens + r.cost() <= scfg.token_budget) {
                Pop::Got(a) => {
                    in_flight_tokens += a.req.cost();
                    admitted.push(a);
                }
                Pop::Refused | Pop::Empty | Pop::Drained => break,
            }
        }
        if admitted.is_empty() && active.is_empty() {
            if queue.is_drained() {
                break;
            }
            queue.wait_arrival(IDLE_POLL);
            continue;
        }
        let work = Instant::now();
        for a in admitted {
            let ArrivedRequest { req, enqueued, deadline_at, reply, .. } = a;
            let admitted_at = work;
            let queue_wait_s = admitted_at.saturating_duration_since(enqueued).as_secs_f64();
            let wire = req.id as u64;
            sink.record(wire, SpanKind::Queue, wid as i64, enqueued, admitted_at, true);
            stats.prompt_tokens += req.tokens.len();
            let s = req.tokens.len();
            let mut cache = ctx.new_cache();
            let t_prefill = Instant::now();
            sink.record(wire, SpanKind::Admit, wid as i64, admitted_at, t_prefill, true);
            let hidden = prefill(ctx, &req.tokens, &mut cache);
            sink.record(wire, SpanKind::Prefill, wid as i64, t_prefill, Instant::now(), true);
            match req.kind {
                ReqKind::Score => {
                    let nll = score_nll(ctx, &hidden, &req.tokens);
                    let nll_sum: f64 = nll.iter().map(|v| *v as f64).sum();
                    in_flight_tokens -= req.cost();
                    retire(
                        Active {
                            req,
                            enqueued,
                            admitted_at,
                            deadline_at,
                            reply,
                            queue_wait_s,
                            cache,
                            last: 0,
                            produced: 0,
                            tokens: Vec::new(),
                            decode_started: None,
                        },
                        wid,
                        queue,
                        sink,
                        &mut finished,
                        &mut stats,
                        Some(nll_sum),
                    );
                }
                ReqKind::Generate { max_new } => {
                    let first = argmax(&last_logits(ctx, &hidden[(s - 1) * d..s * d])) as i32;
                    stats.gen_tokens += 1;
                    if let Some(tx) = &reply {
                        let _ = tx.send(Reply::Token { index: 0, token: first });
                    }
                    let x = Active {
                        req,
                        enqueued,
                        admitted_at,
                        deadline_at,
                        reply,
                        queue_wait_s,
                        cache,
                        last: first,
                        produced: 1,
                        tokens: vec![first],
                        decode_started: None,
                    };
                    if max_new <= 1 {
                        in_flight_tokens -= x.req.cost();
                        retire(x, wid, queue, sink, &mut finished, &mut stats, None);
                    } else {
                        active.push(x);
                    }
                }
            }
        }
        stats.peak_active = stats.peak_active.max(active.len());
        if !active.is_empty() {
            let t_step = Instant::now();
            for x in active.iter_mut() {
                if x.decode_started.is_none() {
                    x.decode_started = Some(t_step);
                }
            }
            let last: Vec<i32> = active.iter().map(|x| x.last).collect();
            let next = {
                let mut caches: Vec<&mut KvCache> =
                    active.iter_mut().map(|x| &mut x.cache).collect();
                decode_step(ctx, &last, &mut caches, &mut scratch)
            };
            stats.gen_tokens += next.len();
            for (x, t) in active.iter_mut().zip(&next) {
                x.last = *t;
                x.produced += 1;
                x.tokens.push(*t);
                if let Some(tx) = &x.reply {
                    let _ = tx.send(Reply::Token { index: x.produced - 1, token: *t });
                }
            }
            let mut i = 0;
            while i < active.len() {
                let max_new = match active[i].req.kind {
                    ReqKind::Generate { max_new } => max_new,
                    ReqKind::Score => 0,
                };
                if active[i].produced >= max_new {
                    let x = active.swap_remove(i);
                    in_flight_tokens -= x.req.cost();
                    retire(x, wid, queue, sink, &mut finished, &mut stats, None);
                } else {
                    i += 1;
                }
            }
        }
        stats.busy_s += work.elapsed().as_secs_f64();
    }
    (stats, finished)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::tests::test_config;
    use crate::model::ParamStore;
    use crate::serve::bench::magnitude_prune_in_place;
    use crate::serve::model::{PackedModel, WeightFormat};
    use crate::serve::trace::{poisson_trace, TraceConfig};

    fn small_trace(n: usize, seed: u64) -> (TraceConfig, Vec<Request>) {
        let tcfg = TraceConfig {
            n_requests: n,
            rate: 1000.0,
            prompt_min: 3,
            prompt_max: 8,
            gen_min: 2,
            gen_max: 5,
            score_fraction: 0.3,
            burst: 1,
            seed,
            ..TraceConfig::default()
        };
        let reqs = poisson_trace(&tcfg);
        (tcfg, reqs)
    }

    fn contexts(n: usize, max_pos: usize) -> Vec<ServeContext> {
        let cfg = test_config();
        let mut params = ParamStore::init(&cfg, 42);
        magnitude_prune_in_place(&mut params, &cfg, 0.5).unwrap();
        (0..n)
            .map(|_| {
                ServeContext::new(
                    PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap(),
                    max_pos,
                )
            })
            .collect()
    }

    #[test]
    fn invalid_configs_are_rejected() {
        let (tcfg, reqs) = small_trace(3, 1);
        let ctxs = contexts(1, tcfg.max_request_tokens());
        let sched = SchedulerConfig { token_budget: 64, max_batch: 2 };
        // zero workers can never serve: queued requests would starve
        let ocfg = OnlineConfig {
            workers: 0,
            sched: sched.clone(),
            pacing: Pacing::Replay { time_scale: 0.0 },
            ..OnlineConfig::default()
        };
        assert!(serve_online(&[], reqs.clone(), &ocfg).is_err());
        // zero batch slots is the same starvation with workers alive
        let ocfg = OnlineConfig {
            workers: 1,
            sched: SchedulerConfig { token_budget: 64, max_batch: 0 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            ..OnlineConfig::default()
        };
        assert!(serve_online(&ctxs, reqs.clone(), &ocfg).is_err());
        // a request that exceeds the per-worker budget would starve the
        // whole FIFO behind it — rejected up front (every request costs
        // at least prompt_min = 3 tokens)
        let ocfg = OnlineConfig {
            workers: 1,
            sched: SchedulerConfig { token_budget: 2, max_batch: 2 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            ..OnlineConfig::default()
        };
        assert!(serve_online(&ctxs, reqs.clone(), &ocfg).is_err());
        // zero closed-loop clients would deadlock the producer
        let ocfg = OnlineConfig {
            workers: 1,
            sched,
            pacing: Pacing::ClosedLoop { clients: 0 },
            ..OnlineConfig::default()
        };
        assert!(serve_online(&ctxs, reqs, &ocfg).is_err());
    }

    #[test]
    fn drain_on_shutdown_retires_in_flight_decodes() {
        // time_scale 0 floods + closes the queue while every generation
        // request is still decoding: the pool must drain them all
        let (tcfg, reqs) = small_trace(8, 2);
        let n = reqs.len();
        let gens: usize = reqs
            .iter()
            .filter(|r| matches!(r.kind, ReqKind::Generate { .. }))
            .count();
        let ctxs = contexts(2, tcfg.max_request_tokens());
        let ocfg = OnlineConfig {
            workers: 2,
            sched: SchedulerConfig { token_budget: 64, max_batch: 2 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            ..OnlineConfig::default()
        };
        let stats = serve_online(&ctxs, reqs.clone(), &ocfg).unwrap();
        assert_eq!(stats.finished.len(), n);
        let mut seen = std::collections::BTreeSet::new();
        for f in &stats.finished {
            assert!(seen.insert(f.id), "request {} retired twice", f.id);
            assert!(f.latency_s >= f.queue_wait_s && f.queue_wait_s >= 0.0);
            assert!(f.deadline_met, "deadline-free requests always report met");
        }
        // every generation request produced its full token budget
        for (f, r) in stats.finished.iter().zip(&reqs) {
            assert_eq!(f.id, r.id);
            match r.kind {
                ReqKind::Generate { max_new } => {
                    assert_eq!(f.out_tokens, max_new);
                    assert_eq!(f.tokens.len(), max_new);
                }
                ReqKind::Score => {
                    assert!(f.nll.is_some());
                    assert!(f.tokens.is_empty());
                }
            }
        }
        assert!(gens > 0, "trace should include generation requests");
        let served: usize = stats.workers.iter().map(|w| w.requests).sum();
        assert_eq!(served, n);
        assert!(stats.shed.is_empty() && stats.rejected.is_empty());
    }

    #[test]
    fn closed_loop_serves_every_request() {
        let (tcfg, reqs) = small_trace(10, 3);
        let n = reqs.len();
        let ctxs = contexts(2, tcfg.max_request_tokens());
        let ocfg = OnlineConfig {
            workers: 2,
            sched: SchedulerConfig { token_budget: 64, max_batch: 2 },
            pacing: Pacing::ClosedLoop { clients: 3 },
            ..OnlineConfig::default()
        };
        let stats = serve_online(&ctxs, reqs, &ocfg).unwrap();
        assert_eq!(stats.finished.len(), n);
        // at most `clients` could ever be in flight pool-wide
        let peak: usize = stats.workers.iter().map(|w| w.peak_active).sum();
        assert!(peak <= 2 * 3, "peak occupancy {peak} vs 3 clients");
    }

    /// Overload accounting under hopeless deadlines: a flooded queue with
    /// microsecond deadlines must shed (in-queue) or reject (at push)
    /// most requests — and every one of the `n` lands in exactly one of
    /// the three ledgers.
    #[test]
    fn deadline_shedding_accounts_for_every_request() {
        let (tcfg, mut reqs) = small_trace(8, 4);
        for r in &mut reqs {
            r.qos.deadline_s = 1e-6;
        }
        let n = reqs.len();
        let ctxs = contexts(1, tcfg.max_request_tokens());
        let ocfg = OnlineConfig {
            workers: 1,
            sched: SchedulerConfig { token_budget: 16, max_batch: 1 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            policy: Policy::Edf,
            ..OnlineConfig::default()
        };
        let stats = serve_online(&ctxs, reqs, &ocfg).unwrap();
        assert_eq!(
            stats.finished.len() + stats.shed.len() + stats.rejected.len(),
            n,
            "no request lost or double-counted under shedding"
        );
        // with a 1µs budget and max_batch 1, the flood cannot all be
        // served in time: something must have been shed or rejected
        assert!(
            stats.shed.len() + stats.rejected.len() > 0,
            "hopeless deadlines must trigger shedding"
        );
        for f in &stats.finished {
            assert!(!f.deadline_met, "nothing completes within 1µs");
        }
    }

    /// A tracer attached to an online run records spans for every
    /// retired request, with queue/prefill spans present per request.
    #[test]
    fn traced_run_records_spans_per_request() {
        let (tcfg, reqs) = small_trace(5, 5);
        let n = reqs.len();
        let ctxs = contexts(1, tcfg.max_request_tokens());
        let ocfg = OnlineConfig {
            workers: 1,
            sched: SchedulerConfig { token_budget: 64, max_batch: 2 },
            pacing: Pacing::Replay { time_scale: 0.0 },
            ..OnlineConfig::default()
        };
        let tracer = Tracer::new();
        let stats = serve_online_traced(&ctxs, reqs, &ocfg, Some(&tracer)).unwrap();
        assert_eq!(stats.finished.len(), n);
        let spans = tracer.drain();
        let mut reqs_with_queue = std::collections::BTreeSet::new();
        let mut reqs_with_prefill = std::collections::BTreeSet::new();
        for s in &spans {
            match s.kind {
                SpanKind::Queue => {
                    reqs_with_queue.insert(s.req);
                }
                SpanKind::Prefill => {
                    reqs_with_prefill.insert(s.req);
                }
                _ => {}
            }
        }
        assert_eq!(reqs_with_queue.len(), n, "a queue span per retired request");
        assert_eq!(reqs_with_prefill.len(), n, "a prefill span per retired request");
        // drained once: a second drain is empty
        assert!(tracer.drain().is_empty());
    }
}
