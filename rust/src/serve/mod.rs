//! Batch inference engine for pruned checkpoints: the first subsystem
//! where BESA's sparsity pays off *end to end* — pruned weights are
//! packed into CSR / quantized-CSR form ([`crate::sparse`]) and executed,
//! not just simulated ([`crate::sim`]) or masked ([`crate::prune`]).
//!
//! The pieces:
//!
//! * [`model`] — [`model::PackedModel`]: a [`crate::model::ParamStore`]
//!   checkpoint materialized into dense / CSR / quantized-CSR projections.
//! * [`kv`] — [`kv::KvCache`]: per-request roped-key/value cache, one
//!   `[capacity, d]` plane per block.
//! * [`paged`] — the paged alternative ([`paged::PagePool`] /
//!   [`paged::PageTable`]): fixed-size pages from a shared free-list pool,
//!   refcounted copy-on-write prefix sharing, page-table migration for
//!   decode work stealing, and the [`paged::Kv`] enum both cache
//!   representations serve through. `--kv paged|contig` selects at run
//!   time; the two are bitwise-parity-pinned.
//! * [`engine`] — variable-length prefill (fills the KV cache), batched
//!   O(1)-per-token decode, prompt scoring, plus a decode path routed
//!   through the runtime backend's `block_fwd_cached` artifact.
//! * [`scheduler`] — continuous batching: FIFO admission under a token
//!   budget and a batch-slot cap; generation and scoring requests mix in
//!   one batch. Heterogeneous prompt lengths are served without padding
//!   by the variable-length kernels here; the complementary
//!   fixed-shape route ([`crate::eval::score_prompts_padded`]) right-pads
//!   a batch into the backend's static `[B, S]` artifacts and masks the
//!   tail — exact under causal attention, and parity-pinned against this
//!   engine.
//! * [`ingest`] / [`online`] — the *online* engine: a producer thread
//!   replays Poisson/bursty traces in wall-clock time (or runs a
//!   closed-loop load generator) into a shared arrival queue, and a
//!   sharded pool of workers — one [`model::PackedModel`] replica and its
//!   own KV caches each — pulls admissions and runs per-worker continuous
//!   batching. Sharding preserves per-request determinism, so any worker
//!   count produces identical outputs (pinned by `tests/serve_parity.rs`).
//! * [`trace`] / [`bench`] — Poisson/bursty request traces and the driver
//!   behind `besa serve-bench`: offline trace replay per weight format
//!   plus the async multi-worker mode (`--async`), reporting throughput,
//!   p50/p95/p99 latency, per-worker utilization and the queue-wait vs
//!   compute split into `BENCH_serve.json` — and `--overload-sweep`,
//!   goodput-vs-offered-load curves per queue [`scheduler::Policy`].
//! * [`fault`] — deterministic, seeded fault injection (`--faults`):
//!   worker panics mid-prefill/mid-decode, slow-worker stalls, client
//!   disconnects mid-stream, and admission pressure at scheduled points,
//!   exercising the supervision layer in [`online`] (panic isolation,
//!   capped-backoff restart, requeue-or-fail recovery) and the
//!   sparsity-tiered degradation path (`--degrade`: answer from a
//!   sparser replica instead of shedding). Grammar, invariants and the
//!   chaos suite in `docs/robustness.md`.
//! * [`net`] — the TCP front end (`besa serve-net`): line-delimited JSON
//!   + an HTTP/1.1-subset adapter over the very same `worker_loop`, with
//!   overload control (per-client token buckets, deadline shedding,
//!   bounded-queue backpressure, FIFO/priority/EDF policies) and
//!   graceful drain. Protocol and operations in `docs/serving.md`;
//!   per-request span tracing in `docs/telemetry.md`.
//!
//! # Quickstart
//!
//! ```text
//! # hermetic smoke run (synthetic magnitude-pruned checkpoint):
//! besa serve-bench --config test --smoke
//!
//! # async multi-worker mode: wall-clock ingestion + sharded workers
//! besa serve-bench --config test --smoke --async --workers 4
//!
//! # the real flow: prune, then serve the pruned checkpoint
//! besa pretrain   --config sm --steps 200 --out runs/sm-dense.bst
//! besa prune      --config sm --method besa --sparsity 0.5 --out runs/sm-besa.bst
//! besa serve-bench --config sm --ckpt runs/sm-besa.bst \
//!     --requests 64 --rate 16 --modes dense,sparse,quant --async --workers 4
//! ```
//!
//! Programmatic use:
//!
//! ```no_run
//! use besa::model::{ModelConfig, ParamStore};
//! use besa::serve::engine::{
//!     decode_step, last_logits, argmax, prefill, DecodeScratch, ServeContext,
//! };
//! use besa::serve::model::{PackedModel, WeightFormat};
//!
//! let cfg = ModelConfig::builtin("test").unwrap();
//! let params = ParamStore::init(&cfg, 1); // normally a pruned checkpoint
//! let model = PackedModel::materialize(&params, &cfg, WeightFormat::Csr).unwrap();
//! let ctx = ServeContext::new(model, 64);
//! let mut cache = ctx.new_cache();
//! let hidden = prefill(&ctx, &[1, 2, 3], &mut cache);
//! let d = ctx.model.cfg.d_model;
//! let mut tok = argmax(&last_logits(&ctx, &hidden[2 * d..3 * d])) as i32;
//! let mut scratch = DecodeScratch::new();
//! for _ in 0..8 {
//!     let mut caches = [&mut cache];
//!     tok = decode_step(&ctx, &[tok], &mut caches, &mut scratch)[0];
//! }
//! ```
//!
//! Parity guarantees (pinned by `tests/serve_parity.rs`): CSR serving
//! reproduces the dense path bitwise, dense serving reproduces the native
//! backend's `block_fwd`/`head_nll` math, and KV-cached decode matches a
//! full-prefix recompute token for token.

pub mod bench;
pub mod engine;
pub mod fault;
pub mod ingest;
pub mod kv;
pub mod model;
pub mod net;
pub mod online;
pub mod paged;
pub mod scheduler;
pub mod trace;

pub use bench::{run_serve_bench, run_trace, ServeBenchConfig, ServeMode};
pub use engine::ServeContext;
pub use fault::{FaultAction, FaultPlan, FaultSite};
pub use ingest::{Admit, IngestQueue, Pacing, QueueConfig, RejectReason, Reply};
pub use kv::KvCache;
pub use model::{PackedModel, WeightFormat};
pub use net::{LineClient, NetConfig, NetServer, NetStats};
pub use online::{
    serve_online, serve_online_tiered, serve_online_traced, FailedOutcome, OnlineConfig,
    OnlineStats,
};
pub use paged::{gather_caches, Kv, KvMode, KvSpec, PagePool, PageTable, PrefixRegistry};
pub use scheduler::{Policy, Qos, ReqKind, Request, Scheduler, SchedulerConfig};
pub use trace::{poisson_trace, TraceConfig};
