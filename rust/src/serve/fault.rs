//! Deterministic, seeded fault injection for the serving stack
//! (`--faults <spec> --fault-seed <n>`, see `docs/robustness.md`).
//!
//! A [`FaultPlan`] is policy, not mechanism: callers ask
//! [`FaultPlan::fire`] at fixed *sites* in the serving loop and perform
//! the returned [`FaultAction`] themselves — the worker panics at its own
//! call site (so the injected death is indistinguishable from a real
//! mid-prefill/mid-decode bug to the supervision layer), the loopback
//! driver closes its own socket, the admission predicate refuses its own
//! pop. The plan only counts site hits and decides *when* to fire.
//!
//! # Spec grammar
//!
//! ```text
//! spec    := clause (',' clause)*
//! clause  := action '@' site trigger [ '=' param ]
//! action  := 'panic' | 'stall' | 'disconnect' | 'deny'
//! site    := 'prefill' | 'decode' | 'admit' | 'stream'
//! trigger := ':' n [ '+' every ]     exact: fire at the n-th site hit
//!                                    (1-based), then every `every` hits
//!          | '%' period              seeded: fire on a pseudo-random
//!                                    1/period of hits (splitmix64 over
//!                                    (seed, site, hit index))
//! param   := stall milliseconds (stall only; default 10)
//! ```
//!
//! Examples: `panic@prefill:2` (die during the 2nd prefill pool-wide),
//! `panic@decode:3+5` (3rd batched decode step, then every 5th),
//! `stall@decode%4=25` (sleep 25 ms on a seeded quarter of decode
//! steps), `disconnect@stream:4` (the driver closes the client socket
//! after the 4th streamed token event), `deny@admit%3` (refuse a seeded
//! third of admission attempts — synthetic page-pool pressure).
//!
//! # Determinism
//!
//! Site counters are global atomics: for a fixed spec and seed, the set
//! of *site-hit indices* that fire is exactly reproducible. Which worker
//! or request owns a given hit still depends on thread interleaving —
//! deliberately so: the chaos suite's invariants (accounting, pool
//! drain, one terminal event per stream) must hold under *any*
//! schedule, and the seeded trigger explores a different one per seed.
//!
//! # Zero overhead when disabled
//!
//! Every injection point guards on `Option<&FaultPlan>`; with `None`
//! (the default — no `--faults`) the check is a branch on a constant
//! `None`, no atomics touched, and a fault-free run is bitwise identical
//! to a build without the harness in the loop (pinned by
//! `tests/chaos.rs`).

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Result};

/// Where in the serving loop a fault can fire. Hit counters are
/// per-site, pool-wide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// a worker is about to prefill an admitted request
    Prefill,
    /// a worker is about to run one batched decode step
    Decode,
    /// a worker's admission predicate is examining the queue front
    Admit,
    /// the loopback driver received one streamed token event
    Stream,
}

const N_SITES: usize = 4;

impl FaultSite {
    pub const ALL: [FaultSite; N_SITES] =
        [FaultSite::Prefill, FaultSite::Decode, FaultSite::Admit, FaultSite::Stream];

    pub fn name(&self) -> &'static str {
        match self {
            FaultSite::Prefill => "prefill",
            FaultSite::Decode => "decode",
            FaultSite::Admit => "admit",
            FaultSite::Stream => "stream",
        }
    }

    fn index(&self) -> usize {
        match self {
            FaultSite::Prefill => 0,
            FaultSite::Decode => 1,
            FaultSite::Admit => 2,
            FaultSite::Stream => 3,
        }
    }

    fn from_name(s: &str) -> Option<FaultSite> {
        FaultSite::ALL.into_iter().find(|k| k.name() == s)
    }
}

/// What the caller must do when a clause fires at its site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// panic right here (an injected worker death — the supervision
    /// layer must recover)
    Panic,
    /// sleep this many milliseconds (a slow worker / stall)
    Stall(u64),
    /// close the client side of the stream (mid-stream disconnect)
    Disconnect,
    /// refuse this admission once (synthetic page-pool pressure)
    Deny,
}

impl FaultAction {
    pub fn name(&self) -> &'static str {
        match self {
            FaultAction::Panic => "panic",
            FaultAction::Stall(_) => "stall",
            FaultAction::Disconnect => "disconnect",
            FaultAction::Deny => "deny",
        }
    }
}

/// When a clause fires, in terms of its site's 1-based hit counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Trigger {
    /// exactly hit `n`, and then every `every` hits after it (0 = once)
    Nth { n: u64, every: u64 },
    /// a seeded pseudo-random 1/period of all hits
    Seeded { period: u64 },
}

#[derive(Debug, Clone, Copy)]
struct Clause {
    site: FaultSite,
    action: FaultAction,
    trigger: Trigger,
}

impl Clause {
    fn fires(&self, hit: u64, seed: u64) -> bool {
        match self.trigger {
            Trigger::Nth { n, every } => {
                hit == n || (every > 0 && hit > n && (hit - n) % every == 0)
            }
            Trigger::Seeded { period } => {
                splitmix64(seed ^ (self.site.index() as u64) << 32 ^ hit) % period == 0
            }
        }
    }
}

/// SplitMix64: the standard 64-bit finalizer — a tiny, seedable,
/// platform-independent hash (same constants as `util::rng`'s family).
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// A parsed, seeded fault schedule. Shared by reference (or `Arc`)
/// across the worker pool; all state is atomic counters.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    clauses: Vec<Clause>,
    /// 1-based hit counters, one per [`FaultSite`]
    hits: [AtomicU64; N_SITES],
    fired: AtomicU64,
}

impl FaultPlan {
    /// Parse a `--faults` spec (grammar in the module docs). Rejects
    /// unknown actions/sites, zero counts/periods, and action/site
    /// combinations that have no injection point (`panic`/`stall` fire
    /// inside workers at `prefill`/`decode`; `deny` only at `admit`;
    /// `disconnect` only at `stream`).
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut clauses = Vec::new();
        for raw in spec.split(',') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            clauses.push(parse_clause(raw).map_err(|e| anyhow!("fault clause '{raw}': {e}"))?);
        }
        if clauses.is_empty() {
            bail!("--faults spec '{spec}' contains no clauses");
        }
        Ok(FaultPlan {
            seed,
            clauses,
            hits: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
            fired: AtomicU64::new(0),
        })
    }

    /// Count one hit at `site` and return the action of the first clause
    /// that fires there, if any. The caller performs the action.
    pub fn fire(&self, site: FaultSite) -> Option<FaultAction> {
        let hit = self.hits[site.index()].fetch_add(1, Ordering::Relaxed) + 1;
        let action = self
            .clauses
            .iter()
            .find(|c| c.site == site && c.fires(hit, self.seed))
            .map(|c| c.action);
        if action.is_some() {
            self.fired.fetch_add(1, Ordering::Relaxed);
        }
        action
    }

    /// Faults fired so far (all sites).
    pub fn fired(&self) -> u64 {
        self.fired.load(Ordering::Relaxed)
    }

    /// Hits counted at `site` so far (fired or not).
    pub fn hits(&self, site: FaultSite) -> u64 {
        self.hits[site.index()].load(Ordering::Relaxed)
    }

    /// True when the plan has a clause at `site` — lets a caller skip
    /// plumbing (e.g. the driver only threads the plan into its client
    /// loop when a `stream` clause exists).
    pub fn covers(&self, site: FaultSite) -> bool {
        self.clauses.iter().any(|c| c.site == site)
    }
}

/// Convenience guard for injection points: counts a hit only when a plan
/// is attached. `None` is the zero-overhead disabled path.
#[inline]
pub fn fire(plan: Option<&FaultPlan>, site: FaultSite) -> Option<FaultAction> {
    match plan {
        Some(p) => p.fire(site),
        None => None,
    }
}

fn parse_clause(raw: &str) -> Result<Clause> {
    // action '@' site trigger ['=' param]
    let (action_s, rest) = raw
        .split_once('@')
        .ok_or_else(|| anyhow!("expected action@site:trigger (e.g. panic@prefill:2)"))?;
    let (rest, param) = match rest.split_once('=') {
        Some((r, p)) => {
            let ms: u64 = p
                .trim()
                .trim_end_matches("ms")
                .parse()
                .map_err(|_| anyhow!("stall parameter '{p}' is not a millisecond count"))?;
            (r, Some(ms))
        }
        None => (rest, None),
    };
    let (site_s, trigger) = if let Some((s, t)) = rest.split_once(':') {
        let (n_s, every_s) = match t.split_once('+') {
            Some((n, e)) => (n, Some(e)),
            None => (t, None),
        };
        let n: u64 = n_s.trim().parse().map_err(|_| anyhow!("hit count '{n_s}' is not a number"))?;
        if n == 0 {
            bail!("hit counts are 1-based; ':0' never fires");
        }
        let every = match every_s {
            Some(e) => {
                let every: u64 =
                    e.trim().parse().map_err(|_| anyhow!("repeat '{e}' is not a number"))?;
                if every == 0 {
                    bail!("'+0' repeat is meaningless; omit it to fire once");
                }
                every
            }
            None => 0,
        };
        (s, Trigger::Nth { n, every })
    } else if let Some((s, p)) = rest.split_once('%') {
        let period: u64 =
            p.trim().parse().map_err(|_| anyhow!("period '{p}' is not a number"))?;
        if period == 0 {
            bail!("'%0' would divide by zero; use %1 to fire on every hit");
        }
        (s, Trigger::Seeded { period })
    } else {
        bail!("missing trigger: append ':n', ':n+k' or '%period'");
    };
    let site = FaultSite::from_name(site_s.trim())
        .ok_or_else(|| anyhow!("unknown site '{site_s}' (prefill|decode|admit|stream)"))?;
    let action = match action_s.trim() {
        "panic" => FaultAction::Panic,
        "stall" => FaultAction::Stall(param.unwrap_or(10)),
        "disconnect" => FaultAction::Disconnect,
        "deny" => FaultAction::Deny,
        other => bail!("unknown action '{other}' (panic|stall|disconnect|deny)"),
    };
    if param.is_some() && !matches!(action, FaultAction::Stall(_)) {
        bail!("'=' parameter only applies to stall");
    }
    match (action, site) {
        (FaultAction::Panic | FaultAction::Stall(_), FaultSite::Prefill | FaultSite::Decode) => {}
        (FaultAction::Deny, FaultSite::Admit) => {}
        (FaultAction::Disconnect, FaultSite::Stream) => {}
        (a, s) => bail!("action '{}' has no injection point at site '{}'", a.name(), s.name()),
    }
    Ok(Clause { site, action, trigger })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_grammar() {
        let p = FaultPlan::parse(
            "panic@prefill:2, panic@decode:3+5, stall@decode%4=25, disconnect@stream:4, deny@admit%3",
            7,
        )
        .unwrap();
        assert_eq!(p.clauses.len(), 5);
        assert!(p.covers(FaultSite::Stream));
        assert_eq!(p.clauses[2].action, FaultAction::Stall(25));
        // 'ms' suffix tolerated on the stall parameter
        let p = FaultPlan::parse("stall@decode:1=40ms", 0).unwrap();
        assert_eq!(p.clauses[0].action, FaultAction::Stall(40));
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "panic",
            "panic@prefill",
            "panic@nowhere:1",
            "explode@decode:1",
            "panic@prefill:0",
            "panic@prefill:2+0",
            "stall@decode%0",
            "panic@stream:1",     // panic has no stream injection point
            "disconnect@decode:1", // disconnect is client-side only
            "deny@prefill:1",
            "panic@prefill:1=5",  // param is stall-only
        ] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "spec '{bad}' should be rejected");
        }
    }

    #[test]
    fn nth_trigger_fires_exactly_on_schedule() {
        let p = FaultPlan::parse("panic@prefill:3+2", 0).unwrap();
        let fired: Vec<bool> =
            (1..=9).map(|_| p.fire(FaultSite::Prefill).is_some()).collect();
        assert_eq!(
            fired,
            [false, false, true, false, true, false, true, false, true],
            "fires at hit 3, then every 2nd"
        );
        assert_eq!(p.fired(), 4);
        assert_eq!(p.hits(FaultSite::Prefill), 9);
        // other sites are untouched
        assert_eq!(p.fire(FaultSite::Decode), None);
    }

    #[test]
    fn seeded_trigger_is_deterministic_per_seed() {
        let runs: Vec<Vec<bool>> = (0..2)
            .map(|_| {
                let p = FaultPlan::parse("deny@admit%3", 42).unwrap();
                (0..64).map(|_| p.fire(FaultSite::Admit).is_some()).collect()
            })
            .collect();
        assert_eq!(runs[0], runs[1], "same seed, same schedule");
        let fired = runs[0].iter().filter(|b| **b).count();
        assert!(fired > 0 && fired < 64, "a %3 trigger fires on some but not all hits");
        // a different seed explores a different schedule
        let p = FaultPlan::parse("deny@admit%3", 43).unwrap();
        let other: Vec<bool> = (0..64).map(|_| p.fire(FaultSite::Admit).is_some()).collect();
        assert_ne!(runs[0], other, "seed 43 should differ from seed 42");
    }

    #[test]
    fn first_matching_clause_wins() {
        let p = FaultPlan::parse("stall@decode:2=5,panic@decode:2", 0).unwrap();
        assert_eq!(p.fire(FaultSite::Decode), None);
        assert_eq!(p.fire(FaultSite::Decode), Some(FaultAction::Stall(5)));
    }

    #[test]
    fn disabled_plan_is_a_no_op() {
        assert_eq!(fire(None, FaultSite::Prefill), None);
        assert_eq!(fire(None, FaultSite::Decode), None);
    }
}
