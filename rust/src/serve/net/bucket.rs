//! Per-client token-bucket admission control.
//!
//! A [`TokenBucket`] refills continuously at `rate` tokens/second up to
//! `burst` capacity; admitting a request takes its token *cost* (prompt +
//! max generation) from the bucket, so rate limiting is denominated in
//! model work, not request count. The math runs on an explicit
//! f64-seconds clock passed by the caller — pure and deterministic, which
//! is what makes the refill arithmetic unit-testable without sleeping —
//! and [`ClientBuckets`] keys one bucket per client id
//! ([`crate::serve::scheduler::Qos::client`]), created on first sight.

use std::collections::BTreeMap;

/// One client's bucket. Level is tracked lazily: it is brought forward
/// to `now_s` on every interaction, so an idle bucket costs nothing.
#[derive(Debug, Clone, Copy)]
pub struct TokenBucket {
    /// refill rate, tokens/second
    rate: f64,
    /// capacity (and the starting level: clients begin with a full burst)
    burst: f64,
    level: f64,
    last_s: f64,
}

impl TokenBucket {
    pub fn new(rate: f64, burst: f64) -> TokenBucket {
        let burst = burst.max(0.0);
        TokenBucket { rate: rate.max(0.0), burst, level: burst, last_s: 0.0 }
    }

    /// Level after refilling up to `now_s` (clamped to `burst`). A clock
    /// that goes backwards refills nothing — the level just holds.
    pub fn level_at(&self, now_s: f64) -> f64 {
        let dt = (now_s - self.last_s).max(0.0);
        (self.level + dt * self.rate).min(self.burst)
    }

    /// Take `amount` tokens if the refilled level covers them. On refusal
    /// the level is still brought forward (time passed either way).
    pub fn try_take(&mut self, now_s: f64, amount: f64) -> bool {
        let level = self.level_at(now_s);
        self.last_s = self.last_s.max(now_s);
        if level >= amount {
            self.level = level - amount;
            true
        } else {
            self.level = level;
            false
        }
    }
}

/// One bucket per client id, all sharing one rate/burst configuration.
/// `rate <= 0` disables rate limiting entirely ([`ClientBuckets::enabled`]
/// is false and every admit succeeds).
pub struct ClientBuckets {
    rate: f64,
    burst: f64,
    buckets: BTreeMap<u32, TokenBucket>,
}

impl ClientBuckets {
    pub fn new(rate: f64, burst: f64) -> ClientBuckets {
        ClientBuckets { rate, burst, buckets: BTreeMap::new() }
    }

    pub fn enabled(&self) -> bool {
        self.rate > 0.0
    }

    /// Admit `amount` tokens of work for `client` at `now_s`.
    pub fn try_admit(&mut self, client: u32, now_s: f64, amount: f64) -> bool {
        if !self.enabled() {
            return true;
        }
        let b = self
            .buckets
            .entry(client)
            .or_insert_with(|| TokenBucket::new(self.rate, self.burst));
        b.try_take(now_s, amount)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refill_math_is_exact_on_a_synthetic_clock() {
        let mut b = TokenBucket::new(10.0, 20.0);
        // starts full
        assert_eq!(b.level_at(0.0), 20.0);
        assert!(b.try_take(0.0, 15.0));
        assert_eq!(b.level_at(0.0), 5.0);
        // 1s at 10 tok/s refills to 15
        assert_eq!(b.level_at(1.0), 15.0);
        assert!(b.try_take(1.0, 15.0));
        assert_eq!(b.level_at(1.0), 0.0);
        // refill clamps at burst no matter how long we wait
        assert_eq!(b.level_at(1000.0), 20.0);
    }

    #[test]
    fn refusal_still_advances_the_clock() {
        let mut b = TokenBucket::new(2.0, 4.0);
        assert!(b.try_take(0.0, 4.0)); // drained
        assert!(!b.try_take(1.0, 3.0)); // only 2 refilled
        // the refused call must not double-refill: at t=1.5 the level is
        // 2 (from t<=1) + 0.5*2 = 3, not 2 + 1.5*2
        assert_eq!(b.level_at(1.5), 3.0);
        assert!(b.try_take(1.5, 3.0));
        assert_eq!(b.level_at(1.5), 0.0);
    }

    #[test]
    fn backwards_clock_is_harmless() {
        let mut b = TokenBucket::new(1.0, 10.0);
        assert!(b.try_take(5.0, 10.0));
        // a clock step backwards refills nothing and never underflows
        assert_eq!(b.level_at(3.0), 0.0);
        assert!(!b.try_take(3.0, 1.0));
        // and the bucket resumes refilling from the high-water mark
        assert_eq!(b.level_at(6.0), 1.0);
    }

    #[test]
    fn per_client_isolation_and_disable() {
        let mut cb = ClientBuckets::new(1.0, 8.0);
        assert!(cb.enabled());
        assert!(cb.try_admit(0, 0.0, 8.0));
        // client 0 drained; client 1 has its own full bucket
        assert!(!cb.try_admit(0, 0.0, 1.0));
        assert!(cb.try_admit(1, 0.0, 8.0));
        // rate 0 disables: everything is admitted
        let mut off = ClientBuckets::new(0.0, 0.0);
        assert!(!off.enabled());
        for i in 0..100 {
            assert!(off.try_admit(i % 3, 0.0, 1e9));
        }
    }
}
