//! A deliberately small HTTP/1.1 subset, just enough to expose the
//! serving engine to `curl` and load generators over the same handler as
//! the line protocol:
//!
//! * `GET /healthz` → `200 {"status":"ok"}`
//! * `POST /v1/generate` (body = one request object, the same schema as
//!   a [`super::proto`] request line) → one-shot JSON response (tokens
//!   are collected, not streamed — use the line protocol for streaming)
//!
//! Every response closes the connection (`Connection: close`); there is
//! no keep-alive, chunked encoding, or TLS. All reads are bounded by
//! [`super::proto::ProtoLimits::max_line_bytes`] so a hostile peer
//! cannot balloon memory; oversizes map to 413 and malformed framing to
//! 400, mirroring the line protocol's error codes.

use std::io::{BufRead, Read, Write};

use super::proto::{ProtoError, ProtoLimits};

/// Longest header section we accept before calling the request hostile.
const MAX_HEADERS: usize = 64;

/// A parsed request head plus its (possibly empty) body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Read one `\n`-terminated line of at most `cap` bytes, stripping the
/// terminator (and a preceding `\r`). `Ok(None)` means clean EOF before
/// any byte. An unterminated line *under* the cap (EOF mid-line) is
/// returned as-is; over the cap is a 413.
pub fn read_line_bounded<R: BufRead>(
    r: &mut R,
    cap: usize,
) -> Result<Option<String>, ProtoError> {
    let mut buf = Vec::new();
    let mut lim = Read::take(&mut *r, cap as u64 + 1);
    match lim.read_until(b'\n', &mut buf) {
        Ok(0) => Ok(None),
        Ok(_) => {
            if buf.last() != Some(&b'\n') && buf.len() > cap {
                return Err(ProtoError::new(
                    413,
                    format!("line exceeds the {cap} byte cap"),
                ));
            }
            if buf.last() == Some(&b'\n') {
                buf.pop();
            }
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            String::from_utf8(buf)
                .map(Some)
                .map_err(|_| ProtoError::new(400, "line is not valid UTF-8"))
        }
        Err(e) => Err(ProtoError::new(400, format!("read failed: {e}"))),
    }
}

/// Read one request (request line, headers, `Content-Length` body).
/// `Ok(None)` on clean EOF before a request line.
pub fn read_request<R: BufRead>(
    r: &mut R,
    limits: &ProtoLimits,
) -> Result<Option<HttpRequest>, ProtoError> {
    let cap = limits.max_line_bytes;
    let line = match read_line_bounded(r, cap)? {
        None => return Ok(None),
        Some(l) => l,
    };
    let mut parts = line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if v.starts_with("HTTP/1.") => {
            (m.to_string(), p.to_string())
        }
        _ => return Err(ProtoError::new(400, format!("bad request line {line:?}"))),
    };

    let mut content_length = 0usize;
    for n in 0.. {
        if n >= MAX_HEADERS {
            return Err(ProtoError::new(400, "too many headers"));
        }
        let h = match read_line_bounded(r, cap)? {
            None => return Err(ProtoError::new(400, "eof inside headers")),
            Some(h) => h,
        };
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse::<usize>()
                    .map_err(|_| ProtoError::new(400, format!("bad content-length {value:?}")))?;
            }
        }
        // headers without ':' are tolerated and ignored — we only ever
        // need content-length
    }

    if content_length > cap {
        return Err(ProtoError::new(
            413,
            format!("body of {content_length} bytes exceeds the {cap} byte cap"),
        ));
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body)
        .map_err(|e| ProtoError::new(400, format!("short body: {e}")))?;
    let body =
        String::from_utf8(body).map_err(|_| ProtoError::new(400, "body is not valid UTF-8"))?;
    Ok(Some(HttpRequest { method, path, body }))
}

/// Reason phrase for the codes this server emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

/// Write a complete response (JSON body, `Connection: close`).
pub fn write_response<W: Write>(w: &mut W, code: u16, body: &str) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        code,
        status_text(code),
        body.len(),
        body
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn limits() -> ProtoLimits {
        ProtoLimits { max_line_bytes: 128, max_prompt: 8, max_new: 8 }
    }

    #[test]
    fn parses_get_without_body() {
        let mut c = Cursor::new(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n".to_vec());
        let r = read_request(&mut c, &limits()).unwrap().unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert_eq!(r.body, "");
    }

    #[test]
    fn parses_post_with_content_length_body() {
        let body = r#"{"id":1,"prompt":[2],"max_new":3}"#;
        let raw = format!(
            "POST /v1/generate HTTP/1.1\r\nContent-Type: application/json\r\nCONTENT-LENGTH: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let mut c = Cursor::new(raw.into_bytes());
        let r = read_request(&mut c, &limits()).unwrap().unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/v1/generate");
        assert_eq!(r.body, body);
    }

    #[test]
    fn bare_lf_lines_are_accepted_too() {
        let mut c = Cursor::new(b"GET / HTTP/1.0\nA: b\n\n".to_vec());
        let r = read_request(&mut c, &limits()).unwrap().unwrap();
        assert_eq!((r.method.as_str(), r.path.as_str()), ("GET", "/"));
    }

    #[test]
    fn clean_eof_is_none_and_framing_errors_are_4xx() {
        let mut c = Cursor::new(Vec::new());
        assert!(read_request(&mut c, &limits()).unwrap().is_none());

        // garbage request line
        let mut c = Cursor::new(b"what is this\r\n\r\n".to_vec());
        assert_eq!(read_request(&mut c, &limits()).unwrap_err().code, 400);

        // eof inside headers
        let mut c = Cursor::new(b"GET / HTTP/1.1\r\nHost: x\r\n".to_vec());
        assert_eq!(read_request(&mut c, &limits()).unwrap_err().code, 400);

        // body shorter than content-length
        let mut c =
            Cursor::new(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc".to_vec());
        assert_eq!(read_request(&mut c, &limits()).unwrap_err().code, 400);

        // non-numeric content-length
        let mut c =
            Cursor::new(b"POST / HTTP/1.1\r\nContent-Length: ten\r\n\r\n".to_vec());
        assert_eq!(read_request(&mut c, &limits()).unwrap_err().code, 400);
    }

    #[test]
    fn oversizes_map_to_413() {
        let l = limits();
        // request line over the cap
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "x".repeat(300));
        let mut c = Cursor::new(long.into_bytes());
        assert_eq!(read_request(&mut c, &l).unwrap_err().code, 413);

        // declared body over the cap — rejected before reading it
        let mut c =
            Cursor::new(b"POST / HTTP/1.1\r\nContent-Length: 4096\r\n\r\n".to_vec());
        assert_eq!(read_request(&mut c, &l).unwrap_err().code, 413);

        // header flood
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..100 {
            raw.push_str(&format!("H{i}: v\r\n"));
        }
        raw.push_str("\r\n");
        let mut c = Cursor::new(raw.into_bytes());
        assert_eq!(read_request(&mut c, &l).unwrap_err().code, 400);
    }

    #[test]
    fn bounded_line_reader_handles_utf8_and_eof_tails() {
        let mut c = Cursor::new(vec![0xff, 0xfe, b'\n']);
        assert_eq!(read_line_bounded(&mut c, 64).unwrap_err().code, 400);

        // unterminated tail under the cap comes back as a line
        let mut c = Cursor::new(b"tail".to_vec());
        assert_eq!(read_line_bounded(&mut c, 64).unwrap().as_deref(), Some("tail"));
        assert!(read_line_bounded(&mut c, 64).unwrap().is_none());
    }

    #[test]
    fn responses_are_well_formed() {
        let mut out = Vec::new();
        write_response(&mut out, 200, r#"{"status":"ok"}"#).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 15\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"status\":\"ok\"}"));
        assert_eq!(status_text(429), "Too Many Requests");
        assert_eq!(status_text(777), "Unknown");
    }
}
