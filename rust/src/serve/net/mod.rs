//! The socket edge of the serving engine (`besa serve-net`): a hermetic,
//! std-only TCP front end over the same continuous-batching workers as
//! the offline engine — see `docs/serving.md` for the protocol and the
//! overload-control model.
//!
//! * [`proto`] — the line-delimited JSON wire protocol (requests,
//!   streamed token/done/error events) and its shared response bodies;
//! * [`http`] — the HTTP/1.1 subset (`GET /healthz`,
//!   `POST /v1/generate`) adapting the same handler for `curl`;
//! * [`bucket`] — per-client token-bucket admission, denominated in
//!   model work (prompt + max generation tokens);
//! * [`server`] — the [`NetServer`] itself: listener thread, per
//!   connection handlers, graceful drain, and the [`LineClient`] the
//!   drive mode and the parity tests use.

pub mod bucket;
pub mod http;
pub mod proto;
pub mod server;

pub use bucket::{ClientBuckets, TokenBucket};
pub use proto::{
    parse_event, parse_request, request_line, ProtoError, ProtoLimits, WireEvent, WireRequest,
};
pub use server::{LineClient, NetConfig, NetServer, NetStats};
