//! The TCP front end: a [`NetServer`] binds a listener, parses both wire
//! protocols ([`super::proto`] lines and the [`super::http`] subset) into
//! the shared arrival queue ([`crate::serve::ingest::IngestQueue`]), and
//! streams generated tokens back while the same supervised worker loop
//! ([`crate::serve::online::supervised_worker`]) as the offline engine
//! does the serving — the socket edge adds *no* model code, which is
//! what makes loopback == offline replay parity (`tests/serve_parity.rs`)
//! structural rather than lucky.
//!
//! # Threads
//!
//! * one nonblocking **listener** thread accepting connections while
//!   `accepting` holds;
//! * one detached **handler** thread per connection (sniffs HTTP vs
//!   lines from the first bytes, then parse → admit → stream replies);
//! * `workers` **serving** threads running the continuous-batching loop.
//!
//! Handlers and workers meet only at the ingest queue and the per-request
//! reply channels. Locks are never nested: the bucket check, the queue
//! push and the connection-count bookkeeping each take exactly one lock
//! in its own statement.
//!
//! # Overload control
//!
//! Admission applies, in order: capacity sanity (a request whose
//! worst-case KV footprint no replica — or, in `--kv paged` mode, the
//! whole page pool — could ever hold is a 400), the per-client token
//! bucket ([`super::bucket`], 429), then the queue's own checks —
//! bounded capacity, expired or predictively-unmeetable deadlines,
//! draining (503s). Queued requests past their deadline are shed by the
//! worker-side sweep and the waiting connection hears [`Reply::Shed`]
//! immediately. A servable request that cannot get pool pages *right
//! now* is not rejected: the worker parks it and retries, so transient
//! pool exhaustion shows up as queueing delay (or a deadline shed), and
//! `queued == finished + shed + failed` keeps holding.
//!
//! # Fault tolerance
//!
//! Workers run under [`crate::serve::online::supervised_worker`]: a
//! panic mid-service is caught, interrupted requests are requeued for a
//! from-scratch replay (or answered `done/failed` once tokens already
//! streamed or the retry budget ran out), and the worker restarts with
//! capped backoff. A client that disconnects mid-stream makes the
//! worker's token send fail; the worker drops the request's KV state,
//! counts it failed, and keeps serving its batch. With
//! [`NetConfig::degrade`] tier replicas installed, overloaded
//! admissions route to the sparser tier (marked `"degraded":true` on
//! the wire) instead of shedding. `docs/robustness.md` has the full
//! policy.
//!
//! # Graceful drain
//!
//! [`NetServer::shutdown`] stops accepting, joins the listener, waits up
//! to `drain_deadline` for open connections to finish, *then* closes the
//! queue (so late in-flight submissions still land) and joins the
//! workers. [`NetStats`] reports whether the drain beat the deadline and
//! the exact `queued == finished + shed + failed` accounting.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::telemetry::{sink_or_disabled, SpanKind, SpanSink, Tracer};
use crate::util::par::{locked, spawn_named, wait_timeout_on};

use super::super::engine::ServeContext;
use super::super::fault::FaultPlan;
use super::super::ingest::{
    Admit, IngestQueue, QueueConfig, RejectOutcome, Reply, ShedOutcome,
};
use super::super::online::{
    supervised_worker, FailedOutcome, OnlineFinished, WorkerEnv, WorkerReport, WorkerRun,
    WorkerStats,
};
use super::super::paged::{KvMode, KvSpec, PoolStats};
use super::super::scheduler::{Policy, SchedulerConfig};
use super::bucket::ClientBuckets;
use super::http::{read_request, write_response};
use super::proto::{
    done_body, done_line, error_body, error_line, failed_body, failed_line, parse_event,
    parse_request, reject_body, reject_line, shed_body, shed_line, token_line, ProtoLimits,
    WireEvent, WireRequest,
};

/// Accept-loop poll interval while the listener is nonblocking-idle.
const ACCEPT_POLL: Duration = Duration::from_millis(2);
/// Socket read timeout: an idle connection eventually releases its
/// handler thread instead of pinning it forever.
const READ_TIMEOUT: Duration = Duration::from_secs(60);
/// Cap on waiting for the serving side of an admitted request.
const REPLY_TIMEOUT: Duration = Duration::from_secs(60);

/// Configuration of one [`NetServer`].
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// bind address; port 0 picks an ephemeral port (read it back with
    /// [`NetServer::addr`])
    pub addr: String,
    /// serving workers (one [`ServeContext`] replica each)
    pub workers: usize,
    /// per-worker admission caps (token budget + batch slots)
    pub sched: SchedulerConfig,
    /// arrival-queue pop order (output-invariant)
    pub policy: Policy,
    /// arrival-queue capacity; 0 = unbounded
    pub queue_cap: usize,
    /// per-client token-bucket refill, tokens/second; 0 disables
    pub bucket_rate: f64,
    /// per-client token-bucket capacity
    pub bucket_burst: f64,
    /// predictive admit-time deadline shedding
    /// ([`QueueConfig::admit_reject`])
    pub admit_reject: bool,
    /// KV-cache backing (`--kv contig|paged`); a bounded paged pool turns
    /// exhaustion into deterministic 400/503 rejections, never a panic
    pub kv: KvMode,
    /// decode work stealing between workers (paged mode)
    pub steal: bool,
    /// fork admissions from registered shared prompt prefixes (paged mode)
    pub share_prefix: bool,
    /// how long [`NetServer::shutdown`] waits for open connections
    pub drain_deadline: Duration,
    pub limits: ProtoLimits,
    /// deterministic fault-injection schedule (`--faults`); `None`
    /// compiles the harness out of the hot path entirely
    pub faults: Option<Arc<FaultPlan>>,
    /// from-scratch replays a panic-interrupted request gets before it
    /// is answered `done/failed`
    pub retry_budget: u32,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 1,
            sched: SchedulerConfig::default(),
            policy: Policy::Fifo,
            queue_cap: 256,
            bucket_rate: 0.0,
            bucket_burst: 0.0,
            admit_reject: false,
            kv: KvMode::Contig,
            steal: false,
            share_prefix: false,
            drain_deadline: Duration::from_secs(10),
            limits: ProtoLimits::default(),
            faults: None,
            retry_budget: 2,
        }
    }
}

/// Everything the listener, handlers and workers share.
struct Shared {
    cfg: NetConfig,
    queue: IngestQueue,
    tracer: Option<Arc<Tracer>>,
    /// server start; request arrival stamps and bucket clocks are
    /// seconds since here
    epoch: Instant,
    /// smallest replica KV capacity — bounds any admissible request
    min_pos: usize,
    /// per-run KV allocation state shared by the worker pool (pool,
    /// steal board, prefix registry)
    env: WorkerEnv,
    accepting: AtomicBool,
    /// open connection handlers (the drain barrier)
    conn_count: Mutex<usize>,
    conn_done: Condvar,
    /// engine-side request ids; 0 is reserved for connection-scoped spans
    next_id: AtomicUsize,
    buckets: Mutex<ClientBuckets>,
    accepted: AtomicUsize,
    queued: AtomicUsize,
    rejected_rate: AtomicUsize,
    parse_errors: AtomicUsize,
}

/// Decrements the connection count on scope exit (including panics), so
/// the drain barrier in [`NetServer::shutdown`] can never hang on a
/// connection that died.
struct ConnGuard {
    sh: Arc<Shared>,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        {
            let mut g = locked(&self.sh.conn_count);
            *g = g.saturating_sub(1);
        }
        self.sh.conn_done.notify_all();
    }
}

/// Final accounting of one server lifetime, returned by
/// [`NetServer::shutdown`].
pub struct NetStats {
    /// retired requests, sorted by engine-side id
    pub finished: Vec<OnlineFinished>,
    pub workers: Vec<WorkerStats>,
    /// queued requests shed after their deadline passed
    pub shed: Vec<ShedOutcome>,
    /// requests rejected by the queue (bounded capacity, unmeetable
    /// deadline, draining)
    pub rejected: Vec<RejectOutcome>,
    /// requests that terminally failed: the client went away mid-stream,
    /// or a worker died mid-service past the retry budget
    pub failed: Vec<FailedOutcome>,
    /// supervised worker restarts after caught panics
    pub restarts: usize,
    /// panic-interrupted requests put back for a from-scratch replay
    pub requeues: usize,
    /// connections accepted over the lifetime
    pub accepted_conns: usize,
    /// requests that entered the queue — `finished + shed + failed`
    /// exactly
    pub requests: usize,
    /// lines/bodies that failed protocol validation
    pub parse_errors: usize,
    /// requests refused by the per-client token bucket (never queued)
    pub rejected_rate: usize,
    /// every connection closed before the drain deadline
    pub drained_clean: bool,
}

impl NetStats {
    /// The graceful-drain invariant: every queued request retired, was
    /// shed, or terminally failed — nothing vanished, even under
    /// injected panics and client disconnects.
    pub fn accounted(&self) -> bool {
        self.requests == self.finished.len() + self.shed.len() + self.failed.len()
    }

    /// Retired requests answered by the degrade tier.
    pub fn degraded(&self) -> usize {
        self.finished.iter().filter(|f| f.degraded).count()
    }
}

/// A running TCP front end. Construct with [`NetServer::start`], stop
/// with [`NetServer::shutdown`].
pub struct NetServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    listener: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<WorkerReport>>,
}

impl NetServer {
    /// Bind `cfg.addr`, spawn the worker pool (consuming one
    /// [`ServeContext`] replica per worker) and the listener thread, and
    /// return once the socket is accepting.
    pub fn start(
        ctxs: Vec<ServeContext>,
        cfg: NetConfig,
        tracer: Option<Arc<Tracer>>,
    ) -> Result<NetServer> {
        NetServer::start_tiered(ctxs, None, cfg, tracer)
    }

    /// [`NetServer::start`] plus an optional degrade tier: one sparser
    /// [`ServeContext`] replica per worker, served to requests admitted
    /// under queue pressure instead of shedding them (`--degrade`).
    pub fn start_tiered(
        ctxs: Vec<ServeContext>,
        degrade_ctxs: Option<Vec<ServeContext>>,
        cfg: NetConfig,
        tracer: Option<Arc<Tracer>>,
    ) -> Result<NetServer> {
        if cfg.workers == 0 {
            anyhow::bail!("serve-net needs at least one worker");
        }
        if ctxs.len() != cfg.workers {
            anyhow::bail!("got {} model replicas for {} workers", ctxs.len(), cfg.workers);
        }
        if let Some(d) = &degrade_ctxs {
            if d.len() != cfg.workers {
                anyhow::bail!("got {} degrade replicas for {} workers", d.len(), cfg.workers);
            }
            for (p, dc) in ctxs.iter().zip(d.iter()) {
                if !p.compatible_tier(dc) {
                    anyhow::bail!(
                        "degrade tier shape mismatch: both tiers must share the model \
                         architecture and context window"
                    );
                }
            }
        }
        if cfg.sched.max_batch == 0 {
            anyhow::bail!("scheduler max_batch must be >= 1");
        }
        if let KvMode::Paged { page_tokens: 0, .. } = cfg.kv {
            anyhow::bail!("paged KV needs a nonzero page size");
        }
        let min_pos = ctxs.iter().map(|c| c.max_pos()).min().unwrap_or(0);
        let mcfg = &ctxs[0].model.cfg;
        let env = WorkerEnv::new(
            KvSpec::for_mode(cfg.kv, mcfg.n_blocks, mcfg.d_model),
            cfg.steal,
            cfg.share_prefix,
            cfg.workers,
        );
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding serve-net listener to {}", cfg.addr))?;
        listener
            .set_nonblocking(true)
            .context("setting the serve-net listener nonblocking")?;
        let addr = listener.local_addr().context("reading the bound listener address")?;

        let buckets = ClientBuckets::new(cfg.bucket_rate, cfg.bucket_burst);
        let queue = IngestQueue::with_config(QueueConfig {
            policy: cfg.policy,
            capacity: cfg.queue_cap,
            workers_hint: cfg.workers,
            admit_reject: cfg.admit_reject,
        });
        let shared = Arc::new(Shared {
            cfg,
            queue,
            tracer,
            epoch: Instant::now(),
            min_pos,
            env,
            accepting: AtomicBool::new(true),
            conn_count: Mutex::new(0),
            conn_done: Condvar::new(),
            next_id: AtomicUsize::new(1),
            buckets: Mutex::new(buckets),
            accepted: AtomicUsize::new(0),
            queued: AtomicUsize::new(0),
            rejected_rate: AtomicUsize::new(0),
            parse_errors: AtomicUsize::new(0),
        });

        let mut workers = Vec::with_capacity(shared.cfg.workers);
        let mut degrade_iter = degrade_ctxs.map(Vec::into_iter);
        for (wid, ctx) in ctxs.into_iter().enumerate() {
            let dctx = degrade_iter.as_mut().and_then(Iterator::next);
            let sh = Arc::clone(&shared);
            let spawned = spawn_named(&format!("besa-serve-worker-{wid}"), move || {
                let mut sink = sink_or_disabled(sh.tracer.as_deref());
                let run = WorkerRun {
                    wid,
                    ctx: &ctx,
                    degrade: dctx.as_ref(),
                    queue: &sh.queue,
                    scfg: &sh.cfg.sched,
                    env: &sh.env,
                    faults: sh.cfg.faults.as_deref(),
                    retry_budget: sh.cfg.retry_budget,
                    queue_cap: sh.cfg.queue_cap,
                };
                supervised_worker(&run, &mut sink)
            });
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    // release the workers already running, then fail
                    shared.queue.close();
                    return Err(e);
                }
            }
        }

        let sh = Arc::clone(&shared);
        let listener_thread = spawn_named("besa-serve-listener", move || {
            accept_loop(&sh, listener);
        });
        let listener_thread = match listener_thread {
            Ok(h) => h,
            Err(e) => {
                shared.queue.close();
                return Err(e);
            }
        };

        Ok(NetServer { shared, addr, listener: Some(listener_thread), workers })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live snapshot of the shared page pool's accounting (`--kv paged`
    /// only) — what the disconnect tests poll to see a dead client's
    /// pages come back.
    pub fn pool_stats(&self) -> Option<PoolStats> {
        self.shared.env.kv().pool().map(|p| p.stats())
    }

    /// Graceful drain: stop accepting, wait for open connections (up to
    /// the drain deadline), close the queue, join the workers, and
    /// return the full accounting.
    pub fn shutdown(mut self) -> Result<NetStats> {
        self.shared.accepting.store(false, Ordering::SeqCst);
        if let Some(h) = self.listener.take() {
            h.join().map_err(|_| anyhow!("serve-net listener thread panicked"))?;
        }
        let deadline = Instant::now() + self.shared.cfg.drain_deadline;
        let drained_clean = {
            let mut g = locked(&self.shared.conn_count);
            while *g > 0 && Instant::now() < deadline {
                g = wait_timeout_on(&self.shared.conn_done, g, Duration::from_millis(20));
            }
            *g == 0
        };
        // only now does the queue close: connections that made it in
        // before the deadline still get served, and anything later is
        // rejected as Draining — race-free by construction
        self.shared.queue.close();
        let mut finished = Vec::new();
        let mut failed = Vec::new();
        let mut workers = Vec::new();
        let mut restarts = 0;
        let mut requeues = 0;
        for h in self.workers.drain(..) {
            let rep = h.join().map_err(|_| anyhow!("serve-net worker panicked"))?;
            workers.push(rep.stats);
            finished.extend(rep.finished);
            failed.extend(rep.failed);
            restarts += rep.restarts;
            requeues += rep.requeues;
        }
        finished.sort_by_key(|f| f.id);
        failed.sort_by_key(|f| f.id);
        let (shed, rejected) = self.shared.queue.take_outcomes();
        if let Some(ps) = self.shared.env.kv().pool().map(|p| p.stats()) {
            if !ps.drained() {
                return Err(anyhow!(
                    "page pool failed to drain: live {} free {} created {}",
                    ps.live,
                    ps.free,
                    ps.created
                ));
            }
        }
        Ok(NetStats {
            finished,
            workers,
            shed,
            rejected,
            failed,
            restarts,
            requeues,
            accepted_conns: self.shared.accepted.load(Ordering::Relaxed),
            requests: self.shared.queued.load(Ordering::Relaxed),
            parse_errors: self.shared.parse_errors.load(Ordering::Relaxed),
            rejected_rate: self.shared.rejected_rate.load(Ordering::Relaxed),
            drained_clean,
        })
    }
}

/// Accept until `accepting` clears; each connection gets a detached
/// handler thread, registered in the drain barrier *before* the spawn so
/// shutdown can never miss it.
fn accept_loop(sh: &Arc<Shared>, listener: TcpListener) {
    while sh.accepting.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                sh.accepted.fetch_add(1, Ordering::Relaxed);
                {
                    let mut g = locked(&sh.conn_count);
                    *g += 1;
                }
                let csh = Arc::clone(sh);
                let spawned = spawn_named("besa-serve-conn", move || {
                    let guard = ConnGuard { sh: Arc::clone(&csh) };
                    handle_conn(&csh, stream);
                    drop(guard);
                });
                if spawned.is_err() {
                    // undo the registration the handler never got to own
                    {
                        let mut g = locked(&sh.conn_count);
                        *g = g.saturating_sub(1);
                    }
                    sh.conn_done.notify_all();
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// Sniff the protocol from the first buffered bytes and dispatch.
fn handle_conn(sh: &Arc<Shared>, stream: TcpStream) {
    let t_accept = Instant::now();
    let mut sink = sink_or_disabled(sh.tracer.as_deref());
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let read_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    let head = match reader.fill_buf() {
        Ok(b) => b,
        Err(_) => return,
    };
    const HTTP_METHODS: [&[u8; 4]; 5] = [b"GET ", b"POST", b"PUT ", b"HEAD", b"DELE"];
    let is_http = HTTP_METHODS.iter().any(|p| head.starts_with(*p));
    sink.record(0, SpanKind::Accept, -1, t_accept, Instant::now(), true);
    if is_http {
        handle_http(sh, &mut reader, &mut writer, &mut sink);
    } else {
        handle_lines(sh, &mut reader, &mut writer, &mut sink);
    }
    let _ = writer.flush();
}

/// The line protocol: one request per line, responses streamed back;
/// protocol errors answer with an `error` line and (except for lost
/// framing) keep the connection.
fn handle_lines(
    sh: &Arc<Shared>,
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
    sink: &mut SpanSink<'_>,
) {
    let cap = sh.cfg.limits.max_line_bytes;
    loop {
        let t_read = Instant::now();
        let mut buf = Vec::new();
        let mut lim = Read::take(&mut *reader, cap as u64 + 1);
        let line = match lim.read_until(b'\n', &mut buf) {
            Ok(0) => return, // clean EOF
            Ok(_) if buf.last() != Some(&b'\n') && buf.len() > cap => {
                // framing is lost past the cap: answer and close
                sh.parse_errors.fetch_add(1, Ordering::Relaxed);
                sink.record(0, SpanKind::Parse, -1, t_read, Instant::now(), false);
                let msg = format!("request line exceeds the {cap} byte cap");
                let _ = writer.write_all(error_line(413, &msg).as_bytes());
                return;
            }
            Ok(_) => {
                if buf.last() == Some(&b'\n') {
                    buf.pop();
                }
                match String::from_utf8(buf) {
                    Ok(s) => s,
                    Err(_) => {
                        sh.parse_errors.fetch_add(1, Ordering::Relaxed);
                        sink.record(0, SpanKind::Parse, -1, t_read, Instant::now(), false);
                        let _ = writer.write_all(
                            error_line(400, "request line is not valid UTF-8").as_bytes(),
                        );
                        let _ = writer.flush();
                        continue;
                    }
                }
            }
            // read timeout or hard socket error: nothing mid-line we
            // could answer coherently
            Err(_) => return,
        };
        if line.trim().is_empty() {
            continue;
        }
        let wire = match parse_request(&line, &sh.cfg.limits) {
            Ok(w) => w,
            Err(e) => {
                sh.parse_errors.fetch_add(1, Ordering::Relaxed);
                sink.record(0, SpanKind::Parse, -1, t_read, Instant::now(), false);
                let _ = writer.write_all(error_line(e.code, &e.reason).as_bytes());
                let _ = writer.flush();
                continue;
            }
        };
        let wire_id = wire.id;
        match admit(sh, wire) {
            Err((code, reason)) => {
                let _ = writer.write_all(reject_line(wire_id, code, &reason).as_bytes());
                let _ = writer.flush();
            }
            Ok((internal, rx)) => {
                sink.record(internal, SpanKind::Parse, -1, t_read, Instant::now(), true);
                if !stream_replies(wire_id, internal, &rx, writer, sink) {
                    return;
                }
            }
        }
    }
}

/// Pump one admitted request's reply channel onto the socket. Returns
/// false when the connection should close (write failure or a serving
/// stall).
fn stream_replies(
    wire_id: u64,
    internal: u64,
    rx: &Receiver<Reply>,
    writer: &mut BufWriter<TcpStream>,
    sink: &mut SpanSink<'_>,
) -> bool {
    loop {
        match rx.recv_timeout(REPLY_TIMEOUT) {
            Ok(Reply::Token { index, token }) => {
                if writer.write_all(token_line(wire_id, index, token).as_bytes()).is_err() {
                    return false;
                }
                if writer.flush().is_err() {
                    return false;
                }
            }
            Ok(Reply::Done { tokens, nll, deadline_met, degraded }) => {
                let t_ser = Instant::now();
                let line = done_line(wire_id, &tokens, nll, deadline_met, degraded);
                let ok = writer.write_all(line.as_bytes()).is_ok() && writer.flush().is_ok();
                sink.record(internal, SpanKind::Serialize, -1, t_ser, Instant::now(), ok);
                return ok;
            }
            Ok(Reply::Shed { waited_s }) => {
                let ok = writer.write_all(shed_line(wire_id, waited_s).as_bytes()).is_ok()
                    && writer.flush().is_ok();
                return ok;
            }
            Ok(Reply::Failed { attempts }) => {
                let ok = writer.write_all(failed_line(wire_id, attempts).as_bytes()).is_ok()
                    && writer.flush().is_ok();
                return ok;
            }
            // the serving side went quiet for a full minute: tell the
            // client and drop the connection rather than hang it
            Err(_) => {
                let _ = writer.write_all(error_line(500, "serving stalled").as_bytes());
                let _ = writer.flush();
                return false;
            }
        }
    }
}

/// The HTTP adapter: exactly one request per connection
/// (`Connection: close`), generation collected into a single body.
fn handle_http(
    sh: &Arc<Shared>,
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
    sink: &mut SpanSink<'_>,
) {
    let req = match read_request(reader, &sh.cfg.limits) {
        Ok(Some(r)) => r,
        Ok(None) => return,
        Err(e) => {
            sh.parse_errors.fetch_add(1, Ordering::Relaxed);
            let _ = write_response(writer, e.code, &error_body(e.code, &e.reason));
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let _ = write_response(writer, 200, r#"{"status":"ok"}"#);
        }
        ("POST", "/v1/generate") => {
            let t_parse = Instant::now();
            let wire = match parse_request(&req.body, &sh.cfg.limits) {
                Ok(w) => w,
                Err(e) => {
                    sh.parse_errors.fetch_add(1, Ordering::Relaxed);
                    sink.record(0, SpanKind::Parse, -1, t_parse, Instant::now(), false);
                    let _ = write_response(writer, e.code, &error_body(e.code, &e.reason));
                    return;
                }
            };
            let wire_id = wire.id;
            match admit(sh, wire) {
                Err((code, reason)) => {
                    let _ = write_response(writer, code, &reject_body(wire_id, code, &reason));
                }
                Ok((internal, rx)) => {
                    sink.record(internal, SpanKind::Parse, -1, t_parse, Instant::now(), true);
                    collect_http_reply(wire_id, internal, &rx, writer, sink);
                }
            }
        }
        _ => {
            let _ = write_response(
                writer,
                404,
                &error_body(404, &format!("no route {} {}", req.method, req.path)),
            );
        }
    }
}

/// Wait out one admitted request and answer it as a single HTTP body
/// (streamed tokens are folded into the terminal `done` event).
fn collect_http_reply(
    wire_id: u64,
    internal: u64,
    rx: &Receiver<Reply>,
    writer: &mut BufWriter<TcpStream>,
    sink: &mut SpanSink<'_>,
) {
    loop {
        match rx.recv_timeout(REPLY_TIMEOUT) {
            Ok(Reply::Token { .. }) => continue,
            Ok(Reply::Done { tokens, nll, deadline_met, degraded }) => {
                let t_ser = Instant::now();
                let body = done_body(wire_id, &tokens, nll, deadline_met, degraded);
                let ok = write_response(writer, 200, &body).is_ok();
                sink.record(internal, SpanKind::Serialize, -1, t_ser, Instant::now(), ok);
                return;
            }
            Ok(Reply::Shed { waited_s }) => {
                let _ = write_response(writer, 503, &shed_body(wire_id, waited_s));
                return;
            }
            Ok(Reply::Failed { attempts }) => {
                let _ = write_response(writer, 500, &failed_body(wire_id, attempts));
                return;
            }
            Err(_) => {
                let _ = write_response(writer, 500, &error_body(500, "serving stalled"));
                return;
            }
        }
    }
}

/// Admission: capacity sanity → per-client token bucket → queue checks.
/// On success returns the engine-side id and the reply channel; on
/// rejection the HTTP-style code and reason for the wire.
fn admit(sh: &Arc<Shared>, wire: WireRequest) -> Result<(u64, Receiver<Reply>), (u16, String)> {
    let arrival_s = sh.epoch.elapsed().as_secs_f64();
    let internal = sh.next_id.fetch_add(1, Ordering::Relaxed);
    let req = wire.into_request(internal, arrival_s);
    let cost = req.cost();
    // the page pool's total capacity bounds requests the same way the
    // token budget and context window do: over it, no reservation could
    // ever succeed, so the request is unservable — a 400, not a 503
    let mut capacity = sh.cfg.sched.token_budget.min(sh.min_pos);
    if let Some(m) = sh.env.max_cost_tokens() {
        capacity = capacity.min(m);
    }
    if cost > capacity {
        return Err((
            400,
            format!("request needs {cost} tokens but the server caps at {capacity}"),
        ));
    }
    let admitted = {
        let mut b = locked(&sh.buckets);
        b.try_admit(req.qos.client, arrival_s, cost as f64)
    };
    if !admitted {
        sh.rejected_rate.fetch_add(1, Ordering::Relaxed);
        return Err((429, format!("client {} rate-limited", req.qos.client)));
    }
    let (tx, rx) = std::sync::mpsc::channel::<Reply>();
    match sh.queue.push_opts(req, Some(tx)) {
        Admit::Queued => {
            sh.queued.fetch_add(1, Ordering::Relaxed);
            Ok((internal as u64, rx))
        }
        Admit::Rejected(r) => Err((r.http_code(), r.label().to_string())),
    }
}

/// A minimal blocking client for the line protocol — what the loopback
/// drive mode (`besa serve-net --drive`), the CI smoke job and the
/// parity tests speak.
pub struct LineClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl LineClient {
    pub fn connect(addr: &SocketAddr) -> Result<LineClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting line client to {addr}"))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
        let reader = BufReader::new(stream.try_clone().context("cloning client stream")?);
        Ok(LineClient { reader, writer: stream })
    }

    /// Send one already-`\n`-terminated request line.
    pub fn send_line(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes()).context("writing request line")?;
        Ok(())
    }

    /// Read the next response event.
    pub fn read_event(&mut self) -> Result<WireEvent> {
        let mut s = String::new();
        let n = self.reader.read_line(&mut s).context("reading response line")?;
        if n == 0 {
            anyhow::bail!("server closed the connection");
        }
        parse_event(s.trim_end())
    }

    /// Send one request and collect events through its terminal event.
    pub fn request(&mut self, line: &str) -> Result<Vec<WireEvent>> {
        self.send_line(line)?;
        let mut events = Vec::new();
        loop {
            let ev = self.read_event()?;
            let terminal = ev.is_terminal();
            events.push(ev);
            if terminal {
                return Ok(events);
            }
        }
    }
}
