//! The line-delimited JSON wire protocol (and the shared response
//! bodies the HTTP adapter reuses).
//!
//! One request per `\n`-terminated line:
//!
//! ```json
//! {"id": 7, "prompt": [3, 14, 15], "max_new": 8,
//!  "deadline_ms": 250, "priority": 0, "client": 2}
//! ```
//!
//! `id` and `prompt` are required; `score: true` turns the request into
//! prompt scoring (`max_new` then being irrelevant); `deadline_ms` /
//! `priority` / `client` are the optional QoS fields. **Unknown fields
//! are rejected** (code 400) — silently ignoring a typo like
//! `"deadline_m"` would drop the client's deadline on the floor, the
//! worst possible failure mode for an overload-control protocol.
//!
//! Responses are also one JSON object per line. Tokens stream as they
//! are generated, then exactly one terminal event closes the request:
//!
//! ```json
//! {"event":"token","id":7,"index":0,"token":42}
//! {"event":"done","id":7,"status":"ok","tokens":[42,17],"nll":null,"deadline_met":true}
//! {"event":"done","id":7,"status":"shed","code":503,"waited_ms":12.5}
//! {"event":"done","id":7,"status":"rejected","code":429,"reason":"client 2 rate-limited"}
//! {"event":"done","id":7,"status":"failed","code":500,"attempts":3}
//! {"event":"error","code":400,"reason":"unknown field 'deadline_m'"}
//! ```
//!
//! A `done/ok` answered by the sparsity-tiered degrade replica
//! (`--degrade`, see `docs/robustness.md`) additionally carries
//! `"degraded":true`; the field is omitted entirely — not `false` — on
//! the primary path, so non-degraded output is byte-identical with and
//! without the feature compiled against. `done/failed` is the terminal
//! event of a request whose worker died mid-service past its retry
//! budget (or whose stream had already seen tokens — a replay never
//! emits a token twice).
//!
//! Number formatting goes through [`crate::util::json`], whose shortest
//! round-trip `f64` printing makes the NLL in a `done` line bit-exact
//! with the engine's value — the loopback parity test compares them as
//! floats, not approximately.

use anyhow::{anyhow, Result};

use crate::util::json::{self, Json};

use super::super::scheduler::{Qos, ReqKind, Request};

/// Caps on what a connection may send.
#[derive(Debug, Clone, Copy)]
pub struct ProtoLimits {
    /// request line / HTTP body byte cap (oversizes are 413s)
    pub max_line_bytes: usize,
    /// prompt token cap (with `max_new`, bounds the KV footprint the
    /// server provisioned per request)
    pub max_prompt: usize,
    /// generation cap per request
    pub max_new: usize,
}

impl Default for ProtoLimits {
    fn default() -> Self {
        ProtoLimits { max_line_bytes: 64 * 1024, max_prompt: 512, max_new: 128 }
    }
}

impl ProtoLimits {
    /// Largest KV footprint any conforming request can reach — what the
    /// server must provision per batch slot.
    pub fn max_request_tokens(&self) -> usize {
        self.max_prompt + self.max_new
    }
}

/// A parse/validation failure, with its HTTP-style status code.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtoError {
    pub code: u16,
    pub reason: String,
}

impl ProtoError {
    pub fn new(code: u16, reason: impl Into<String>) -> ProtoError {
        ProtoError { code, reason: reason.into() }
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}", self.code, self.reason)
    }
}

/// A validated wire request, not yet bound to an engine id.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    /// client-chosen correlation id, echoed in every response event
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub score: bool,
    pub qos: Qos,
}

impl WireRequest {
    /// Bind to an engine-side request id and arrival stamp.
    pub fn into_request(self, internal_id: usize, arrival_s: f64) -> Request {
        let kind = if self.score {
            ReqKind::Score
        } else {
            ReqKind::Generate { max_new: self.max_new }
        };
        Request { id: internal_id, arrival: arrival_s, tokens: self.prompt, kind, qos: self.qos }
    }
}

const KNOWN_FIELDS: [&str; 7] =
    ["id", "prompt", "max_new", "score", "deadline_ms", "priority", "client"];

fn uint_field(v: &Json, name: &str, max: f64) -> Result<f64, ProtoError> {
    match v.as_f64() {
        Some(n) if n.is_finite() && n >= 0.0 && n.fract() == 0.0 && n <= max => Ok(n),
        _ => Err(ProtoError::new(
            400,
            format!("field '{name}' must be an integer in 0..={max:.0}"),
        )),
    }
}

/// Parse and validate one request line (without its terminator) against
/// `limits`. Every failure is a [`ProtoError`] with a 4xx code; the
/// caller turns it into an `error` event or HTTP status.
pub fn parse_request(line: &str, limits: &ProtoLimits) -> Result<WireRequest, ProtoError> {
    if line.len() > limits.max_line_bytes {
        return Err(ProtoError::new(
            413,
            format!(
                "request of {} bytes exceeds the {} byte cap",
                line.len(),
                limits.max_line_bytes
            ),
        ));
    }
    let v = Json::parse(line).map_err(|e| ProtoError::new(400, format!("bad json: {e}")))?;
    let obj = v.as_obj().ok_or_else(|| ProtoError::new(400, "request must be a JSON object"))?;
    for k in obj.keys() {
        if !KNOWN_FIELDS.contains(&k.as_str()) {
            return Err(ProtoError::new(400, format!("unknown field '{k}'")));
        }
    }
    let id = match obj.get("id") {
        Some(j) => uint_field(j, "id", 9.0e15)? as u64,
        None => return Err(ProtoError::new(400, "missing field 'id'")),
    };
    let prompt_arr = obj
        .get("prompt")
        .and_then(Json::as_arr)
        .ok_or_else(|| ProtoError::new(400, "missing or non-array field 'prompt'"))?;
    if prompt_arr.is_empty() {
        return Err(ProtoError::new(400, "'prompt' must not be empty"));
    }
    if prompt_arr.len() > limits.max_prompt {
        return Err(ProtoError::new(
            413,
            format!(
                "prompt of {} tokens exceeds the {} token cap",
                prompt_arr.len(),
                limits.max_prompt
            ),
        ));
    }
    let mut prompt = Vec::with_capacity(prompt_arr.len());
    for t in prompt_arr {
        match t.as_f64() {
            Some(n)
                if n.is_finite()
                    && n.fract() == 0.0
                    && n >= i32::MIN as f64
                    && n <= i32::MAX as f64 =>
            {
                prompt.push(n as i32)
            }
            _ => return Err(ProtoError::new(400, "'prompt' tokens must be 32-bit integers")),
        }
    }
    let score = match obj.get("score") {
        None => false,
        Some(Json::Bool(b)) => *b,
        Some(_) => return Err(ProtoError::new(400, "'score' must be a boolean")),
    };
    let max_new = match obj.get("max_new") {
        None => {
            if score {
                0
            } else {
                return Err(ProtoError::new(400, "missing field 'max_new'"));
            }
        }
        Some(j) => {
            let n = uint_field(j, "max_new", limits.max_new as f64)? as usize;
            if n == 0 && !score {
                return Err(ProtoError::new(400, "'max_new' must be >= 1"));
            }
            n
        }
    };
    let deadline_s = match obj.get("deadline_ms") {
        None => f64::INFINITY,
        Some(j) => match j.as_f64() {
            Some(ms) if ms.is_finite() && ms > 0.0 && ms <= 1.0e9 => ms / 1e3,
            _ => {
                return Err(ProtoError::new(
                    400,
                    "'deadline_ms' must be a positive number of milliseconds (<= 1e9)",
                ))
            }
        },
    };
    let priority = match obj.get("priority") {
        None => 1u8,
        Some(j) => uint_field(j, "priority", 255.0)? as u8,
    };
    let client = match obj.get("client") {
        None => 0u32,
        Some(j) => uint_field(j, "client", u32::MAX as f64)? as u32,
    };
    Ok(WireRequest {
        id,
        prompt,
        max_new,
        score,
        qos: Qos { deadline_s, priority, client },
    })
}

/// Serialize a [`Request`] back into a request line (used by the
/// loopback driver and the parity tests — the exact inverse of
/// [`parse_request`] for in-range values).
pub fn request_line(wire_id: u64, r: &Request) -> String {
    let mut fields: Vec<(&str, Json)> = vec![
        ("id", json::num(wire_id as f64)),
        ("prompt", json::arr(r.tokens.iter().map(|t| json::num(*t as f64)))),
    ];
    match r.kind {
        ReqKind::Score => fields.push(("score", Json::Bool(true))),
        ReqKind::Generate { max_new } => fields.push(("max_new", json::num(max_new as f64))),
    }
    if r.qos.deadline_s.is_finite() {
        fields.push(("deadline_ms", json::num(r.qos.deadline_s * 1e3)));
    }
    if r.qos.priority != 1 {
        fields.push(("priority", json::num(r.qos.priority as f64)));
    }
    if r.qos.client != 0 {
        fields.push(("client", json::num(r.qos.client as f64)));
    }
    let mut line = json::obj(fields).to_string();
    line.push('\n');
    line
}

fn nll_json(nll: Option<f64>) -> Json {
    match nll {
        Some(v) => json::num(v),
        None => Json::Null,
    }
}

/// `token` stream event line.
pub fn token_line(id: u64, index: usize, token: i32) -> String {
    let mut line = json::obj(vec![
        ("event", json::s("token")),
        ("id", json::num(id as f64)),
        ("index", json::num(index as f64)),
        ("token", json::num(token as f64)),
    ])
    .to_string();
    line.push('\n');
    line
}

/// Terminal `done/ok` body (no terminator — the HTTP adapter sends it as
/// a response body). `degraded` appears only when true, so the primary
/// path's bytes are identical to a build that never heard of tiers.
pub fn done_body(
    id: u64,
    tokens: &[i32],
    nll: Option<f64>,
    deadline_met: bool,
    degraded: bool,
) -> String {
    let mut fields = vec![
        ("event", json::s("done")),
        ("id", json::num(id as f64)),
        ("status", json::s("ok")),
        ("tokens", json::arr(tokens.iter().map(|t| json::num(*t as f64)))),
        ("nll", nll_json(nll)),
        ("deadline_met", Json::Bool(deadline_met)),
    ];
    if degraded {
        fields.push(("degraded", Json::Bool(true)));
    }
    json::obj(fields).to_string()
}

pub fn done_line(
    id: u64,
    tokens: &[i32],
    nll: Option<f64>,
    deadline_met: bool,
    degraded: bool,
) -> String {
    let mut line = done_body(id, tokens, nll, deadline_met, degraded);
    line.push('\n');
    line
}

/// Terminal `done/failed` body: the request's worker died mid-service
/// and recovery could not replay it (retry budget or deadline exhausted,
/// or tokens had already streamed).
pub fn failed_body(id: u64, attempts: u32) -> String {
    json::obj(vec![
        ("event", json::s("done")),
        ("id", json::num(id as f64)),
        ("status", json::s("failed")),
        ("code", json::num(500.0)),
        ("attempts", json::num(attempts as f64)),
    ])
    .to_string()
}

pub fn failed_line(id: u64, attempts: u32) -> String {
    let mut line = failed_body(id, attempts);
    line.push('\n');
    line
}

/// Terminal `done/shed` body: the deadline passed while queued.
pub fn shed_body(id: u64, waited_s: f64) -> String {
    json::obj(vec![
        ("event", json::s("done")),
        ("id", json::num(id as f64)),
        ("status", json::s("shed")),
        ("code", json::num(503.0)),
        ("waited_ms", json::num(waited_s * 1e3)),
    ])
    .to_string()
}

pub fn shed_line(id: u64, waited_s: f64) -> String {
    let mut line = shed_body(id, waited_s);
    line.push('\n');
    line
}

/// Terminal `done/rejected` body: turned away at admission.
pub fn reject_body(id: u64, code: u16, reason: &str) -> String {
    json::obj(vec![
        ("event", json::s("done")),
        ("id", json::num(id as f64)),
        ("status", json::s("rejected")),
        ("code", json::num(code as f64)),
        ("reason", json::s(reason)),
    ])
    .to_string()
}

pub fn reject_line(id: u64, code: u16, reason: &str) -> String {
    let mut line = reject_body(id, code, reason);
    line.push('\n');
    line
}

/// Connection-level `error` body (no request id: the line never parsed).
pub fn error_body(code: u16, reason: &str) -> String {
    json::obj(vec![
        ("event", json::s("error")),
        ("code", json::num(code as f64)),
        ("reason", json::s(reason)),
    ])
    .to_string()
}

pub fn error_line(code: u16, reason: &str) -> String {
    let mut line = error_body(code, reason);
    line.push('\n');
    line
}

/// Client-side view of one response line.
#[derive(Debug, Clone, PartialEq)]
pub enum WireEvent {
    Token { id: u64, index: usize, token: i32 },
    Done { id: u64, tokens: Vec<i32>, nll: Option<f64>, deadline_met: bool, degraded: bool },
    Shed { id: u64, code: u16, waited_ms: f64 },
    Rejected { id: u64, code: u16, reason: String },
    Failed { id: u64, code: u16, attempts: u32 },
    Error { code: u16, reason: String },
}

impl WireEvent {
    /// True for the event that closes a request (everything but `token`).
    pub fn is_terminal(&self) -> bool {
        !matches!(self, WireEvent::Token { .. })
    }
}

fn need_f64(v: &Json, key: &str) -> Result<f64> {
    v.get(key).and_then(Json::as_f64).ok_or_else(|| anyhow!("missing numeric '{key}'"))
}

fn need_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.get(key).and_then(Json::as_str).ok_or_else(|| anyhow!("missing string '{key}'"))
}

/// Parse one response line (the client half of the protocol, used by the
/// loopback driver and the tests).
pub fn parse_event(line: &str) -> Result<WireEvent> {
    let v = Json::parse(line)?;
    match need_str(&v, "event")? {
        "token" => Ok(WireEvent::Token {
            id: need_f64(&v, "id")? as u64,
            index: need_f64(&v, "index")? as usize,
            token: need_f64(&v, "token")? as i32,
        }),
        "error" => Ok(WireEvent::Error {
            code: need_f64(&v, "code")? as u16,
            reason: need_str(&v, "reason")?.to_string(),
        }),
        "done" => {
            let id = need_f64(&v, "id")? as u64;
            match need_str(&v, "status")? {
                "ok" => {
                    let tokens = v
                        .get("tokens")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("done/ok without 'tokens'"))?
                        .iter()
                        .map(|t| t.as_f64().map(|n| n as i32))
                        .collect::<Option<Vec<i32>>>()
                        .ok_or_else(|| anyhow!("non-numeric token in done/ok"))?;
                    let nll = v.get("nll").and_then(Json::as_f64);
                    let deadline_met = matches!(v.get("deadline_met"), Some(Json::Bool(true)));
                    let degraded = matches!(v.get("degraded"), Some(Json::Bool(true)));
                    Ok(WireEvent::Done { id, tokens, nll, deadline_met, degraded })
                }
                "shed" => Ok(WireEvent::Shed {
                    id,
                    code: need_f64(&v, "code")? as u16,
                    waited_ms: need_f64(&v, "waited_ms")?,
                }),
                "rejected" => Ok(WireEvent::Rejected {
                    id,
                    code: need_f64(&v, "code")? as u16,
                    reason: need_str(&v, "reason")?.to_string(),
                }),
                "failed" => Ok(WireEvent::Failed {
                    id,
                    code: need_f64(&v, "code")? as u16,
                    attempts: need_f64(&v, "attempts")? as u32,
                }),
                other => Err(anyhow!("unknown done status '{other}'")),
            }
        }
        other => Err(anyhow!("unknown event '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limits() -> ProtoLimits {
        ProtoLimits { max_line_bytes: 256, max_prompt: 8, max_new: 16 }
    }

    #[test]
    fn minimal_request_parses_with_defaults() {
        let w = parse_request(r#"{"id": 3, "prompt": [1, 2, 3], "max_new": 4}"#, &limits())
            .unwrap();
        assert_eq!(w.id, 3);
        assert_eq!(w.prompt, vec![1, 2, 3]);
        assert_eq!(w.max_new, 4);
        assert!(!w.score);
        assert!(w.qos.deadline_s.is_infinite());
        assert_eq!((w.qos.priority, w.qos.client), (1, 0));
    }

    #[test]
    fn qos_fields_parse() {
        let w = parse_request(
            r#"{"id":1,"prompt":[5],"max_new":2,"deadline_ms":250,"priority":0,"client":7}"#,
            &limits(),
        )
        .unwrap();
        assert_eq!(w.qos.deadline_s, 0.25);
        assert_eq!((w.qos.priority, w.qos.client), (0, 7));
    }

    #[test]
    fn score_requests_need_no_max_new() {
        let w = parse_request(r#"{"id":1,"prompt":[5,6],"score":true}"#, &limits()).unwrap();
        assert!(w.score);
        let r = w.into_request(42, 1.5);
        assert_eq!(r.kind, ReqKind::Score);
        assert_eq!((r.id, r.arrival), (42, 1.5));
    }

    /// Fuzz-ish rejection table: every malformed line maps to the right
    /// 4xx without panicking.
    #[test]
    fn malformed_requests_reject_with_codes() {
        let l = limits();
        let cases: Vec<(&str, u16)> = vec![
            // truncated / bad json
            (r#"{"id": 3, "prompt": [1,"#, 400),
            (r#""#, 400),
            (r#"garbage"#, 400),
            (r#"[1,2,3]"#, 400),
            (r#"null"#, 400),
            // unknown fields are rejected, not ignored
            (r#"{"id":1,"prompt":[1],"max_new":2,"deadline_m":9}"#, 400),
            (r#"{"id":1,"prompt":[1],"max_new":2,"extra":true}"#, 400),
            // missing requireds
            (r#"{"prompt":[1],"max_new":2}"#, 400),
            (r#"{"id":1,"max_new":2}"#, 400),
            (r#"{"id":1,"prompt":[1]}"#, 400),
            // type errors
            (r#"{"id":"x","prompt":[1],"max_new":2}"#, 400),
            (r#"{"id":1.5,"prompt":[1],"max_new":2}"#, 400),
            (r#"{"id":-1,"prompt":[1],"max_new":2}"#, 400),
            (r#"{"id":1,"prompt":[1.5],"max_new":2}"#, 400),
            (r#"{"id":1,"prompt":[1e12],"max_new":2}"#, 400),
            (r#"{"id":1,"prompt":"abc","max_new":2}"#, 400),
            (r#"{"id":1,"prompt":[],"max_new":2}"#, 400),
            (r#"{"id":1,"prompt":[1],"max_new":0}"#, 400),
            (r#"{"id":1,"prompt":[1],"max_new":2,"score":1}"#, 400),
            (r#"{"id":1,"prompt":[1],"max_new":2,"deadline_ms":0}"#, 400),
            (r#"{"id":1,"prompt":[1],"max_new":2,"deadline_ms":-5}"#, 400),
            (r#"{"id":1,"prompt":[1],"max_new":2,"priority":300}"#, 400),
            // oversizes
            (r#"{"id":1,"prompt":[1,2,3,4,5,6,7,8,9],"max_new":2}"#, 413),
            (r#"{"id":1,"prompt":[1],"max_new":17}"#, 400),
        ];
        for (line, want) in cases {
            match parse_request(line, &l) {
                Err(e) => assert_eq!(e.code, want, "line {line:?} gave {e}"),
                Ok(w) => panic!("line {line:?} unexpectedly parsed: {w:?}"),
            }
        }
        // the byte cap trips before json parsing
        let huge = format!(r#"{{"id":1,"prompt":[{}],"max_new":2}}"#, "1,".repeat(400) + "1");
        assert_eq!(parse_request(&huge, &l).unwrap_err().code, 413);
    }

    #[test]
    fn request_line_round_trips_through_parse() {
        let l = ProtoLimits::default();
        let reqs = vec![
            Request {
                id: 9,
                arrival: 0.0,
                tokens: vec![1, 2, 3],
                kind: ReqKind::Generate { max_new: 5 },
                qos: Qos { deadline_s: 0.25, priority: 2, client: 3 },
            },
            Request {
                id: 10,
                arrival: 0.0,
                tokens: vec![-4, 0, 7],
                kind: ReqKind::Score,
                qos: Qos::default(),
            },
        ];
        for r in reqs {
            let line = request_line(r.id as u64, &r);
            let w = parse_request(line.trim(), &l).unwrap();
            assert_eq!(w.id, r.id as u64);
            assert_eq!(w.prompt, r.tokens);
            assert_eq!(w.qos, r.qos);
            let back = w.into_request(r.id, r.arrival);
            assert_eq!(back.kind, r.kind);
        }
    }

    #[test]
    fn response_lines_parse_as_events() {
        let ev = parse_event(token_line(7, 0, 42).trim()).unwrap();
        assert_eq!(ev, WireEvent::Token { id: 7, index: 0, token: 42 });
        assert!(!ev.is_terminal());

        let line = done_line(7, &[42, 17], None, true, false);
        assert!(
            !line.contains("degraded"),
            "primary-path done lines must not carry a degraded key: {line}"
        );
        let ev = parse_event(line.trim()).unwrap();
        assert_eq!(
            ev,
            WireEvent::Done {
                id: 7,
                tokens: vec![42, 17],
                nll: None,
                deadline_met: true,
                degraded: false
            }
        );
        assert!(ev.is_terminal());

        let line = done_line(7, &[42], None, true, true);
        assert!(line.contains(r#""degraded":true"#), "degrade tier must be marked: {line}");
        match parse_event(line.trim()).unwrap() {
            WireEvent::Done { degraded, .. } => assert!(degraded),
            other => panic!("bad event {other:?}"),
        }

        // NLL round-trips bit-exactly through the shortest-repr writer
        let nll = 123.456789012345678_f64 / 7.0;
        match parse_event(done_line(1, &[], Some(nll), false, false).trim()).unwrap() {
            WireEvent::Done { nll: Some(back), deadline_met, .. } => {
                assert_eq!(back, nll, "f64 must round-trip exactly over the wire");
                assert!(!deadline_met);
            }
            other => panic!("bad event {other:?}"),
        }

        let ev = parse_event(shed_line(5, 0.0125).trim()).unwrap();
        assert_eq!(ev, WireEvent::Shed { id: 5, code: 503, waited_ms: 12.5 });

        let ev = parse_event(reject_line(6, 429, "client 2 rate-limited").trim()).unwrap();
        assert!(matches!(ev, WireEvent::Rejected { id: 6, code: 429, .. }));

        let ev = parse_event(failed_line(9, 3).trim()).unwrap();
        assert_eq!(ev, WireEvent::Failed { id: 9, code: 500, attempts: 3 });
        assert!(ev.is_terminal());

        let ev = parse_event(error_line(400, "bad json").trim()).unwrap();
        assert!(matches!(ev, WireEvent::Error { code: 400, .. }));

        assert!(parse_event("{}").is_err());
        assert!(parse_event(r#"{"event":"mystery"}"#).is_err());
    }
}
