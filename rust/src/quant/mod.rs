//! Weight-only min-max quantization (paper Eqn. 7, OmniQuant-style).
//!
//! The joint BESA+quant optimization lives in [`crate::prune::besa`] (the
//! `besa_quant_step_row` artifact learns clipping strengths γ alongside the
//! sparsity logits). This module provides the rust-side quantizer used for
//! the Joint-Wanda baseline (Table 3: quantize first, then Wanda-prune) and
//! for materializing quantized checkpoints; it is bit-exact with the
//! `fake_quant` Pallas kernel (cross-checked in integration tests against
//! the `quant_apply_*` artifacts).

use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantSpec {
    pub bits: u32,
    pub gamma0: f32,
    pub gamma1: f32,
}

impl Default for QuantSpec {
    fn default() -> Self {
        QuantSpec { bits: 4, gamma0: 1.0, gamma1: 1.0 }
    }
}

/// Fake-quantize a weight tensor: quantize to `bits` integers with min-max
/// scaling and learnable clipping, dequantize back to f32.
pub fn fake_quant(w: &Tensor, spec: QuantSpec) -> Tensor {
    let data = w.f32s();
    let qmax = (2f64.powi(spec.bits as i32) - 1.0) as f32;
    let wmin = data.iter().cloned().fold(f32::INFINITY, f32::min) * spec.gamma0;
    let wmax = data.iter().cloned().fold(f32::NEG_INFINITY, f32::max) * spec.gamma1;
    let h = ((wmax - wmin) / qmax).max(1e-8);
    let z = (-wmin / h).round();
    let out: Vec<f32> = data
        .iter()
        .map(|v| {
            let q = ((v / h).round() + z).clamp(0.0, qmax);
            (q - z) * h
        })
        .collect();
    Tensor::from_f32(&w.shape, out)
}

/// Quantize all prunable weights of a model in place (per-tensor spec).
pub fn quantize_model(
    params: &mut crate::model::ParamStore,
    cfg: &crate::model::ModelConfig,
    spec: QuantSpec,
) -> anyhow::Result<()> {
    for l in 0..cfg.n_blocks {
        for w in crate::model::LAYER_NAMES {
            let name = crate::model::ParamStore::layer_name(l, w);
            let q = fake_quant(params.get(&name)?, spec);
            params.set(&name, q)?;
        }
    }
    Ok(())
}

/// Mean squared quantization error (diagnostics + tests).
pub fn quant_mse(w: &Tensor, spec: QuantSpec) -> f64 {
    let q = fake_quant(w, spec);
    w.f32s()
        .iter()
        .zip(q.f32s())
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / w.numel() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_w(seed: u64) -> Tensor {
        let mut rng = Rng::seed(seed);
        Tensor::from_f32(&[16, 16], (0..256).map(|_| rng.normal_f32()).collect())
    }

    #[test]
    fn level_count_bounded() {
        let w = random_w(1);
        for bits in [2, 3, 4] {
            let q = fake_quant(&w, QuantSpec { bits, ..Default::default() });
            let mut levels: Vec<i64> = q.f32s().iter().map(|v| (v * 1e6) as i64).collect();
            levels.sort();
            levels.dedup();
            assert!(levels.len() <= 1 << bits, "bits={bits}: {} levels", levels.len());
        }
    }

    #[test]
    fn more_bits_less_error() {
        let w = random_w(2);
        let e4 = quant_mse(&w, QuantSpec { bits: 4, ..Default::default() });
        let e8 = quant_mse(&w, QuantSpec { bits: 8, ..Default::default() });
        assert!(e8 < e4 / 10.0, "e4={e4:.3e} e8={e8:.3e}");
    }

    #[test]
    fn sixteen_bits_near_lossless() {
        let w = random_w(3);
        assert!(quant_mse(&w, QuantSpec { bits: 16, ..Default::default() }) < 1e-8);
    }

    #[test]
    fn clipping_shrinks_range() {
        let w = random_w(4);
        let q = fake_quant(&w, QuantSpec { bits: 4, gamma0: 0.5, gamma1: 0.5 });
        let maxabs = q.f32s().iter().cloned().fold(0.0f32, |a, b| a.max(b.abs()));
        let maxabs_w = w.f32s().iter().cloned().fold(0.0f32, |a, b| a.max(b.abs()));
        assert!(maxabs <= maxabs_w * 0.75);
    }

    #[test]
    fn zeros_preserved() {
        // quantization must map 0.0 exactly to 0.0 (pruned weights stay
        // pruned after quantization) as long as 0 is a representable level
        let mut w = random_w(5);
        for i in 0..64 {
            w.f32s_mut()[i] = 0.0;
        }
        let q = fake_quant(&w, QuantSpec::default());
        for i in 0..64 {
            assert!(
                q.f32s()[i].abs() < 1e-6,
                "zero weight quantized to {}",
                q.f32s()[i]
            );
        }
    }
}
